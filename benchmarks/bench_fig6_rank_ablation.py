"""Paper Fig. 6: influence of TR rank on operator quality vs acceleration.

Micro-scale proxy (CPU container): gpt-micro -> width / depth / both growth,
ranks {1, 4, 7, 10}.  For each (growth-type, rank): train the Mango operator
a few steps and report the operator-trained loss (paper's "operator
accuracy" analogue, lower=better).  For rank 1 vs 10 additionally measure
steps-to-target of continued training (paper's acceleration ratio): the
paper's finding — quality rises with rank, acceleration stays flat, rank 1
suffices — is what this reproduces.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import flops_saving_ratio, train_to_target
from repro.configs.base import get_config
from repro.core import grow as growlib
from repro.data.synthetic import lm_data_iter
from repro.models import get_family
from repro.train.loss import loss_for

RANKS = (1, 4, 7, 10)
OP_STEPS = 30
SEQ, BATCH = 64, 8


def _loss_fn(cfg):
    fam = get_family(cfg)
    lf = loss_for(cfg)

    def fn(params, batch):
        logits, aux = fam.forward(params, batch, cfg)
        return lf(logits, aux, batch, cfg)[0]

    return fn


def _pretrained_small(cfg_s, steps=150):
    fam = get_family(cfg_s)
    params = fam.init(jax.random.PRNGKey(0), cfg_s)
    _, hist = train_to_target(cfg_s, params, target_loss=-1.0,
                              max_steps=steps, batch=BATCH, seq=SEQ)
    # re-train (train_to_target donates params); rebuild quickly
    params = fam.init(jax.random.PRNGKey(0), cfg_s)
    from repro.optim import OptimizerConfig, make_optimizer
    from repro.train.steps import make_train_step
    opt_cfg = OptimizerConfig(lr=1e-3)
    init_fn, _ = make_optimizer(opt_cfg)
    opt = init_fn(params)
    step = jax.jit(make_train_step(cfg_s, opt_cfg))
    data = lm_data_iter(cfg_s.vocab_size, BATCH, SEQ, seed=0)
    for s in range(steps):
        b = {k: jnp.asarray(v) for k, v in next(data).items()}
        params, opt, m = step(params, opt, b, jnp.int32(s + 1))
    return params, float(m["loss"])


def run(print_fn=print, quick=False):
    cfg_s = get_config("gpt-micro")
    growths = {
        "width": cfg_s.replace(name="w", d_model=128, n_heads=8,
                               n_kv_heads=8, d_ff=512),
        "depth": cfg_s.replace(name="d", n_layers=8),
        "both": get_config("gpt-micro-big"),
    }
    small, small_loss = _pretrained_small(cfg_s, steps=60 if quick else 150)
    print_fn(f"fig6/small_pretrained_loss,{small_loss:.4f},")
    ranks = RANKS[:2] if quick else RANKS
    results = {}
    for gname, cfg_t in growths.items():
        for rank in ranks:
            gop, op_params = growlib.build("mango", cfg_s, cfg_t, rank=rank,
                                           rng=jax.random.PRNGKey(rank))
            data = lm_data_iter(cfg_t.vocab_size, BATCH, SEQ, seed=3)
            op_params, losses = growlib.train_operator(
                gop, op_params, small, _loss_fn(cfg_t),
                iter({k: jnp.asarray(v) for k, v in b.items()}
                     for b in data), steps=OP_STEPS, lr=2e-3)
            results[(gname, rank)] = (losses[0], losses[-1])
            print_fn(f"fig6/{gname}_rank{rank},"
                     f"{losses[-1]:.4f},op_loss_start={losses[0]:.4f}")
            if rank in (1, ranks[-1]):
                big = growlib.grow_params(gop, op_params, small)
                target = small_loss * 1.02
                steps_used, _ = train_to_target(
                    cfg_t, big, target_loss=target,
                    max_steps=60 if quick else 200, batch=BATCH, seq=SEQ,
                    seed=7)
                print_fn(f"fig6/{gname}_rank{rank}_steps_to_small_loss,"
                         f"{steps_used},target={target:.4f}")
    return results


if __name__ == "__main__":
    run()
