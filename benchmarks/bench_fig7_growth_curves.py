"""Paper Fig. 7: growth-method comparison — FLOPs saving ratio (Eq. 8).

Micro-scale proxy of the GPT experiment: pretrain gpt-micro, grow to
gpt-micro-big with each method (Mango / LiGO / bert2BERT / StackBERT-depth /
scratch), train the target to a fixed loss, and report Eq. 8

    r = (xi_scratch - xi_method) / xi_scratch

with FLOPs ∝ steps (fixed batch/model) and Mango/LiGO's operator warm
training charged at target-model step cost.  The paper's ordering to
reproduce: Mango >= bert2BERT/LiGO >> StackBERT > scratch(=0).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import flops_saving_ratio, train_to_target
from benchmarks.bench_fig6_rank_ablation import (_loss_fn,
                                                 _pretrained_small)
from repro.configs.base import get_config
from repro.core import grow as growlib
from repro.data.synthetic import lm_data_iter

SEQ, BATCH = 64, 8
OP_STEPS = 30


def run(print_fn=print, quick=False):
    cfg_s = get_config("gpt-micro")
    cfg_t = get_config("gpt-micro-big")
    max_steps = 120 if quick else 400
    small, small_loss = _pretrained_small(cfg_s, steps=60 if quick else 150)

    # scratch baseline defines the target metric \Psi
    fam_t = __import__("repro.models", fromlist=["get_family"]) \
        .get_family(cfg_t)
    scratch = fam_t.init(jax.random.PRNGKey(42), cfg_t)
    steps_scratch, hist = train_to_target(
        cfg_t, scratch, target_loss=-1.0, max_steps=max_steps, batch=BATCH,
        seq=SEQ, seed=11)
    target = float(min(hist)) * 1.0
    # re-run scratch against its own target to get steps_scratch
    scratch = fam_t.init(jax.random.PRNGKey(42), cfg_t)
    steps_scratch, _ = train_to_target(
        cfg_t, scratch, target_loss=target, max_steps=max_steps,
        batch=BATCH, seq=SEQ, seed=11)
    print_fn(f"fig7/scratch_steps,{steps_scratch},target={target:.4f}")

    results = {"scratch": 0.0}
    for method in ("mango", "ligo", "bert2bert", "stackbert"):
        if method == "stackbert":
            cfg_src, warm = cfg_s.replace(name="sd", d_model=128,
                                          n_heads=8, n_kv_heads=8,
                                          d_ff=512), 0
            # stackbert needs width match: pretrain a width-matched small
            fam_s = __import__("repro.models",
                               fromlist=["get_family"]).get_family(cfg_src)
            src = fam_s.init(jax.random.PRNGKey(0), cfg_src)
            src_steps = 60 if quick else 150
            from repro.optim import OptimizerConfig, make_optimizer
            from repro.train.steps import make_train_step
            oc = OptimizerConfig(lr=1e-3)
            ifn, _ = make_optimizer(oc)
            opt = ifn(src)
            stp = jax.jit(make_train_step(cfg_src, oc))
            data = lm_data_iter(cfg_src.vocab_size, BATCH, SEQ, seed=0)
            for s in range(src_steps):
                b = {k: jnp.asarray(v) for k, v in next(data).items()}
                src, opt, _ = stp(src, opt, b, jnp.int32(s + 1))
        else:
            cfg_src, src, warm = cfg_s, small, \
                (OP_STEPS if method in ("mango", "ligo") else 0)
        gop, op_params = growlib.build(method, cfg_src, cfg_t, rank=1,
                                       rng=jax.random.PRNGKey(1))
        if gop.trainable:
            data = lm_data_iter(cfg_t.vocab_size, BATCH, SEQ, seed=3)
            op_params, _ = growlib.train_operator(
                gop, op_params, src, _loss_fn(cfg_t),
                iter({k: jnp.asarray(v) for k, v in b.items()}
                     for b in data), steps=OP_STEPS, lr=2e-3)
        big = growlib.grow_params(gop, op_params, src)
        steps_used, _ = train_to_target(
            cfg_t, big, target_loss=target, max_steps=max_steps,
            batch=BATCH, seq=SEQ, seed=11)
        r = flops_saving_ratio(steps_scratch, steps_used, warm_steps=warm)
        results[method] = r
        print_fn(f"fig7/{method},{steps_used},saving_ratio={r:.3f}")
    return results


if __name__ == "__main__":
    run()
