"""Kernel microbenchmarks: interpret-mode vs jnp-reference wall time.

On CPU the interpreter is NOT the perf story (TPU is the target); this
bench is here so the harness exercises every kernel end-to-end and records
the reference-path timings used to sanity-check relative costs.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import time_call
from repro.kernels import ops, ref


def run(print_fn=print):
    k = jax.random.PRNGKey(0)
    # tr_sandwich
    x = jax.random.normal(k, (4, 256, 256), jnp.float32)
    ai = 0.05 * jax.random.normal(jax.random.PRNGKey(1), (256, 256))
    ao = 0.05 * jax.random.normal(jax.random.PRNGKey(2), (256, 256))
    us = time_call(jax.jit(ref.tr_sandwich_ref), x, ai, ao)
    print_fn(f"kernels/tr_sandwich_ref,{us:.0f},shape=4x256x256")

    q = jax.random.normal(k, (1, 4, 512, 64), jnp.float32)
    kk = jax.random.normal(k, (1, 2, 512, 64), jnp.float32)
    vv = jax.random.normal(k, (1, 2, 512, 64), jnp.float32)
    us = time_call(jax.jit(lambda a, b, c: ref.flash_attention_ref(
        a, b, c, causal=True)), q, kk, vv)
    print_fn(f"kernels/flash_attention_ref,{us:.0f},shape=1x4x512x64")

    qd = jax.random.normal(k, (2, 8, 64), jnp.float32)
    us = time_call(jax.jit(lambda a, b, c: ref.decode_attention_ref(
        a, b, c, 500)), qd, kk.repeat(2, 0), vv.repeat(2, 0))
    print_fn(f"kernels/decode_attention_ref,{us:.0f},cache=512")

    a = jax.nn.sigmoid(jax.random.normal(k, (2, 512, 256)))
    b = 0.1 * jax.random.normal(k, (2, 512, 256))
    us = time_call(jax.jit(ref.rglru_scan_ref), a, b)
    print_fn(f"kernels/rglru_scan_ref,{us:.0f},shape=2x512x256")
    return True


if __name__ == "__main__":
    run()
