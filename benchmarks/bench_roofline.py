"""Roofline analysis over the dry-run results (§Roofline deliverable).

Reads results/dryrun/*.json (written by repro.launch.dryrun) and derives,
per (arch x shape x mesh):

    compute term    = HLO_FLOPs_per_device / peak_FLOPs        [s]
    memory term     = HLO_bytes_per_device / HBM_bw            [s]
    collective term = wire_bytes_per_device / (links * link_bw) [s]

Hardware: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI.
Also reports MODEL_FLOPS = 6*N(_active)*D tokens and the useful-compute
ratio MODEL_FLOPS / HLO_FLOPs (catches remat/dispatch/causal-waste).
"""
from __future__ import annotations

import glob
import json
import os

from repro.configs.base import SHAPES, get_config
from repro.models import get_family
from repro.utils.pytree import tree_param_count

PEAK_FLOPS = 197e12  # bf16 per chip
HBM_BW = 819e9
LINK_BW = 50e9

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "results",
                           "dryrun")


def active_param_count(cfg):
    """Params touched per token (MoE: top_k of routed experts + shared)."""
    import jax
    fam = get_family(cfg)
    shapes = jax.eval_shape(
        lambda: fam.init(jax.random.PRNGKey(0), cfg))
    total = tree_param_count(shapes)
    if not cfg.moe:
        return total, total
    moe = shapes.get("moe_blocks", {}).get("moe", {})
    routed = sum(tree_param_count(moe.get(k, {}))
                 for k in ("w_up", "w_gate", "w_down"))
    active = total - routed + routed * cfg.top_k / cfg.n_experts
    return total, int(active)


def model_flops(cfg, shape):
    """6*N*D for train, 2*N*D for prefill, 2*N per token for decode."""
    shp = SHAPES[shape]
    total, active = active_param_count(cfg)
    n = active
    if shp.kind == "train":
        tokens = shp.global_batch * shp.seq_len
        return 6.0 * n * tokens
    if shp.kind == "prefill":
        tokens = shp.global_batch * shp.seq_len
        return 2.0 * n * tokens
    return 2.0 * n * shp.global_batch  # decode: one token per row


def analyze(result):
    n_dev = result["n_devices"]
    flops = result.get("flops_per_device")
    nbytes = result.get("bytes_accessed_per_device")
    colls = result.get("collective_bytes_per_device", {})
    coll_bytes = sum(colls.values())
    t_compute = flops / PEAK_FLOPS
    t_memory = nbytes / HBM_BW
    t_coll = coll_bytes / LINK_BW
    terms = {"compute_s": t_compute, "memory_s": t_memory,
             "collective_s": t_coll}
    bottleneck = max(terms, key=terms.get)
    cfg = get_config(result["arch"])
    mf = model_flops(cfg, result["shape"]) if result["shape"] in SHAPES \
        else None
    out = {
        **terms,
        "bottleneck": bottleneck.replace("_s", ""),
        "step_time_bound_s": max(terms.values()),
        "model_flops_global": mf,
        "useful_compute_ratio":
            (mf / (flops * n_dev)) if mf else None,
        "roofline_fraction":
            (t_compute / max(terms.values())) if mf else None,
        "hbm_gib_per_device": (result["memory"]["argument_bytes"]
                               + result["memory"]["temp_bytes"]) / 2**30,
    }
    return out


def run(print_fn=print):
    rows = []
    for path in sorted(glob.glob(os.path.join(RESULTS_DIR, "*.json"))):
        with open(path) as f:
            r = json.load(f)
        if r.get("status") != "ok" or "flops_per_device" not in r:
            continue
        a = analyze(r)
        key = f"{r['arch']}__{r['shape']}__{r['mesh']}"
        print_fn(
            f"roofline/{key},{a['step_time_bound_s'] * 1e6:.0f},"
            f"bottleneck={a['bottleneck']};"
            f"compute_s={a['compute_s']:.3f};"
            f"memory_s={a['memory_s']:.3f};"
            f"collective_s={a['collective_s']:.3f};"
            f"useful={a['useful_compute_ratio'] or 0:.3f};"
            f"hbm_gib={a['hbm_gib_per_device']:.1f}")
        rows.append((key, a))
    return rows


if __name__ == "__main__":
    run()
