"""Serving benchmark: continuous batching vs the naive lock-step loop.

A Poisson arrival trace of mixed-length requests is replayed against
wall-clock time through both engines:

  * naive      — requests are collected into fixed batches; each batch
                 waits for all its members to arrive, then runs prefill +
                 lock-step decode to the LONGEST request's length
                 (``launch/serve.generate``); the next batch waits behind;
  * continuous — the slot-pool engine admits each request as soon as a
                 slot frees up and decodes all in-flight slots in one step.

Reported: total tok/s and per-request completion-latency percentiles
(p50/p99, seconds from arrival to last token).

Run:  PYTHONPATH=src:. python benchmarks/bench_serve_engine.py [--quick]
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import get_config
from repro.data.synthetic import lm_batch
from repro.launch.serve import generate
from repro.models import get_family
from repro.serve import ContinuousBatchingEngine, Request


def poisson_trace(cfg, n, *, rate_hz, seed=0, max_prompt=24, max_gen=16):
    """n requests with exponential inter-arrival gaps at ``rate_hz``."""
    rng = np.random.default_rng(seed)
    t = 0.0
    reqs = []
    for uid in range(n):
        t += float(rng.exponential(1.0 / rate_hz))
        plen = int(rng.integers(4, max_prompt + 1))
        gen = int(rng.integers(2, max_gen + 1))
        prompt = lm_batch(cfg.vocab_size, 1, plen, seed=300 + uid)[0]
        reqs.append(Request(uid=uid, prompt=prompt, max_new_tokens=gen,
                            arrival=t))
    return reqs


def _pctl(lat):
    return (float(np.percentile(lat, 50)), float(np.percentile(lat, 99)))


def warm_naive(cfg, params, reqs, batch):
    """Compile every (chunk, pmax, gmax) shape the naive loop will hit, so
    the timed comparison measures serving, not XLA retraces."""
    for i in range(0, len(reqs), batch):
        chunk = reqs[i:i + batch]
        pmax = max(len(r.prompt) for r in chunk)
        gmax = max(r.max_new_tokens for r in chunk)
        generate(cfg, params, jnp.zeros((len(chunk), pmax), jnp.int32),
                 max_new_tokens=gmax)


def bench_naive(cfg, params, reqs, batch):
    t0 = time.monotonic()
    lat = []
    n_tok = 0
    for i in range(0, len(reqs), batch):
        chunk = reqs[i:i + batch]
        wait = max(r.arrival for r in chunk) - (time.monotonic() - t0)
        if wait > 0:  # the whole batch must have arrived before it can run
            time.sleep(wait)
        pmax = max(len(r.prompt) for r in chunk)
        gmax = max(r.max_new_tokens for r in chunk)
        prompts = np.zeros((len(chunk), pmax), np.int32)
        for j, r in enumerate(chunk):
            prompts[j, pmax - len(r.prompt):] = r.prompt  # left-pad
        toks = generate(cfg, params, jnp.asarray(prompts),
                        max_new_tokens=gmax)
        jax.block_until_ready(toks)
        done = time.monotonic() - t0
        for r in chunk:
            lat.append(done - r.arrival)
            n_tok += r.max_new_tokens
    return n_tok / (time.monotonic() - t0), _pctl(lat)


def bench_continuous(cfg, params, reqs, *, capacity, max_len):
    engine = ContinuousBatchingEngine(cfg, params, capacity=capacity,
                                      max_len=max_len)
    t0 = time.monotonic()
    engine.run(reqs, realtime=True)
    dt = time.monotonic() - t0
    n_tok = sum(len(v) for v in engine.finished.values())
    by_uid = {r.uid: r for r in reqs}
    # t_done stamps are absolute monotonic times; arrivals are trace offsets
    lat = [(s.t_done - t0) - by_uid[s.req.uid].arrival
           for s in engine.retired]
    return n_tok / dt, _pctl(lat), engine


def run(quick: bool = False):
    cfg = get_config("qwen1.5-0.5b-smoke")
    fam = get_family(cfg)
    params = fam.init(jax.random.PRNGKey(0), cfg)
    n = 12 if quick else 32
    capacity = 4
    max_len = 48
    reqs = poisson_trace(cfg, n, rate_hz=8.0)

    # warm both engines' compile caches outside the timed runs — one
    # request per distinct prefill-bucket shape the trace will hit
    warm_naive(cfg, params, reqs, capacity)
    warm = ContinuousBatchingEngine(cfg, params, capacity=capacity,
                                    max_len=max_len)
    buckets = {warm._bucketed(len(r.prompt)) for r in reqs}
    warm.run([Request(uid=-1 - i, prompt=np.ones(b, np.int32),
                      max_new_tokens=2)
              for i, b in enumerate(sorted(buckets))])

    tput_n, (p50_n, p99_n) = bench_naive(cfg, params, reqs, batch=capacity)
    tput_c, (p50_c, p99_c), engine = bench_continuous(
        cfg, params, reqs, capacity=capacity, max_len=max_len)

    print(f"serve_naive,tok_per_s,{tput_n:.1f}")
    print(f"serve_naive,p50_s,{p50_n:.3f}")
    print(f"serve_naive,p99_s,{p99_n:.3f}")
    print(f"serve_continuous,tok_per_s,{tput_c:.1f}")
    print(f"serve_continuous,p50_s,{p50_c:.3f}")
    print(f"serve_continuous,p99_s,{p99_c:.3f}")
    print(f"serve_continuous,decode_steps,{engine.n_decode_steps}")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    run(quick=ap.parse_args().quick)
