"""Serving benchmark: naive lock-step vs per-token vs macro-step engines,
swept over model families (transformer / griffin / xlstm).

A Poisson arrival trace of mixed-length requests is replayed against
wall-clock time through three serving paths:

  * naive      — requests are collected into fixed batches; each batch
                 waits for all its members to arrive, then runs prefill +
                 lock-step decode to the LONGEST request's length
                 (``launch/serve.generate``); the next batch waits behind;
  * per-token  — the slot-pool engine with K=1 and no readback pipeline:
                 one jitted decode dispatch AND one blocking host sync per
                 generated token (the PR 1 engine's host-interaction
                 pattern);
  * macro-step — the slot-pool engine with K>1: K decode steps run on
                 device under one ``lax.scan`` dispatch, readback is
                 double-buffered, and admission is batched — the host
                 syncs ~1/K times per token.

The arrival rate is set high enough that the engines (not the trace) are
the bottleneck, so tok/s compares engine speed.  Reported per engine:
total tok/s, per-request completion-latency percentiles (p50/p99, seconds
from arrival to last token), and host syncs per generated token.  Results
are also written to ``BENCH_serve_engine.json`` at the repo root; every
entry records its ``family`` and slot-pool ``cache_layout`` (full KV vs
ring-buffer window vs recurrent state) so the perf trajectory
distinguishes transformer, griffin, and xlstm serving.

The transformer family runs the full comparison (naive + per-token +
macro K-sweep); the recurrent families run per-token vs one macro point —
enough to track their serving speed without tripling the bench runtime.

A ``--speculate`` sweep benches the speculative engine on the paper's
own pair: the SOURCE model (gpt-micro) is pretrained on the synthetic
task, the target (gpt-micro-big) is grown from it with a Mango operator
trained for a few steps (Eq. 7), and the source then drafts for its
grown target.  Entries record ``acceptance_rate`` plus the draft/target
config names next to tok/s, so the perf trajectory ties speedup to
draft quality.  A ``--pool`` sweep benches dense-vs-paged pairs per
family (transformer mixed + shared-prefix traces, griffin ring pages,
xlstm slot-tail pages) plus two prefix-sharing traces that used to be
gated off — a window-9 ring (tail-restore hits) and a seeded sampled
trace (chain-replay hits) — recording pages-in-use high-water,
prefix-cache hit rate, and pages-per-request next to tok/s: each
dense-vs-paged pair is the direct measure of the paged pool's
reservation and re-prefill savings.  A ``--chaos`` sweep benches the
fault-tolerance layer: the
``chaos_faultfree`` entry pins the journaling overhead (its
``host_syncs_per_token`` must match the plain macro entry — flushes
ride existing readbacks), ``chaos_injected`` records survival rate
under a seeded nan/oom/slow/malformed plan with every survivor
token-checked against the fault-free run, and ``chaos_crash`` kills
the engine mid-trace and records the journal-restart recovery latency.
A ``--mesh`` sweep benches sharded serving under a forced 4-device host
mesh (2x2 data x model, dense and paged) against single-device on the
same trace, asserting token-exactness and that ``host_syncs_per_token``
does not regress; every JSON entry records ``mesh_shape``/``n_devices``
(pre-sharding entries backfill as 1x1 so the schema stays uniform).
Partial runs (``--family``, ``--speculate``, ``--pool``, ``--chaos``,
``--mesh``) MERGE into ``BENCH_serve_engine.json`` — they never clobber
the other sections' trajectory entries.

Run:  PYTHONPATH=src:. python benchmarks/bench_serve_engine.py [--quick]
          [--family transformer|griffin|xlstm|all|none] [--speculate]
          [--pool] [--chaos] [--mesh]
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import write_bench_json
from repro.configs.base import get_config
from repro.data.synthetic import lm_batch
from repro.launch.serve import generate
from repro.models import get_family, slot_cache_layout
from repro.serve import ContinuousBatchingEngine, Request, SpeculativeConfig

K_SWEEP = (4, 8, 16)

# one smoke arch per family; recurrentgemma's window (32) is smaller than
# the bench max_len (48), so its slots genuinely wrap the ring buffer
FAMILY_ARCHS = {
    "transformer": "qwen1.5-0.5b-smoke",
    "griffin": "recurrentgemma-2b-smoke",
    "xlstm": "xlstm-1.3b-smoke",
}

# the speculative pair: pretrained source drafts for its grown target
SPEC_DRAFT = "gpt-micro"
SPEC_TARGET = "gpt-micro-big"
SPEC_D_SWEEP = (2, 4)
SPEC_K = 2  # speculative blocks per dispatch (each commits up to d+1 tok)


def poisson_trace(cfg, n, *, rate_hz, seed=0, max_prompt=24, max_gen=16):
    """n requests with exponential inter-arrival gaps at ``rate_hz``."""
    rng = np.random.default_rng(seed)
    t = 0.0
    reqs = []
    for uid in range(n):
        t += float(rng.exponential(1.0 / rate_hz))
        plen = int(rng.integers(4, max_prompt + 1))
        gen = int(rng.integers(2, max_gen + 1))
        prompt = lm_batch(cfg.vocab_size, 1, plen, seed=300 + uid)[0]
        reqs.append(Request(uid=uid, prompt=prompt, max_new_tokens=gen,
                            arrival=t))
    return reqs


def prefix_trace(cfg, n, *, rate_hz, seed=0, prefix_len=18, max_gen=12):
    """n requests that all share one ``prefix_len``-token prompt prefix
    (distinct short tails), arriving at ``rate_hz``.  Against the paged
    pool's copy-on-write prefix cache, every request after the first
    admission wave hits resident pages and skips its prefix prefill."""
    rng = np.random.default_rng(seed)
    prefix = lm_batch(cfg.vocab_size, 1, prefix_len, seed=701)[0]
    t = 0.0
    reqs = []
    for uid in range(n):
        t += float(rng.exponential(1.0 / rate_hz))
        tail = lm_batch(cfg.vocab_size, 1, 2 + uid % 3, seed=900 + uid)[0]
        gen = int(rng.integers(2, max_gen + 1))
        reqs.append(Request(uid=uid,
                            prompt=np.concatenate([prefix, tail]),
                            max_new_tokens=gen, arrival=t))
    return reqs


def _pctl(lat):
    return (float(np.percentile(lat, 50)), float(np.percentile(lat, 99)))


def warm_naive(cfg, params, reqs, batch):
    """Compile every (chunk, pmax, gmax) shape the naive loop will hit, so
    the timed comparison measures serving, not XLA retraces."""
    for i in range(0, len(reqs), batch):
        chunk = reqs[i:i + batch]
        pmax = max(len(r.prompt) for r in chunk)
        gmax = max(r.max_new_tokens for r in chunk)
        generate(cfg, params, jnp.zeros((len(chunk), pmax), jnp.int32),
                 max_new_tokens=gmax)


def warm_engine(cfg, params, reqs, *, capacity, max_len, k,
                speculative=None, pool="dense", pages=None, sampling=None):
    """Compile every shape a (cfg, k) engine can hit on this trace: the
    macro (or speculative) loop, and each (pow2 admission-group size,
    prefill bucket) prefill/scatter pair.  With ``pool='paged'`` the
    uniform warm prompts also hit the prefix cache after the first wave,
    compiling the hit-admission scan."""
    warm = ContinuousBatchingEngine(cfg, params, capacity=capacity,
                                    max_len=max_len, k=k,
                                    speculative=speculative, pool=pool,
                                    pages=pages, sampling=sampling)
    buckets = sorted({warm._bucketed(len(r.prompt)) for r in reqs})
    uid = -1
    n = 1
    while n <= capacity:
        for b in buckets:
            # distinct prompt CONTENT per request: identical prompts
            # would hit the paged prefix cache after the first wave and
            # skip the miss-path prefill this loop exists to compile
            warm.run([Request(
                uid=uid - i,
                prompt=np.full(b, (i - uid) % (cfg.vocab_size - 1) + 1,
                               np.int32),
                max_new_tokens=2) for i in range(n)])
            uid -= n
        n *= 2
    if getattr(warm, "pool_kind", "dense") == "paged":
        # now the opposite: IDENTICAL prompts, so waves past the first
        # hit resident prefix pages and compile the hit-admission scan
        shared = np.zeros(max(buckets), np.int32)
        n = 1
        while n <= capacity:
            warm.run([Request(uid=uid - i, prompt=shared,
                              max_new_tokens=2) for i in range(n)])
            uid -= n
            n *= 2
    return warm


def bench_naive(cfg, params, reqs, batch):
    t0 = time.monotonic()
    lat = []
    n_tok = 0
    for i in range(0, len(reqs), batch):
        chunk = reqs[i:i + batch]
        wait = max(r.arrival for r in chunk) - (time.monotonic() - t0)
        if wait > 0:  # the whole batch must have arrived before it can run
            time.sleep(wait)
        pmax = max(len(r.prompt) for r in chunk)
        gmax = max(r.max_new_tokens for r in chunk)
        prompts = np.zeros((len(chunk), pmax), np.int32)
        for j, r in enumerate(chunk):
            prompts[j, pmax - len(r.prompt):] = r.prompt  # left-pad
        toks = generate(cfg, params, jnp.asarray(prompts),
                        max_new_tokens=gmax)
        jax.block_until_ready(toks)
        done = time.monotonic() - t0
        for r in chunk:
            lat.append(done - r.arrival)
            n_tok += r.max_new_tokens
    tput = n_tok / (time.monotonic() - t0)
    p50, p99 = _pctl(lat)
    return {"tok_per_s": tput, "p50_s": p50, "p99_s": p99}


def bench_engine(cfg, params, reqs, *, capacity, max_len, k, pipeline,
                 speculative=None, pool="dense", pages=None, sampling=None):
    engine = ContinuousBatchingEngine(cfg, params, capacity=capacity,
                                      max_len=max_len, k=k,
                                      speculative=speculative, pool=pool,
                                      pages=pages, sampling=sampling)
    t0 = time.monotonic()
    engine.run(reqs, realtime=True, pipeline=pipeline)
    dt = time.monotonic() - t0
    n_tok = sum(len(v) for v in engine.finished.values())
    by_uid = {r.uid: r for r in reqs}
    # t_done stamps are absolute monotonic times; arrivals are trace offsets
    lat = [(s.t_done - t0) - by_uid[s.req.uid].arrival
           for s in engine.retired]
    p50, p99 = _pctl(lat)
    assert n_tok == engine.n_tokens  # engine accounting matches outputs
    out = {"tok_per_s": n_tok / dt, "p50_s": p50, "p99_s": p99,
           "host_syncs_per_token": engine.n_host_syncs / max(n_tok, 1),
           "decode_dispatches": engine.n_decode_dispatches,
           "prefill_batches": engine.n_prefills, "k": k,
           "decode_kernel": engine.decode_kernel}
    if speculative is not None:
        out["acceptance_rate"] = engine.acceptance_rate
        out["d"] = speculative.d
        out["draft"] = speculative.cfg.name
    out["pool"] = engine.pool_kind
    if engine.pool_kind == "paged":
        meta = engine._metas[0]
        out["pages_highwater"] = engine.pages_highwater
        out["prefix_hit_rate"] = engine.prefix_hit_rate
        out["pages_per_request"] = (engine.n_pages_allocated
                                    / max(len(reqs), 1))
        # what one slot reserves under the dense pool, in page units —
        # the over-reservation the paged pool avoids
        out["dense_reservation_pages"] = meta.nblk
        out["rejected"] = len(engine.rejected)
    return out


def _bench_family(family: str, quick: bool):
    """One family's sweep.  The transformer (the original trajectory)
    keeps its naive/pertoken/macro-K comparison and top-level keys; the
    recurrent families run pertoken vs one macro point under
    ``<family>_``-prefixed keys."""
    cfg = get_config(FAMILY_ARCHS[family])
    fam = get_family(cfg)
    params = fam.init(jax.random.PRNGKey(0), cfg)
    primary = family == "transformer"
    n = (12 if quick else 64) if primary else (8 if quick else 32)
    capacity = 4
    max_len = 48
    k_sweep = (K_SWEEP[:2] if quick else K_SWEEP) if primary else (8,)
    # arrival rate far above the service rate, so the engine — not the
    # trace — is the bottleneck and tok/s measures serving speed, not load
    reqs = poisson_trace(cfg, n, rate_hz=2000.0,
                         max_gen=16 if quick else 24)

    # warm every engine's compile cache outside the timed runs
    if primary:
        warm_naive(cfg, params, reqs, capacity)
    for k in (1,) + tuple(k_sweep):
        warm_engine(cfg, params, reqs, capacity=capacity, max_len=max_len,
                    k=k)

    def fresh():
        return [Request(uid=r.uid, prompt=r.prompt,
                        max_new_tokens=r.max_new_tokens, arrival=r.arrival)
                for r in reqs]

    prefix = "" if primary else f"{family}_"
    results = {}
    if primary:
        results["naive"] = bench_naive(cfg, params, fresh(), batch=capacity)
    results[f"{prefix}pertoken"] = bench_engine(
        cfg, params, fresh(), capacity=capacity, max_len=max_len, k=1,
        pipeline=False)
    for k in k_sweep:
        results[f"{prefix}macro_k{k}"] = bench_engine(
            cfg, params, fresh(), capacity=capacity, max_len=max_len, k=k,
            pipeline=True)
    layout = slot_cache_layout(cfg)
    for m in results.values():
        m["family"] = family
        m["cache_layout"] = layout
    return results


def _spec_pair(quick: bool):
    """Build the paper's speculative pair: PRETRAIN the source on the
    synthetic LM task, then grow the target from it with a Mango operator
    trained on the task loss (Eq. 7).  The grown target approximates the
    source's function at init — exactly what makes the source a
    well-matched draft — so the measured acceptance rate reflects the
    paper's setting, not random-init luck."""
    from repro.core import grow as growlib
    from repro.data.synthetic import lm_data_iter
    from repro.optim import OptimizerConfig, make_optimizer
    from repro.train.steps import make_train_step

    cfg_d, cfg_t = get_config(SPEC_DRAFT), get_config(SPEC_TARGET)
    fam_d = get_family(cfg_d)
    params_d = fam_d.init(jax.random.PRNGKey(0), cfg_d)
    opt_cfg = OptimizerConfig(lr=3e-3, weight_decay=1e-2)
    opt = make_optimizer(opt_cfg)[0](params_d)
    step_fn = jax.jit(make_train_step(cfg_d, opt_cfg),
                      donate_argnums=(0, 1))
    data = lm_data_iter(cfg_d.vocab_size, 8, 64, seed=0)
    for step in range(60 if quick else 120):
        b = {kk: jnp.asarray(v) for kk, v in next(data).items()}
        params_d, opt, _ = step_fn(params_d, opt, b, jnp.int32(step + 1))
    params_t = growlib.grow_from_source(
        cfg_d, cfg_t, method="mango", rank=1, steps=10 if quick else 30,
        data_iter=lm_data_iter(cfg_t.vocab_size, 4, 32, seed=1),
        params_src=params_d, log_fn=lambda *a: None)
    return cfg_t, params_t, cfg_d, params_d


def _bench_speculative(quick: bool):
    """Speculative sweep: non-speculative macro baseline vs d-sweep on
    the grown target, acceptance rate recorded per entry."""
    cfg_t, params_t, cfg_d, params_d = _spec_pair(quick)
    n = 16 if quick else 48
    capacity, max_len = 4, 48
    # speculation pays off on the decode side (it double-pays prefill for
    # the second pool), so even the quick trace keeps full-length
    # generations — only the request count shrinks
    reqs = poisson_trace(cfg_t, n, rate_hz=2000.0, max_prompt=16,
                         max_gen=24)

    def fresh():
        return [Request(uid=r.uid, prompt=r.prompt,
                        max_new_tokens=r.max_new_tokens, arrival=r.arrival)
                for r in reqs]

    results = {}
    warm_engine(cfg_t, params_t, reqs, capacity=capacity, max_len=max_len,
                k=8)
    results["spec_baseline_k8"] = bench_engine(
        cfg_t, params_t, fresh(), capacity=capacity, max_len=max_len, k=8,
        pipeline=True)
    for d in SPEC_D_SWEEP:
        spec = SpeculativeConfig(cfg_d, params_d, d=d)
        warm_engine(cfg_t, params_t, reqs, capacity=capacity,
                    max_len=max_len, k=SPEC_K, speculative=spec)
        results[f"spec_d{d}"] = bench_engine(
            cfg_t, params_t, fresh(), capacity=capacity, max_len=max_len,
            k=SPEC_K, pipeline=True,
            speculative=SpeculativeConfig(cfg_d, params_d, d=d))
    layout = slot_cache_layout(cfg_t)
    for m in results.values():
        m["family"] = cfg_t.family
        m["cache_layout"] = layout
        m["target"] = cfg_t.name
    return results


def _bench_kernel_modes(quick: bool):
    """Kernel-vs-jnp slot decode, side by side, full-KV and ring-window.

    Same trace, same K, only ``cfg.decode_kernel`` differs — the entry
    pair is the direct measure of the kernel-backed slot path.  On this
    CPU container the kernel modes run the Pallas INTERPRETER (orders of
    magnitude slower than compiled — the entries document correctness
    cost, not TPU speed; on a TPU backend ``auto`` compiles).  The trace
    is kept small accordingly.
    """
    cfg = get_config(FAMILY_ARCHS["transformer"])
    fam = get_family(cfg)
    params = fam.init(jax.random.PRNGKey(0), cfg)
    n = 4 if quick else 8
    capacity, max_len, k = 2, 48, 8
    reqs = poisson_trace(cfg, n, rate_hz=2000.0, max_gen=8 if quick else 16)

    def fresh():
        return [Request(uid=r.uid, prompt=r.prompt,
                        max_new_tokens=r.max_new_tokens, arrival=r.arrival)
                for r in reqs]

    results = {}
    kernel_mode = "auto" if jax.default_backend() == "tpu" else "interpret"
    for tag, cfg_m in (("jnp", cfg),
                       (kernel_mode,
                        cfg.replace(decode_kernel=kernel_mode))):
        for wcfg in (cfg_m, cfg_m.replace(name=cfg.name + "-win", window=16)):
            layout = slot_cache_layout(wcfg)
            warm_engine(wcfg, params, reqs, capacity=capacity,
                        max_len=max_len, k=k)
            m = bench_engine(wcfg, params, fresh(), capacity=capacity,
                             max_len=max_len, k=k, pipeline=True)
            m["family"] = wcfg.family
            m["cache_layout"] = layout
            key = "kernel_" + ("ring_" if wcfg.window else "") + tag
            results[key + f"_k{k}"] = m
    return results


def _bench_pool_modes(quick: bool):
    """Dense vs paged slot pool, side by side:

      * mixed / prefix — the transformer trajectory pairs: a Poisson
        trace of unrelated prompts (paged indirection overhead,
        pages-per-request vs the dense full reservation) and a trace
        sharing one prompt prefix (copy-on-write hit rate, fewer
        prefill batches);
      * griffin / xlstm — per-family pairs on the mixed trace: these
        families no longer silently fall back to dense (griffin pages
        its attention rings, xlstm its conv tails), so the pairs price
        the indirection where only part of the pool pages;
      * ring_prefix — a window-9 transformer (its padded ring holds one
        page of slack over the window, the tail-restore gate) on the
        shared-prefix trace with explicit arena headroom — registration
        copies need free pages — so ``prefix_hit_rate`` measures ring
        tail-restore sharing;
      * sampled_prefix — seeded non-greedy sampling on the shared-prefix
        trace: a hit replays the request's per-uid PRNG chain on device,
        so sharing survives sampled serving (``prefix_hit_rate`` > 0
        without ``sampling is None``).

    Same trace, same K, only ``pool=`` differs per pair — the paged
    engine is token-exact vs dense (tested in test_paged_pool.py), so the
    pairs compare cost, not quality.
    """
    from repro.serve import SamplingParams

    cfg = get_config(FAMILY_ARCHS["transformer"])
    fam = get_family(cfg)
    params = fam.init(jax.random.PRNGKey(0), cfg)
    n = 8 if quick else 24
    capacity, max_len, k = 4, 48, 8

    results = {}

    def _pair(tag, pcfg, pparams, reqs, *, pages=None, sampling=None):
        layout = slot_cache_layout(pcfg)

        def fresh():
            return [Request(uid=r.uid, prompt=r.prompt,
                            max_new_tokens=r.max_new_tokens,
                            arrival=r.arrival) for r in reqs]

        for pool in ("dense", "paged"):
            warm_engine(pcfg, pparams, reqs, capacity=capacity,
                        max_len=max_len, k=k, pool=pool, pages=pages,
                        sampling=sampling)
            # dry-run the exact trace untimed, in BOTH admission shapes
            # (batch and realtime trickle): hit-admission replay scans
            # compile per (group size, tail length), which the synthetic
            # warm prompts cannot cover
            for realtime in (False, True):
                ContinuousBatchingEngine(
                    pcfg, pparams, capacity=capacity, max_len=max_len,
                    k=k, pool=pool, pages=pages, sampling=sampling,
                ).run(fresh(), realtime=realtime, pipeline=realtime)
            m = bench_engine(pcfg, pparams, fresh(), capacity=capacity,
                             max_len=max_len, k=k, pipeline=True,
                             pool=pool, pages=pages, sampling=sampling)
            m["family"] = pcfg.family
            m["cache_layout"] = layout
            results[f"pool_{pool}_{tag}_k{k}"] = m

    _pair("mixed", cfg, params,
          poisson_trace(cfg, n, rate_hz=2000.0, max_gen=8 if quick else 16))
    _pair("prefix", cfg, params,
          prefix_trace(cfg, n, rate_hz=2000.0, max_gen=8 if quick else 12))

    # per-family pairs on a mixed trace (smaller: recurrent compiles are
    # the cost here, not tokens)
    nf = 6 if quick else 16
    for family in ("griffin", "xlstm"):
        fcfg = get_config(FAMILY_ARCHS[family])
        fparams = get_family(fcfg).init(jax.random.PRNGKey(0), fcfg)
        _pair(family, fcfg, fparams,
              poisson_trace(fcfg, nf, rate_hz=2000.0, max_gen=6))

    # ring tail-restore sharing: window 9 pads its ring to 16 (page 8,
    # nblk 2), satisfying the slack gate; --pages headroom lets the
    # best-effort registration copies actually land
    wcfg = cfg.replace(name=cfg.name + "-win9", window=9)
    _pair("ring_prefix", wcfg, params,
          prefix_trace(wcfg, n, rate_hz=2000.0, max_gen=8 if quick else 12),
          pages=16)

    # sampled replay sharing: hits must emit the same chain-sampled
    # tokens a miss admission would
    _pair("sampled_prefix", cfg, params,
          prefix_trace(cfg, n, rate_hz=2000.0, max_gen=8 if quick else 12),
          sampling=SamplingParams(temperature=0.9, top_k=12, seed=11))
    return results


def _bench_chaos(quick: bool):
    """Fault-tolerance cost and recovery, measured:

      * chaos_faultfree — the same trace on a journal-attached engine
        with an EMPTY fault plan: its ``host_syncs_per_token`` vs the
        plain ``macro_k8`` entry is the direct price of journaling
        (the acceptance bar is: none — flushes ride existing syncs);
      * chaos_injected  — a seeded plan (nan/oom/slow/malformed) against
        the same trace: ``survival_rate`` is the fraction of requests
        finishing normally, and every survivor is asserted token-equal
        to the fault-free run (a mismatch raises — the bench doubles as
        an integration check);
      * chaos_crash     — kill the engine mid-trace, rebuild from the
        journal, finish: ``recovery_latency_s`` is construction +
        journal replay + re-admission prefill of the resumed requests
        (first token of the first resumed request), and survivors are
        again token-checked.
    """
    from repro.serve import (EngineKilled, FaultPlan, RequestJournal,
                             read_journal, recovery_requests)
    import tempfile

    cfg = get_config(FAMILY_ARCHS["transformer"])
    fam = get_family(cfg)
    params = fam.init(jax.random.PRNGKey(0), cfg)
    n = 8 if quick else 24
    capacity, max_len, k = 4, 48, 8
    reqs = poisson_trace(cfg, n, rate_hz=2000.0, max_gen=8 if quick else 16)

    def fresh():
        return [Request(uid=r.uid, prompt=r.prompt,
                        max_new_tokens=r.max_new_tokens, arrival=r.arrival)
                for r in reqs]

    warm_engine(cfg, params, reqs, capacity=capacity, max_len=max_len, k=k)
    results = {}
    tmp = tempfile.mkdtemp(prefix="chaos_journal_")
    layout = slot_cache_layout(cfg)

    # fault-free, journal attached: the journaling overhead entry
    j0 = RequestJournal(f"{tmp}/faultfree.jsonl")
    e0 = ContinuousBatchingEngine(cfg, params, capacity=capacity,
                                  max_len=max_len, k=k, journal=j0,
                                  faults=FaultPlan([]))
    t0 = time.monotonic()
    e0.run(fresh(), realtime=True, pipeline=True)
    dt = time.monotonic() - t0
    j0.close()
    want = dict(e0.finished)
    n_tok = sum(len(v) for v in want.values())
    results["chaos_faultfree_k8"] = {
        "tok_per_s": n_tok / dt, "p50_s": 0.0, "p99_s": 0.0,
        "host_syncs_per_token": e0.n_host_syncs / max(n_tok, 1),
        "survival_rate": 1.0, "journaled": True, "k": k,
    }

    # seeded non-crash plan: survival + blast radius
    plan = FaultPlan.seeded(3, 10, kinds=("nan", "oom", "slow",
                                          "malformed"), n_faults=3,
                            slow_s=0.01)
    e1 = ContinuousBatchingEngine(cfg, params, capacity=capacity,
                                  max_len=max_len, k=k, faults=plan)
    t0 = time.monotonic()
    e1.run(fresh(), realtime=True, pipeline=True)
    dt = time.monotonic() - t0
    survived = [u for u in want
                if e1.outcomes.get(u) == "finished"]
    mismatch = sum(
        not np.array_equal(e1.finished[u], want[u]) for u in survived)
    if mismatch:
        raise AssertionError(f"{mismatch} survivors token-mismatched "
                             "under injected faults")
    n_tok1 = sum(len(v) for v in e1.finished.values())
    results["chaos_injected_k8"] = {
        "tok_per_s": n_tok1 / dt, "p50_s": 0.0, "p99_s": 0.0,
        "survival_rate": len(survived) / len(reqs),
        "faults_injected": e1.n_faults_injected,
        "quarantined": e1.n_quarantined, "token_mismatches": 0, "k": k,
    }

    # crash + journal restart: recovery latency
    jpath = f"{tmp}/crash.jsonl"
    j2 = RequestJournal(jpath)
    e2 = ContinuousBatchingEngine(
        cfg, params, capacity=capacity, max_len=max_len, k=k, journal=j2,
        faults=FaultPlan.parse("crash@3"))
    try:
        e2.run(fresh(), realtime=True, pipeline=True)
        raise AssertionError("crash fault never fired")
    except EngineKilled:
        j2.close()
    t0 = time.monotonic()
    resumed, done = recovery_requests(read_journal(jpath))
    j3 = RequestJournal(jpath)
    e3 = ContinuousBatchingEngine(cfg, params, capacity=capacity,
                                  max_len=max_len, k=k, journal=j3)
    for r in resumed:
        e3.submit(r)
    while not e3.finished and (e3.waiting or e3.active or e3._inflight):
        e3.step()  # drive until the FIRST resumed request completes
    recovery_latency = time.monotonic() - t0
    e3.run([])  # drain the rest
    j3.close()
    out = {**done, **e3.finished}
    mismatch = sum(not np.array_equal(out[u], want[u]) for u in want
                   if u in out)
    if mismatch:
        raise AssertionError(f"{mismatch} resumed requests "
                             "token-mismatched vs uninterrupted run")
    results["chaos_crash_k8"] = {
        "tok_per_s": 0.0, "p50_s": 0.0, "p99_s": 0.0,
        "recovery_latency_s": recovery_latency,
        "resumed_requests": len(resumed),
        "recovered_done": len(done),
        "survival_rate": len(out) / len(reqs),
        "token_mismatches": 0, "k": k,
    }
    for m in results.values():
        m["family"] = cfg.family
        m["cache_layout"] = layout
    return results


def _bench_mesh(quick: bool):
    """Sharded-vs-single-device serving on one deterministic trace.

    The in-process jax sees 1 CPU device, so the sweep runs in a
    subprocess with 4 forced host devices: the same gpt-micro trace
    through the single-device engine, a 2x2 (data x model) dense engine,
    and a 2x2 paged engine.  The subprocess ASSERTS the acceptance
    criteria before reporting — sharded tokens must equal single-device
    tokens exactly, and sharded ``host_syncs_per_token`` must not exceed
    single-device on the same trace (the readback-locality contract:
    sharding adds collectives on device, never host syncs) — so a
    regression fails the bench rather than drifting into the trajectory.
    Entries record ``mesh_shape``/``n_devices``; forced host devices
    measure dispatch structure, not real multi-chip speed.
    """
    import json as _json
    import os
    import pathlib
    import subprocess
    import sys
    import textwrap

    n = 8 if quick else 24
    gen = 8 if quick else 16
    child = textwrap.dedent(f"""
        import json, time
        import jax
        import numpy as np
        from repro.configs.base import get_config
        from repro.models import get_family, slot_cache_layout
        from repro.serve import ContinuousBatchingEngine, Request
        from benchmarks.bench_serve_engine import poisson_trace

        cfg = get_config("gpt-micro")
        params = get_family(cfg).init(jax.random.PRNGKey(0), cfg)
        reqs = poisson_trace(cfg, {n}, rate_hz=2000.0, max_gen={gen})

        def fresh():
            return [Request(uid=r.uid, prompt=r.prompt,
                            max_new_tokens=r.max_new_tokens,
                            arrival=r.arrival) for r in reqs]

        def bench(mesh, pool):
            def build():
                return ContinuousBatchingEngine(
                    cfg, params, capacity=4, max_len=48, k=8, pool=pool,
                    mesh=mesh)
            build().run(fresh())          # warm the shared jit caches
            eng = build()
            t0 = time.monotonic()
            out = eng.run(fresh())        # realtime=False: deterministic
            dt = time.monotonic() - t0
            n_tok = sum(len(v) for v in out.values())
            m = {{
                "tok_per_s": n_tok / dt, "p50_s": 0.0, "p99_s": 0.0,
                "host_syncs_per_token": eng.n_host_syncs / max(n_tok, 1),
                "decode_dispatches": eng.n_decode_dispatches,
                "prefill_batches": eng.n_prefills, "k": 8,
                "pool": eng.pool_kind, "mesh_shape": eng.mesh_shape,
                "n_devices": eng.n_devices, "family": cfg.family,
                "cache_layout": slot_cache_layout(cfg),
                "params_bytes_per_device": eng.params_bytes_per_device,
                "pool_bytes_per_device": eng.pool_bytes_per_device,
            }}
            if eng.pool_kind == "paged":
                m["pages_highwater"] = eng.pages_highwater
                m["prefix_hit_rate"] = eng.prefix_hit_rate
                m["pages_per_request"] = (eng.n_pages_allocated
                                          / max(len(reqs), 1))
                m["dense_reservation_pages"] = eng._metas[0].nblk
                m["rejected"] = len(eng.rejected)
            return eng, out, m

        results = {{}}
        _, want, results["mesh_1x1_dense_k8"] = bench(None, "dense")
        for tag, pool in (("mesh_2x2_dense_k8", "dense"),
                          ("mesh_2x2_paged_k8", "paged")):
            _, got, results[tag] = bench("2x2", pool)
            for u in want:
                assert np.array_equal(got[u], want[u]), \\
                    (tag, u, got[u], want[u])
            single = results["mesh_1x1_dense_k8"]["host_syncs_per_token"]
            shard = results[tag]["host_syncs_per_token"]
            assert shard <= single + 1e-9, (tag, shard, single)
        print("BENCH_JSON:" + json.dumps(results))
    """)
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    root = pathlib.Path(__file__).resolve().parent.parent
    env["PYTHONPATH"] = f"{root / 'src'}:{root}"
    out = subprocess.run([sys.executable, "-c", child],
                         capture_output=True, text=True, env=env,
                         timeout=560)
    if out.returncode != 0:
        raise RuntimeError("mesh bench subprocess failed:\n"
                           + out.stderr[-3000:])
    line = [l for l in out.stdout.splitlines()
            if l.startswith("BENCH_JSON:")][-1]
    return _json.loads(line[len("BENCH_JSON:"):])


def run(quick: bool = False, write_json: bool = True, families=None,
        speculate: bool = False, kernel: bool = False, pool: bool = False,
        chaos: bool = False, mesh: bool = False):
    families = tuple(FAMILY_ARCHS) if families is None else tuple(families)
    results = {}
    partial = set(families) != set(FAMILY_ARCHS) or speculate or kernel \
        or pool or chaos or mesh
    if write_json and partial:
        # a partial run (--family subset, --speculate) must MERGE into
        # BENCH_serve_engine.json, never erase the other sections'
        # trajectory entries
        import json
        import pathlib
        path = pathlib.Path(__file__).resolve().parent.parent / \
            "BENCH_serve_engine.json"
        if path.exists():
            results.update(json.loads(path.read_text()).get("metrics", {}))
    for family in families:
        results.update(_bench_family(family, quick))
    if speculate:
        results.update(_bench_speculative(quick))
    if kernel:
        # the kernel section always reflects THIS sweep: purge merged-in
        # kernel_* keys first, or a CPU (interpret) and a TPU (auto) run
        # would accumulate stale side-by-side entries per layout
        for key in [k for k in results if k.startswith("kernel_")]:
            del results[key]
        results.update(_bench_kernel_modes(quick))
    if pool:
        # like the kernel section: the dense-vs-paged pairs always
        # reflect THIS sweep — purge merged-in pool_* keys first
        for key in [k for k in results if k.startswith("pool_")]:
            del results[key]
        results.update(_bench_pool_modes(quick))
    if chaos:
        for key in [k for k in results if k.startswith("chaos_")]:
            del results[key]
        results.update(_bench_chaos(quick))
    if mesh:
        for key in [k for k in results if k.startswith("mesh_")]:
            del results[key]
        results.update(_bench_mesh(quick))
    for m in results.values():
        # uniform schema across the whole trajectory: every entry says
        # what mesh it ran on (pre-sharding entries backfill as 1x1)
        m.setdefault("mesh_shape", "1x1")
        m.setdefault("n_devices", 1)

    for name, m in results.items():
        print(f"serve_{name},tok_per_s,{m['tok_per_s']:.1f}")
        print(f"serve_{name},p50_s,{m['p50_s']:.3f}")
        print(f"serve_{name},p99_s,{m['p99_s']:.3f}")
        if "host_syncs_per_token" in m:
            print(f"serve_{name},host_syncs_per_token,"
                  f"{m['host_syncs_per_token']:.3f}")
        if "acceptance_rate" in m:
            print(f"serve_{name},acceptance_rate,{m['acceptance_rate']:.3f}")
        if "survival_rate" in m:
            print(f"serve_{name},survival_rate,{m['survival_rate']:.3f}")
        if "recovery_latency_s" in m:
            print(f"serve_{name},recovery_latency_s,"
                  f"{m['recovery_latency_s']:.3f}")
        if m.get("pool") == "paged":
            print(f"serve_{name},pages_highwater,{m['pages_highwater']}")
            print(f"serve_{name},prefix_hit_rate,"
                  f"{m['prefix_hit_rate']:.3f}")
            print(f"serve_{name},pages_per_request,"
                  f"{m['pages_per_request']:.2f}")
    if write_json:
        path = write_bench_json("serve_engine", results)
        print(f"# wrote {path}")
    return results


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--no-json", action="store_true")
    ap.add_argument("--family", default="all",
                    choices=["all", "none"] + sorted(FAMILY_ARCHS),
                    help="restrict the sweep to one model family "
                         "('none': only the --speculate section)")
    ap.add_argument("--speculate", action="store_true",
                    help="also bench speculative decode on the grown "
                         "gpt-micro pair (acceptance_rate recorded)")
    ap.add_argument("--kernel", action="store_true",
                    help="also bench kernel-vs-jnp slot decode side by "
                         "side (Pallas interpreter off-TPU — small trace)")
    ap.add_argument("--pool", action="store_true",
                    help="also bench dense-vs-paged slot pool pairs per "
                         "family (transformer/griffin/xlstm) plus ring "
                         "tail-restore and sampled-replay prefix traces "
                         "(pages high-water, prefix hit rate recorded)")
    ap.add_argument("--chaos", action="store_true",
                    help="also bench fault tolerance: journaling "
                         "overhead, survival under a seeded fault plan "
                         "(survivors token-checked), and crash+journal "
                         "recovery latency")
    ap.add_argument("--mesh", action="store_true",
                    help="also bench sharded serving on a forced 4-device "
                         "host mesh (2x2 dense + paged vs single-device; "
                         "token-exactness and host-sync parity asserted)")
    a = ap.parse_args()
    fams = {"all": tuple(FAMILY_ARCHS), "none": ()}.get(
        a.family, (a.family,))
    run(quick=a.quick, write_json=not a.no_json, families=fams,
        speculate=a.speculate, kernel=a.kernel, pool=a.pool,
        chaos=a.chaos, mesh=a.mesh)
