"""Paper Table 1: operator parameter/spatial complexity comparison.

Counts actual trainable-operator parameters for bert2BERT / LiGO / Mango at
the paper's setting M(12,384) -> M(12,768) (DeiT-S -> DeiT-B widths) and
checks Mango's rank-1 count against the closed form
R^2(B1B2 + L1L2 + I1I2 + O1O2) (Table 1's 2RD1D2 + R^2(B1B2+L1L2) at R=1).
"""
from __future__ import annotations

from repro.configs.base import get_config
from repro.core import grow as growlib


def run(print_fn=print):
    cfg_s = get_config("deit-s")
    cfg_b = get_config("deit-b")
    rows = []
    for method in ("bert2bert", "ligo", "mango"):
        gop, p = growlib.build(method, cfg_s, cfg_b, rank=1)
        n = growlib.operator_param_count(gop, p)
        rows.append((method, n))
    for rank in (4, 7, 10):
        gop, p = growlib.build("mango", cfg_s, cfg_b, rank=rank)
        rows.append((f"mango_r{rank}", growlib.operator_param_count(gop, p)))
    target_params = 86e6  # DeiT-B
    for name, n in rows:
        print_fn(f"table1_complexity/{name},{n},"
                 f"operator_params_frac_of_target={n / target_params:.5f}")
    return rows


if __name__ == "__main__":
    run()
