"""Paper Tables 2/3 proxy: growth must not hurt transferability.

Micro-scale: pretrain gpt-micro-big (a) from scratch and (b) grown via
Mango from gpt-micro, both to the same pretraining loss; then fine-tune on
a *different* synthetic distribution (shifted chain constants) and compare
final losses.  The paper's claim: grown ~= scratch on downstream (within
noise) while having spent far fewer pretrain FLOPs.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.bench_fig6_rank_ablation import _loss_fn, _pretrained_small
from benchmarks.common import train_to_target
from repro.configs.base import get_config
from repro.core import grow as growlib
from repro.data.synthetic import lm_data_iter
from repro.models import get_family
from repro.optim import OptimizerConfig, make_optimizer
from repro.train.steps import make_train_step

SEQ, BATCH = 64, 8


def _finetune(cfg, params, steps, seed):
    opt_cfg = OptimizerConfig(lr=5e-4)
    init_fn, _ = make_optimizer(opt_cfg)
    opt = init_fn(params)
    step = jax.jit(make_train_step(cfg, opt_cfg), donate_argnums=(0, 1))
    # downstream task: different chain seed => different transition stats
    data = lm_data_iter(cfg.vocab_size, BATCH, SEQ, seed=seed + 1000)
    losses = []
    for s in range(steps):
        b = {k: jnp.asarray(v) for k, v in next(data).items()}
        params, opt, m = step(params, opt, b, jnp.int32(s + 1))
        losses.append(float(m["loss"]))
    return float(np.mean(losses[-10:]))


def run(print_fn=print, quick=False):
    cfg_s = get_config("gpt-micro")
    cfg_t = get_config("gpt-micro-big")
    fam = get_family(cfg_t)
    pre_steps = 80 if quick else 250
    ft_steps = 40 if quick else 120

    small, _ = _pretrained_small(cfg_s, steps=60 if quick else 150)
    gop, op_params = growlib.build("mango", cfg_s, cfg_t, rank=1)
    data = lm_data_iter(cfg_t.vocab_size, BATCH, SEQ, seed=3)
    op_params, _ = growlib.train_operator(
        gop, op_params, small, _loss_fn(cfg_t),
        iter({k: jnp.asarray(v) for k, v in b.items()} for b in data),
        steps=20, lr=2e-3)
    grown = growlib.grow_params(gop, op_params, small)
    _, hist_g = train_to_target(cfg_t, grown, target_loss=-1.0,
                                max_steps=pre_steps, batch=BATCH, seq=SEQ,
                                seed=11)
    # scratch pretrain, same budget
    scratch = fam.init(jax.random.PRNGKey(42), cfg_t)
    _, hist_s = train_to_target(cfg_t, scratch, target_loss=-1.0,
                                max_steps=pre_steps, batch=BATCH, seq=SEQ,
                                seed=11)
    # NOTE: train_to_target donates; rebuild both models at their final
    # state by re-running (cheap at micro scale) without donation
    def pretrain(params, steps):
        opt_cfg = OptimizerConfig(lr=1e-3)
        init_fn, _ = make_optimizer(opt_cfg)
        opt = init_fn(params)
        step = jax.jit(make_train_step(cfg_t, opt_cfg))
        d = lm_data_iter(cfg_t.vocab_size, BATCH, SEQ, seed=11)
        for s in range(steps):
            b = {k: jnp.asarray(v) for k, v in next(d).items()}
            params, opt, m = step(params, opt, b, jnp.int32(s + 1))
        return params, float(m["loss"])

    grown = growlib.grow_params(gop, op_params, small)
    grown, loss_g = pretrain(grown, pre_steps)
    scratch = fam.init(jax.random.PRNGKey(42), cfg_t)
    scratch, loss_s = pretrain(scratch, pre_steps)
    ft_g = _finetune(cfg_t, grown, ft_steps, seed=1)
    ft_s = _finetune(cfg_t, scratch, ft_steps, seed=1)
    print_fn(f"transfer/pretrain_loss_grown,{loss_g:.4f},")
    print_fn(f"transfer/pretrain_loss_scratch,{loss_s:.4f},")
    print_fn(f"transfer/finetune_loss_grown,{ft_g:.4f},")
    print_fn(f"transfer/finetune_loss_scratch,{ft_s:.4f},"
             f"delta={ft_g - ft_s:+.4f}")
    return {"ft_grown": ft_g, "ft_scratch": ft_s}


if __name__ == "__main__":
    run()
