"""Shared benchmark harness helpers."""
from __future__ import annotations

import json
import pathlib
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.data.synthetic import lm_data_iter
from repro.models import get_family
from repro.optim import OptimizerConfig, make_optimizer
from repro.train.steps import make_train_step


def write_bench_json(name, metrics, root=None):
    """Write ``BENCH_<name>.json`` at the repo root (machine-readable perf
    trajectory — one file per benchmark, overwritten per run).

    ``metrics`` is any JSON-serializable dict; the payload records the
    backend and a wall-clock stamp so trajectory tooling can order runs.
    Returns the written path.
    """
    root = pathlib.Path(root) if root else \
        pathlib.Path(__file__).resolve().parent.parent
    path = root / f"BENCH_{name}.json"
    payload = {
        "name": name,
        "backend": jax.default_backend(),
        "unix_time": round(time.time(), 3),
        "metrics": metrics,
    }
    path.write_text(json.dumps(payload, indent=2, sort_keys=True) + "\n")
    return path


def time_call(fn, *args, reps=3, warmup=1):
    """Median wall time (us) of fn(*args) with block_until_ready."""
    for _ in range(warmup):
        out = fn(*args)
        jax.block_until_ready(out)
    ts = []
    for _ in range(reps):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts)) * 1e6


def train_to_target(cfg, params, *, target_loss, max_steps, batch=8,
                    seq=64, lr=1e-3, seed=0, flops_per_step=1.0):
    """Train until loss <= target; returns (steps_used, history).

    steps_used = max_steps+1 when the target is never reached.
    """
    fam = get_family(cfg)
    opt_cfg = OptimizerConfig(lr=lr, weight_decay=1e-2)
    init_fn, _ = make_optimizer(opt_cfg)
    opt = init_fn(params)
    step_fn = jax.jit(make_train_step(cfg, opt_cfg), donate_argnums=(0, 1))
    data = lm_data_iter(cfg.vocab_size, batch, seq, seed=seed)
    hist = []
    reached = max_steps + 1
    ema = None
    for step in range(max_steps):
        b = {k: jnp.asarray(v) for k, v in next(data).items()}
        params, opt, m = step_fn(params, opt, b, jnp.int32(step + 1))
        loss = float(m["loss"])
        ema = loss if ema is None else 0.8 * ema + 0.2 * loss
        hist.append(ema)
        if ema <= target_loss and reached > max_steps:
            reached = step + 1
            break
    return reached, hist


def flops_saving_ratio(steps_scratch, steps_method, warm_steps=0,
                       op_overhead_frac=0.0):
    """Paper Eq. 8 with FLOPs proportional to steps at fixed batch/model;
    operator warm-training counted via ``op_overhead_frac`` (its 100 steps
    run at target-model cost too)."""
    xi_scratch = float(steps_scratch)
    xi_method = float(steps_method) + warm_steps * (1.0 + op_overhead_frac)
    return (xi_scratch - xi_method) / xi_scratch
