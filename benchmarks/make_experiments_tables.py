"""Emit the EXPERIMENTS.md §Dry-run and §Roofline markdown tables from
results/dryrun/*.json.  Usage:
    PYTHONPATH=src python -m benchmarks.make_experiments_tables > tables.md
"""
from __future__ import annotations

import glob
import json
import os

from benchmarks.bench_roofline import RESULTS_DIR, analyze


def fmt_bytes(b):
    return f"{b / 2**30:.2f}"


def load_all(include_variants=False):
    rows = []
    for path in sorted(glob.glob(os.path.join(RESULTS_DIR, "*.json"))):
        with open(path) as f:
            r = json.load(f)
        if not include_variants and \
                r.get("variant", "baseline") != "baseline":
            continue
        rows.append(r)
    return rows


def dryrun_table(rows, mesh):
    out = ["| arch | shape | status | compile s | HBM GiB/dev | "
           "arg GiB | temp GiB | collectives GiB/dev |",
           "|---|---|---|---|---|---|---|---|"]
    for r in rows:
        if r["mesh"] != mesh:
            continue
        if r["status"] == "skipped":
            out.append(f"| {r['arch']} | {r['shape']} | SKIP "
                       f"({r['reason'][:40]}…) | | | | | |")
            continue
        if r["status"] != "ok":
            out.append(f"| {r['arch']} | {r['shape']} | **FAIL** | | | | | |")
            continue
        m = r["memory"]
        coll = sum(r.get("collective_bytes_per_device", {}).values())
        out.append(
            f"| {r['arch']} | {r['shape']} | ok | {r['compile_s']} | "
            f"{fmt_bytes(m['argument_bytes'] + m['temp_bytes'])} | "
            f"{fmt_bytes(m['argument_bytes'])} | "
            f"{fmt_bytes(m['temp_bytes'])} | {fmt_bytes(coll)} |")
    return "\n".join(out)


def roofline_table(rows, mesh="pod_16x16"):
    out = ["| arch | shape | compute s | memory s | collective s | "
           "bottleneck | MODEL_FLOPS | useful | roofline frac |",
           "|---|---|---|---|---|---|---|---|---|"]
    for r in rows:
        if r["mesh"] != mesh or r["status"] != "ok" \
                or "flops_per_device" not in r:
            continue
        a = analyze(r)
        mf = a["model_flops_global"]
        out.append(
            f"| {r['arch']} | {r['shape']} | {a['compute_s']:.3f} | "
            f"{a['memory_s']:.3f} | {a['collective_s']:.3f} | "
            f"**{a['bottleneck']}** | "
            f"{mf:.2e} | {a['useful_compute_ratio']:.3f} | "
            f"{a['roofline_fraction']:.3f} |"
            if mf else
            f"| {r['arch']} | {r['shape']} | {a['compute_s']:.3f} | "
            f"{a['memory_s']:.3f} | {a['collective_s']:.3f} | "
            f"**{a['bottleneck']}** | n/a | n/a | n/a |")
    return "\n".join(out)


def main():
    rows = load_all()
    print("### Dry-run — single pod (16x16 = 256 chips)\n")
    print(dryrun_table(rows, "pod_16x16"))
    print("\n### Dry-run — multi-pod (2x16x16 = 512 chips)\n")
    print(dryrun_table(rows, "multipod_2x16x16"))
    print("\n### Roofline — single pod (v5e: 197 TF/s bf16, 819 GB/s HBM, "
          "50 GB/s/link)\n")
    print(roofline_table(rows, "pod_16x16"))
    print("\n### Roofline — multi-pod\n")
    print(roofline_table(rows, "multipod_2x16x16"))


if __name__ == "__main__":
    main()
