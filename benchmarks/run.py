"""Benchmark harness entry: one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV lines.  ``--quick`` shrinks training
budgets (CI); default budgets reproduce the EXPERIMENTS.md numbers.
Benchmarks with machine-readable output (currently ``serve``) also write
``BENCH_<name>.json`` at the repo root via ``common.write_bench_json``.
"""
from __future__ import annotations

import argparse


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--only", default=None,
                    help="comma list: table1,fig6,fig7,transfer,roofline,"
                         "kernels,serve,spec,servek,servep,servec,servem,"
                         "serveg")
    args, _ = ap.parse_known_args()
    only = set(args.only.split(",")) if args.only else None

    print("name,us_per_call,derived")

    def section(name):
        return only is None or name in only

    if section("table1"):
        from benchmarks.bench_table1_complexity import run as t1
        t1()
    if section("kernels"):
        from benchmarks.bench_kernels import run as bk
        bk()
    if section("roofline"):
        from benchmarks.bench_roofline import run as rf
        rf()
    if section("serve"):
        from benchmarks.bench_serve_engine import run as sv
        sv(quick=args.quick)
    if section("spec"):
        # speculative decode on the grown pair only (merges into the
        # serve JSON)
        from benchmarks.bench_serve_engine import run as sv_spec
        sv_spec(quick=args.quick, families=(), speculate=True)
    if section("servek"):
        # kernel-vs-jnp slot decode only (merges into the serve JSON)
        from benchmarks.bench_serve_engine import run as sv_kern
        sv_kern(quick=args.quick, families=(), kernel=True)
    if section("servep"):
        # dense-vs-paged slot pool pairs only (merges into the serve JSON)
        from benchmarks.bench_serve_engine import run as sv_pool
        sv_pool(quick=args.quick, families=(), pool=True)
    if section("servec"):
        # chaos/fault-tolerance sweep only (merges into the serve JSON)
        from benchmarks.bench_serve_engine import run as sv_chaos
        sv_chaos(quick=args.quick, families=(), chaos=True)
    if section("servem"):
        # sharded-vs-single-device mesh sweep only (subprocess with 4
        # forced host devices; merges into the serve JSON)
        from benchmarks.bench_serve_engine import run as sv_mesh
        sv_mesh(quick=args.quick, families=(), mesh=True)
    if section("serveg"):
        # scenario sweep: families x pool x kernel x trace-shape matrix
        # in per-cell subprocesses, incl. mid-trace live-upgrade cells
        # (merges into the serve JSON)
        from benchmarks.scenarios import run as sv_scen
        sv_scen(quick=args.quick)
    if section("fig6"):
        from benchmarks.bench_fig6_rank_ablation import run as f6
        f6(quick=args.quick)
    if section("fig7"):
        from benchmarks.bench_fig7_growth_curves import run as f7
        f7(quick=args.quick)
    if section("transfer"):
        from benchmarks.bench_transfer import run as tr
        tr(quick=args.quick)


if __name__ == "__main__":
    main()
