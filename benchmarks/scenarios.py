"""Scenario sweep harness: families x pool x kernel x trace-shape matrix,
one subprocess per cell, merged into ``BENCH_serve_engine.json``.

Each cell runs in its OWN interpreter so jit caches, page arenas and
window counters never bleed between configurations — the numbers are
what a cold engine of that exact shape does on that exact trace.  The
child prints a single ``BENCH_JSON:{...}`` line (the same protocol as
``bench_serve_engine._bench_mesh``); the parent collects the cells,
purges stale ``scenario_*`` / ``upgrade_*`` keys, and MERGES into the
serve JSON so the other sections' trajectory entries survive.

Trace shapes:
  * ``bursty``      — short mixed requests arriving in two dense waves
                      (queueing + slot churn);
  * ``long_prompt`` — few requests whose prompts nearly fill ``max_len``
                      (prefill-bound, page-hungry);
  * ``eos_heavy``   — every request carries an eos it WILL emit mid-
                      budget (derived from a greedy dry run), so slots
                      retire early and admission backfills constantly.

``upgrade_*`` cells additionally arm a live :class:`UpgradeManager`
(growth pre-done so the swap lands deterministically at ``upgrade_at``
dispatches) and record the swap telemetry: ``upgrade_pause_ms``,
``dropped`` (ASSERTED zero — a swap that sheds load fails the bench),
resumed count, pre/post-swap tok/s, and the post-swap speculative
acceptance rate.

Run:  PYTHONPATH=src:. python benchmarks/scenarios.py [--quick]
"""
from __future__ import annotations

import argparse
import json
import os
import pathlib
import subprocess
import sys

from benchmarks.common import write_bench_json

# quick=True cells form the CI smoke subset; the rest only run in the
# full sweep.  The interpret-kernel cell runs the Pallas INTERPRETER on
# CPU hosts (documenting correctness cost, not TPU speed) and is kept
# tiny for that reason.
SCENARIOS = (
    {"key": "scenario_gpt_dense_bursty", "arch": "gpt-micro",
     "pool": "dense", "trace": "bursty", "quick": True},
    {"key": "scenario_gpt_paged_long_prompt", "arch": "gpt-micro",
     "pool": "paged", "trace": "long_prompt", "quick": True},
    {"key": "scenario_gpt_dense_eos_heavy", "arch": "gpt-micro",
     "pool": "dense", "trace": "eos_heavy", "quick": True},
    {"key": "scenario_gpt_paged_bursty", "arch": "gpt-micro",
     "pool": "paged", "trace": "bursty", "quick": False},
    {"key": "scenario_griffin_dense_bursty", "arch": "griffin-micro",
     "pool": "dense", "trace": "bursty", "quick": True},
    {"key": "scenario_griffin_dense_eos_heavy", "arch": "griffin-micro",
     "pool": "dense", "trace": "eos_heavy", "quick": False},
    {"key": "scenario_gpt_kernel_bursty", "arch": "gpt-micro",
     "pool": "dense", "trace": "bursty", "kernel": "kernel",
     "quick": False},
    {"key": "upgrade_gpt_dense_midtrace", "arch": "gpt-micro",
     "grow": "gpt-micro-big", "pool": "dense", "trace": "bursty",
     "upgrade": True, "quick": True},
    {"key": "upgrade_gpt_paged_midtrace", "arch": "gpt-micro",
     "grow": "gpt-micro-big", "pool": "paged", "trace": "bursty",
     "upgrade": True, "quick": True},
    {"key": "upgrade_griffin_dense_midtrace", "arch": "griffin-micro",
     "grow": "griffin-micro-big", "pool": "dense", "trace": "bursty",
     "upgrade": True, "quick": False},
)

# the child re-reads its cell spec from argv[1]; everything it needs is
# in-repo, so the only environment is PYTHONPATH
_CHILD = r'''
import json, sys, time
import jax
import numpy as np
from repro.configs.base import get_config
from repro.data.synthetic import lm_batch
from repro.launch.serve import generate
from repro.models import get_family, slot_cache_layout
from repro.serve import ContinuousBatchingEngine, Request, UpgradeManager

spec = json.loads(sys.argv[1])
quick = spec["quick_run"]
cfg = get_config(spec["arch"])
if spec.get("kernel") == "kernel":
    mode = "auto" if jax.default_backend() == "tpu" else "interpret"
    cfg = cfg.replace(decode_kernel=mode)
params = get_family(cfg).init(jax.random.PRNGKey(0), cfg)

MAX_LEN = 40
capacity, k = 3, 2
interp = cfg.decode_kernel not in ("jnp", "auto") \
    and jax.default_backend() != "tpu"


def _req(uid, plen, gen, arrival=0.0, eos=None):
    prompt = lm_batch(cfg.vocab_size, 1, plen, seed=400 + uid)[0]
    return Request(uid=uid, prompt=prompt, max_new_tokens=gen,
                   arrival=arrival, eos_id=eos)


def make_trace(kind):
    rng = np.random.default_rng(7)
    if kind == "bursty":
        n = 4 if interp else (8 if quick else 12)
        g = 4 if interp else 10
        return [_req(u, int(rng.integers(4, 11)), g,
                     arrival=0.0 if u < n // 2 else 0.05)
                for u in range(n)]
    if kind == "long_prompt":
        n = 3 if quick else 5
        gen = 6
        return [_req(u, MAX_LEN - gen - int(rng.integers(0, 4)), gen)
                for u in range(n)]
    if kind == "eos_heavy":
        n = 6 if quick else 10
        reqs = [_req(u, int(rng.integers(4, 11)), 12) for u in range(n)]
        out = []
        for r in reqs:
            toks = np.asarray(generate(
                cfg, params, np.asarray(r.prompt)[None],
                max_new_tokens=r.max_new_tokens, max_len=MAX_LEN))[0]
            # the token it WILL greedily emit mid-budget becomes its eos
            out.append(Request(uid=r.uid, prompt=r.prompt,
                               max_new_tokens=r.max_new_tokens,
                               eos_id=int(toks[len(toks) // 2])))
        return out
    raise ValueError(kind)


reqs = make_trace(spec["trace"])
eng = ContinuousBatchingEngine(cfg, params, capacity=capacity,
                               max_len=MAX_LEN, k=k, pool=spec["pool"],
                               prefill_bucket=16)
mgr = None
if spec.get("upgrade"):
    mgr = UpgradeManager(eng, get_config(spec["grow"]), upgrade_at=4,
                         prewarm=not quick)
    mgr.start(background=False)  # growth pre-done: swap point is exact

t0 = time.monotonic()
out = eng.run(reqs)
dt = time.monotonic() - t0
n_tok = sum(len(v) for v in out.values())
lat = sorted(s.t_done - t0 for s in eng.retired)
p50 = lat[len(lat) // 2] if lat else 0.0
p99 = lat[min(len(lat) - 1, int(0.99 * len(lat)))] if lat else 0.0

m = {
    "tok_per_s": n_tok / dt, "p50_s": p50, "p99_s": p99,
    "host_syncs_per_token": eng.n_host_syncs / max(n_tok, 1),
    "k": k, "trace": spec["trace"], "n_requests": len(reqs),
    "pool": eng.pool_kind, "decode_kernel": eng.decode_kernel,
    "family": cfg.family, "cache_layout": slot_cache_layout(eng.cfg),
    "mesh_shape": eng.mesh_shape, "n_devices": eng.n_devices,
}
if eng.pool_kind == "paged":
    m["pages_highwater"] = eng.pages_highwater
    m["prefix_hit_rate"] = eng.prefix_hit_rate
if mgr is not None:
    assert mgr.state == "swapped", mgr.state
    dropped = len(eng.rejected)
    assert dropped == 0, eng.rejected  # zero-drop is the contract
    assert all(v == "finished" for v in eng.outcomes.values()), \
        eng.outcomes
    totals = eng.lifetime_totals()
    pre_tok = mgr.tokens_at_swap
    m.update({
        "upgrade_pause_ms": mgr.pause_ms,
        "grow_s": mgr.grow_seconds,
        "dropped": dropped,
        "resumed_requests": mgr.resumed,
        "held_submits": totals["n_held_for_upgrade"],
        "pre_swap_tok_per_s": pre_tok / max(mgr.t_swap - t0, 1e-9),
        "post_swap_tok_per_s": (totals["n_tokens"] - pre_tok)
                               / max(t0 + dt - mgr.t_swap, 1e-9),
        "source": spec["arch"], "target": spec["grow"],
        # page-residency delta: pages live at quiesce (all invalidated by
        # the grown params), pages carried (structurally 0), and the
        # re-prefill page bill the resume wave pays for zero drops
        "pages_resident_at_swap": mgr.pages_resident_at_swap,
        "pages_carried": mgr.pages_carried,
        "pages_reprefilled": mgr.pages_reprefilled,
    })
    if eng.speculative is not None:
        m["acceptance_rate"] = eng.acceptance_rate
        m["draft"] = eng.speculative.cfg.name
    elif mgr.spec_reason:
        m["spec_disabled"] = mgr.spec_reason
print("BENCH_JSON:" + json.dumps({spec["key"]: m}))
'''


def _run_cell(spec, quick, timeout=560):
    root = pathlib.Path(__file__).resolve().parent.parent
    env = dict(os.environ)
    env["PYTHONPATH"] = f"{root / 'src'}:{root}"
    payload = dict(spec, quick_run=quick)
    out = subprocess.run([sys.executable, "-c", _CHILD,
                          json.dumps(payload)],
                         capture_output=True, text=True, env=env,
                         timeout=timeout)
    if out.returncode != 0:
        raise RuntimeError(f"scenario cell {spec['key']} failed:\n"
                           + out.stderr[-3000:])
    line = [l for l in out.stdout.splitlines()
            if l.startswith("BENCH_JSON:")][-1]
    return json.loads(line[len("BENCH_JSON:"):])


def run(quick: bool = False, write_json: bool = True):
    cells = [s for s in SCENARIOS if s["quick"] or not quick]
    results = {}
    if write_json:
        # merge, never clobber: the scenario sweep owns only its own keys
        path = pathlib.Path(__file__).resolve().parent.parent / \
            "BENCH_serve_engine.json"
        if path.exists():
            results.update(json.loads(path.read_text()).get("metrics", {}))
        for key in [k for k in results
                    if k.startswith(("scenario_", "upgrade_"))]:
            del results[key]
    for spec in cells:
        results.update(_run_cell(spec, quick))
    for name in (s["key"] for s in cells):
        m = results[name]
        print(f"serve_{name},tok_per_s,{m['tok_per_s']:.1f}")
        print(f"serve_{name},p50_s,{m['p50_s']:.3f}")
        print(f"serve_{name},p99_s,{m['p99_s']:.3f}")
        if "upgrade_pause_ms" in m:
            print(f"serve_{name},upgrade_pause_ms,"
                  f"{m['upgrade_pause_ms']:.1f}")
            print(f"serve_{name},dropped,{m['dropped']}")
            if m.get("pages_resident_at_swap"):
                print(f"serve_{name},pages_carried,{m['pages_carried']}")
                print(f"serve_{name},pages_reprefilled,"
                      f"{m['pages_reprefilled']}")
            print(f"serve_{name},pre_swap_tok_per_s,"
                  f"{m['pre_swap_tok_per_s']:.1f}")
            print(f"serve_{name},post_swap_tok_per_s,"
                  f"{m['post_swap_tok_per_s']:.1f}")
        if "acceptance_rate" in m:
            print(f"serve_{name},acceptance_rate,"
                  f"{m['acceptance_rate']:.3f}")
    if write_json:
        path = write_bench_json("serve_engine", results)
        print(f"# wrote {path}")
    return results


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--no-json", action="store_true")
    a = ap.parse_args()
    run(quick=a.quick, write_json=not a.no_json)
