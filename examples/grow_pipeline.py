"""End-to-end production pipeline: pretrain -> checkpoint -> grow (Mango)
-> continue training -> simulated failure -> elastic resume.

This drives the same trainer the launcher exposes (repro.launch.train) and
exercises checkpoint/restart — the fault-tolerance path.

Run:  PYTHONPATH=src:. python examples/grow_pipeline.py
"""
import os
import shutil
import tempfile

from repro.launch.train import train

ROOT = tempfile.mkdtemp(prefix="repro_pipeline_")


def main():
    small_dir = os.path.join(ROOT, "gpt-micro")
    big_dir = os.path.join(ROOT, "gpt-micro-big")

    print("=== stage 1: pretrain the small model (with checkpoints) ===")
    train("gpt-micro", steps=100, batch=8, ckpt_dir=small_dir,
          ckpt_every=50, log_every=25)

    print("\n=== stage 2: grow to the target + train, checkpointing ===")
    train("gpt-micro-big", steps=60, batch=8, ckpt_dir=big_dir,
          ckpt_every=20, grow_from="gpt-micro", grow_method="mango",
          grow_steps=20, log_every=20)

    print("\n=== stage 3: 'crash' mid-run and elastically resume ===")
    # resume from the latest checkpoint and train further
    _, hist = train("gpt-micro-big", steps=90, batch=8, ckpt_dir=big_dir,
                    ckpt_every=30, resume=True, log_every=15)
    print(f"\npipeline complete; final loss "
          f"{hist[-1]['loss']:.4f}; artifacts in {ROOT}")
    shutil.rmtree(ROOT, ignore_errors=True)


if __name__ == "__main__":
    main()
