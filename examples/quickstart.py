"""Quickstart: grow a pretrained micro-GPT into a 2x bigger one with Mango
and watch the grown model start far below the scratch loss.

Run:  PYTHONPATH=src:. python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.configs.base import get_config
from repro.core import grow as growlib
from repro.data.synthetic import lm_data_iter
from repro.models import get_family
from repro.optim import OptimizerConfig, make_optimizer
from repro.train.loss import loss_for
from repro.train.steps import make_eval_step, make_train_step

BATCH, SEQ = 8, 64


def pretrain(cfg, steps, seed=0):
    fam = get_family(cfg)
    params = fam.init(jax.random.PRNGKey(seed), cfg)
    opt_cfg = OptimizerConfig(lr=1e-3)
    init_fn, _ = make_optimizer(opt_cfg)
    opt = init_fn(params)
    step = jax.jit(make_train_step(cfg, opt_cfg))
    data = lm_data_iter(cfg.vocab_size, BATCH, SEQ, seed=seed)
    for s in range(steps):
        b = {k: jnp.asarray(v) for k, v in next(data).items()}
        params, opt, m = step(params, opt, b, jnp.int32(s + 1))
        if s % 25 == 0:
            print(f"  [small] step {s:4d} loss {float(m['loss']):.4f}")
    return params


def main():
    cfg_s = get_config("gpt-micro")
    cfg_t = get_config("gpt-micro-big")
    fam = get_family(cfg_t)
    print(f"pretraining {cfg_s.name} ...")
    small = pretrain(cfg_s, 120)

    print("training Mango operator (Eq. 7, a few steps) ...")
    gop, op_params = growlib.build("mango", cfg_s, cfg_t, rank=1)
    lf = loss_for(cfg_t)

    def op_loss(big, b):
        logits, aux = fam.forward(big, b, cfg_t)
        return lf(logits, aux, b, cfg_t)[0]

    data = lm_data_iter(cfg_t.vocab_size, BATCH, SEQ, seed=3)
    op_params, losses = growlib.train_operator(
        gop, op_params, small, op_loss,
        iter({k: jnp.asarray(v) for k, v in b.items()} for b in data),
        steps=25, lr=2e-3)
    print(f"  operator loss {losses[0]:.4f} -> {losses[-1]:.4f}")

    big = growlib.grow_params(gop, op_params, small)
    scratch = fam.init(jax.random.PRNGKey(99), cfg_t)
    ev = jax.jit(make_eval_step(cfg_t))
    b = {k: jnp.asarray(v)
         for k, v in next(lm_data_iter(cfg_t.vocab_size, BATCH, SEQ,
                                       seed=50)).items()}
    l_grown = float(ev(big, b)["loss"])
    l_scratch = float(ev(scratch, b)["loss"])
    print(f"\ninitial loss of {cfg_t.name}: grown(Mango)={l_grown:.4f}  "
          f"scratch={l_scratch:.4f}")
    assert l_grown < l_scratch, "growth should beat random init"
    print("OK: the grown model inherits the small model's knowledge.")


if __name__ == "__main__":
    main()
