"""Batched serving example: prefill a batch of prompts on a smoke-scale
assigned arch and greedy-decode continuations with a KV cache — the same
prefill/decode functions the dry-run lowers at 32k/500k scale.

Run:  PYTHONPATH=src:. python examples/serve_batched.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import get_config
from repro.data.synthetic import lm_batch
from repro.launch.serve import generate
from repro.models import get_family


def main():
    for arch in ("qwen3-0.6b-smoke", "recurrentgemma-2b-smoke",
                 "xlstm-1.3b-smoke"):
        cfg = get_config(arch)
        fam = get_family(cfg)
        params = fam.init(jax.random.PRNGKey(0), cfg)
        prompts = jnp.asarray(lm_batch(cfg.vocab_size, 4, 24))
        t0 = time.time()
        toks = generate(cfg, params, prompts, max_new_tokens=12)
        jax.block_until_ready(toks)
        dt = time.time() - t0
        print(f"{arch:28s} generated {toks.shape} in {dt:5.2f}s; "
              f"sample row: {np.asarray(toks[0])[:8]}")


if __name__ == "__main__":
    main()
