"""Continuous-batching serving example.

A stream of requests with mixed prompt lengths and mixed generation
lengths flows through a fixed-capacity slot pool: sequences are admitted
as slots free up, decode runs as ONE batched step per engine iteration
regardless of how sequences come and go, and retired slots are backfilled
without recompiling.  Compare with ``serve_batched.py``, which must run
every sequence lock-step to the longest request.

Also shows the paper's end-to-end story at serve time: growing a small
pretrained model into the target architecture (Mango operator) and serving
the grown weights through the same engine — and, because the engine talks
only to the family-agnostic slot-state protocol, the same loop serving a
RECURRENT family (griffin: O(1) rglru/conv state per slot + ring-buffer
local-attention caches) with zero engine changes.

Run:  PYTHONPATH=src:. python examples/serve_continuous.py
"""
import time

import jax
import numpy as np

from repro.configs.base import get_config
from repro.data.synthetic import lm_batch
from repro.launch.serve import build_params
from repro.models import get_family
from repro.serve import ContinuousBatchingEngine, Request


def mixed_trace(cfg, n, *, seed=0, max_prompt=24, max_gen=12):
    rng = np.random.default_rng(seed)
    reqs = []
    for uid in range(n):
        plen = int(rng.integers(4, max_prompt + 1))
        gen = int(rng.integers(2, max_gen + 1))
        prompt = lm_batch(cfg.vocab_size, 1, plen, seed=100 + uid)[0]
        reqs.append(Request(uid=uid, prompt=prompt, max_new_tokens=gen))
    return reqs


def main():
    cfg = get_config("qwen1.5-0.5b-smoke")
    fam = get_family(cfg)
    params = fam.init(jax.random.PRNGKey(0), cfg)
    engine = ContinuousBatchingEngine(cfg, params, capacity=4, max_len=64)
    reqs = mixed_trace(cfg, 10)
    t0 = time.time()
    out = engine.run(reqs)
    dt = time.time() - t0
    n_tok = sum(len(v) for v in out.values())
    print(f"{cfg.name:24s} served {len(reqs)} mixed-length requests "
          f"({n_tok} tokens) in {dt:.2f}s via {engine.n_decode_dispatches} "
          f"on-device macro-steps ({engine.n_host_syncs / max(n_tok, 1):.2f} "
          f"host syncs/token)")
    for uid in (0, 1):
        print(f"  req {uid}: {out[uid]}")

    # serve a Mango-grown model through the same engine
    cfg_big = get_config("gpt-micro-big")
    grown = build_params(cfg_big, grow_from="gpt-micro",
                         grow_method="mango", grow_steps=0)
    engine = ContinuousBatchingEngine(cfg_big, grown, capacity=4,
                                      max_len=64)
    out = engine.run(mixed_trace(cfg_big, 6))
    print(f"{cfg_big.name:24s} served {len(out)} requests on Mango-grown "
          f"params; sample: {out[0][:8]}")

    # a recurrent family through the SAME engine: griffin slots carry O(1)
    # rglru/conv state plus ring-buffer window KV (O(window), not O(max_len))
    cfg_rec = get_config("recurrentgemma-2b-smoke")
    params = get_family(cfg_rec).init(jax.random.PRNGKey(0), cfg_rec)
    engine = ContinuousBatchingEngine(cfg_rec, params, capacity=4,
                                      max_len=40)
    out = engine.run(mixed_trace(cfg_rec, 6))
    ring = engine.pool["attn"]["k"].shape[2]
    print(f"{cfg_rec.name:24s} served {len(out)} requests "
          f"({engine.cache_layout} slots, attn ring={ring} "
          f"of window={cfg_rec.window}); sample: {out[0][:8]}")


if __name__ == "__main__":
    main()
