"""The ~100M end-to-end driver: train a 100M-parameter GPT for a few
hundred steps (optionally grown from a 25M model first).

On this CPU container a full run takes a while; ``--steps`` controls the
budget (EXPERIMENTS.md records a real run).  On TPU this exact script is
the single-pod trainer.

Run:  PYTHONPATH=src:. python examples/train_100m.py --steps 200
"""
import argparse

import repro.configs.base as base
from repro.configs.base import ModelConfig, register_named
from repro.launch.train import train


@register_named("gpt-100m")
def gpt_100m():
    # 12L x 768 GPT-2-small-like on a 32k synthetic vocab: ~110M params
    return ModelConfig(
        name="gpt-100m", family="transformer", n_layers=12, d_model=768,
        n_heads=12, n_kv_heads=12, d_ff=3072, vocab_size=32768,
        causal=True, rope="standard", norm="rms", act="swiglu",
        max_seq_len=1024)


@register_named("gpt-25m")
def gpt_25m():
    return gpt_100m().replace(name="gpt-25m", n_layers=6, d_model=384,
                              n_heads=6, n_kv_heads=6, d_ff=1536)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--grow", action="store_true",
                    help="pretrain gpt-25m briefly and grow via Mango")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_100m")
    args = ap.parse_args()

    if args.grow:
        print("=== pretraining the 25M source ===")
        train("gpt-25m", steps=max(args.steps // 4, 20), batch=args.batch,
              seq=args.seq, log_every=10)
        print("=== growing 25M -> 100M (Mango) + training ===")
        train("gpt-100m", steps=args.steps, batch=args.batch, seq=args.seq,
              ckpt_dir=args.ckpt_dir, ckpt_every=max(args.steps // 3, 1),
              grow_from="gpt-25m", grow_method="mango", grow_steps=20,
              log_every=10, watchdog_s=600)
    else:
        train("gpt-100m", steps=args.steps, batch=args.batch, seq=args.seq,
              ckpt_dir=args.ckpt_dir, ckpt_every=max(args.steps // 3, 1),
              log_every=10, watchdog_s=600)


if __name__ == "__main__":
    main()
