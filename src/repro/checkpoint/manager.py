"""Checkpointing: npy-shard + JSON-manifest format, built for fault
tolerance and elastic restarts (no orbax in the container — and none
needed; the format is deliberately boring).

Guarantees:
  * **atomicity** — writes go to ``<dir>/tmp.<step>/`` and are renamed to
    ``step_<n>/`` only after the manifest (with per-leaf CRC32) is fsynced;
    a crash mid-write can never corrupt the latest valid checkpoint;
  * **integrity** — every leaf carries a CRC32 checked on load;
  * **mesh-agnosticism** — leaves are stored as full logical arrays (host
    gathered); restore takes *any* mesh/sharding, so a 512-chip job can
    resume on 256 chips (elastic re-shard) — see ``repro/distributed/
    elastic.py``;
  * **keep-K GC** — old steps are pruned only after a newer one commits;
  * **async** — ``CheckpointManager(async_save=True)`` snapshots to host
    memory synchronously and writes on a worker thread, keeping the train
    loop running.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import zlib
from typing import Any, Optional

import jax
import ml_dtypes
import numpy as np

from repro.utils.pytree import path_str

_MANIFEST = "manifest.json"


class CheckpointShapeError(ValueError):
    """The restore template's geometry does not match the checkpoint on
    disk (e.g. a pre-growth snapshot loaded into a post-growth model).
    Carries the offending leaf in ``.leaf`` and names it in the message,
    so the caller sees WHICH arrays disagree instead of an XLA shape
    crash deep inside the first jitted forward pass."""

    def __init__(self, msg: str, leaf: Optional[str] = None):
        super().__init__(msg)
        self.leaf = leaf

# numpy round-trips exotic dtypes (bfloat16, fp8) as raw void bytes; map
# the manifest's logical dtype string back to the ml_dtypes view on load.
_EXOTIC = {"bfloat16": ml_dtypes.bfloat16,
           "float8_e4m3fn": ml_dtypes.float8_e4m3fn,
           "float8_e5m2": ml_dtypes.float8_e5m2}


def _flatten(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    return [(path_str(p).replace("/", "_"), leaf) for p, leaf in flat], \
        treedef


def save_checkpoint(ckpt_dir: str, step: int, tree: Any,
                    extra: Optional[dict] = None) -> str:
    """Atomic write of ``tree`` (pytree of arrays) at ``step``."""
    os.makedirs(ckpt_dir, exist_ok=True)
    tmp = os.path.join(ckpt_dir, f"tmp.{step}")
    final = os.path.join(ckpt_dir, f"step_{step:010d}")
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    flat, _ = _flatten(tree)
    manifest = {"step": step, "leaves": {}, "extra": extra or {}}
    for i, (name, leaf) in enumerate(flat):
        arr = np.asarray(jax.device_get(leaf))
        fname = f"leaf_{i:05d}.npy"
        np.save(os.path.join(tmp, fname), arr)
        manifest["leaves"][name] = {
            "file": fname,
            "shape": list(arr.shape),
            "dtype": str(arr.dtype),
            "crc32": zlib.crc32(np.ascontiguousarray(arr).tobytes()),
        }
    mpath = os.path.join(tmp, _MANIFEST)
    with open(mpath, "w") as f:
        json.dump(manifest, f)
        f.flush()
        os.fsync(f.fileno())
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    return final


def latest_step(ckpt_dir: str) -> Optional[int]:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = []
    for name in os.listdir(ckpt_dir):
        if name.startswith("step_") and os.path.exists(
                os.path.join(ckpt_dir, name, _MANIFEST)):
            steps.append(int(name.split("_")[1]))
    return max(steps) if steps else None


def load_checkpoint(ckpt_dir: str, template: Any, step: Optional[int] = None,
                    shardings: Any = None, verify: bool = True):
    """Restore into the structure of ``template``.

    ``shardings`` — optional matching pytree of NamedShardings: leaves are
    device_put directly to their (possibly brand-new) mesh layout, which is
    the elastic-restart path.
    Returns (tree, step, extra).
    """
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {ckpt_dir}")
    d = os.path.join(ckpt_dir, f"step_{step:010d}")
    with open(os.path.join(d, _MANIFEST)) as f:
        manifest = json.load(f)
    flat, treedef = _flatten(template)
    shard_flat = None
    if shardings is not None:
        shard_flat = [s for _, s in _flatten(shardings)[0]]
    leaves = []
    for i, (name, tmpl) in enumerate(flat):
        meta = manifest["leaves"].get(name)
        if meta is None:
            raise CheckpointShapeError(
                f"checkpoint step {step} in {ckpt_dir} has no leaf "
                f"{name!r}: the restore template describes a different "
                f"geometry ({len(flat)} template leaves vs "
                f"{len(manifest['leaves'])} on disk)", leaf=name)
        arr = np.load(os.path.join(d, meta["file"]))
        if meta["dtype"] in _EXOTIC and arr.dtype.kind == "V":
            arr = arr.view(_EXOTIC[meta["dtype"]])
        if verify:
            crc = zlib.crc32(np.ascontiguousarray(arr).tobytes())
            if crc != meta["crc32"]:
                raise IOError(
                    f"checksum mismatch for {name} in step {step}")
        if list(arr.shape) != list(tmpl.shape):
            raise CheckpointShapeError(
                f"leaf {name!r} in checkpoint step {step} has shape "
                f"{tuple(arr.shape)} but the restore template expects "
                f"{tuple(tmpl.shape)}", leaf=name)
        if shard_flat is not None:
            leaves.append(jax.device_put(arr.astype(tmpl.dtype),
                                         shard_flat[i]))
        else:
            leaves.append(jax.numpy.asarray(arr.astype(tmpl.dtype)))
    tree = jax.tree_util.tree_unflatten(treedef, leaves)
    return tree, step, manifest.get("extra", {})


class CheckpointManager:
    """Keep-K, optionally-async checkpoint driver for the train loop."""

    def __init__(self, ckpt_dir: str, *, keep: int = 3, every: int = 100,
                 async_save: bool = False):
        self.ckpt_dir = ckpt_dir
        self.keep = keep
        self.every = every
        self.async_save = async_save
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    def maybe_save(self, step: int, tree: Any, extra=None, force=False):
        if not force and (self.every <= 0 or step % self.every != 0):
            return False
        if self.async_save:
            self.wait()  # one in flight at a time; surfaces prior failure
            host_tree = jax.tree.map(
                lambda x: np.asarray(jax.device_get(x)), tree)
            self._thread = threading.Thread(
                target=self._save_bg, args=(step, host_tree, extra),
                daemon=True)
            self._thread.start()
        else:
            self._save_and_gc(step, tree, extra)
        return True

    def _save_and_gc(self, step, tree, extra):
        save_checkpoint(self.ckpt_dir, step, tree, extra)
        self._gc()

    def _save_bg(self, step, tree, extra):
        # a daemon thread's traceback otherwise evaporates — and with it
        # the fact that the checkpoint was silently never written
        try:
            self._save_and_gc(step, tree, extra)
        except BaseException as e:  # noqa: BLE001 — re-raised on wait()
            self._error = e

    def wait(self):
        """Join the in-flight async save.  If it FAILED, re-raise its
        exception here (and on the next ``maybe_save``) instead of
        letting the train loop believe the checkpoint exists."""
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def _gc(self):
        if not os.path.isdir(self.ckpt_dir):
            return
        steps = sorted(
            int(n.split("_")[1]) for n in os.listdir(self.ckpt_dir)
            if n.startswith("step_"))
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.ckpt_dir, f"step_{s:010d}"),
                          ignore_errors=True)

    def restore_latest(self, template, shardings=None):
        step = latest_step(self.ckpt_dir)
        if step is None:
            return None
        return load_checkpoint(self.ckpt_dir, template, step, shardings)
