"""The 10 assigned architectures — exact configs from the assignment table,
plus reduced same-family smoke variants (suffix ``-smoke``).

Sources ([tier] per assignment): phi3.5-moe [hf], deepseek-v3
[arXiv:2412.19437], stablelm-3b [hf, unverified], qwen1.5-0.5b [hf],
qwen3-0.6b [hf], yi-9b [arXiv:2403.04652], recurrentgemma-2b
[arXiv:2402.19427], qwen2-vl-72b [arXiv:2409.12191], xlstm-1.3b
[arXiv:2405.04517, unverified], hubert-xlarge [arXiv:2106.07447,
unverified].
"""
from __future__ import annotations

from repro.configs.base import ModelConfig, register_named

_SCALE = dict(param_dtype="bfloat16", compute_dtype="bfloat16",
              remat="block")


@register_named("phi3.5-moe-42b")
def phi35_moe():
    return ModelConfig(
        name="phi3.5-moe-42b", family="transformer",
        n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8, head_dim=128,
        d_ff=6400, vocab_size=32064,
        moe=True, n_experts=16, top_k=2, expert_d_ff=6400,
        router_score="softmax", capacity_factor=1.25,
        act="swiglu", norm="rms", rope="standard", rope_theta=10000.0,
        max_seq_len=131072, **_SCALE)


@register_named("phi3.5-moe-42b-smoke")
def phi35_moe_smoke():
    return phi35_moe().replace(
        name="phi3.5-moe-42b-smoke", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=1, head_dim=16, d_ff=128, expert_d_ff=128, n_experts=4,
        vocab_size=128, max_seq_len=256, param_dtype="float32",
        compute_dtype="float32", attn_chunk=16)


@register_named("deepseek-v3-671b")
def deepseek_v3():
    return ModelConfig(
        name="deepseek-v3-671b", family="transformer",
        n_layers=61, d_model=7168, n_heads=128, n_kv_heads=128,
        head_dim=128, d_ff=18432, vocab_size=129280,
        mla=True, q_lora_rank=1536, kv_lora_rank=512, qk_nope_dim=128,
        qk_rope_dim=64, v_head_dim=128,
        moe=True, moe_layer_start=3, n_experts=256, top_k=8,
        n_shared_experts=1, expert_d_ff=2048, router_score="sigmoid",
        capacity_factor=1.25, aux_loss_weight=1e-4,
        mtp=True, act="swiglu", norm="rms", rope_theta=10000.0,
        max_seq_len=131072, **_SCALE)


@register_named("deepseek-v3-671b-smoke")
def deepseek_v3_smoke():
    return deepseek_v3().replace(
        name="deepseek-v3-671b-smoke", n_layers=3, d_model=64, n_heads=4,
        n_kv_heads=4, head_dim=16, d_ff=128, vocab_size=128,
        moe_layer_start=1, n_experts=4, top_k=2, expert_d_ff=64,
        q_lora_rank=32, kv_lora_rank=16, qk_nope_dim=16, qk_rope_dim=8,
        v_head_dim=16, max_seq_len=256, param_dtype="float32",
        compute_dtype="float32", attn_chunk=16)


@register_named("stablelm-3b")
def stablelm_3b():
    return ModelConfig(
        name="stablelm-3b", family="transformer",
        n_layers=32, d_model=2560, n_heads=32, n_kv_heads=32, head_dim=80,
        d_ff=6912, vocab_size=50304,
        act="swiglu", norm="ln", rope="standard", rope_fraction=0.25,
        rope_theta=10000.0, max_seq_len=4096, **_SCALE)


@register_named("stablelm-3b-smoke")
def stablelm_3b_smoke():
    return stablelm_3b().replace(
        name="stablelm-3b-smoke", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=4, head_dim=16, d_ff=160, vocab_size=128,
        max_seq_len=256, param_dtype="float32", compute_dtype="float32",
        attn_chunk=16)


@register_named("qwen1.5-0.5b")
def qwen15_05b():
    return ModelConfig(
        name="qwen1.5-0.5b", family="transformer",
        n_layers=24, d_model=1024, n_heads=16, n_kv_heads=16, head_dim=64,
        d_ff=2816, vocab_size=151936, qkv_bias=True, tie_embeddings=True,
        act="swiglu", norm="rms", rope="standard", rope_theta=1000000.0,
        max_seq_len=32768, **_SCALE)


@register_named("qwen1.5-0.5b-smoke")
def qwen15_05b_smoke():
    return qwen15_05b().replace(
        name="qwen1.5-0.5b-smoke", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=4, head_dim=16, d_ff=160, vocab_size=256,
        max_seq_len=256, param_dtype="float32", compute_dtype="float32",
        attn_chunk=16)


@register_named("qwen3-0.6b")
def qwen3_06b():
    return ModelConfig(
        name="qwen3-0.6b", family="transformer",
        n_layers=28, d_model=1024, n_heads=16, n_kv_heads=8, head_dim=128,
        d_ff=3072, vocab_size=151936, qk_norm=True, tie_embeddings=True,
        act="swiglu", norm="rms", rope="standard", rope_theta=1000000.0,
        max_seq_len=40960, **_SCALE)


@register_named("qwen3-0.6b-smoke")
def qwen3_06b_smoke():
    return qwen3_06b().replace(
        name="qwen3-0.6b-smoke", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=2, head_dim=32, d_ff=160, vocab_size=256,
        max_seq_len=256, param_dtype="float32", compute_dtype="float32",
        attn_chunk=16)


@register_named("yi-9b")
def yi_9b():
    return ModelConfig(
        name="yi-9b", family="transformer",
        n_layers=48, d_model=4096, n_heads=32, n_kv_heads=4, head_dim=128,
        d_ff=11008, vocab_size=64000,
        act="swiglu", norm="rms", rope="standard", rope_theta=5000000.0,
        max_seq_len=4096, **_SCALE)


@register_named("yi-9b-smoke")
def yi_9b_smoke():
    return yi_9b().replace(
        name="yi-9b-smoke", n_layers=3, d_model=64, n_heads=4, n_kv_heads=2,
        head_dim=16, d_ff=160, vocab_size=256, max_seq_len=256,
        param_dtype="float32", compute_dtype="float32", attn_chunk=16)


@register_named("yi-9b-half")
def yi_9b_half():
    """Source model for the yi-9b Mango grow_step dry-run cell
    (M(24, 2048) -> M(48, 4096), the paper's L/2, D/2 setting)."""
    return yi_9b().replace(
        name="yi-9b-half", n_layers=24, d_model=2048, n_heads=16,
        n_kv_heads=2, head_dim=128, d_ff=5504, vocab_size=64000)


@register_named("recurrentgemma-2b")
def recurrentgemma_2b():
    return ModelConfig(
        name="recurrentgemma-2b", family="griffin",
        n_layers=26, d_model=2560, n_heads=10, n_kv_heads=1, head_dim=256,
        d_ff=7680, vocab_size=256000, lru_width=2560, conv_width=4,
        window=2048, act="geglu", norm="rms", rope_theta=10000.0,
        scale_embeddings=True, tie_embeddings=True,
        max_seq_len=1048576, **_SCALE)


@register_named("recurrentgemma-2b-smoke")
def recurrentgemma_2b_smoke():
    return recurrentgemma_2b().replace(
        name="recurrentgemma-2b-smoke", n_layers=5, d_model=80, n_heads=4,
        n_kv_heads=1, head_dim=20, d_ff=240, vocab_size=256, lru_width=80,
        window=32, max_seq_len=256, param_dtype="float32",
        compute_dtype="float32", attn_chunk=16)


@register_named("griffin-micro")
def griffin_micro():
    """Micro griffin (rec, rec, attn) — the recurrent-family analogue of
    gpt-micro: CPU-feasible growth source and speculative draft.  Its
    window (16) is far below max_seq_len, so serve-time local-attention
    rings genuinely wrap."""
    return ModelConfig(
        name="griffin-micro", family="griffin", n_layers=3, d_model=64,
        n_heads=4, n_kv_heads=1, head_dim=16, d_ff=192, vocab_size=257,
        lru_width=64, conv_width=4, window=16, act="geglu", norm="rms",
        rope_theta=10000.0, scale_embeddings=True, tie_embeddings=True,
        max_seq_len=256, attn_chunk=16)


@register_named("griffin-micro-big")
def griffin_micro_big():
    """Growth/speculation target for griffin-micro (2x layers, 2x width,
    same vocab + window)."""
    return griffin_micro().replace(
        name="griffin-micro-big", n_layers=6, d_model=128, n_heads=4,
        head_dim=32, d_ff=384, lru_width=128)


@register_named("qwen2-vl-72b")
def qwen2_vl_72b():
    return ModelConfig(
        name="qwen2-vl-72b", family="transformer",
        n_layers=80, d_model=8192, n_heads=64, n_kv_heads=8, head_dim=128,
        d_ff=29568, vocab_size=152064, qkv_bias=True,
        act="swiglu", norm="rms", rope="mrope", mrope_sections=(16, 24, 24),
        rope_theta=1000000.0, max_seq_len=32768, **_SCALE)


@register_named("qwen2-vl-72b-smoke")
def qwen2_vl_72b_smoke():
    return qwen2_vl_72b().replace(
        name="qwen2-vl-72b-smoke", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=2, head_dim=16, d_ff=160, vocab_size=256,
        mrope_sections=(2, 3, 3), max_seq_len=256, param_dtype="float32",
        compute_dtype="float32", attn_chunk=16)


@register_named("xlstm-1.3b")
def xlstm_13b():
    return ModelConfig(
        name="xlstm-1.3b", family="xlstm",
        n_layers=48, d_model=2048, n_heads=4, n_kv_heads=4, d_ff=0,
        vocab_size=50304, proj_factor=2.0, slstm_every=8, conv_width=4,
        norm="ln", max_seq_len=1048576, **_SCALE)


@register_named("xlstm-1.3b-smoke")
def xlstm_13b_smoke():
    return xlstm_13b().replace(
        name="xlstm-1.3b-smoke", n_layers=4, d_model=64, n_heads=4,
        d_ff=0, vocab_size=256, slstm_every=4, max_seq_len=256,
        param_dtype="float32", compute_dtype="float32", attn_chunk=16)


@register_named("hubert-xlarge")
def hubert_xlarge():
    return ModelConfig(
        name="hubert-xlarge", family="transformer",
        n_layers=48, d_model=1280, n_heads=16, n_kv_heads=16, head_dim=80,
        d_ff=5120, vocab_size=504, causal=False, continuous_inputs=1280,
        rope="none", learned_pos=32768, act="gelu", norm="ln",
        max_seq_len=32768, **_SCALE)


@register_named("hubert-xlarge-smoke")
def hubert_xlarge_smoke():
    return hubert_xlarge().replace(
        name="hubert-xlarge-smoke", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=4, head_dim=16, d_ff=160, vocab_size=32,
        continuous_inputs=64, learned_pos=256, max_seq_len=256,
        param_dtype="float32", compute_dtype="float32", attn_chunk=16)


ARCH_IDS = [
    "phi3.5-moe-42b", "deepseek-v3-671b", "stablelm-3b", "qwen1.5-0.5b",
    "qwen3-0.6b", "yi-9b", "recurrentgemma-2b", "qwen2-vl-72b",
    "xlstm-1.3b", "hubert-xlarge",
]
