"""Config dataclasses + registry.

``ModelConfig`` is intentionally one flat dataclass covering every family —
configs are data, the family field selects the forward implementation, and
unknown-to-a-family fields are simply unused.  This is what lets the
launcher/dry-run treat all 10 assigned architectures uniformly
(``--arch <id>``).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


DECODE_KERNELS = ("jnp", "auto", "interpret", "reference")


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str = "transformer"  # transformer | griffin | xlstm | vit
    n_layers: int = 2
    d_model: int = 128
    n_heads: int = 2
    n_kv_heads: int = 2
    head_dim: int = 0  # 0 -> d_model // n_heads
    d_ff: int = 256
    vocab_size: int = 256

    act: str = "swiglu"  # swiglu | geglu | gelu
    norm: str = "rms"  # rms | ln
    qkv_bias: bool = False
    attn_out_bias: bool = False
    mlp_bias: bool = False
    qk_norm: bool = False
    causal: bool = True
    scale_embeddings: bool = False

    rope: str = "standard"  # none | standard | mrope
    rope_theta: float = 10000.0
    rope_fraction: float = 1.0
    mrope_sections: Tuple[int, ...] = (16, 24, 24)
    learned_pos: int = 0  # >0: learned absolute positions (max len)
    tie_embeddings: bool = False
    continuous_inputs: int = 0  # >0: stub frontend input dim (audio/vision)
    head: str = "lm"  # lm | none

    # --- MoE ---
    moe: bool = False
    n_experts: int = 0
    top_k: int = 0
    expert_d_ff: int = 0
    n_shared_experts: int = 0
    router_score: str = "softmax"  # softmax | sigmoid
    capacity_factor: float = 1.25
    moe_dispatch_dtype: str = "float32"  # bf16: halves dispatch bytes
    moe_layer_start: int = 0
    aux_loss_weight: float = 0.01

    # --- MLA (DeepSeek) ---
    mla: bool = False
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    qk_nope_dim: int = 0
    qk_rope_dim: int = 0
    v_head_dim: int = 0
    mtp: bool = False
    mtp_weight: float = 0.3

    # --- local attention ---
    window: Optional[int] = None

    # --- griffin / recurrent ---
    block_pattern: Tuple[str, ...] = ()  # e.g. ("rec","rec","attn",...)
    lru_width: int = 0
    conv_width: int = 4

    # --- xlstm ---
    proj_factor: float = 2.0
    slstm_every: int = 0  # 1 sLSTM block every N (0: pure mLSTM)

    # --- vit ---
    image_size: int = 224
    patch_size: int = 16
    n_classes: int = 1000

    # --- runtime policy ---
    max_seq_len: int = 8192
    param_dtype: str = "float32"
    compute_dtype: str = "float32"
    remat: str = "block"  # none | block
    attn_chunk: int = 512
    attn_logits_dtype: str = "float32"  # bf16: models VMEM-resident flash
    attn_prefix_chunks: bool = False  # static-prefix causal chunks (§Perf)
    unroll_scans: bool = False  # unroll inner chunk scans (cost calibration)
    # serving slot-decode attention backend: "jnp" (pure-jnp model path),
    # "auto" (Pallas kernels — compiled on TPU, interpreter elsewhere),
    # "interpret" (Pallas CPU interpreter), "reference" (kernels/ref.py
    # oracles).  Non-jnp modes route decode_step_slots / verify_step_slots
    # through kernels/ops.py; MLA latent caches always use the jnp path.
    decode_kernel: str = "jnp"

    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)
        if self.decode_kernel not in DECODE_KERNELS:
            raise ValueError(
                f"decode_kernel must be one of {DECODE_KERNELS} "
                f"(got {self.decode_kernel!r})")

    @property
    def n_dense_layers(self):
        return self.moe_layer_start if self.moe else self.n_layers

    def replace(self, **kw):
        return dataclasses.replace(self, **kw)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}

_REGISTRY: dict = {}


def register_named(name):
    """Decorator registering a zero-arg config factory under ``name``."""
    def deco(fn):
        _REGISTRY[name] = fn
        return fn
    return deco


def get_config(name: str) -> ModelConfig:
    import repro.configs.archs  # noqa: F401  (populates registry)
    import repro.configs.paper_models  # noqa: F401
    if name not in _REGISTRY:
        raise KeyError(
            f"unknown config '{name}'; known: {sorted(_REGISTRY)}")
    return _REGISTRY[name]()


def list_configs():
    import repro.configs.archs  # noqa: F401
    import repro.configs.paper_models  # noqa: F401
    return sorted(_REGISTRY)
