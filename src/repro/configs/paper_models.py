"""The paper's own experiment models (Tables 4/5): DeiT, BERT, GPT.

DeiT variants are ViTs expressed through the transformer family
(``head="cls"``, stub patch embeddings as continuous inputs, learned
positions).  BERT is encoder (non-causal) with an MLM-style head; GPT is a
causal pre-LN decoder.  Paper experiments run these at reduced ("micro")
scale on synthetic data — same growth mappings, CPU-feasible.
"""
from __future__ import annotations

from repro.configs.base import ModelConfig, register_named

_PATCH = 16 * 16 * 3  # patchified input dim


def _deit(name, layers, hidden, heads, **kw):
    base = dict(
        name=name, family="transformer", n_layers=layers, d_model=hidden,
        n_heads=heads, n_kv_heads=heads, d_ff=4 * hidden, vocab_size=1,
        causal=False, continuous_inputs=_PATCH, rope="none",
        learned_pos=197, head="cls", n_classes=1000, norm="ln", act="gelu",
        max_seq_len=256)
    base.update(kw)  # micro variants override defaults (e.g. n_classes)
    return ModelConfig(**base)


@register_named("deit-t-a")
def deit_t_a():
    return _deit("deit-t-a", 12, 192, 3)


@register_named("deit-t-b")
def deit_t_b():
    return _deit("deit-t-b", 10, 320, 5)


@register_named("deit-t-c")
def deit_t_c():
    return _deit("deit-t-c", 12, 384, 6)


@register_named("deit-s")
def deit_s():
    return _deit("deit-s", 12, 384, 6)


@register_named("deit-b")
def deit_b():
    return _deit("deit-b", 12, 768, 12)


def _bert(name, layers, hidden, heads):
    return ModelConfig(
        name=name, family="transformer", n_layers=layers, d_model=hidden,
        n_heads=heads, n_kv_heads=heads, d_ff=4 * hidden, vocab_size=30522,
        causal=False, rope="none", learned_pos=512, norm="ln", act="gelu",
        max_seq_len=512)


@register_named("bert-small")
def bert_small():
    return _bert("bert-small", 12, 512, 8)


@register_named("bert-base")
def bert_base():
    return _bert("bert-base", 12, 768, 12)


@register_named("bert-large")
def bert_large():
    return _bert("bert-large", 24, 1024, 16)


def _gpt(name, layers, hidden, heads):
    return ModelConfig(
        name=name, family="transformer", n_layers=layers, d_model=hidden,
        n_heads=heads, n_kv_heads=heads, d_ff=4 * hidden, vocab_size=50257,
        causal=True, rope="none", learned_pos=1024, norm="ln", act="gelu",
        max_seq_len=1024)


@register_named("gpt-small")
def gpt_small():
    return _gpt("gpt-small", 12, 512, 8)


@register_named("gpt-base")
def gpt_base():
    return _gpt("gpt-base", 12, 768, 12)


# ---- micro-scale variants for CPU growth experiments (same families) ----
def _micro(base: ModelConfig, name, layers, hidden, heads, **kw):
    return base.replace(
        name=name, n_layers=layers, d_model=hidden, n_heads=heads,
        n_kv_heads=heads, d_ff=4 * hidden, **kw)


@register_named("gpt-micro")
def gpt_micro():
    return _micro(_gpt("x", 4, 64, 4), "gpt-micro", 4, 64, 4,
                  vocab_size=997, learned_pos=256, max_seq_len=256)


@register_named("gpt-micro-big")
def gpt_micro_big():
    return _micro(_gpt("x", 8, 128, 8), "gpt-micro-big", 8, 128, 8,
                  vocab_size=997, learned_pos=256, max_seq_len=256)


@register_named("deit-micro")
def deit_micro():
    return _deit("deit-micro", 3, 64, 4, n_classes=16).replace(
        learned_pos=65, continuous_inputs=48)


@register_named("deit-micro-big")
def deit_micro_big():
    return _deit("deit-micro-big", 6, 128, 8, n_classes=16).replace(
        learned_pos=65, continuous_inputs=48)
