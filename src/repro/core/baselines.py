"""Growth baselines expressed in the same tensor-diagram algebra as Mango.

Per the paper's Fig. 5 / Table 1, bert2BERT and LiGO are special cases of
the TR-MPO operator:

  * bert2BERT — frozen cores: S_I = Net2Net split map, S_O = duplicate map,
    S_L = layer copy (AKI variant copies the *next* layer's knowledge for
    new depth), S_B = identity.  Nothing is trained.
  * LiGO      — trainable rank-1 S_I, S_O, S_L; S_B frozen to identity
    (no same-layer cross-weight mixing — the partial mapping the paper
    criticizes).
  * StackBERT — width-preserving, S_L = block-stacking map; S_I=S_O=S_B=I.

Implementing them through the identical packing/contract path makes the
comparison exact: the only difference between methods is which cores exist
and which are trainable.
"""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import mango


def layer_map_stack(l1, l2):
    """StackBERT map: block-stack copies (l2 % l1 -> l2)."""
    mat = np.zeros((l1, l2), np.float32)
    for j in range(l2):
        mat[j % l1, j] = 1.0
    return jnp.asarray(mat)


def layer_map_aki(l1, l2):
    """bert2BERT AKI-flavoured map: duplicated depth takes the *next*
    source layer's knowledge (advanced knowledge initialization)."""
    mat = np.zeros((l1, l2), np.float32)
    for j in range(l2):
        base = int(j * l1 / l2)
        src = min(base + (1 if j >= l1 else 0), l1 - 1)
        mat[src, j] = 1.0
    return jnp.asarray(mat)


def _identity_cores(dims, s_i, s_o, s_l, s_b=None):
    """Assemble rank-1 cores from explicit (mode) matrices."""
    def lift(m):
        return m[None, :, :, None].astype(jnp.float32)
    if s_b is None:
        s_b = jnp.eye(dims["B1"], dims["B2"])
    return {"S_B": lift(s_b), "S_I": lift(s_i), "S_O": lift(s_o),
            "S_L": lift(s_l)}


def init_bert2bert_params(op: mango.MangoOperator, aki=True):
    """Frozen function-preserving cores (not trained)."""
    p = {"groups": {}, "aux": {}}
    d1, d2 = op.plan_src.d_model, op.plan_tgt.d_model
    for g in op.plan_src.groups:
        dims = op.dims(g.name)
        lm = (layer_map_aki if aki else mango.layer_map_matrix)(
            dims["L1"], dims["L2"])
        p["groups"][g.name] = _identity_cores(
            dims,
            s_i=mango.width_expand_matrix(d1, d2, normalized=True),
            s_o=mango.width_expand_matrix(d1, d2, normalized=False),
            s_l=lm)
        p["aux"][f"{g.name}.layers"] = lm
    p["aux"]["width"] = {
        f"{d1}->{d2}": mango.width_expand_matrix(d1, d2, normalized=False)}
    return p


def init_ligo_params(rng, op: mango.MangoOperator, noise=0.01):
    """Trainable S_I/S_O/S_L, frozen-identity S_B.

    Returned params hold only the mode *matrices*; ``ligo_to_cores``
    assembles full rank-1 cores at grow time so gradients never touch S_B.
    """
    d1, d2 = op.plan_src.d_model, op.plan_tgt.d_model
    keys = jax.random.split(rng, 3 * len(op.plan_src.groups))
    ki = iter(keys)
    p = {"groups": {}, "aux": {}}
    for g in op.plan_src.groups:
        dims = op.dims(g.name)
        p["groups"][g.name] = {
            "W_I": mango.width_expand_matrix(d1, d2, True)
            + noise * jax.random.normal(next(ki), (d1, d2)),
            "W_O": mango.width_expand_matrix(d1, d2, False)
            + noise * jax.random.normal(next(ki), (d1, d2)),
            "W_L": mango.layer_map_matrix(dims["L1"], dims["L2"])
            + noise * jax.random.normal(next(ki),
                                        (dims["L1"], dims["L2"])),
        }
        p["aux"][f"{g.name}.layers"] = mango.layer_map_matrix(
            dims["L1"], dims["L2"])
    p["aux"]["width"] = {
        f"{d1}->{d2}": mango.width_expand_matrix(d1, d2, False)}
    return p


def ligo_to_cores(op: mango.MangoOperator, ligo_params):
    """LiGO mode matrices -> full core dict usable by mango.grow."""
    p = {"groups": {}, "aux": ligo_params["aux"]}
    for g in op.plan_src.groups:
        dims = op.dims(g.name)
        gp = ligo_params["groups"][g.name]
        p["groups"][g.name] = _identity_cores(
            dims, s_i=gp["W_I"], s_o=gp["W_O"], s_l=gp["W_L"])
    return p


def init_stackbert_params(op: mango.MangoOperator):
    """Width-preserving depth stacking (requires d1 == d2)."""
    d1, d2 = op.plan_src.d_model, op.plan_tgt.d_model
    assert d1 == d2, "StackBERT only grows depth"
    p = {"groups": {}, "aux": {}}
    eye = jnp.eye(d1)
    for g in op.plan_src.groups:
        dims = op.dims(g.name)
        lm = layer_map_stack(dims["L1"], dims["L2"])
        p["groups"][g.name] = _identity_cores(dims, s_i=eye, s_o=eye, s_l=lm)
        p["aux"][f"{g.name}.layers"] = lm
    p["aux"]["width"] = {f"{d1}->{d2}": eye}
    return p
