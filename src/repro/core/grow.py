"""Unified growth API: build / grow / train-operator for all methods.

Procedure (paper §3.2 "Procedures of Applying Mango"):
 (i)   pack the pretrained M(L1,D1) into the weight tensor M1;
 (ii)  train the growth operator on the task loss for ~100 steps (Eq. 7) —
       only Mango and LiGO are trainable; bert2BERT/StackBERT are frozen;
 (iii) recover M2 through the operator;
 (iv)  split M2 into M(L2,D2) initial weights and continue normal training.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro.core import baselines, mango
from repro.models import get_family

METHODS = ("mango", "ligo", "bert2bert", "stackbert", "net2net")


@dataclasses.dataclass(frozen=True)
class GrowthOperator:
    method: str
    op: mango.MangoOperator
    trainable: bool


def build(method: str, cfg_src, cfg_tgt, rank=1, rng=None, noise=None):
    """-> (GrowthOperator, op_params).

    ``noise`` scales the random component of the trainable methods'
    structured init (default 0.01).  ``noise=0`` makes an UNTRAINED
    mango operator coincide with the Net2Net expansion (width
    duplication + depth stacking) — the most function-preserving init
    available, which is what a live hot-swap wants.  Preservation is
    approximate, not exact: depth growth re-applies copied blocks, so
    grown logits drift from the source (measure with
    ``serve/upgrade.py: probe_token_agreement``)."""
    assert method in METHODS, method
    rng = rng if rng is not None else jax.random.PRNGKey(0)
    op = mango.build_operator(cfg_src, cfg_tgt, rank=rank)
    if method == "mango":
        params = mango.init_operator_params(
            rng, op, **({} if noise is None else {"noise": noise}))
        return GrowthOperator(method, op, True), params
    if method == "ligo":
        params = baselines.init_ligo_params(
            rng, op, **({} if noise is None else {"noise": noise}))
        return GrowthOperator(method, op, True), params
    if method == "bert2bert":
        return GrowthOperator(method, op, False), \
            baselines.init_bert2bert_params(op, aki=True)
    if method == "net2net":
        return GrowthOperator(method, op, False), \
            baselines.init_bert2bert_params(op, aki=False)
    if method == "stackbert":
        return GrowthOperator(method, op, False), \
            baselines.init_stackbert_params(op)


def grow_params(gop: GrowthOperator, op_params, params_src, dtype=None):
    """Differentiable for mango/ligo; pure function of frozen cores else."""
    if gop.method == "ligo":
        core_params = baselines.ligo_to_cores(gop.op, op_params)
    else:
        core_params = op_params
    return mango.grow(gop.op, core_params, params_src, dtype=dtype)


def operator_param_count(gop: GrowthOperator, op_params) -> int:
    """Trainable-parameter count (paper Table 1 comparisons)."""
    if not gop.trainable:
        return 0
    leaves = jax.tree.leaves(
        {"groups": op_params["groups"], "width": op_params["aux"]["width"]})
    return sum(int(x.size) for x in leaves)


def grow_from_source(cfg_src, cfg_tgt, *, method="mango", rank=1, steps=0,
                     data_iter=None, params_src=None, rng=None,
                     noise=None, log_fn=print):
    """Full grow bootstrap: source init -> operator -> (optional Eq. 7
    operator training on ``data_iter``) -> grown target params.

    Shared by the train and serve launchers; pass ``params_src`` to grow
    from pretrained (e.g. checkpoint-restored) weights instead of a fresh
    init.  ``noise=0`` (with ``steps=0``) keeps the untrained operator
    maximally function-preserving — see :func:`build`.
    """
    from repro.train.loss import loss_for

    rng = rng if rng is not None else jax.random.PRNGKey(0)
    if params_src is None:
        params_src = get_family(cfg_src).init(rng, cfg_src)
    gop, op_params = build(method, cfg_src, cfg_tgt, rank=rank, rng=rng,
                           noise=noise)
    if steps:
        if data_iter is None:
            raise ValueError("operator training (steps > 0) needs data_iter")
        fam_tgt = get_family(cfg_tgt)
        loss_fn = loss_for(cfg_tgt)

        def op_loss(big, batch):
            logits, aux = fam_tgt.forward(big, batch, cfg_tgt)
            return loss_fn(logits, aux, batch, cfg_tgt)[0]

        op_params, losses = train_operator(gop, op_params, params_src,
                                           op_loss, data_iter, steps=steps)
        if losses:
            log_fn(f"[grow] {method} operator trained {len(losses)} "
                   f"steps: {losses[0]:.4f} -> {losses[-1]:.4f}")
    return grow_params(gop, op_params, params_src)


def train_operator(gop: GrowthOperator, op_params, params_src, loss_fn,
                   data_iter, *, steps=100, lr=1e-3, weight_decay=1e-2):
    """Stage-(ii): optimize the operator on the task loss (Eq. 7).

    ``loss_fn(big_params, batch) -> scalar`` — the target model's loss.
    Frozen methods return their params unchanged.
    """
    if not gop.trainable:
        return op_params, []
    from repro.optim import adamw_init, adamw_update

    def objective(p, batch):
        big = grow_params(gop, p, params_src)
        return loss_fn(big, batch)

    opt_state = adamw_init(op_params)
    grad_fn = jax.jit(jax.value_and_grad(objective))

    @jax.jit
    def upd(p, s, g, step):
        return adamw_update(p, s, g, step, lr=lr, weight_decay=weight_decay)

    losses = []
    for step in range(steps):
        batch = next(data_iter)
        loss, grads = grad_fn(op_params, batch)
        op_params, opt_state = upd(op_params, opt_state, grads,
                                   jnp.int32(step + 1))
        losses.append(float(loss))
    return op_params, losses
