"""Mango: the multi-linear (TR-MPO) full-mapping growth operator (Eq. 5/6).

The full mapping tensor S ∈ R^{B1×I1×O1×L1×B2×I2×O2×L2} is decomposed into
four ring-bonded cores

    S_B (R1,B1,B2,R2)  S_O (R2,O1,O2,R3)  S_L (R3,L1,L2,R4)  S_I (R4,I1,I2,R1)

and the growth M2 = M1 ×_S is evaluated as a chain of mode products (never
materializing S):

    T1[iolp,B,q] = Σ_b  M1[b,i,o,l]  S_B[p,b,B,q]
    T2[il,pB,r,O] = Σ_{o,q} T1 S_O
    T3[i,pB,O,s,L] = Σ_{l,r} T2 S_L
    M2[B,I,O,L]  = Σ_{i,p,s} T3 S_I

Every intermediate is ≤ R² × |M2| (paper uses rank 1), and each step is a
plain matmul — MXU-shaped.  FLOPs of the chain are reported by
``contract_flops`` for the grow-step roofline.

Structured init: the rank-0 component of the cores reproduces a
function-preserving-style expansion (Net2Net width duplication on S_I/S_O,
modular layer copy on S_L, identity on S_B) so operator training (Eq. 7)
starts from a sane growth instead of noise; remaining rank components start
near zero.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import packing
from repro.models import get_family


# ------------------------------------------------------------ core tensors
def width_expand_matrix(d1, d2, rng=None, normalized=True):
    """Net2Net-style (d1, d2) expansion: col j2 copies col (j2 % d1);
    duplicated source columns are split (divided by multiplicity) so that
    compositions approximately preserve function."""
    idx = np.arange(d2) % d1
    mat = np.zeros((d1, d2), np.float32)
    counts = np.bincount(idx, minlength=d1).astype(np.float32)
    for j2, j1 in enumerate(idx):
        mat[j1, j2] = 1.0 / counts[j1] if normalized else 1.0
    return jnp.asarray(mat)


def layer_map_matrix(l1, l2):
    """(l1, l2): target layer copies source layer (interleaved stacking)."""
    mat = np.zeros((l1, l2), np.float32)
    for j in range(l2):
        mat[int(j * l1 / l2), j] = 1.0
    return jnp.asarray(mat)


def init_cores(rng, dims, rank, noise=0.01, structured=True):
    """dims: dict with B1,B2,I1,I2,O1,O2,L1,L2. rank: int or 4-tuple."""
    if isinstance(rank, int):
        rank = (rank,) * 4
    R1, R2, R3, R4 = rank
    k1, k2, k3, k4 = jax.random.split(rng, 4)

    def core(key, r_in, a, b, r_out, base):
        c = noise * jax.random.normal(key, (r_in, a, b, r_out), jnp.float32)
        if structured:
            c = c.at[0, :, :, 0].add(base)
        return c

    sb = core(k1, R1, dims["B1"], dims["B2"], R2,
              jnp.eye(dims["B1"], dims["B2"]))
    so = core(k2, R2, dims["O1"], dims["O2"], R3,
              width_expand_matrix(dims["O1"], dims["O2"], normalized=False))
    sl = core(k3, R3, dims["L1"], dims["L2"], R4,
              layer_map_matrix(dims["L1"], dims["L2"]))
    si = core(k4, R4, dims["I1"], dims["I2"], R1,
              width_expand_matrix(dims["I1"], dims["I2"], normalized=True))
    return {"S_B": sb, "S_O": so, "S_L": sl, "S_I": si}


def contract(M1, cores):
    """M1 (B1,I1,O1,L1) x cores -> M2 (B2,I2,O2,L2).

    Sharding: intermediates keep the source I mode on the data axis and the
    (growing) O mode on the model axis, so M2 is *born* in the target
    model's FSDP+TP layout — it is never replicated (the §Perf fix that
    took the grow-step cell from 61 GiB temp to fitting; see
    EXPERIMENTS.md).
    """
    from repro.distributed.sharding import annotate

    sb, so, sl, si = (cores[k] for k in ("S_B", "S_O", "S_L", "S_I"))
    t = jnp.einsum("biol,pbcq->iolpcq", M1, sb)
    t = annotate(t, ("grow_in", "grow_out", None, None, None, None))
    t = jnp.einsum("iolpcq,qomr->ilpcrm", t, so)
    t = annotate(t, ("grow_in", None, None, None, None, "grow_out"))
    t = jnp.einsum("ilpcrm,rlns->ipcmsn", t, sl)
    t = annotate(t, ("grow_in", None, None, "grow_out", None, None))
    M2 = jnp.einsum("ipcmsn,sijp->cjmn", t, si)
    M2 = annotate(M2, (None, "grow_in", "grow_out", None))
    return M2  # (B2, I2, O2, L2)


def contract_reference(M1, cores):
    """Single 8-index einsum straight from Eq. 6 (oracle for tests)."""
    return jnp.einsum(
        "biol,pbcq,qomr,rlns,sijp->cjmn",
        M1, cores["S_B"], cores["S_O"], cores["S_L"], cores["S_I"],
        optimize=True)


def contract_flops(dims, rank):
    """Total multiply-add FLOPs (x2) of the 4-step chain."""
    if isinstance(rank, int):
        rank = (rank,) * 4
    R1, R2, R3, R4 = rank
    B1, B2 = dims["B1"], dims["B2"]
    I1, I2 = dims["I1"], dims["I2"]
    O1, O2 = dims["O1"], dims["O2"]
    L1, L2 = dims["L1"], dims["L2"]
    f = 0
    f += B1 * I1 * O1 * L1 * R1 * B2 * R2          # step 1
    f += I1 * O1 * L1 * R1 * B2 * R2 * O2 * R3     # step 2
    f += I1 * L1 * R1 * B2 * O2 * R3 * L2 * R4     # step 3
    f += I1 * R1 * B2 * O2 * L2 * R4 * I2          # step 4
    return 2 * f


# ------------------------------------------------------- the full operator
@dataclasses.dataclass(frozen=True)
class MangoOperator:
    """Static description of a growth  M(cfg_src) -> M(cfg_tgt)."""
    cfg_src: Any
    cfg_tgt: Any
    plan_src: packing.Plan
    plan_tgt: packing.Plan
    rank: Any = 1
    trainable: bool = True  # False: frozen structured init (ablations)

    def dims(self, gname):
        gs = {g.name: g for g in self.plan_src.groups}[gname]
        gt = {g.name: g for g in self.plan_tgt.groups}[gname]
        assert len(gs.slots) == len(gt.slots), (
            f"slot mismatch in {gname}: {len(gs.slots)} vs {len(gt.slots)}")
        return {
            "B1": len(gs.slots), "B2": len(gt.slots),
            "I1": self.plan_src.d_model, "I2": self.plan_tgt.d_model,
            "O1": self.plan_src.d_model, "O2": self.plan_tgt.d_model,
            "L1": gs.n_layers, "L2": gt.n_layers,
        }


def build_operator(cfg_src, cfg_tgt, rank=1) -> MangoOperator:
    fam_s, fam_t = get_family(cfg_src), get_family(cfg_tgt)
    assert cfg_src.family == cfg_tgt.family
    shapes_src = jax.eval_shape(lambda: fam_s.init(jax.random.PRNGKey(0),
                                                   cfg_src))
    shapes_tgt = jax.eval_shape(lambda: fam_t.init(jax.random.PRNGKey(0),
                                                   cfg_tgt))
    plan_src = packing.build_plan(cfg_src, shapes_src)
    plan_tgt = packing.build_plan(cfg_tgt, shapes_tgt)
    return MangoOperator(cfg_src, cfg_tgt, plan_src, plan_tgt, rank)


def init_operator_params(rng, op: MangoOperator, noise=0.01):
    """Trainable params: per-group TR cores + aux vector/width operators."""
    keys = jax.random.split(rng, 2 + 2 * len(op.plan_src.groups))
    ki = iter(keys)
    p: Dict[str, Any] = {"groups": {}, "aux": {}}
    for g_src, g_tgt in zip(op.plan_src.groups, op.plan_tgt.groups):
        dims = op.dims(g_src.name)
        p["groups"][g_src.name] = init_cores(next(ki), dims, op.rank,
                                             noise=noise)
        # aux layer-mix for per-layer vectors of this group
        p["aux"][f"{g_src.name}.layers"] = layer_map_matrix(
            g_src.n_layers, g_tgt.n_layers)
    # width matrices, one per distinct (d1 -> d2) pair encountered.
    # duplication (not split) is the function-preserving choice for
    # embeddings/norm scales: downstream consumers see duplicated features.
    p["aux"]["width"] = {}
    d1, d2 = op.plan_src.d_model, op.plan_tgt.d_model
    p["aux"]["width"][f"{d1}->{d2}"] = width_expand_matrix(
        d1, d2, normalized=False)
    return p


def _grow_vector_stack(vec1, layer_mat, width_mats, d1, d2, tgt_shape):
    """(L1, n1) -> (L2, n2): layer mix then width expansion on last axis."""
    L2, n2 = tgt_shape
    v = jnp.einsum("ln,lm->mn", vec1.astype(jnp.float32), layer_mat)
    n1 = v.shape[-1]
    if n1 != n2:
        w = _width_for(width_mats, n1, n2, d1, d2)
        v = v @ w
    return v


def _width_for(width_mats, n1, n2, d1, d2):
    """Width matrix for an (n1 -> n2) axis, derived from the trainable
    (d1 -> d2) matrix when the axis is a multiple of d_model, else a fixed
    Net2Net map (cheap, non-trainable — e.g. odd head_dim paddings)."""
    key = f"{n1}->{n2}"
    if key in width_mats:
        return width_mats[key]
    base = width_mats[f"{d1}->{d2}"]
    if n1 == d1 and n2 == d2:
        return base
    if n1 % d1 == 0 and n2 % d2 == 0 and n1 // d1 == n2 // d2:
        k = n1 // d1
        return jax.scipy.linalg.block_diag(*([base] * k))
    return width_expand_matrix(n1, n2)


def grow(op: MangoOperator, op_params, params_src, dtype=None):
    """Differentiable growth: source params -> target params."""
    fam_t = get_family(op.cfg_tgt)
    shapes_tgt = jax.eval_shape(
        lambda: fam_t.init(jax.random.PRNGKey(0), op.cfg_tgt))
    dtype = dtype or jnp.dtype(op.cfg_tgt.param_dtype)
    d1, d2 = op.plan_src.d_model, op.plan_tgt.d_model
    width_mats = op_params["aux"]["width"]
    out: Dict[str, Any] = {}

    for g_src, g_tgt in zip(op.plan_src.groups, op.plan_tgt.groups):
        gname = g_src.name
        M1 = packing.pack_group(
            g_src, params_src[gname], d1,
            dtype=jnp.dtype(op.cfg_src.param_dtype))
        M2 = contract(M1, op_params["groups"][gname]).astype(dtype)
        grown = packing.unpack_group(g_tgt, M2, shapes_tgt[gname], d2)
        # per-layer vectors via aux ops
        lmat = op_params["aux"][f"{gname}.layers"]
        for v in g_src.vectors:
            leaf1 = packing._get(params_src[gname], v.path)
            tgt_shape = tuple(packing._get(shapes_tgt[gname], v.path).shape)
            grown[v.path] = _grow_vector_stack(
                leaf1, lmat, width_mats, d1, d2, tgt_shape)
        out[gname] = _unflatten_group(grown)

    # global leaves: every mismatched axis expanded by a width matrix
    for wref in op.plan_tgt.widths:
        leaf1 = packing._get(params_src, wref.path)
        tgt_shape = tuple(packing._get(shapes_tgt, wref.path).shape)
        x = leaf1.astype(jnp.float32)
        for ax, (n1, n2) in enumerate(zip(leaf1.shape, tgt_shape)):
            if n1 != n2:
                x = jnp.moveaxis(
                    jnp.moveaxis(x, ax, -1) @ _width_for(
                        width_mats, n1, n2, d1, d2), -1, ax)
        _nested_set(out, wref.path, x)
    # any leaves not covered (e.g. same-shape scalars) copied through
    _copy_missing(out, params_src, shapes_tgt)
    return jax.tree.map(lambda a, s: a.astype(dtype).reshape(s.shape),
                        out, _as_tree_template(out, shapes_tgt))


def _unflatten_group(flat: Dict[str, Any]):
    tree: Dict[str, Any] = {}
    for path, val in flat.items():
        parts = path.split(".")
        node = tree
        for part in parts[:-1]:
            node = node.setdefault(part, {})
        node[parts[-1]] = val
    return tree


def _nested_set(tree, path, val):
    parts = path.split(".")
    node = tree
    for part in parts[:-1]:
        node = node.setdefault(part, {})
    node[parts[-1]] = val


def _copy_missing(out, params_src, shapes_tgt):
    flat_t, _ = jax.tree_util.tree_flatten_with_path(shapes_tgt)
    for p, leaf in flat_t:
        path = packing.path_str(p)
        try:
            packing._get(out, path)
        except (KeyError, TypeError):
            src = packing._get(params_src, path)
            assert tuple(src.shape) == tuple(leaf.shape), \
                f"uncovered leaf {path}: {src.shape} vs {leaf.shape}"
            _nested_set(out, path, src)


def _as_tree_template(out, shapes_tgt):
    """shapes_tgt re-ordered to match out's structure."""
    def pick(path):
        return packing._get(shapes_tgt, path)
    flat, _ = jax.tree_util.tree_flatten_with_path(out)
    tmpl = {}
    for p, _leaf in flat:
        path = packing.path_str(p)
        _nested_set(tmpl, path, pick(path))
    return tmpl
