"""Packing: model params  <->  Mango weight tensor  M ∈ (B, I, O, L).

The paper concatenates a vanilla transformer layer's {W^Q, W^K, W^V, W^O,
W^IN, W^OUT} into B = 2k+4 slots of (D × D) tiles (Fig. 4).  The assigned
architectures are not vanilla (GQA, MLA low-rank factors, MoE experts,
RG-LRU gates, mLSTM projections), so we generalize:

 * every per-layer *matrix* leaf (L, a, b) is cut into ceil(a/D) x ceil(b/D)
   zero-padded (D x D) tiles — each tile is one B-slot; for a vanilla block
   this reduces exactly to the paper's 2k+4 layout;
 * 4-D expert leaves (L, E, a, b) contribute E x tiles slots — expert-expert
   interaction lands in the S_B mode (same-layer correlation, which is
   precisely what S_B models);
 * block-diagonal leaves (L, H, w, w) are embedded as one dense (HW x HW)
   block-diagonal tile (the true linear map), blocks re-extracted after
   growth;
 * per-layer vectors (norm scales, biases, conv taps) are grown by a small
   auxiliary operator (layer-mix matrix + width matrix) — the LiGO-style
   treatment, since a rank-anything S-mapping of a vector degenerates;
 * global leaves (embeddings, lm head, positional embeddings) are grown on
   their width axis by shared trainable width matrices.

Slot identity between source and target models is structural: both models
are walked in the same sorted-leaf order and must produce identical slot
counts (asserted), which holds whenever both configs are the same family
with proportionally scaled dims — the paper's setting.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.utils.pytree import path_str

# params groups that hold per-layer stacked weights, per family
BLOCK_GROUPS = ("dense_blocks", "moe_blocks", "rec_blocks", "attn_blocks",
                "m_blocks", "s_blocks")
# leaves excluded from matrix packing (semantic: routers map to expert ids,
# not a spatial axis; grown as vectors along their embed axis instead)
VECTOR_LIKE_MIN = 8  # matrices smaller than this on any side -> vectors


@dataclasses.dataclass(frozen=True)
class SlotRef:
    path: str          # leaf path inside the group subtree
    kind: str          # "matrix" | "expert" | "blockdiag"
    leaf_shape: Tuple[int, ...]
    ti: int            # tile row index (input axis)
    tj: int            # tile col index (output axis)
    expert: int = -1   # expert index for 4-D leaves / head for blockdiag


@dataclasses.dataclass(frozen=True)
class VecRef:
    path: str
    leaf_shape: Tuple[int, ...]
    tap: int = -1      # for (L, K, W) leaves: tap index


@dataclasses.dataclass(frozen=True)
class GroupPlan:
    name: str
    n_layers: int
    slots: Tuple[SlotRef, ...]
    vectors: Tuple[VecRef, ...]


@dataclasses.dataclass(frozen=True)
class WidthRef:
    path: str          # top-level leaf path
    axis: int          # axis carrying d_model
    leaf_shape: Tuple[int, ...]


@dataclasses.dataclass(frozen=True)
class Plan:
    d_model: int
    groups: Tuple[GroupPlan, ...]
    widths: Tuple[WidthRef, ...]

    @property
    def n_slots(self):
        return {g.name: len(g.slots) for g in self.groups}


def _leaves(tree):
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    return sorted(((path_str(p), l) for p, l in flat), key=lambda t: t[0])


def _n_tiles(dim, d):
    return max(1, math.ceil(dim / d))


def build_plan(cfg, shapes) -> Plan:
    """shapes: pytree of ShapeDtypeStructs (jax.eval_shape of init)."""
    D = cfg.d_model
    groups: List[GroupPlan] = []
    widths: List[WidthRef] = []

    for gname in BLOCK_GROUPS:
        if gname not in shapes:
            continue
        sub = shapes[gname]
        slots: List[SlotRef] = []
        vecs: List[VecRef] = []
        n_layers = None
        for path, leaf in _leaves(sub):
            shp = tuple(leaf.shape)
            if n_layers is None:
                n_layers = shp[0]
            assert shp[0] == n_layers, (path, shp, n_layers)
            if len(shp) == 2:
                vecs.append(VecRef(path, shp))
            elif len(shp) == 3:
                _, a, b = shp
                # NOTE: small/semantic axes (conv taps, router expert dim,
                # per-head gate outputs) are packed as zero-padded tiles too —
                # the structured core init is identity on the valid region, so
                # they start out preserved and the operator may learn to mix
                # them (the full-mapping philosophy).
                for ti in range(_n_tiles(a, D)):
                    for tj in range(_n_tiles(b, D)):
                        slots.append(SlotRef(path, "matrix", shp, ti, tj))
            elif len(shp) == 4:
                _, e, a, b = shp
                if a == b and a * e <= 4 * D and a < D:
                    # block-diagonal gate (L, H, w, w): one dense tile
                    nt = _n_tiles(a * e, D)
                    for ti in range(nt):
                        for tj in range(nt):
                            slots.append(
                                SlotRef(path, "blockdiag", shp, ti, tj))
                else:
                    for ex in range(e):
                        for ti in range(_n_tiles(a, D)):
                            for tj in range(_n_tiles(b, D)):
                                slots.append(
                                    SlotRef(path, "expert", shp, ti, tj, ex))
            else:
                raise ValueError(f"unsupported leaf rank: {path} {shp}")
        groups.append(GroupPlan(gname, n_layers, tuple(slots), tuple(vecs)))

    for path, leaf in _leaves(
            {k: v for k, v in shapes.items() if k not in BLOCK_GROUPS}):
        shp = tuple(leaf.shape)
        widths.append(WidthRef(path, -1, shp))

    return Plan(D, tuple(groups), tuple(widths))


def _get(tree, path):
    node = tree
    for part in path.split("."):
        node = node[int(part) if part.isdigit() else part]
    return node


def _set(tree, path, val):
    parts = path.split(".")
    node = tree
    for part in parts[:-1]:
        node = node[int(part) if part.isdigit() else part]
    node[parts[-1]] = val


def _to_blockdiag(w):
    """(L, H, a, a) -> (L, H*a, H*a) dense block diagonal."""
    L, H, a, _ = w.shape
    eye = jnp.eye(H, dtype=w.dtype)
    return (eye[None, :, None, :, None] *
            w[:, :, :, None, :]).reshape(L, H * a, H * a)


def _from_blockdiag(m, H, a):
    """(L, H*a, H*a) -> (L, H, a, a) extracting diagonal blocks."""
    L = m.shape[0]
    blocks = m.reshape(L, H, a, H, a)
    return blocks[:, jnp.arange(H), :, jnp.arange(H), :].transpose(
        1, 0, 2, 3)


def pack_group(group: GroupPlan, params_group, d_model: int,
               dtype=jnp.float32):
    """-> M (B, D, D, L) in ``dtype`` (bf16 halves the packed-stack HBM at
    growth time; the contraction still accumulates per-einsum in f32)."""
    D = d_model
    tiles = []
    bd_cache = {}
    for s in group.slots:
        w = _get(params_group, s.path)
        if s.kind == "blockdiag":
            if s.path not in bd_cache:
                bd_cache[s.path] = _to_blockdiag(w)
            w2 = bd_cache[s.path]  # (L, Ha, Ha)
        elif s.kind == "expert":
            w2 = w[:, s.expert]
        else:
            w2 = w
        a, b = w2.shape[1], w2.shape[2]
        i0, j0 = s.ti * D, s.tj * D
        tile = w2[:, i0:i0 + D, j0:j0 + D]
        pad = ((0, 0), (0, D - tile.shape[1]), (0, D - tile.shape[2]))
        tile = jnp.pad(tile, pad) if (tile.shape[1] < D or
                                      tile.shape[2] < D) else tile
        tiles.append(tile.astype(dtype))
    # (B, L, D, D) -> (B, D, D, L)
    M = jnp.stack(tiles, 0).transpose(0, 2, 3, 1)
    from repro.distributed.sharding import annotate
    return annotate(M, ("stack", "grow_in", "grow_out", None))


def unpack_group(group: GroupPlan, M2, target_group_shapes, d_model: int):
    """M2 (B, D2, D2, L2) -> dict of target-group matrix leaves."""
    D = d_model
    out = {}
    # gather slots per path
    per_path = {}
    for b_idx, s in enumerate(group.slots):
        per_path.setdefault(s.path, []).append((b_idx, s))
    for path, entries in per_path.items():
        shp = tuple(_get(target_group_shapes, path).shape)
        kind = entries[0][1].kind
        if kind == "blockdiag":
            L, H, a, _ = shp
            nt = _n_tiles(a * H, D)
            full = jnp.zeros((L, nt * D, nt * D), M2.dtype)
            for b_idx, s in entries:
                tile = M2[b_idx].transpose(2, 0, 1)  # (L2, D2, D2)
                full = jax.lax.dynamic_update_slice(
                    full, tile, (0, s.ti * D, s.tj * D))
            out[path] = _from_blockdiag(full[:, :a * H, :a * H], H, a)
        elif kind == "expert":
            L, E, a, b = shp
            nt_i, nt_j = _n_tiles(a, D), _n_tiles(b, D)
            full = jnp.zeros((L, E, nt_i * D, nt_j * D), M2.dtype)
            for b_idx, s in entries:
                tile = M2[b_idx].transpose(2, 0, 1)
                full = jax.lax.dynamic_update_slice(
                    full, tile[:, None], (0, s.expert, s.ti * D, s.tj * D))
            out[path] = full[:, :, :a, :b]
        else:
            L, a, b = shp
            nt_i, nt_j = _n_tiles(a, D), _n_tiles(b, D)
            full = jnp.zeros((L, nt_i * D, nt_j * D), M2.dtype)
            for b_idx, s in entries:
                tile = M2[b_idx].transpose(2, 0, 1)
                full = jax.lax.dynamic_update_slice(
                    full, tile, (0, s.ti * D, s.tj * D))
            out[path] = full[:, :a, :b]
    return out
