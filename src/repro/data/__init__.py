from repro.data.synthetic import (
    lm_batch,
    lm_data_iter,
    vision_batch,
    frames_batch,
)
