"""Deterministic synthetic data with *learnable* structure.

The container has no datasets, so every experiment runs on synthetic data
whose statistics a model can actually fit (pure-uniform tokens would make
loss curves flat and growth comparisons meaningless):

  * LM tokens follow a noisy affine-modular chain
        t_{k+1} = (a * t_k + b + e_k) mod V,   e_k ~ clipped geometric,
    which has low conditional entropy (learnable) but full vocab coverage.
  * Vision batches plant a class-dependent low-frequency pattern in noise.
  * Audio-frame batches plant a class sequence into continuous frames.

Determinism contract (fault tolerance / elastic restart): batch content is a
pure function of (seed, step, shard) — any shard of any step can be
recomputed on any host after a failure, so data needs no checkpointing.
"""
from __future__ import annotations

import numpy as np

_A, _B = 5, 17


def _rng(seed, step, shard=0):
    return np.random.default_rng(
        np.random.SeedSequence([seed, step, shard]))


def lm_batch(vocab_size, batch, seq_len, *, seed=0, step=0, shard=0,
             noise=4):
    """(batch, seq_len) int32 tokens with learnable chain structure."""
    r = _rng(seed, step, shard)
    t0 = r.integers(0, vocab_size, size=(batch, 1))
    e = r.geometric(0.5, size=(batch, seq_len - 1)).clip(0, noise)
    toks = [t0]
    cur = t0
    for k in range(seq_len - 1):
        cur = (_A * cur + _B + e[:, k:k + 1]) % vocab_size
        toks.append(cur)
    return np.concatenate(toks, axis=1).astype(np.int32)


def lm_data_iter(vocab_size, batch, seq_len, *, seed=0, shard=0,
                 start_step=0):
    step = start_step
    while True:
        yield {"tokens": lm_batch(vocab_size, batch, seq_len, seed=seed,
                                  step=step, shard=shard)}
        step += 1


def vision_batch(n_classes, batch, image_size, patch_size, *, seed=0,
                 step=0, shard=0, channels=3):
    """Patchified synthetic images: returns {"inputs": (B, N, P), "labels"}.

    Class c plants cos/sin gratings of frequency (c mod 8) — a pattern a
    ViT can classify nearly perfectly, giving real accuracy curves.
    """
    r = _rng(seed, step, shard)
    labels = r.integers(0, n_classes, size=(batch,))
    H = image_size
    yy, xx = np.meshgrid(np.arange(H), np.arange(H), indexing="ij")
    imgs = 0.3 * r.standard_normal((batch, H, H, channels)).astype(np.float32)
    freq = (labels % 8 + 1).astype(np.float32)
    phase = (labels // 8).astype(np.float32)
    pat = np.cos(2 * np.pi * freq[:, None, None] * xx[None] / H
                 + phase[:, None, None]) \
        * np.sin(2 * np.pi * freq[:, None, None] * yy[None] / H)
    imgs += pat[..., None].astype(np.float32)
    # patchify -> (B, N, p*p*C)
    p = patch_size
    n = H // p
    x = imgs.reshape(batch, n, p, n, p, channels).transpose(0, 1, 3, 2, 4, 5)
    x = x.reshape(batch, n * n, p * p * channels)
    return {"inputs": x, "labels": labels.astype(np.int32)}


def frames_batch(dim, vocab_size, batch, seq_len, *, seed=0, step=0,
                 shard=0):
    """Continuous frames + per-frame unit labels (HuBERT-style stub).

    Frame t embeds its unit id as a planted sinusoid so the encoder can
    learn the masked-unit task.
    """
    r = _rng(seed, step, shard)
    units = lm_batch(vocab_size, batch, seq_len, seed=seed + 1, step=step,
                     shard=shard)
    base = r.standard_normal((batch, seq_len, dim)).astype(np.float32) * 0.3
    t = np.arange(dim)[None, None, :]
    base += np.sin(2 * np.pi * (units[..., None] + 1) * t / dim).astype(
        np.float32)
    return {"inputs": base, "tokens": units}
