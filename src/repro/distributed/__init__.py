from repro.distributed.sharding import (
    LOGICAL_RULES_SINGLE_POD,
    LOGICAL_RULES_MULTI_POD,
    logical_to_spec,
    sharding_rules_for_mesh,
    annotate,
    use_rules,
    params_shardings,
    named_sharding_tree,
)
