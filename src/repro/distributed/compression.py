"""Gradient compression for the bandwidth-thin cross-pod axis.

At 2+ pods the data-parallel all-reduce crosses the inter-pod links (DCN or
optical), which are far thinner than intra-pod ICI.  Two standard tricks,
implemented as a ``grad_transform`` hook for ``make_train_step``:

  * bf16 reduction — cast grads to bf16 before the cross-pod psum
    (halves wire bytes; Adam is insensitive to bf16 gradient noise);
  * int8 + error feedback (1-bit-Adam-family, arXiv:2102.02888 lineage) —
    per-tensor scaled int8 quantization with the quantization residual
    carried to the next step, preserving convergence.

Inside pjit, collectives are partitioner-inserted, so explicit compression
uses ``shard_map`` over the pod axis: within the map we quantize, psum the
int8/bf16 payload, and dequantize.  The intra-pod reduction stays full
precision (fat links), only the pod axis is compressed.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.utils.compat import shard_map_compat


def bf16_compress(grads):
    """Lossy cast hook (applied pre-optimizer, after the mean)."""
    return jax.tree.map(
        lambda g: g.astype(jnp.bfloat16).astype(g.dtype), grads)


def _quantize_int8(x):
    scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-8) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def _dequantize(q, scale):
    return q.astype(jnp.float32) * scale


def make_crosspod_psum(mesh, *, method: str = "bf16", axis: str = "pod"):
    """Returns psum_fn(grads) -> grads, averaging over ``axis`` with
    compressed payloads via shard_map.  Error feedback state (int8 mode) is
    carried functionally: psum_fn(grads, err) -> (grads, err)."""
    if axis not in mesh.axis_names:
        raise ValueError(f"mesh has no axis {axis}")
    other = tuple(a for a in mesh.axis_names if a != axis)
    n = dict(zip(mesh.axis_names, mesh.devices.shape))[axis]

    if method == "bf16":
        def inner(g):
            return jax.lax.psum(g.astype(jnp.bfloat16),
                                axis).astype(g.dtype) / n

        def psum_fn(grads):
            fn = shard_map_compat(
                lambda t: jax.tree.map(inner, t), mesh,
                in_specs=P(), out_specs=P())
            return fn(grads)
        return psum_fn

    if method == "int8":
        def inner(g, e):
            x = g.astype(jnp.float32) + e
            q, scale = _quantize_int8(x)
            err = x - _dequantize(q, scale)  # residual feedback
            total = jax.lax.psum(q.astype(jnp.int32), axis)
            s_total = jax.lax.psum(scale, axis)  # conservative shared scale
            out = (total.astype(jnp.float32) * (s_total / n) / n)
            return out.astype(g.dtype), err

        def psum_fn(grads, err):
            def mapped(gt, et):
                out = jax.tree.map(inner, gt, et)
                g_new = jax.tree.map(lambda t: t[0], out,
                                     is_leaf=lambda t: isinstance(t, tuple))
                e_new = jax.tree.map(lambda t: t[1], out,
                                     is_leaf=lambda t: isinstance(t, tuple))
                return g_new, e_new
            fn = shard_map_compat(
                mapped, mesh, in_specs=(P(), P()),
                out_specs=(P(), P()))
            return fn(grads, err)
        return psum_fn

    raise ValueError(method)


def init_error_feedback(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
