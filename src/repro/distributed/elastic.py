"""Elastic scaling: resume any checkpoint on any device count.

Checkpoints are stored as full logical arrays (``repro/checkpoint``), so
elasticity is purely a *placement* question: build the new mesh from
whatever devices exist, resolve shardings from the same logical rules, and
``device_put`` the restored leaves.  Combined with the deterministic data
pipeline (batch = f(seed, step, shard)) a job can lose a pod, restart on
half the chips, and reproduce the exact gradient sequence (modulo batch
layout) from the last checkpoint.

``choose_mesh_shape`` picks the largest (data, model) factorization with
model <= requested TP degree — the policy a real launcher applies after a
node failure re-inventory.
"""
from __future__ import annotations

from typing import Optional

import jax
import numpy as np

from repro.distributed.sharding import params_shardings, \
    sharding_rules_for_mesh
from repro.utils.compat import make_mesh_compat


def choose_mesh_shape(n_devices: int, prefer_model: int = 16):
    """Largest power-of-two model axis <= prefer_model dividing n."""
    model = 1
    m = 1
    while m * 2 <= prefer_model and n_devices % (m * 2) == 0:
        m *= 2
    model = m
    return (n_devices // model, model)


def make_elastic_mesh(prefer_model: int = 16):
    n = len(jax.devices())
    shape = choose_mesh_shape(n, prefer_model)
    return make_mesh_compat(shape, ("data", "model"))


def reshard_restore(ckpt_dir: str, template, param_specs, *,
                    prefer_model: int = 16, step: Optional[int] = None):
    """Restore a checkpoint onto a mesh built from the CURRENT device set.

    Returns (tree, mesh, step, extra).
    """
    from repro.checkpoint import load_checkpoint

    mesh = make_elastic_mesh(prefer_model)
    rules = sharding_rules_for_mesh(mesh)
    shardings = params_shardings(param_specs, mesh, rules, shapes=template)
    tree, step, extra = load_checkpoint(ckpt_dir, template, step,
                                        shardings=shardings)
    return tree, mesh, step, extra
