"""Serve-side sharding: the (data=replica, model=TP) mesh plan for the
continuous-batching engine.

The training stack already has everything needed to shard a forward pass
(`sharding.py` logical rules + ``annotate`` constraints); what serving
adds is a *placement plan* for the engine's long-lived device state:

  * weights        — TP-only (``inference_rules``): heads/mlp/vocab shard
                     over ``model``, everything else replicated.  No FSDP:
                     the decode loop reads every weight every step, so the
                     full model lives on each replica.
  * slot pools     — the capacity axis shards over ``data`` (each replica
                     owns a contiguous band of slots) and the head axes
                     shard over ``model`` (each TP rank owns its heads'
                     KV/recurrent state).  The cache *sequence* axis stays
                     local: slot decode addresses it with per-row dynamic
                     indices, which sequence-sharding would turn into
                     per-step collectives.
  * paged arenas   — page payloads shard on the head axis only; the page
                     axis is a shared id space (any slot may hold any
                     page), so it must not shard.  Block tables are tiny
                     int32 index tensors and stay fully REPLICATED — every
                     device resolves the same page indirection locally.
  * decode state   — the per-slot scalar vectors (tokens, positions,
                     remaining, eos, done) and PRNG chains shard over
                     ``data`` with the slots they describe.

``ServeMeshPlan`` is hashable (one canonical instance per mesh shape via
``get_serve_plan``) so it can extend the engine's jit-cache key, and the
jitted engine functions are traced under ``use_rules(plan.mesh, ...)`` so
the model-internal ``annotate`` calls pin activations to the same layout
— per-layer collectives (the TP psums of attention/MLP output
projections) are then the only cross-device traffic in a macro step.

Everything here is inert at ``plan=None`` (the single-device engine), and
validatable in this container via
``XLA_FLAGS=--xla_force_host_platform_device_count=N``.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.distributed.sharding import (
    LOGICAL_RULES_SINGLE_POD,
    inference_rules,
    logical_to_spec,
    params_shardings,
    use_rules,
)
from repro.utils.compat import make_mesh_compat


def serve_sharding_rules() -> dict:
    """Inference rules specialised to SLOT decode.

    ``inference_rules`` shards the cache sequence axis (flash-decode
    style) — right for one long sequence, wrong for a slot pool where
    every row reads/writes its own dynamic position every step.  Serving
    shards the slot ("batch") axis over ``data`` and the head axes over
    ``model`` instead, keeping each position update device-local.
    """
    r = inference_rules(LOGICAL_RULES_SINGLE_POD)
    r["cache_seq"] = None
    return r


def parse_mesh_arg(s) -> Tuple[int, int]:
    """``"DxM"`` / ``(D, M)`` -> a (data, model) shape tuple."""
    if isinstance(s, tuple):
        shape = s
    else:
        parts = str(s).lower().split("x")
        if len(parts) != 2:
            raise ValueError(
                f"mesh layout {s!r} must be DATAxMODEL, e.g. '2x2'")
        try:
            shape = (int(parts[0]), int(parts[1]))
        except ValueError:
            raise ValueError(
                f"mesh layout {s!r} must be DATAxMODEL, e.g. '2x2'")
    if len(shape) != 2 or shape[0] < 1 or shape[1] < 1:
        raise ValueError(f"mesh shape {shape} must be two positive sizes "
                         "(data, model)")
    return (int(shape[0]), int(shape[1]))


def validate_serve_mesh(shape, cfg, capacity: int,
                        n_devices: Optional[int] = None) -> Tuple[int, int]:
    """Reject layouts that cannot shard this engine, with errors that
    name the offending geometry (instead of an XLA shape crash later).
    """
    data, model = parse_mesh_arg(shape)
    if n_devices is not None and data * model != n_devices:
        raise ValueError(
            f"mesh {data}x{model} needs {data * model} devices but "
            f"{n_devices} are visible (set "
            f"XLA_FLAGS=--xla_force_host_platform_device_count="
            f"{data * model}, or pick a layout whose product is "
            f"{n_devices})")
    if cfg.n_heads % model != 0:
        raise ValueError(
            f"model axis {model} does not divide {cfg.name!r}'s "
            f"n_heads={cfg.n_heads} — tensor parallelism splits the head "
            f"axis, so pick model from the divisors of {cfg.n_heads}")
    if capacity % data != 0:
        raise ValueError(
            f"data axis {data} does not divide the slot-pool capacity "
            f"{capacity} — each replica owns capacity/data slots, so "
            f"raise --capacity to a multiple of {data} or shrink the "
            f"data axis")
    return (data, model)


def choose_serve_mesh_shape(n_devices: int, cfg, capacity: int
                            ) -> Tuple[int, int]:
    """Pick a (data, model) layout for this device count + model geometry:
    the largest TP (model) axis that divides both the device count and the
    head count, with the remainder as data replicas dividing capacity.
    TP-first mirrors ``elastic.choose_mesh_shape``'s preference — weights
    are the scarce memory, and TP is what shrinks them per device."""
    for model in sorted((m for m in range(1, n_devices + 1)
                         if n_devices % m == 0), reverse=True):
        data = n_devices // model
        if cfg.n_heads % model == 0 and capacity % data == 0:
            return (data, model)
    raise ValueError(
        f"no (data, model) layout over {n_devices} devices divides both "
        f"n_heads={cfg.n_heads} and capacity={capacity}; adjust "
        f"--capacity or pass --mesh explicitly")


class ServeMeshPlan:
    """One mesh + the sharding builders the engine needs.  Hashable by
    identity; ``get_serve_plan`` canonicalises per shape so every engine
    over the same mesh shares one jit cache."""

    def __init__(self, shape: Tuple[int, int]):
        self.shape = shape
        self.data, self.model = shape
        self.n_devices = self.data * self.model
        self.mesh = make_mesh_compat(shape, ("data", "model"))
        self.rules = serve_sharding_rules()

    def describe(self) -> str:
        return f"{self.data}x{self.model}"

    # ------------------------------------------------------------ shardings
    def params_shardings_for(self, fam, cfg, params):
        return params_shardings(fam.param_specs(cfg), self.mesh,
                                self.rules, shapes=params)

    def pool_shardings(self, fam, cfg, pool, meta):
        """NamedSharding tree for one slot pool (dense or paged).

        Dense pools resolve ``fam.cache_specs(cfg)`` directly (the
        "batch" axis is the slot axis -> data; kv_heads/lru -> model,
        with the divisibility guard replicating non-dividing head
        counts).  Paged pools re-map each DECLARED group: arena payloads
        keep only the layer + trailing (head) axes of the dense spec —
        the page axis (and, for seq groups, the in-page axis) must NOT
        shard, since pages are one shared id space any slot may hold —
        and the block table is replicated everywhere.  Leaves a group
        does not name (dense per-slot carries) shard like the dense
        pool.
        """
        specs = fam.cache_specs(cfg)
        if meta is None:
            return params_shardings(specs, self.mesh, self.rules,
                                    shapes=pool)
        paged = {g.path[0]: g for g in meta.groups}

        def leaf_sh(logical, leaf):
            return NamedSharding(
                self.mesh, logical_to_spec(tuple(logical), leaf.shape,
                                           self.mesh, self.rules))

        def walk(sp, pl, g=None):
            if isinstance(pl, dict) and "bt" in pl and g is not None:
                out = {}
                for lk, leaf in pl.items():
                    if lk == "bt":
                        out[lk] = NamedSharding(self.mesh, P())
                    elif lk in g.leaves:
                        # seq: (L, B, S, ...) -> (L, pages, page, ...);
                        # slot: (L, B, tail...) -> (L, pages, tail...)
                        arena = ((sp[lk][0], None, None)
                                 + tuple(sp[lk][3:]) if g.kind == "seq"
                                 else (sp[lk][0], None) + tuple(sp[lk][2:]))
                        out[lk] = leaf_sh(arena, leaf)
                    else:
                        out[lk] = leaf_sh(sp[lk], leaf)
                return out
            if isinstance(pl, dict):
                return {k: walk(sp[k], pl[k], paged.get(k)) for k in pl}
            return leaf_sh(sp, pl)

        return walk(specs, pool)

    def state_shardings(self):
        """The engine's persistent decode-state six-tuple: per-slot
        vectors ride the data axis with their slots."""
        d = NamedSharding(self.mesh, P("data"))
        return (d, d, d, d, d, NamedSharding(self.mesh, P("data", None)))

    # ------------------------------------------------------------ admission
    def free_slot_order(self, capacity: int):
        """Slot ids in admission order, round-robining consecutive
        admissions across data replicas: the j-th admitted request lands
        on replica ``j % data`` (each replica owns a contiguous
        capacity/data band of the slot axis), so light traffic spreads
        over replicas instead of saturating replica 0's band first."""
        band = capacity // self.data
        return [(j % self.data) * band + j // self.data
                for j in range(capacity)]

    # -------------------------------------------------------------- tracing
    def wrap(self, fn):
        """Run ``fn`` under this plan's mesh + logical rules, so the
        model-internal ``annotate`` calls become live sharding
        constraints at trace time.  Entering the context per call is a
        few thread-local writes — nothing on the steady-state hot path
        recompiles or syncs."""
        if fn is None:
            return None

        @functools.wraps(fn)
        def wrapped(*args, **kwargs):
            with self.mesh, use_rules(self.mesh, self.rules):
                return fn(*args, **kwargs)

        return wrapped


@functools.lru_cache(maxsize=None)
def get_serve_plan(shape: Tuple[int, int]) -> ServeMeshPlan:
    """Canonical plan per mesh shape (identity-hashable jit-cache key)."""
    return ServeMeshPlan(parse_mesh_arg(shape))


def per_device_bytes(tree) -> int:
    """Bytes one device holds for ``tree`` — the startup report's
    per-device pool reservation.  Uses each leaf's actual sharding
    (committed arrays), falling back to the full shape for uncommitted
    single-device arrays."""
    total = 0
    for leaf in jax.tree.leaves(tree):
        sh = getattr(leaf, "sharding", None)
        shape = leaf.shape
        if sh is not None:
            try:
                shape = sh.shard_shape(leaf.shape)
            except Exception:
                pass
        total += int(np.prod(shape)) * np.dtype(leaf.dtype).itemsize
    return total
