"""Logical-axis sharding rules (flax.linen.partitioning style, stand-alone).

Every parameter and activation in the model zoo is annotated with *logical*
axis names ("batch", "heads", "mlp", ...).  A rules table maps logical names
to physical mesh axes.  This keeps the model code mesh-agnostic: the same
forward function lowers for 1 device (tests), a 16x16 pod, or a 2x16x16
multi-pod mesh.

Shardability guard: a logical axis only binds to a mesh axis when the
dimension is divisible by the mesh-axis size — e.g. kv_heads=8 cannot shard
over model=16 and silently falls back to replicated, which is exactly the
GQA-on-TPU convention (q heads sharded, kv replicated/partially sharded).
"""
from __future__ import annotations

import contextlib
import threading

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.utils.compat import get_abstract_mesh

# logical axis -> physical mesh axis (or tuple of axes)
LOGICAL_RULES_SINGLE_POD = {
    "batch": ("data",),
    "seq": None,
    "embed": None,
    "heads": ("model",),
    "kv_heads": ("model",),
    "head_dim": None,
    "mlp": ("model",),
    "vocab": ("model",),
    "experts": ("model",),
    "expert_mlp": None,
    "layers": None,
    "lru": ("model",),
    "q_lora": None,
    "kv_lora": None,
    "capacity": None,
    "stack": None,  # growth-operator weight-slot mode
    "grow_in": ("data",),
    "grow_out": ("model",),
    "rank": None,
    "cache_seq": None,  # KV-cache sequence axis (sharded for inference)
    "moe_group": ("data",),  # MoE dispatch-group axis (tokens stay local
    #                          in training; None for serving => tokens move
    #                          to expert owners, weights stay resident)
}

LOGICAL_RULES_MULTI_POD = dict(LOGICAL_RULES_SINGLE_POD)
LOGICAL_RULES_MULTI_POD["batch"] = ("pod", "data")
LOGICAL_RULES_MULTI_POD["moe_group"] = ("pod", "data")


def fsdp_rules(rules: dict, multi_pod: bool = False) -> dict:
    """FSDP+TP: parameter d_model ("embed") axes additionally shard over the
    data axis (GSPMD all-gathers at use, reduce-scatters grads — ZeRO-3).
    Activation specs are unaffected: their "batch" axis claims the data axis
    first, so "embed" falls back to replicated there (see logical_to_spec's
    used-axis tracking)."""
    r = dict(rules)
    r["embed"] = ("data",) if not multi_pod else ("data",)
    return r


def inference_rules(rules: dict) -> dict:
    """Serving layout: TP-only weights (no FSDP — GSPMD hoists the
    loop-invariant param all-gathers out of the decode loop, materializing
    the full model per device), KV caches sharded along *sequence* over the
    model axis (flash-decode style partial-softmax; required when kv_heads
    < model axis size), experts sharded 2-D (data x model) so 100B+-param
    MoEs fit without FSDP."""
    r = dict(rules)
    r["embed"] = None
    r["cache_seq"] = ("model",)
    # NOTE: within a cache spec, cache_seq claims "model" first and the
    # used-axis guard then replicates kv_heads there; weight specs have no
    # cache_seq, so wk/wv still shard over model.
    r["kv_heads"] = ("model",)
    r["heads"] = ("model",)
    r["experts"] = ("data", "model")
    r["expert_mlp"] = ("model",)  # experts axis rarely divides data*model
    # dispatched-token tensors follow the expert owners (all-to-all on the
    # tiny token activations) instead of forcing weight gathers
    r["moe_group"] = None
    return r


def sharding_rules_for_mesh(mesh: Mesh, fsdp: bool = False,
                            inference: bool = False) -> dict:
    multi = "pod" in mesh.axis_names
    base = LOGICAL_RULES_MULTI_POD if multi else LOGICAL_RULES_SINGLE_POD
    if inference:
        return inference_rules(base)
    return fsdp_rules(base, multi) if fsdp else base


class _RulesState(threading.local):
    def __init__(self):
        self.rules = None
        self.mesh = None


_STATE = _RulesState()


@contextlib.contextmanager
def use_rules(mesh: Mesh, rules: dict | None = None):
    """Activate logical->physical rules; inside, ``annotate`` is live."""
    prev = (_STATE.rules, _STATE.mesh)
    _STATE.rules = rules if rules is not None else sharding_rules_for_mesh(mesh)
    _STATE.mesh = mesh
    try:
        yield
    finally:
        _STATE.rules, _STATE.mesh = prev


@contextlib.contextmanager
def suspend_rules():
    """Deactivate logical-rule annotations (``annotate`` becomes a no-op).

    Old-jax escape hatch for partial-manual ``shard_map`` bodies: a
    ``with_sharding_constraint`` built on the concrete mesh there trips the
    SPMD partitioner's manual-subgroup check, and without abstract-mesh
    introspection ``annotate`` cannot rebuild the constraint correctly —
    inside such regions GSPMD must infer layouts from the operands alone.
    """
    prev = (_STATE.rules, _STATE.mesh)
    _STATE.rules, _STATE.mesh = None, None
    try:
        yield
    finally:
        _STATE.rules, _STATE.mesh = prev


def _axis_sizes(mesh: Mesh) -> dict:
    return dict(zip(mesh.axis_names, mesh.devices.shape))


def logical_to_spec(logical, shape=None, mesh: Mesh | None = None,
                    rules: dict | None = None) -> P:
    """Map a tuple of logical axis names to a PartitionSpec.

    ``shape`` (optional) enables the divisibility guard.
    """
    mesh = mesh if mesh is not None else _STATE.mesh
    rules = rules if rules is not None else _STATE.rules
    if rules is None:
        return P()
    sizes = _axis_sizes(mesh) if mesh is not None else {}
    out = []
    used = set()
    for i, name in enumerate(logical):
        axes = rules.get(name)
        if axes is None:
            out.append(None)
            continue
        axes = tuple(a for a in axes if a not in used and a in sizes)
        if not axes:
            out.append(None)
            continue
        total = 1
        for a in axes:
            total *= sizes[a]
        if shape is not None and shape[i] % total != 0:
            # fall back: try a prefix of the axes that divides
            ok = ()
            tot = 1
            for a in axes:
                if shape[i] % (tot * sizes[a]) == 0:
                    ok = ok + (a,)
                    tot *= sizes[a]
                else:
                    break
            axes = ok
        if not axes:
            out.append(None)
        else:
            used.update(axes)
            out.append(axes if len(axes) > 1 else axes[0])
    return P(*out)


def annotate(x, logical):
    """with_sharding_constraint by logical names; no-op outside use_rules.

    Inside a partial-auto ``shard_map`` region (lazy-sync FSDP step), the
    ambient abstract mesh has Manual axes: constraints are rebuilt on that
    mesh with the manual axes stripped from the spec (they are physical
    there, not the partitioner's business).
    """
    if _STATE.rules is None or _STATE.mesh is None:
        return x
    spec = logical_to_spec(logical, shape=x.shape)
    mesh = _STATE.mesh
    cur = get_abstract_mesh()
    if cur is not None and getattr(cur, "_any_axis_manual", False):
        manual = set(cur.manual_axes)
        parts = []
        for e in spec:
            if e is None:
                parts.append(None)
                continue
            es = e if isinstance(e, tuple) else (e,)
            kept = tuple(a for a in es if a not in manual)
            parts.append(kept if len(kept) > 1
                         else (kept[0] if kept else None))
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(cur, P(*parts)))
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def params_shardings(param_specs, mesh: Mesh, rules: dict | None = None,
                     shapes=None):
    """Resolve a pytree of logical-spec tuples into NamedShardings.

    ``shapes`` — optional matching pytree of ShapeDtypeStructs/arrays used for
    the divisibility guard.
    """
    rules = rules if rules is not None else sharding_rules_for_mesh(mesh)

    if shapes is None:
        def f(spec):
            return NamedSharding(mesh, logical_to_spec(spec, None, mesh, rules))
        return jax.tree.map(f, param_specs, is_leaf=lambda x: isinstance(x, tuple))

    def g(spec, arr):
        return NamedSharding(
            mesh, logical_to_spec(spec, arr.shape, mesh, rules)
        )
    return jax.tree.map(
        g, param_specs, shapes, is_leaf=lambda x: isinstance(x, tuple)
    )


def named_sharding_tree(tree, mesh: Mesh, spec=P()):
    """Uniform NamedSharding over a whole pytree (e.g. replicated)."""
    return jax.tree.map(lambda _: NamedSharding(mesh, spec), tree)


def zero_shardings(base_shardings, shapes, mesh: Mesh,
                   zero_axes=("data",)):
    """ZeRO-style extra sharding for optimizer state.

    For each leaf, take the parameter's sharding and additionally shard the
    *largest free (replicated) dimension* over ``zero_axes`` if divisible.
    Optimizer moments/master weights are only touched by the update (no
    activation interplay), so this is free memory savings; GSPMD inserts the
    all-gather/reduce-scatter pair around the update.
    """
    sizes = _axis_sizes(mesh)

    def one(sh, leaf):
        spec = list(sh.spec) + [None] * (len(leaf.shape) - len(sh.spec))
        used = set()
        for e in spec:
            if e is None:
                continue
            used.update(e if isinstance(e, tuple) else (e,))
        # place every still-unused zero axis on the largest divisible free
        # dim (each axis independently — pod and data may land on different
        # dims, or stack on the same one if divisibility allows)
        shard_per_dim = [
            int(np.prod([sizes[a] for a in
                         (e if isinstance(e, tuple) else (e,))]))
            if e is not None else 1 for e in spec]
        for a in zero_axes:
            if a in used or a not in sizes:
                continue
            cands = [(leaf.shape[i] // shard_per_dim[i], i)
                     for i in range(len(spec))
                     if (leaf.shape[i] % (shard_per_dim[i] * sizes[a]) == 0)]
            if not cands:
                continue
            _, idx = max(cands)
            cur = spec[idx]
            if cur is None:
                spec[idx] = a
            else:
                spec[idx] = (cur if isinstance(cur, tuple) else (cur,)) + (a,)
            shard_per_dim[idx] *= sizes[a]
            used.add(a)
        return NamedSharding(mesh, P(*spec))

    return jax.tree.map(one, base_shardings, shapes)
