"""Pallas TPU kernel: flash-decode (one query token vs a long KV cache).

Decode attention is memory-bound: the whole KV cache streams through VMEM
once per step.  Grid (B, KV, Sk/BK) with the cache axis innermost; a running
(m, l, acc) per (batch, kv-head) lives in VMEM scratch — all G query heads
of a kv group are processed together as a (G, hd) tile so the cache block is
read exactly once per group (the GQA bandwidth win).

``kv_len`` masks the unwritten cache tail (padded caches); it may be a
scalar (uniform batch) or a (B,) vector — the continuous-batching case
where every batch row is a cache slot at its own sequence length.  Rows
with kv_len == 0 (idle slots) return zeros.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(len_ref, q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            bk, scale):
    ki = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    kv_len = len_ref[pl.program_id(0)]

    @pl.when(ki * bk < kv_len)
    def _body():
        q = q_ref[0, 0]  # (G, hd)
        k = k_ref[0, 0]  # (BK, hd)
        v = v_ref[0, 0]
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
        kpos = ki * bk + jax.lax.broadcasted_iota(
            jnp.int32, s.shape, 1)
        s = jnp.where(kpos < kv_len, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, -1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jnp.dot(
            p.astype(v.dtype), v, preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(ki == nk - 1)
    def _fin():
        o_ref[0, 0] = (acc_ref[...] /
                       jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


def _pick_bk(S: int, cap: int = 256) -> int:
    """Largest divisor of the cache length that is <= ``cap``.

    The grid tiles the cache axis in ``bk``-sized blocks, so ``bk`` must
    divide S exactly; short caches (e.g. a serve pool with max_len=48)
    simply use one block instead of failing the old ``S % 256 == 0``
    assert and falling back to the reference path.  Cache lengths whose
    only divisors in range are tiny (e.g. prime S > 256) would silently
    degenerate into a pathological one-element-block grid — fail loudly
    instead and let the caller pad the cache or pass ``bk``.
    """
    bk = min(S, cap)
    while S % bk:
        bk -= 1
    if S > cap and bk < 32:
        raise ValueError(
            f"cache length {S} has no block divisor in [32, {cap}]; pad "
            f"the cache axis or pass bk explicitly")
    return bk


@functools.partial(jax.jit, static_argnames=("bk", "interpret"))
def decode_attention(q, k, v, kv_len, *, bk=None, interpret=False):
    """q: (B, H, hd); k, v: (B, KV, S, hd); kv_len: scalar or (B,) vector
    of valid lengths -> (B, H, hd).

    ``bk=None`` auto-picks the largest cache-axis block <= 256 that divides
    S.  Rows with ``kv_len == 0`` (idle/finished slots — the
    continuous-batching macro-step's ``done`` rows, folded into kv_len by
    ``ops.decode_attention``) skip every KV block and return exact zeros.
    """
    B, H, hd = q.shape
    KV, S = k.shape[1], k.shape[2]
    g = H // KV
    if bk is None:
        bk = _pick_bk(S)
    assert S % bk == 0, (S, bk)
    qg = q.reshape(B, KV, g, hd)
    scale = hd ** -0.5
    kv_len = jnp.broadcast_to(
        jnp.asarray(kv_len, jnp.int32).reshape(-1), (B,))

    grid = (B, KV, S // bk)
    out = pl.pallas_call(
        functools.partial(_kernel, bk=bk, scale=scale),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, 1, g, hd), lambda b, h, j, *_: (b, h, 0, 0)),
                pl.BlockSpec((1, 1, bk, hd),
                             lambda b, h, j, *_: (b, h, j, 0)),
                pl.BlockSpec((1, 1, bk, hd),
                             lambda b, h, j, *_: (b, h, j, 0)),
            ],
            out_specs=pl.BlockSpec((1, 1, g, hd),
                                   lambda b, h, j, *_: (b, h, 0, 0)),
            scratch_shapes=[
                pltpu.VMEM((g, 1), jnp.float32),
                pltpu.VMEM((g, 1), jnp.float32),
                pltpu.VMEM((g, hd), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((B, KV, g, hd), q.dtype),
        interpret=interpret,
    )(kv_len, qg, k, v)
    return out.reshape(B, H, hd)
