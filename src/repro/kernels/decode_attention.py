"""Pallas TPU kernels: flash-decode against a KV cache (slot-serving family).

Decode attention is memory-bound: the whole KV cache streams through VMEM
once per step.  Grid (B, KV, Sk/BK) with the cache axis innermost; a running
(m, l, acc) per (batch, kv-head) lives in VMEM scratch — all G query heads
of a kv group are processed together as a (G, hd) tile so the cache block is
read exactly once per group (the GQA bandwidth win).

``kv_len`` masks the unwritten cache tail (padded caches); it may be a
scalar (uniform batch) or a (B,) vector — the continuous-batching case
where every batch row is a cache slot at its own sequence length.  Rows
with kv_len == 0 (idle slots) return zeros.

Four kernels share the streaming-softmax machinery:

  * ``decode_attention``      — (B, KV, S, hd) caches, scalar/(B,) kv_len
                                (the original head-major layout);
  * ``slot_decode_attention`` — the same math over the serve engine's
                                POOL layout (B, S, KV, hd): no transpose
                                of the cache on the hot path;
  * ``ring_decode_attention`` — ring-buffer window caches: the band mask
                                is reconstructed per block from the ring
                                invariant at each row's own length;
  * ``chunk_verify_attention``— speculative verify: D+1 chunk queries per
                                row against [cache ‖ chunk] at per-row
                                offsets, cache read-only.

The slot-path kernels encode done/idle rows as a negative per-row scalar
(kv_len == 0, slot_positions == -1, offsets == -1): every KV block is
skipped and the empty accumulator finalizes to exact zeros.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_update(s, v, m_ref, l_ref, acc_ref):
    """One streaming-softmax accumulator update.

    s: (..., BK) masked logits; v: (BK, hd) values; scratch shapes are
    m/l: (..., 1) and acc: (..., hd).  A block must contain at least one
    unmasked logit (callers guard with ``mask.any()``) — otherwise the
    NEG_INF - NEG_INF shift would turn masked entries into exp(0).
    """
    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    p = jnp.exp(s - m_new)
    alpha = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, -1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jnp.dot(
        p.astype(v.dtype), v, preferred_element_type=jnp.float32)
    m_ref[...] = m_new


def _finalize(o_ref, acc_ref, l_ref, idx):
    o_ref[idx] = (acc_ref[...] /
                  jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


def _kernel(len_ref, q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            bk, scale):
    ki = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    kv_len = len_ref[pl.program_id(0)]

    @pl.when(ki * bk < kv_len)
    def _body():
        q = q_ref[0, 0]  # (G, hd)
        k = k_ref[0, 0]  # (BK, hd)
        v = v_ref[0, 0]
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
        kpos = ki * bk + jax.lax.broadcasted_iota(
            jnp.int32, s.shape, 1)
        s = jnp.where(kpos < kv_len, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, -1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jnp.dot(
            p.astype(v.dtype), v, preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(ki == nk - 1)
    def _fin():
        o_ref[0, 0] = (acc_ref[...] /
                       jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


def _pick_bk(S: int, cap: int = 256) -> int:
    """Largest divisor of the cache length that is <= ``cap``.

    The grid tiles the cache axis in ``bk``-sized blocks, so ``bk`` must
    divide S exactly; short caches (e.g. a serve pool with max_len=48)
    simply use one block instead of failing the old ``S % 256 == 0``
    assert and falling back to the reference path.  Cache lengths whose
    only divisors in range are tiny (e.g. prime S > 256) would silently
    degenerate into a pathological one-element-block grid — fail loudly
    instead and let the caller pad the cache or pass ``bk``.
    """
    bk = min(S, cap)
    while S % bk:
        bk -= 1
    if S > cap and bk < 32:
        raise ValueError(
            f"cache length {S} has no block divisor in [32, {cap}]; pad "
            f"the cache axis or pass bk explicitly")
    return bk


@functools.partial(jax.jit, static_argnames=("bk", "interpret"))
def decode_attention(q, k, v, kv_len, *, bk=None, interpret=False):
    """q: (B, H, hd); k, v: (B, KV, S, hd); kv_len: scalar or (B,) vector
    of valid lengths -> (B, H, hd).

    ``bk=None`` auto-picks the largest cache-axis block <= 256 that divides
    S.  Rows with ``kv_len == 0`` (idle/finished slots — the
    continuous-batching macro-step's ``done`` rows, folded into kv_len by
    ``ops.decode_attention``) skip every KV block and return exact zeros.
    """
    B, H, hd = q.shape
    KV, S = k.shape[1], k.shape[2]
    g = H // KV
    if bk is None:
        bk = _pick_bk(S)
    assert S % bk == 0, (S, bk)
    qg = q.reshape(B, KV, g, hd)
    scale = hd ** -0.5
    kv_len = jnp.broadcast_to(
        jnp.asarray(kv_len, jnp.int32).reshape(-1), (B,))

    grid = (B, KV, S // bk)
    out = pl.pallas_call(
        functools.partial(_kernel, bk=bk, scale=scale),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, 1, g, hd), lambda b, h, j, *_: (b, h, 0, 0)),
                pl.BlockSpec((1, 1, bk, hd),
                             lambda b, h, j, *_: (b, h, j, 0)),
                pl.BlockSpec((1, 1, bk, hd),
                             lambda b, h, j, *_: (b, h, j, 0)),
            ],
            out_specs=pl.BlockSpec((1, 1, g, hd),
                                   lambda b, h, j, *_: (b, h, 0, 0)),
            scratch_shapes=[
                pltpu.VMEM((g, 1), jnp.float32),
                pltpu.VMEM((g, 1), jnp.float32),
                pltpu.VMEM((g, hd), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((B, KV, g, hd), q.dtype),
        interpret=interpret,
    )(kv_len, qg, k, v)
    return out.reshape(B, H, hd)


# ===================================================== pool-layout kernels
# The serve engine's slot pool stores KV as (B, S, KV, hd) — scatters index
# the cache axis right after the slot axis.  These kernels read that layout
# directly (BlockSpec (1, bk, 1, hd) over the cache axis), so the hot path
# never transposes the pool.

def _slot_kernel(len_ref, q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref,
                 *, bk, scale):
    """Full-KV slot decode: per-row valid length, pool layout."""
    ki = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    kv_len = len_ref[pl.program_id(0)]

    @pl.when(ki * bk < kv_len)
    def _body():
        q = q_ref[0, 0]       # (G, hd)
        k = k_ref[0, :, 0]    # (BK, hd)
        v = v_ref[0, :, 0]
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
        kpos = ki * bk + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where(kpos < kv_len, s, NEG_INF)
        _flash_update(s, v, m_ref, l_ref, acc_ref)

    @pl.when(ki == nk - 1)
    def _fin():
        _finalize(o_ref, acc_ref, l_ref, (0, 0))


def _ring_kernel(pos_ref, q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref,
                 *, bk, ring, window, scale):
    """Ring-buffer window slot decode, pool layout.

    Each cache slot's ABSOLUTE position is reconstructed from the ring
    invariant (slot ``s`` holds the largest position ``p <= qpos`` with
    ``p % ring == s``) at the row's own length, and the attention band
    ``(qpos - window, qpos]`` is masked on those positions — the in-kernel
    mirror of ``models.attention.ring_slot_attend``.  Rows with
    ``slot_positions < 0`` (done/idle) skip every block and finalize to
    exact zeros.
    """
    ki = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    qpos = pos_ref[pl.program_id(0)]  # row length - 1 == query position
    slot = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (1, bk), 1)
    wrap = qpos // ring  # == (cur_len - 1) // ring with cur_len = qpos + 1
    base = wrap * ring + slot
    kpos = jnp.where(base <= qpos, base, base - ring)
    valid = (kpos >= 0) & (kpos > qpos - window)  # kpos <= qpos by constr.

    @pl.when((qpos >= 0) & jnp.any(valid))
    def _body():
        q = q_ref[0, 0]       # (G, hd)
        k = k_ref[0, :, 0]    # (BK, hd)
        v = v_ref[0, :, 0]
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
        s = jnp.where(valid, s, NEG_INF)
        _flash_update(s, v, m_ref, l_ref, acc_ref)

    @pl.when(ki == nk - 1)
    def _fin():
        _finalize(o_ref, acc_ref, l_ref, (0, 0))


def _chunk_kernel(off_ref, q_ref, ck_ref, cv_ref, kc_ref, vc_ref, o_ref,
                  m_ref, l_ref, acc_ref, *, bk, nk, s_chunk, cache_len,
                  ring, window, scale):
    """Speculative chunk-verify: S = d+1 queries per row over
    [cache ‖ chunk] at per-row offsets, cache READ-ONLY.

    Grid axis 2 runs nk cache blocks then one chunk step (j == nk): the
    cache streams through VMEM exactly once while all S chunk queries
    accumulate, and the in-flight chunk's own K/V (tiny: S keys) is
    attended causally in the final step.  ``ring`` selects the ring- vs
    full-layout reconstruction of cache key positions; rows with
    ``offsets < 0`` (done) produce exact zeros.
    """
    j = pl.program_id(2)
    off = off_ref[pl.program_id(0)]

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # query j sits at absolute position off + j  -> (S, 1, 1)
    qpos = off + jax.lax.broadcasted_iota(jnp.int32, (s_chunk, 1, 1), 0)

    def band(kpos):
        v = (kpos >= 0) & (kpos <= qpos)
        if window is not None:
            v &= kpos > qpos - window
        return v

    @pl.when((off >= 0) & (j < nk))
    def _cache_block():
        slot = j * bk + jax.lax.broadcasted_iota(jnp.int32, (1, 1, bk), 2)
        if ring:
            # committed length == off: slot s holds the largest p < off
            # with p % ring == s (never-written slots go negative)
            wrap = (off - 1) // cache_len
            base = wrap * cache_len + slot
            kpos = jnp.where(base < off, base, base - cache_len)
        else:
            kpos = jnp.where(slot < off, slot, -1)
        valid = band(kpos)

        @pl.when(jnp.any(valid))
        def _():
            q = q_ref[0, :, 0]      # (S, G, hd)
            k = ck_ref[0, :, 0]     # (BK, hd)
            v = cv_ref[0, :, 0]
            s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
            s = jnp.where(valid, s, NEG_INF)
            _flash_update(s, v, m_ref, l_ref, acc_ref)

    @pl.when((off >= 0) & (j == nk))
    def _chunk_block():
        kpos = off + jax.lax.broadcasted_iota(jnp.int32, (1, 1, s_chunk), 2)
        valid = band(kpos)  # causal within the chunk (first key always in)
        q = q_ref[0, :, 0]      # (S, G, hd)
        k = kc_ref[0, :, 0]     # (S, hd)
        v = vc_ref[0, :, 0]
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
        s = jnp.where(valid, s, NEG_INF)
        _flash_update(s, v, m_ref, l_ref, acc_ref)

    @pl.when(j == nk)
    def _fin():
        _finalize(o_ref, acc_ref, l_ref, (0, slice(None), 0))


def _scalar_rows(x, B):
    return jnp.broadcast_to(jnp.asarray(x, jnp.int32).reshape(-1), (B,))


# ====================================================== paged-pool kernels
# The paged pool stores KV as a shared page arena (n_pages, page, KV, hd)
# plus per-row block tables (B, nblk).  The page INDIRECTION lives entirely
# in the BlockSpec index_map — logical cache block ``j`` of row ``b``
# fetches physical page ``bt[b, j]`` via scalar prefetch — so the kernel
# bodies delegate verbatim to the dense pool-layout bodies above: position
# arithmetic is over LOGICAL blocks and is unchanged.  Sentinel table
# entries (never-allocated blocks, value n_pages) are clamped to the last
# page; the fetched garbage is dropped by the same kv_len/ring/band masks
# that hide the dense pool's unwritten tail.

def _paged_slot_kernel(len_ref, bt_ref, q_ref, k_ref, v_ref, o_ref, m_ref,
                       l_ref, acc_ref, *, bk, scale):
    del bt_ref  # consumed by the index_map only
    _slot_kernel(len_ref, q_ref, k_ref, v_ref, o_ref, m_ref, l_ref,
                 acc_ref, bk=bk, scale=scale)


def _paged_ring_kernel(pos_ref, bt_ref, q_ref, k_ref, v_ref, o_ref, m_ref,
                       l_ref, acc_ref, *, bk, ring, window, scale):
    del bt_ref
    _ring_kernel(pos_ref, q_ref, k_ref, v_ref, o_ref, m_ref, l_ref,
                 acc_ref, bk=bk, ring=ring, window=window, scale=scale)


def _paged_chunk_kernel(off_ref, bt_ref, q_ref, ck_ref, cv_ref, kc_ref,
                        vc_ref, o_ref, m_ref, l_ref, acc_ref, **kw):
    del bt_ref
    _chunk_kernel(off_ref, q_ref, ck_ref, cv_ref, kc_ref, vc_ref, o_ref,
                  m_ref, l_ref, acc_ref, **kw)


def _page_index_map(n_pages, nblk):
    """Cache-operand index_map: logical block j -> physical page bt[b, j]
    (clamped sentinel), block offset 0 on the page axis."""
    def index_map(b, h, j, scal_ref, bt_ref):
        del scal_ref
        jj = jnp.minimum(j, nblk - 1)  # chunk grid overruns clamp (no-op
        return (jnp.minimum(bt_ref[b, jj], n_pages - 1), 0, h, 0)  # else)
    return index_map


@functools.partial(jax.jit, static_argnames=("interpret",))
def paged_slot_decode_attention(q, k, v, bt, kv_len, *, interpret=False):
    """``slot_decode_attention`` over a page arena.

    q: (B, H, hd); k, v: (n_pages, page, KV, hd) shared arenas; bt:
    (B, nblk) int32 block tables (page ids; n_pages = OOB sentinel);
    kv_len: (B,) valid lengths.  The block size is pinned to the page —
    pages are only contiguous within themselves.  Returns (B, H, hd).
    """
    B, H, hd = q.shape
    n_pages, page, KV = k.shape[0], k.shape[1], k.shape[2]
    nblk = bt.shape[1]
    g = H // KV
    qg = q.reshape(B, KV, g, hd)
    kv_len = _scalar_rows(kv_len, B)
    pmap = _page_index_map(n_pages, nblk)

    out = pl.pallas_call(
        functools.partial(_paged_slot_kernel, bk=page, scale=hd ** -0.5),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=(B, KV, nblk),
            in_specs=[
                pl.BlockSpec((1, 1, g, hd), lambda b, h, j, *_: (b, h, 0, 0)),
                pl.BlockSpec((1, page, 1, hd), pmap),
                pl.BlockSpec((1, page, 1, hd), pmap),
            ],
            out_specs=pl.BlockSpec((1, 1, g, hd),
                                   lambda b, h, j, *_: (b, h, 0, 0)),
            scratch_shapes=[
                pltpu.VMEM((g, 1), jnp.float32),
                pltpu.VMEM((g, 1), jnp.float32),
                pltpu.VMEM((g, hd), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((B, KV, g, hd), q.dtype),
        interpret=interpret,
    )(kv_len, bt.astype(jnp.int32), qg, k, v)
    return out.reshape(B, H, hd)


@functools.partial(jax.jit, static_argnames=("window", "interpret"))
def paged_ring_decode_attention(q, k, v, bt, slot_positions, *, window,
                                interpret=False):
    """``ring_decode_attention`` over a page arena.

    The ring modulus is the LOGICAL length ``nblk * page``; ring slot
    ``s`` of row ``b`` lives at ``arena[bt[b, s // page], s % page]``.
    slot_positions: (B,) query positions, -1 for done rows.
    """
    B, H, hd = q.shape
    n_pages, page, KV = k.shape[0], k.shape[1], k.shape[2]
    nblk = bt.shape[1]
    ring = nblk * page
    g = H // KV
    qg = q.reshape(B, KV, g, hd)
    slot_positions = _scalar_rows(slot_positions, B)
    pmap = _page_index_map(n_pages, nblk)

    out = pl.pallas_call(
        functools.partial(_paged_ring_kernel, bk=page, ring=ring,
                          window=window, scale=hd ** -0.5),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=(B, KV, nblk),
            in_specs=[
                pl.BlockSpec((1, 1, g, hd), lambda b, h, j, *_: (b, h, 0, 0)),
                pl.BlockSpec((1, page, 1, hd), pmap),
                pl.BlockSpec((1, page, 1, hd), pmap),
            ],
            out_specs=pl.BlockSpec((1, 1, g, hd),
                                   lambda b, h, j, *_: (b, h, 0, 0)),
            scratch_shapes=[
                pltpu.VMEM((g, 1), jnp.float32),
                pltpu.VMEM((g, 1), jnp.float32),
                pltpu.VMEM((g, hd), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((B, KV, g, hd), q.dtype),
        interpret=interpret,
    )(slot_positions, bt.astype(jnp.int32), qg, k, v)
    return out.reshape(B, H, hd)


@functools.partial(jax.jit,
                   static_argnames=("ring", "window", "interpret"))
def paged_chunk_verify_attention(q, ck, cv, bt, k, v, offsets, *, ring,
                                 window=None, interpret=False):
    """``chunk_verify_attention`` over a page arena (cache read-only).

    ck, cv: (n_pages, page, KV, hd) arenas; bt: (B, nblk); the logical
    cache length is ``nblk * page``.  Grid axis 2 runs the nblk cache
    blocks then one chunk step — the cache index_map clamps the chunk
    step's overrun to the last logical block before resolving the page.
    """
    B, S, H, hd = q.shape
    n_pages, page, KV = ck.shape[0], ck.shape[1], ck.shape[2]
    nblk = bt.shape[1]
    g = H // KV
    qg = q.reshape(B, S, KV, g, hd)
    offsets = _scalar_rows(offsets, B)

    def cmap(b, h, j, scal_ref, bt_ref):
        del scal_ref
        jj = jnp.minimum(j, nblk - 1)
        return (jnp.minimum(bt_ref[b, jj], n_pages - 1), 0, h, 0)

    out = pl.pallas_call(
        functools.partial(_paged_chunk_kernel, bk=page, nk=nblk, s_chunk=S,
                          cache_len=nblk * page, ring=ring, window=window,
                          scale=hd ** -0.5),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=(B, KV, nblk + 1),
            in_specs=[
                pl.BlockSpec((1, S, 1, g, hd),
                             lambda b, h, j, *_: (b, 0, h, 0, 0)),
                pl.BlockSpec((1, page, 1, hd), cmap),
                pl.BlockSpec((1, page, 1, hd), cmap),
                pl.BlockSpec((1, S, 1, hd), lambda b, h, j, *_: (b, 0, h, 0)),
                pl.BlockSpec((1, S, 1, hd), lambda b, h, j, *_: (b, 0, h, 0)),
            ],
            out_specs=pl.BlockSpec((1, S, 1, g, hd),
                                   lambda b, h, j, *_: (b, 0, h, 0, 0)),
            scratch_shapes=[
                pltpu.VMEM((S, g, 1), jnp.float32),
                pltpu.VMEM((S, g, 1), jnp.float32),
                pltpu.VMEM((S, g, hd), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((B, S, KV, g, hd), q.dtype),
        interpret=interpret,
    )(offsets, bt.astype(jnp.int32), qg, ck, cv, k, v)
    return out.reshape(B, S, H, hd)


@functools.partial(jax.jit, static_argnames=("bk", "interpret"))
def slot_decode_attention(q, k, v, kv_len, *, bk=None, interpret=False):
    """Full-KV slot decode in POOL layout.

    q: (B, H, hd); k, v: (B, S, KV, hd) — the serve pool's native layout;
    kv_len: (B,) per-row valid lengths (0 = idle/done row -> exact zeros).
    Returns (B, H, hd).
    """
    B, H, hd = q.shape
    S, KV = k.shape[1], k.shape[2]
    g = H // KV
    if bk is None:
        bk = _pick_bk(S)
    assert S % bk == 0, (S, bk)
    qg = q.reshape(B, KV, g, hd)
    kv_len = _scalar_rows(kv_len, B)

    out = pl.pallas_call(
        functools.partial(_slot_kernel, bk=bk, scale=hd ** -0.5),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(B, KV, S // bk),
            in_specs=[
                pl.BlockSpec((1, 1, g, hd), lambda b, h, j, *_: (b, h, 0, 0)),
                pl.BlockSpec((1, bk, 1, hd),
                             lambda b, h, j, *_: (b, j, h, 0)),
                pl.BlockSpec((1, bk, 1, hd),
                             lambda b, h, j, *_: (b, j, h, 0)),
            ],
            out_specs=pl.BlockSpec((1, 1, g, hd),
                                   lambda b, h, j, *_: (b, h, 0, 0)),
            scratch_shapes=[
                pltpu.VMEM((g, 1), jnp.float32),
                pltpu.VMEM((g, 1), jnp.float32),
                pltpu.VMEM((g, hd), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((B, KV, g, hd), q.dtype),
        interpret=interpret,
    )(kv_len, qg, k, v)
    return out.reshape(B, H, hd)


@functools.partial(jax.jit,
                   static_argnames=("window", "bk", "interpret"))
def ring_decode_attention(q, k, v, slot_positions, *, window, bk=None,
                          interpret=False):
    """Ring-buffer window slot decode in POOL layout.

    q: (B, H, hd); k, v: (B, ring, KV, hd) ring caches that already hold
    this step's K/V at ``slot_positions % ring``; slot_positions: (B,)
    per-row query positions (== row length - 1 after the write), -1 for
    done/idle rows (exact-zero output).  ``window`` is the attention band;
    the ring modulus is the cache length itself (>= window once padded).
    Returns (B, H, hd).
    """
    B, H, hd = q.shape
    ring, KV = k.shape[1], k.shape[2]
    g = H // KV
    if bk is None:
        bk = _pick_bk(ring)
    assert ring % bk == 0, (ring, bk)
    qg = q.reshape(B, KV, g, hd)
    slot_positions = _scalar_rows(slot_positions, B)

    out = pl.pallas_call(
        functools.partial(_ring_kernel, bk=bk, ring=ring, window=window,
                          scale=hd ** -0.5),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(B, KV, ring // bk),
            in_specs=[
                pl.BlockSpec((1, 1, g, hd), lambda b, h, j, *_: (b, h, 0, 0)),
                pl.BlockSpec((1, bk, 1, hd),
                             lambda b, h, j, *_: (b, j, h, 0)),
                pl.BlockSpec((1, bk, 1, hd),
                             lambda b, h, j, *_: (b, j, h, 0)),
            ],
            out_specs=pl.BlockSpec((1, 1, g, hd),
                                   lambda b, h, j, *_: (b, h, 0, 0)),
            scratch_shapes=[
                pltpu.VMEM((g, 1), jnp.float32),
                pltpu.VMEM((g, 1), jnp.float32),
                pltpu.VMEM((g, hd), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((B, KV, g, hd), q.dtype),
        interpret=interpret,
    )(slot_positions, qg, k, v)
    return out.reshape(B, H, hd)


@functools.partial(jax.jit,
                   static_argnames=("ring", "window", "bk", "interpret"))
def chunk_verify_attention(q, ck, cv, k, v, offsets, *, ring, window=None,
                           bk=None, interpret=False):
    """Speculative chunk-verify attention in POOL layout.

    q: (B, S, H, hd) — the D+1-token verify chunk's queries; ck, cv:
    (B, Sc, KV, hd) read-only cache (full prefix or ring buffer — pick
    with the static ``ring`` flag); k, v: (B, S, KV, hd) the chunk's own
    K/V; offsets: (B,) per-row committed lengths (-1 = done row -> exact
    zeros).  ``window`` adds the sliding-window band.  Returns
    (B, S, H, hd); the cache operands are never written.
    """
    B, S, H, hd = q.shape
    Sc, KV = ck.shape[1], ck.shape[2]
    g = H // KV
    if bk is None:
        bk = _pick_bk(Sc)
    assert Sc % bk == 0, (Sc, bk)
    nk = Sc // bk
    qg = q.reshape(B, S, KV, g, hd)
    offsets = _scalar_rows(offsets, B)

    out = pl.pallas_call(
        functools.partial(_chunk_kernel, bk=bk, nk=nk, s_chunk=S,
                          cache_len=Sc, ring=ring, window=window,
                          scale=hd ** -0.5),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(B, KV, nk + 1),
            in_specs=[
                pl.BlockSpec((1, S, 1, g, hd),
                             lambda b, h, j, *_: (b, 0, h, 0, 0)),
                pl.BlockSpec((1, bk, 1, hd),
                             lambda b, h, j, *_: (b, jnp.minimum(j, nk - 1),
                                                  h, 0)),
                pl.BlockSpec((1, bk, 1, hd),
                             lambda b, h, j, *_: (b, jnp.minimum(j, nk - 1),
                                                  h, 0)),
                pl.BlockSpec((1, S, 1, hd), lambda b, h, j, *_: (b, 0, h, 0)),
                pl.BlockSpec((1, S, 1, hd), lambda b, h, j, *_: (b, 0, h, 0)),
            ],
            out_specs=pl.BlockSpec((1, S, 1, g, hd),
                                   lambda b, h, j, *_: (b, 0, h, 0, 0)),
            scratch_shapes=[
                pltpu.VMEM((S, g, 1), jnp.float32),
                pltpu.VMEM((S, g, 1), jnp.float32),
                pltpu.VMEM((S, g, hd), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((B, S, KV, g, hd), q.dtype),
        interpret=interpret,
    )(offsets, qg, ck, cv, k, v)
    return out.reshape(B, S, H, hd)
