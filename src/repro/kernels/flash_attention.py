"""Pallas TPU kernel: causal flash attention (prefill), GQA-aware.

Online-softmax schedule (FlashAttention-2): grid (B, H, Sq/BQ, Sk/BK) with
the KV axis innermost; running (m, l, acc) persist in VMEM scratch across KV
iterations for a fixed query block, so logits never exist in HBM.  Causal
blocks beyond the diagonal are skipped with ``pl.when`` (the dry-run's jnp
chunked path pays the 2x masked-compute tax; this kernel does not — that
delta is part of the §Perf story).

GQA: the kv-head index of q-head h is h // (H // KV), mapped in the
BlockSpec index map — repeated KV heads are never materialized.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            bq, bk, scale, causal):
    qi = pl.program_id(2)
    ki = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    run = True
    if causal:
        run = ki * bk <= qi * bq + bq - 1  # any kv pos <= any q pos

    @pl.when(run if causal else True)
    def _body():
        q = q_ref[0, 0]  # (BQ, hd)
        k = k_ref[0, 0]  # (BK, hd)
        v = v_ref[0, 0]
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
        if causal:
            qpos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
            kpos = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
            s = jnp.where(kpos <= qpos, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, -1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jnp.dot(
            p.astype(v.dtype), v, preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(ki == nk - 1)
    def _fin():
        o_ref[0, 0] = (acc_ref[...] /
                       jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("causal", "bq", "bk", "interpret"))
def flash_attention(q, k, v, *, causal=True, bq=128, bk=128,
                    interpret=False):
    """q: (B, H, S, hd); k, v: (B, KV, S, hd) -> (B, H, S, hd)."""
    B, H, S, hd = q.shape
    KV = k.shape[1]
    g = H // KV
    assert S % bq == 0 and S % bk == 0, (S, bq, bk)
    scale = hd ** -0.5

    grid = (B, H, S // bq, S // bk)
    kern = functools.partial(_kernel, bq=bq, bk=bk, scale=scale,
                             causal=causal)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, hd), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, bk, hd),
                         lambda b, h, i, j: (b, h // g, j, 0)),
            pl.BlockSpec((1, 1, bk, hd),
                         lambda b, h, i, j: (b, h // g, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, hd),
                               lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),   # running max
            pltpu.VMEM((bq, 1), jnp.float32),   # running denom
            pltpu.VMEM((bq, hd), jnp.float32),  # output acc
        ],
        interpret=interpret,
    )(q, k, v)
