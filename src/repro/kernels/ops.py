"""Public jit'd entry points for the Pallas kernels.

TPU is the TARGET; this container is CPU-only, so ``interpret=True`` (the
Pallas CPU interpreter) validates kernel-body semantics and the jnp refs in
``ref.py`` serve as oracles.  On a real TPU deployment these wrappers run
compiled (interpret=False) — callers select via ``mode``:

  mode="auto"      — compiled on TPU backends, interpret elsewhere
  mode="interpret" — force the interpreter (tests)
  mode="reference" — the jnp oracle (lowering/dry-run path)
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.decode_attention import (
    chunk_verify_attention as _chunk_verify,
    decode_attention as _decode,
    paged_chunk_verify_attention as _paged_chunk_verify,
    paged_ring_decode_attention as _paged_ring_decode,
    paged_slot_decode_attention as _paged_slot_decode,
    ring_decode_attention as _ring_decode,
    slot_decode_attention as _slot_decode,
)
from repro.kernels.flash_attention import flash_attention as _flash
from repro.kernels.rglru_scan import rglru_scan as _rglru
from repro.kernels.tr_sandwich import tr_sandwich as _sandwich


def _interp(mode: str) -> bool:
    if mode == "interpret":
        return True
    if mode == "auto":
        return jax.default_backend() != "tpu"
    raise ValueError(mode)


def tr_sandwich(x, a_i, a_o, *, mode="auto", **kw):
    if mode == "reference":
        return ref.tr_sandwich_ref(x, a_i, a_o)
    return _sandwich(x, a_i, a_o, interpret=_interp(mode), **kw)


def flash_attention(q, k, v, *, causal=True, mode="auto", **kw):
    if mode == "reference":
        return ref.flash_attention_ref(q, k, v, causal=causal)
    return _flash(q, k, v, causal=causal, interpret=_interp(mode), **kw)


def decode_attention(q, k, v, kv_len, *, mode="auto", done=None, **kw):
    if done is not None:
        # the macro-step done vector is sugar for kv_len = 0 — apply it
        # here so the reference oracle and the kernel agree on done rows
        kv_len = jnp.where(done, 0, jnp.broadcast_to(
            jnp.asarray(kv_len, jnp.int32).reshape(-1), (q.shape[0],)))
    if mode == "reference":
        return ref.decode_attention_ref(q, k, v, kv_len)
    return _decode(q, k, v, kv_len, interpret=_interp(mode), **kw)


def slot_decode_attention(q, k, v, kv_len, *, mode="auto", done=None, **kw):
    """Full-KV slot decode over the serve pool layout (B, S, KV, hd).
    ``done`` rows are folded into ``kv_len = 0`` (exact-zero output)."""
    kv_len = jnp.broadcast_to(
        jnp.asarray(kv_len, jnp.int32).reshape(-1), (q.shape[0],))
    if done is not None:
        kv_len = jnp.where(done, 0, kv_len)
    if mode == "reference":
        return ref.slot_decode_attention_ref(q, k, v, kv_len)
    return _slot_decode(q, k, v, kv_len, interpret=_interp(mode), **kw)


def ring_decode_attention(q, k, v, slot_positions, *, window, mode="auto",
                          done=None, **kw):
    """Ring-buffer window slot decode over the pool layout.  ``done``
    rows are folded into ``slot_positions = -1`` (exact-zero output)."""
    slot_positions = jnp.broadcast_to(
        jnp.asarray(slot_positions, jnp.int32).reshape(-1), (q.shape[0],))
    if done is not None:
        slot_positions = jnp.where(done, -1, slot_positions)
    if mode == "reference":
        return ref.ring_decode_attention_ref(q, k, v, slot_positions,
                                             window=window)
    return _ring_decode(q, k, v, slot_positions, window=window,
                        interpret=_interp(mode), **kw)


def chunk_verify_attention(q, ck, cv, k, v, offsets, *, ring, window=None,
                           mode="auto", done=None, **kw):
    """Speculative chunk-verify attention (read-only cache) over the pool
    layout.  ``done`` rows are folded into ``offsets = -1``."""
    offsets = jnp.broadcast_to(
        jnp.asarray(offsets, jnp.int32).reshape(-1), (q.shape[0],))
    if done is not None:
        offsets = jnp.where(done, -1, offsets)
    if mode == "reference":
        return ref.chunk_verify_attention_ref(q, ck, cv, k, v, offsets,
                                              ring=ring, window=window)
    return _chunk_verify(q, ck, cv, k, v, offsets, ring=ring, window=window,
                         interpret=_interp(mode), **kw)


def paged_slot_decode_attention(q, k, v, bt, kv_len, *, mode="auto",
                                done=None, **kw):
    """Full-KV slot decode over a PAGED pool: (n_pages, page, KV, hd)
    arenas + (B, nblk) block tables.  ``done`` rows fold into
    ``kv_len = 0`` exactly as in the dense entry."""
    kv_len = jnp.broadcast_to(
        jnp.asarray(kv_len, jnp.int32).reshape(-1), (q.shape[0],))
    if done is not None:
        kv_len = jnp.where(done, 0, kv_len)
    if mode == "reference":
        return ref.paged_slot_decode_attention_ref(q, k, v, bt, kv_len)
    return _paged_slot_decode(q, k, v, bt, kv_len, interpret=_interp(mode),
                              **kw)


def paged_ring_decode_attention(q, k, v, bt, slot_positions, *, window,
                                mode="auto", done=None, **kw):
    """Ring-buffer window slot decode over a PAGED pool.  ``done`` rows
    fold into ``slot_positions = -1``."""
    slot_positions = jnp.broadcast_to(
        jnp.asarray(slot_positions, jnp.int32).reshape(-1), (q.shape[0],))
    if done is not None:
        slot_positions = jnp.where(done, -1, slot_positions)
    if mode == "reference":
        return ref.paged_ring_decode_attention_ref(q, k, v, bt,
                                                   slot_positions,
                                                   window=window)
    return _paged_ring_decode(q, k, v, bt, slot_positions, window=window,
                              interpret=_interp(mode), **kw)


def paged_chunk_verify_attention(q, ck, cv, bt, k, v, offsets, *, ring,
                                 window=None, mode="auto", done=None, **kw):
    """Speculative chunk-verify over a PAGED pool (cache read-only).
    ``done`` rows fold into ``offsets = -1``."""
    offsets = jnp.broadcast_to(
        jnp.asarray(offsets, jnp.int32).reshape(-1), (q.shape[0],))
    if done is not None:
        offsets = jnp.where(done, -1, offsets)
    if mode == "reference":
        return ref.paged_chunk_verify_attention_ref(
            q, ck, cv, bt, k, v, offsets, ring=ring, window=window)
    return _paged_chunk_verify(q, ck, cv, bt, k, v, offsets, ring=ring,
                               window=window, interpret=_interp(mode), **kw)


def paged_latent_gather(arena, bt, *, mode="auto"):
    """Dense (B, S, r) view of a paged MLA latent arena.

    Not a Pallas kernel: the absorbed-MLA decode consumes the latent
    cache as ordinary matmul operands, so the paged layout only needs a
    layout gather (XLA fuses it into the consuming einsum).  The entry
    lives here so paged MLA dispatches through the same mode switch as
    every other paged cache group and the oracle suite covers it."""
    if mode == "reference":
        return ref.paged_latent_gather_ref(arena, bt)
    _interp(mode)  # validate the mode string
    n_pages = arena.shape[0]
    g = arena[jnp.minimum(jnp.asarray(bt, jnp.int32), n_pages - 1)]
    return g.reshape((bt.shape[0], -1) + arena.shape[2:])


def rglru_scan(a, b, h0=None, *, mode="auto", **kw):
    if mode == "reference":
        return ref.rglru_scan_ref(a, b, h0)
    return _rglru(a, b, h0, interpret=_interp(mode), **kw)
