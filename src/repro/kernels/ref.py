"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def tr_sandwich_ref(x, a_i, a_o):
    """Mango fused I/O mode product: Y[n] = A_I^T @ X[n] @ A_O.

    x: (N, D1i, D1o); a_i: (D1i, D2i); a_o: (D1o, D2o) -> (N, D2i, D2o).
    """
    return jnp.einsum("nio,ij,ok->njk", x.astype(jnp.float32),
                      a_i.astype(jnp.float32),
                      a_o.astype(jnp.float32)).astype(x.dtype)


def flash_attention_ref(q, k, v, *, causal=True):
    """q: (B, H, S, hd); k, v: (B, KV, S, hd) -> (B, H, S, hd)."""
    B, H, S, hd = q.shape
    KV = k.shape[1]
    G = H // KV
    qg = q.reshape(B, KV, G, S, hd).astype(jnp.float32)
    logits = jnp.einsum("bkgqh,bksh->bkgqs", qg,
                        k.astype(jnp.float32)) * hd ** -0.5
    if causal:
        mask = jnp.tril(jnp.ones((S, S), bool))
        logits = jnp.where(mask, logits, -1e30)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bkgqs,bksh->bkgqh", p, v.astype(jnp.float32))
    return out.reshape(B, H, S, hd).astype(q.dtype)


def decode_attention_ref(q, k, v, kv_len):
    """q: (B, H, hd); k, v: (B, KV, S, hd); kv_len: int or (B,) per-row
    valid lengths -> (B, H, hd).  Rows with kv_len == 0 (idle slots)
    return zeros, matching the kernel's empty-accumulator convention."""
    B, H, hd = q.shape
    KV = k.shape[1]
    G = H // KV
    qg = q.reshape(B, KV, G, hd).astype(jnp.float32)
    logits = jnp.einsum("bkgh,bksh->bkgs", qg,
                        k.astype(jnp.float32)) * hd ** -0.5
    kvl = jnp.broadcast_to(jnp.asarray(kv_len).reshape(-1), (B,))
    mask = jnp.arange(k.shape[2])[None] < kvl[:, None]
    logits = jnp.where(mask[:, None, None], logits, -1e30)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bkgs,bksh->bkgh", p, v.astype(jnp.float32))
    out = out * (kvl > 0).astype(out.dtype)[:, None, None, None]
    return out.reshape(B, H, hd).astype(q.dtype)


def _ring_kpos(cur_len, ring):
    """Absolute position held by each ring slot at per-row lengths.

    cur_len: (B,) -> (B, ring) int32, -1 where never written.  (An
    independent re-derivation of the ring invariant — deliberately NOT
    imported from ``models.attention`` so the oracle can catch bugs in
    either implementation.)
    """
    slot = jnp.arange(ring, dtype=jnp.int32)[None]
    cur = cur_len[:, None]
    base = ((cur - 1) // ring) * ring + slot
    pos = jnp.where(base < cur, base, base - ring)
    return jnp.where(pos >= 0, pos, -1)


def slot_decode_attention_ref(q, k, v, kv_len):
    """Pool-layout twin of ``decode_attention_ref``: k, v are
    (B, S, KV, hd) — the serve pool's native layout."""
    return decode_attention_ref(q, k.transpose(0, 2, 1, 3),
                                v.transpose(0, 2, 1, 3), kv_len)


def ring_decode_attention_ref(q, k, v, slot_positions, *, window):
    """q: (B, H, hd); k, v: (B, ring, KV, hd) pool-layout ring caches;
    slot_positions: (B,) per-row query positions (-1: done -> zeros).
    Masks by absolute position reconstructed from the ring invariant,
    banded to ``(qpos - window, qpos]``."""
    B, H, hd = q.shape
    ring, KV = k.shape[1], k.shape[2]
    G = H // KV
    qg = q.reshape(B, KV, G, hd).astype(jnp.float32)
    kt = k.transpose(0, 2, 1, 3).astype(jnp.float32)
    vt = v.transpose(0, 2, 1, 3).astype(jnp.float32)
    pos = jnp.asarray(slot_positions, jnp.int32).reshape(-1)
    kpos = _ring_kpos(pos + 1, ring)  # (B, ring)
    qpos = pos[:, None]
    mask = (kpos >= 0) & (kpos > qpos - window) & (qpos >= 0)
    logits = jnp.einsum("bkgh,bksh->bkgs", qg, kt) * hd ** -0.5
    logits = jnp.where(mask[:, None, None], logits, -1e30)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bkgs,bksh->bkgh", p, vt)
    out = out * (pos >= 0).astype(out.dtype)[:, None, None, None]
    return out.reshape(B, H, hd).astype(q.dtype)


def chunk_verify_attention_ref(q, ck, cv, k, v, offsets, *, ring,
                               window=None):
    """q: (B, S, H, hd); ck, cv: (B, Sc, KV, hd) read-only cache; k, v:
    (B, S, KV, hd) the chunk's own K/V; offsets: (B,) committed lengths
    (-1: done -> zeros).  Attends [cache ‖ chunk] by absolute position."""
    B, S, H, hd = q.shape
    Sc, KV = ck.shape[1], ck.shape[2]
    G = H // KV
    off = jnp.asarray(offsets, jnp.int32).reshape(-1)
    if ring:
        kpos_cache = _ring_kpos(off, Sc)
    else:
        pos = jnp.broadcast_to(jnp.arange(Sc, dtype=jnp.int32)[None],
                               (B, Sc))
        kpos_cache = jnp.where(pos < off[:, None], pos, -1)
    kpos_chunk = off[:, None] + jnp.arange(S, dtype=jnp.int32)[None]
    kpos = jnp.concatenate([kpos_cache, kpos_chunk], 1)  # (B, Sc + S)
    qpos = off[:, None] + jnp.arange(S, dtype=jnp.int32)[None]  # (B, S)
    mask = (kpos[:, None] >= 0) & (kpos[:, None] <= qpos[:, :, None]) \
        & (off >= 0)[:, None, None]
    if window is not None:
        mask &= kpos[:, None] > qpos[:, :, None] - window
    k_all = jnp.concatenate([ck.astype(jnp.float32), k.astype(jnp.float32)],
                            1).transpose(0, 2, 1, 3)  # (B, KV, Sc+S, hd)
    v_all = jnp.concatenate([cv.astype(jnp.float32), v.astype(jnp.float32)],
                            1).transpose(0, 2, 1, 3)
    qg = q.reshape(B, S, KV, G, hd).astype(jnp.float32)
    logits = jnp.einsum("bqkgh,bksh->bkgqs", qg, k_all) * hd ** -0.5
    logits = jnp.where(mask[:, None, None], logits, -1e30)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bkgqs,bksh->bqkgh", p, v_all)
    out = out * (off >= 0).astype(out.dtype)[:, None, None, None, None]
    return out.reshape(B, S, H, hd).astype(q.dtype)


def _paged_gather_ref(arena, bt):
    """(n_pages, page, ...) arena + (B, nblk) block table -> the dense
    pool layout (B, nblk * page, ...).  Sentinel entries clamp to the
    last page; the garbage bytes sit at positions every paged oracle
    masks away (an independent twin of ``models.attention.paged_gather``
    — deliberately re-derived, same as ``_ring_kpos``)."""
    n_pages = arena.shape[0]
    g = arena[jnp.minimum(jnp.asarray(bt, jnp.int32), n_pages - 1)]
    return g.reshape((bt.shape[0], -1) + arena.shape[2:])


def paged_latent_gather_ref(arena, bt):
    """Dense view of a paged MLA latent arena: (n_pages, page, r) +
    (B, nblk) -> (B, nblk * page, r).  The absorbed-MLA decode consumes
    the latent cache as plain matmul operands, so paging it needs only
    this gather (garbage behind the sentinel clamp is masked by kv_len
    downstream), not a bespoke attention kernel."""
    return _paged_gather_ref(arena, bt)


def paged_slot_decode_attention_ref(q, k, v, bt, kv_len):
    """Paged oracle: materialize the dense view, defer to the dense ref."""
    return slot_decode_attention_ref(
        q, _paged_gather_ref(k, bt), _paged_gather_ref(v, bt), kv_len)


def paged_ring_decode_attention_ref(q, k, v, bt, slot_positions, *, window):
    return ring_decode_attention_ref(
        q, _paged_gather_ref(k, bt), _paged_gather_ref(v, bt),
        slot_positions, window=window)


def paged_chunk_verify_attention_ref(q, ck, cv, bt, k, v, offsets, *, ring,
                                     window=None):
    return chunk_verify_attention_ref(
        q, _paged_gather_ref(ck, bt), _paged_gather_ref(cv, bt), k, v,
        offsets, ring=ring, window=window)


def rglru_scan_ref(a, b, h0=None):
    """Linear recurrence h_t = a_t * h_{t-1} + b_t.

    a, b: (B, S, W) f32; h0: (B, W) or None -> h: (B, S, W).
    """
    if h0 is None:
        h0 = jnp.zeros(a[:, 0].shape, jnp.float32)

    def step(h, ab):
        at, bt = ab
        h = at * h + bt
        return h, h

    _, hs = jax.lax.scan(step, h0.astype(jnp.float32),
                         (a.transpose(1, 0, 2).astype(jnp.float32),
                          b.transpose(1, 0, 2).astype(jnp.float32)))
    return hs.transpose(1, 0, 2).astype(a.dtype)
