"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def tr_sandwich_ref(x, a_i, a_o):
    """Mango fused I/O mode product: Y[n] = A_I^T @ X[n] @ A_O.

    x: (N, D1i, D1o); a_i: (D1i, D2i); a_o: (D1o, D2o) -> (N, D2i, D2o).
    """
    return jnp.einsum("nio,ij,ok->njk", x.astype(jnp.float32),
                      a_i.astype(jnp.float32),
                      a_o.astype(jnp.float32)).astype(x.dtype)


def flash_attention_ref(q, k, v, *, causal=True):
    """q: (B, H, S, hd); k, v: (B, KV, S, hd) -> (B, H, S, hd)."""
    B, H, S, hd = q.shape
    KV = k.shape[1]
    G = H // KV
    qg = q.reshape(B, KV, G, S, hd).astype(jnp.float32)
    logits = jnp.einsum("bkgqh,bksh->bkgqs", qg,
                        k.astype(jnp.float32)) * hd ** -0.5
    if causal:
        mask = jnp.tril(jnp.ones((S, S), bool))
        logits = jnp.where(mask, logits, -1e30)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bkgqs,bksh->bkgqh", p, v.astype(jnp.float32))
    return out.reshape(B, H, S, hd).astype(q.dtype)


def decode_attention_ref(q, k, v, kv_len):
    """q: (B, H, hd); k, v: (B, KV, S, hd); kv_len: int or (B,) per-row
    valid lengths -> (B, H, hd).  Rows with kv_len == 0 (idle slots)
    return zeros, matching the kernel's empty-accumulator convention."""
    B, H, hd = q.shape
    KV = k.shape[1]
    G = H // KV
    qg = q.reshape(B, KV, G, hd).astype(jnp.float32)
    logits = jnp.einsum("bkgh,bksh->bkgs", qg,
                        k.astype(jnp.float32)) * hd ** -0.5
    kvl = jnp.broadcast_to(jnp.asarray(kv_len).reshape(-1), (B,))
    mask = jnp.arange(k.shape[2])[None] < kvl[:, None]
    logits = jnp.where(mask[:, None, None], logits, -1e30)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bkgs,bksh->bkgh", p, v.astype(jnp.float32))
    out = out * (kvl > 0).astype(out.dtype)[:, None, None, None]
    return out.reshape(B, H, hd).astype(q.dtype)


def rglru_scan_ref(a, b, h0=None):
    """Linear recurrence h_t = a_t * h_{t-1} + b_t.

    a, b: (B, S, W) f32; h0: (B, W) or None -> h: (B, S, W).
    """
    if h0 is None:
        h0 = jnp.zeros(a[:, 0].shape, jnp.float32)

    def step(h, ab):
        at, bt = ab
        h = at * h + bt
        return h, h

    _, hs = jax.lax.scan(step, h0.astype(jnp.float32),
                         (a.transpose(1, 0, 2).astype(jnp.float32),
                          b.transpose(1, 0, 2).astype(jnp.float32)))
    return hs.transpose(1, 0, 2).astype(a.dtype)
