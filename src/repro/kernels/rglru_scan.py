"""Pallas TPU kernel: blocked linear-recurrence scan (RG-LRU core).

h_t = a_t * h_{t-1} + b_t over the sequence.  The recurrence is sequential
in time but embarrassingly parallel over (batch, width): grid
(B, W/BW, S/BS); each grid step advances one (batch, width-block) lane by
BS timesteps with an unrolled in-VMEM loop, carrying h in scratch across the
sequence-block axis (innermost).  This is the TPU shape of RecurrentGemma's
custom scan: HBM traffic is exactly one read of (a, b) and one write of h —
the op is bandwidth-bound, and the kernel hits that bound by never
spilling the carry.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(a_ref, b_ref, h0_ref, o_ref, h_ref, *, bs):
    si = pl.program_id(2)

    @pl.when(si == 0)
    def _init():
        h_ref[...] = h0_ref[0].astype(jnp.float32)

    a = a_ref[0].astype(jnp.float32)  # (BS, BW)
    b = b_ref[0].astype(jnp.float32)
    h = h_ref[...]                    # (1, BW) carried across seq blocks
    rows = []
    for t in range(bs):               # unrolled: VPU-resident recurrence
        h = a[t:t + 1] * h + b[t:t + 1]
        rows.append(h)
    o_ref[0] = jnp.concatenate(rows, axis=0).astype(o_ref.dtype)
    h_ref[...] = h


@functools.partial(jax.jit, static_argnames=("bs", "bw", "interpret"))
def rglru_scan(a, b, h0=None, *, bs=128, bw=256, interpret=False):
    """a, b: (B, S, W); h0: (B, W) or None -> (B, S, W)."""
    B, S, W = a.shape
    assert S % bs == 0 and W % bw == 0, (a.shape, bs, bw)
    if h0 is None:
        h0 = jnp.zeros((B, W), jnp.float32)
    h0 = h0.reshape(B, 1, W)

    grid = (B, W // bw, S // bs)
    return pl.pallas_call(
        functools.partial(_kernel, bs=bs),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bs, bw), lambda nb, w, s: (nb, s, w)),
            pl.BlockSpec((1, bs, bw), lambda nb, w, s: (nb, s, w)),
            pl.BlockSpec((1, 1, bw), lambda nb, w, s: (nb, 0, w)),
        ],
        out_specs=pl.BlockSpec((1, bs, bw), lambda nb, w, s: (nb, s, w)),
        out_shape=jax.ShapeDtypeStruct((B, S, W), a.dtype),
        scratch_shapes=[pltpu.VMEM((1, bw), jnp.float32)],
        interpret=interpret,
    )(a, b, h0)
