"""Pallas TPU kernel: fused Mango I/O mode-product ("sandwich").

Computes  Y[n] = A_I^T @ X[n] @ A_O  for a stack of weight tiles X — the two
large mode products of the TR-MPO contraction (Eq. 6) fused so the
(D2i x D1o) intermediate T = A_I^T X never round-trips to HBM.  Arithmetic
intensity roughly doubles vs running the two matmuls separately, which is
what moves this step from memory-bound to MXU-bound at growth time.

Blocking (all 128-aligned for the MXU):
  grid = (N, D2i/TI, D2o/TO, D1i/TK)   — k innermost, accumulating in the
  output block; per-iteration VMEM:
     X block     (TK, D1o)
     A_I block   (TK, TI)
     A_O         (D1o, TO)
     Y block/acc (TI, TO) f32
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, ai_ref, ao_ref, y_ref, *, nk):
    k = pl.program_id(3)

    @pl.when(k == 0)
    def _init():
        y_ref[...] = jnp.zeros_like(y_ref)

    x = x_ref[0]          # (TK, D1o)
    ai = ai_ref[...]      # (TK, TI)
    ao = ao_ref[...]      # (D1o, TO)
    t = jnp.dot(x, ao, preferred_element_type=jnp.float32)   # (TK, TO)
    y_ref[0] += jnp.dot(ai.T, t, preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("ti", "to", "tk", "interpret"))
def tr_sandwich(x, a_i, a_o, *, ti=128, to=128, tk=128, interpret=False):
    """x: (N, D1i, D1o); a_i: (D1i, D2i); a_o: (D1o, D2o) -> (N, D2i, D2o).

    Dims must be multiples of the block sizes (the Mango packing pads tiles
    to d_model which is 128-aligned for every assigned arch).
    """
    n, d1i, d1o = x.shape
    d2i, d2o = a_i.shape[1], a_o.shape[1]
    assert d1i % tk == 0 and d2i % ti == 0 and d2o % to == 0, (
        x.shape, a_i.shape, a_o.shape)

    grid = (n, d2i // ti, d2o // to, d1i // tk)
    out = pl.pallas_call(
        functools.partial(_kernel, nk=d1i // tk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, tk, d1o), lambda nb, i, o, k: (nb, k, 0)),
            pl.BlockSpec((tk, ti), lambda nb, i, o, k: (k, i)),
            pl.BlockSpec((d1o, to), lambda nb, i, o, k: (0, o)),
        ],
        out_specs=pl.BlockSpec((1, ti, to), lambda nb, i, o, k: (nb, i, o)),
        out_shape=jax.ShapeDtypeStruct((n, d2i, d2o), jnp.float32),
        interpret=interpret,
    )(x, a_i, a_o)
    return out.astype(x.dtype)
