import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

The two lines above MUST stay the first statements in this module — jax
locks the device count on first init, and the production meshes need 512
placeholder host devices.  Never set this flag globally (tests and benches
must see 1 device).

For every cell we record, into results/dryrun/<cell>.json:
  * per-device memory stats (argument/output/temp/generated code)
  * cost_analysis flops + bytes accessed (per device)
  * collective wire bytes parsed from the post-SPMD HLO
  * lowering/compile wall times

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch yi-9b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--mesh both]
"""
import argparse  # noqa: E402
import json  # noqa: E402
import re  # noqa: E402
import time  # noqa: E402
import traceback  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro.configs.base import SHAPES, get_config  # noqa: E402
from repro.configs.archs import ARCH_IDS  # noqa: E402
from repro.distributed.sharding import (  # noqa: E402
    logical_to_spec,
    params_shardings,
    sharding_rules_for_mesh,
    use_rules,
    zero_shardings,
)
from repro.launch import specs as specs_lib  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.models import get_family  # noqa: E402
from repro.optim import OptimizerConfig, make_optimizer  # noqa: E402
from repro.train.steps import (  # noqa: E402
    make_decode_step,
    make_grow_step,
    make_prefill_step,
    make_train_step,
)

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                           "results", "dryrun")

# --- cell skip rules (documented in DESIGN.md §Arch-applicability) --------
FULL_ATTENTION = {"phi3.5-moe-42b", "deepseek-v3-671b", "stablelm-3b",
                  "qwen1.5-0.5b", "qwen3-0.6b", "yi-9b", "qwen2-vl-72b"}
ENCODER_ONLY = {"hubert-xlarge"}


def cell_skip_reason(arch: str, shape: str):
    if shape in ("decode_32k", "long_500k") and arch in ENCODER_ONLY:
        return "encoder-only arch has no decode step"
    if shape == "long_500k" and arch in FULL_ATTENTION:
        return "long_500k needs sub-quadratic attention (pure full-attn arch)"
    return None


COLLECTIVE_RE = re.compile(
    r"=\s*(\(?)([a-z0-9]+)\[([0-9,]*)\][^ ]*\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(-start|-done)?\(")

DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s32": 4, "u32": 4,
               "s64": 8, "u64": 8, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
               "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1}
# wire-bytes factor per collective (ring algorithms, large-N limit)
WIRE_FACTOR = {"all-gather": 1.0, "all-reduce": 2.0, "reduce-scatter": 1.0,
               "all-to-all": 1.0, "collective-permute": 1.0}


def collective_bytes(hlo_text: str):
    """Sum wire bytes over collectives in post-SPMD HLO (per device)."""
    totals = {}
    for m in COLLECTIVE_RE.finditer(hlo_text):
        _, dt, dims, op, suffix = m.groups()
        if suffix == "-done":  # -start carries the shape; skip the done
            continue
        nbytes = DTYPE_BYTES.get(dt)
        if nbytes is None:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        totals[op] = totals.get(op, 0.0) + n * nbytes * WIRE_FACTOR[op]
    return totals


def _opt_cfg(clip=1.0):
    return OptimizerConfig(lr=1e-4, moment_dtype="bfloat16",
                           master_weights=True, clip_norm=clip)


def build_cell(arch: str, shape_name: str, mesh, fsdp=True,
               n_microbatches=None, variant="baseline"):
    """-> (fn, arg_specs, in_shardings, out_shardings, rules, donate).

    ``variant``: "baseline" (pjit-automatic step) or "lazy" (manual ZeRO-3
    lazy-sync step, train shapes only) — the §Perf comparison axis.
    """
    cfg = get_config(arch)
    fam = get_family(cfg)
    shp = SHAPES[shape_name] if shape_name in SHAPES else None
    inference = shp is not None and shp.kind in ("prefill", "decode")
    rules = sharding_rules_for_mesh(mesh, fsdp=fsdp and not inference,
                                    inference=inference)

    params_abs = specs_lib.params_specs_abstract(cfg)
    p_specs = fam.param_specs(cfg)
    p_shard = params_shardings(p_specs, mesh, rules, shapes=params_abs)
    repl = NamedSharding(mesh, P())

    def shard_of(logical_tree, abs_tree):
        return jax.tree.map(
            lambda lg, ab: NamedSharding(
                mesh, logical_to_spec(lg, ab.shape, mesh, rules)),
            logical_tree, abs_tree,
            is_leaf=lambda x: isinstance(x, tuple) and all(
                isinstance(e, (str, type(None))) for e in x))

    if shape_name.startswith("grow"):
        return _build_grow_cell(arch, mesh, rules, fsdp) + (rules, (0, 1))

    if shp.kind == "train":
        if n_microbatches is None:
            # auto: big models need microbatching to bound the per-layer
            # activation stash (block remat saves one (B,S,D) per layer)
            n_microbatches = 8 if cfg.d_model >= 4096 else 1
        if variant == "lazy":
            # distributed grad-norm clip is out of scope for the manual
            # body; compared against a matched no-clip baseline in §Perf
            from repro.train.lazy_sync import make_lazy_sync_train_step
            opt_cfg = _opt_cfg(clip=None)
            step = make_lazy_sync_train_step(
                cfg, opt_cfg, mesh, p_shard,
                n_microbatches=max(n_microbatches, 1))
            init_fn, _ = make_optimizer(opt_cfg)
            opt_abs = jax.eval_shape(init_fn, params_abs)
            # lazy body assumes state layout == param layout
            opt_shard = {"m": p_shard, "v": p_shard, "master": p_shard}
        else:
            if variant == "baseline-m8":
                n_microbatches = 8
            clip = None if variant.startswith("baseline-") else 1.0
            step = make_train_step(cfg, _opt_cfg(clip),
                                   n_microbatches=n_microbatches)
            init_fn, _ = make_optimizer(_opt_cfg(clip))
            opt_abs = jax.eval_shape(init_fn, params_abs)
            zaxes = tuple(a for a in ("pod", "data")
                          if a in mesh.axis_names)
            zshard = zero_shardings(p_shard, params_abs, mesh,
                                    zero_axes=zaxes)
            opt_shard = {"m": zshard, "v": zshard, "master": zshard}
        batch_abs = specs_lib.batch_specs(cfg, shp.global_batch, shp.seq_len)
        batch_shard = shard_of(specs_lib.batch_logical(cfg), batch_abs)
        step_abs = jax.ShapeDtypeStruct((), jnp.int32)
        args = (params_abs, opt_abs, batch_abs, step_abs)
        in_sh = (p_shard, opt_shard, batch_shard, repl)
        out_sh = (p_shard, opt_shard, None)
        return step, args, in_sh, out_sh, rules, (0, 1)

    cache_len = shp.seq_len
    cache_abs = specs_lib.cache_specs_abstract(cfg, shp.global_batch,
                                               cache_len)
    cache_shard = shard_of(specs_lib.cache_logical(cfg), cache_abs)

    if shp.kind == "prefill":
        fn = make_prefill_step(cfg)
        batch_abs = specs_lib.batch_specs(cfg, shp.global_batch, shp.seq_len)
        batch_shard = shard_of(specs_lib.batch_logical(cfg), batch_abs)
        args = (params_abs, batch_abs, cache_abs)
        in_sh = (p_shard, batch_shard, cache_shard)
        out_sh = (None, cache_shard)
        return fn, args, in_sh, out_sh, rules, (2,)

    # decode: one new token against a seq_len cache
    fn = make_decode_step(cfg)
    tok_abs = jax.ShapeDtypeStruct((shp.global_batch,), jnp.int32)
    pos_abs = jax.ShapeDtypeStruct((), jnp.int32)
    tok_shard = NamedSharding(
        mesh, logical_to_spec(("batch",), (shp.global_batch,), mesh, rules))
    args = (params_abs, tok_abs, pos_abs, cache_abs)
    in_sh = (p_shard, tok_shard, repl, cache_shard)
    out_sh = (tok_shard, cache_shard)
    return fn, args, in_sh, out_sh, rules, (3,)


def _build_grow_cell(arch, mesh, rules, fsdp):
    """Mango operator-training step at scale (the paper's technique)."""
    from repro.core import grow as growlib

    cfg_tgt = get_config(arch)
    cfg_src = get_config(f"{arch}-half")
    fam_t = get_family(cfg_tgt)
    gop, op_params0 = growlib.build("mango", cfg_src, cfg_tgt, rank=1)
    op_abs = jax.eval_shape(lambda: op_params0)
    step = make_grow_step(gop, cfg_tgt, _opt_cfg(), n_microbatches=8)
    init_fn, _ = make_optimizer(_opt_cfg())
    opt_abs = jax.eval_shape(init_fn, op_abs)

    fam_s = get_family(cfg_src)
    small_abs = specs_lib.params_specs_abstract(cfg_src)
    small_shard = params_shardings(fam_s.param_specs(cfg_src), mesh, rules,
                                   shapes=small_abs)
    repl = NamedSharding(mesh, P())
    op_shard = jax.tree.map(lambda _: repl, op_abs)
    opt_shard = jax.tree.map(lambda _: repl, opt_abs)
    shp = SHAPES["train_4k"]
    batch_abs = specs_lib.batch_specs(cfg_tgt, shp.global_batch, shp.seq_len)
    batch_shard = jax.tree.map(
        lambda ab: NamedSharding(
            mesh, logical_to_spec(("batch", "seq"), ab.shape, mesh, rules)),
        {"tokens": batch_abs["tokens"]})
    step_abs = jax.ShapeDtypeStruct((), jnp.int32)
    args = (op_abs, opt_abs, small_abs, {"tokens": batch_abs["tokens"]},
            step_abs)
    in_sh = (op_shard, opt_shard, small_shard, batch_shard, repl)
    out_sh = (op_shard, opt_shard, None)
    return step, args, in_sh, out_sh


# ---------------------------------------------------------- cost calibration
# XLA's cost_analysis() counts while-loop bodies ONCE (scan trip counts are
# not multiplied in).  All layer stacks / attention chunk loops / microbatch
# loops here are scans, so raw numbers are large under-counts.  We therefore
# lower reduced-DEPTH variants of each cell at full width/batch with inner
# chunk scans unrolled (cfg.unroll_scans) and a single microbatch, solve
#     cost(L_a, L_b) = base + a*L_a + b*L_b
# exactly, and extrapolate to the real depth.  The full-config compile is
# still what proves sharding coherence and measures memory.

def _depth_counts(cfg):
    """-> (A, B): real per-type layer counts for the two block types."""
    if cfg.family == "transformer":
        nd = cfg.n_dense_layers
        return nd, cfg.n_layers - nd
    if cfg.family == "griffin":
        from repro.models.griffin import block_pattern
        pat = block_pattern(cfg)
        nr = sum(1 for t in pat if t == "rec")
        return nr, len(pat) - nr
    if cfg.family == "xlstm":
        from repro.models.xlstm import block_types
        ts = block_types(cfg)
        nm = sum(1 for t in ts if t == "m")
        return nm, len(ts) - nm
    raise ValueError(cfg.family)


def _with_depth(cfg, a, b):
    """Same-arch config with a blocks of type A and b of type B."""
    kw = dict(unroll_scans=True)
    if cfg.family == "transformer":
        if cfg.moe:
            return cfg.replace(n_layers=a + b, moe_layer_start=a, **kw)
        return cfg.replace(n_layers=a, **kw)
    if cfg.family == "griffin":
        return cfg.replace(n_layers=a + b,
                           block_pattern=("rec",) * a + ("attn",) * b, **kw)
    if cfg.family == "xlstm":
        return cfg.replace(n_layers=a + b,
                           block_pattern=("m",) * a + ("s",) * b, **kw)
    raise ValueError(cfg.family)


def _calib_variants(cfg):
    """[(a, b)] probe depths. 3 probes when both types exist, else 2."""
    A, B = _depth_counts(cfg)
    if A and B:
        return [(1, 1), (2, 1), (1, 2)]
    if A:
        return [(1, 0), (2, 0)]
    return [(0, 1), (0, 2)]


def _slstm_flops_correction(cfg, batch, seq, train: bool):
    """sLSTM's per-timestep scan cannot be unrolled (true recurrence);
    analytic recurrence flops: R-gate matmul 2*B*S*NH*dh*4dh per layer,
    x(2 fwd+bwd)(+1 remat) for training."""
    if cfg.family != "xlstm":
        return 0.0
    from repro.models.xlstm import block_types
    n_s = sum(1 for t in block_types(cfg) if t == "s")
    if not n_s:
        return 0.0
    dh = cfg.d_model // cfg.n_heads
    per_layer = 2.0 * batch * seq * cfg.n_heads * dh * 4 * dh
    return n_s * per_layer * (4.0 if train else 1.0)


def _measure_costs(arch, cfg_variant, shape_name, mesh, fsdp,
                   variant="baseline"):
    """Lower+compile one reduced variant, return (flops, bytes, colls)."""
    import repro.configs.base as base_mod
    key = f"__calib_{arch}_{id(cfg_variant)}"
    base_mod._REGISTRY[key] = lambda: cfg_variant
    try:
        fn, args, in_sh, out_sh, rules, donate = build_cell(
            key, shape_name, mesh, fsdp=fsdp,
            n_microbatches=8 if variant in ("lazy", "baseline-m8") else 1,
            variant=variant)
        with mesh, use_rules(mesh, rules):
            compiled = jax.jit(
                fn, in_shardings=in_sh, out_shardings=out_sh,
                donate_argnums=donate).lower(*args).compile()
        cost = compiled.cost_analysis()
        colls = collective_bytes(compiled.as_text())
        return (cost.get("flops", 0.0), cost.get("bytes accessed", 0.0),
                colls)
    finally:
        del base_mod._REGISTRY[key]


def calibrate_cell(arch, shape_name, mesh, fsdp, variant="baseline"):
    """-> dict with extrapolated per-device flops/bytes/collectives."""
    cfg = get_config(arch)
    A, B = _depth_counts(cfg)
    probes = _calib_variants(cfg)
    # xLSTM prefill: chunkwise-mLSTM cost is exactly linear in S at fixed
    # chunk size (attention-free), but unrolling 32k/256 = 128 chunk bodies
    # makes probe compiles pathological on this host — probe at a reduced
    # sequence and scale linearly.
    seq_scale = 1.0
    probe_shape = shape_name
    shp = SHAPES.get(shape_name)
    if (cfg.family == "xlstm" and shp is not None
            and shp.kind == "prefill" and shp.seq_len > 4096):
        import dataclasses as _dc
        short = _dc.replace(shp, name=f"{shape_name}_calib", seq_len=2048)
        SHAPES[short.name] = short
        probe_shape = short.name
        seq_scale = shp.seq_len / short.seq_len
    meas = []
    for (a, b) in probes:
        m = _measure_costs(
            arch, _with_depth(cfg, a, b), probe_shape, mesh, fsdp,
            variant=variant)
        if seq_scale != 1.0:
            m = (m[0] * seq_scale, m[1] * seq_scale,
                 {k: v * seq_scale for k, v in m[2].items()})
        meas.append(m)
    if probe_shape != shape_name:
        del SHAPES[probe_shape]

    def solve(vals):
        if len(probes) == 3:
            c11, c21, c12 = vals
            pa, pb = c21 - c11, c12 - c11
            base = c11 - pa - pb
        else:
            c1, c2 = vals
            per = c2 - c1
            pa, pb = (per, 0.0) if probes[0][0] else (0.0, per)
            base = c1 - per
        return max(base, 0.0) + pa * A + pb * B

    flops = solve([m[0] for m in meas])
    nbytes = solve([m[1] for m in meas])
    ops = set()
    for m in meas:
        ops.update(m[2])
    colls = {op: solve([m[2].get(op, 0.0) for m in meas]) for op in ops}

    shp = SHAPES.get(shape_name)
    if shp is not None:
        flops += _slstm_flops_correction(
            cfg, shp.global_batch,
            shp.seq_len if shp.kind != "decode" else 1,
            shp.kind == "train") / mesh.devices.size
    return {"flops_per_device": flops, "bytes_accessed_per_device": nbytes,
            "collective_bytes_per_device": colls,
            "raw_probes": [[list(p), list(m[:2])]
                           for p, m in zip(probes, meas)]}


def _resolve_variant_arch(arch, variant):
    """Register a config override for non-structural variants and return
    the registry key to use."""
    if variant == "opt":
        cfg = get_config(arch).replace(moe_dispatch_dtype="bfloat16",
                                       attn_prefix_chunks=True)
    elif variant == "remat-dots":
        cfg = get_config(arch).replace(remat="dots")
    else:
        return arch
    import repro.configs.base as base_mod
    key = f"__{variant}_{arch}"
    base_mod._REGISTRY[key] = (lambda c: (lambda: c))(cfg)
    return key


def run_cell(arch: str, shape_name: str, *, multi_pod: bool, fsdp=True,
             save=True, keep_hlo=False, variant="baseline"):
    mesh_name = "multipod_2x16x16" if multi_pod else "pod_16x16"
    skip = cell_skip_reason(arch, shape_name)
    result = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
              "fsdp": fsdp, "variant": variant}
    if skip:
        result["status"] = "skipped"
        result["reason"] = skip
        _save(result, save)
        return result
    mesh = make_production_mesh(multi_pod=multi_pod)
    run_arch = _resolve_variant_arch(arch, variant)
    try:
        t0 = time.time()
        fn, args, in_sh, out_sh, rules, donate = build_cell(
            run_arch, shape_name, mesh, fsdp=fsdp, variant=variant)
        with mesh, use_rules(mesh, rules):
            jfn = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh,
                          donate_argnums=donate)
            lowered = jfn.lower(*args)
            t1 = time.time()
            compiled = lowered.compile()
            t2 = time.time()
        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis()
        text = compiled.as_text()
        colls = collective_bytes(text)
        n_dev = mesh.devices.size
        result.update({
            "status": "ok",
            "n_devices": int(n_dev),
            "lower_s": round(t1 - t0, 2),
            "compile_s": round(t2 - t1, 2),
            "memory": {
                "argument_bytes": mem.argument_size_in_bytes,
                "output_bytes": mem.output_size_in_bytes,
                "temp_bytes": mem.temp_size_in_bytes,
                "alias_bytes": mem.alias_size_in_bytes,
                "code_bytes": mem.generated_code_size_in_bytes,
            },
            "raw_loopcounted_flops_per_device": cost.get("flops", 0.0),
            "raw_loopcounted_bytes_per_device": cost.get(
                "bytes accessed", 0.0),
            "raw_loopcounted_collectives": colls,
            "hlo_chars": len(text),
        })
        if keep_hlo:
            result["hlo_path"] = _save_hlo(arch, shape_name, mesh_name, text)
        del text, compiled, lowered
        if shape_name in SHAPES:
            t3 = time.time()
            calib = calibrate_cell(run_arch, shape_name, mesh, fsdp,
                                   variant=variant)
            calib["calib_s"] = round(time.time() - t3, 2)
            result.update(calib)
        else:  # grow cells: contraction flops reported analytically
            from repro.core import grow as growlib
            from repro.core import mango as mango_lib
            gop, _ = growlib.build("mango", get_config(f"{arch}-half"),
                                   get_config(arch), rank=1)
            result["analytic_contract_flops"] = sum(
                mango_lib.contract_flops(gop.op.dims(g.name), 1)
                for g in gop.op.plan_src.groups)
    except Exception as e:  # record failures — they are bugs to fix
        result["status"] = "error"
        result["error"] = f"{type(e).__name__}: {e}"
        result["traceback"] = traceback.format_exc()[-4000:]
    _save(result, save)
    return result


def _save(result, save):
    if not save:
        return
    os.makedirs(RESULTS_DIR, exist_ok=True)
    suffix = "" if result.get("variant", "baseline") == "baseline" \
        else f"__{result['variant']}"
    name = (f"{result['arch']}__{result['shape']}__{result['mesh']}"
            f"{suffix}.json")
    with open(os.path.join(RESULTS_DIR, name), "w") as f:
        json.dump(result, f, indent=1)


def _save_hlo(arch, shape, mesh_name, text):
    os.makedirs(RESULTS_DIR, exist_ok=True)
    path = os.path.join(RESULTS_DIR, f"{arch}__{shape}__{mesh_name}.hlo")
    with open(path, "w") as f:
        f.write(text)
    return path


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="both",
                    choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--grow", action="store_true",
                    help="include the mango grow_step cells")
    ap.add_argument("--no-fsdp", action="store_true")
    ap.add_argument("--variant", default="baseline",
                    choices=["baseline", "baseline-noclip", "baseline-m8",
                             "lazy", "opt", "remat-dots"])
    ap.add_argument("--keep-hlo", action="store_true")
    args = ap.parse_args()

    cells = []
    archs = ARCH_IDS if (args.all or args.arch is None) else [args.arch]
    shapes = list(SHAPES) if (args.all or args.shape is None) \
        else [args.shape]
    for a in archs:
        for s in shapes:
            cells.append((a, s))
    if args.grow:
        cells.append(("yi-9b", "grow_4k"))
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]

    failures = 0
    for arch, shape in cells:
        for mp in meshes:
            r = run_cell(arch, shape, multi_pod=mp, fsdp=not args.no_fsdp,
                         keep_hlo=args.keep_hlo, variant=args.variant)
            tag = f"{arch} x {shape} x {r['mesh']}"
            if r["status"] == "ok":
                mem_gb = (r["memory"]["argument_bytes"]
                          + r["memory"]["temp_bytes"]) / 2**30
                print(f"[ok]   {tag}: compile {r['compile_s']}s, "
                      f"{mem_gb:.2f} GiB/dev, "
                      f"{r['flops_per_device']:.3e} flops/dev", flush=True)
            elif r["status"] == "skipped":
                print(f"[skip] {tag}: {r['reason']}", flush=True)
            else:
                failures += 1
                print(f"[FAIL] {tag}: {r['error']}", flush=True)
    raise SystemExit(1 if failures else 0)


if __name__ == "__main__":
    main()
