"""Production meshes.

Defined as functions (never module-level constants) so importing this module
never touches jax device state — smoke tests must keep seeing 1 CPU device;
only ``dryrun.py`` forces 512 host devices.
"""
from __future__ import annotations

import jax

from repro.utils.compat import make_mesh_compat  # noqa: F401  (re-export)


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; 2x16x16 = 512 chips across two pods."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh_compat(shape, axes)


def make_serve_mesh(shape):
    """The serving engine's (data=replica, model=TP) mesh from a "DxM"
    string or (data, model) tuple — the launch-layer face of
    ``distributed/serve_sharding.py``."""
    from repro.distributed.serve_sharding import parse_mesh_arg
    return make_mesh_compat(parse_mesh_arg(shape), ("data", "model"))


def make_host_mesh():
    """Whatever devices exist locally, as a (data, model) mesh with model=1.

    Used by the trainer/examples on this CPU container and by the elastic
    subprocess tests with forced host device counts.
    """
    n = len(jax.devices())
    return make_mesh_compat((n, 1), ("data", "model"))
