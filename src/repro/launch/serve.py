"""Serving launcher: naive lock-step batch or continuous batching.

Drives the same ``prefill``/``decode_step`` functions the dry-run lowers at
production scale.  Usable as a library (examples) or CLI:

  # naive fixed-batch loop
  PYTHONPATH=src python -m repro.launch.serve --arch qwen1.5-0.5b-smoke \
      --batch 4 --prompt-len 32 --gen 16

  # continuous batching over a slot pool (any family implementing the
  # slot-decode protocol: transformer, griffin, xlstm)
  PYTHONPATH=src python -m repro.launch.serve --arch qwen1.5-0.5b-smoke \
      --engine continuous --batch 8 --gen 16
  PYTHONPATH=src python -m repro.launch.serve --arch recurrentgemma-2b-smoke \
      --engine continuous --batch 4 --gen 8

  # serve a model grown from a pretrained source (the paper's operator,
  # end-to-end at serve time)
  PYTHONPATH=src python -m repro.launch.serve --arch gpt-micro-big \
      --engine continuous --grow gpt-micro --grow-method mango
"""
from __future__ import annotations

import argparse
import functools
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import get_config, list_configs
from repro.data.synthetic import lm_batch
from repro.models import get_family, serve_supported
from repro.serve import ContinuousBatchingEngine, Request
from repro.train.steps import make_decode_step, make_prefill_step


@functools.lru_cache(maxsize=None)
def _jitted_steps(cfg):
    """One jitted prefill/decode pair per config — ``cfg`` is a frozen
    dataclass, so repeated ``generate`` calls (and the test suite's many
    per-request baselines) reuse the compile cache instead of re-tracing
    fresh closures every call."""
    return (jax.jit(make_prefill_step(cfg)), jax.jit(make_decode_step(cfg)))


def generate(cfg, params, prompt_tokens, *, max_new_tokens=16,
             max_len=None):
    """prompt_tokens: (B, P) int32 -> (B, max_new_tokens) greedy tokens."""
    fam = get_family(cfg)
    B, P = prompt_tokens.shape
    max_len = max_len or (P + max_new_tokens)
    cache = fam.init_cache(cfg, B, max_len)
    prefill, decode = _jitted_steps(cfg)

    logits, cache = prefill(params, {"tokens": prompt_tokens}, cache)
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    out = [tok]
    for t in range(max_new_tokens - 1):
        tok, cache = decode(params, tok, jnp.int32(P + t), cache)
        out.append(tok)
    return jnp.stack(out, axis=1)


def build_params(cfg, *, grow_from=None, grow_method="mango", grow_rank=1,
                 grow_steps=0, seed=0, log_fn=print):
    """Init params — directly, or grown from a source architecture via the
    paper's multi-linear operator (``core/grow.py``)."""
    fam = get_family(cfg)
    rng = jax.random.PRNGKey(seed)
    if not grow_from:
        return fam.init(rng, cfg)

    from repro.core import grow as growlib
    from repro.data.synthetic import lm_data_iter

    return growlib.grow_from_source(
        get_config(grow_from), cfg, method=grow_method, rank=grow_rank,
        steps=grow_steps,
        data_iter=lm_data_iter(cfg.vocab_size, 4, 32, seed=seed + 1),
        rng=rng, log_fn=log_fn)


def require_servable(cfg):
    """Gate ``--engine continuous`` behind the slot-decode capability probe
    with an actionable message: WHY this config is out, and WHAT is in."""
    ok, why = serve_supported(cfg)
    if ok:
        return
    def probe(name):
        try:
            return serve_supported(get_config(name))[0]
        except Exception:
            return False

    servable = [n for n in list_configs() if probe(n)]
    raise SystemExit(
        f"error: --engine continuous cannot serve {cfg.name!r}: {why}\n"
        "The slot-decode protocol serves causal decoder configs of every "
        "family in the zoo:\n"
        "  transformer — full KV, MLA latent, and ring-buffer window "
        "caches;\n"
        "  griffin     — rglru/conv recurrent state + local-attention "
        "rings;\n"
        "  xlstm       — mLSTM/sLSTM recurrent state.\n"
        f"Servable registered configs: {', '.join(servable)}\n"
        "(--engine naive runs any decoder config lock-step.)")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--engine", default="naive",
                    choices=["naive", "continuous"])
    ap.add_argument("--batch", type=int, default=4,
                    help="naive: batch size; continuous: request count")
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--capacity", type=int, default=4,
                    help="continuous: decode slot-pool size")
    ap.add_argument("--max-len", type=int, default=0,
                    help="continuous: per-slot cache length (0 = auto)")
    ap.add_argument("--k", type=int, default=8,
                    help="continuous: macro-step length (decode tokens per "
                         "on-device dispatch; host syncs once per K tokens)")
    ap.add_argument("--grow", default=None, metavar="SRC_ARCH",
                    help="grow params from this source arch before serving")
    ap.add_argument("--grow-method", default="mango",
                    choices=["mango", "ligo", "bert2bert", "stackbert",
                             "net2net"])
    ap.add_argument("--grow-rank", type=int, default=1)
    ap.add_argument("--grow-steps", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.engine == "continuous":
        # probe BEFORE param init/growth — rejection must not cost a grow
        require_servable(cfg)
    params = build_params(cfg, grow_from=args.grow,
                          grow_method=args.grow_method,
                          grow_rank=args.grow_rank,
                          grow_steps=args.grow_steps)

    if args.engine == "naive":
        prompts = jnp.asarray(lm_batch(cfg.vocab_size, args.batch,
                                       args.prompt_len))
        t0 = time.time()
        toks = generate(cfg, params, prompts, max_new_tokens=args.gen)
        toks.block_until_ready()
        dt = time.time() - t0
        print(f"[naive] generated {args.batch}x{args.gen} tokens in "
              f"{dt:.2f}s ({args.batch * args.gen / dt:.1f} tok/s)")
        print(np.asarray(toks[:2]))
        return

    max_len = args.max_len or (args.prompt_len + args.gen)
    engine = ContinuousBatchingEngine(cfg, params, capacity=args.capacity,
                                      max_len=max_len, k=args.k)
    rng = np.random.default_rng(0)
    reqs = []
    for uid in range(args.batch):
        plen = int(rng.integers(max(1, args.prompt_len // 2),
                                args.prompt_len + 1))
        prompt = lm_batch(cfg.vocab_size, 1, plen, seed=uid)[0]
        reqs.append(Request(uid=uid, prompt=prompt,
                            max_new_tokens=args.gen))
    t0 = time.time()
    out = engine.run(reqs)
    dt = time.time() - t0
    n_tok = sum(len(v) for v in out.values())
    print(f"[continuous] {cfg.family}/{engine.cache_layout} served "
          f"{len(reqs)} requests / {n_tok} tokens in "
          f"{dt:.2f}s ({n_tok / dt:.1f} tok/s, "
          f"{engine.n_decode_dispatches} macro-steps of K={args.k}, "
          f"{engine.n_prefills} prefill batches, "
          f"{engine.n_host_syncs / max(n_tok, 1):.2f} host syncs/token)")
    for uid in sorted(out)[:2]:
        print(uid, out[uid])


if __name__ == "__main__":
    main()
