"""Batched serving loop: prefill + greedy decode with KV/recurrent caches.

Drives the same ``prefill``/``decode_step`` functions the dry-run lowers at
production scale.  Usable as a library (examples) or CLI:

  PYTHONPATH=src python -m repro.launch.serve --arch qwen1.5-0.5b-smoke \
      --batch 4 --prompt-len 32 --gen 16
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import get_config
from repro.data.synthetic import lm_batch
from repro.models import get_family
from repro.train.steps import make_decode_step, make_prefill_step


def generate(cfg, params, prompt_tokens, *, max_new_tokens=16,
             max_len=None):
    """prompt_tokens: (B, P) int32 -> (B, max_new_tokens) greedy tokens."""
    fam = get_family(cfg)
    B, P = prompt_tokens.shape
    max_len = max_len or (P + max_new_tokens)
    cache = fam.init_cache(cfg, B, max_len)
    prefill = jax.jit(make_prefill_step(cfg))
    decode = jax.jit(make_decode_step(cfg))

    logits, cache = prefill(params, {"tokens": prompt_tokens}, cache)
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    out = [tok]
    for t in range(max_new_tokens - 1):
        tok, cache = decode(params, tok, jnp.int32(P + t), cache)
        out.append(tok)
    return jnp.stack(out, axis=1)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    fam = get_family(cfg)
    params = fam.init(jax.random.PRNGKey(0), cfg)
    prompts = jnp.asarray(lm_batch(cfg.vocab_size, args.batch,
                                   args.prompt_len))
    t0 = time.time()
    toks = generate(cfg, params, prompts, max_new_tokens=args.gen)
    toks.block_until_ready()
    dt = time.time() - t0
    print(f"generated {args.batch}x{args.gen} tokens in {dt:.2f}s "
          f"({args.batch * args.gen / dt:.1f} tok/s)")
    print(np.asarray(toks[:2]))


if __name__ == "__main__":
    main()
