"""Serving launcher: naive lock-step batch or continuous batching.

Drives the same ``prefill``/``decode_step`` functions the dry-run lowers at
production scale.  Usable as a library (examples) or CLI:

  # naive fixed-batch loop
  PYTHONPATH=src python -m repro.launch.serve --arch qwen1.5-0.5b-smoke \
      --batch 4 --prompt-len 32 --gen 16

  # continuous batching over a slot pool (any family implementing the
  # slot-decode protocol: transformer, griffin, xlstm)
  PYTHONPATH=src python -m repro.launch.serve --arch qwen1.5-0.5b-smoke \
      --engine continuous --batch 8 --gen 16
  PYTHONPATH=src python -m repro.launch.serve --arch recurrentgemma-2b-smoke \
      --engine continuous --batch 4 --gen 8

  # serve a model grown from a pretrained source (the paper's operator,
  # end-to-end at serve time)
  PYTHONPATH=src python -m repro.launch.serve --arch gpt-micro-big \
      --engine continuous --grow gpt-micro --grow-method mango

  # speculative serving: the pretrained SOURCE drafts for its grown
  # target (with --grow the source checkpoint is reused as the draft;
  # --draft picks any other servable config with the same vocab)
  PYTHONPATH=src python -m repro.launch.serve --arch gpt-micro-big \
      --engine continuous --grow gpt-micro --speculate --spec-d 4

  # non-greedy decode in the macro loop (also valid with --speculate:
  # draft proposals then go through rejection sampling)
  PYTHONPATH=src python -m repro.launch.serve --arch qwen1.5-0.5b-smoke \
      --engine continuous --temperature 0.8 --top-k 40 --top-p 0.95
"""
from __future__ import annotations

import argparse
import functools
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import get_config, list_configs
from repro.data.synthetic import lm_batch
from repro.models import get_family, serve_supported
from repro.serve import (
    ContinuousBatchingEngine,
    Request,
    SamplingParams,
    SpeculativeConfig,
    spec_pair_supported,
)
from repro.serve.engine import POLICIES
from repro.train.steps import make_decode_step, make_prefill_step


@functools.lru_cache(maxsize=None)
def _jitted_steps(cfg):
    """One jitted prefill/decode pair per config — ``cfg`` is a frozen
    dataclass, so repeated ``generate`` calls (and the test suite's many
    per-request baselines) reuse the compile cache instead of re-tracing
    fresh closures every call."""
    return (jax.jit(make_prefill_step(cfg)), jax.jit(make_decode_step(cfg)))


def generate(cfg, params, prompt_tokens, *, max_new_tokens=16,
             max_len=None, eos_id=None):
    """prompt_tokens: (B, P) int32 -> (B, <=max_new_tokens) greedy tokens.

    ``eos_id`` enables per-row early stopping: a row that emits eos is
    frozen (later entries clamp to eos) and the loop exits as soon as
    EVERY row has fired — the returned array is then shorter than
    ``max_new_tokens``.  Without eos the loop always decodes the full
    budget and stays fully lazy (no per-step host sync).
    """
    fam = get_family(cfg)
    B, P = prompt_tokens.shape
    max_len = max_len or (P + max_new_tokens)
    cache = fam.init_cache(cfg, B, max_len)
    prefill, decode = _jitted_steps(cfg)

    logits, cache = prefill(params, {"tokens": prompt_tokens}, cache)
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    out = [tok]
    done = None if eos_id is None else (tok == eos_id)
    for t in range(max_new_tokens - 1):
        if done is not None and bool(done.all()):
            break
        tok, cache = decode(params, tok, jnp.int32(P + t), cache)
        if done is not None:
            tok = jnp.where(done, eos_id, tok)  # freeze finished rows
            done = done | (tok == eos_id)
        out.append(tok)
    return jnp.stack(out, axis=1)


def build_params(cfg, *, grow_from=None, grow_method="mango", grow_rank=1,
                 grow_steps=0, seed=0, log_fn=print, return_source=False):
    """Init params — directly, or grown from a source architecture via the
    paper's multi-linear operator (``core/grow.py``).

    ``return_source=True`` returns ``(params, cfg_src, params_src)`` —
    the pretrained source checkpoint the target was grown from, which is
    exactly the draft model speculative serving wants (``cfg_src`` /
    ``params_src`` are ``None`` without ``grow_from``).
    """
    fam = get_family(cfg)
    rng = jax.random.PRNGKey(seed)
    if not grow_from:
        params = fam.init(rng, cfg)
        return (params, None, None) if return_source else params

    from repro.core import grow as growlib
    from repro.data.synthetic import lm_data_iter

    cfg_src = get_config(grow_from)
    params_src = get_family(cfg_src).init(rng, cfg_src)
    params = growlib.grow_from_source(
        cfg_src, cfg, method=grow_method, rank=grow_rank,
        steps=grow_steps, params_src=params_src,
        data_iter=lm_data_iter(cfg.vocab_size, 4, 32, seed=seed + 1),
        rng=rng, log_fn=log_fn)
    return (params, cfg_src, params_src) if return_source else params


def require_servable(cfg):
    """Gate ``--engine continuous`` behind the slot-decode capability probe
    with an actionable message: WHY this config is out, and WHAT is in."""
    ok, why = serve_supported(cfg)
    if ok:
        return
    def probe(name):
        try:
            return serve_supported(get_config(name))[0]
        except Exception:
            return False

    servable = [n for n in list_configs() if probe(n)]
    raise SystemExit(
        f"error: --engine continuous cannot serve {cfg.name!r}: {why}\n"
        "The slot-decode protocol serves causal decoder configs of every "
        "family in the zoo:\n"
        "  transformer — full KV, MLA latent, and ring-buffer window "
        "caches;\n"
        "  griffin     — rglru/conv recurrent state + local-attention "
        "rings;\n"
        "  xlstm       — mLSTM/sLSTM recurrent state.\n"
        f"Servable registered configs: {', '.join(servable)}\n"
        "(--engine naive runs any decoder config lock-step.)")


def require_spec_servable(cfg_tgt, cfg_draft, d, max_len):
    """Gate ``--speculate`` behind the PAIR probe.

    Speculative serving needs BOTH models servable through the
    chunk-verify slot protocol (plus a shared vocabulary and a verify
    chunk that fits every ring) — probing only the target would accept
    pairs that fail at the first draft step.  The probe detail reports
    per-mode servability for each model, so the error names the failing
    side."""
    ok, why = spec_pair_supported(cfg_tgt, cfg_draft, d, max_len)
    if ok:
        print(f"[serve] speculative pair: {why}")
        return
    raise SystemExit(
        f"error: --speculate cannot serve this draft/target pair: {why}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--engine", default="naive",
                    choices=["naive", "continuous"])
    ap.add_argument("--batch", type=int, default=4,
                    help="naive: batch size; continuous: request count")
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--capacity", type=int, default=4,
                    help="continuous: decode slot-pool size")
    ap.add_argument("--max-len", type=int, default=0,
                    help="continuous: per-slot cache length (0 = auto)")
    ap.add_argument("--k", type=int, default=8,
                    help="continuous: macro-step length (decode tokens — or "
                         "speculative blocks — per on-device dispatch; host "
                         "syncs once per dispatch)")
    ap.add_argument("--policy", default="fifo", choices=list(POLICIES),
                    help="admission policy: fifo, or spf (length-bucketed "
                         "shortest-prefill-first — less pad waste)")
    ap.add_argument("--grow", default=None, metavar="SRC_ARCH",
                    help="grow params from this source arch before serving")
    ap.add_argument("--grow-method", default="mango",
                    choices=["mango", "ligo", "bert2bert", "stackbert",
                             "net2net"])
    ap.add_argument("--grow-rank", type=int, default=1)
    ap.add_argument("--grow-steps", type=int, default=0)
    ap.add_argument("--grow-cfg", default=None, metavar="TGT_ARCH",
                    help="continuous: LIVE upgrade — Mango-grow --arch "
                         "into this target while serving, then hot-swap "
                         "the grown weights into the running engine with "
                         "zero dropped requests (mid-flight sequences "
                         "continue token-exactly; the old source becomes "
                         "the speculative draft when the pair probe "
                         "passes).  Growth method/rank/steps follow "
                         "--grow-method/--grow-rank/--grow-steps")
    ap.add_argument("--upgrade-at", type=int, default=0,
                    help="with --grow-cfg: minimum decode dispatches "
                         "before the hot-swap may land (0 = first block "
                         "boundary after growth is ready)")
    ap.add_argument("--upgrade-sync", action="store_true",
                    help="with --grow-cfg: grow BEFORE serving starts "
                         "instead of on a background thread — the swap "
                         "then lands deterministically at --upgrade-at "
                         "(CI smoke / reproducible traces)")
    ap.add_argument("--speculate", action="store_true",
                    help="speculative decode: a draft model proposes, the "
                         "target verifies (needs --draft, or --grow whose "
                         "source checkpoint then drafts)")
    ap.add_argument("--draft", default=None, metavar="DRAFT_ARCH",
                    help="draft config for --speculate (default: the --grow "
                         "source)")
    ap.add_argument("--spec-d", type=int, default=4,
                    help="speculation depth: draft proposals per block")
    ap.add_argument("--temperature", type=float, default=0.0,
                    help="sampling temperature (0 = greedy; implied 1.0 "
                         "when only --top-k/--top-p are set)")
    ap.add_argument("--top-k", type=int, default=0)
    ap.add_argument("--top-p", type=float, default=1.0)
    ap.add_argument("--sample-seed", type=int, default=0)
    ap.add_argument("--eos-id", type=int, default=None,
                    help="stop a sequence early when it emits this token")
    ap.add_argument("--kernel", default="jnp",
                    choices=["jnp", "auto", "interpret", "reference"],
                    help="slot-decode attention backend: jnp (pure-jnp "
                         "model path), auto (Pallas kernels — compiled on "
                         "TPU, interpreter elsewhere), interpret (Pallas "
                         "CPU interpreter), reference (kernels/ref.py "
                         "oracles)")
    ap.add_argument("--pool", default="dense", choices=["dense", "paged"],
                    help="continuous: slot-pool layout — dense (one full "
                         "max_len row per slot) or paged (block tables "
                         "over a shared page arena + copy-on-write prefix "
                         "cache; families without a pageable KV group "
                         "fall back to dense)")
    ap.add_argument("--pages", type=int, default=0,
                    help="paged: page-arena depth (0 = capacity * blocks "
                         "per slot, i.e. the dense pool's footprint)")
    ap.add_argument("--mesh", default=None, metavar="DxM",
                    help="continuous: serve over a (data=replica, "
                         "model=TP) device mesh — weights and slot pools "
                         "shard, the engine protocol is unchanged.  "
                         "Default: auto-chosen from the visible device "
                         "count (1 device serves unsharded).  Validate "
                         "on CPU with XLA_FLAGS="
                         "--xla_force_host_platform_device_count=N")
    ap.add_argument("--deadline", type=float, default=0.0,
                    help="continuous: per-request TTL in seconds — the "
                         "watchdog evicts a request this long after its "
                         "arrival with outcome 'expired' (0 = off)")
    ap.add_argument("--journal", default=None, metavar="PATH",
                    help="continuous: append-only crash-safe request "
                         "journal (JSONL); committed tokens flush at "
                         "block-readback granularity")
    ap.add_argument("--resume", action="store_true",
                    help="replay --journal before serving: mid-flight "
                         "requests re-admit token-exactly (prompt ‖ "
                         "committed), finished ones are not re-run")
    ap.add_argument("--snapshot", default=None, metavar="DIR",
                    help="continuous: write an engine snapshot (weights + "
                         "geometry, checkpoint format) before serving — "
                         "restore_engine() rebuilds the engine from it")
    ap.add_argument("--faults", default=None, metavar="PLAN",
                    help="continuous: deterministic fault injection — "
                         "'kind@step[:arg],...' with kinds "
                         "nan/oom/slow/hang/malformed/crash, or "
                         "'seed:S[:N]' for a seeded random plan "
                         "(chaos testing; see serve/faults.py)")
    args = ap.parse_args()

    cfg = get_config(args.arch).replace(decode_kernel=args.kernel)
    if args.engine == "continuous":
        # probe BEFORE param init/growth — rejection must not cost a grow
        require_servable(cfg)
    sampling = None
    if args.temperature > 0 or args.top_k > 0 or args.top_p < 1.0:
        # honor ANY non-default sampling flag: --top-k/--top-p alone used
        # to be silently greedy (SamplingParams was only built for
        # --temperature > 0, and temperature 0 means greedy)
        temperature = args.temperature if args.temperature > 0 else 1.0
        if args.temperature <= 0:
            print("[serve] --top-k/--top-p without --temperature: "
                  "sampling at temperature 1.0")
        sampling = SamplingParams(temperature=temperature,
                                  top_k=args.top_k, top_p=args.top_p,
                                  seed=args.sample_seed)
    if args.engine == "naive" and (sampling is not None
                                   or args.policy != "fifo"):
        # silently greedy-decoding while the user asked for sampling
        # would misrepresent the output
        raise SystemExit("error: --temperature/--top-k/--top-p/--policy "
                         "require --engine continuous (the naive loop is "
                         "greedy lock-step)")
    if args.engine == "naive" and args.kernel != "jnp":
        # same silently-ignored-flag class: the naive loop never touches
        # the slot-decode protocol, so a kernel mode would not run
        raise SystemExit("error: --kernel requires --engine continuous "
                         "(the Pallas kernels back the slot-decode path)")
    if args.engine == "naive" and (args.pool != "dense" or args.pages):
        raise SystemExit("error: --pool/--pages require --engine "
                         "continuous (the naive loop has no slot pool)")
    if args.engine == "naive" and args.mesh:
        raise SystemExit("error: --mesh requires --engine continuous "
                         "(only the slot-pool engine shards across "
                         "devices)")
    if args.engine == "naive" and (args.deadline or args.journal
                                   or args.resume or args.faults
                                   or args.snapshot):
        raise SystemExit("error: --deadline/--journal/--resume/--snapshot/"
                         "--faults require --engine continuous (the fault "
                         "tolerance layer lives in the slot-pool engine)")
    if args.resume and not args.journal:
        raise SystemExit("error: --resume needs --journal PATH (the "
                         "journal IS the recovery record)")
    if args.grow_cfg and args.engine != "continuous":
        raise SystemExit("error: --grow-cfg requires --engine continuous "
                         "(a live upgrade hot-swaps the slot-pool "
                         "engine)")
    if (args.upgrade_at or args.upgrade_sync) and not args.grow_cfg:
        raise SystemExit("error: --upgrade-at/--upgrade-sync need "
                         "--grow-cfg TGT_ARCH (they schedule the live "
                         "upgrade)")
    speculative = None
    max_len = args.max_len or (args.prompt_len + args.gen)
    if args.speculate:
        if args.engine != "continuous":
            raise SystemExit("error: --speculate requires --engine "
                             "continuous")
        draft_name = args.draft or args.grow
        if draft_name is None:
            raise SystemExit("error: --speculate needs a draft model — "
                             "pass --draft ARCH, or --grow SRC (the "
                             "pretrained source then drafts for its grown "
                             "target)")
        # probe the PAIR before any param init/growth
        require_spec_servable(cfg, get_config(draft_name), args.spec_d,
                              max_len)
    params, cfg_src, params_src = build_params(
        cfg, grow_from=args.grow, grow_method=args.grow_method,
        grow_rank=args.grow_rank, grow_steps=args.grow_steps,
        return_source=True)
    if args.speculate:
        if args.draft and (cfg_src is None or args.draft != cfg_src.name):
            cfg_d = get_config(args.draft)
            params_d = get_family(cfg_d).init(jax.random.PRNGKey(0), cfg_d)
        else:
            # the paper's pair: the pretrained source checkpoint the
            # target was grown from doubles as the draft
            cfg_d, params_d = cfg_src, params_src
        speculative = SpeculativeConfig(cfg_d, params_d, d=args.spec_d)

    if args.engine == "naive":
        prompts = jnp.asarray(lm_batch(cfg.vocab_size, args.batch,
                                       args.prompt_len))
        t0 = time.time()
        toks = generate(cfg, params, prompts, max_new_tokens=args.gen,
                        eos_id=args.eos_id)
        toks.block_until_ready()
        dt = time.time() - t0
        toks_np = np.asarray(toks)
        if args.eos_id is None:
            n_tok = toks_np.size
        else:
            # count up to each row's first eos — the frozen filler past
            # it was never really decoded
            fired = toks_np == args.eos_id
            n_tok = sum(int(np.argmax(r)) + 1 if r.any() else len(r)
                        for r in fired)
        print(f"[naive] generated {n_tok} tokens "
              f"({args.batch}x<={toks_np.shape[1]}) in "
              f"{dt:.2f}s ({n_tok / dt:.1f} tok/s)")
        print(toks_np[:2])
        return

    from repro.serve import (
        EngineKilled,
        FaultPlan,
        RequestJournal,
        read_journal,
        recovery_requests,
        snapshot_engine,
    )

    recovered = {}
    resumed = []
    if args.resume:
        st = read_journal(args.journal)
        resumed, recovered = recovery_requests(st)
        print(f"[serve] --resume: journal replays {len(st.order)} "
              f"request(s) — {len(recovered)} already complete, "
              f"{len(resumed)} re-admitting mid-flight")
    journal = RequestJournal(args.journal) if args.journal else None
    faults = FaultPlan.parse(args.faults) if args.faults else None
    from repro.distributed import serve_sharding
    mesh_arg = None
    if args.mesh:
        try:
            mesh_arg = serve_sharding.validate_serve_mesh(
                args.mesh, cfg, args.capacity,
                n_devices=len(jax.devices()))
        except ValueError as e:
            # the clear-error contract: a layout that cannot shard this
            # engine dies HERE, naming the geometry, not as an XLA shape
            # crash three layers down
            raise SystemExit(f"error: {e}")
    elif len(jax.devices()) > 1:
        try:
            mesh_arg = serve_sharding.choose_serve_mesh_shape(
                len(jax.devices()), cfg, args.capacity)
        except ValueError as e:
            print(f"[serve] {e} — serving single-device")
    engine = ContinuousBatchingEngine(cfg, params, capacity=args.capacity,
                                      max_len=max_len, k=args.k,
                                      policy=args.policy, pool=args.pool,
                                      pages=args.pages or None,
                                      sampling=sampling,
                                      speculative=speculative,
                                      deadline=args.deadline or None,
                                      journal=journal, faults=faults,
                                      mesh=mesh_arg)
    mb = 1024 * 1024
    print(f"[serve] mesh {engine.mesh_shape} "
          f"({engine.n_devices} device(s)) — per-device reservation: "
          f"params {engine.params_bytes_per_device / mb:.2f} MiB, "
          f"slot pools {engine.pool_bytes_per_device / mb:.2f} MiB")
    if engine.kernel_tp_fallback:
        print(f"[serve] --kernel {args.kernel}: the Pallas slot kernels "
              "read whole pool rows, so tensor-parallel serving falls "
              "back to the jnp path (token-exact either way)")
    if engine.pages_budget is not None:
        arena = ("ONE physical arena shared by target and draft "
                 "(per-engine refcount namespaces; pages trade freely)"
                 if engine.speculative is not None else "target arena")
        note = (f"--pages {args.pages}" if args.pages
                else "default: dense pool footprint")
        print(f"[serve] page budget: {engine.pages_budget} pages — "
              f"{arena} ({note})")
    if args.pool == "paged" and engine.pool_fallback_reason is not None:
        print(f"[serve] --pool paged fallback: "
              f"{engine.pool_fallback_reason} — affected pool(s) serve "
              "dense")
    if args.snapshot:
        path = snapshot_engine(engine, args.snapshot)
        print(f"[serve] engine snapshot -> {path}")
    upgrade_mgr = None
    if args.grow_cfg:
        from repro.serve.upgrade import UpgradeError, UpgradeManager
        try:
            upgrade_mgr = UpgradeManager(
                engine, get_config(args.grow_cfg),
                method=args.grow_method, rank=args.grow_rank,
                grow_steps=args.grow_steps, spec_d=args.spec_d,
                upgrade_at=args.upgrade_at, probe_fp=True)
        except UpgradeError as e:
            raise SystemExit(f"error: --grow-cfg: {e}")
        upgrade_mgr.start(background=not args.upgrade_sync)
        mode = "pre-grown" if args.upgrade_sync else "growing in background"
        print(f"[serve] live upgrade armed: {cfg.name} -> "
              f"{upgrade_mgr.cfg_tgt.name} ({mode}, swap at dispatch "
              f">= {args.upgrade_at})")
    rng = np.random.default_rng(0)
    reqs = list(resumed)
    known = {r.uid for r in resumed} | set(recovered)
    for uid in range(args.batch):
        if uid in known:
            continue  # --resume already owns this uid
        plen = int(rng.integers(max(1, args.prompt_len // 2),
                                args.prompt_len + 1))
        prompt = lm_batch(cfg.vocab_size, 1, plen, seed=uid)[0]
        reqs.append(Request(uid=uid, prompt=prompt,
                            max_new_tokens=args.gen, eos_id=args.eos_id))
    t0 = time.time()
    try:
        out = engine.run(reqs)
    except EngineKilled as e:
        # the injected crash: the journal survived, the process "died" —
        # exit cleanly so the kill/restart smoke can resume us
        if journal is not None:
            journal.close()
        print(f"[serve] ENGINE KILLED ({e}) — journal at {args.journal} "
              "holds the committed state; rerun with --resume")
        return
    dt = time.time() - t0
    if upgrade_mgr is not None:
        if upgrade_mgr.state in ("growing", "ready"):
            # the trace finished before the background growth was ready:
            # land the swap now so the NEXT trace serves the target
            upgrade_mgr.wait()
            upgrade_mgr.poll(engine)
            print("[serve] upgrade: growth outlived the trace — swap "
                  "landed at trace end")
        if upgrade_mgr.state == "swapped":
            spec_note = (f"draft={upgrade_mgr.cfg_src.name} "
                         f"d={upgrade_mgr.spec_d}"
                         if engine.speculative is not None else
                         f"off ({upgrade_mgr.spec_reason})")
            fp = upgrade_mgr.fp_token_agreement
            page_note = ""
            if upgrade_mgr.pages_resident_at_swap:
                page_note = (
                    f", pages {upgrade_mgr.pages_carried} carried / "
                    f"{upgrade_mgr.pages_reprefilled} re-prefilled "
                    f"({upgrade_mgr.pages_resident_at_swap} resident at "
                    "swap)")
            print(f"[serve] upgrade SWAPPED: {upgrade_mgr.cfg_src.name} "
                  f"-> {upgrade_mgr.cfg_tgt.name} in "
                  f"{upgrade_mgr.grow_seconds:.1f}s growth, pause "
                  f"{upgrade_mgr.pause_ms:.0f} ms, "
                  f"{upgrade_mgr.resumed} mid-flight resumed, "
                  f"{engine.n_held_for_upgrade} held submits, "
                  f"{len(engine.rejected)} dropped{page_note}; greedy "
                  f"agreement {'n/a' if fp is None else f'{fp:.3f}'}; "
                  f"post-swap speculation {spec_note}")
        elif upgrade_mgr.state == "failed":
            print(f"[serve] upgrade FAILED (engine kept serving "
                  f"{cfg.name}): {upgrade_mgr.error}")
    out = {**recovered, **out}
    n_tok = sum(len(v) for v in out.values())
    mode = "speculative" if speculative is not None else "continuous"
    spec_note = "" if speculative is None else (
        f", draft={speculative.cfg.name} d={speculative.d} "
        f"acceptance={engine.acceptance_rate:.2f}")
    paged_note = "" if engine.pool_kind != "paged" else (
        f", {engine.pages_highwater} pages peak"
        f" ({next(m for m in engine._metas if m is not None).page}"
        " tok/page)"
        f", prefix hit rate {engine.prefix_hit_rate:.2f}")
    print(f"[{mode}] {cfg.family}/{engine.cache_layout} "
          f"({engine.pool_kind} pool) served "
          f"{len(reqs)} requests / {n_tok} tokens in "
          f"{dt:.2f}s ({n_tok / dt:.1f} tok/s, "
          f"{engine.n_decode_dispatches} macro-steps of K={args.k}, "
          f"{engine.n_prefills} prefill batches, "
          f"{engine.n_host_syncs / max(n_tok, 1):.2f} host syncs/token"
          f"{spec_note}{paged_note})")
    if engine.rejected:
        # rejections are recorded, not raised — surface them in the report
        print(f"[{mode}] rejected {len(engine.rejected)} request(s):")
        for uid, why in sorted(engine.rejected.items()):
            print(f"  uid {uid}: {why}")
    bad = {u: o for u, o in engine.outcomes.items() if o != "finished"}
    if bad or engine.n_faults_injected:
        print(f"[{mode}] fault report: {engine.n_faults_injected} "
              f"fault(s) injected, {engine.n_expired} expired, "
              f"{engine.n_quarantined} quarantined, {engine.n_shed} shed, "
              f"{engine.n_spec_fallbacks} spec fallback(s), "
              f"{engine.n_degraded_admissions} degraded admission(s)")
        for uid, o in sorted(bad.items()):
            print(f"  uid {uid}: {o}")
    if journal is not None:
        journal.close()
    for uid in sorted(out)[:2]:
        print(uid, out[uid])


if __name__ == "__main__":
    main()
