"""Abstract input specs (ShapeDtypeStruct) for every lowered entry point.

No device allocation ever happens here — these are the stand-ins the
dry-run lowers against (weak-type-correct, shardable).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import get_family

S = jax.ShapeDtypeStruct


def batch_specs(cfg: ModelConfig, batch: int, seq: int):
    """Training/prefill batch: the model's input dict."""
    specs = {}
    if cfg.continuous_inputs:
        specs["inputs"] = S((batch, seq, cfg.continuous_inputs),
                            jnp.dtype(cfg.compute_dtype))
        specs["tokens"] = S((batch, seq), jnp.int32)
        specs["mask"] = S((batch, seq), jnp.float32)
    else:
        specs["tokens"] = S((batch, seq), jnp.int32)
    if cfg.rope == "mrope":
        specs["positions"] = S((3, batch, seq), jnp.int32)
    return specs


def batch_logical(cfg: ModelConfig):
    specs = {"tokens": ("batch", "seq")}
    if cfg.continuous_inputs:
        specs["inputs"] = ("batch", "seq", None)
        specs["mask"] = ("batch", "seq")
    if cfg.rope == "mrope":
        specs["positions"] = (None, "batch", "seq")
    return specs


def params_specs_abstract(cfg: ModelConfig):
    fam = get_family(cfg)
    return jax.eval_shape(lambda: fam.init(jax.random.PRNGKey(0), cfg))


def cache_specs_abstract(cfg: ModelConfig, batch: int, max_len: int):
    fam = get_family(cfg)
    return jax.eval_shape(lambda: fam.init_cache(cfg, batch, max_len))


def cache_logical(cfg: ModelConfig):
    fam = get_family(cfg)
    return fam.cache_specs(cfg)


def slot_pool_specs(cfg: ModelConfig, capacity: int, max_len: int):
    """Abstract slot pool of the continuous-batching engine: one
    ``init_cache`` allocation whose batch axis is the slot axis.  For
    sliding-window configs the cache-seq axis is min(max_len, window) —
    the ring buffer — so per-slot memory is O(window), and recurrent
    families (griffin, xlstm) carry O(1) state leaves per slot."""
    return cache_specs_abstract(cfg, capacity, max_len)


def paged_slot_pool_specs(cfg: ModelConfig, capacity: int, max_len: int,
                          pages: int | None = None):
    """Abstract PAGED slot pool (``--pool paged``): every cache group the
    family declares in ``paged_groups`` is re-laid over the shared arena —
    seq groups as ``(L, n_pages, page, *tail)`` pages plus per-slot block
    tables ``(L, capacity, nblk)``, slot groups (xlstm conv tails) as
    one-row-per-slot arenas ``(L, n_pages, *tail)`` with ``nblk = 1``.
    Undeclared leaves (O(1) recurrent state) stay dense.  Returns None when
    the family declares no groups — the engine serves dense in that case."""
    from repro.serve import paged as paged_lib

    fam = get_family(cfg)
    meta = paged_lib.pool_meta(
        cfg, cache_specs_abstract(cfg, capacity, max_len), pages)
    if meta is None:
        return None
    return jax.eval_shape(
        lambda: paged_lib.build_paged_pool(fam, cfg, capacity, max_len,
                                           pages)[0])


def slot_pool_shardings(cfg: ModelConfig, capacity: int, max_len: int,
                        mesh_shape, *, pool: str = "dense",
                        pages: int | None = None):
    """NamedSharding tree for a serve slot pool on a (data, model) mesh —
    the launch-layer view of what ``--mesh`` commits to devices: slots
    band over ``data``, head axes shard over ``model``, paged arenas keep
    their page id space whole with replicated block tables.  Built from
    abstract specs only (no device allocation), so dry-run tooling can
    inspect a placement it never materializes."""
    from repro.distributed.serve_sharding import get_serve_plan
    from repro.serve import paged as paged_lib

    fam = get_family(cfg)
    plan = get_serve_plan(tuple(mesh_shape))
    meta = None
    specs = slot_pool_specs(cfg, capacity, max_len)
    if pool == "paged":
        paged_specs = paged_slot_pool_specs(cfg, capacity, max_len, pages)
        if paged_specs is not None:
            specs = paged_specs
            meta = paged_lib.pool_meta(
                cfg, cache_specs_abstract(cfg, capacity, max_len), pages)
    return plan.pool_shardings(fam, cfg, specs, meta)


def slot_decode_specs(cfg: ModelConfig, capacity: int, max_len: int):
    """Abstract inputs of one slot-decode macro-step dispatch
    (``make_slot_decode_loop`` / ``make_speculative_loop``): the engine's
    persistent device-resident decode state plus the slot pool.  ``keys``
    are the per-slot sampling chains — carried (and donated) even in
    greedy mode, consumed by the sampled and speculative loops."""
    return {
        "tokens": S((capacity,), jnp.int32),
        "positions": S((capacity,), jnp.int32),
        "remaining": S((capacity,), jnp.int32),
        "eos_ids": S((capacity,), jnp.int32),
        "done": S((capacity,), jnp.bool_),
        "keys": S((capacity, 2), jnp.uint32),
        "pool": slot_pool_specs(cfg, capacity, max_len),
    }
