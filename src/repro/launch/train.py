"""Trainer: end-to-end training loop with growth, checkpointing, elastic
resume, straggler watchdog — runs on anything from 1 CPU device to the
production meshes.

This is what the examples drive; the dry-run lowers the same ``train_step``
at production scale.

Usage:
  PYTHONPATH=src python -m repro.launch.train --arch gpt-micro --steps 200
  PYTHONPATH=src python -m repro.launch.train --arch gpt-micro-big \
      --grow-from gpt-micro --grow-method mango --steps 200
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.configs.base import get_config
from repro.data.synthetic import lm_data_iter, vision_batch
from repro.distributed.sharding import (
    params_shardings,
    sharding_rules_for_mesh,
    use_rules,
)
from repro.launch.mesh import make_host_mesh
from repro.models import get_family
from repro.optim import OptimizerConfig, make_optimizer, \
    linear_warmup_cosine
from repro.train.steps import make_train_step

# XLA flags a real TPU launch would set for compute/comm overlap (the
# latency-hiding scheduler); harmless no-ops on CPU.
TPU_PERF_FLAGS = (
    "--xla_tpu_enable_latency_hiding_scheduler=true "
    "--xla_tpu_megacore_fusion_allow_ags=true "
    "--xla_enable_async_all_gather=true "
    "--xla_enable_async_collective_permute=true "
)


def data_for(cfg, batch, seq, seed=0, start_step=0):
    if cfg.head == "cls":
        def it():
            step = start_step
            while True:
                n = int(cfg.image_size // cfg.patch_size) ** 2
                b = vision_batch(cfg.n_classes, batch, cfg.image_size,
                                 cfg.patch_size, seed=seed, step=step)
                # stub frontend dims must match continuous_inputs
                b["inputs"] = b["inputs"][..., :cfg.continuous_inputs]
                b["inputs"] = b["inputs"][:, :cfg.learned_pos - 1]
                yield b
                step += 1
        return it()
    return lm_data_iter(cfg.vocab_size, batch, seq, seed=seed,
                        start_step=start_step)


def train(arch: str, *, steps=100, batch=8, seq=None, lr=3e-4,
          warmup=20, ckpt_dir=None, ckpt_every=0, resume=False,
          grow_from=None, grow_method="mango", grow_rank=1,
          grow_steps=50, grow_src_ckpt=None, log_every=10, seed=0,
          watchdog_s=None, n_microbatches=1, log_fn=print):
    cfg = get_config(arch)
    fam = get_family(cfg)
    seq = seq or min(cfg.max_seq_len, 256)
    mesh = make_host_mesh()
    rules = sharding_rules_for_mesh(mesh)

    opt_cfg = OptimizerConfig(lr=lr, weight_decay=1e-2)
    schedule = linear_warmup_cosine(lr, warmup, steps)
    init_fn, _ = make_optimizer(opt_cfg, schedule)
    step_fn = make_train_step(cfg, opt_cfg, schedule,
                              n_microbatches=n_microbatches)

    # ---- init (fresh, grown from a source model, or resumed) ----
    start = 0
    rng = jax.random.PRNGKey(seed)
    history = []
    if grow_from:
        from repro.core import grow as growlib

        cfg_src = get_config(grow_from)
        src_ckpt = grow_src_ckpt or (
            ckpt_dir and os.path.join(ckpt_dir, "..", grow_from))
        fam_src = get_family(cfg_src)
        params_src = fam_src.init(rng, cfg_src)
        if src_ckpt and os.path.isdir(src_ckpt):
            from repro.checkpoint import load_checkpoint
            tree, sstep, _ = load_checkpoint(
                src_ckpt, {"p": params_src, "o": None})
            params_src = tree["p"]
            log_fn(f"[grow] source weights from {src_ckpt} @ step {sstep}")
        params = growlib.grow_from_source(
            cfg_src, cfg, method=grow_method, rank=grow_rank,
            steps=grow_steps, data_iter=data_for(cfg, batch, seq, seed + 1),
            params_src=params_src, rng=rng, log_fn=log_fn)
    else:
        params = fam.init(rng, cfg)
    opt_state = init_fn(params)

    mgr = None
    if ckpt_dir:
        mgr = CheckpointManager(ckpt_dir, keep=3,
                                every=ckpt_every or max(steps // 4, 1),
                                async_save=True)
        if resume:
            restored = mgr.restore_latest({"p": params, "o": opt_state})
            if restored:
                tree, start, extra = restored
                params, opt_state = tree["p"], tree["o"]
                log_fn(f"[resume] restored step {start}")

    p_shard = params_shardings(fam.param_specs(cfg), mesh,
                               rules, shapes=params)
    params = jax.device_put(params, p_shard)

    jstep = jax.jit(step_fn, donate_argnums=(0, 1))
    data = data_for(cfg, batch, seq, seed, start_step=start)
    t_last = time.time()
    for step in range(start, steps):
        b = next(data)
        b = {k: jnp.asarray(v) for k, v in b.items()}
        with use_rules(mesh, rules):
            params, opt_state, metrics = jstep(params, opt_state, b,
                                               jnp.int32(step + 1))
        if watchdog_s and time.time() - t_last > watchdog_s:
            log_fn(f"[watchdog] step {step} exceeded {watchdog_s}s — "
                   "in production this triggers checkpoint + re-mesh")
        t_last = time.time()
        if step % log_every == 0 or step == steps - 1:
            m = {k: float(v) for k, v in metrics.items()}
            history.append({"step": step, **m})
            log_fn(f"step {step:5d}  loss {m.get('loss', 0):.4f}  "
                   f"gnorm {m.get('grad_norm', 0):.3f}")
        if mgr:
            mgr.maybe_save(step + 1, {"p": params, "o": opt_state},
                           extra={"arch": arch})
    if mgr:
        mgr.maybe_save(steps, {"p": params, "o": opt_state},
                       extra={"arch": arch}, force=True)
        mgr.wait()
    return params, history


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=None)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=0)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--grow-from", default=None)
    ap.add_argument("--grow-method", default="mango",
                    choices=["mango", "ligo", "bert2bert", "stackbert",
                             "net2net"])
    ap.add_argument("--grow-rank", type=int, default=1)
    ap.add_argument("--grow-steps", type=int, default=50)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--history-out", default=None)
    args = ap.parse_args()
    _, hist = train(
        args.arch, steps=args.steps, batch=args.batch, seq=args.seq,
        lr=args.lr, ckpt_dir=args.ckpt_dir, ckpt_every=args.ckpt_every,
        resume=args.resume, grow_from=args.grow_from,
        grow_method=args.grow_method, grow_rank=args.grow_rank,
        grow_steps=args.grow_steps, n_microbatches=args.microbatches)
    if args.history_out:
        with open(args.history_out, "w") as f:
            json.dump(hist, f, indent=1)


if __name__ == "__main__":
    main()
