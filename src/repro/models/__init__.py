"""Model zoo: family registry.

Every family module exposes the same functional interface:
  init(rng, cfg) -> params
  forward(params, batch, cfg) -> (logits, aux)
  param_specs(cfg) -> pytree of logical-axis tuples
  init_cache(cfg, batch, max_len) / prefill / decode_step   (decoders only)
"""
from __future__ import annotations

import importlib

_FAMILIES = {
    "transformer": "repro.models.transformer",
    "griffin": "repro.models.griffin",
    "xlstm": "repro.models.xlstm",
}


def get_family(cfg_or_name):
    name = getattr(cfg_or_name, "family", cfg_or_name)
    return importlib.import_module(_FAMILIES[name])
