"""Model zoo: family registry.

Every family module exposes the same functional interface:
  init(rng, cfg) -> params
  forward(params, batch, cfg) -> (logits, aux)
  param_specs(cfg) -> pytree of logical-axis tuples
  init_cache(cfg, batch, max_len) / prefill / decode_step   (decoders only)

Families that serve through the continuous-batching engine additionally
implement the SLOT-STATE PROTOCOL (see docs/serving.md):
  cache_specs(cfg)                 -> logical axes of the slot pool
  prefill_full(params, batch, cfg, cache)
      batch = {"tokens": (B, S) bucket-padded, "plens": (B,) true lengths}
      -> (logits (B, S, V), cache after each row's REAL prompt)
  decode_step_slots(params, tokens, positions, cache, cfg, done=None)
      one token per slot at per-slot lengths; ``done`` rows are exact
      no-ops (frozen state / bit-identical cache re-stores)
  serve_supported(cfg) -> (ok, detail)

``cfg.decode_kernel`` selects the slot attention backend inside these
hooks: "jnp" (default) or a Pallas kernel mode ("auto" / "interpret" /
"reference" — see kernels/ops.py); caches are allocated in the TPU
pool layout (cache axis padded via ``common.pad_cache_len``) either way.

Families that additionally serve as a speculative draft/target implement
the chunk-verify extension of the protocol:
  verify_step_slots(params, tokens (B,S), positions (B,), cache, cfg,
                    done=None) -> (logits (B,S,V), pending)
      feed an S-token chunk per slot starting at each row's own length,
      logits at every chunk index, cache READ-ONLY;
  commit_slots(params, tokens, positions, n_feed (B,), cache, pending,
               cfg, done=None) -> cache
      realize exactly each row's first ``n_feed`` chunk feeds (accepted
      prefix) — deferred scatter for KV layouts, stacked-state gather for
      recurrent layouts; ``n_feed == 0`` / ``done`` rows are untouched.

Paging is part of the protocol too: ``paged_groups(cfg)`` declares which
top-level slot-cache groups re-lay as page arenas under ``--pool paged``
(see ``serve/paged.py``).  Every slot hook above must then accept groups
carrying a ``"bt"`` block table — writes resolve their page through the
table, ``done``/unallocated rows redirect to the page sentinel and drop.
A family with no declaration (or an empty one) serves dense, and the
engine reports the named ``pool_fallback_reason`` instead of silently
flipping the pool kind.
"""
from __future__ import annotations

import importlib

_FAMILIES = {
    "transformer": "repro.models.transformer",
    "griffin": "repro.models.griffin",
    "xlstm": "repro.models.xlstm",
}


def get_family(cfg_or_name):
    name = getattr(cfg_or_name, "family", cfg_or_name)
    return importlib.import_module(_FAMILIES[name])


def serve_supported(cfg):
    """Capability probe: can ``ContinuousBatchingEngine`` serve this config?

    Returns (ok, detail) — ``detail`` names the slot cache layout when
    servable, or the reason when not.  This replaces hard-coded family
    checks: a family opts in by implementing the slot-state protocol and
    its own ``serve_supported``.
    """
    fam = get_family(cfg)
    probe = getattr(fam, "serve_supported", None)
    if probe is None or not (hasattr(fam, "prefill_full")
                             and hasattr(fam, "decode_step_slots")):
        return False, (f"family {cfg.family!r} does not implement the "
                       "slot-state protocol")
    return probe(cfg)


def spec_decode_supported(cfg):
    """Capability probe: can this config run as a speculative draft or
    target?  Requires the slot-state protocol plus the chunk-verify hooks
    (``verify_step_slots`` / ``commit_slots``)."""
    ok, detail = serve_supported(cfg)
    if not ok:
        return ok, detail
    fam = get_family(cfg)
    if not (hasattr(fam, "verify_step_slots")
            and hasattr(fam, "commit_slots")):
        return False, (f"family {cfg.family!r} does not implement the "
                       "chunk-verify (speculative) slot hooks")
    return True, detail


def paged_groups(cfg):
    """Slot-state protocol: which slot-cache groups page under ``--pool
    paged``.

    Returns ``{top_level_cache_key: (kind, leaf_names)}`` where ``kind``
    is:
      * ``"seq"``  — the named leaves are (L, B, S, ...) sequence caches
        sharing one S axis; S splits into fixed pages and every slot
        holds a block table of page ids (transformer K/V, MLA latents,
        griffin local-attention rings).
      * ``"slot"`` — the named leaves are per-slot state with no sequence
        axis (xlstm conv shift tails); the whole per-slot tail is one
        page and the block table has a single entry.
    Leaves of a declared group NOT named stay dense-per-slot (xlstm's
    mLSTM C/n/m carries ride in the same group dict as its paged conv
    tail).  An empty dict means nothing pages — the engine serves dense
    and surfaces the family's ``pool_fallback_reason``.
    """
    fam = get_family(cfg)
    probe = getattr(fam, "paged_groups", None)
    return probe(cfg) if probe else {}


def slot_cache_layout(cfg):
    """Short layout tag for benchmarks/telemetry: how a serve slot stores
    its sequence state.  Dispatches to the family module (part of the
    slot-state protocol) — no hard-coded family switch here."""
    fam = get_family(cfg)
    probe = getattr(fam, "slot_cache_layout", None)
    return probe(cfg) if probe else "unsupported"
