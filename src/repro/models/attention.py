"""Attention: GQA/MQA grouped einsum with memory-efficient chunking.

Naive attention materializes the (B, H, S, S) logits tensor — at the assigned
shapes (e.g. train_4k: B=256, S=4096; prefill_32k: S=32768) that is TBs of
HBM, so the *default* lowering path is a FlashAttention-style query-chunk scan
(Rabe & Staats, arXiv:2112.05682): O(S * chunk) live memory, with
``jax.remat`` on the chunk body so the backward pass recomputes chunk logits
instead of saving them.  The Pallas ``flash_attention`` kernel in
``repro/kernels`` is the TPU-native realization of the same schedule; this
module is the partitioner-friendly jnp form used for lowering/dry-run.

GQA is computed grouped — queries reshaped to (B, S, KV, G, hd) — so repeated
KV heads are never materialized.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def _band_mask(qpos, kpos, *, causal: bool, window: Optional[int],
               kv_len=None):
    """(Sq, Sk) bool mask — or (B, Sq, Sk) when ``kv_len`` is per-row (B,).

    qpos/kpos are int32 position vectors; a vector ``kv_len`` is the
    continuous-batching case where every batch row is a slot at its own
    sequence length.
    """
    m = jnp.ones((qpos.shape[-1], kpos.shape[-1]), bool)
    if causal:
        m &= kpos[None, :] <= qpos[:, None]
    if window is not None:
        m &= kpos[None, :] > (qpos[:, None] - window)
    if kv_len is not None:
        kvl = jnp.asarray(kv_len)
        if kvl.ndim == 0:
            m &= kpos[None, :] < kvl
        else:
            m = m[None] & (kpos[None, None, :] < kvl[:, None, None])
    return m


def _sdpa(q, k, v, mask, scale, logits_dtype=jnp.float32):
    """q: (B,Sq,KV,G,hd)  k,v: (B,Sk,KV,hd)  mask: (Sq,Sk) or (B,Sq,Sk).

    ``logits_dtype=bf16`` halves the S x S intermediate chain (max-shifted
    exp stays well-conditioned in bf16) — the jnp-path approximation of
    what the Pallas flash kernel gets for free by keeping logits in VMEM.
    """
    logits = jnp.einsum(
        "bqkgh,bskh->bkgqs", q, k, preferred_element_type=jnp.float32
    ) * scale
    if mask.ndim == 2:
        mask = mask[None, None, None]
    else:
        mask = mask[:, None, None]
    logits = jnp.where(mask, logits, NEG_INF)
    if logits_dtype != jnp.float32:
        m = jnp.max(logits, axis=-1, keepdims=True)
        p = jnp.exp((logits - m).astype(logits_dtype))
        denom = jnp.sum(p.astype(jnp.float32), axis=-1, keepdims=True)
        probs = p.astype(jnp.float32) / denom
    else:
        probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum(
        "bkgqs,bskh->bqkgh", probs.astype(v.dtype), v
    )
    return out


def attention(q, k, v, *, causal=True, window=None, q_offset=0,
              kv_len=None, scale=None, chunk_q=512, unroll=False,
              logits_dtype=jnp.float32, prefix_chunks=False):
    """Grouped-query attention.

    q: (B, Sq, H, hd); k, v: (B, Sk, KV, hd_k/hd_v); returns (B, Sq, H, hd_v).
    ``q_offset``  — absolute position of q[0] (prefill chunking / decode).
    ``kv_len``    — valid prefix length of k/v (padded caches), traced scalar ok.
    ``prefix_chunks`` — causal self-attention only: unroll the query-chunk
    loop in python so chunk i attends a *static KV prefix* [0, (i+1)*chunk)
    instead of the full masked S — cuts the ~2x causal masked-compute waste
    of the scan path at the cost of O(nc) HLO size (§Perf optimization).
    """
    B, Sq, H, hd = q.shape
    KV = k.shape[2]
    G = H // KV
    if scale is None:
        scale = hd ** -0.5
    qg = q.reshape(B, Sq, KV, G, hd)

    Sk = k.shape[1]
    kpos = jnp.arange(Sk, dtype=jnp.int32)

    if Sq <= chunk_q:
        qpos = q_offset + jnp.arange(Sq, dtype=jnp.int32)
        mask = _band_mask(qpos, kpos, causal=causal, window=window,
                          kv_len=kv_len)
        out = _sdpa(qg, k, v, mask, scale, logits_dtype)
        if kv_len is not None and jnp.ndim(kv_len) == 1:
            # rows with kv_len == 0 (idle/finished slots in the macro-step
            # decode loop) have every key masked; the softmax degenerates to
            # uniform garbage, so pin them to the Pallas decode kernel's
            # semantics: exact zeros.
            out = jnp.where(
                (jnp.asarray(kv_len) > 0)[:, None, None, None, None], out, 0)
        return out.reshape(B, Sq, H, v.shape[-1])

    if Sq % chunk_q:  # ragged tail (e.g. MTP's S-1 stream): pad + slice
        pad = chunk_q - Sq % chunk_q
        qp = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
        out = attention(qp, k, v, causal=causal, window=window,
                        q_offset=q_offset, kv_len=kv_len, scale=scale,
                        chunk_q=chunk_q, unroll=unroll,
                        logits_dtype=logits_dtype)
        return out[:, :Sq]
    nc = Sq // chunk_q
    qc = qg.reshape(B, nc, chunk_q, KV, G, hd).transpose(1, 0, 2, 3, 4, 5)

    if (prefix_chunks and causal and window is None and kv_len is None
            and Sq == Sk and q_offset == 0):
        sdpa = jax.remat(_sdpa, prevent_cse=False,
                         static_argnums=(4, 5))
        outs = []
        for ci in range(nc):
            hi = (ci + 1) * chunk_q
            qpos = ci * chunk_q + jnp.arange(chunk_q, dtype=jnp.int32)
            kpos_c = jnp.arange(hi, dtype=jnp.int32)
            mask = _band_mask(qpos, kpos_c, causal=True, window=None)
            outs.append(sdpa(qc[ci], k[:, :hi], v[:, :hi], mask, scale,
                             logits_dtype))
        out = jnp.stack(outs, 0)
        return out.transpose(1, 0, 2, 3, 4, 5).reshape(
            B, Sq, H, v.shape[-1])

    if window is not None:
        # local attention: each chunk only needs a static (window + chunk_q)
        # KV slice — O(S * window) total work instead of O(S^2).
        # look-back windows are causal by construction (griffin/gemma-style);
        # a non-causal window would need forward KV context the slice
        # doesn't cover.
        assert causal, "windowed attention requires causal=True"
        span = window + chunk_q
        pad = span  # left-pad so every dynamic_slice start is in range
        # ...and right-pad up to the padded query length: the ragged-tail
        # q padding can push the last chunk's slice past the true KV
        # length, and a clamped dynamic_slice start would silently
        # mislabel that chunk's kpos (out-of-range positions are masked
        # below instead)
        Sk_data = k.shape[1]
        right = max(0, nc * chunk_q - Sk_data)
        kp = jnp.pad(k, ((0, 0), (pad, right), (0, 0), (0, 0)))
        vp = jnp.pad(v, ((0, 0), (pad, right), (0, 0), (0, 0)))

        def chunk_body(_, ci):
            qi = qc[ci]
            start = ci * chunk_q + pad - window
            ks = jax.lax.dynamic_slice_in_dim(kp, start, span, axis=1)
            vs = jax.lax.dynamic_slice_in_dim(vp, start, span, axis=1)
            qpos = q_offset + ci * chunk_q + jnp.arange(chunk_q,
                                                        dtype=jnp.int32)
            kpos_c = start - pad + jnp.arange(span, dtype=jnp.int32)
            mask = _band_mask(qpos, kpos_c, causal=causal, window=window,
                              kv_len=kv_len) \
                & ((kpos_c >= 0) & (kpos_c < Sk_data))[None, :]
            return None, _sdpa(qi, ks, vs, mask, scale, logits_dtype)

        body = jax.remat(chunk_body, prevent_cse=False)
        _, outs = jax.lax.scan(body, None, jnp.arange(nc), unroll=unroll)
    else:
        def chunk_body(_, ci):
            qi = qc[ci]
            qpos = q_offset + ci * chunk_q + jnp.arange(chunk_q,
                                                        dtype=jnp.int32)
            mask = _band_mask(qpos, kpos, causal=causal, window=None,
                              kv_len=kv_len)
            return None, _sdpa(qi, k, v, mask, scale, logits_dtype)

        body = jax.remat(chunk_body, prevent_cse=False)
        _, outs = jax.lax.scan(body, None, jnp.arange(nc), unroll=unroll)

    out = outs.transpose(1, 0, 2, 3, 4, 5).reshape(B, Sq, H, v.shape[-1])
    return out


def ring_positions_rows(cur_len, ring):
    """Absolute position stored in each ring-buffer cache slot, PER ROW.

    cur_len: (B,) int32 — number of positions written so far in each row
    (the ring invariant: slot ``s`` holds the largest position ``p <
    cur_len`` with ``p % ring == s``).  Returns (B, ring) int32 absolute
    positions, -1 for slots never written.  The scalar form lives in
    ``transformer._ring_positions``; this is its continuous-batching
    counterpart where every batch row is at its own length.
    """
    slot = jnp.arange(ring, dtype=jnp.int32)[None]
    cur = cur_len[:, None]
    wrap = (cur - 1) // ring
    base = wrap * ring + slot
    pos = jnp.where(base < cur, base, base - ring)
    return jnp.where(pos >= 0, pos, -1)


def ring_fill_rows(x, plens, ring, dtype):
    """Fill a ring-buffer cache from a bucket-padded prefill, PER ROW.

    x: (B, S, ...) per-position values (e.g. K or V) of a tail-padded
    prompt batch; plens: (B,) true prompt lengths.  Ring slot ``s`` of row
    ``b`` gets the value at the largest real position ``p < plens[b]``
    with ``p % ring == s`` (a gather — wrapped positions never race a
    scatter), 0 where never written.  Returns (B, ring, ...) in ``dtype``.
    """
    kpos = ring_positions_rows(plens, ring)  # (B, ring)
    shape = kpos.shape + (1,) * (x.ndim - 2)
    take = jnp.clip(kpos, 0, x.shape[1] - 1).reshape(shape)
    written = (kpos >= 0).reshape(shape)
    return jnp.where(written, jnp.take_along_axis(x, take, axis=1),
                     0).astype(dtype)


def ring_slot_attend(q, ck, cv, slot_positions, *, window, scale=None,
                     done=None):
    """One-token attention over a ring-buffer window cache at per-row slots.

    q: (B, 1, H, hd); ck/cv: (B, ring, KV, hd) ring caches whose row ``b``
    already contains this step's K/V written at ``slot_positions[b] %
    ring``; slot_positions: (B,) — each row's current length (== the
    query's absolute position).  Masking is by ABSOLUTE position
    reconstructed from the ring invariant: a slot is attendable iff its
    position is in ``(qpos - window, qpos]`` and was ever written.  Rows
    flagged ``done`` attend nothing and return exact zeros (the idle-row
    semantics of the full-cache slot path and the Pallas decode kernel).
    """
    B, Sq, H, hd = q.shape
    KV = ck.shape[2]
    ring = ck.shape[1]
    if scale is None:
        scale = hd ** -0.5
    kpos = ring_positions_rows(slot_positions + 1, ring)  # (B, ring)
    qpos = slot_positions[:, None]
    mask = (kpos <= qpos) & (kpos > qpos - window) & (kpos >= 0)
    if done is not None:
        mask &= ~done[:, None]
    qg = q.reshape(B, Sq, KV, H // KV, hd)
    out = _sdpa(qg, ck.astype(q.dtype), cv.astype(q.dtype),
                mask[:, None, :], scale)
    if done is not None:
        out = jnp.where(done[:, None, None, None, None], 0.0, out)
    return out.reshape(B, Sq, H, cv.shape[-1])


def ring_slot_update_attend(q, cache, k, v, slot_positions, *, window,
                            done=None, scale=None, kernel=None):
    """One slot-decode step over a ring-buffer window cache: write each
    row's K/V at its own ring slot (``pos % ring``), freeze ``done`` rows
    to their old bytes, and attend by absolute position.

    The single authoritative implementation of the exactness-critical
    write/freeze/attend ordering, shared by the transformer window path
    and griffin's local-attention blocks.  cache: {"k": (B, ring, KV, hd),
    "v": ...}; k/v: (B, 1, KV, hd) this step's projections; the ring
    modulus is the cache length (>= window once the pool is padded, or
    shorter never-wrapping caches when max_len < window); ``window`` sets
    the attention band.  ``kernel`` selects the attend backend: None runs
    the jnp ``ring_slot_attend``, otherwise the Pallas
    ``ring_decode_attention`` kernel in that mode (auto / interpret /
    reference) reads the pool layout directly.  Returns
    (out (B, 1, H, hd_v), new_cache).
    """
    from repro.models.common import freeze_rows

    ring = cache["k"].shape[1]
    b_idx = jnp.arange(k.shape[0])
    slot_idx = slot_positions % ring
    ck = cache["k"].at[b_idx, slot_idx].set(k[:, 0].astype(cache["k"].dtype))
    cv = cache["v"].at[b_idx, slot_idx].set(v[:, 0].astype(cache["v"].dtype))
    new_cache = {"k": ck, "v": cv}
    if done is not None:
        # done rows' frozen (token, position) re-store identical bytes
        # anyway; the explicit freeze makes the no-op unconditional
        new_cache = freeze_rows(cache, new_cache, done)
    if kernel is not None:
        assert scale is None, "the ring kernel fixes scale at hd**-0.5"
        from repro.kernels import ops
        out = ops.ring_decode_attention(
            q[:, 0], new_cache["k"], new_cache["v"], slot_positions,
            window=window, done=done, mode=kernel)[:, None]
        return out, new_cache
    out = ring_slot_attend(q, new_cache["k"].astype(q.dtype),
                           new_cache["v"].astype(q.dtype), slot_positions,
                           window=window, scale=scale, done=done)
    return out, new_cache


def paged_gather(arena, bt):
    """Materialize a slot's dense cache view from a page arena.

    arena: (n_pages, page, ...) shared pages; bt: (B, nblk) int32 block
    table (page ids; ``n_pages`` is the OOB sentinel for never-allocated
    blocks).  Sentinels are CLAMPED to the last page — the garbage rows
    that produces are finite bytes at positions every caller masks away
    (per-row ``kv_len``, ring validity, or the verify band), so their
    softmax weight underflows to exactly 0.0.  Returns (B, nblk * page,
    ...) in the dense pool layout.
    """
    n_pages = arena.shape[0]
    g = arena[jnp.minimum(bt, n_pages - 1)]  # (B, nblk, page, ...)
    return g.reshape((bt.shape[0], -1) + arena.shape[2:])


def paged_ring_slot_update_attend(q, cache, k, v, slot_positions, *,
                                  window, done=None, scale=None,
                                  kernel=None):
    """``ring_slot_update_attend`` over a PAGED ring cache.

    cache: {"k": (n_pages, page, KV, hd), "v": ..., "bt": (B, nblk)} —
    the ring modulus is the logical length ``nblk * page`` and row ``b``'s
    ring slot ``s`` lives at ``arena[bt[b, s // page], s % page]``.  The
    write resolves its page through the block table; ``done`` rows (and
    rows whose block was never allocated) redirect to the page sentinel,
    where the scatter is dropped — the paged realization of the dense
    path's freeze-is-a-no-op-restore.  The attend runs either on a
    gathered dense view through the exactness-proven ``ring_slot_attend``
    (jnp) or through the paged Pallas kernel (``kernel`` mode string).
    """
    bt = cache["bt"]
    n_pages, page = cache["k"].shape[:2]
    ring = bt.shape[1] * page
    sidx = slot_positions % ring
    pid = jnp.take_along_axis(bt, (sidx // page)[:, None], axis=1)[:, 0]
    if done is not None:
        pid = jnp.where(done, n_pages, pid)
    off = sidx % page
    ck = cache["k"].at[pid, off].set(k[:, 0].astype(cache["k"].dtype),
                                     mode="drop")
    cv = cache["v"].at[pid, off].set(v[:, 0].astype(cache["v"].dtype),
                                     mode="drop")
    new_cache = {"k": ck, "v": cv, "bt": bt}
    if kernel is not None:
        assert scale is None, "the ring kernel fixes scale at hd**-0.5"
        from repro.kernels import ops
        out = ops.paged_ring_decode_attention(
            q[:, 0], ck, cv, bt, slot_positions, window=window, done=done,
            mode=kernel)[:, None]
        return out, new_cache
    out = ring_slot_attend(q, paged_gather(ck, bt).astype(q.dtype),
                           paged_gather(cv, bt).astype(q.dtype),
                           slot_positions, window=window, scale=scale,
                           done=done)
    return out, new_cache


def paged_ring_restore_sites(bt, positions, n_feed, chunk_len, page,
                             n_pages):
    """Scatter sites for the paged speculative ring ROLLBACK.

    The verify scan already wrote the whole chunk into the paged ring
    through the block table; commit must re-store the PRE-chunk bytes at
    every rejected write site (``j >= n_feed[b]``).  Returns
    (pid_restore, pid_read, off), each (B, chunk): ``pid_read`` is the
    clamped physical page to gather old bytes from, ``pid_restore``
    redirects accepted sites (and never-allocated blocks) to the page
    sentinel ``n_pages`` so their scatter drops, ``off`` is the in-page
    offset.  Requires ``chunk_len <= ring`` (the speculative pair probe
    enforces ``d + 1 <= window``) so no ring slot is written twice within
    one chunk.
    """
    ring = bt.shape[1] * page
    j = jnp.arange(chunk_len, dtype=positions.dtype)
    sidx = (positions[:, None] + j[None]) % ring  # (B, chunk)
    pid = jnp.take_along_axis(bt, sidx // page, axis=1)
    rejected = j[None] >= n_feed[:, None]
    pid_restore = jnp.where(rejected, pid, n_pages)
    pid_read = jnp.minimum(pid, n_pages - 1)
    return pid_restore, pid_read, sidx % page


def chunk_verify_kpos(offsets, cache_len, S, *, ring: bool):
    """Absolute key positions of [cache ‖ chunk] for the speculative
    verify: (B, cache_len + S) int32, -1 for unattendable cache entries.

    ``offsets`` (B,) is each row's chunk start (== its committed length):
    ring caches reconstruct per-slot positions from the ring invariant at
    that length; full-layout caches are valid on ``[0, offsets)`` and the
    tail (stale bytes of a longer previous tenant, or positions the row
    has not reached) is masked out.  Chunk key ``i`` sits at absolute
    position ``offsets + i``.
    """
    B = offsets.shape[0]
    if ring:
        kpos_cache = ring_positions_rows(offsets, cache_len)
    else:
        kpos_cache = jnp.broadcast_to(
            jnp.arange(cache_len, dtype=jnp.int32)[None], (B, cache_len))
        kpos_cache = jnp.where(kpos_cache < offsets[:, None], kpos_cache, -1)
    kpos_chunk = offsets[:, None] + jnp.arange(S, dtype=jnp.int32)[None]
    return jnp.concatenate([kpos_cache, kpos_chunk], axis=1)


def chunk_verify_mask(offsets, kpos, S, *, window=None, done=None):
    """(B, S, Sk) mask for the speculative verify chunk: query ``j`` (at
    absolute position ``offsets + j``) attends keys whose absolute
    position is in ``(qpos - window, qpos]`` and was ever written; rows
    flagged ``done`` attend nothing (their output is pinned to zeros by
    the caller, matching the idle-row slot semantics)."""
    qpos = offsets[:, None] + jnp.arange(S, dtype=jnp.int32)[None]
    m = (kpos[:, None, :] >= 0) & (kpos[:, None, :] <= qpos[:, :, None])
    if window is not None:
        m &= kpos[:, None, :] > qpos[:, :, None] - window
    if done is not None:
        m &= ~done[:, None, None]
    return m


def chunk_verify_attend(q, ck, cv, k, v, offsets, *, ring: bool,
                        window=None, done=None, scale=None,
                        logits_dtype=jnp.float32):
    """Speculative-verify attention: S chunk queries per row over
    [cache ‖ in-flight chunk], each row's chunk starting at its own
    absolute offset, WITHOUT writing the cache.

    q: (B, S, H, hd); ck/cv: (B, Sc, KV, hd) read-only cache (full-layout
    prefix or ring buffer); k/v: (B, S, KV, hd) the chunk's own K/V;
    offsets: (B,) committed length per row.  The cache stays untouched —
    ``commit_slots`` later scatters only the *accepted* chunk prefix, so
    speculative rollback is "never wrote it" rather than "undo it".
    Returns (B, S, H, hd_v); ``done`` rows return exact zeros.
    """
    B, S, H, hd = q.shape
    KV = ck.shape[2]
    if scale is None:
        scale = hd ** -0.5
    kpos = chunk_verify_kpos(offsets, ck.shape[1], S, ring=ring)
    mask = chunk_verify_mask(offsets, kpos, S, window=window, done=done)
    k_all = jnp.concatenate([ck.astype(q.dtype), k], axis=1)
    v_all = jnp.concatenate([cv.astype(q.dtype), v], axis=1)
    qg = q.reshape(B, S, KV, H // KV, hd)
    out = _sdpa(qg, k_all, v_all, mask, scale, logits_dtype)
    if done is not None:
        out = jnp.where(done[:, None, None, None, None], 0.0, out)
    return out.reshape(B, S, H, v_all.shape[-1])


def reference_attention(q, k, v, *, causal=True, window=None, kv_len=None,
                        scale=None):
    """Tiny-oracle full attention (tests only — materializes S×S)."""
    B, Sq, H, hd = q.shape
    KV = k.shape[2]
    if scale is None:
        scale = hd ** -0.5
    qg = q.reshape(B, Sq, KV, H // KV, hd)
    qpos = jnp.arange(Sq, dtype=jnp.int32)
    kpos = jnp.arange(k.shape[1], dtype=jnp.int32)
    mask = _band_mask(qpos, kpos, causal=causal, window=window, kv_len=kv_len)
    out = _sdpa(qg, k, v, mask, scale)
    return out.reshape(B, Sq, H, v.shape[-1])
