"""Shared building blocks: inits, norms, embeddings.

All models in the zoo are *functional*: params are plain nested dicts of
jnp arrays, stacked over the layer axis (leading ``L``) so that
``jax.lax.scan`` can run the block stack with O(1) HLO size, and so the
Mango growth operator can view the whole stack as one (B, I, O, L) tensor.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


# ---------------------------------------------------------------- init utils
def trunc_normal(key, shape, std=0.02, dtype=jnp.float32):
    return std * jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32).astype(dtype)


def keygen(key):
    """Infinite stream of fresh keys."""
    while True:
        key, sub = jax.random.split(key)
        yield sub


# --------------------------------------------------------------------- norms
def rms_norm(x, scale, eps=1e-6):
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32)).astype(dt)


def layer_norm(x, scale, bias, eps=1e-5):
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    y = y * scale.astype(jnp.float32)
    if bias is not None:
        y = y + bias.astype(jnp.float32)
    return y.astype(dt)


def apply_norm(x, p, kind, eps=1e-6):
    if kind == "rms":
        return rms_norm(x, p["scale"], eps)
    return layer_norm(x, p["scale"], p.get("bias"), eps)


def init_norm(kind, dim, layers=None, dtype=jnp.float32):
    shape = (dim,) if layers is None else (layers, dim)
    p = {"scale": jnp.ones(shape, dtype)}
    if kind == "ln":
        p["bias"] = jnp.zeros(shape, dtype)
    return p


# ----------------------------------------------------------------- misc math
def gelu(x):
    return jax.nn.gelu(x, approximate=True)


def pad_cache_len(n: int) -> int:
    """Kernel-friendly KV-cache sequence length (the TPU-layout pool).

    The Pallas decode kernels tile the cache axis in blocks that must
    divide it exactly (``kernels.decode_attention._pick_bk``), which a
    prime or awkward-odd ``max_len`` > 256 cannot satisfy.  Lengths above
    256 round up to a multiple of 64 — guaranteeing a block in [64, 256]
    — and short caches round up to the f32 sublane quantum (8).  Padding
    is invisible to the math: full layouts mask the tail behind per-row
    ``kv_len``, ring layouts take the padded length as their ring modulus
    (absolute-position masking makes a ring larger than the window
    attend identically).
    """
    q = 8 if n <= 256 else 64
    return -(-n // q) * q


def take_layer(stacked, i):
    """Slice layer ``i`` from every leaf of a stacked-params subtree."""
    return jax.tree.map(lambda a: a[i], stacked)


def slice_layers(stacked, start, stop):
    """Static sub-range of the layer axis on every leaf."""
    return jax.tree.map(lambda a: a[start:stop], stacked)


def freeze_rows(old, new, done):
    """Per-row cache freeze for the continuous-batching slot protocol.

    ``old``/``new`` are matching cache pytrees whose leaves lead with the
    batch (slot) axis; rows flagged in ``done`` (B,) keep their old state.
    Recurrent families need this explicitly — a recurrent update mutates
    state irreversibly, unlike a KV cache write that can re-store
    identical bytes as a no-op.
    """
    def per_leaf(o, n):
        mask = done.reshape(done.shape + (1,) * (n.ndim - 1))
        return jnp.where(mask, o, n)

    return jax.tree.map(per_leaf, old, new)


# ------------------------------------------- speculative-decode slot hooks
def spec_verify_scan(step_fn, params, tokens, positions, cache, cfg,
                     done=None, stack_filter=None):
    """Generic ``verify_step_slots`` for recurrent slot layouts.

    Scans the family's single-token ``decode_step_slots`` over the chunk
    axis, stacking the per-step slot state — the recurrent realization of
    the speculative verify: a recurrence has no one-shot parallel verify,
    but its per-slot state is O(1), so snapshotting it at EVERY chunk
    position is cheap and gives exact per-row rollback for free.  Because
    each step runs the very same (B, 1) slot-decode arithmetic as the
    sequential path, the logits (and the committed state, after
    ``spec_commit_gather``) are bit-identical to feeding the chunk token
    by token.

    ``stack_filter`` selects the sub-pytree of the cache to stack —
    families whose slot cache mixes O(1) recurrent leaves with larger
    ones (griffin's O(window) local-attention rings) must stack only the
    former and commit the rest via ``spec_ring_restore``; stacking a
    window-sized ring S times would multiply its memory by the chunk
    length.

    tokens: (B, S) chunk per slot; positions: (B,) per-row start offsets.
    Returns (logits (B, S, V), stacked, final): ``stacked`` mirrors the
    (filtered) cache pytree with a leading chunk axis — ``stacked[j]`` is
    the state after each row fed its first ``j + 1`` chunk tokens — and
    ``final`` is the full post-chunk cache.
    """
    def body(cache_c, xs):
        tok, j = xs
        logits, cache_n = step_fn(params, tok, positions + j, cache_c, cfg,
                                  done=done)
        ys = cache_n if stack_filter is None else stack_filter(cache_n)
        return cache_n, (logits, ys)

    steps = jnp.arange(tokens.shape[1], dtype=positions.dtype)
    final, (logits, stacked) = jax.lax.scan(body, cache, (tokens.T, steps))
    return jnp.swapaxes(logits, 0, 1), stacked, final


def spec_commit_gather(cache, stacked, n_feed, done=None):
    """Generic ``commit_slots`` for recurrent (O(1)-per-slot) leaves.

    Selects, per row, the stacked per-step state at the accepted boundary:
    row ``b`` gets ``stacked[n_feed[b] - 1]`` — the state after its first
    ``n_feed[b]`` chunk feeds — and rows with ``n_feed == 0`` (or flagged
    ``done``) keep their pre-chunk state untouched.  This is the
    snapshot/restore mirror of ``freeze_rows``: the rejected tail of the
    chunk never reaches the committed state because the gather simply
    predates it.
    """
    keep = n_feed <= 0
    if done is not None:
        keep = keep | done
    idx = jnp.maximum(n_feed - 1, 0)

    def per_leaf(old, st):
        # st: (S, L, B, ...) stacked states; old: (L, B, ...)
        B = old.shape[1]
        sel = jnp.take_along_axis(
            st, idx.reshape((1, 1, B) + (1,) * (old.ndim - 2)), axis=0)[0]
        mask = keep.reshape((1, B) + (1,) * (old.ndim - 2))
        return jnp.where(mask, old, sel)

    return jax.tree.map(per_leaf, cache, stacked)


def paged_spec_ring_restore(old, new, positions, n_feed, chunk_len):
    """``spec_ring_restore`` over a PAGED ring cache group.

    ``old``/``new`` are the same group dict before/after the verify scan:
    {"k"/"v": (layers, n_pages, page, ...) arenas, "bt": (layers, B,
    nblk)} — the scan wrote the whole chunk through the block table, so
    commit re-stores the pre-chunk arena bytes at every rejected write
    site (``j >= n_feed[b]``), resolved through the same table.  Sound
    because ring pages are slot-private (the prefix cache never aliases
    ring block tables) and ``chunk_len <= ring`` means no in-chunk
    double-write.  Accepted sites — and rows whose blocks were never
    allocated — redirect to the page sentinel and drop.
    """
    from repro.models.attention import paged_ring_restore_sites

    bt = old["bt"][0]  # layers share one table
    leaves = [k for k in old if k != "bt"]
    n_pages, page = old[leaves[0]].shape[1:3]
    pid_restore, pid_read, off = paged_ring_restore_sites(
        bt, positions, n_feed, chunk_len, page, n_pages)

    out = {"bt": old["bt"]}
    for key in leaves:
        def per_layer(o, n):
            src = o[pid_read, off]  # (B, chunk, ...) pre-chunk bytes
            return n.at[pid_restore, off].set(src, mode="drop")

        out[key] = jax.vmap(per_layer)(old[key], new[key])
    return out


def spec_ring_restore(old, new, positions, n_feed, chunk_len):
    """Commit ring-buffer leaves after a verify scan WITHOUT per-step
    stacking: keep the post-chunk bytes where the chunk write was
    accepted, restore the pre-chunk bytes where it was rejected.

    ``old``/``new`` are matching pytrees of (layers, B, ring, ...) ring
    caches before/after the scan; chunk index ``j`` wrote row ``b``'s
    slot ``(positions[b] + j) % ring`` and is rejected iff
    ``j >= n_feed[b]``.  Requires ``chunk_len <= ring`` (the speculative
    pair probe enforces ``d + 1 <= window``), so no ring slot is written
    twice within one chunk and accept/reject is per-slot unambiguous.
    """
    j = jnp.arange(chunk_len)

    def per_leaf(o, n):
        ring = o.shape[2]
        B = o.shape[1]
        wslot = (positions[:, None] + j[None]) % ring  # (B, chunk)
        rejected = j[None] >= n_feed[:, None]  # (B, chunk)
        restore = jnp.zeros((B, ring), bool).at[
            jnp.arange(B)[:, None], wslot].max(rejected)
        mask = restore.reshape((1, B, ring) + (1,) * (o.ndim - 3))
        return jnp.where(mask, o, n)

    return jax.tree.map(per_leaf, old, new)
