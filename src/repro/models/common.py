"""Shared building blocks: inits, norms, embeddings.

All models in the zoo are *functional*: params are plain nested dicts of
jnp arrays, stacked over the layer axis (leading ``L``) so that
``jax.lax.scan`` can run the block stack with O(1) HLO size, and so the
Mango growth operator can view the whole stack as one (B, I, O, L) tensor.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


# ---------------------------------------------------------------- init utils
def trunc_normal(key, shape, std=0.02, dtype=jnp.float32):
    return std * jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32).astype(dtype)


def keygen(key):
    """Infinite stream of fresh keys."""
    while True:
        key, sub = jax.random.split(key)
        yield sub


# --------------------------------------------------------------------- norms
def rms_norm(x, scale, eps=1e-6):
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32)).astype(dt)


def layer_norm(x, scale, bias, eps=1e-5):
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    y = y * scale.astype(jnp.float32)
    if bias is not None:
        y = y + bias.astype(jnp.float32)
    return y.astype(dt)


def apply_norm(x, p, kind, eps=1e-6):
    if kind == "rms":
        return rms_norm(x, p["scale"], eps)
    return layer_norm(x, p["scale"], p.get("bias"), eps)


def init_norm(kind, dim, layers=None, dtype=jnp.float32):
    shape = (dim,) if layers is None else (layers, dim)
    p = {"scale": jnp.ones(shape, dtype)}
    if kind == "ln":
        p["bias"] = jnp.zeros(shape, dtype)
    return p


# ----------------------------------------------------------------- misc math
def gelu(x):
    return jax.nn.gelu(x, approximate=True)


def take_layer(stacked, i):
    """Slice layer ``i`` from every leaf of a stacked-params subtree."""
    return jax.tree.map(lambda a: a[i], stacked)


def slice_layers(stacked, start, stop):
    """Static sub-range of the layer axis on every leaf."""
    return jax.tree.map(lambda a: a[start:stop], stacked)


def freeze_rows(old, new, done):
    """Per-row cache freeze for the continuous-batching slot protocol.

    ``old``/``new`` are matching cache pytrees whose leaves lead with the
    batch (slot) axis; rows flagged in ``done`` (B,) keep their old state.
    Recurrent families need this explicitly — a recurrent update mutates
    state irreversibly, unlike a KV cache write that can re-store
    identical bytes as a no-op.
    """
    def per_leaf(o, n):
        mask = done.reshape(done.shape + (1,) * (n.ndim - 1))
        return jnp.where(mask, o, n)

    return jax.tree.map(per_leaf, old, new)
