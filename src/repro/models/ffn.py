"""Feed-forward blocks: GELU MLP, SwiGLU / GeGLU gated MLPs."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.distributed.sharding import annotate
from repro.models.common import gelu


def mlp(x, p, act="swiglu"):
    """x: (B,S,D). p has w_up (D,F) [+ w_gate (D,F)], w_down (F,D), opt biases."""
    h = jnp.einsum("bsd,df->bsf", x, p["w_up"].astype(x.dtype))
    if "b_up" in p:
        h = h + p["b_up"].astype(x.dtype)
    if act in ("swiglu", "geglu"):
        g = jnp.einsum("bsd,df->bsf", x, p["w_gate"].astype(x.dtype))
        if "b_gate" in p:
            g = g + p["b_gate"].astype(x.dtype)
        g = jax.nn.silu(g) if act == "swiglu" else gelu(g)
        h = g * h
    else:
        h = gelu(h)
    h = annotate(h, ("batch", "seq", "mlp"))
    y = jnp.einsum("bsf,fd->bsd", h, p["w_down"].astype(x.dtype))
    if "b_down" in p:
        y = y + p["b_down"].astype(x.dtype)
    return y


def init_mlp(keys, d_model, d_ff, *, layers=None, act="swiglu", bias=False,
             dtype=jnp.float32, std=0.02):
    from repro.models.common import trunc_normal

    def shp(*s):
        return s if layers is None else (layers, *s)

    p = {
        "w_up": trunc_normal(next(keys), shp(d_model, d_ff), std, dtype),
        "w_down": trunc_normal(next(keys), shp(d_ff, d_model), std, dtype),
    }
    if act in ("swiglu", "geglu"):
        p["w_gate"] = trunc_normal(next(keys), shp(d_model, d_ff), std, dtype)
    if bias:
        p["b_up"] = jnp.zeros(shp(d_ff), dtype)
        p["b_down"] = jnp.zeros(shp(d_model), dtype)
        if act in ("swiglu", "geglu"):
            p["b_gate"] = jnp.zeros(shp(d_ff), dtype)
    return p


def mlp_specs(act="swiglu", bias=False, layers=True):
    L = ("layers",) if layers else ()
    s = {
        "w_up": L + ("embed", "mlp"),
        "w_down": L + ("mlp", "embed"),
    }
    if act in ("swiglu", "geglu"):
        s["w_gate"] = L + ("embed", "mlp")
    if bias:
        s["b_up"] = L + ("mlp",)
        s["b_down"] = L + ("embed",)
        if act in ("swiglu", "geglu"):
            s["b_gate"] = L + ("mlp",)
    return s
