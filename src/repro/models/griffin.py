"""Griffin / RecurrentGemma family (arXiv:2402.19427).

Block pattern 2 recurrent : 1 local-MQA-attention.  The recurrent temporal
block is: linear → causal depthwise conv(4) → RG-LRU, gated by a parallel
GeLU branch.  RG-LRU:

    r_t = sigmoid(W_a y_t + b_a)          (recurrence gate)
    i_t = sigmoid(W_i y_t + b_i)          (input gate)
    log a_t = -c * softplus(Λ) * r_t      (c = 8)
    h_t = a_t h_{t-1} + sqrt(1 - a_t^2) * (i_t * y_t)

Training/prefill runs the recurrence as a parallel prefix
(``lax.associative_scan``) — the jnp lowering analogue of the paper's custom
scan kernel; ``repro/kernels/rglru_scan.py`` is the Pallas TPU version.
Decode keeps O(1) state per layer: (h, conv tail) — this is why this arch
runs the ``long_500k`` cell.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.distributed.sharding import annotate
from repro.models import attention as attn_lib
from repro.models import ffn as ffn_lib
from repro.models.common import (
    apply_norm,
    freeze_rows,
    gelu,
    init_norm,
    keygen,
    trunc_normal,
)
from repro.models.rope import apply_rope

C_RGLRU = 8.0


def block_pattern(cfg):
    if cfg.block_pattern:
        return cfg.block_pattern
    # default recurrentgemma pattern: (rec, rec, attn) repeating
    pat = []
    for i in range(cfg.n_layers):
        pat.append("attn" if i % 3 == 2 else "rec")
    return tuple(pat)


# ------------------------------------------------------------------- init
def init(rng, cfg) -> dict:
    keys = keygen(rng)
    dtype = jnp.dtype(cfg.param_dtype)
    std = 0.02
    D, W = cfg.d_model, cfg.lru_width
    pat = block_pattern(cfg)
    n_rec = sum(1 for t in pat if t == "rec")
    n_attn = len(pat) - n_rec
    H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim

    def shp(n, *s):
        return (n, *s)

    params: dict[str, Any] = {
        "embed": trunc_normal(next(keys), (cfg.vocab_size, D), std, dtype),
    }
    params["rec_blocks"] = {
        "ln1": init_norm(cfg.norm, D, n_rec, dtype),
        "ln2": init_norm(cfg.norm, D, n_rec, dtype),
        "w_x": trunc_normal(next(keys), shp(n_rec, D, W), std, dtype),
        "w_gate": trunc_normal(next(keys), shp(n_rec, D, W), std, dtype),
        "w_out": trunc_normal(next(keys), shp(n_rec, W, D), std, dtype),
        "conv_w": trunc_normal(next(keys), shp(n_rec, cfg.conv_width, W),
                               std, dtype),
        "conv_b": jnp.zeros(shp(n_rec, W), dtype),
        # RG-LRU gate projections are block-diagonal with n_heads blocks
        # (recurrentgemma's BlockDiagonalLinear)
        "w_a": trunc_normal(next(keys), shp(n_rec, H, W // H, W // H), std,
                            dtype),
        "b_a": jnp.zeros(shp(n_rec, W), dtype),
        "w_i": trunc_normal(next(keys), shp(n_rec, H, W // H, W // H), std,
                            dtype),
        "b_i": jnp.zeros(shp(n_rec, W), dtype),
        # Λ init so that a spans ~(0.9, 0.999) as in the paper
        "lam": jnp.asarray(
            jax.random.uniform(next(keys), (n_rec, W), jnp.float32,
                               0.0, 1.0) * 0.5 + 0.2, dtype),
        "mlp": ffn_lib.init_mlp(keys, D, cfg.d_ff, layers=n_rec, act=cfg.act,
                                dtype=dtype, std=std),
    }
    if n_attn:
        params["attn_blocks"] = {
            "ln1": init_norm(cfg.norm, D, n_attn, dtype),
            "ln2": init_norm(cfg.norm, D, n_attn, dtype),
            "wq": trunc_normal(next(keys), shp(n_attn, D, H * hd), std, dtype),
            "wk": trunc_normal(next(keys), shp(n_attn, D, KV * hd), std, dtype),
            "wv": trunc_normal(next(keys), shp(n_attn, D, KV * hd), std, dtype),
            "wo": trunc_normal(next(keys), shp(n_attn, H * hd, D), std, dtype),
            "mlp": ffn_lib.init_mlp(keys, D, cfg.d_ff, layers=n_attn,
                                    act=cfg.act, dtype=dtype, std=std),
        }
    params["final_norm"] = init_norm(cfg.norm, D, None, dtype)
    if not cfg.tie_embeddings:
        params["head"] = trunc_normal(next(keys), (D, cfg.vocab_size), std,
                                      dtype)
    return params


# ------------------------------------------------------------------ RG-LRU
def _block_diag(yf, w):
    """Block-diagonal linear: yf (B,S,W), w (H, W/H, W/H) -> (B,S,W)."""
    B, S, W = yf.shape
    H = w.shape[0]
    yh = yf.reshape(B, S, H, W // H)
    out = jnp.einsum("bshw,hwv->bshv", yh, w.astype(yf.dtype))
    return out.reshape(B, S, W)


def _rglru_gates(y, bp):
    """y: (B,S,W) post-conv activations -> (log_a, x_scaled) both f32."""
    yf = y.astype(jnp.float32)
    r = jax.nn.sigmoid(
        _block_diag(yf, bp["w_a"]) + bp["b_a"].astype(jnp.float32))
    i = jax.nn.sigmoid(
        _block_diag(yf, bp["w_i"]) + bp["b_i"].astype(jnp.float32))
    log_a = -C_RGLRU * jax.nn.softplus(bp["lam"].astype(jnp.float32)) * r
    a = jnp.exp(log_a)
    gated = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12)) * (i * yf)
    return log_a, gated


def rglru_parallel(y, bp, h0=None, valid=None):
    """Parallel-prefix RG-LRU over the sequence. y: (B,S,W).

    ``h0``: optional (B,W) f32 initial state (multi-token prefill into an
    existing cache): h_t = (prod a_{0..t}) h0 + scan_t.  ``valid``:
    optional (B,S) bool — invalid positions are frozen to the identity
    element (a=1, b=0), so the recurrence carries h across the padded
    tails of bucketed admission prompts unchanged and the final state is
    exactly h_{plen-1}.  Returns (h (B,S,W) in y.dtype, h_last (B,W) f32).
    """
    log_a, b = _rglru_gates(y, bp)
    if valid is not None:
        log_a = jnp.where(valid[..., None], log_a, 0.0)
        b = jnp.where(valid[..., None], b, 0.0)
    a = jnp.exp(log_a)

    def op(e1, e2):
        a1, b1 = e1
        a2, b2 = e2
        return a1 * a2, a2 * b1 + b2

    prod_a, h = jax.lax.associative_scan(op, (a, b), axis=1)
    if h0 is not None:
        h = h + prod_a * h0[:, None]
    return h.astype(y.dtype), h[:, -1]


def rglru_step(y, h_prev, bp):
    """Single-step RG-LRU. y: (B,1,W); h_prev: (B,W) f32."""
    log_a, b = _rglru_gates(y, bp)
    h = jnp.exp(log_a[:, 0]) * h_prev + b[:, 0]
    return h.astype(y.dtype)[:, None], h


def _causal_conv(y, w, b, state=None, lengths=None):
    """Depthwise causal conv. y: (B,S,W); w: (K,W); state: (B,K-1,W)|None.

    ``lengths`` (B,): per-row true sequence lengths — the returned conv
    tail is then gathered at each row's own boundary (bucketed admission
    prompts are tail-padded, and the state handed to decode must be the
    last K-1 REAL inputs, not the padding).
    """
    K = w.shape[0]
    if state is None:
        ypad = jnp.pad(y, ((0, 0), (K - 1, 0), (0, 0)))
    else:
        ypad = jnp.concatenate([state.astype(y.dtype), y], axis=1)
    out = sum(
        ypad[:, k:k + y.shape[1]] * w[k].astype(y.dtype) for k in range(K)
    ) + b.astype(y.dtype)
    if K == 1:
        new_state = None
    elif lengths is None:
        new_state = ypad[:, -(K - 1):]
    else:
        # ypad index of position t is t + (K-1): row b's tail covers
        # positions lengths[b]-(K-1) .. lengths[b]-1 -> ypad rows
        # lengths[b] .. lengths[b]+K-2 (identical to the static slice
        # when lengths[b] == S)
        idx = (lengths[:, None] + jnp.arange(K - 1)[None])[..., None]
        new_state = jnp.take_along_axis(ypad, idx, axis=1)
    return out, new_state


def _rec_temporal(x, bp, cfg, conv_state=None, h_state=None, plens=None):
    """Recurrent temporal block. Returns (out, new_conv_state, new_h).

    Single-token cached steps take the O(1) recurrence; every multi-token
    call (training, prefill — with or without an initial state) runs the
    parallel prefix.  ``plens`` marks a bucketed admission prefill: pad
    positions freeze the RG-LRU to identity and the conv tail is gathered
    at each row's true boundary.
    """
    y = jnp.einsum("bsd,dw->bsw", x, bp["w_x"].astype(x.dtype))
    g = gelu(jnp.einsum("bsd,dw->bsw", x, bp["w_gate"].astype(x.dtype)))
    y = annotate(y, ("batch", "seq", "lru"))
    y, new_conv = _causal_conv(y, bp["conv_w"], bp["conv_b"], conv_state,
                               lengths=plens)
    if h_state is not None and y.shape[1] == 1:
        h, new_h = rglru_step(y, h_state, bp)
    else:
        valid = None
        if plens is not None:
            valid = jnp.arange(y.shape[1])[None] < plens[:, None]
        h, new_h = rglru_parallel(y, bp, h0=h_state, valid=valid)
    out = jnp.einsum("bsw,wd->bsd", h * g, bp["w_out"].astype(x.dtype))
    return out, new_conv, new_h


# ------------------------------------------------------------------ blocks
def _rec_block(x, bp, cfg, cache=None, plens=None, done=None):
    h, new_conv, new_h = _rec_temporal(
        apply_norm(x, bp["ln1"], cfg.norm), bp, cfg,
        conv_state=None if cache is None else cache["conv"],
        h_state=None if cache is None else cache["h"],
        plens=plens)
    x = x + h
    x = x + ffn_lib.mlp(apply_norm(x, bp["ln2"], cfg.norm), bp["mlp"],
                        cfg.act)
    x = annotate(x, ("batch", "seq", "embed"))
    nc = None
    if cache is not None:
        nc = {"conv": new_conv, "h": new_h}
        if done is not None:
            nc = freeze_rows(cache, nc, done)
    return x, nc


def _attn_block(x, bp, cfg, positions, cache=None, q_offset=0,
                slot_positions=None, slot_done=None, plens=None):
    from repro.models import transformer as tf

    xin = apply_norm(x, bp["ln1"], cfg.norm)
    q = xin @ bp["wq"].astype(x.dtype)
    k = xin @ bp["wk"].astype(x.dtype)
    v = xin @ bp["wv"].astype(x.dtype)
    B, S, _ = x.shape
    q = q.reshape(B, S, cfg.n_heads, cfg.head_dim)
    k = k.reshape(B, S, cfg.n_kv_heads, cfg.head_dim)
    v = v.reshape(B, S, cfg.n_kv_heads, cfg.head_dim)
    q = apply_rope(q, positions, theta=cfg.rope_theta)
    k = apply_rope(k, positions, theta=cfg.rope_theta)
    nc = None
    if slot_positions is not None:
        # continuous-batching decode: every row is a slot at its own
        # length — write this step's K/V at the row's own ring slot and
        # attend by absolute position (the slot mirror of the S == 1
        # path); ``cfg.decode_kernel`` routes the attend through the
        # Pallas ring kernel.  A paged pool routes the write through the
        # row's block table instead of a private ring row.
        update = (attn_lib.paged_ring_slot_update_attend
                  if "bt" in cache else attn_lib.ring_slot_update_attend)
        out, nc = update(
            q, cache, k, v, slot_positions, window=cfg.window,
            done=slot_done, kernel=tf._kernel_mode(cfg))
    elif cache is not None:
        ck, cv = cache["k"], cache["v"]
        window = cfg.window
        ring = ck.shape[1]  # the ring modulus (>= window once padded)
        if plens is not None and S > 1:
            # bucketed admission prefill: fill each row's ring from its
            # TRUE prompt length by absolute position
            ck = attn_lib.ring_fill_rows(k, plens, ring, ck.dtype)
            cv = attn_lib.ring_fill_rows(v, plens, ring, cv.dtype)
            nc = {"k": ck, "v": cv}
            out = attn_lib.attention(q, k, v, causal=True, window=window,
                                     q_offset=q_offset,
                                     chunk_q=cfg.attn_chunk,
                                     unroll=cfg.unroll_scans)
        else:
            w_eff = min(S, ring)
            idx = (q_offset + S - w_eff + jnp.arange(w_eff)) % ring
            ck = ck.at[:, idx].set(k[:, -w_eff:].astype(ck.dtype))
            cv = cv.at[:, idx].set(v[:, -w_eff:].astype(cv.dtype))
            nc = {"k": ck, "v": cv}
            if S == 1:
                kpos_abs = tf._ring_positions(q_offset + S, ring)
                out = tf._ring_window_attend(q, ck.astype(x.dtype),
                                             cv.astype(x.dtype), kpos_abs,
                                             q_offset, cfg)
            else:
                out = attn_lib.attention(q, k, v, causal=True,
                                         window=cfg.window,
                                         q_offset=q_offset,
                                         chunk_q=cfg.attn_chunk,
                                         unroll=cfg.unroll_scans)
    else:
        out = attn_lib.attention(q, k, v, causal=True, window=cfg.window,
                                 q_offset=q_offset, chunk_q=cfg.attn_chunk,
                                 unroll=cfg.unroll_scans)
    out = out.reshape(B, S, -1)
    x = x + out @ bp["wo"].astype(x.dtype)
    x = x + ffn_lib.mlp(apply_norm(x, bp["ln2"], cfg.norm), bp["mlp"],
                        cfg.act)
    x = annotate(x, ("batch", "seq", "embed"))
    return x, nc


def _pattern_runs(pat):
    """[(type, start_idx_within_type, count), ...] contiguous runs."""
    runs = []
    counts = {"rec": 0, "attn": 0}
    i = 0
    while i < len(pat):
        j = i
        while j < len(pat) and pat[j] == pat[i]:
            j += 1
        runs.append((pat[i], counts[pat[i]], j - i))
        counts[pat[i]] += j - i
        i = j
    return runs


# ----------------------------------------------------------------- forward
def forward(params, batch, cfg):
    cdt = jnp.dtype(cfg.compute_dtype)
    x = params["embed"].astype(cdt)[batch["tokens"]]
    if cfg.scale_embeddings:
        x = x * jnp.asarray(cfg.d_model ** 0.5, cdt)
    B, S = x.shape[:2]
    positions = batch.get("positions")
    if positions is None:
        positions = jnp.broadcast_to(
            jnp.arange(S, dtype=jnp.int32)[None], (B, S))

    x = _run_blocks(params, x, cfg, positions)
    x = apply_norm(x, params["final_norm"], cfg.norm)
    w = params["embed"].T if cfg.tie_embeddings else params["head"]
    logits = jnp.einsum("bsd,dv->bsv", x, w.astype(cdt))
    return annotate(logits, ("batch", "seq", "vocab")), {"moe_aux": 0.0}


def _run_blocks(params, x, cfg, positions, caches=None, q_offset=0,
                plens=None, slot_positions=None, slot_done=None):
    from repro.models.common import slice_layers, take_layer

    pat = block_pattern(cfg)
    new_caches = {"rec": [], "attn": []} if caches is not None else None
    for typ, start, count in _pattern_runs(pat):
        if typ == "rec":
            group = slice_layers(params["rec_blocks"], start, start + count)

            def body(carry, xs):
                xc = carry
                bp, cache_l = xs if caches is not None else (xs, None)
                xc, nc = _rec_block(xc, bp, cfg, cache=cache_l, plens=plens,
                                    done=slot_done)
                return xc, nc

            if cfg.remat == "block":
                body = jax.remat(body, prevent_cse=False)
            xs = group
            if caches is not None:
                xs = (group, slice_layers(caches["rec"], start, start + count))
            x, ncs = jax.lax.scan(body, x, xs, unroll=cfg.unroll_scans)
            if caches is not None:
                new_caches["rec"].append(ncs)
        else:
            for k in range(count):
                bp = take_layer(params["attn_blocks"], start + k)
                cache_l = (take_layer(caches["attn"], start + k)
                           if caches is not None else None)
                fn = _attn_block
                if cfg.remat == "block" and caches is None:
                    fn = jax.remat(_attn_block, static_argnums=(2,),
                                   prevent_cse=False)
                x, nc = fn(x, bp, cfg, positions, cache_l, q_offset,
                           slot_positions=slot_positions,
                           slot_done=slot_done, plens=plens)
                if caches is not None:
                    new_caches["attn"].append(
                        jax.tree.map(lambda a: a[None], nc))
    if caches is not None:
        out = {}
        out["rec"] = jax.tree.map(
            lambda *xs: jnp.concatenate(xs, 0), *new_caches["rec"])
        if new_caches["attn"]:
            out["attn"] = jax.tree.map(
                lambda *xs: jnp.concatenate(xs, 0), *new_caches["attn"])
        return x, out
    return x


# -------------------------------------------------------------------- serve
def init_cache(cfg, batch_size, max_len, dtype=None):
    dtype = dtype or jnp.dtype(cfg.compute_dtype)
    pat = block_pattern(cfg)
    n_rec = sum(1 for t in pat if t == "rec")
    n_attn = len(pat) - n_rec
    from repro.models.common import pad_cache_len
    wlen = pad_cache_len(min(max_len, cfg.window or max_len))
    cache = {
        "rec": {
            "conv": jnp.zeros((n_rec, batch_size, cfg.conv_width - 1,
                               cfg.lru_width), dtype),
            "h": jnp.zeros((n_rec, batch_size, cfg.lru_width), jnp.float32),
        }
    }
    if n_attn:
        cache["attn"] = {
            "k": jnp.zeros((n_attn, batch_size, wlen, cfg.n_kv_heads,
                            cfg.head_dim), dtype),
            "v": jnp.zeros((n_attn, batch_size, wlen, cfg.n_kv_heads,
                            cfg.head_dim), dtype),
        }
    return cache


def _forward_cached(params, batch, cfg, cache, q_offset, plens=None):
    cdt = jnp.dtype(cfg.compute_dtype)
    x = params["embed"].astype(cdt)[batch["tokens"]]
    if cfg.scale_embeddings:
        x = x * jnp.asarray(cfg.d_model ** 0.5, cdt)
    B, S = x.shape[:2]
    positions = q_offset + jnp.arange(S, dtype=jnp.int32)[None]
    positions = jnp.broadcast_to(positions, (B, S))
    x, new_cache = _run_blocks(params, x, cfg, positions, caches=cache,
                               q_offset=q_offset, plens=plens)
    x = apply_norm(x, params["final_norm"], cfg.norm)
    w = params["embed"].T if cfg.tie_embeddings else params["head"]
    return jnp.einsum("bsd,dv->bsv", x, w.astype(cdt)), new_cache


def prefill(params, batch, cfg, cache):
    logits, cache = _forward_cached(params, batch, cfg, cache, 0)
    return logits[:, -1], cache


def decode_step(params, tokens, pos, cache, cfg):
    logits, cache = _forward_cached(
        params, {"tokens": tokens[:, None]}, cfg, cache, pos)
    return logits[:, -1], cache


def prefill_full(params, batch, cfg, cache):
    """Admission prefill: logits at EVERY position + per-row final state.

    ``batch["plens"]`` (B,) carries each row's true prompt length: RG-LRU
    pad positions freeze to identity, conv tails are gathered at the row
    boundary, and ring window caches are filled per row by absolute
    position — so the returned cache is exactly the state after each
    row's REAL prompt, tail padding notwithstanding.
    """
    plens = batch.get("plens")
    batch = {k: v for k, v in batch.items() if k != "plens"}
    return _forward_cached(params, batch, cfg, cache, 0, plens=plens)


def decode_step_slots(params, tokens, positions, cache, cfg, done=None):
    """Continuous-batching decode: one token per slot at per-slot lengths.

    tokens/positions: (B,) — each row's last token and current length.
    ``done`` rows FREEZE their recurrent state (conv tails, RG-LRU h —
    a recurrent update is irreversible, unlike a KV re-store) and their
    ring slots keep their old bytes; live rows advance the O(1)
    recurrence and write their ring slot at ``pos % ring``.
    Returns (logits (B, V), new_cache).
    """
    cdt = jnp.dtype(cfg.compute_dtype)
    x = params["embed"].astype(cdt)[tokens[:, None]]
    if cfg.scale_embeddings:
        x = x * jnp.asarray(cfg.d_model ** 0.5, cdt)
    x, new_cache = _run_blocks(params, x, cfg, positions[:, None],
                               caches=cache, slot_positions=positions,
                               slot_done=done)
    x = apply_norm(x, params["final_norm"], cfg.norm)
    w = params["embed"].T if cfg.tie_embeddings else params["head"]
    logits = jnp.einsum("bsd,dv->bsv", x, w.astype(cdt))
    return logits[:, -1], new_cache


def verify_step_slots(params, tokens, positions, cache, cfg, done=None):
    """Speculative verify for the recurrent slot layout: one fused scan of
    the single-token slot decode over the chunk.  Only the genuinely O(1)
    recurrent state (rglru h, conv tails) is stacked per chunk position;
    the O(window) local-attention rings are NOT — they commit through an
    accept-masked restore instead, so verify memory stays O(state +
    window), not O(chunk * window).  Bit-identical to sequential decode
    by construction — each scan step runs the same (B, 1) arithmetic as
    the macro decode loop.
    """
    from repro.models.common import spec_verify_scan
    logits, stacked, final = spec_verify_scan(
        decode_step_slots, params, tokens, positions, cache, cfg,
        done=done, stack_filter=lambda c: {"rec": c["rec"]})
    pending = {"rec": stacked["rec"]}
    if "attn" in cache:
        pending["attn_new"] = final["attn"]
    return logits, pending


def commit_slots(params, tokens, positions, n_feed, cache, pending, cfg,
                 done=None):
    """Commit per leaf kind: recurrent state gathers the stacked verify
    snapshots at ``n_feed - 1`` per row (the ``freeze_rows``-style
    snapshot/restore a recurrence needs — its updates cannot be
    re-stored); local-attention rings keep the scan's accepted writes and
    restore pre-chunk bytes at rejected slots.  Rows with ``n_feed == 0``
    or flagged ``done`` keep their pre-chunk state wholesale."""
    from repro.models.common import (
        paged_spec_ring_restore,
        spec_commit_gather,
        spec_ring_restore,
    )
    del params
    if done is not None:
        n_feed = jnp.where(done, 0, n_feed)
    out = {"rec": spec_commit_gather(cache["rec"], pending["rec"], n_feed)}
    if "attn" in cache:
        restore = (paged_spec_ring_restore if "bt" in cache["attn"]
                   else spec_ring_restore)
        out["attn"] = restore(cache["attn"], pending["attn_new"],
                              positions, n_feed, tokens.shape[1])
    return out


def serve_supported(cfg):
    """Capability probe for the continuous-batching slot-decode protocol."""
    pat = block_pattern(cfg)
    has_attn = any(t == "attn" for t in pat)
    if has_attn and not cfg.window:
        return False, "griffin local-attention blocks require cfg.window"
    detail = "recurrent state (O(1) per slot: rglru h + conv tail)"
    if has_attn:
        detail += " + ring-buffer window KV (O(window) per slot)"
    return True, detail


def slot_cache_layout(cfg):
    has_attn = any(t == "attn" for t in block_pattern(cfg))
    if not has_attn:
        return "recurrent"
    if cfg.decode_kernel != "jnp":
        return "recurrent+ring+kernel"
    return "recurrent+ring"


def paged_groups(cfg):
    """Slot-state protocol: the local-attention ring K/V pages; the
    recurrent group (rglru h + conv tail, O(1)/slot) stays dense — there
    is no sequence axis to page and the state is already minimal."""
    if any(t == "attn" for t in block_pattern(cfg)):
        return {"attn": ("seq", ("k", "v"))}
    return {}


def cache_specs(cfg):
    pat = block_pattern(cfg)
    n_attn = sum(1 for t in pat if t == "attn")
    c = {"rec": {
        "conv": ("layers", "batch", None, "lru"),
        "h": ("layers", "batch", "lru"),
    }}
    if n_attn:
        c["attn"] = {"k": ("layers", "batch", "cache_seq", "kv_heads", "head_dim"),
                     "v": ("layers", "batch", "cache_seq", "kv_heads", "head_dim")}
    return c


# -------------------------------------------------------------- param specs
def param_specs(cfg):
    pat = block_pattern(cfg)
    n_attn = sum(1 for t in pat if t == "attn")
    L = ("layers",)
    rec = {
        "ln1": {"scale": L + ("embed",)},
        "ln2": {"scale": L + ("embed",)},
        "w_x": L + ("embed", "lru"),
        "w_gate": L + ("embed", "lru"),
        "w_out": L + ("lru", "embed"),
        "conv_w": L + (None, "lru"),
        "conv_b": L + ("lru",),
        "w_a": L + (None, None, None),
        "b_a": L + ("lru",),
        "w_i": L + (None, None, None),
        "b_i": L + ("lru",),
        "lam": L + ("lru",),
        "mlp": ffn_lib.mlp_specs(cfg.act, False),
    }
    specs = {"embed": ("vocab", "embed"), "rec_blocks": rec,
             "final_norm": {"scale": ("embed",)}}
    if n_attn:
        specs["attn_blocks"] = {
            "ln1": {"scale": L + ("embed",)},
            "ln2": {"scale": L + ("embed",)},
            "wq": L + ("embed", "heads"),
            "wk": L + ("embed", "kv_heads"),
            "wv": L + ("embed", "kv_heads"),
            "wo": L + ("heads", "embed"),
            "mlp": ffn_lib.mlp_specs(cfg.act, False),
        }
    if not cfg.tie_embeddings:
        specs["head"] = ("embed", "vocab")
    return specs
