"""Mixture-of-Experts with GSPMD-style capacity dispatch.

Token-choice top-k routing realized as the classic one-hot
dispatch/combine einsum formulation (GShard/Switch, arXiv:2006.16668): the
expert axis is sharded over the ``model`` mesh axis (expert parallelism) and
the partitioner inserts the all-to-alls on the (groups, experts, capacity, d)
dispatched tensor automatically.  Memory of the dispatch tensors is
O(tokens * E * C / (dp * ep)) per device — checked against v5e HBM in the
roofline report.

Supports: softmax top-k (Switch/Mixtral/phi-3.5-MoE) and sigmoid scoring with
top-k renormalization + shared experts (DeepSeek-V3, arXiv:2412.19437),
auxiliary load-balance loss, capacity-factor token dropping.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.distributed.sharding import annotate
from repro.models.common import trunc_normal


def router(x, w_router, *, top_k, score="softmax", n_groups=1):
    """x: (B,S,D) -> (weights (B,S,K) f32, idx (B,S,K) i32, aux_loss f32)."""
    logits = jnp.einsum(
        "bsd,de->bse", x.astype(jnp.float32), w_router.astype(jnp.float32)
    )
    E = logits.shape[-1]
    if score == "softmax":
        probs = jax.nn.softmax(logits, axis=-1)
        w, idx = jax.lax.top_k(probs, top_k)
        w = w / jnp.clip(w.sum(-1, keepdims=True), 1e-9)
    else:  # sigmoid scoring (DeepSeek-V3); weights renormalized over top-k
        scores = jax.nn.sigmoid(logits)
        w, idx = jax.lax.top_k(scores, top_k)
        w = w / jnp.clip(w.sum(-1, keepdims=True), 1e-9)
        probs = scores / jnp.clip(scores.sum(-1, keepdims=True), 1e-9)
    # Switch aux loss: E * sum_e f_e * p_e   (f = token fraction, p = prob mass)
    one_hot = jax.nn.one_hot(idx, E, dtype=jnp.float32)  # (B,S,K,E)
    f = one_hot.sum(2).mean((0, 1))  # (E,) fraction routed (pre-capacity)
    p = probs.mean((0, 1))
    aux = E * jnp.sum(f * p) / top_k
    return w, idx, aux


def dispatch_combine(weights, idx, n_experts, capacity,
                     dtype=jnp.float32):
    """Build dispatch (bool) and combine tensors, (B,S,E,C) in ``dtype``.

    Position-in-expert via cumulative sum over the flattened (S) token axis
    per batch group (groups == batch rows), tokens over capacity are dropped
    (standard capacity-factor semantics).
    """
    B, S, K = idx.shape
    onehot = jax.nn.one_hot(idx, n_experts, dtype=jnp.float32)  # (B,S,K,E)
    # NOTE: position-in-expert cumsum stays f32 (exact small integers);
    # the big (B,S,E,C) one-hots downstream may be cast via
    # cfg.moe_dispatch_dtype (bf16 holds integers < 257 exactly, and
    # capacities here are < 2^8, so bf16 dispatch is lossless for disp and
    # rounds only combine *weights*).
    # priority: lower k first, then earlier tokens
    flat = onehot.transpose(0, 2, 1, 3).reshape(B, K * S, n_experts)
    pos = jnp.cumsum(flat, axis=1) - flat  # (B, K*S, E) position in expert
    pos = pos.reshape(B, K, S, n_experts).transpose(0, 2, 1, 3)  # (B,S,K,E)
    keep = (pos < capacity) * onehot
    # a token routes to a given expert at most once => reduce over K *before*
    # expanding the capacity one-hot (keeps peak tensor at (B,S,E,C), never
    # (B,S,K,E,C)).
    keep_e = keep.sum(2)  # (B,S,E) in {0,1}
    pos_e = (pos * keep).sum(2)  # (B,S,E)
    w_e = (weights[..., None] * keep).sum(2)  # (B,S,E)
    cap_oh = jax.nn.one_hot(pos_e.astype(jnp.int32), capacity,
                            dtype=dtype)  # (B,S,E,C)
    disp = keep_e[..., None].astype(dtype) * cap_oh
    comb = w_e[..., None].astype(dtype) * cap_oh
    return disp, comb


MOE_GROUP_SIZE = 512  # dispatch-group tokens (GShard-style): bounds the
#                       (G, S_g, E, C) one-hot at S_g^2 * K * cf per group


def moe_mlp(x, p, cfg):
    """Routed-experts MLP.  x: (B,S,D).

    Tokens are re-grouped into dispatch groups of ``MOE_GROUP_SIZE`` before
    the capacity one-hot is built: the dispatch/combine tensors are then
    (G, S_g, E, C) with C = S_g*K/E*cf, i.e. O(S_g * K * cf) per token
    instead of O(S * K * cf) — the difference between 10s of GB and 10s of
    TB at deepseek scale.  Capacity (and dropping) applies per group, the
    standard GShard/Switch semantics.

    p: w_router (D,E); experts: w_up/w_gate (E,D,F), w_down (E,F,D);
       optional shared expert: shared_w_up/gate/down (D,Fs)/(Fs,D).
    Returns (y, aux_loss).
    """
    B, S, D = x.shape
    E, K = cfg.n_experts, cfg.top_k
    weights, idx, aux = router(
        x, p["w_router"], top_k=K, score=cfg.router_score
    )
    sg = min(MOE_GROUP_SIZE, S) if S > 1 else 1
    assert S % sg == 0, (S, sg)
    G = B * (S // sg)
    xg = x.reshape(G, sg, D)
    wg = weights.reshape(G, sg, K)
    ig = idx.reshape(G, sg, K)

    capacity = max(int(sg * K / E * cfg.capacity_factor), 1)
    ddt = jnp.dtype(cfg.moe_dispatch_dtype)
    disp, comb = dispatch_combine(wg, ig, E, capacity, dtype=ddt)
    disp = annotate(disp.astype(x.dtype),
                    ("moe_group", "seq", "experts", None))
    comb = annotate(comb.astype(ddt),
                    ("moe_group", "seq", "experts", None))

    xe = jnp.einsum("gsec,gsd->gecd", disp, xg)
    xe = annotate(xe, ("moe_group", "experts", None, "embed"))
    h = jnp.einsum("gecd,edf->gecf", xe, p["w_up"].astype(x.dtype))
    g = jnp.einsum("gecd,edf->gecf", xe, p["w_gate"].astype(x.dtype))
    h = jax.nn.silu(g) * h
    ye = jnp.einsum("gecf,efd->gecd", h, p["w_down"].astype(x.dtype))
    ye = annotate(ye, ("moe_group", "experts", None, "embed"))
    y = jnp.einsum("gsec,gecd->gsd", comb.astype(x.dtype), ye)
    y = y.reshape(B, S, D)

    if "shared_w_up" in p:
        hs = jnp.einsum("bsd,df->bsf", x, p["shared_w_up"].astype(x.dtype))
        gs = jnp.einsum("bsd,df->bsf", x, p["shared_w_gate"].astype(x.dtype))
        y = y + jnp.einsum(
            "bsf,fd->bsd", jax.nn.silu(gs) * hs,
            p["shared_w_down"].astype(x.dtype)
        )
    return y, aux


def init_moe(keys, cfg, *, layers, dtype=jnp.float32, std=0.02):
    D, F, E = cfg.d_model, cfg.expert_d_ff, cfg.n_experts

    def shp(*s):
        return s if layers is None else (layers, *s)

    p = {
        "w_router": trunc_normal(next(keys), shp(D, E), std, dtype),
        "w_up": trunc_normal(next(keys), shp(E, D, F), std, dtype),
        "w_gate": trunc_normal(next(keys), shp(E, D, F), std, dtype),
        "w_down": trunc_normal(next(keys), shp(E, F, D), std, dtype),
    }
    if cfg.n_shared_experts:
        Fs = F * cfg.n_shared_experts
        p["shared_w_up"] = trunc_normal(next(keys), shp(D, Fs), std, dtype)
        p["shared_w_gate"] = trunc_normal(next(keys), shp(D, Fs), std, dtype)
        p["shared_w_down"] = trunc_normal(next(keys), shp(Fs, D), std, dtype)
    return p


def moe_specs(cfg, layers=True):
    L = ("layers",) if layers else ()
    s = {
        "w_router": L + ("embed", None),
        "w_up": L + ("experts", "embed", "expert_mlp"),
        "w_gate": L + ("experts", "embed", "expert_mlp"),
        "w_down": L + ("experts", "expert_mlp", "embed"),
    }
    if cfg.n_shared_experts:
        s["shared_w_up"] = L + ("embed", "mlp")
        s["shared_w_gate"] = L + ("embed", "mlp")
        s["shared_w_down"] = L + ("mlp", "embed")
    return s
