"""Rotary position embeddings: standard, partial-fraction, and M-RoPE.

M-RoPE (Qwen2-VL, arXiv:2409.12191): head_dim frequencies are split into
(temporal, height, width) sections; each section rotates with its own
position stream.  For text-only tokens all three streams carry the same
position, which reproduces 1-D RoPE exactly — that is the backbone behaviour
exercised here (the vision frontend is a stub per the assignment).
"""
from __future__ import annotations

import jax.numpy as jnp


def rope_freqs(dim, theta=10000.0):
    return 1.0 / (theta ** (jnp.arange(0, dim, 2, dtype=jnp.float32) / dim))


def _rotate(x, cos, sin):
    x1, x2 = jnp.split(x, 2, axis=-1)
    return jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)


def apply_rope(x, positions, *, theta=10000.0, fraction=1.0):
    """x: (B, S, H, hd); positions: (B, S) int32.

    ``fraction`` < 1 rotates only the first ``fraction * hd`` dims
    (StableLM-2 style partial rotary).
    """
    hd = x.shape[-1]
    rot = int(hd * fraction)
    rot -= rot % 2
    xr, xp = x[..., :rot], x[..., rot:]
    freqs = rope_freqs(rot, theta)  # (rot/2,)
    ang = positions.astype(jnp.float32)[..., None] * freqs  # (B,S,rot/2)
    cos = jnp.cos(ang)[:, :, None, :].astype(x.dtype)
    sin = jnp.sin(ang)[:, :, None, :].astype(x.dtype)
    xr = _rotate(xr, cos, sin)
    return jnp.concatenate([xr, xp], axis=-1) if rot < hd else xr


def apply_mrope(x, positions3, *, theta=10000.0, sections=(16, 24, 24)):
    """x: (B, S, H, hd); positions3: (3, B, S) — (t, h, w) position streams.

    ``sections`` are half-dim section sizes (sum == hd // 2), Qwen2-VL layout.
    """
    hd = x.shape[-1]
    assert sum(sections) == hd // 2, (sections, hd)
    freqs = rope_freqs(hd, theta)  # (hd/2,)
    ang_all = positions3.astype(jnp.float32)[..., None] * freqs  # (3,B,S,hd/2)
    pieces = []
    off = 0
    for i, sec in enumerate(sections):
        pieces.append(ang_all[i, :, :, off:off + sec])
        off += sec
    ang = jnp.concatenate(pieces, axis=-1)  # (B,S,hd/2)
    cos = jnp.cos(ang)[:, :, None, :].astype(x.dtype)
    sin = jnp.sin(ang)[:, :, None, :].astype(x.dtype)
    return _rotate(x, cos, sin)
