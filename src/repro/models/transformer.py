"""Unified transformer family (decoder LM / encoder / MoE / MLA / VLM).

One functional implementation covers:
  * dense decoder LMs       (qwen1.5, qwen3, stablelm, yi, GPT)
  * encoder-only            (hubert, BERT)          — ``causal=False``
  * MoE decoders            (phi3.5-moe)            — GSPMD capacity dispatch
  * MLA + MoE + MTP         (deepseek-v3)           — latent attention
  * VLM backbones           (qwen2-vl)              — M-RoPE, stub frontend

Parameters are plain dicts; per-layer weights are stacked on a leading L axis
and the stack runs under ``jax.lax.scan`` (O(1) HLO size for 61/80-layer
models — essential for the 512-device dry-run compile times).  Heterogeneous
stacks (DeepSeek's 3 dense + 58 MoE layers) are two scans over two stacked
groups.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.distributed.sharding import annotate
from repro.models import attention as attn_lib
from repro.models import ffn as ffn_lib
from repro.models import moe as moe_lib
from repro.models.common import (
    apply_norm,
    init_norm,
    keygen,
    pad_cache_len,
    rms_norm,
    trunc_normal,
)
from repro.models.rope import apply_mrope, apply_rope


# =============================================================== param init
def _attn_init(keys, cfg, layers, dtype, std):
    D, H, KV, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim

    def shp(*s):
        return (layers, *s)

    if cfg.mla:
        p = {
            "w_dq": trunc_normal(next(keys), shp(D, cfg.q_lora_rank), std, dtype),
            "q_norm": jnp.ones(shp(cfg.q_lora_rank), dtype),
            "w_uq": trunc_normal(
                next(keys),
                shp(cfg.q_lora_rank, H * (cfg.qk_nope_dim + cfg.qk_rope_dim)),
                std, dtype),
            "w_dkv": trunc_normal(next(keys), shp(D, cfg.kv_lora_rank), std, dtype),
            "kv_norm": jnp.ones(shp(cfg.kv_lora_rank), dtype),
            "w_kr": trunc_normal(next(keys), shp(D, cfg.qk_rope_dim), std, dtype),
            "w_uk": trunc_normal(
                next(keys), shp(cfg.kv_lora_rank, H * cfg.qk_nope_dim), std, dtype),
            "w_uv": trunc_normal(
                next(keys), shp(cfg.kv_lora_rank, H * cfg.v_head_dim), std, dtype),
            "wo": trunc_normal(next(keys), shp(H * cfg.v_head_dim, D), std, dtype),
        }
        return p

    p = {
        "wq": trunc_normal(next(keys), shp(D, H * hd), std, dtype),
        "wk": trunc_normal(next(keys), shp(D, KV * hd), std, dtype),
        "wv": trunc_normal(next(keys), shp(D, KV * hd), std, dtype),
        "wo": trunc_normal(next(keys), shp(H * hd, D), std, dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros(shp(H * hd), dtype)
        p["bk"] = jnp.zeros(shp(KV * hd), dtype)
        p["bv"] = jnp.zeros(shp(KV * hd), dtype)
    if cfg.attn_out_bias:
        p["bo"] = jnp.zeros(shp(D), dtype)
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones(shp(hd), dtype)
        p["k_norm"] = jnp.ones(shp(hd), dtype)
    return p


def _block_group_init(keys, cfg, n, moe, dtype, std):
    """One stacked group of ``n`` blocks (dense mlp or moe)."""
    g = {
        "ln1": init_norm(cfg.norm, cfg.d_model, n, dtype),
        "ln2": init_norm(cfg.norm, cfg.d_model, n, dtype),
        "attn": _attn_init(keys, cfg, n, dtype, std),
    }
    if moe:
        g["moe"] = moe_lib.init_moe(keys, cfg, layers=n, dtype=dtype, std=std)
    else:
        g["mlp"] = ffn_lib.init_mlp(
            keys, cfg.d_model, cfg.d_ff, layers=n, act=cfg.act,
            bias=cfg.mlp_bias, dtype=dtype, std=std)
    return g


def init(rng, cfg) -> dict:
    keys = keygen(rng)
    dtype = jnp.dtype(cfg.param_dtype)
    std = 0.02
    params: dict[str, Any] = {}
    D = cfg.d_model

    if cfg.continuous_inputs:
        params["in_proj"] = trunc_normal(
            next(keys), (cfg.continuous_inputs, D), std, dtype)
    else:
        params["embed"] = trunc_normal(
            next(keys), (cfg.vocab_size, D), std, dtype)
    if cfg.learned_pos:
        params["pos_embed"] = trunc_normal(
            next(keys), (cfg.learned_pos, D), std, dtype)

    n_dense = cfg.moe_layer_start if cfg.moe else cfg.n_layers
    n_moe = cfg.n_layers - n_dense
    if n_dense:
        params["dense_blocks"] = _block_group_init(
            keys, cfg, n_dense, False, dtype, std)
    if n_moe:
        params["moe_blocks"] = _block_group_init(
            keys, cfg, n_moe, True, dtype, std)

    params["final_norm"] = init_norm(cfg.norm, D, None, dtype)
    if cfg.head == "lm" and not cfg.tie_embeddings:
        params["head"] = trunc_normal(
            next(keys), (D, cfg.vocab_size), std, dtype)
    elif cfg.head == "cls":
        params["cls_token"] = trunc_normal(next(keys), (D,), std, dtype)
        params["head"] = trunc_normal(
            next(keys), (D, cfg.n_classes), std, dtype)

    if cfg.mtp:
        params["mtp"] = {
            "proj": trunc_normal(next(keys), (2 * D, D), std, dtype),
            "norm_h": init_norm(cfg.norm, D, None, dtype),
            "norm_e": init_norm(cfg.norm, D, None, dtype),
            "block": _block_group_init(keys, cfg, 1, False, dtype, std),
        }
    return params


# ============================================================ forward pieces
def _split_heads(x, n, hd):
    B, S, _ = x.shape
    return x.reshape(B, S, n, hd)


def _slot_kv_len(slot_positions, slot_done):
    """Per-row valid cache length for the slot-decode path.

    Finished/idle rows (``slot_done``) get ``kv_len == 0`` — the same
    short-circuit the Pallas decode kernel takes for idle slots — so the
    macro-step's no-op steps skip their attention reads entirely.
    """
    kv = slot_positions + 1
    if slot_done is None:
        return kv
    return jnp.where(slot_done, 0, kv)


def _kernel_mode(cfg):
    """The slot-decode attention backend: None (pure jnp) or the mode
    string handed to ``kernels.ops`` (auto / interpret / reference)."""
    return None if cfg.decode_kernel == "jnp" else cfg.decode_kernel


def _flash_block(s):
    """Flash-attention block size for a prefill of length ``s``: the
    largest power-of-two divisor, capped at the kernel's native 128.
    None when the divisor is degenerate (< 8) — the tiny-grid launch
    overhead then exceeds the masked-compute tax the kernel avoids."""
    b = min(s & -s, 128)
    return b if b >= 8 else None


def _is_ring(cache_len, window):
    """A window cache whose length reaches the window is a wrapping ring
    (slot = pos % cache_len); a shorter one never wraps and uses the
    full-cache layout.  ``>=`` not ``==``: the pool pads the cache axis to
    a kernel block multiple, which may push a ring past the window —
    absolute-position masking keeps a larger ring attend-identical.
    """
    return window is not None and cache_len >= window


def _cache_seq_len(cache):
    """Logical sequence length of a slot cache group: the cache axis for
    the dense layout, ``nblk * page`` through the block table for a paged
    group (whose arrays no longer carry a per-slot sequence axis)."""
    if "bt" in cache:
        return cache["bt"].shape[1] * cache["k"].shape[1]
    return cache["k"].shape[1]


def _paged_slot_forward(q, p, cfg, cache, k, v, slot_positions, slot_done,
                        window, cdt):
    """Slot-decode step over a PAGED cache group.

    cache: {"k"/"v": (n_pages, page, KV, hd), "bt": (B, nblk)}.  The
    write position resolves through the block table (logical block
    ``pos // page`` → physical page); ``done`` rows redirect to the page
    sentinel so their write is dropped — the paged freeze (a done row's
    table may be all-sentinel after eviction, so the dense path's
    "re-store identical bytes" trick is not available).  Reads either
    gather the arena back to the dense layout and reuse the
    exactness-proven jnp paths, or hand the arena + table to the paged
    Pallas kernels.
    """
    bt = cache["bt"]
    n_pages, page = cache["k"].shape[:2]
    S = bt.shape[1] * page
    if _is_ring(S, window):
        out, new_cache = attn_lib.paged_ring_slot_update_attend(
            q, cache, k, v, slot_positions, window=window, done=slot_done,
            kernel=_kernel_mode(cfg))
        return _attn_out(out, p, cfg, cdt), new_cache
    blk = slot_positions // page
    pid = jnp.take_along_axis(bt, blk[:, None], axis=1)[:, 0]
    if slot_done is not None:
        pid = jnp.where(slot_done, n_pages, pid)
    off = slot_positions % page
    ck = cache["k"].at[pid, off].set(k[:, 0].astype(cache["k"].dtype),
                                     mode="drop")
    cv = cache["v"].at[pid, off].set(v[:, 0].astype(cache["v"].dtype),
                                     mode="drop")
    new_cache = {"k": ck, "v": cv, "bt": bt}
    kvl = _slot_kv_len(slot_positions, slot_done)
    kmode = _kernel_mode(cfg)
    if kmode is not None:
        from repro.kernels import ops
        out = ops.paged_slot_decode_attention(
            q[:, 0], ck, cv, bt, kvl, mode=kmode)[:, None]
    else:
        out = attn_lib.attention(
            q, attn_lib.paged_gather(ck, bt).astype(cdt),
            attn_lib.paged_gather(cv, bt).astype(cdt), causal=False,
            kv_len=kvl, chunk_q=cfg.attn_chunk, unroll=cfg.unroll_scans,
            logits_dtype=jnp.dtype(cfg.attn_logits_dtype))
    return _attn_out(out, p, cfg, cdt), new_cache


def _attn_forward(x, p, cfg, positions, *, cache=None, q_offset=0,
                  kv_len=None, window=None, slot_positions=None,
                  slot_done=None, plens=None, chunk_offsets=None):
    """Returns (out, new_cache_entry). x: (B,S,D).

    ``slot_positions`` (B,) switches to the continuous-batching decode path:
    S must be 1, each batch row is an independent cache slot at its own
    length, the new K/V is scattered to ``cache[b, slot_positions[b]]``
    (``% ring`` for ring-buffer window caches) and attention masks per-row
    to ``kv_len = slot_positions + 1`` — or 0 for rows flagged in
    ``slot_done`` (macro-step no-op rows).

    ``plens`` (B,) marks a continuous-batching ADMISSION prefill: prompts
    are tail-padded to a bucket length, and ring-buffer window caches must
    be filled per row from each prompt's true length (a full cache needs
    nothing — its pad-tail entries stay invisible behind the per-row
    ``kv_len`` mask until overwritten).

    ``chunk_offsets`` (B,) marks a SPECULATIVE VERIFY chunk: S tokens per
    row starting at each row's own committed length.  The cache is
    READ-ONLY — attention runs over [cache ‖ in-flight chunk] by absolute
    position and the chunk's K/V is returned as the pending entry for
    ``commit_slots``'s accept-masked scatter (rejected speculative
    positions are simply never written).
    """
    B, S, D = x.shape
    H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    cdt = x.dtype

    if cfg.mla:
        return _mla_forward(x, p, cfg, positions, cache=cache,
                            q_offset=q_offset, kv_len=kv_len,
                            slot_positions=slot_positions,
                            slot_done=slot_done,
                            chunk_offsets=chunk_offsets)

    q = jnp.einsum("bsd,dh->bsh", x, p["wq"].astype(cdt))
    k = jnp.einsum("bsd,dh->bsh", x, p["wk"].astype(cdt))
    v = jnp.einsum("bsd,dh->bsh", x, p["wv"].astype(cdt))
    if cfg.qkv_bias:
        q = q + p["bq"].astype(cdt)
        k = k + p["bk"].astype(cdt)
        v = v + p["bv"].astype(cdt)
    q = _split_heads(q, H, hd)
    k = _split_heads(k, KV, hd)
    v = _split_heads(v, KV, hd)
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"])
        k = rms_norm(k, p["k_norm"])
    if cfg.rope == "standard":
        q = apply_rope(q, positions, theta=cfg.rope_theta,
                       fraction=cfg.rope_fraction)
        k = apply_rope(k, positions, theta=cfg.rope_theta,
                       fraction=cfg.rope_fraction)
    elif cfg.rope == "mrope":
        q = apply_mrope(q, positions, theta=cfg.rope_theta,
                        sections=cfg.mrope_sections)
        k = apply_mrope(k, positions, theta=cfg.rope_theta,
                        sections=cfg.mrope_sections)
    q = annotate(q, ("batch", "seq", "heads", "head_dim"))
    k = annotate(k, ("batch", "seq", "kv_heads", "head_dim"))
    v = annotate(v, ("batch", "seq", "kv_heads", "head_dim"))

    if chunk_offsets is not None:
        # speculative verify: attend [cache ‖ chunk] read-only.  The
        # pending entry never carries a block table — commit resolves
        # pages through the live cache's own "bt".
        is_ring = _is_ring(_cache_seq_len(cache), window)
        kmode = _kernel_mode(cfg)
        if "bt" in cache:
            if kmode is not None:
                from repro.kernels import ops
                out = ops.paged_chunk_verify_attention(
                    q, cache["k"], cache["v"], cache["bt"], k, v,
                    chunk_offsets, ring=is_ring, window=window,
                    done=slot_done, mode=kmode)
            else:
                out = attn_lib.chunk_verify_attend(
                    q, attn_lib.paged_gather(cache["k"], cache["bt"]),
                    attn_lib.paged_gather(cache["v"], cache["bt"]),
                    k, v, chunk_offsets, ring=is_ring, window=window,
                    done=slot_done,
                    logits_dtype=jnp.dtype(cfg.attn_logits_dtype))
            return _attn_out(out, p, cfg, cdt), {"k": k, "v": v}
        if kmode is not None:
            from repro.kernels import ops
            out = ops.chunk_verify_attention(
                q, cache["k"], cache["v"], k, v, chunk_offsets,
                ring=is_ring, window=window, done=slot_done, mode=kmode)
        else:
            out = attn_lib.chunk_verify_attend(
                q, cache["k"], cache["v"], k, v, chunk_offsets,
                ring=is_ring, window=window, done=slot_done,
                logits_dtype=jnp.dtype(cfg.attn_logits_dtype))
        return _attn_out(out, p, cfg, cdt), {"k": k, "v": v}

    new_cache = None
    if slot_positions is not None:
        if "bt" in cache:
            return _paged_slot_forward(q, p, cfg, cache, k, v,
                                       slot_positions, slot_done, window,
                                       cdt)
        if _is_ring(cache["k"].shape[1], window):
            # Ring-buffer window cache: each row writes its own slot
            # ``pos % ring`` and attends by ABSOLUTE position
            # reconstructed from the ring invariant — the per-slot mirror
            # of ``_ring_window_attend``.  Done rows freeze (their frozen
            # token/position would re-store identical bytes anyway) and
            # attend nothing.  (A window cfg whose cache is shorter than
            # the window never wraps, so it falls through to the
            # full-cache scatter below: every cached position is inside
            # the band by construction.)
            out, new_cache = attn_lib.ring_slot_update_attend(
                q, cache, k, v, slot_positions, window=window,
                done=slot_done, kernel=_kernel_mode(cfg))
            return _attn_out(out, p, cfg, cdt), new_cache
        # Scatter this step's K/V to each row's own write position, then
        # attend with a per-row valid length.  Row arithmetic is identical
        # to the scalar-offset decode path (same einsums, same masked
        # softmax), which is what makes continuous batching token-exact
        # against sequential generate().  Done rows scatter too: their
        # token and position are frozen, so the write re-stores the exact
        # same K/V values (a bit-identical no-op) while kv_len == 0 keeps
        # the position unreadable.
        b_idx = jnp.arange(B)
        ck = cache["k"].at[b_idx, slot_positions].set(
            k[:, 0].astype(cache["k"].dtype))
        cv = cache["v"].at[b_idx, slot_positions].set(
            v[:, 0].astype(cache["v"].dtype))
        new_cache = {"k": ck, "v": cv}
        kmode = _kernel_mode(cfg)
        if kmode is not None:
            from repro.kernels import ops
            out = ops.slot_decode_attention(
                q[:, 0], ck, cv, _slot_kv_len(slot_positions, slot_done),
                mode=kmode)[:, None]
        else:
            out = attn_lib.attention(
                q, ck.astype(cdt), cv.astype(cdt), causal=False,
                kv_len=_slot_kv_len(slot_positions, slot_done),
                chunk_q=cfg.attn_chunk, unroll=cfg.unroll_scans,
                logits_dtype=jnp.dtype(cfg.attn_logits_dtype))
        return _attn_out(out, p, cfg, cdt), new_cache
    if cache is not None:
        # cache: {"k": (B, Smax, KV, hd), "v": ...} — window caches are ring
        # buffers of size ``window`` (slot = abs_pos % window).
        ck, cv = cache["k"], cache["v"]
        wsize = ck.shape[1]
        if _is_ring(wsize, window):
            # the ring modulus is the CACHE length (>= window once the
            # pool pads to a kernel block multiple), not the window
            if plens is not None and S > 1:
                # admission prefill of tail-padded prompts: fill each
                # row's ring from its TRUE length
                ck = attn_lib.ring_fill_rows(k, plens, wsize, ck.dtype)
                cv = attn_lib.ring_fill_rows(v, plens, wsize, cv.dtype)
            else:
                w_eff = min(S, wsize)
                idx = (q_offset + S - w_eff + jnp.arange(w_eff)) % wsize
                ck = ck.at[:, idx].set(k[:, -w_eff:].astype(ck.dtype))
                cv = cv.at[:, idx].set(v[:, -w_eff:].astype(cv.dtype))
            new_cache = {"k": ck, "v": cv}
            if S > 1:
                # prefill: window attention over the in-flight k/v directly
                out = attn_lib.attention(
                    q, k, v, causal=cfg.causal, window=window,
                    q_offset=q_offset, chunk_q=cfg.attn_chunk,
                    unroll=cfg.unroll_scans)
            else:
                kpos_abs = _ring_positions(q_offset + S, wsize)
                out = _ring_window_attend(q, ck.astype(cdt), cv.astype(cdt),
                                          kpos_abs, q_offset, cfg)
            return _attn_out(out, p, cfg, cdt), new_cache
        ck = jax.lax.dynamic_update_slice_in_dim(
            ck, k.astype(ck.dtype), q_offset, axis=1)
        cv = jax.lax.dynamic_update_slice_in_dim(
            cv, v.astype(cv.dtype), q_offset, axis=1)
        new_cache = {"k": ck, "v": cv}
        k, v = ck.astype(cdt), cv.astype(cdt)
        kv_len = q_offset + S
        kmode = _kernel_mode(cfg)
        if (S > 1 and kmode is not None and cfg.causal and window is None
                and isinstance(q_offset, int) and q_offset == 0):
            # Batched prefill admission on the kernel backend: causal
            # flash attention over exactly the S in-flight positions.
            # At q_offset 0, kv_len == S, so the jnp path below masks
            # nothing beyond the causal band — the flash kernel computes
            # the identical softmax.  K/V come back out of the cache
            # slice (not the raw in-flight tensors) so cache-dtype
            # rounding matches the jnp path bit-for-bit.  Tail-padded
            # rows still compute, but stay unread: admission gathers each
            # row's first token at plens-1, inside its true prompt.
            blk = _flash_block(S)
            if blk is not None:
                from repro.kernels import ops
                of = ops.flash_attention(
                    q.transpose(0, 2, 1, 3),
                    k[:, :S].transpose(0, 2, 1, 3),
                    v[:, :S].transpose(0, 2, 1, 3),
                    causal=True, mode=kmode,
                    **({} if kmode == "reference"
                       else {"bq": blk, "bk": blk}))
                return (_attn_out(of.transpose(0, 2, 1, 3), p, cfg, cdt),
                        new_cache)

    out = attn_lib.attention(
        q, k, v, causal=cfg.causal, window=window, q_offset=q_offset,
        kv_len=kv_len, chunk_q=cfg.attn_chunk, unroll=cfg.unroll_scans,
        logits_dtype=jnp.dtype(cfg.attn_logits_dtype),
        prefix_chunks=cfg.attn_prefix_chunks)
    return _attn_out(out, p, cfg, cdt), new_cache


def _attn_out(out, p, cfg, cdt):
    B, S = out.shape[:2]
    out = out.reshape(B, S, -1)
    y = jnp.einsum("bsh,hd->bsd", out, p["wo"].astype(cdt))
    if cfg.attn_out_bias:
        y = y + p["bo"].astype(cdt)
    return y


def _ring_positions(cur_len, ring):
    """Absolute position stored in each ring-buffer slot; -1 if unwritten.
    ``ring`` is the cache length (the ring modulus), not the window."""
    slot = jnp.arange(ring)
    wrap = (cur_len - 1) // ring
    base = wrap * ring + slot
    pos = jnp.where(base < cur_len, base, base - ring)
    return jnp.where(pos >= 0, pos, -1)


def _ring_window_attend(q, ck, cv, kpos_abs, q_offset, cfg):
    """Decode/short-prefill attention over a ring-buffer window cache."""
    B, S, H, hd = q.shape
    KV = ck.shape[2]
    qg = q.reshape(B, S, KV, H // KV, hd)
    qpos = q_offset + jnp.arange(S)
    mask = (kpos_abs[None, :] <= qpos[:, None]) & \
           (kpos_abs[None, :] > qpos[:, None] - cfg.window) & \
           (kpos_abs[None, :] >= 0)
    out = attn_lib._sdpa(qg, ck.astype(q.dtype), cv.astype(q.dtype),
                         mask, cfg.head_dim ** -0.5)
    return out.reshape(B, S, H, hd)


def _mla_forward(x, p, cfg, positions, *, cache=None, q_offset=0, kv_len=None,
                 slot_positions=None, slot_done=None, chunk_offsets=None):
    """DeepSeek-V3 Multi-head Latent Attention (arXiv:2412.19437)."""
    B, S, D = x.shape
    cdt = x.dtype
    H = cfg.n_heads
    dn, dr, dv = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim

    cq = jnp.einsum("bsd,dr->bsr", x, p["w_dq"].astype(cdt))
    cq = rms_norm(cq, p["q_norm"])
    q = jnp.einsum("bsr,rh->bsh", cq, p["w_uq"].astype(cdt))
    q = q.reshape(B, S, H, dn + dr)
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    q_rope = apply_rope(q_rope, positions, theta=cfg.rope_theta)

    ckv = jnp.einsum("bsd,dr->bsr", x, p["w_dkv"].astype(cdt))
    kr = jnp.einsum("bsd,dr->bsr", x, p["w_kr"].astype(cdt))
    kr = apply_rope(kr[:, :, None, :], positions,
                    theta=cfg.rope_theta)[:, :, 0]

    if chunk_offsets is not None:
        # speculative verify in the latent space: absorbed-weight
        # attention over [cached latents ‖ chunk latents] at per-row
        # offsets, cache read-only; the raw chunk latents are the pending
        # entry for ``commit_slots`` (never carrying a block table —
        # commit resolves pages through the live cache's own "bt")
        cache_view = cache
        if "bt" in cache:
            cc, cr = _mla_paged_gather(cache, cfg)
            cache_view = {"ckv": cc, "kr": cr}
        out = _mla_chunk_verify(q_nope, q_rope, cache_view, ckv, kr, p, cfg,
                                chunk_offsets, slot_done)
        y = jnp.einsum("bsh,hd->bsd", out, p["wo"].astype(cdt))
        return y, {"ckv": ckv, "kr": kr}
    new_cache = None
    if slot_positions is not None:
        if "bt" in cache:
            # paged latent cache: the write resolves its page through the
            # block table (done rows redirect to the sentinel and drop —
            # the paged freeze), the absorbed-weight attention reads a
            # gathered dense view
            bt = cache["bt"]
            n_pages, page = cache["ckv"].shape[:2]
            blk = slot_positions // page
            pid = jnp.take_along_axis(bt, blk[:, None], axis=1)[:, 0]
            if slot_done is not None:
                pid = jnp.where(slot_done, n_pages, pid)
            off = slot_positions % page
            cc = cache["ckv"].at[pid, off].set(
                ckv[:, 0].astype(cache["ckv"].dtype), mode="drop")
            cr = cache["kr"].at[pid, off].set(
                kr[:, 0].astype(cache["kr"].dtype), mode="drop")
            new_cache = {"ckv": cc, "kr": cr, "bt": bt}
            gc, gr = _mla_paged_gather(new_cache, cfg)
            out = _mla_absorbed_decode(
                q_nope, q_rope, gc.astype(cdt), gr.astype(cdt), p, cfg,
                kv_len=_slot_kv_len(slot_positions, slot_done))
            y = jnp.einsum("bsh,hd->bsd", out, p["wo"].astype(cdt))
            return y, new_cache
        # continuous-batching decode: per-row latent-cache scatter + the
        # absorbed-weight attention with per-row valid lengths
        b_idx = jnp.arange(B)
        cc = cache["ckv"].at[b_idx, slot_positions].set(
            ckv[:, 0].astype(cache["ckv"].dtype))
        cr = cache["kr"].at[b_idx, slot_positions].set(
            kr[:, 0].astype(cache["kr"].dtype))
        new_cache = {"ckv": cc, "kr": cr}
        out = _mla_absorbed_decode(
            q_nope, q_rope, cc.astype(cdt), cr.astype(cdt), p, cfg,
            kv_len=_slot_kv_len(slot_positions, slot_done))
        y = jnp.einsum("bsh,hd->bsd", out, p["wo"].astype(cdt))
        return y, new_cache
    if cache is not None:
        cc, cr = cache["ckv"], cache["kr"]
        cc = jax.lax.dynamic_update_slice_in_dim(
            cc, ckv.astype(cc.dtype), q_offset, axis=1)
        cr = jax.lax.dynamic_update_slice_in_dim(
            cr, kr.astype(cr.dtype), q_offset, axis=1)
        new_cache = {"ckv": cc, "kr": cr}
        if S == 1:
            # Absorbed-weight MLA decode (DeepSeek-V3 §: W_uk folded into q,
            # W_uv applied after the latent attention) — attends directly in
            # the compressed kv_lora space, avoiding re-expanding K/V to
            # (B, S_cache, H, dn+dv) every step.
            out = _mla_absorbed_decode(
                q_nope, q_rope, cc.astype(cdt), cr.astype(cdt), p, cfg,
                kv_len=q_offset + 1)
            y = jnp.einsum("bsh,hd->bsd", out, p["wo"].astype(cdt))
            return y, new_cache
        ckv, kr = cc.astype(cdt), cr.astype(cdt)
        kv_len = q_offset + S

    ckv_n = rms_norm(ckv, p["kv_norm"])
    k_nope = jnp.einsum("bsr,rh->bsh", ckv_n, p["w_uk"].astype(cdt))
    k_nope = k_nope.reshape(B, -1, H, dn)
    v = jnp.einsum("bsr,rh->bsh", ckv_n, p["w_uv"].astype(cdt))
    v = v.reshape(B, -1, H, dv)

    qf = jnp.concatenate([q_nope, q_rope], -1)
    kf = jnp.concatenate(
        [k_nope, jnp.broadcast_to(kr[:, :, None, :],
                                  (*k_nope.shape[:3], dr))], -1)
    qf = annotate(qf, ("batch", "seq", "heads", "head_dim"))
    kf = annotate(kf, ("batch", "seq", "heads", "head_dim"))
    v = annotate(v, ("batch", "seq", "heads", "head_dim"))
    out = attn_lib.attention(
        qf, kf, v, causal=cfg.causal, q_offset=q_offset, kv_len=kv_len,
        scale=(dn + dr) ** -0.5, chunk_q=cfg.attn_chunk,
        unroll=cfg.unroll_scans,
        logits_dtype=jnp.dtype(cfg.attn_logits_dtype),
        prefix_chunks=cfg.attn_prefix_chunks)
    out = out.reshape(B, S, H * dv)
    y = jnp.einsum("bsh,hd->bsd", out, p["wo"].astype(cdt))
    return y, new_cache


def _mla_paged_gather(cache, cfg):
    """Dense (B, S, ·) views of a paged MLA latent group.  A kernel-mode
    config routes through ``kernels.ops.paged_latent_gather`` so the
    independently-derived reference gather oracles the arena layout (MLA
    has no Pallas decode kernel — the absorbed-weight path is jnp)."""
    kmode = _kernel_mode(cfg)
    if kmode is not None:
        from repro.kernels import ops
        return (ops.paged_latent_gather(cache["ckv"], cache["bt"],
                                        mode=kmode),
                ops.paged_latent_gather(cache["kr"], cache["bt"],
                                       mode=kmode))
    return (attn_lib.paged_gather(cache["ckv"], cache["bt"]),
            attn_lib.paged_gather(cache["kr"], cache["bt"]))


def _mla_absorbed_decode(q_nope, q_rope, ckv, kr, p, cfg, *, kv_len):
    """One-token MLA attention in the latent space.

    q_nope: (B,1,H,dn); q_rope: (B,1,H,dr); ckv: (B,Smax,R); kr: (B,Smax,dr).
    Returns (B, 1, H*dv).
    """
    B, _, H, dn = q_nope.shape
    R, dv = cfg.kv_lora_rank, cfg.v_head_dim
    ckv_n = rms_norm(ckv, p["kv_norm"])  # (B,S,R)
    w_uk = p["w_uk"].astype(q_nope.dtype).reshape(R, H, dn)
    q_lat = jnp.einsum("bqhd,rhd->bqhr", q_nope, w_uk)  # (B,1,H,R)
    logits = jnp.einsum("bqhr,bsr->bhqs", q_lat, ckv_n,
                        preferred_element_type=jnp.float32)
    logits += jnp.einsum("bqhd,bsd->bhqs", q_rope, kr,
                         preferred_element_type=jnp.float32)
    logits *= (dn + cfg.qk_rope_dim) ** -0.5
    kvl = jnp.asarray(kv_len)
    if kvl.ndim == 0:
        mask = (jnp.arange(ckv.shape[1]) < kvl)[None, None, None]
    else:  # per-row lengths (continuous batching)
        mask = (jnp.arange(ckv.shape[1])[None] < kvl[:, None])[:, None, None]
    logits = jnp.where(mask, logits, attn_lib.NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1).astype(ckv.dtype)
    o_lat = jnp.einsum("bhqs,bsr->bqhr", probs, ckv_n)  # (B,1,H,R)
    w_uv = p["w_uv"].astype(ckv.dtype).reshape(R, H, dv)
    out = jnp.einsum("bqhr,rhv->bqhv", o_lat, w_uv)
    if kvl.ndim == 1:
        # fully-masked rows (kv_len == 0: idle/finished slots) degenerate
        # to a uniform softmax over the cache — pin them to the exact
        # zeros the standard attention path and Pallas kernel return
        out = jnp.where((kvl > 0)[:, None, None, None], out, 0)
    return out.reshape(B, 1, H * dv)


def _mla_chunk_verify(q_nope, q_rope, cache, ckv, kr, p, cfg, offsets, done):
    """Speculative-verify MLA attention: S chunk queries per row over
    [cached latents ‖ this chunk's raw latents], cache read-only.

    q_nope: (B,S,H,dn); q_rope: (B,S,H,dr); cache: {"ckv": (B,Smax,R),
    "kr": (B,Smax,dr)}; ckv/kr: (B,S,·) the chunk's latents.  Returns
    (B, S, H*dv); ``done`` rows return exact zeros.
    """
    B, S, H, dn = q_nope.shape
    R, dv = cfg.kv_lora_rank, cfg.v_head_dim
    ckv_all = jnp.concatenate([cache["ckv"].astype(ckv.dtype), ckv], 1)
    kr_all = jnp.concatenate([cache["kr"].astype(kr.dtype), kr], 1)
    ckv_n = rms_norm(ckv_all, p["kv_norm"])
    w_uk = p["w_uk"].astype(q_nope.dtype).reshape(R, H, dn)
    q_lat = jnp.einsum("bqhd,rhd->bqhr", q_nope, w_uk)
    logits = jnp.einsum("bqhr,bsr->bhqs", q_lat, ckv_n,
                        preferred_element_type=jnp.float32)
    logits += jnp.einsum("bqhd,bsd->bhqs", q_rope, kr_all,
                         preferred_element_type=jnp.float32)
    logits *= (dn + cfg.qk_rope_dim) ** -0.5
    kpos = attn_lib.chunk_verify_kpos(offsets, cache["ckv"].shape[1], S,
                                      ring=False)
    mask = attn_lib.chunk_verify_mask(offsets, kpos, S, done=done)
    logits = jnp.where(mask[:, None], logits, attn_lib.NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1).astype(ckv_all.dtype)
    o_lat = jnp.einsum("bhqs,bsr->bqhr", probs, ckv_n)
    w_uv = p["w_uv"].astype(ckv_all.dtype).reshape(R, H, dv)
    out = jnp.einsum("bqhr,rhv->bqhv", o_lat, w_uv)
    if done is not None:
        out = jnp.where(done[:, None, None, None], 0.0, out)
    return out.reshape(B, S, H * dv)


def _block(x, bp, cfg, positions, *, moe, cache=None, q_offset=0,
           window=None, slot_positions=None, slot_done=None, plens=None,
           chunk_offsets=None):
    h, new_cache = _attn_forward(
        apply_norm(x, bp["ln1"], cfg.norm), bp["attn"], cfg, positions,
        cache=cache, q_offset=q_offset, window=window,
        slot_positions=slot_positions, slot_done=slot_done, plens=plens,
        chunk_offsets=chunk_offsets)
    x = x + h
    hin = apply_norm(x, bp["ln2"], cfg.norm)
    if moe:
        h, aux = moe_lib.moe_mlp(hin, bp["moe"], cfg)
    else:
        h, aux = ffn_lib.mlp(hin, bp["mlp"], cfg.act), 0.0
    x = x + h
    x = annotate(x, ("batch", "seq", "embed"))
    return x, aux, new_cache


def _run_group(x, group, cfg, positions, *, moe, caches=None, q_offset=0,
               slot_positions=None, slot_done=None, plens=None,
               chunk_offsets=None):
    """Scan a stacked block group. caches: stacked (n, ...) or None."""
    def body(carry, xs):
        xc, aux_sum = carry
        if caches is None:
            bp = xs
            xc, aux, _ = _block(xc, bp, cfg, positions, moe=moe,
                                q_offset=q_offset, window=cfg.window)
            return (xc, aux_sum + aux), None
        bp, cache_l = xs
        xc, aux, nc = _block(xc, bp, cfg, positions, moe=moe, cache=cache_l,
                             q_offset=q_offset, window=cfg.window,
                             slot_positions=slot_positions,
                             slot_done=slot_done, plens=plens,
                             chunk_offsets=chunk_offsets)
        return (xc, aux_sum + aux), nc

    if cfg.remat == "block":
        body = jax.remat(body, prevent_cse=False)
    elif cfg.remat == "dots":
        # save matmul outputs, recompute elementwise — trades HBM for a
        # ~2x cut of backward recompute traffic
        body = jax.remat(
            body, prevent_cse=False,
            policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    xs = group if caches is None else (group, caches)
    (x, aux), new_caches = jax.lax.scan(
        body, (x, jnp.zeros((), jnp.float32)), xs,
        unroll=cfg.unroll_scans)
    return x, aux, new_caches


# ================================================================== forward
def embed_inputs(params, batch, cfg):
    cdt = jnp.dtype(cfg.compute_dtype)
    if cfg.continuous_inputs:
        x = jnp.einsum("bsi,id->bsd", batch["inputs"].astype(cdt),
                       params["in_proj"].astype(cdt))
    else:
        tokens = batch["tokens"]
        x = params["embed"].astype(cdt)[tokens]
    if cfg.scale_embeddings:
        x = x * jnp.asarray(cfg.d_model ** 0.5, cdt)
    if cfg.head == "cls":
        cls = jnp.broadcast_to(params["cls_token"].astype(cdt),
                               (x.shape[0], 1, x.shape[-1]))
        x = jnp.concatenate([cls, x], axis=1)
    B, S = x.shape[:2]
    if cfg.learned_pos:
        pos = batch.get("positions")
        if pos is None or pos.ndim != 2:
            pos = jnp.arange(S, dtype=jnp.int32)[None, :]
        x = x + params["pos_embed"].astype(cdt)[pos]
    return annotate(x, ("batch", "seq", "embed"))


def _positions_from_batch(batch, B, S, cfg, q_offset=0):
    pos = batch.get("positions")
    if pos is not None:
        return pos
    p = q_offset + jnp.arange(S, dtype=jnp.int32)[None, :]
    p = jnp.broadcast_to(p, (B, S))
    if cfg.rope == "mrope":
        return jnp.broadcast_to(p[None], (3, B, S))
    return p


def forward(params, batch, cfg):
    """Full forward. batch: {"tokens": (B,S)} or {"inputs": (B,S,Din)}.

    Returns (logits, aux) where aux = {"moe_aux": scalar, "mtp_logits": ...}.
    """
    x = embed_inputs(params, batch, cfg)
    B, S = x.shape[:2]
    positions = _positions_from_batch(batch, B, S, cfg)
    aux_total = 0.0
    if "dense_blocks" in params:
        x, aux, _ = _run_group(x, params["dense_blocks"], cfg, positions,
                               moe=False)
        aux_total += aux
    if "moe_blocks" in params:
        x, aux, _ = _run_group(x, params["moe_blocks"], cfg, positions,
                               moe=True)
        aux_total += aux
    x = apply_norm(x, params["final_norm"], cfg.norm)
    aux = {"moe_aux": aux_total}

    if cfg.mtp and "mtp" in params and not cfg.continuous_inputs:
        aux["mtp_logits"] = _mtp_forward(params, x, batch, positions, cfg)

    logits = _head(params, x, cfg)
    return logits, aux


def _head(params, x, cfg):
    cdt = x.dtype
    if cfg.head == "none":
        return x
    if cfg.head == "cls":
        return jnp.einsum("bd,dc->bc", x[:, 0], params["head"].astype(cdt))
    w = params["embed"].T if cfg.tie_embeddings else params["head"]
    logits = jnp.einsum("bsd,dv->bsv", x, w.astype(cdt))
    return annotate(logits, ("batch", "seq", "vocab"))


def _mtp_forward(params, h, batch, positions, cfg):
    """DeepSeek-V3 depth-1 multi-token prediction head (predicts t+2)."""
    mp = params["mtp"]
    cdt = h.dtype
    emb = params["embed"].astype(cdt)[batch["tokens"]]
    hh = apply_norm(h[:, :-1], mp["norm_h"], cfg.norm)
    ee = apply_norm(emb[:, 1:], mp["norm_e"], cfg.norm)
    z = jnp.einsum("bsd,dD->bsD", jnp.concatenate([hh, ee], -1),
                   mp["proj"].astype(cdt))
    pos = positions[:, :-1] if positions.ndim == 2 else positions[..., :-1]
    z, _, _ = _run_group(z, mp["block"], cfg, pos, moe=False)
    return _head(params, z, cfg)


# ============================================================== serve (KV)
def init_cache(cfg, batch_size, max_len, dtype=None):
    """Stacked per-group caches.

    The cache axis is padded to a kernel block multiple
    (``common.pad_cache_len`` — the TPU-layout pool contract), so the
    Pallas decode kernels always find a valid cache-axis block even for
    prime/odd ``max_len``.  The padding is invisible: full layouts mask
    it behind per-row ``kv_len``, ring layouts take the padded length as
    their ring modulus.
    """
    dtype = dtype or jnp.dtype(cfg.compute_dtype)
    n_dense = cfg.moe_layer_start if cfg.moe else cfg.n_layers
    n_moe = cfg.n_layers - n_dense
    wlen = min(max_len, cfg.window) if cfg.window else max_len
    wlen = pad_cache_len(wlen)
    flen = pad_cache_len(max_len)

    def one(n):
        if cfg.mla:
            return {
                "ckv": jnp.zeros((n, batch_size, flen, cfg.kv_lora_rank),
                                 dtype),
                "kr": jnp.zeros((n, batch_size, flen, cfg.qk_rope_dim),
                                dtype),
            }
        return {
            "k": jnp.zeros((n, batch_size, wlen, cfg.n_kv_heads,
                            cfg.head_dim), dtype),
            "v": jnp.zeros((n, batch_size, wlen, cfg.n_kv_heads,
                            cfg.head_dim), dtype),
        }

    cache = {}
    if n_dense:
        cache["dense"] = one(n_dense)
    if n_moe:
        cache["moe"] = one(n_moe)
    return cache


def _forward_cached(params, batch, cfg, cache, q_offset, plens=None):
    x = embed_inputs(params, batch, cfg)
    B, S = x.shape[:2]
    positions = _positions_from_batch(batch, B, S, cfg, q_offset=q_offset)
    new_cache = {}
    if "dense_blocks" in params:
        x, _, nc = _run_group(x, params["dense_blocks"], cfg, positions,
                              moe=False, caches=cache["dense"],
                              q_offset=q_offset, plens=plens)
        new_cache["dense"] = nc
    if "moe_blocks" in params:
        x, _, nc = _run_group(x, params["moe_blocks"], cfg, positions,
                              moe=True, caches=cache["moe"],
                              q_offset=q_offset, plens=plens)
        new_cache["moe"] = nc
    x = apply_norm(x, params["final_norm"], cfg.norm)
    return _head(params, x, cfg), new_cache


def prefill(params, batch, cfg, cache):
    """Run the prompt through the model, filling the cache.

    Returns (last-position logits (B, V), cache).
    """
    logits, cache = _forward_cached(params, batch, cfg, cache, q_offset=0)
    return logits[:, -1], cache


def decode_step(params, tokens, pos, cache, cfg):
    """One decode step. tokens: (B,) int32; pos: scalar int32 (current len).

    Returns (logits (B, V), new_cache).
    """
    batch = {"tokens": tokens[:, None]}
    if cfg.learned_pos:
        # absolute learned positions must track the decode offset (rope
        # models get this through q_offset already)
        batch["positions"] = jnp.full((tokens.shape[0], 1), pos, jnp.int32)
    logits, cache = _forward_cached(params, batch, cfg, cache, q_offset=pos)
    return logits[:, -1], cache


def prefill_full(params, batch, cfg, cache):
    """Prefill returning logits at EVERY prompt position: (B, S, V).

    The continuous-batching engine pads prompts to a bucket length to bound
    prefill recompiles; it reads the logits at each request's true last
    prompt token, so it needs the whole sequence of logits.  An optional
    ``batch["plens"]`` (B,) carries each row's TRUE prompt length — ignored
    by full caches (pad-tail entries hide behind the per-row ``kv_len``
    mask) but required to fill ring-buffer window caches per row.
    """
    plens = batch.get("plens")
    batch = {k: v for k, v in batch.items() if k != "plens"}
    return _forward_cached(params, batch, cfg, cache, q_offset=0,
                           plens=plens)


def _forward_cached_slots(params, batch, cfg, cache, slot_positions,
                          slot_done=None):
    x = embed_inputs(params, batch, cfg)
    B, S = x.shape[:2]
    positions = slot_positions[:, None]
    if cfg.rope == "mrope":
        positions = jnp.broadcast_to(positions[None], (3, B, S))
    new_cache = {}
    if "dense_blocks" in params:
        x, _, nc = _run_group(x, params["dense_blocks"], cfg, positions,
                              moe=False, caches=cache["dense"],
                              slot_positions=slot_positions,
                              slot_done=slot_done)
        new_cache["dense"] = nc
    if "moe_blocks" in params:
        x, _, nc = _run_group(x, params["moe_blocks"], cfg, positions,
                              moe=True, caches=cache["moe"],
                              slot_positions=slot_positions,
                              slot_done=slot_done)
        new_cache["moe"] = nc
    x = apply_norm(x, params["final_norm"], cfg.norm)
    return _head(params, x, cfg), new_cache


def decode_step_slots(params, tokens, positions, cache, cfg, done=None):
    """Continuous-batching decode: one token per slot at per-slot lengths.

    tokens: (B,) int32 — the last generated token of each slot;
    positions: (B,) int32 — each slot's current length (the write position
    of this step's K/V);
    done: optional (B,) bool — finished/idle rows; they attend with
    ``kv_len == 0`` (the idle-row short-circuit) and their cache write is a
    bit-identical re-store, so the macro-step scan can keep running them as
    no-ops.  Returns (logits (B, V), new_cache).
    """
    batch = {"tokens": tokens[:, None], "positions": positions[:, None]}
    logits, cache = _forward_cached_slots(params, batch, cfg, cache,
                                          positions, slot_done=done)
    return logits[:, -1], cache


def verify_step_slots(params, tokens, positions, cache, cfg, done=None):
    """Speculative verify: feed an (B, S) token chunk per slot, each row
    starting at its own committed length ``positions[b]``, in ONE batched
    forward — the parallel target pass of speculative decoding.

    Returns (logits (B, S, V), pending): ``logits[:, j]`` is the
    distribution after each row consumed its chunk prefix ``[:j + 1]``.
    The slot cache is READ-ONLY here; ``pending`` carries the chunk's
    per-layer K/V (latents for MLA) so ``commit_slots`` can scatter
    exactly the accepted prefix afterwards — speculative rollback is
    "never wrote it", not "undo it", for every KV layout including
    ring-buffer windows.  ``done`` rows attend nothing and return
    garbage logits the caller must mask.
    """
    B, S = tokens.shape
    batch = {"tokens": tokens}
    pos2d = positions[:, None] + jnp.arange(S, dtype=jnp.int32)[None]
    if cfg.learned_pos:
        # clamp keeps speculative overshoot past the position table legal;
        # overshot positions are never committed (budget-masked)
        batch["positions"] = jnp.minimum(pos2d, cfg.learned_pos - 1)
    x = embed_inputs(params, batch, cfg)
    pos = pos2d
    if cfg.rope == "mrope":
        pos = jnp.broadcast_to(pos2d[None], (3, B, S))
    pending = {}
    if "dense_blocks" in params:
        x, _, pd = _run_group(x, params["dense_blocks"], cfg, pos,
                              moe=False, caches=cache["dense"],
                              chunk_offsets=positions, slot_done=done)
        pending["dense"] = pd
    if "moe_blocks" in params:
        x, _, pd = _run_group(x, params["moe_blocks"], cfg, pos,
                              moe=True, caches=cache["moe"],
                              chunk_offsets=positions, slot_done=done)
        pending["moe"] = pd
    x = apply_norm(x, params["final_norm"], cfg.norm)
    return _head(params, x, cfg), pending


def commit_slots(params, tokens, positions, n_feed, cache, pending, cfg,
                 done=None):
    """Commit each row's accepted chunk prefix: scatter the pending K/V of
    chunk indices ``j < n_feed[b]`` at ``positions[b] + j`` (``% ring``
    for ring-buffer layouts) and drop the rest — rejected speculative
    positions never reach the cache, so KV truncation is implicit in the
    row's committed length.  Rows with ``n_feed == 0`` (or ``done``) are
    untouched bit-for-bit: their scatter indices are all out of range.
    """
    del params, tokens
    if done is not None:
        n_feed = jnp.where(done, 0, n_feed)
    leaf0 = jax.tree.leaves(pending)[0]
    B, S = leaf0.shape[1], leaf0.shape[2]
    pos = positions[:, None] + jnp.arange(S, dtype=jnp.int32)[None]
    committed = jnp.arange(S)[None] < n_feed[:, None]
    b_idx = jnp.arange(B)[:, None]

    def per_leaf(cl, pl):
        # cl: (L, B, Sc, ...) cache; pl: (L, B, S, ...) chunk pending.
        # ``pos % Sc`` is the ring slot for wrapping window caches and the
        # identity for full layouts (committed positions are < Sc by the
        # engine's max_len admission bound); uncommitted rows target the
        # out-of-range index Sc and are dropped by the scatter.
        Sc = cl.shape[2]
        idx = jnp.where(committed, pos % Sc, Sc)
        return jax.vmap(
            lambda c, ch: c.at[b_idx, idx].set(ch.astype(c.dtype)))(cl, pl)

    def per_paged_group(cg, pg):
        # cg: {leaf arenas (L, n_pages, page, ...), "bt": (L, B, nblk)};
        # pg: matching (L, B, S, ...) leaves — pending never carries a
        # table.  Leaves are k/v for KV layouts, ckv/kr for MLA latents.
        # Chunk position ``pos`` resolves to page ``bt[b, (pos % ring) //
        # page]`` (ring == the logical length, so the mod is the identity
        # for full layouts); rejected positions — and rows whose block
        # was never allocated — redirect to the page sentinel and drop.
        leaves = [key for key in cg if key != "bt"]
        n_pages, page = cg[leaves[0]].shape[1:3]
        bt = cg["bt"][0]  # layers share one table
        ring = bt.shape[1] * page
        sidx = pos % ring
        pid = jnp.take_along_axis(bt, sidx // page, axis=1)  # (B, S)
        pid = jnp.where(committed, pid, n_pages)
        off = sidx % page
        out = {"bt": cg["bt"]}
        for key in leaves:
            out[key] = jax.vmap(
                lambda c, ch: c.at[pid, off].set(ch.astype(c.dtype),
                                                 mode="drop"))(
                cg[key], pg[key])
        return out

    def walk(cg, pg):
        if isinstance(cg, dict) and "bt" in cg:
            return per_paged_group(cg, pg)
        if isinstance(cg, dict):
            return {key: walk(cg[key], pg[key]) for key in cg}
        return per_leaf(cg, pg)

    return walk(cache, pending)


def serve_supported(cfg):
    """Capability probe for the continuous-batching slot-decode protocol.

    Returns (ok, detail): ``detail`` names the slot cache layout when
    servable, or the reason when not.
    """
    if not cfg.causal or cfg.continuous_inputs:
        return False, ("requires a causal token LM "
                       f"(causal={cfg.causal}, "
                       f"continuous_inputs={cfg.continuous_inputs})")
    if cfg.mla and cfg.window:
        return False, "MLA latent caches have no ring-buffer window layout"
    if cfg.mla:
        return True, "full MLA latent cache (O(max_len) per slot)"
    if cfg.window:
        return True, "ring-buffer window KV cache (O(window) per slot)"
    return True, "full KV cache (O(max_len) per slot)"


def paged_groups(cfg):
    """Slot-state protocol: every transformer cache group pages on its
    sequence axis — K/V for standard attention, the compressed ckv/kr
    latents for MLA (both share one S axis and one block table)."""
    leaves = ("ckv", "kr") if cfg.mla else ("k", "v")
    n_dense = cfg.moe_layer_start if cfg.moe else cfg.n_layers
    out = {}
    if n_dense:
        out["dense"] = ("seq", leaves)
    if cfg.n_layers - n_dense:
        out["moe"] = ("seq", leaves)
    return out


def slot_cache_layout(cfg):
    """Slot-pool layout tag for benchmarks/telemetry.  A ``+kernel``
    suffix marks configs whose slot decode / chunk verify runs through
    the Pallas kernel family (``cfg.decode_kernel != "jnp"``); MLA latent
    caches always use the jnp absorbed-weight path."""
    if cfg.mla:
        return "full-mla"
    base = "ring" if cfg.window else "full"
    if _kernel_mode(cfg) is not None:
        return base + "+kernel"
    return base


# ============================================================= param specs
def param_specs(cfg):
    """Pytree of logical-axis tuples matching ``init``'s output."""
    specs: dict[str, Any] = {}
    if cfg.continuous_inputs:
        specs["in_proj"] = (None, "embed")
    else:
        specs["embed"] = ("vocab", "embed")
    if cfg.learned_pos:
        specs["pos_embed"] = (None, "embed")

    def attn_specs():
        if cfg.mla:
            return {
                "w_dq": ("layers", "embed", "q_lora"),
                "q_norm": ("layers", "q_lora"),
                "w_uq": ("layers", "q_lora", "heads"),
                "w_dkv": ("layers", "embed", "kv_lora"),
                "kv_norm": ("layers", "kv_lora"),
                "w_kr": ("layers", "embed", None),
                "w_uk": ("layers", "kv_lora", "heads"),
                "w_uv": ("layers", "kv_lora", "heads"),
                "wo": ("layers", "heads", "embed"),
            }
        s = {
            "wq": ("layers", "embed", "heads"),
            "wk": ("layers", "embed", "kv_heads"),
            "wv": ("layers", "embed", "kv_heads"),
            "wo": ("layers", "heads", "embed"),
        }
        if cfg.qkv_bias:
            s["bq"] = ("layers", "heads")
            s["bk"] = ("layers", "kv_heads")
            s["bv"] = ("layers", "kv_heads")
        if cfg.attn_out_bias:
            s["bo"] = ("layers", "embed")
        if cfg.qk_norm:
            s["q_norm"] = ("layers", "head_dim")
            s["k_norm"] = ("layers", "head_dim")
        return s

    def norm_specs():
        s = {"scale": ("layers", "embed")}
        if cfg.norm == "ln":
            s["bias"] = ("layers", "embed")
        return s

    def group_specs(moe):
        g = {"ln1": norm_specs(), "ln2": norm_specs(), "attn": attn_specs()}
        if moe:
            g["moe"] = moe_lib.moe_specs(cfg)
        else:
            g["mlp"] = ffn_lib.mlp_specs(cfg.act, cfg.mlp_bias)
        return g

    n_dense = cfg.moe_layer_start if cfg.moe else cfg.n_layers
    if n_dense:
        specs["dense_blocks"] = group_specs(False)
    if cfg.n_layers - n_dense:
        specs["moe_blocks"] = group_specs(True)

    fn = {"scale": ("embed",)}
    if cfg.norm == "ln":
        fn["bias"] = ("embed",)
    specs["final_norm"] = fn
    if cfg.head == "lm" and not cfg.tie_embeddings:
        specs["head"] = ("embed", "vocab")
    elif cfg.head == "cls":
        specs["cls_token"] = ("embed",)
        specs["head"] = ("embed", None)
    if cfg.mtp:
        specs["mtp"] = {
            "proj": (None, "embed"),
            "norm_h": {"scale": ("embed",)},
            "norm_e": {"scale": ("embed",)},
            "block": group_specs(False),
        }
        if cfg.norm == "ln":
            specs["mtp"]["norm_h"]["bias"] = ("embed",)
            specs["mtp"]["norm_e"]["bias"] = ("embed",)
    return specs


def cache_specs(cfg):
    n_dense = cfg.moe_layer_start if cfg.moe else cfg.n_layers
    n_moe = cfg.n_layers - n_dense

    def one():
        if cfg.mla:
            return {"ckv": ("layers", "batch", "cache_seq", "kv_lora"),
                    "kr": ("layers", "batch", "cache_seq", None)}
        return {"k": ("layers", "batch", "cache_seq", "kv_heads", "head_dim"),
                "v": ("layers", "batch", "cache_seq", "kv_heads", "head_dim")}

    c = {}
    if n_dense:
        c["dense"] = one()
    if n_moe:
        c["moe"] = one()
    return c
