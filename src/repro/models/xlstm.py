"""xLSTM family (arXiv:2405.04517): mLSTM (matrix memory) + sLSTM blocks.

Training/prefill run the mLSTM in *chunkwise-recurrent* form: within a chunk
the contribution is a decay-masked attention-like quadratic form; across
chunks a (dh x dh) matrix state C, normalizer n and stabilizer m are carried
— O(S * chunk) compute, O(1)-in-S state.  Decode is the pure recurrence
(O(1) per token), which is why this arch runs the ``long_500k`` cell.

The sequential recurrence (``mlstm_sequential``) doubles as the test oracle
for the chunkwise form.  sLSTM blocks (true recurrence via block-diagonal R)
run under ``lax.scan`` over time, as in the paper (not parallelizable).
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.distributed.sharding import annotate
from repro.models.common import (
    apply_norm,
    freeze_rows,
    gelu,
    init_norm,
    keygen,
    trunc_normal,
)
from repro.models.griffin import _causal_conv


def block_types(cfg):
    """Per-layer type list: 'm' (mLSTM) or 's' (sLSTM)."""
    if cfg.block_pattern:
        return tuple(cfg.block_pattern)
    out = []
    for i in range(cfg.n_layers):
        if cfg.slstm_every and (i % cfg.slstm_every == cfg.slstm_every - 1):
            out.append("s")
        else:
            out.append("m")
    return tuple(out)


# ===================================================================== init
def init(rng, cfg) -> dict:
    keys = keygen(rng)
    dtype = jnp.dtype(cfg.param_dtype)
    std = 0.02
    D, NH = cfg.d_model, cfg.n_heads
    di = int(cfg.proj_factor * D)  # mLSTM inner dim
    types = block_types(cfg)
    n_m = sum(1 for t in types if t == "m")
    n_s = len(types) - n_m

    params: dict[str, Any] = {
        "embed": trunc_normal(next(keys), (cfg.vocab_size, D), std, dtype),
    }
    params["m_blocks"] = {
        "ln": init_norm(cfg.norm, D, n_m, dtype),
        "w_up": trunc_normal(next(keys), (n_m, D, 2 * di), std, dtype),
        "conv_w": trunc_normal(next(keys), (n_m, cfg.conv_width, di), std,
                               dtype),
        "conv_b": jnp.zeros((n_m, di), dtype),
        "w_q": trunc_normal(next(keys), (n_m, di, di), std, dtype),
        "w_k": trunc_normal(next(keys), (n_m, di, di), std, dtype),
        "w_v": trunc_normal(next(keys), (n_m, di, di), std, dtype),
        "w_if": trunc_normal(next(keys), (n_m, di, 2 * NH), std, dtype),
        "b_if": jnp.zeros((n_m, 2 * NH), dtype),
        "gn": jnp.ones((n_m, di), dtype),  # per-head group norm scale
        "w_down": trunc_normal(next(keys), (n_m, di, D), std, dtype),
    }
    if n_s:
        dh = D // NH
        pf = 4.0 / 3.0
        dff = int(pf * D)
        params["s_blocks"] = {
            "ln": init_norm(cfg.norm, D, n_s, dtype),
            "conv_w": trunc_normal(next(keys), (n_s, cfg.conv_width, D), std,
                                   dtype),
            "conv_b": jnp.zeros((n_s, D), dtype),
            "w_gates": trunc_normal(next(keys), (n_s, D, 4 * D), std, dtype),
            "r_gates": trunc_normal(next(keys), (n_s, NH, dh, 4 * dh),
                                    std, dtype),
            "b_gates": jnp.zeros((n_s, 4 * D), dtype),
            "gn": jnp.ones((n_s, D), dtype),
            "w_up1": trunc_normal(next(keys), (n_s, D, dff), std, dtype),
            "w_up2": trunc_normal(next(keys), (n_s, D, dff), std, dtype),
            "w_down": trunc_normal(next(keys), (n_s, dff, D), std, dtype),
        }
    params["final_norm"] = init_norm(cfg.norm, D, None, dtype)
    if not cfg.tie_embeddings:
        params["head"] = trunc_normal(next(keys), (D, cfg.vocab_size), std,
                                      dtype)
    return params


# ---------------------------------------------------- paged conv tails
def _conv_tail_gather(arena, bt):
    """Dense per-slot view of a paged conv tail (per layer).

    arena: (n_pages, K-1, d); bt: (B, 1) single-block table (the whole
    shift tail is one page).  Sentinel ids clamp to the last page — the
    garbage tail that produces belongs to rows whose state writes are
    dropped and whose logits the engine masks.
    """
    n_pages = arena.shape[0]
    return arena[jnp.minimum(bt[:, 0], n_pages - 1)]


def _conv_tail_scatter(arena, bt, tail, done=None):
    """Write each live row's new conv tail back to its page; ``done``
    rows (and never-allocated sentinel blocks) drop — the paged freeze."""
    n_pages = arena.shape[0]
    pid = bt[:, 0]
    if done is not None:
        pid = jnp.where(done, n_pages, pid)
    return arena.at[pid].set(tail.astype(arena.dtype), mode="drop")


# ============================================================== mLSTM cell
def _group_norm(x, scale, nh, eps=1e-6):
    """Per-head RMS-style groupnorm. x: (..., di)."""
    shp = x.shape
    xh = x.reshape(*shp[:-1], nh, shp[-1] // nh).astype(jnp.float32)
    var = jnp.mean(jnp.square(xh), axis=-1, keepdims=True)
    xh = xh * jax.lax.rsqrt(var + eps)
    return (xh.reshape(shp) * scale.astype(jnp.float32)).astype(x.dtype)


def mlstm_chunkwise(q, k, v, log_i, log_f, state=None, chunk=256,
                    unroll=False):
    """Chunkwise mLSTM.

    q,k,v: (B,NH,S,dh) — q pre-scaled by dh**-0.5.
    log_i, log_f: (B,NH,S) f32 gate log-activations.
    state: None or (C (B,NH,dh,dh), n (B,NH,dh), m (B,NH)) f32.
    Returns (h (B,NH,S,dh), final state).
    """
    B, NH, S, dh = q.shape
    if S % chunk != 0:
        chunk = S  # single chunk fallback
    nc = S // chunk

    def to_chunks(x):
        return x.reshape(B, NH, nc, chunk, *x.shape[3:]).transpose(
            2, 0, 1, 3, *range(4, x.ndim + 1))

    qc, kc, vc = to_chunks(q), to_chunks(k), to_chunks(v)
    lic, lfc = to_chunks(log_i), to_chunks(log_f)

    if state is None:
        C0 = jnp.zeros((B, NH, dh, dh), jnp.float32)
        n0 = jnp.zeros((B, NH, dh), jnp.float32)
        m0 = jnp.full((B, NH), -1e30, jnp.float32)
    else:
        C0, n0, m0 = state

    def chunk_body(carry, xs):
        C, n, m = carry
        qj, kj, vj, li, lf = xs  # (B,NH,T,...) / (B,NH,T)
        b = jnp.cumsum(lf, axis=-1)  # inclusive forget cumsum (B,NH,T)
        btot = b[..., -1]
        # per-step stabilizer: m_t = max(m_prev + b_t, b_t + max_{s<=t}(li_s - b_s))
        run_max = jax.lax.associative_scan(jnp.maximum, li - b, axis=-1)
        m_t = jnp.maximum(m[..., None] + b, b + run_max)
        m_intra = jnp.max(li - b, axis=-1)  # max_s (log_i_s - b_s)
        # inter-chunk part
        scale_inter = jnp.exp(m[..., None] + b - m_t)  # (B,NH,T)
        qf = qj.astype(jnp.float32)
        kf = kj.astype(jnp.float32)
        vf = vj.astype(jnp.float32)
        h_inter = jnp.einsum("bhtd,bhde->bhte", qf, C) * scale_inter[..., None]
        d_inter = jnp.einsum("bhtd,bhd->bht", qf, n) * scale_inter
        # intra-chunk decay matrix  D[t,s] = exp(b_t - b_s + li_s - m_t)
        dmat = b[..., :, None] - b[..., None, :] + li[..., None, :] \
            - m_t[..., :, None]
        tri = jnp.tril(jnp.ones((chunk, chunk), bool))
        dmat = jnp.where(tri, dmat, -jnp.inf)
        dexp = jnp.exp(dmat)
        scores = jnp.einsum("bhtd,bhsd->bhts", qf, kf) * dexp
        h_intra = jnp.einsum("bhts,bhse->bhte", scores, vf)
        d_intra = jnp.sum(scores, axis=-1)
        denom = jnp.maximum(jnp.abs(d_inter + d_intra), jnp.exp(-m_t))
        h = (h_inter + h_intra) / denom[..., None]
        # state update to end of chunk
        m_next = jnp.maximum(m + btot, btot + m_intra)
        sc_old = jnp.exp(m + btot - m_next)  # (B,NH)
        sc_new = jnp.exp(btot[..., None] - b + li - m_next[..., None])
        C_new = (C * sc_old[..., None, None]
                 + jnp.einsum("bht,bhtd,bhte->bhde", sc_new, kf, vf))
        n_new = n * sc_old[..., None] + jnp.einsum("bht,bhtd->bhd", sc_new, kf)
        return (C_new, n_new, m_next), h.astype(q.dtype)

    (C, n, m), hs = jax.lax.scan(chunk_body, (C0, n0, m0),
                                 (qc, kc, vc, lic, lfc), unroll=unroll)
    h = hs.transpose(1, 2, 0, 3, 4).reshape(B, NH, S, dh)
    return h, (C, n, m)


def mlstm_sequential(q, k, v, log_i, log_f, state=None):
    """Step-by-step oracle (and decode path for S==1)."""
    B, NH, S, dh = q.shape
    if state is None:
        C = jnp.zeros((B, NH, dh, dh), jnp.float32)
        n = jnp.zeros((B, NH, dh), jnp.float32)
        m = jnp.full((B, NH), -1e30, jnp.float32)
    else:
        C, n, m = state

    def step(carry, xs):
        C, n, m = carry
        qt, kt, vt, li, lf = xs
        qt, kt, vt = (a.astype(jnp.float32) for a in (qt, kt, vt))
        m_new = jnp.maximum(lf + m, li)
        i_p = jnp.exp(li - m_new)
        f_p = jnp.exp(lf + m - m_new)
        C = f_p[..., None, None] * C + i_p[..., None, None] * (
            kt[..., :, None] * vt[..., None, :])
        n = f_p[..., None] * n + i_p[..., None] * kt
        num = jnp.einsum("bhd,bhde->bhe", qt, C)
        den = jnp.maximum(jnp.abs(jnp.einsum("bhd,bhd->bh", qt, n)),
                          jnp.exp(-m_new))
        h = num / den[..., None]
        return (C, n, m_new), h

    xs = (q.transpose(2, 0, 1, 3), k.transpose(2, 0, 1, 3),
          v.transpose(2, 0, 1, 3), log_i.transpose(2, 0, 1),
          log_f.transpose(2, 0, 1))
    (C, n, m), hs = jax.lax.scan(step, (C, n, m), xs)
    return hs.transpose(1, 2, 0, 3).astype(q.dtype), (C, n, m)


def _mlstm_block(x, bp, cfg, cache=None, chunkwise=True, plens=None,
                 done=None):
    """x: (B,S,D). cache: {"conv": (B,K-1,di), "C","n","m"} or None.

    ``plens`` (B,): bucketed admission prefill — pad positions freeze the
    recurrence exactly (input gate -> exp(-inf) = 0 contribution, forget
    log -> 0 decay) and the conv tail is gathered at each row's true
    boundary, so the carried (C, n, m) is the state after the REAL prompt.
    ``done`` (B,): slot-decode rows whose state must not advance.
    """
    B, S, D = x.shape
    NH = cfg.n_heads
    di = int(cfg.proj_factor * D)
    dh = di // NH
    xin = apply_norm(x, bp["ln"], cfg.norm)
    up = jnp.einsum("bsd,du->bsu", xin, bp["w_up"].astype(x.dtype))
    xi, z = up[..., :di], up[..., di:]
    xi = annotate(xi, ("batch", "seq", "lru"))
    conv_state = None
    if cache is not None:
        conv_state = (_conv_tail_gather(cache["conv"], cache["bt"])
                      if "bt" in cache else cache["conv"])
    c, new_conv = _causal_conv(xi, bp["conv_w"], bp["conv_b"], conv_state,
                               lengths=plens)
    c = jax.nn.silu(c)
    q = jnp.einsum("bsu,uv->bsv", c, bp["w_q"].astype(x.dtype))
    k = jnp.einsum("bsu,uv->bsv", c, bp["w_k"].astype(x.dtype))
    v = jnp.einsum("bsu,uv->bsv", xi, bp["w_v"].astype(x.dtype))

    def heads(a):
        return a.reshape(B, S, NH, dh).transpose(0, 2, 1, 3)

    q, k, v = heads(q) * (dh ** -0.5), heads(k), heads(v)
    gates = jnp.einsum("bsu,ug->bsg", c.astype(jnp.float32),
                       bp["w_if"].astype(jnp.float32)) \
        + bp["b_if"].astype(jnp.float32)
    gates = gates.reshape(B, S, 2, NH).transpose(2, 0, 3, 1)  # (2,B,NH,S)
    log_i, log_f = gates[0], jax.nn.log_sigmoid(gates[1])
    if plens is not None:
        valid = (jnp.arange(S)[None] < plens[:, None])[:, None, :]  # (B,1,S)
        log_i = jnp.where(valid, log_i, -jnp.inf)
        log_f = jnp.where(valid, log_f, 0.0)

    state = None
    if cache is not None:
        state = (cache["C"], cache["n"], cache["m"])
    if S == 1 or not chunkwise:
        h, state = mlstm_sequential(q, k, v, log_i, log_f, state)
    else:
        h, state = mlstm_chunkwise(q, k, v, log_i, log_f, state,
                                   chunk=min(cfg.attn_chunk, 256),
                                   unroll=cfg.unroll_scans)
    h = h.transpose(0, 2, 1, 3).reshape(B, S, di)
    h = _group_norm(h, bp["gn"], NH)
    h = h * jax.nn.silu(z)
    out = jnp.einsum("bsu,ud->bsd", h, bp["w_down"].astype(x.dtype))
    x = annotate(x + out, ("batch", "seq", "embed"))
    nc = None
    if cache is not None:
        nc = {"conv": new_conv, "C": state[0], "n": state[1], "m": state[2]}
        if "bt" in cache:
            dense = {k: nc[k] for k in ("C", "n", "m")}
            if done is not None:
                dense = freeze_rows({k: cache[k] for k in dense}, dense,
                                    done)
            nc = {"conv": _conv_tail_scatter(cache["conv"], cache["bt"],
                                             new_conv, done=done),
                  "bt": cache["bt"], **dense}
        elif done is not None:
            nc = freeze_rows(cache, nc, done)
    return x, nc


# ============================================================== sLSTM cell
def _slstm_block(x, bp, cfg, cache=None, plens=None, done=None):
    """Sequential sLSTM block. x: (B,S,D).

    ``plens``: pad positions of a bucketed admission prefill freeze the
    carried (c, n, h, m) in-scan — the hidden-state recurrence would
    otherwise absorb the padding.  ``done``: slot rows frozen wholesale.
    """
    B, S, D = x.shape
    NH = cfg.n_heads
    dh = D // NH
    xin = apply_norm(x, bp["ln"], cfg.norm)
    conv_state = None
    if cache is not None:
        conv_state = (_conv_tail_gather(cache["conv"], cache["bt"])
                      if "bt" in cache else cache["conv"])
    c_in, new_conv = _causal_conv(xin, bp["conv_w"], bp["conv_b"], conv_state,
                                  lengths=plens)
    c_in = jax.nn.silu(c_in)
    # gate pre-activations from inputs (i,f from conv branch; z,o direct)
    wx = jnp.einsum("bsd,dg->bsg", xin.astype(jnp.float32),
                    bp["w_gates"].astype(jnp.float32))
    wc = jnp.einsum("bsd,dg->bsg", c_in.astype(jnp.float32),
                    bp["w_gates"].astype(jnp.float32))
    # use conv features for i,f; direct for z,o (xLSTM Fig. 11)
    pre = jnp.concatenate([wc[..., :2 * D], wx[..., 2 * D:]], -1) \
        + bp["b_gates"].astype(jnp.float32)
    pre = pre.reshape(B, S, 4, NH, dh)

    r = bp["r_gates"].astype(jnp.float32)  # (NH, dh, 4*dh)

    if cache is None:
        cs = jnp.zeros((B, NH, dh), jnp.float32)
        ns = jnp.zeros((B, NH, dh), jnp.float32)
        hs = jnp.zeros((B, NH, dh), jnp.float32)
        ms = jnp.full((B, NH, dh), -1e30, jnp.float32)
    else:
        cs, ns, hs, ms = cache["c"], cache["n"], cache["h"], cache["m"]

    def step(carry, xs):
        cs, ns, hs, ms = carry
        pre_t, valid_t = xs
        rec = jnp.einsum("bhd,hdg->bhg", hs, r).reshape(B, NH, 4, dh)
        rec = rec.transpose(0, 2, 1, 3)  # (B,4,NH,dh)
        g = pre_t.astype(jnp.float32) + rec
        li = g[:, 0]
        lf = jax.nn.log_sigmoid(g[:, 1])
        z = jnp.tanh(g[:, 2])
        o = jax.nn.sigmoid(g[:, 3])
        m_new = jnp.maximum(lf + ms, li)
        i_p = jnp.exp(li - m_new)
        f_p = jnp.exp(lf + ms - m_new)
        cs_n = f_p * cs + i_p * z
        ns_n = f_p * ns + i_p
        h = o * cs_n / jnp.maximum(ns_n, 1e-6)
        if valid_t is not None:  # freeze the carry across padded positions
            keep = valid_t[:, None, None]
            cs_n = jnp.where(keep, cs_n, cs)
            ns_n = jnp.where(keep, ns_n, ns)
            h_c = jnp.where(keep, h, hs)
            m_new = jnp.where(keep, m_new, ms)
            return (cs_n, ns_n, h_c, m_new), h
        return (cs_n, ns_n, h, m_new), h

    valid = None
    if plens is not None:
        valid = (jnp.arange(S)[None] < plens[:, None]).T  # (S,B)
    (cs, ns, hs, ms), hseq = jax.lax.scan(
        step, (cs, ns, hs, ms), (pre.transpose(1, 0, 2, 3, 4), valid))
    h = hseq.transpose(1, 0, 2, 3).reshape(B, S, D).astype(x.dtype)
    h = _group_norm(h, bp["gn"], NH)
    # gated up/down MLP (pf = 4/3)
    u1 = jnp.einsum("bsd,df->bsf", h, bp["w_up1"].astype(x.dtype))
    u2 = jnp.einsum("bsd,df->bsf", h, bp["w_up2"].astype(x.dtype))
    out = jnp.einsum("bsf,fd->bsd", gelu(u1) * u2,
                     bp["w_down"].astype(x.dtype))
    x = annotate(x + out, ("batch", "seq", "embed"))
    nc = None
    if cache is not None:
        nc = {"conv": new_conv, "c": cs, "n": ns, "h": hs, "m": ms}
        if "bt" in cache:
            dense = {k: nc[k] for k in ("c", "n", "h", "m")}
            if done is not None:
                dense = freeze_rows({k: cache[k] for k in dense}, dense,
                                    done)
            nc = {"conv": _conv_tail_scatter(cache["conv"], cache["bt"],
                                             new_conv, done=done),
                  "bt": cache["bt"], **dense}
        elif done is not None:
            nc = freeze_rows(cache, nc, done)
    return x, nc


# ================================================================= forward
def _run_blocks(params, x, cfg, caches=None, plens=None, done=None):
    from repro.models.common import slice_layers

    types = block_types(cfg)
    new_caches = {"m": [], "s": []} if caches is not None else None
    runs = []
    counts = {"m": 0, "s": 0}
    i = 0
    while i < len(types):
        j = i
        while j < len(types) and types[j] == types[i]:
            j += 1
        runs.append((types[i], counts[types[i]], j - i))
        counts[types[i]] += j - i
        i = j

    valid = None
    if plens is not None:
        # bucketed admission prefill: pad positions of the residual stream
        # are zeroed after every block — the mLSTM stabilizer degenerates
        # on all-masked pad queries (inf denominators), and a NaN at a pad
        # position must never reach the next block's K/V products (where
        # 0 * NaN would poison the carried state)
        valid = (jnp.arange(x.shape[1])[None] < plens[:, None])[..., None]

    for typ, start, count in runs:
        key = "m_blocks" if typ == "m" else "s_blocks"
        group = slice_layers(params[key], start, start + count)
        fn = _mlstm_block if typ == "m" else _slstm_block

        def body(carry, xs, fn=fn):
            xc = carry
            if caches is None:
                bp, cache_l = xs, None
            else:
                bp, cache_l = xs
            xc, nc = fn(xc, bp, cfg, cache=cache_l, plens=plens, done=done)
            if valid is not None:
                xc = jnp.where(valid, xc, 0.0)
            return xc, nc

        if cfg.remat == "block":
            body = jax.remat(body, prevent_cse=False)
        xs = group
        if caches is not None:
            ckey = typ
            xs = (group, slice_layers(caches[ckey], start, start + count))
        x, ncs = jax.lax.scan(body, x, xs, unroll=cfg.unroll_scans)
        if caches is not None:
            new_caches[typ].append(ncs)

    if caches is not None:
        out = {}
        for t in ("m", "s"):
            if new_caches[t]:
                out[t] = jax.tree.map(
                    lambda *xs: jnp.concatenate(xs, 0), *new_caches[t])
        return x, out
    return x


def forward(params, batch, cfg):
    cdt = jnp.dtype(cfg.compute_dtype)
    x = params["embed"].astype(cdt)[batch["tokens"]]
    x = _run_blocks(params, x, cfg)
    x = apply_norm(x, params["final_norm"], cfg.norm)
    w = params["embed"].T if cfg.tie_embeddings else params["head"]
    logits = jnp.einsum("bsd,dv->bsv", x, w.astype(cdt))
    return annotate(logits, ("batch", "seq", "vocab")), {"moe_aux": 0.0}


def init_cache(cfg, batch_size, max_len, dtype=None):
    dtype = dtype or jnp.dtype(cfg.compute_dtype)
    types = block_types(cfg)
    n_m = sum(1 for t in types if t == "m")
    n_s = len(types) - n_m
    D, NH = cfg.d_model, cfg.n_heads
    di = int(cfg.proj_factor * D)
    dh = di // NH
    K = cfg.conv_width
    cache = {
        "m": {
            "conv": jnp.zeros((n_m, batch_size, K - 1, di), dtype),
            "C": jnp.zeros((n_m, batch_size, NH, dh, dh), jnp.float32),
            "n": jnp.zeros((n_m, batch_size, NH, dh), jnp.float32),
            "m": jnp.full((n_m, batch_size, NH), -1e30, jnp.float32),
        }
    }
    if n_s:
        dhs = D // NH
        cache["s"] = {
            "conv": jnp.zeros((n_s, batch_size, K - 1, D), dtype),
            "c": jnp.zeros((n_s, batch_size, NH, dhs), jnp.float32),
            "n": jnp.zeros((n_s, batch_size, NH, dhs), jnp.float32),
            "h": jnp.zeros((n_s, batch_size, NH, dhs), jnp.float32),
            "m": jnp.full((n_s, batch_size, NH, dhs), -1e30, jnp.float32),
        }
    return cache


def _forward_cached(params, batch, cfg, cache, q_offset, plens=None,
                    done=None):
    cdt = jnp.dtype(cfg.compute_dtype)
    x = params["embed"].astype(cdt)[batch["tokens"]]
    x, new_cache = _run_blocks(params, x, cfg, caches=cache, plens=plens,
                               done=done)
    x = apply_norm(x, params["final_norm"], cfg.norm)
    w = params["embed"].T if cfg.tie_embeddings else params["head"]
    return jnp.einsum("bsd,dv->bsv", x, w.astype(cdt)), new_cache


def prefill(params, batch, cfg, cache):
    logits, cache = _forward_cached(params, batch, cfg, cache, 0)
    return logits[:, -1], cache


def decode_step(params, tokens, pos, cache, cfg):
    logits, cache = _forward_cached(
        params, {"tokens": tokens[:, None]}, cfg, cache, pos)
    return logits[:, -1], cache


def prefill_full(params, batch, cfg, cache):
    """Admission prefill: logits at EVERY position + per-row final state.

    ``batch["plens"]`` (B,) carries each row's true prompt length: pad
    positions contribute exp(-inf) = 0 to the mLSTM state with unit
    forget decay, sLSTM carries freeze in-scan, and conv tails are
    gathered at the row boundary — the returned (C, n, m, conv, ...)
    is the state after each row's REAL prompt.
    """
    plens = batch.get("plens")
    batch = {k: v for k, v in batch.items() if k != "plens"}
    return _forward_cached(params, batch, cfg, cache, 0, plens=plens)


def decode_step_slots(params, tokens, positions, cache, cfg, done=None):
    """Continuous-batching decode: one token per slot, O(1) state per row.

    ``positions`` is accepted for protocol uniformity but unused — the
    xLSTM recurrence is position-free.  Rows flagged ``done`` FREEZE
    their entire per-slot state (C/n/m, sLSTM carries, conv tails): a
    recurrent update is irreversible, so the macro-step loop's no-op
    steps must not advance it.  Returns (logits (B, V), new_cache).
    """
    del positions
    logits, new_cache = _forward_cached(
        params, {"tokens": tokens[:, None]}, cfg, cache, 0, done=done)
    return logits[:, -1], new_cache


def _dense_state_view(cache):
    """Per-slot dense view of a (possibly paged) xlstm slot cache: paged
    conv arenas gather back to (L, B, K-1, d) through their single-block
    tables; everything else passes through.  The speculative hooks stack
    and gather THIS view — per-slot snapshots, not per-page arenas."""
    out = {}
    for gk, gv in cache.items():
        if "bt" in gv:
            n_pages = gv["conv"].shape[1]
            pid = jnp.minimum(gv["bt"][0][:, 0], n_pages - 1)
            dense = {k: v for k, v in gv.items() if k != "bt"}
            dense["conv"] = gv["conv"][:, pid]
            out[gk] = dense
        else:
            out[gk] = gv
    return out


def verify_step_slots(params, tokens, positions, cache, cfg, done=None):
    """Speculative verify for the recurrent slot layout: one fused scan of
    the single-token slot decode over the chunk, stacking the per-step
    O(1) slot state (mLSTM C/n/m, sLSTM carries, conv tails — every xlstm
    leaf is O(1)/slot, so stacking all of them is cheap) so
    ``commit_slots`` can roll every row back to its accepted boundary.
    Paged pools stack the per-slot DENSE view (conv tails gathered
    through the block table) — snapshots are per slot, never per page.
    Bit-identical to sequential decode by construction."""
    from repro.models.common import spec_verify_scan
    paged = any("bt" in g for g in cache.values())
    logits, stacked, _ = spec_verify_scan(
        decode_step_slots, params, tokens, positions, cache, cfg,
        done=done, stack_filter=_dense_state_view if paged else None)
    return logits, stacked


def commit_slots(params, tokens, positions, n_feed, cache, pending, cfg,
                 done=None):
    """Commit = gather the stacked verify states at ``n_feed - 1`` per row;
    rows with ``n_feed == 0`` or flagged ``done`` keep their pre-chunk
    state (a recurrent update cannot be re-stored, so rollback is a
    snapshot gather, not a truncation).  Paged pools gather in the dense
    per-slot view, then scatter the committed conv tails back to their
    pages (kept rows re-store their own gathered bytes; evicted rows'
    sentinel blocks drop)."""
    from repro.models.common import spec_commit_gather
    del params, tokens, positions
    if not any("bt" in g for g in cache.values()):
        return spec_commit_gather(cache, pending, n_feed, done=done)
    committed = spec_commit_gather(_dense_state_view(cache), pending,
                                   n_feed, done=done)
    out = {}
    for gk, gv in cache.items():
        grp = dict(committed[gk])
        if "bt" in gv:
            grp["conv"] = jax.vmap(_conv_tail_scatter)(
                gv["conv"], gv["bt"], grp["conv"])
            grp["bt"] = gv["bt"]
        out[gk] = grp
    return out


def serve_supported(cfg):
    """Capability probe for the continuous-batching slot-decode protocol."""
    return True, ("recurrent state (O(1) per slot: mLSTM C/n/m + conv "
                  "tails, sLSTM c/n/h/m)")


def slot_cache_layout(cfg):
    return "recurrent"


def paged_groups(cfg):
    """Slot-state protocol: the conv shift tails page (one single-entry
    block per slot — the tail has no sequence axis, so the whole K-1
    window is its page); the mLSTM C/n/m and sLSTM carries stay
    dense-per-slot (O(1) matrix/vector state, nothing to page)."""
    types = block_types(cfg)
    out = {}
    if any(t == "m" for t in types):
        out["m"] = ("slot", ("conv",))
    if any(t == "s" for t in types):
        out["s"] = ("slot", ("conv",))
    return out


def cache_specs(cfg):
    types = block_types(cfg)
    n_s = sum(1 for t in types if t == "s")
    c = {"m": {
        "conv": ("layers", "batch", None, "lru"),
        "C": ("layers", "batch", None, None, "lru"),
        "n": ("layers", "batch", None, "lru"),
        "m": ("layers", "batch", None),
    }}
    if n_s:
        c["s"] = {"conv": ("layers", "batch", None, "embed"),
                  "c": ("layers", "batch", None, None),
                  "n": ("layers", "batch", None, None),
                  "h": ("layers", "batch", None, None),
                  "m": ("layers", "batch", None, None)}
    return c


# ============================================================== param specs
def param_specs(cfg):
    types = block_types(cfg)
    n_s = sum(1 for t in types if t == "s")
    L = ("layers",)

    def norm_spec(layered=True):
        s = {"scale": (L + ("embed",)) if layered else ("embed",)}
        if cfg.norm == "ln":
            s["bias"] = s["scale"]
        return s

    specs = {
        "embed": ("vocab", "embed"),
        "m_blocks": {
            "ln": norm_spec(),
            "w_up": L + ("embed", "lru"),
            "conv_w": L + (None, "lru"),
            "conv_b": L + ("lru",),
            "w_q": L + ("lru", "lru"),
            "w_k": L + ("lru", "lru"),
            "w_v": L + ("lru", "lru"),
            "w_if": L + ("lru", None),
            "b_if": L + (None,),
            "gn": L + ("lru",),
            "w_down": L + ("lru", "embed"),
        },
        "final_norm": norm_spec(layered=False),
    }
    if n_s:
        specs["s_blocks"] = {
            "ln": norm_spec(),
            "conv_w": L + (None, "embed"),
            "conv_b": L + ("embed",),
            "w_gates": L + ("embed", "mlp"),
            "r_gates": L + (None, None, None),
            "b_gates": L + ("mlp",),
            "gn": L + ("embed",),
            "w_up1": L + ("embed", "mlp"),
            "w_up2": L + ("embed", "mlp"),
            "w_down": L + ("mlp", "embed"),
        }
    if not cfg.tie_embeddings:
        specs["head"] = ("embed", "vocab")
    return specs
