from repro.optim.adamw import (
    adamw_init,
    adamw_update,
    OptimizerConfig,
    make_optimizer,
)
from repro.optim.schedules import cosine_schedule, linear_warmup_cosine
