"""AdamW, hand-rolled (no optax in the container), scale-ready.

Features needed at 1000+ nodes:
  * optional bf16 first/second moments (halves optimizer HBM — the moments
    are pure accumulators and tolerate bf16 at these decay rates);
  * optional f32 master copy when params are stored bf16;
  * global-norm clipping computed in f32;
  * the state pytree mirrors the param pytree leaf-for-leaf, so the
    ZeRO-style sharding rules in ``repro/distributed`` apply verbatim.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptimizerConfig:
    lr: float = 1e-3
    b1: float = 0.9
    b2: float = 0.999
    eps: float = 1e-8
    weight_decay: float = 1e-2
    clip_norm: Optional[float] = 1.0
    moment_dtype: str = "float32"  # bfloat16 at scale
    master_weights: bool = False   # keep f32 master copy of bf16 params


# ------------------------------------------------- minimal functional form
def adamw_init(params):
    zeros = lambda p: jnp.zeros_like(p, dtype=jnp.float32)
    return {"m": jax.tree.map(zeros, params),
            "v": jax.tree.map(zeros, params)}


def adamw_update(params, state, grads, step, *, lr=1e-3, b1=0.9, b2=0.999,
                 eps=1e-8, weight_decay=0.0):
    stepf = step.astype(jnp.float32)
    c1 = 1.0 - b1 ** stepf
    c2 = 1.0 - b2 ** stepf

    def upd(p, m, v, g):
        g = g.astype(jnp.float32)
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * g * g
        mh = m / c1
        vh = v / c2
        p32 = p.astype(jnp.float32)
        p32 = p32 - lr * (mh / (jnp.sqrt(vh) + eps) + weight_decay * p32)
        return p32.astype(p.dtype), m, v

    out = jax.tree.map(upd, params, state["m"], state["v"], grads)
    new_params = jax.tree.map(lambda t: t[0], out,
                              is_leaf=lambda t: isinstance(t, tuple))
    new_m = jax.tree.map(lambda t: t[1], out,
                         is_leaf=lambda t: isinstance(t, tuple))
    new_v = jax.tree.map(lambda t: t[2], out,
                         is_leaf=lambda t: isinstance(t, tuple))
    return new_params, {"m": new_m, "v": new_v}


# -------------------------------------------------- full configurable form
def global_norm(tree):
    return jnp.sqrt(sum(
        jnp.sum(jnp.square(x.astype(jnp.float32)))
        for x in jax.tree.leaves(tree)))


def make_optimizer(cfg: OptimizerConfig, schedule=None):
    """Returns (init_fn(params) -> state, update_fn(params, state, grads,
    step) -> (params, state, metrics))."""
    mdt = jnp.dtype(cfg.moment_dtype)

    def init_fn(params):
        state = {
            "m": jax.tree.map(lambda p: jnp.zeros(p.shape, mdt), params),
            "v": jax.tree.map(lambda p: jnp.zeros(p.shape, mdt), params),
        }
        if cfg.master_weights:
            state["master"] = jax.tree.map(
                lambda p: p.astype(jnp.float32), params)
        return state

    def update_fn(params, state, grads, step):
        stepf = step.astype(jnp.float32)
        lr = cfg.lr if schedule is None else schedule(stepf)
        gnorm = global_norm(grads)
        metrics = {"grad_norm": gnorm, "lr": lr}
        if cfg.clip_norm is not None:
            scale = jnp.minimum(1.0, cfg.clip_norm / (gnorm + 1e-9))
            grads = jax.tree.map(
                lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype),
                grads)
        c1 = 1.0 - cfg.b1 ** stepf
        c2 = 1.0 - cfg.b2 ** stepf
        base = state.get("master", params)

        def upd(p_master, m, v, g):
            g32 = g.astype(jnp.float32)
            m32 = m.astype(jnp.float32)
            v32 = v.astype(jnp.float32)
            m32 = cfg.b1 * m32 + (1 - cfg.b1) * g32
            v32 = cfg.b2 * v32 + (1 - cfg.b2) * g32 * g32
            mh, vh = m32 / c1, v32 / c2
            p32 = p_master.astype(jnp.float32)
            p32 = p32 - lr * (mh / (jnp.sqrt(vh) + cfg.eps)
                              + cfg.weight_decay * p32)
            return p32, m32.astype(mdt), v32.astype(mdt)

        out = jax.tree.map(upd, base, state["m"], state["v"], grads)
        pick = lambda i: jax.tree.map(
            lambda t: t[i], out, is_leaf=lambda t: isinstance(t, tuple))
        p32, new_m, new_v = pick(0), pick(1), pick(2)
        new_state = {"m": new_m, "v": new_v}
        if cfg.master_weights:
            new_state["master"] = p32
        new_params = jax.tree.map(
            lambda p, q: q.astype(p.dtype), params, p32)
        return new_params, new_state, metrics

    return init_fn, update_fn


def state_specs(param_specs, master_weights=False):
    """Logical sharding specs for optimizer state (mirrors params)."""
    s = {"m": param_specs, "v": param_specs}
    if master_weights:
        s["master"] = param_specs
    return s
