"""Learning-rate schedules."""
from __future__ import annotations

import jax.numpy as jnp


def cosine_schedule(base_lr, total_steps, final_frac=0.1):
    def fn(step):
        frac = jnp.clip(step / max(total_steps, 1), 0.0, 1.0)
        cos = 0.5 * (1 + jnp.cos(jnp.pi * frac))
        return base_lr * (final_frac + (1 - final_frac) * cos)
    return fn


def linear_warmup_cosine(base_lr, warmup_steps, total_steps, final_frac=0.1):
    cos = cosine_schedule(base_lr, max(total_steps - warmup_steps, 1),
                          final_frac)
    def fn(step):
        warm = base_lr * step / max(warmup_steps, 1)
        return jnp.where(step < warmup_steps, warm, cos(step - warmup_steps))
    return fn
