"""Continuous-batching serving (slot-pool scheduler over family caches),
speculative draft/target decoding, and decode-time sampling."""
from repro.serve.engine import ContinuousBatchingEngine, Request
from repro.serve.sampling import SamplingParams
from repro.serve.speculative import SpeculativeConfig, spec_pair_supported

__all__ = ["ContinuousBatchingEngine", "Request", "SamplingParams",
           "SpeculativeConfig", "spec_pair_supported"]
