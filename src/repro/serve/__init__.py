"""Continuous-batching serving (slot-pool scheduler over family caches),
speculative draft/target decoding, decode-time sampling, and the fault
tolerance layer (crash-safe journal + restart, deterministic fault
injection)."""
from repro.serve.engine import ContinuousBatchingEngine, Request
from repro.serve.faults import EngineKilled, Fault, FaultPlan
from repro.serve.recovery import (
    RequestJournal,
    read_journal,
    recovery_requests,
    restore_engine,
    snapshot_engine,
)
from repro.serve.sampling import SamplingParams
from repro.serve.speculative import SpeculativeConfig, spec_pair_supported
from repro.serve.upgrade import UpgradeError, UpgradeManager

__all__ = ["ContinuousBatchingEngine", "Request", "SamplingParams",
           "SpeculativeConfig", "spec_pair_supported", "EngineKilled",
           "Fault", "FaultPlan", "RequestJournal", "read_journal",
           "recovery_requests", "restore_engine", "snapshot_engine",
           "UpgradeManager", "UpgradeError"]
