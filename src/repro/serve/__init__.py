"""Continuous-batching serving (slot-pool scheduler over family caches)."""
from repro.serve.engine import ContinuousBatchingEngine, Request

__all__ = ["ContinuousBatchingEngine", "Request"]
