"""Continuous-batching serve engine.

The naive loop in ``launch/serve.py`` runs one fixed batch lock-step:
every sequence prefills together, decodes together, and the batch ends
when the *longest* request finishes.  Under real traffic (mixed prompt
lengths, mixed generation lengths, asynchronous arrivals) that wastes
most decode FLOPs on finished or not-yet-admitted rows.

This engine serves a *stream* of requests through a fixed-capacity slot
pool instead:

  * ``Request``       — prompt + max_new_tokens (+ optional eos, arrival
                        time for trace replay);
  * slot cache pool   — one ``fam.init_cache(cfg, capacity, max_len)``
                        allocation; row ``i`` is an independent sequence
                        slot that is initialized at admission, read/written
                        per-step at its own length, and zero-evicted at
                        retirement;
  * admission (FIFO)  — waiting requests claim free slots; admission
                        prefils the prompt into a single-row cache (padded
                        to ``prefill_bucket`` to bound recompiles) and
                        scatters the row into the pool;
  * step loop         — one batched slot-decode over the whole pool per
                        step, retiring finished sequences and backfilling
                        their slots with newly admitted ones.  The decode
                        step compiles exactly once (fixed capacity), no
                        matter how sequences come and go.

Invariant (tested in ``tests/test_serve_engine.py``): greedy tokens are
*exactly* the sequential ``generate()`` tokens for every request, for any
interleaving — per-row decode arithmetic is identical to the scalar-offset
path, and masked (softmax-zero) cache positions contribute exact zeros.
"""
from __future__ import annotations

import collections
import dataclasses
import functools
import time
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import get_family
from repro.train.steps import make_prefill_full_step, make_slot_decode_step


@functools.lru_cache(maxsize=None)
def _jitted_engine_fns(cfg):
    """Shared jitted (prefill_full, slot_decode, write_slot, evict_slot)
    per config: every engine instance over the same frozen config reuses
    one compile cache.  The cache-pool argument is donated throughout —
    the engine always rebinds the returned pool, so scatter/evict update
    in place instead of copying the whole pool each step."""
    prefill = jax.jit(make_prefill_full_step(cfg), donate_argnums=(2,))
    decode = jax.jit(make_slot_decode_step(cfg), donate_argnums=(3,))
    write = jax.jit(lambda pool, row, slot: jax.tree.map(
        lambda p, r: p.at[:, slot].set(r[:, 0]), pool, row),
        donate_argnums=(0,))
    evict = jax.jit(lambda pool, slot: jax.tree.map(
        lambda p: p.at[:, slot].set(0), pool), donate_argnums=(0,))
    return prefill, decode, write, evict


@dataclasses.dataclass
class Request:
    """One generation request."""
    uid: int
    prompt: np.ndarray  # (P,) int32 prompt tokens
    max_new_tokens: int = 16
    eos_id: Optional[int] = None
    arrival: float = 0.0  # seconds since trace start (trace replay only)


@dataclasses.dataclass
class _Sequence:
    """In-flight state of an admitted request."""
    req: Request
    slot: int
    pos: int  # current length == write position of the next decode step
    tokens: List[int]
    t_first: float = 0.0  # wall time of first token (admission prefill)
    t_done: float = 0.0


class ContinuousBatchingEngine:
    """Slot-pool continuous batching over a family's cache layout.

    Supports the transformer family's standard KV and MLA latent caches
    (ring-buffer window caches and recurrent states are not slot-addressable
    by position yet).
    """

    def __init__(self, cfg, params, *, capacity: int = 8,
                 max_len: int = 256, prefill_bucket: int = 16):
        if cfg.family != "transformer":
            raise NotImplementedError(
                f"continuous batching supports the transformer family only "
                f"(got {cfg.family!r})")
        if cfg.window:
            raise NotImplementedError(
                "ring-buffer window caches are not slot-addressable")
        if not cfg.causal or cfg.continuous_inputs:
            # bucket-padded prefill positions would be visible to
            # bidirectional attention, silently breaking token-exactness
            raise NotImplementedError(
                "continuous batching requires a causal token LM "
                f"(causal={cfg.causal}, "
                f"continuous_inputs={cfg.continuous_inputs})")
        limit = cfg.max_seq_len
        if cfg.learned_pos:
            limit = min(limit, cfg.learned_pos)
        if max_len > limit:
            # beyond this, position lookups clamp silently instead of erroring
            raise ValueError(
                f"max_len {max_len} exceeds the model's position range "
                f"{limit}")
        self.cfg = cfg
        self.params = params
        self.fam = get_family(cfg)
        self.capacity = capacity
        self.max_len = max_len
        self.prefill_bucket = prefill_bucket

        self.pool = self.fam.init_cache(cfg, capacity, max_len)
        self.free: List[int] = list(range(capacity))[::-1]  # pop -> slot 0..
        self.waiting: collections.deque[Request] = collections.deque()
        self.active: Dict[int, _Sequence] = {}
        self.finished: Dict[int, np.ndarray] = {}
        self.retired: List[_Sequence] = []  # kept for latency accounting
        self._seen_uids: set = set()
        self.n_decode_steps = 0
        self.n_prefills = 0

        # _write_slot scatters one prefilled row (batch=1 cache) into pool
        # slot ``slot``, overwriting the whole row — a reused slot can never
        # see the previous tenant's KV
        (self._prefill, self._decode, self._write_slot,
         self._evict_slot) = _jitted_engine_fns(cfg)

    # ------------------------------------------------------------- admission
    def submit(self, req: Request):
        if req.uid in self._seen_uids:
            raise ValueError(f"request uid {req.uid} already submitted")
        if req.max_new_tokens < 1:
            raise ValueError(
                f"request {req.uid}: max_new_tokens must be >= 1 "
                "(prefill always emits the first token)")
        if len(req.prompt) < 1:
            raise ValueError(f"request {req.uid}: empty prompt")
        if len(req.prompt) + req.max_new_tokens > self.max_len:
            raise ValueError(
                f"request {req.uid}: prompt {len(req.prompt)} + "
                f"{req.max_new_tokens} new tokens exceeds max_len "
                f"{self.max_len}")
        self._seen_uids.add(req.uid)
        self.waiting.append(req)

    def _bucketed(self, n: int) -> int:
        b = self.prefill_bucket
        return min(-(-n // b) * b, self.max_len)

    def _admit(self, req: Request):
        slot = self.free.pop()
        P = len(req.prompt)
        padded = np.zeros((1, self._bucketed(P)), np.int32)
        padded[0, :P] = req.prompt
        # pad-tail cache entries are garbage but never visible: each decode
        # step overwrites its own position before the per-row length mask
        # reaches it
        row = self.fam.init_cache(self.cfg, 1, self.max_len)
        logits, row = self._prefill(self.params, {"tokens": jnp.asarray(padded)},
                                    row)
        first = int(jnp.argmax(logits[0, P - 1]))
        self.pool = self._write_slot(self.pool, row, jnp.int32(slot))
        self.n_prefills += 1
        seq = _Sequence(req, slot, pos=P, tokens=[first],
                        t_first=time.monotonic())
        self.active[slot] = seq
        self._finish_if_done(seq, first)

    # ------------------------------------------------------------- lifecycle
    # Retirement zero-evicts the slot even though admission's full-row
    # overwrite already guarantees correctness: in multi-tenant serving a
    # retired request's KV (derived from its prompt) must not outlive the
    # request in device memory.  With donated buffers this is an in-place
    # write of one slot, not a pool copy.
    def _finish_if_done(self, seq: _Sequence, last_token: int):
        done = (len(seq.tokens) >= seq.req.max_new_tokens
                or (seq.req.eos_id is not None
                    and last_token == seq.req.eos_id))
        if not done:
            return
        seq.t_done = time.monotonic()
        self.finished[seq.req.uid] = np.asarray(seq.tokens, np.int32)
        self.retired.append(seq)
        del self.active[seq.slot]
        self.pool = self._evict_slot(self.pool, jnp.int32(seq.slot))
        self.free.append(seq.slot)

    def _pop_arrived(self, now: Optional[float]):
        """First waiting request that has arrived (submission order may
        differ from arrival order — scan, don't just peek the head)."""
        for i, r in enumerate(self.waiting):
            if now is None or r.arrival <= now:
                del self.waiting[i]
                return r
        return None

    # ------------------------------------------------------------- step loop
    def step(self, now: Optional[float] = None):
        """One engine iteration: admit arrived requests into free slots,
        then one batched decode over all in-flight slots."""
        while self.free and self.waiting:
            req = self._pop_arrived(now)
            if req is None:
                break
            self._admit(req)
        if not self.active:
            return

        tokens = np.zeros((self.capacity,), np.int32)
        positions = np.zeros((self.capacity,), np.int32)
        for slot, seq in self.active.items():
            tokens[slot] = seq.tokens[-1]
            positions[slot] = seq.pos
        nxt, self.pool = self._decode(self.params, jnp.asarray(tokens),
                                      jnp.asarray(positions), self.pool)
        self.n_decode_steps += 1
        nxt = np.asarray(nxt)
        for slot, seq in list(self.active.items()):
            seq.pos += 1
            tok = int(nxt[slot])
            seq.tokens.append(tok)
            self._finish_if_done(seq, tok)

    def run(self, requests=None, *, realtime: bool = False):
        """Serve until every submitted request finishes.

        ``realtime=True`` replays ``Request.arrival`` offsets against the
        wall clock (benchmark traces); otherwise arrivals are ignored and
        admission is purely slot-limited FIFO.

        Returns {uid: np.ndarray of generated tokens} for the requests that
        finished during THIS call (``self.finished`` keeps the full
        history across calls).
        """
        already = set(self.finished)
        for r in requests or ():
            self.submit(r)
        t0 = time.monotonic()
        while self.waiting or self.active:
            if realtime:
                now = time.monotonic() - t0
                if not self.active and self.waiting:
                    next_arrival = min(r.arrival for r in self.waiting)
                    if next_arrival > now:
                        time.sleep(next_arrival - now)
                        now = time.monotonic() - t0
                self.step(now=now)
            else:
                self.step()
        return {uid: toks for uid, toks in self.finished.items()
                if uid not in already}

    def drain(self):
        """Return and clear all accumulated results and latency history.

        A long-lived server must call this periodically — ``finished``,
        ``retired``, and the uid-dedup set otherwise grow with every
        request ever served.  Drained uids become submittable again.
        """
        out = self.finished
        self.finished = {}
        self.retired = []
        self._seen_uids.difference_update(out)
        return out
