"""Continuous-batching serve engine with on-device macro-step decode.

The naive loop in ``launch/serve.py`` runs one fixed batch lock-step:
every sequence prefills together, decodes together, and the batch ends
when the *longest* request finishes.  Under real traffic (mixed prompt
lengths, mixed generation lengths, asynchronous arrivals) that wastes
most decode FLOPs on finished or not-yet-admitted rows.

This engine serves a *stream* of requests through a fixed-capacity slot
pool instead:

  * ``Request``       — prompt + max_new_tokens (+ optional eos, arrival
                        time for trace replay);
  * slot cache pool   — one ``fam.init_cache(cfg, capacity, max_len)``
                        allocation; row ``i`` is an independent sequence
                        slot, initialized at admission, advanced per-step
                        at its own length, and zero-evicted at retirement;
  * batched admission — all newly-arrived requests sharing a prefill
                        bucket prefill in ONE multi-row call (group size
                        padded to a power of two to bound recompiles;
                        padding rows scatter to an out-of-range slot index
                        and are dropped) and scatter into their slots in
                        one donated update; the admission *policy* decides
                        who goes first when slots are scarce (FIFO, or
                        length-bucketed shortest-prefill-first);
  * macro-step loop   — ``make_slot_decode_loop(cfg, k)`` runs K decode
                        steps per dispatch entirely on device under a
                        ``lax.scan``: per-slot eos / max-new-token
                        stopping is applied INSIDE the scan (finished rows
                        freeze and become bit-exact no-ops with
                        ``kv_len == 0``), and the host reads back a
                        ``(K, capacity)`` token block — one host↔device
                        sync per K tokens instead of one per token;
  * speculative mode  — a ``SpeculativeConfig`` swaps the macro loop for
                        ``make_speculative_loop``: a small DRAFT model
                        (the paper's pretrained source / growth seed)
                        proposes ``d`` tokens per slot, the target
                        verifies them in one batched chunk forward, and
                        each block commits 1..d+1 tokens per slot — the
                        engine then runs TWO slot pools (target + draft)
                        through the same admission/eviction scatters, and
                        acceptance telemetry rides the block readback;
  * sampling          — a non-greedy ``SamplingParams`` threads per-slot
                        PRNG chains through admission and the decode
                        loops (temperature / top-k / top-p; speculative
                        mode uses draft-rejection sampling);
  * kernel backend    — ``cfg.decode_kernel`` swaps the slot attention
                        inside ``decode_step_slots``/``verify_step_slots``
                        between the jnp path and the Pallas kernel family
                        (token-exact either way; the draft cfg is aligned
                        to the target's switch automatically);
  * double buffering  — ``run()`` dispatches macro-block N+1 (pure
                        device-side dataflow, no sync) before blocking on
                        block N's tokens, so readback overlaps compute.

All decode state (tokens, positions, remaining budget, eos ids, sampling
chains, done mask) is persistent and device-resident; the host touches it
only through incremental scatters at admission/eviction — there is no
per-step O(capacity) host rebuild and no per-token ``np.asarray``.

Invariant (tested in ``tests/test_serve_engine.py``,
``tests/test_serve_families.py`` and ``tests/test_speculative.py``):
greedy tokens are *exactly* the sequential ``generate()`` tokens for
every request, for any interleaving, any K — and any speculation depth:
a speculative block only ever emits the target's own argmax tokens, so
acceptance changes speed, never output.
"""
from __future__ import annotations

import collections
import dataclasses
import functools
import time
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import get_family, serve_supported, slot_cache_layout
from repro.serve import sampling as sampling_lib
from repro.serve.speculative import (
    SpeculativeConfig,
    make_draft_prefill,
    make_speculative_loop,
    spec_pair_supported,
)
from repro.train.steps import make_prefill_admit_step, make_slot_decode_loop

POLICIES = ("fifo", "spf")


def _pow2(n: int) -> int:
    p = 1
    while p < n:
        p *= 2
    return p


@functools.lru_cache(maxsize=None)
def _jitted_engine_fns(cfg, k, sampling, spec_key):
    """Shared jitted (loop, prefill, draft_prefill, admit, evict) per
    (config, K, sampling, speculative pair): every engine instance over
    the same frozen configs reuses one compile cache.  Pool and state
    buffers are donated throughout — the engine always rebinds the
    returned handles, so every update is in place instead of a pool copy.

    ``pools`` is a TUPLE of slot pools — ``(target,)`` normally,
    ``(target, draft)`` in speculative mode — so admission and eviction
    scatter every model's pool in the same donated update.

    ``admit`` and ``evict`` take slot-index vectors that may contain the
    out-of-range index ``capacity`` (padding rows); jnp scatters drop
    out-of-bounds updates, so padded rows are no-ops by construction.
    """
    sampled = not sampling_lib.is_greedy(sampling)
    if spec_key is None:
        loop = jax.jit(make_slot_decode_loop(cfg, k, sampling),
                       donate_argnums=(1, 2, 3, 5, 6)
                       + ((7,) if sampled else ()))
        draft_prefill = None
    else:
        cfg_d, d = spec_key
        loop = jax.jit(make_speculative_loop(cfg, cfg_d, d, k, sampling),
                       donate_argnums=(2, 3, 4, 6, 7, 8, 9))
        draft_prefill = jax.jit(make_draft_prefill(cfg_d),
                                donate_argnums=(3,))
    prefill = jax.jit(make_prefill_admit_step(cfg, sampling),
                      donate_argnums=(3,))

    def admit_fn(pools, rows, state, slots, first, plens, rem0, eos_new,
                 keys_new):
        pools = tuple(
            jax.tree.map(lambda p, r: p.at[:, slots].set(r), pool, row)
            for pool, row in zip(pools, rows))
        tokens, positions, remaining, eos, done, keys = state
        tokens = tokens.at[slots].set(first)
        positions = positions.at[slots].set(plens)
        remaining = remaining.at[slots].set(rem0)
        eos = eos.at[slots].set(eos_new)
        keys = keys.at[slots].set(keys_new)
        # a request can finish at its very first (prefill) token
        done = done.at[slots].set((first == eos_new) | (rem0 <= 0))
        return pools, (tokens, positions, remaining, eos, done, keys)

    def evict_fn(pools, state, slots):
        pools = tuple(jax.tree.map(lambda p: p.at[:, slots].set(0), pool)
                      for pool in pools)
        tokens, positions, remaining, eos, done, keys = state
        tokens = tokens.at[slots].set(0)
        positions = positions.at[slots].set(0)
        remaining = remaining.at[slots].set(0)
        eos = eos.at[slots].set(-1)
        keys = keys.at[slots].set(0)
        done = done.at[slots].set(True)
        return pools, (tokens, positions, remaining, eos, done, keys)

    # rows (arg 1) is NOT donated: an (n, ...)-shaped buffer can never alias
    # the (capacity, ...) pool, so donating it only produces warnings
    admit = jax.jit(admit_fn, donate_argnums=(0, 2))
    evict = jax.jit(evict_fn, donate_argnums=(0, 1))
    return loop, prefill, draft_prefill, admit, evict


@dataclasses.dataclass
class Request:
    """One generation request."""
    uid: int
    prompt: np.ndarray  # (P,) int32 prompt tokens
    max_new_tokens: int = 16
    eos_id: Optional[int] = None
    arrival: float = 0.0  # seconds since trace start (trace replay only)


@dataclasses.dataclass
class _Sequence:
    """In-flight state of an admitted request."""
    req: Request
    slot: int
    pos: int  # current length == write position of the next decode step
    tokens: List[int]
    t_first: float = 0.0  # wall time of first token (admission prefill)
    t_done: float = 0.0


class ContinuousBatchingEngine:
    """Slot-pool continuous batching over a family's slot-state protocol.

    The engine is family-agnostic: it only talks to ``init_cache`` /
    ``prefill_full`` / ``decode_step_slots`` (plus ``verify_step_slots``
    / ``commit_slots`` in speculative mode) and treats the slot pool as
    an opaque pytree whose leaves lead with (layers, capacity, ...).  That
    covers the transformer family's full KV and MLA latent caches,
    ring-buffer window KV caches (sliding-window configs — O(window)
    per-slot memory), and the O(1) recurrent states of griffin (rglru h +
    conv tails + local-attention rings) and xlstm (mLSTM C/n/m, sLSTM
    carries, conv tails).  ``repro.models.serve_supported(cfg)`` is the
    capability probe gating admission to this engine;
    ``serve.speculative.spec_pair_supported`` gates a draft/target pair.

    ``k`` is the macro-step length: decode tokens per on-device dispatch
    (speculative blocks per dispatch in speculative mode, each emitting
    up to ``d + 1`` tokens).  Larger K amortizes host work and syncs over
    more tokens; admission (and therefore TTFT for queued requests)
    happens only at block boundaries, so K trades admission latency
    against decode throughput.  ``k=1`` recovers per-token behaviour
    through the same code path.

    ``policy`` picks who wins scarce slots at admission: ``"fifo"``
    (arrival order) or ``"spf"`` — length-bucketed shortest-prefill-first,
    which groups short prompts into shared prefill buckets ahead of long
    ones, cutting pad waste in the batched admission forward (ties break
    by arrival, so spf cannot starve a long prompt behind an endless
    stream of short ones forever — it only reorders the currently-arrived
    set).
    """

    def __init__(self, cfg, params, *, capacity: int = 8,
                 max_len: int = 256, prefill_bucket: int = 16, k: int = 8,
                 policy: str = "fifo",
                 sampling: Optional[sampling_lib.SamplingParams] = None,
                 speculative: Optional[SpeculativeConfig] = None):
        ok, why = serve_supported(cfg)
        if not ok:
            raise NotImplementedError(
                f"continuous batching cannot serve {cfg.name!r}: {why}")
        if k < 1:
            raise ValueError(f"macro-step length k must be >= 1 (got {k})")
        if policy not in POLICIES:
            raise ValueError(f"unknown admission policy {policy!r} "
                             f"(choose from {POLICIES})")
        limit = cfg.max_seq_len
        if cfg.learned_pos:
            limit = min(limit, cfg.learned_pos)
        if max_len > limit:
            # beyond this, position lookups clamp silently instead of erroring
            raise ValueError(
                f"max_len {max_len} exceeds the model's position range "
                f"{limit}")
        if speculative is not None:
            ok, why = spec_pair_supported(cfg, speculative.cfg,
                                          speculative.d, max_len)
            if not ok:
                raise NotImplementedError(
                    f"speculative serving cannot run this pair: {why}")
            if speculative.cfg.decode_kernel != cfg.decode_kernel:
                # one attention backend per engine: the draft pool's slot
                # decode and catch-up verify follow the target's switch
                speculative = SpeculativeConfig(
                    speculative.cfg.replace(decode_kernel=cfg.decode_kernel),
                    speculative.params, speculative.d)
        self.cfg = cfg
        self.params = params
        self.fam = get_family(cfg)
        self.cache_layout = slot_cache_layout(cfg)
        self.decode_kernel = cfg.decode_kernel  # telemetry / bench tag
        self.capacity = capacity
        self.max_len = max_len
        self.prefill_bucket = prefill_bucket
        self.k = k
        self.policy = policy
        self.sampling = None if sampling_lib.is_greedy(sampling) \
            else sampling
        self.speculative = speculative

        pools = [self.fam.init_cache(cfg, capacity, max_len)]
        if speculative is not None:
            pools.append(get_family(speculative.cfg).init_cache(
                speculative.cfg, capacity, max_len))
        self._pools = tuple(pools)
        # persistent device-resident decode state: (tokens, positions,
        # remaining, eos_ids, done, sampling keys) — idle slots are done
        self._state = (jnp.zeros((capacity,), jnp.int32),
                       jnp.zeros((capacity,), jnp.int32),
                       jnp.zeros((capacity,), jnp.int32),
                       jnp.full((capacity,), -1, jnp.int32),
                       jnp.ones((capacity,), bool),
                       jnp.zeros((capacity, 2), jnp.uint32))
        self.free: List[int] = list(range(capacity))[::-1]  # pop -> slot 0..
        self.waiting: collections.deque[Request] = collections.deque()
        self.active: Dict[int, _Sequence] = {}
        self.finished: Dict[int, np.ndarray] = {}
        self.retired: List[_Sequence] = []  # kept for latency accounting
        self._seen_uids: set = set()
        self._evict_pending: List[int] = []
        # (block, valid, [(slot, uid)], stats) of dispatched-but-unread
        # macro steps
        self._inflight: collections.deque = collections.deque()
        self.n_decode_dispatches = 0
        self.n_decode_steps = 0  # dispatches * k (scan steps executed)
        self.n_prefills = 0  # admission-batch prefill dispatches
        self.n_host_syncs = 0  # blocking device->host reads
        self.n_tokens = 0  # generated tokens (incl. prefill first tokens)
        self.n_spec_proposed = 0  # draft tokens offered to the target
        self.n_spec_accepted = 0  # draft tokens the target kept

        spec_key = None if speculative is None \
            else (speculative.cfg, speculative.d)
        (self._loop, self._prefill, self._draft_prefill, self._admit,
         self._evict) = _jitted_engine_fns(cfg, k, self.sampling, spec_key)

    @property
    def pool(self):
        """The target model's slot pool (kept for telemetry/tests)."""
        return self._pools[0]

    @property
    def acceptance_rate(self) -> float:
        """Fraction of draft proposals the target accepted (speculative
        mode; 0.0 before any speculative block was read back)."""
        return self.n_spec_accepted / max(self.n_spec_proposed, 1)

    # ------------------------------------------------------------- admission
    def submit(self, req: Request):
        if req.uid in self._seen_uids:
            raise ValueError(f"request uid {req.uid} already submitted")
        if req.max_new_tokens < 1:
            raise ValueError(
                f"request {req.uid}: max_new_tokens must be >= 1 "
                "(prefill always emits the first token)")
        if len(req.prompt) < 1:
            raise ValueError(f"request {req.uid}: empty prompt")
        if len(req.prompt) + req.max_new_tokens > self.max_len:
            raise ValueError(
                f"request {req.uid}: prompt {len(req.prompt)} + "
                f"{req.max_new_tokens} new tokens exceeds max_len "
                f"{self.max_len}")
        self._seen_uids.add(req.uid)
        self.waiting.append(req)

    def _bucketed(self, n: int) -> int:
        b = self.prefill_bucket
        return min(-(-n // b) * b, self.max_len)

    def _select_admissions(self, now: Optional[float]) -> List[Request]:
        """Pick the arrived requests to admit into the free slots.

        FIFO takes them in submission order (the original behaviour);
        ``spf`` sorts the currently-arrived set by bucketed prefill
        length first (ties by submission order), so short prompts share
        admission buckets instead of padding up to a long straggler's
        bucket — less pad waste per batched prefill and faster TTFT for
        cheap requests.  Selection never skips an arrived request when a
        slot is free for it.
        """
        arrived = [i for i, r in enumerate(self.waiting)
                   if now is None or r.arrival <= now]
        if self.policy == "spf":
            arrived.sort(key=lambda i: (
                self._bucketed(len(self.waiting[i].prompt)), i))
        take = arrived[:len(self.free)]
        grabbed = [self.waiting[i] for i in take]
        for i in sorted(take, reverse=True):
            del self.waiting[i]
        return grabbed

    def _admit_batch(self, now: Optional[float]):
        """Admit every arrived request a free slot can take, ONE prefill
        dispatch per model + ONE pool/state scatter + ONE host sync per
        prefill-bucket group — instead of three host syncs per request."""
        grabbed = self._select_admissions(now)
        if not grabbed:
            return
        groups: Dict[int, List[Request]] = {}
        for r in grabbed:
            groups.setdefault(self._bucketed(len(r.prompt)), []).append(r)
        for bucket, reqs in sorted(groups.items()):
            n = len(reqs)
            npad = _pow2(n)  # bound (group size, bucket) compile count
            padded = np.zeros((npad, bucket), np.int32)
            plens = np.ones((npad,), np.int32)
            rem0 = np.zeros((npad,), np.int32)
            eos_new = np.full((npad,), -1, np.int32)
            # padding rows target the out-of-range slot ``capacity``:
            # their scatters are dropped entirely
            slots = np.full((npad,), self.capacity, np.int32)
            for j, r in enumerate(reqs):
                plens[j] = len(r.prompt)
                padded[j, :plens[j]] = r.prompt
                rem0[j] = r.max_new_tokens - 1
                eos_new[j] = -1 if r.eos_id is None else r.eos_id
                slots[j] = self.free.pop()
            rows = [self.fam.init_cache(self.cfg, npad, self.max_len)]
            # pad-tail cache entries are garbage but never visible: each
            # decode step overwrites its own position before the per-row
            # length mask reaches it
            if self.sampling is None:
                first, rows[0] = self._prefill(
                    self.params, jnp.asarray(padded), jnp.asarray(plens),
                    rows[0])
                keys_dev = jnp.zeros((npad, 2), jnp.uint32)
            else:
                # chain roots are derived from (seed, uid) ON DEVICE in
                # the same prefill dispatch — no key round-trip/sync
                uids = np.zeros((npad,), np.int32)
                uids[:len(reqs)] = [r.uid for r in reqs]
                first, rows[0], keys_dev = self._prefill(
                    self.params, jnp.asarray(padded), jnp.asarray(plens),
                    rows[0], jnp.asarray(uids))
            if self.speculative is not None:
                # the draft pool admits the SAME prompt rows: its per-row
                # state after the real prompt, first token comes from the
                # target
                draft_rows = get_family(self.speculative.cfg).init_cache(
                    self.speculative.cfg, npad, self.max_len)
                rows.append(self._draft_prefill(
                    self.speculative.params, jnp.asarray(padded),
                    jnp.asarray(plens), draft_rows))
                self.n_prefills += 1
            self._pools, self._state = self._admit(
                self._pools, tuple(rows), self._state, jnp.asarray(slots),
                first, jnp.asarray(plens), jnp.asarray(rem0),
                jnp.asarray(eos_new), keys_dev)
            self.n_prefills += 1
            first_host = np.asarray(first)
            self.n_host_syncs += 1
            t = time.monotonic()
            for j, r in enumerate(reqs):
                seq = _Sequence(r, int(slots[j]), pos=int(plens[j]),
                                tokens=[int(first_host[j])], t_first=t)
                self.active[seq.slot] = seq
                self.n_tokens += 1
                self._finish_if_done(seq, seq.tokens[-1])

    # ------------------------------------------------------------- lifecycle
    def _finish_if_done(self, seq: _Sequence, last_token: int):
        """Host-side stopping rule — the exact mirror of the in-scan rule
        (the device marks the row done at the same token)."""
        done = (len(seq.tokens) >= seq.req.max_new_tokens
                or (seq.req.eos_id is not None
                    and last_token == seq.req.eos_id))
        if not done:
            return
        seq.t_done = time.monotonic()
        self.finished[seq.req.uid] = np.asarray(seq.tokens, np.int32)
        self.retired.append(seq)
        del self.active[seq.slot]
        # the slot re-enters ``free`` only once its eviction has been
        # APPLIED (_flush_evictions) — handing it out earlier would let a
        # same-wave admission claim it and then be wiped by the pending
        # zero-evict
        self._evict_pending.append(seq.slot)

    def _flush_evictions(self):
        """Zero-evict retired slots and reset their decode state, batched
        into one fixed-shape donated scatter (slot list padded to capacity
        with the dropped out-of-range index — a single compile).

        Even though admission's full-row overwrite already guarantees
        correctness, in multi-tenant serving a retired request's KV must
        not outlive the request in device memory; resetting the frozen
        token also means idle-slot no-op steps derive from token 0, never
        from a previous tenant's text.
        """
        if not self._evict_pending:
            return
        slots = np.full((self.capacity,), self.capacity, np.int32)
        slots[:len(self._evict_pending)] = self._evict_pending
        self._pools, self._state = self._evict(self._pools, self._state,
                                               jnp.asarray(slots))
        self.free.extend(self._evict_pending)
        self._evict_pending.clear()

    # ------------------------------------------------------------- step loop
    def _dispatch(self):
        """Launch one on-device macro step (K decode steps — or K whole
        speculative draft→verify→commit blocks — with no sync)."""
        tokens, positions, remaining, eos_ids, done, keys = self._state
        stats = None
        if self.speculative is not None:
            (block, valid, tokens, positions, remaining, done, pool_t,
             pool_d, keys, n_prop, n_acc) = self._loop(
                self.params, self.speculative.params, tokens, positions,
                remaining, eos_ids, done, self._pools[0], self._pools[1],
                keys)
            self._pools = (pool_t, pool_d)
            stats = (n_prop, n_acc)
        elif self.sampling is not None:
            (block, valid, tokens, positions, remaining, done, pool,
             keys) = self._loop(self.params, tokens, positions, remaining,
                                eos_ids, done, self._pools[0], keys)
            self._pools = (pool,)
        else:
            (block, valid, tokens, positions, remaining, done,
             pool) = self._loop(self.params, tokens, positions, remaining,
                                eos_ids, done, self._pools[0])
            self._pools = (pool,)
        self._state = (tokens, positions, remaining, eos_ids, done, keys)
        self.n_decode_dispatches += 1
        self.n_decode_steps += self.k
        live = [(slot, seq.req.uid) for slot, seq in self.active.items()]
        self._inflight.append((block, valid, live, stats))

    def _process(self, item):
        """Block on one macro step's token block (the single host sync per
        dispatch) and advance the host-side sequence records."""
        block, valid, live, stats = item
        block, valid, stats = jax.device_get((block, valid, stats))
        self.n_host_syncs += 1
        if stats is not None:
            # acceptance telemetry rides the same readback — no extra sync
            self.n_spec_proposed += int(stats[0])
            self.n_spec_accepted += int(stats[1])
        for slot, uid in live:
            seq = self.active.get(slot)
            if seq is None or seq.req.uid != uid:
                # the slot was retired (and possibly re-admitted) while this
                # block was in flight; its rows were device-done, so the
                # valid mask is all False for it anyway
                continue
            vm = valid[:, slot]
            nv = int(vm.sum())
            if nv == 0:
                continue
            seq.pos += nv
            seq.tokens.extend(int(t) for t in block[:, slot][vm])
            self.n_tokens += nv
            self._finish_if_done(seq, seq.tokens[-1])

    def step(self, now: Optional[float] = None):
        """One synchronous engine iteration: evict, admit arrived requests
        into free slots, run one macro step, and read it back."""
        self._flush_evictions()
        self._admit_batch(now)
        if not self.active and not self._inflight:
            return
        if self.active:
            self._dispatch()
        while self._inflight:
            self._process(self._inflight.popleft())

    def run(self, requests=None, *, realtime: bool = False,
            pipeline: bool = True):
        """Serve until every submitted request finishes.

        ``realtime=True`` replays ``Request.arrival`` offsets against the
        wall clock (benchmark traces); otherwise arrivals are ignored and
        admission is purely slot-limited (FIFO or spf by ``policy``).

        ``pipeline=True`` double-buffers readback: macro-block N+1 is
        dispatched (device-side dataflow only) before the host blocks on
        block N's tokens, so the device never idles on readback.
        Admissions chain onto the latest dispatched state, which defers a
        queued request by at most one extra block.  ``pipeline=False``
        syncs after every block (the per-token engine of PR 1 when k=1).

        Returns {uid: np.ndarray of generated tokens} for the requests that
        finished during THIS call (``self.finished`` keeps the full
        history across calls).
        """
        already = set(self.finished)
        for r in requests or ():
            self.submit(r)
        t0 = time.monotonic()

        def wall_now():
            return time.monotonic() - t0 if realtime else None

        if not pipeline:
            while self.waiting or self.active or self._inflight:
                now = wall_now()
                if realtime and not self.active and self.waiting:
                    nxt = min(r.arrival for r in self.waiting)
                    if nxt > now:
                        time.sleep(nxt - now)
                        now = wall_now()
                self.step(now=now)
        else:
            while self.waiting or self.active or self._inflight:
                now = wall_now()
                if (realtime and not self.active and not self._inflight
                        and self.waiting):
                    nxt = min(r.arrival for r in self.waiting)
                    if nxt > now:
                        time.sleep(nxt - now)
                        now = wall_now()
                self._flush_evictions()
                self._admit_batch(now)
                if self.active:
                    self._dispatch()
                # block on the OLDEST in-flight block only once a newer one
                # is already dispatched (or nothing is left to dispatch)
                if len(self._inflight) >= (2 if self.active else 1):
                    self._process(self._inflight.popleft())
        self._flush_evictions()
        return {uid: toks for uid, toks in self.finished.items()
                if uid not in already}

    def drain(self):
        """Return and clear all accumulated results and latency history.

        A long-lived server must call this periodically — ``finished``,
        ``retired``, and the uid-dedup set otherwise grow with every
        request ever served.  Drained uids become submittable again.
        """
        out = self.finished
        self.finished = {}
        self.retired = []
        self._seen_uids.difference_update(out)
        return out
