"""Continuous-batching serve engine with on-device macro-step decode.

The naive loop in ``launch/serve.py`` runs one fixed batch lock-step:
every sequence prefills together, decodes together, and the batch ends
when the *longest* request finishes.  Under real traffic (mixed prompt
lengths, mixed generation lengths, asynchronous arrivals) that wastes
most decode FLOPs on finished or not-yet-admitted rows.

This engine serves a *stream* of requests through a fixed-capacity slot
pool instead:

  * ``Request``       — prompt + max_new_tokens (+ optional eos, arrival
                        time for trace replay);
  * slot cache pool   — one ``fam.init_cache(cfg, capacity, max_len)``
                        allocation; row ``i`` is an independent sequence
                        slot, initialized at admission, advanced per-step
                        at its own length, and zero-evicted at retirement;
  * batched admission — all newly-arrived requests sharing a prefill
                        bucket prefill in ONE multi-row call (group size
                        padded to a power of two to bound recompiles;
                        padding rows scatter to an out-of-range slot index
                        and are dropped) and scatter into their slots in
                        one donated update; the admission *policy* decides
                        who goes first when slots are scarce (FIFO, or
                        length-bucketed shortest-prefill-first);
  * macro-step loop   — ``make_slot_decode_loop(cfg, k)`` runs K decode
                        steps per dispatch entirely on device under a
                        ``lax.scan``: per-slot eos / max-new-token
                        stopping is applied INSIDE the scan (finished rows
                        freeze and become bit-exact no-ops with
                        ``kv_len == 0``), and the host reads back a
                        ``(K, capacity)`` token block — one host↔device
                        sync per K tokens instead of one per token;
  * speculative mode  — a ``SpeculativeConfig`` swaps the macro loop for
                        ``make_speculative_loop``: a small DRAFT model
                        (the paper's pretrained source / growth seed)
                        proposes ``d`` tokens per slot, the target
                        verifies them in one batched chunk forward, and
                        each block commits 1..d+1 tokens per slot — the
                        engine then runs TWO slot pools (target + draft)
                        through the same admission/eviction scatters, and
                        acceptance telemetry rides the block readback;
  * sampling          — a non-greedy ``SamplingParams`` threads per-slot
                        PRNG chains through admission and the decode
                        loops (temperature / top-k / top-p; speculative
                        mode uses draft-rejection sampling);
  * kernel backend    — ``cfg.decode_kernel`` swaps the slot attention
                        inside ``decode_step_slots``/``verify_step_slots``
                        between the jnp path and the Pallas kernel family
                        (token-exact either way; the draft cfg is aligned
                        to the target's switch automatically);
  * double buffering  — ``run()`` dispatches macro-block N+1 (pure
                        device-side dataflow, no sync) before blocking on
                        block N's tokens, so readback overlaps compute.

All decode state (tokens, positions, remaining budget, eos ids, sampling
chains, done mask) is persistent and device-resident; the host touches it
only through incremental scatters at admission/eviction — there is no
per-step O(capacity) host rebuild and no per-token ``np.asarray``.

Invariant (tested in ``tests/test_serve_engine.py``,
``tests/test_serve_families.py`` and ``tests/test_speculative.py``):
greedy tokens are *exactly* the sequential ``generate()`` tokens for
every request, for any interleaving, any K — and any speculation depth:
a speculative block only ever emits the target's own argmax tokens, so
acceptance changes speed, never output.

Fault tolerance (PR 7): per-request deadlines and queue-age load
shedding fold into the same done-mask/eviction machinery; per-slot
NaN/Inf sentinels computed INSIDE the decode scans ride the existing
block readback (zero extra host syncs) and quarantine-evict poisoned
slots; a speculative engine whose draft misbehaves drops to the plain
macro loop, and a faulted paged arena drops prefix sharing for
dense-style full reservation.  A :class:`repro.serve.recovery
.RequestJournal` (``journal=``) makes every committed token crash-safe,
and a :class:`repro.serve.faults.FaultPlan` (``faults=``) injects
deterministic failures for the chaos harness.  All of it defaults off:
the fault-free hot path dispatches exactly as before.
"""
from __future__ import annotations

import collections
import dataclasses
import functools
import time
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import get_family, serve_supported, slot_cache_layout
from repro.serve import faults as faults_lib
from repro.serve import paged as paged_lib
from repro.serve import sampling as sampling_lib
from repro.serve.speculative import (
    SpeculativeConfig,
    make_draft_prefill,
    make_speculative_loop,
    spec_pair_supported,
)
from repro.train.steps import make_prefill_admit_step, make_slot_decode_loop

POLICIES = ("fifo", "spf")

# Telemetry that accumulates per drain window.  ``drain()`` folds these
# into ``engine.lifetime`` and zeroes them, so a long-lived server's
# windowed rates (acceptance, tok/s, hit rate) reflect the CURRENT window
# instead of everything since boot.
_WINDOW_COUNTERS = (
    "n_decode_dispatches", "n_decode_steps", "n_prefills", "n_host_syncs",
    "n_tokens", "n_spec_proposed", "n_spec_accepted", "n_admitted",
    "n_prefix_hits", "n_prefix_misses", "n_prefix_stalls",
    "n_pages_allocated", "n_expired", "n_quarantined", "n_shed",
    "n_spec_fallbacks", "n_faults_injected", "n_degraded_admissions",
    "n_held_for_upgrade",
)


def _pow2(n: int) -> int:
    p = 1
    while p < n:
        p *= 2
    return p


@functools.lru_cache(maxsize=None)
def _jitted_engine_fns(cfg, k, sampling, spec_key, paged_key, mesh_plan):
    """Shared jitted (loop, prefill, draft_prefill, admit, evict,
    hit_admit) per (config, K, sampling, speculative pair, paging
    geometry, mesh plan): every engine instance over the same frozen
    configs reuses one compile cache.  Pool and state buffers are donated
    throughout — the engine always rebinds the returned handles, so every
    update is in place instead of a pool copy.

    ``mesh_plan`` (a :class:`repro.distributed.serve_sharding
    .ServeMeshPlan`, or None for the single-device engine) wraps every
    returned function so it traces under the plan's mesh + logical rules:
    the model-internal ``annotate`` calls then pin activations to the
    (data=slots, model=heads) layout, and the committed shardings of the
    params/pool/state arguments do the rest through GSPMD.

    ``pools`` is a TUPLE of slot pools — ``(target,)`` normally,
    ``(target, draft)`` in speculative mode — so admission and eviction
    scatter every model's pool in the same donated update.  ``paged_key``
    carries one :class:`repro.serve.paged.PoolMeta` (or None for a dense
    pool) per pool; the decode/prefill jits are pool-structure-opaque
    (``decode_step_slots`` dispatches on ``"bt" in cache`` internally),
    only admission and eviction scatter differently.

    ``admit`` and ``evict`` take slot-index vectors that may contain the
    out-of-range index ``capacity`` (padding rows); jnp scatters drop
    out-of-bounds updates, so padded rows are no-ops by construction.
    The same convention covers paged pools: unallocated / padding block
    table entries carry the out-of-range page id ``n_pages``.
    """
    sampled = not sampling_lib.is_greedy(sampling)
    fb_loop = None
    if spec_key is None:
        loop = jax.jit(make_slot_decode_loop(cfg, k, sampling),
                       donate_argnums=(1, 2, 3, 5, 6)
                       + ((7,) if sampled else ()))
        draft_prefill = None
    else:
        cfg_d, d = spec_key
        loop = jax.jit(make_speculative_loop(cfg, cfg_d, d, k, sampling),
                       donate_argnums=(2, 3, 4, 6, 7, 8, 9))
        draft_prefill = jax.jit(make_draft_prefill(cfg_d),
                                donate_argnums=(3,))
        # the degradation ladder's target: a plain (non-speculative)
        # macro loop over the TARGET pool alone, compiled lazily on
        # first use when the draft misbehaves mid-serve
        fb_loop = jax.jit(make_slot_decode_loop(cfg, k, sampling),
                          donate_argnums=(1, 2, 3, 5, 6)
                          + ((7,) if sampled else ()))
    prefill = jax.jit(make_prefill_admit_step(cfg, sampling),
                      donate_argnums=(3,))

    def _scatter_state(state, slots, first, plens, rem0, eos_new, keys_new):
        tokens, positions, remaining, eos, done, keys = state
        tokens = tokens.at[slots].set(first)
        positions = positions.at[slots].set(plens)
        remaining = remaining.at[slots].set(rem0)
        eos = eos.at[slots].set(eos_new)
        keys = keys.at[slots].set(keys_new)
        # a request can finish at its very first (prefill) token
        done = done.at[slots].set((first == eos_new) | (rem0 <= 0))
        return tokens, positions, remaining, eos, done, keys

    def admit_fn(pools, rows, state, slots, bt_rows, first, plens, rem0,
                 eos_new, keys_new):
        new_pools = []
        for pool, row, btr, m in zip(pools, rows, bt_rows, paged_key):
            if btr is None:
                new_pools.append(jax.tree.map(
                    lambda p, r: p.at[:, slots].set(r), pool, row))
            else:
                new_pools.append(paged_lib.admit_scatter(pool, row, slots,
                                                         btr, m))
        state = _scatter_state(state, slots, first, plens, rem0, eos_new,
                               keys_new)
        return tuple(new_pools), state

    def evict_fn(pools, state, slots, zero_pids):
        new_pools = []
        for pool, zp, m in zip(pools, zero_pids, paged_key):
            if zp is None:
                new_pools.append(jax.tree.map(
                    lambda p: p.at[:, slots].set(0), pool))
            else:
                new_pools.append(paged_lib.evict_clear(pool, slots, zp, m))
        tokens, positions, remaining, eos, done, keys = state
        tokens = tokens.at[slots].set(0)
        positions = positions.at[slots].set(0)
        remaining = remaining.at[slots].set(0)
        eos = eos.at[slots].set(-1)
        keys = keys.at[slots].set(0)
        done = done.at[slots].set(True)
        return tuple(new_pools), (tokens, positions, remaining, eos, done,
                                  keys)

    # rows (arg 1) is NOT donated: an (n, ...)-shaped buffer can never alias
    # the (capacity, ...) pool, so donating it only produces warnings
    admit = jax.jit(admit_fn, donate_argnums=(0, 2))
    evict = jax.jit(evict_fn, donate_argnums=(0, 1))

    # prefix-hit admission: the shared prompt pages are already resident,
    # so the new slot only runs its private TAIL tokens (at most one
    # page) through decode steps — no bucket prefill dispatch at all.
    # Built for every non-speculative paged-target engine: full-KV and
    # MLA hits alias resident pages directly; windowed (ring) hits first
    # RECONSTRUCT the ring by copying resident absolute-position pages
    # into the slot's private ring pages; sampled engines derive the
    # row's chain on device — (seed, uid) advanced by ``skips`` splits,
    # exactly mirroring ``prefill_sampled`` — and draw the first token
    # from the chain instead of argmax, so a hit-admitted request emits
    # the same tokens as its bucket-prefilled twin.
    hit_admit = None
    reg_copy = None
    if paged_key and paged_key[0] is not None and spec_key is None:
        meta0 = paged_key[0]
        fam = get_family(cfg)
        windowed = bool(getattr(cfg, "window", None))

        def hit_fn(params, pools, state, slots, bt_rows0, src_pids,
                   dst_pids, tail_tokens, tail_len, pos0, plens, rem0,
                   eos_new, uids, skips):
            pool = paged_lib.set_block_tables(pools[0], slots, bt_rows0,
                                              meta0)
            if windowed:
                pool = paged_lib.ring_restore_copy(pool, src_pids,
                                                   dst_pids, meta0)
            cap = state[0].shape[0]

            def scat(vals, fill, dtype):
                return jnp.full((cap,), fill, dtype).at[slots].set(
                    vals, mode="drop")

            wave = jnp.zeros((cap,), bool).at[slots].set(
                jnp.ones(slots.shape, bool), mode="drop")
            tl = scat(tail_len, 0, jnp.int32)
            p0 = scat(pos0, 0, jnp.int32)
            toks = jnp.zeros((cap, meta0.page), jnp.int32).at[slots].set(
                tail_tokens, mode="drop")
            if sampled:
                uc = scat(uids, 0, jnp.int32)
                sk = scat(skips, 0, jnp.int32)
                roots = jax.vmap(lambda u: sampling_lib.request_key(
                    sampling.seed, u))(uc)
                # a resume's committed run consumed one split per token
                roots = jax.lax.fori_loop(
                    0, jnp.max(sk),
                    lambda i, ks: jnp.where(
                        (i < sk)[:, None],
                        sampling_lib.next_keys(ks)[0], ks),
                    roots)
            else:
                roots = jnp.zeros((cap, 2), jnp.uint32)

            def body(carry, j):
                cache, first, chain = carry
                live = wave & (j < tl)
                last = live & (j == tl - 1)
                logits, cache = fam.decode_step_slots(
                    params, toks[:, j], p0 + j, cache, cfg, done=~live)
                if sampled:
                    chain_new, subs = sampling_lib.next_keys(chain)
                    nxt = sampling_lib.sample_logits(logits, subs,
                                                     sampling)
                    # the chain advances exactly once: on the first
                    # really-sampled token (the j == tl - 1 draw)
                    chain = jnp.where(last[:, None], chain_new, chain)
                else:
                    nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
                first = jnp.where(last, nxt, first)
                return (cache, first, chain), None

            (pool, first, chain), _ = jax.lax.scan(
                body, (pool, jnp.zeros((cap,), jnp.int32), roots),
                jnp.arange(meta0.page, dtype=jnp.int32))
            tokens, positions, remaining, eos, done, keys = state
            plc = scat(plens, 0, jnp.int32)
            rmc = scat(rem0, 0, jnp.int32)
            eoc = scat(eos_new, -1, jnp.int32)
            tokens = jnp.where(wave, first, tokens)
            positions = jnp.where(wave, plc, positions)
            remaining = jnp.where(wave, rmc, remaining)
            eos = jnp.where(wave, eoc, eos)
            keys = jnp.where(wave[:, None], chain, keys)
            done = jnp.where(wave, (first == eoc) | (rmc <= 0), done)
            return ((pool,) + pools[1:],
                    (tokens, positions, remaining, eos, done, keys), first)

        hit_admit = jax.jit(hit_fn, donate_argnums=(1, 2))
        if windowed:
            # miss-admission companion: copy the prompt's last intact
            # full pages out of the (ring-layout) prefill scratch into
            # registry-only pages, so later admissions can reconstruct
            def reg_fn(pool0, rows0, reg_pids, reg_blk):
                return paged_lib.register_copy(pool0, reg_pids, reg_blk,
                                               rows0, meta0)

            reg_copy = jax.jit(reg_fn, donate_argnums=(0,))
    fns = (loop, prefill, draft_prefill, admit, evict, hit_admit, fb_loop,
           reg_copy)
    if mesh_plan is not None:
        fns = tuple(mesh_plan.wrap(f) for f in fns)
    return fns


@dataclasses.dataclass
class Request:
    """One generation request.

    ``deadline`` (seconds from arrival/submission) overrides the
    engine-wide TTL; ``n_committed`` marks the last N prompt tokens as
    previously-COMMITTED generated tokens — the journal-resume contract:
    the "prompt" is the original prompt ‖ the committed run, prefill
    re-derives the exact next token, and the budget counts the committed
    run against ``max_new_tokens``.
    """
    uid: int
    prompt: np.ndarray  # (P,) int32 prompt tokens
    max_new_tokens: int = 16
    eos_id: Optional[int] = None
    arrival: float = 0.0  # seconds since trace start (trace replay only)
    deadline: Optional[float] = None  # per-request TTL override
    n_committed: int = 0  # journal resume: committed suffix of ``prompt``


@dataclasses.dataclass
class _Sequence:
    """In-flight state of an admitted request."""
    req: Request
    slot: int
    pos: int  # current length == write position of the next decode step
    tokens: List[int]
    t_first: float = 0.0  # wall time of first token (admission prefill)
    t_done: float = 0.0


class ContinuousBatchingEngine:
    """Slot-pool continuous batching over a family's slot-state protocol.

    The engine is family-agnostic: it only talks to ``init_cache`` /
    ``prefill_full`` / ``decode_step_slots`` (plus ``verify_step_slots``
    / ``commit_slots`` in speculative mode) and treats the slot pool as
    an opaque pytree whose leaves lead with (layers, capacity, ...).  That
    covers the transformer family's full KV and MLA latent caches,
    ring-buffer window KV caches (sliding-window configs — O(window)
    per-slot memory), and the O(1) recurrent states of griffin (rglru h +
    conv tails + local-attention rings) and xlstm (mLSTM C/n/m, sLSTM
    carries, conv tails).  ``repro.models.serve_supported(cfg)`` is the
    capability probe gating admission to this engine;
    ``serve.speculative.spec_pair_supported`` gates a draft/target pair.

    ``k`` is the macro-step length: decode tokens per on-device dispatch
    (speculative blocks per dispatch in speculative mode, each emitting
    up to ``d + 1`` tokens).  Larger K amortizes host work and syncs over
    more tokens; admission (and therefore TTFT for queued requests)
    happens only at block boundaries, so K trades admission latency
    against decode throughput.  ``k=1`` recovers per-token behaviour
    through the same code path.

    ``policy`` picks who wins scarce slots at admission: ``"fifo"``
    (arrival order) or ``"spf"`` — length-bucketed shortest-prefill-first,
    which groups short prompts into shared prefill buckets ahead of long
    ones, cutting pad waste in the batched admission forward (ties break
    by arrival, so spf cannot starve a long prompt behind an endless
    stream of short ones forever — it only reorders the currently-arrived
    set).
    """

    def __init__(self, cfg, params, *, capacity: int = 8,
                 max_len: int = 256, prefill_bucket: int = 16, k: int = 8,
                 policy: str = "fifo", pool: str = "dense",
                 pages: Optional[int] = None,
                 sampling: Optional[sampling_lib.SamplingParams] = None,
                 speculative: Optional[SpeculativeConfig] = None,
                 deadline: Optional[float] = None,
                 shed_age: Optional[float] = None,
                 journal=None, faults=None, mesh=None):
        if pool not in ("dense", "paged"):
            raise ValueError(f"unknown pool kind {pool!r} "
                             "(choose 'dense' or 'paged')")
        if k < 1:
            raise ValueError(f"macro-step length k must be >= 1 (got {k})")
        if policy not in POLICIES:
            raise ValueError(f"unknown admission policy {policy!r} "
                             f"(choose from {POLICIES})")
        if deadline is not None and deadline <= 0:
            raise ValueError(f"deadline must be > 0 (got {deadline})")
        if shed_age is not None and shed_age <= 0:
            raise ValueError(f"shed_age must be > 0 (got {shed_age})")
        self.capacity = capacity
        self.max_len = max_len
        self.prefill_bucket = prefill_bucket
        self.k = k
        self.policy = policy
        self.sampling = None if sampling_lib.is_greedy(sampling) \
            else sampling
        self.deadline = deadline  # engine-wide TTL (seconds); None = off
        self.shed_age = shed_age  # queue-age load-shed threshold
        self.journal = journal  # RequestJournal or None
        self.faults = faults  # FaultPlan or None (chaos harness only)
        self._pool_arg = pool  # requested pool kind (re-applied on swap)
        self.pages_arg = pages  # requested --pages budget (snapshot field)
        self._mesh_arg = mesh  # requested mesh (re-validated on swap)
        # host-side request bookkeeping.  Owned by __init__ and NEVER
        # rebuilt by _configure: a live upgrade replaces the model under
        # the traffic, not the traffic under the model.
        self.waiting: collections.deque[Request] = collections.deque()
        self.active: Dict[int, _Sequence] = {}
        self.finished: Dict[int, np.ndarray] = {}
        self.retired: List[_Sequence] = []  # kept for latency accounting
        self.rejected: Dict[int, str] = {}  # uid -> why submit refused it
        # uid -> terminal outcome: finished / expired / quarantined /
        # shed / rejected (only "finished" rows are complete outputs)
        self.outcomes: Dict[int, str] = {}
        self._seen_uids: set = set()
        self._t_submit: Dict[int, float] = {}  # uid -> wall submit time
        self._any_deadline = deadline is not None  # fast path when off
        self._fault_step = 0  # dispatches seen (FaultPlan clock)
        self._oom_waves = 0  # admission waves stalled by an oom fault
        self._poison_jit = None  # lazy donated jit of faults.poison_pool
        self._evict_pending: List[int] = []
        # (block, valid, [(slot, uid)], stats) of dispatched-but-unread
        # macro steps
        self._inflight: collections.deque = collections.deque()
        # live-upgrade machinery: serve/upgrade.py attaches an
        # UpgradeManager here and drives upgrade_state through
        # serving -> relayout -> swapped at a block-readback boundary
        self.upgrade = None
        self.upgrade_state = "serving"
        self._held_for_upgrade: List[Request] = []
        self.n_upgrades = 0  # completed hot-swaps since boot
        self.last_upgrade_pause_ms: Optional[float] = None
        self.n_decode_dispatches = 0
        self.n_decode_steps = 0  # dispatches * k (scan steps executed)
        self.n_prefills = 0  # admission-batch prefill dispatches
        self.n_host_syncs = 0  # blocking device->host reads
        self.n_tokens = 0  # generated tokens (incl. prefill first tokens)
        self.n_spec_proposed = 0  # draft tokens offered to the target
        self.n_spec_accepted = 0  # draft tokens the target kept
        self.n_admitted = 0  # requests that got a slot (+pages if paged)
        self.n_prefix_hits = 0  # admissions served from resident pages
        self.n_prefix_misses = 0  # prefix probes that found no full chain
        self.n_prefix_stalls = 0  # hits deferred on tail-page backpressure
        self.n_pages_allocated = 0  # fresh target-pool pages handed out
        self.n_expired = 0  # deadline-evicted requests (active or queued)
        self.n_quarantined = 0  # NaN/Inf-poisoned slots evicted
        self.n_shed = 0  # queued requests dropped by queue-age shedding
        self.n_spec_fallbacks = 0  # draft faults that tripped plain decode
        self.n_faults_injected = 0  # FaultPlan records actually fired
        self.n_degraded_admissions = 0  # full-reservation paged admissions
        self.n_held_for_upgrade = 0  # submits held across a swap window
        # drained-window history (satellite: drain() snapshots + resets
        # the window counters; lifetime totals live here)
        self.lifetime: Dict[str, int] = {c: 0 for c in _WINDOW_COUNTERS}
        self._configure(cfg, params, speculative)

    def _configure(self, cfg, params, speculative):
        """Build — or, on a live upgrade, REBUILD — everything derived
        from the model configuration: mesh plan, slot pools + paging
        metadata, decode state, committed shardings, and the jitted fn
        set.  ``_apply_upgrade`` calls this again with the grown config
        after quiescing, which is exactly the "pool re-layout" step of
        the swap; every host-side queue/telemetry structure lives in
        ``__init__`` and survives."""
        capacity, max_len = self.capacity, self.max_len
        pool, pages = self._pool_arg, self.pages_arg
        ok, why = serve_supported(cfg)
        if not ok:
            raise NotImplementedError(
                f"continuous batching cannot serve {cfg.name!r}: {why}")
        # ``mesh``: None (single-device), "DxM", or a (data, model) tuple.
        # A 1x1 mesh is inert — the same engine serves 1..N devices.
        self.mesh_plan = None
        self.kernel_tp_fallback = False
        if self._mesh_arg is not None:
            from repro.distributed import serve_sharding
            shape = serve_sharding.validate_serve_mesh(
                self._mesh_arg, cfg, capacity, n_devices=None)
            if shape[0] * shape[1] > 1:
                if shape[0] * shape[1] != len(jax.devices()):
                    raise ValueError(
                        f"mesh {shape[0]}x{shape[1]} needs "
                        f"{shape[0] * shape[1]} devices but "
                        f"{len(jax.devices())} are visible")
                self.mesh_plan = serve_sharding.get_serve_plan(shape)
                if cfg.decode_kernel != "jnp":
                    # the Pallas slot kernels read whole pool rows per
                    # block — under TP each device only holds its head
                    # shard, so sharded engines fall back to the jnp
                    # path (token-exact either way)
                    cfg = cfg.replace(decode_kernel="jnp")
                    self.kernel_tp_fallback = True
        self.mesh_shape = (self.mesh_plan.describe()
                           if self.mesh_plan is not None else "1x1")
        self.n_devices = (self.mesh_plan.n_devices
                          if self.mesh_plan is not None else 1)
        limit = cfg.max_seq_len
        if cfg.learned_pos:
            limit = min(limit, cfg.learned_pos)
        if max_len > limit:
            # beyond this, position lookups clamp silently instead of erroring
            raise ValueError(
                f"max_len {max_len} exceeds the model's position range "
                f"{limit}")
        if speculative is not None:
            ok, why = spec_pair_supported(cfg, speculative.cfg,
                                          speculative.d, max_len)
            if not ok:
                raise NotImplementedError(
                    f"speculative serving cannot run this pair: {why}")
            if speculative.cfg.decode_kernel != cfg.decode_kernel:
                # one attention backend per engine: the draft pool's slot
                # decode and catch-up verify follow the target's switch
                speculative = SpeculativeConfig(
                    speculative.cfg.replace(decode_kernel=cfg.decode_kernel),
                    speculative.params, speculative.d)
        self.cfg = cfg
        self.params = params
        self.fam = get_family(cfg)
        self.cache_layout = slot_cache_layout(cfg)
        self.decode_kernel = cfg.decode_kernel  # telemetry / bench tag
        self.speculative = speculative

        fams = [self.fam]
        cfgs = [cfg]
        if speculative is not None:
            fams.append(get_family(speculative.cfg))
            cfgs.append(speculative.cfg)
        # Probe every pool's natural paging geometry first: families
        # DECLARE their pageable cache groups through the slot-state
        # protocol (``models.paged_groups``), so paging is no longer a
        # transformer-shaped structural guess — xlstm pages its conv
        # tails (mLSTM carries stay dense-per-slot), MLA pages its
        # latent caches, griffin pages its local-attention rings (and
        # keeps them paged under speculation via the paged ring-restore
        # commit).  A family that declares nothing stays dense WITH a
        # named reason instead of a silent ``pool_kind`` flip.
        probe = [None] * len(fams)
        reasons = []
        if pool == "paged":
            for i, (f, c) in enumerate(zip(fams, cfgs)):
                probe[i] = paged_lib.pool_meta(
                    c, jax.eval_shape(lambda f=f, c=c: f.init_cache(
                        c, capacity, max_len)))
                if probe[i] is None:
                    role = "target" if i == 0 else "draft"
                    reasons.append(
                        f"{role}: "
                        f"{paged_lib.pool_fallback_reason(c) or 'unpageable cache layout'}")
        self.pool_fallback_reason = "; ".join(reasons) or None
        paged_idx = [i for i, m in enumerate(probe) if m is not None]
        # ONE page-id space across every paged pool of the engine: page
        # ``p`` is row ``p`` of each pool's arenas, a request allocates
        # its worst-case page count once and every pool consumes the
        # leading slice — so an explicit --pages budget is real shared
        # memory (draft and target trade pages freely) instead of the
        # old static per-pool split.
        self.pages_budget = None
        n_pages = None
        if paged_idx:
            n_pages = int(pages) if pages else max(
                probe[i].n_pages for i in paged_idx)
            self.pages_budget = n_pages
        pools, metas = [], []
        for i, (f, c) in enumerate(zip(fams, cfgs)):
            if probe[i] is not None:
                p, m = paged_lib.build_paged_pool(f, c, capacity, max_len,
                                                  n_pages=n_pages)
            else:
                p, m = f.init_cache(c, capacity, max_len), None
            pools.append(p)
            metas.append(m)
        self._pools = tuple(pools)
        self._metas = tuple(metas)
        self._paged = bool(paged_idx)
        self.pool_kind = "paged" if self._paged else "dense"
        # pool index -> refcount namespace in the shared allocator
        self._ns_of = {pi: j for j, pi in enumerate(paged_idx)}
        self._alloc = paged_lib.PageAllocator(
            metas[paged_idx[0]], namespaces=len(paged_idx)) \
            if paged_idx else None
        # slot -> page-id list owned by the admitted request (one list:
        # every paged pool consumes its leading slice of the same ids)
        self._slot_pages: Dict[int, list] = {}
        # release()d pages awaiting their zeroing scatter (rollbacks);
        # a page that hits global zero is zeroed in EVERY paged pool
        self._zero_pending: List[int] = []
        # shared-prefix admission: meaningful where the target's seq
        # pages are absolute-position-addressed (full KV, MLA latents)
        # or reconstructible (rings with at least one page of slack
        # over the window — the admission prefill's partial tail page
        # always clobbers the oldest ring page, so a slack-less ring
        # has no intact shareable tail).  Sampled engines take the path
        # too: the hit replays the request's (seed, uid) chain on
        # device, so hit and miss admissions emit identical tokens.
        window = getattr(cfg, "window", None)
        self._windowed = bool(window)
        ring_ok = True
        if window and metas[0] is not None:
            ring_ok = (metas[0].nblk - 1) * metas[0].page + 1 >= window \
                and metas[0].nblk > 1
        self._prefix_ok = (metas[0] is not None and speculative is None
                           and cfg.family == "transformer"
                           and metas[0].page > 0 and ring_ok)
        self._spec_fallback = False  # draft faulted: plain macro decode
        self._arena_degraded = False  # paged arena faulted: no sharing
        # persistent device-resident decode state: (tokens, positions,
        # remaining, eos_ids, done, sampling keys) — idle slots are done
        self._state = (jnp.zeros((capacity,), jnp.int32),
                       jnp.zeros((capacity,), jnp.int32),
                       jnp.zeros((capacity,), jnp.int32),
                       jnp.full((capacity,), -1, jnp.int32),
                       jnp.ones((capacity,), bool),
                       jnp.zeros((capacity, 2), jnp.uint32))
        if self.mesh_plan is not None:
            # Commit every long-lived buffer to the mesh ONCE, here.
            # After this, each macro step's cross-device traffic is only
            # the per-layer TP collectives GSPMD inserts in the forward
            # pass — the host never moves pool bytes again (readback is
            # the per-slot token/done scalars only).
            from repro.distributed import serve_sharding
            plan = self.mesh_plan
            self.params = jax.device_put(
                self.params, plan.params_shardings_for(self.fam, cfg,
                                                       self.params))
            if self.speculative is not None:
                self.speculative = SpeculativeConfig(
                    self.speculative.cfg,
                    jax.device_put(
                        self.speculative.params,
                        plan.params_shardings_for(
                            get_family(self.speculative.cfg),
                            self.speculative.cfg,
                            self.speculative.params)),
                    self.speculative.d)
            self._pools = tuple(
                jax.device_put(p, plan.pool_shardings(f, c, p, m))
                for f, c, p, m in zip(fams, cfgs, self._pools,
                                      self._metas))
            self._state = jax.device_put(self._state,
                                         plan.state_shardings())
            self.params_bytes_per_device = serve_sharding.per_device_bytes(
                self.params)
            self.pool_bytes_per_device = serve_sharding.per_device_bytes(
                self._pools)
        else:
            from repro.distributed.serve_sharding import per_device_bytes
            self.params_bytes_per_device = per_device_bytes(self.params)
            self.pool_bytes_per_device = per_device_bytes(self._pools)
        if self.mesh_plan is not None and self.mesh_plan.data > 1:
            # admission round-robins consecutive requests across the data
            # replicas' slot bands (pop from the end)
            self.free = self.mesh_plan.free_slot_order(capacity)[::-1]
        else:
            self.free = list(range(capacity))[::-1]  # pop -> slot 0..

        spec_key = None if speculative is None \
            else (speculative.cfg, speculative.d)
        (self._loop, self._prefill, self._draft_prefill, self._admit,
         self._evict, self._hit_admit, self._fb_loop,
         self._reg_copy) = _jitted_engine_fns(
            cfg, self.k, self.sampling, spec_key, self._metas,
            self.mesh_plan)

    @property
    def pool(self):
        """The target model's slot pool (kept for telemetry/tests)."""
        return self._pools[0]

    @property
    def acceptance_rate(self) -> float:
        """Fraction of draft proposals the target accepted (speculative
        mode; 0.0 before any speculative block was read back)."""
        return self.n_spec_accepted / max(self.n_spec_proposed, 1)

    @property
    def pages_in_use(self) -> int:
        """Live (refcounted) pages in the shared arena right now (0 when
        dense)."""
        return self._alloc.pages_in_use() if self._alloc is not None else 0

    @property
    def pages_highwater(self) -> int:
        """Peak live shared-arena pages since construction (0 when
        dense)."""
        return self._alloc.highwater if self._alloc is not None else 0

    @property
    def prefix_hit_rate(self) -> float:
        """Fraction of prefix probes served from resident pages (current
        drain window)."""
        probes = self.n_prefix_hits + self.n_prefix_misses
        return self.n_prefix_hits / max(probes, 1)

    def lifetime_totals(self) -> Dict[str, int]:
        """Window counters summed across every drained window PLUS the
        live one — the "since boot" view ``drain()`` no longer clobbers."""
        return {c: self.lifetime[c] + getattr(self, c)
                for c in _WINDOW_COUNTERS}

    # ------------------------------------------------------------- admission
    def _reject(self, uid: int, why: str):
        """Graceful rejection: record, journal, keep serving.  The uid is
        NOT marked seen — a corrected resubmission is fine."""
        self.rejected[uid] = why
        self.outcomes[uid] = "rejected"
        if self.journal is not None:
            self.journal.record_reject(uid, why)

    def _invalid_reason(self, req: Request) -> Optional[str]:
        """Every malformed-request class, in one place.  A mid-trace bad
        request must never raise out of ``submit`` — a replayed trace (or
        a hostile client) would otherwise kill every in-flight sequence
        over one request that was never servable anyway."""
        P, nc = len(req.prompt), req.n_committed
        if req.max_new_tokens < 1:
            return ("max_new_tokens must be >= 1 "
                    "(prefill always emits the first token)")
        if P < 1:
            return "empty prompt"
        if not (0 <= nc < req.max_new_tokens and nc < P):
            return (f"n_committed {nc} must lie in [0, max_new_tokens) "
                    "and leave at least one real prompt token")
        if req.eos_id is not None and not (
                0 <= req.eos_id < self.cfg.vocab_size):
            return (f"eos_id {req.eos_id} outside the vocabulary "
                    f"[0, {self.cfg.vocab_size})")
        if req.deadline is not None and req.deadline <= 0:
            return f"deadline must be > 0 (got {req.deadline})"
        toks = np.asarray(req.prompt)
        if toks.size and (int(toks.min()) < 0
                          or int(toks.max()) >= self.cfg.vocab_size):
            return (f"prompt tokens outside the vocabulary "
                    f"[0, {self.cfg.vocab_size})")
        # a resumed request's committed run sits in its prompt, so the
        # cache needs P - nc original + max_new positions, not P + max_new
        if P - nc + req.max_new_tokens > self.max_len:
            return (f"prompt {P - nc} + {req.max_new_tokens} new tokens "
                    f"exceeds max_len {self.max_len}")
        if self._alloc is not None:
            need = max(paged_lib.pages_needed(
                P, req.max_new_tokens - nc, m)
                for m in self._metas if m is not None)
            if need > self._alloc.meta.n_pages:
                # a request no eviction wave can ever make room for must
                # not enter the queue: _admit_batch would push it back to
                # the front forever and livelock the whole server
                return (f"needs {need} pages but the arena holds only "
                        f"{self._alloc.meta.n_pages} (raise --pages or "
                        f"shrink the request)")
        return None

    def submit(self, req: Request):
        if req.uid in self._seen_uids or any(
                r.uid == req.uid for r in self._held_for_upgrade):
            # a DUPLICATE uid is a caller bug, not a malformed request:
            # silently rejecting it would orphan the caller's wait on
            # the first submission's output
            raise ValueError(f"request uid {req.uid} already submitted")
        if self.upgrade_state == "relayout":
            # mid-swap the geometry (and therefore validity — max_len,
            # vocab, page need) is changing underneath us: hold the
            # request and run it through the ordinary submit path once
            # the flip lands, instead of racing the pool re-layout
            self._held_for_upgrade.append(req)
            self.n_held_for_upgrade += 1
            self._t_submit.setdefault(req.uid, time.monotonic())
            return
        self._submit_checked(req)

    def _submit_checked(self, req: Request):
        """Validate + enqueue (the body of ``submit`` past the dup-uid
        and upgrade gates; also the release path for held submissions)."""
        why = self._invalid_reason(req)
        if why is not None:
            self._t_submit.pop(req.uid, None)
            self._reject(req.uid, f"request {req.uid}: {why}")
            return
        self._seen_uids.add(req.uid)
        # setdefault: a request held across a swap keeps its original
        # submit time, so deadlines/shedding count the held window too
        self._t_submit.setdefault(req.uid, time.monotonic())
        if req.deadline is not None:
            self._any_deadline = True
        if self.journal is not None:
            self.journal.record_submit(req)
        self.waiting.append(req)

    def _bucketed(self, n: int) -> int:
        b = self.prefill_bucket
        return min(-(-n // b) * b, self.max_len)

    def _select_admissions(self, now: Optional[float]) -> List[Request]:
        """Pick the arrived requests to admit into the free slots.

        FIFO takes them in submission order (the original behaviour);
        ``spf`` sorts the currently-arrived set by bucketed prefill
        length first (ties by submission order), so short prompts share
        admission buckets instead of padding up to a long straggler's
        bucket — less pad waste per batched prefill and faster TTFT for
        cheap requests.  Selection never skips an arrived request when a
        slot is free for it.

        Cost note: this used to ``del self.waiting[i]`` once per taken
        request — each delete is O(queue) on a deque, so a deep backlog
        paid O(queue * capacity) per admission wave on top of the scan.
        Selection is now one linear pass and ONE queue rebuild per wave
        (and the common fifo/no-clock case is a plain popleft run).
        """
        nfree = len(self.free)
        if nfree == 0 or not self.waiting:
            return []
        if now is None and self.policy == "fifo":
            # everything has "arrived": take straight off the head
            return [self.waiting.popleft()
                    for _ in range(min(nfree, len(self.waiting)))]
        items = list(self.waiting)
        arrived = [i for i, r in enumerate(items)
                   if now is None or r.arrival <= now]
        if self.policy == "spf":
            arrived.sort(key=lambda i: (
                self._bucketed(len(items[i].prompt)), i))
        take = arrived[:nfree]
        if not take:
            return []
        taken = set(take)
        self.waiting = collections.deque(
            r for i, r in enumerate(items) if i not in taken)
        return [items[i] for i in take]

    def _alloc_request(self, req: Request):
        """Reserve shared-arena pages for one request.

        Returns an admission record, or None on backpressure (nothing is
        held — the alloc is all-or-nothing).  A request allocates its
        WORST-CASE page count across the engine's paged pools once, with
        a reference in every paged pool's namespace; each pool's block
        table consumes the leading slice of the same ids (page ``p`` is
        a row in every pool's arenas), so draft and target trade freely
        inside one budget.

        The target pool is probed for a shared-prefix hit first.  Full /
        MLA layouts: every full page strictly before the prompt's last
        token must resolve through the registry (full chain or nothing);
        the request increfs the resident pages, allocates only its
        private tail, and rides the no-prefill admission path.  Ring
        layouts cannot alias resident pages (the slot's ring keeps
        wrapping over them), so a ring hit pins the registered tail
        copies only long enough for ``_admit_hits`` to COPY them into
        the slot's freshly-allocated private ring pages — the chained
        digest of the last looked-up page commits to the entire prefix,
        so matching just the reconstructible tail still proves identity.
        """
        P = len(req.prompt)
        n_new = req.max_new_tokens - req.n_committed
        alloc = self._alloc
        ns_all = tuple(self._ns_of.values())
        info = {"hit": False, "share": 0, "nreg": 0, "digests": None,
                "pids": None, "resident": None}
        if self._prefix_ok and not self._arena_degraded:
            meta = self._metas[0]
            digests = paged_lib.prefix_digests(req.prompt, meta.page)
            info["digests"] = digests
            share = (P - 1) // meta.page  # >= 1 private tail token stays
            # rings can only reconstruct the last nblk - 1 full pages
            # (the prefill tail always clobbered the oldest ring page)
            nreg = min(share, meta.nblk - 1) if self._windowed else share
            resident = alloc.lookup(digests[share - nreg:share]) \
                if nreg > 0 else None
            if resident is not None:
                # Pin the resident pages BEFORE the tail alloc: under
                # memory pressure alloc() reclaims zero-ref LRU-retained
                # pages, which can include the very pages lookup() just
                # returned — the same physical page would then serve as
                # both a shared prefix page and a private tail page of
                # this slot, and tail writes would corrupt the prefix KV.
                alloc.incref(resident)
                total = paged_lib.pages_needed(P, n_new, meta)
                # a ring hit's pages are ALL private (resident copies
                # are sources for the reconstruction, not aliased)
                tail = alloc.alloc(total if self._windowed
                                   else total - share, ns=ns_all)
                if tail is None:
                    # Tail backpressure, NOT a registry miss: unpin and
                    # wait for the next eviction wave.  (A fresh full
                    # alloc of ``total > tail`` pages cannot succeed
                    # either, so don't fall through to the miss path.)
                    self._zero_pending.extend(alloc.release(resident))
                    self.n_prefix_stalls += 1
                    return None
                info.update(hit=True, share=share, nreg=nreg)
                if self._windowed:
                    info["pids"] = tail
                    info["resident"] = list(resident)
                else:
                    info["pids"] = list(resident) + tail
                self.n_prefix_hits += 1
                self.n_pages_allocated += len(tail)
                return info
            if nreg > 0:
                self.n_prefix_misses += 1
        paged_metas = [m for m in self._metas if m is not None]
        # degradation ladder: once the arena has seen a poisoned slot,
        # sharing is off and every admission reserves its FULL block
        # table (dense-pool semantics on paged storage) — worst-case
        # isolation in exchange for capacity
        need = max(m.nblk for m in paged_metas) if self._arena_degraded \
            else max(paged_lib.pages_needed(P, n_new, m)
                     for m in paged_metas)
        pids = alloc.alloc(need, ns=ns_all)
        if pids is None:
            return None
        info["pids"] = pids
        self.n_pages_allocated += len(pids)
        if self._arena_degraded:
            self.n_degraded_admissions += 1
        return info

    def _admit_batch(self, now: Optional[float]):
        """Admit every arrived request a free slot can take, ONE prefill
        dispatch per model + ONE pool/state scatter + ONE host sync per
        prefill-bucket group — instead of three host syncs per request.

        Paged pools add two stages in front: a host-side page-allocation
        pass (all-or-nothing per request; the first request that cannot
        get its pages returns itself and everything grabbed after it to
        the FRONT of the queue, preserving order), and the prefix probe
        that diverts full-chain hits to the no-prefill admission path.
        """
        if self._oom_waves > 0:
            # injected allocator exhaustion: this wave admits nothing
            # (requests stay queued — exactly the page-backpressure path)
            if self.waiting:
                self._oom_waves -= 1
            return
        grabbed = self._select_admissions(now)
        if not grabbed:
            return
        if self._paged:
            pairs = []
            for i, r in enumerate(grabbed):
                info = self._alloc_request(r)
                if info is None:
                    # page backpressure: wait for the next eviction wave
                    self.waiting.extendleft(reversed(grabbed[i:]))
                    break
                pairs.append((r, info))
        else:
            pairs = [(r, None) for r in grabbed]
        misses = [(r, a) for r, a in pairs if a is None or not a["hit"]]
        hits = [(r, a) for r, a in pairs if a is not None and a["hit"]]
        if misses:
            self._admit_miss_groups(misses)
        if hits:
            self._admit_hits(hits)

    def _admit_miss_groups(self, pairs):
        """The batched-prefill admission path (dense pools, and paged
        requests whose prefix missed)."""
        groups: Dict[int, list] = {}
        for r, a in pairs:
            groups.setdefault(self._bucketed(len(r.prompt)),
                              []).append((r, a))
        for bucket, group in sorted(groups.items()):
            n = len(group)
            npad = _pow2(n)  # bound (group size, bucket) compile count
            padded = np.zeros((npad, bucket), np.int32)
            plens = np.ones((npad,), np.int32)
            rem0 = np.zeros((npad,), np.int32)
            eos_new = np.full((npad,), -1, np.int32)
            # padding rows target the out-of-range slot ``capacity``:
            # their scatters are dropped entirely (paged pools likewise
            # pad block-table rows with the out-of-range page sentinel)
            slots = np.full((npad,), self.capacity, np.int32)
            bt_rows = [None if m is None else
                       np.full((npad, m.nblk), m.sentinel, np.int32)
                       for m in self._metas]
            for j, (r, a) in enumerate(group):
                plens[j] = len(r.prompt)
                padded[j, :plens[j]] = r.prompt
                # a resume's committed run is part of its prompt and
                # already spent that much budget
                rem0[j] = r.max_new_tokens - r.n_committed - 1
                eos_new[j] = -1 if r.eos_id is None else r.eos_id
                slots[j] = self.free.pop()
                if a is not None:
                    pids = a["pids"]
                    self._slot_pages[int(slots[j])] = pids
                    for pi, m in enumerate(self._metas):
                        if m is not None:
                            cnt = min(len(pids), m.nblk)
                            bt_rows[pi][j, :cnt] = pids[:cnt]
            rows = [self.fam.init_cache(self.cfg, npad, self.max_len)]
            # pad-tail cache entries are garbage but never visible: each
            # decode step overwrites its own position before the per-row
            # length mask reaches it
            if self.sampling is None:
                first, rows[0] = self._prefill(
                    self.params, jnp.asarray(padded), jnp.asarray(plens),
                    rows[0])
                keys_dev = jnp.zeros((npad, 2), jnp.uint32)
            else:
                # chain roots are derived from (seed, uid) ON DEVICE in
                # the same prefill dispatch — no key round-trip/sync;
                # ``skips`` replays a resume's committed-run chain splits
                # so its first fresh sample draws from the same chain
                # position as the uninterrupted run
                uids = np.zeros((npad,), np.int32)
                uids[:n] = [r.uid for r, _ in group]
                skips = np.zeros((npad,), np.int32)
                skips[:n] = [r.n_committed for r, _ in group]
                first, rows[0], keys_dev = self._prefill(
                    self.params, jnp.asarray(padded), jnp.asarray(plens),
                    rows[0], jnp.asarray(uids), jnp.asarray(skips))
            if self.speculative is not None:
                # the draft pool admits the SAME prompt rows: its per-row
                # state after the real prompt, first token comes from the
                # target
                draft_rows = get_family(self.speculative.cfg).init_cache(
                    self.speculative.cfg, npad, self.max_len)
                rows.append(self._draft_prefill(
                    self.speculative.params, jnp.asarray(padded),
                    jnp.asarray(plens), draft_rows))
                self.n_prefills += 1
            if (self._windowed and self._prefix_ok
                    and not self._arena_degraded
                    and self._reg_copy is not None):
                # ring prefix cache: the admit scatter is about to write
                # RING-wrapped pages, which the donor will keep
                # overwriting — so copy the prompt's last intact full
                # pages out of the prefill scratch into registry-only
                # pages first (best-effort: an admission proceeds fine
                # without registering, it just can't donate hits)
                meta = self._metas[0]
                reg_pids = np.full((npad, meta.nblk), meta.sentinel,
                                   np.int32)
                reg_blk = np.zeros((npad, meta.nblk), np.int32)
                reg_records = []
                for j, (r, a) in enumerate(group):
                    if a is None or not a["digests"]:
                        continue
                    share = (len(r.prompt) - 1) // meta.page
                    nreg = min(share, meta.nblk - 1)
                    if nreg <= 0:
                        continue
                    got = self._alloc.alloc(nreg)
                    if got is None:
                        continue
                    for t, ab in enumerate(range(share - nreg, share)):
                        reg_pids[j, t] = got[t]
                        reg_blk[j, t] = ab % meta.nblk
                    reg_records.append(
                        (a["digests"][share - nreg:share], got))
                if reg_records:
                    pool0 = self._reg_copy(
                        self._pools[0], rows[0], jnp.asarray(reg_pids),
                        jnp.asarray(reg_blk))
                    self._pools = (pool0,) + self._pools[1:]
                    for dg, got in reg_records:
                        self._alloc.register(dg, got)
                        # registered pages retire to the LRU with their
                        # bytes intact; a first-writer-wins loser comes
                        # back on the zero list and is freed
                        self._zero_pending.extend(self._alloc.release(got))
            self._pools, self._state = self._admit(
                self._pools, tuple(rows), self._state, jnp.asarray(slots),
                tuple(None if b is None else jnp.asarray(b)
                      for b in bt_rows),
                first, jnp.asarray(plens), jnp.asarray(rem0),
                jnp.asarray(eos_new), keys_dev)
            self.n_prefills += 1
            first_host = np.asarray(first)
            self.n_host_syncs += 1
            t = time.monotonic()
            for j, (r, a) in enumerate(group):
                # a resume re-enters holding its committed run: output
                # continuity without replaying already-delivered tokens
                prior = [int(x) for x in
                         r.prompt[len(r.prompt) - r.n_committed:]] \
                    if r.n_committed else []
                seq = _Sequence(r, int(slots[j]), pos=int(plens[j]),
                                tokens=prior + [int(first_host[j])],
                                t_first=t)
                self.active[seq.slot] = seq
                self.n_tokens += 1
                self.n_admitted += 1
                if self.journal is not None:
                    self.journal.record_tokens(r.uid, [int(first_host[j])])
                if (a is not None and self._prefix_ok and a["digests"]
                        and not self._windowed):
                    # pages fully covered by the prompt now hold its
                    # canonical prefill-built KV — make them shareable.
                    # (Tail pages decode-built by the HIT path are never
                    # registered: only prefill bytes enter the registry.
                    # Windowed rings registered via the copy pass above.)
                    reg = len(r.prompt) // self._metas[0].page
                    if reg:
                        self._alloc.register(a["digests"][:reg],
                                             a["pids"][:reg])
                self._finish_if_done(seq, seq.tokens[-1])
            if self.journal is not None:
                # ride the admission host sync that just happened
                self.journal.flush()

    def _admit_hits(self, pairs):
        """No-prefill admission: point the slots' leading block-table
        entries at the resident shared pages (full / MLA layouts) or
        reconstruct the slot's private ring from the registered
        absolute-position copies (windowed layouts), then run ONLY the
        private tail tokens (at most one page of them) through masked
        decode steps inside one jit — no bucket prefill dispatch at
        all.  Sampled engines derive the first token from the request's
        (seed, uid) chain inside the same jit."""
        meta = self._metas[0]
        n = len(pairs)
        npad = _pow2(n)
        slots = np.full((npad,), self.capacity, np.int32)
        bt_rows = np.full((npad, meta.nblk), meta.sentinel, np.int32)
        src_pids = np.full((npad, meta.nblk), meta.sentinel, np.int32)
        dst_pids = np.full((npad, meta.nblk), meta.sentinel, np.int32)
        tail_tokens = np.zeros((npad, meta.page), np.int32)
        tail_len = np.zeros((npad,), np.int32)
        pos0 = np.zeros((npad,), np.int32)
        plens = np.ones((npad,), np.int32)
        rem0 = np.zeros((npad,), np.int32)
        eos_new = np.full((npad,), -1, np.int32)
        uids = np.zeros((npad,), np.int32)
        skips = np.zeros((npad,), np.int32)
        for j, (r, a) in enumerate(pairs):
            pids = a["pids"]
            slots[j] = self.free.pop()
            self._slot_pages[int(slots[j])] = pids
            bt_rows[j, :len(pids)] = pids
            pos0[j] = a["share"] * meta.page
            if self._windowed:
                # absolute page ``ab`` was registered at copy ``t`` and
                # lands in the slot's private ring page for block
                # ``ab % nblk`` — the exact rotation a sequential fill
                # of the ring would have left it at
                for t, ab in enumerate(range(a["share"] - a["nreg"],
                                             a["share"])):
                    src_pids[j, t] = a["resident"][t]
                    dst_pids[j, t] = pids[ab % meta.nblk]
            tail = np.asarray(r.prompt[pos0[j]:], np.int32)
            tail_len[j] = len(tail)
            tail_tokens[j, :len(tail)] = tail
            plens[j] = len(r.prompt)
            rem0[j] = r.max_new_tokens - r.n_committed - 1
            eos_new[j] = -1 if r.eos_id is None else r.eos_id
            uids[j] = r.uid
            skips[j] = r.n_committed
        self._pools, self._state, first = self._hit_admit(
            self.params, self._pools, self._state, jnp.asarray(slots),
            jnp.asarray(bt_rows), jnp.asarray(src_pids),
            jnp.asarray(dst_pids), jnp.asarray(tail_tokens),
            jnp.asarray(tail_len), jnp.asarray(pos0), jnp.asarray(plens),
            jnp.asarray(rem0), jnp.asarray(eos_new), jnp.asarray(uids),
            jnp.asarray(skips))
        if self._windowed:
            # the reconstruction copy has consumed the resident pages
            # (ordering via the donated pool buffer chain); unpin them —
            # still-registered pages retire back to the LRU intact
            for _, a in pairs:
                if a["resident"]:
                    self._zero_pending.extend(
                        self._alloc.release(a["resident"]))
        first_host = np.asarray(first)  # capacity-wide: index by slot
        self.n_host_syncs += 1
        t = time.monotonic()
        for j, (r, a) in enumerate(pairs):
            slot = int(slots[j])
            prior = [int(x) for x in
                     r.prompt[len(r.prompt) - r.n_committed:]] \
                if r.n_committed else []
            seq = _Sequence(r, slot, pos=int(plens[j]),
                            tokens=prior + [int(first_host[slot])],
                            t_first=t)
            self.active[slot] = seq
            self.n_tokens += 1
            self.n_admitted += 1
            if self.journal is not None:
                self.journal.record_tokens(r.uid, [int(first_host[slot])])
            self._finish_if_done(seq, seq.tokens[-1])
        if self.journal is not None:
            self.journal.flush()

    # ------------------------------------------------------------- lifecycle
    def _finish_if_done(self, seq: _Sequence, last_token: int):
        """Host-side stopping rule — the exact mirror of the in-scan rule
        (the device marks the row done at the same token)."""
        done = (len(seq.tokens) >= seq.req.max_new_tokens
                or (seq.req.eos_id is not None
                    and last_token == seq.req.eos_id))
        if not done:
            return
        self._retire(seq, "finished")

    def _retire(self, seq: _Sequence, outcome: str):
        """Retire a sequence with a terminal ``outcome`` — the shared
        tail of normal completion AND forced eviction (expiry,
        quarantine).  Partial tokens are still delivered: a request the
        watchdog killed keeps everything it committed."""
        seq.t_done = time.monotonic()
        self.finished[seq.req.uid] = np.asarray(seq.tokens, np.int32)
        self.outcomes[seq.req.uid] = outcome
        if self.journal is not None:
            self.journal.record_finish(seq.req.uid, outcome)
        self.retired.append(seq)
        del self.active[seq.slot]
        # the slot re-enters ``free`` only once its eviction has been
        # APPLIED (_flush_evictions) — handing it out earlier would let a
        # same-wave admission claim it and then be wiped by the pending
        # zero-evict
        self._evict_pending.append(seq.slot)

    def _quarantine(self, seq: _Sequence):
        """Evict a slot whose logits went non-finite.  The device row
        already froze itself (the in-scan sentinel folds into the done
        mask at the bad step, committing nothing from it), so quarantine
        is an ordinary forced retirement — plus arena degradation: a
        paged pool can no longer trust resident prefix pages, so the
        registry is flushed and admissions fall back to full
        reservation."""
        self.n_quarantined += 1
        self._retire(seq, "quarantined")
        if self._alloc is not None and not self._arena_degraded:
            self._arena_degraded = True
            self._zero_pending.extend(self._alloc.flush_registry())
            self._prefix_ok = False

    def _deadline_of(self, req: Request) -> Optional[float]:
        return req.deadline if req.deadline is not None else self.deadline

    def _age(self, req: Request, now: Optional[float]) -> float:
        """Seconds since the request entered the system: trace-clock when
        replaying arrivals, wall-clock since ``submit`` otherwise."""
        if now is not None:
            return now - req.arrival
        return time.monotonic() - self._t_submit.get(req.uid,
                                                     time.monotonic())

    def _expire(self, now: Optional[float]):
        """Deadline watchdog + queue-age load shedding.  No-op (single
        dict check) unless a TTL or shed threshold is configured, so the
        fault-free path pays nothing."""
        if not self._any_deadline and self.shed_age is None:
            return
        for seq in list(self.active.values()):
            ddl = self._deadline_of(seq.req)
            if ddl is not None and self._age(seq.req, now) > ddl:
                self.n_expired += 1
                self._retire(seq, "expired")
        if not self.waiting:
            return
        keep = collections.deque()
        for r in self.waiting:
            age = self._age(r, now)
            ddl = self._deadline_of(r)
            if ddl is not None and age > ddl:
                # expired before ever getting a slot: empty output, same
                # terminal telemetry as an active expiry
                self.n_expired += 1
                self.finished[r.uid] = np.zeros((0,), np.int32)
                self.outcomes[r.uid] = "expired"
                if self.journal is not None:
                    self.journal.record_finish(r.uid, "expired")
            elif self.shed_age is not None and age > self.shed_age:
                # sustained backpressure: drop the oldest queued work
                # with an explicit outcome instead of serving everyone
                # late; the uid may be resubmitted after the storm
                self.n_shed += 1
                self.outcomes[r.uid] = "shed"
                self.rejected[r.uid] = (
                    f"shed after {age:.3f}s queued (> {self.shed_age})")
                self._seen_uids.discard(r.uid)
                if self.journal is not None:
                    self.journal.record_finish(r.uid, "shed")
            else:
                keep.append(r)
        self.waiting = keep

    def _flush_evictions(self):
        """Zero-evict retired slots and reset their decode state, batched
        into one fixed-shape donated scatter (slot list padded to capacity
        with the dropped out-of-range index — a single compile).

        Even though admission's full-row overwrite already guarantees
        correctness, in multi-tenant serving a retired request's KV must
        not outlive the request in device memory; resetting the frozen
        token also means idle-slot no-op steps derive from token 0, never
        from a previous tenant's text.

        Paged pools release the retired slots' pages here too (symmetric
        with slot reuse — a page re-enters circulation only once its
        zeroing is applied).  Pages whose refcount drops to zero while
        PREFIX-REGISTERED are retained with their bytes intact (they ARE
        the cached value) and are absent from the zero list.
        """
        if not self._evict_pending and not (self._paged
                                            and self._zero_pending):
            return
        zero = list(self._zero_pending)
        self._zero_pending.clear()
        for slot in self._evict_pending:
            pids = self._slot_pages.pop(slot, None)
            if pids:
                # one reference per namespace was taken at admission; a
                # page crosses GLOBAL zero during exactly one of these
                # releases and must then be zeroed in EVERY paged pool
                # (it is a row in each pool's arenas)
                for ns in self._ns_of.values():
                    zero.extend(self._alloc.release(pids, ns=ns))
        slots = np.full((self.capacity,), self.capacity, np.int32)
        slots[:len(self._evict_pending)] = self._evict_pending
        if not self._paged:
            self._pools, self._state = self._evict(
                self._pools, self._state, jnp.asarray(slots),
                (None,) * len(self._pools))
        else:
            # fixed zero-list shape (capacity * max nblk, shared by all
            # paged pools) bounds the compile count; overflow (possible
            # after alloc rollbacks) loops — the slot scatter is
            # idempotent
            lim = self.capacity * max(m.nblk for m in self._metas
                                      if m is not None)
            while True:
                take = zero[:lim]
                del zero[:lim]
                chunk = []
                for m in self._metas:
                    if m is None:
                        chunk.append(None)
                        continue
                    zp = np.full((lim,), m.sentinel, np.int32)
                    zp[:len(take)] = take
                    chunk.append(jnp.asarray(zp))
                self._pools, self._state = self._evict(
                    self._pools, self._state, jnp.asarray(slots),
                    tuple(chunk))
                if not zero:
                    break
        self.free.extend(self._evict_pending)
        self._evict_pending.clear()

    # ----------------------------------------------------------- live upgrade
    def _apply_upgrade(self, mgr) -> None:
        """Hot-swap the grown model under live traffic.  Driven by an
        attached :class:`repro.serve.upgrade.UpgradeManager` at a
        block-readback boundary (``poll`` from :meth:`step`/:meth:`run`).

        The pause is ONE quiesce, not a compile (the manager pre-warmed
        the grown fn set): every in-flight macro block is read back and
        its tokens committed, each mid-flight sequence becomes a
        journal-style resume request (original prompt ‖ committed run,
        ``n_committed`` marking the suffix), the pools / decode state /
        shardings / jitted fns are rebuilt for the grown geometry, and
        the resumes re-enter through the ordinary admission path at the
        FRONT of the queue — ahead of everything that was still waiting.
        Zero requests are dropped: a resume's position and page need
        equal its original request's, so it is admissible by
        construction."""
        t0 = time.perf_counter()
        self.upgrade_state = "relayout"
        while self._inflight:
            self._process(self._inflight.popleft())
        self._flush_evictions()
        # page-residency delta: pages live at quiesce are all LOST by the
        # swap — cache rows are internal activations of the OLD function
        # (grown params + re-laid geometry invalidate every byte), so
        # "carried" is structurally zero and the visible cost of a live
        # upgrade is the re-prefill page bill of the resume wave.
        pages_at_swap = self.pages_in_use
        resumes: List[Request] = []
        for seq in sorted(self.active.values(),
                          key=lambda s: (s.t_first, s.req.uid)):
            r = seq.req
            orig = (r.prompt[:len(r.prompt) - r.n_committed]
                    if r.n_committed else r.prompt)
            resumes.append(Request(
                uid=r.uid,
                prompt=np.asarray(list(orig) + seq.tokens, np.int32),
                max_new_tokens=r.max_new_tokens, eos_id=r.eos_id,
                arrival=r.arrival, deadline=r.deadline,
                n_committed=len(seq.tokens)))
        self.active.clear()
        self._evict_pending.clear()
        spec = mgr.spec_config()
        self._configure(mgr.cfg_tgt, mgr.grown_params, spec)
        if spec is not None and any(self._invalid_reason(r) is not None
                                    for r in resumes):
            # enabling the post-swap draft raised the shared-arena page
            # need (a request reserves max(need) across pools) above an
            # explicit --pages budget for an in-flight resume; zero-drop
            # beats free speculation, so swap without the draft
            mgr.disable_spec("draft page need exceeds the shared arena "
                             "for an in-flight request")
            self._configure(mgr.cfg_tgt, mgr.grown_params, None)
        # queued (never-admitted) requests were validated under the OLD
        # geometry; re-validate so one that became unservable cannot
        # livelock admission.  Mid-flight resumes skip this by design.
        keep: collections.deque = collections.deque()
        for r in self.waiting:
            why = self._invalid_reason(r)
            if why is None:
                keep.append(r)
            else:
                self._seen_uids.discard(r.uid)
                self._reject(r.uid,
                             f"request {r.uid}: {why} "
                             "(post-upgrade geometry)")
        keep.extendleft(reversed(resumes))
        self.waiting = keep
        self.n_upgrades += 1
        self.upgrade_state = "swapped"
        held, self._held_for_upgrade = self._held_for_upgrade, []
        for r in held:
            self._submit_checked(r)
        if self.journal is not None:
            # last-submit-wins resume records: a crash right after the
            # swap replays exactly these prompt‖committed requests
            for r in resumes:
                self.journal.record_submit(r)
            self.journal.flush()
        pause_ms = (time.perf_counter() - t0) * 1e3
        self.last_upgrade_pause_ms = pause_ms
        pages_reprefill = 0
        if self._alloc is not None:
            pages_reprefill = sum(
                max(paged_lib.pages_needed(len(r.prompt),
                                           r.max_new_tokens, m)
                    for m in self._metas if m is not None)
                for r in resumes)
        mgr._swapped(self, pause_ms, resumes,
                     pages_resident=pages_at_swap,
                     pages_reprefilled=pages_reprefill)

    # ---------------------------------------------------------------- faults
    def _inject(self, f):
        """Fire one FaultPlan record.  Called from ``_dispatch`` only
        when a plan is attached — the default path never gets here."""
        self.n_faults_injected += 1
        if f.kind == "crash":
            # kill -9 at a step boundary: journaled state survives,
            # unread in-flight blocks do not
            if self.journal is not None:
                self.journal.flush()
            raise faults_lib.EngineKilled(
                f"injected crash at engine step {self._fault_step}")
        if f.kind in ("slow", "hang"):
            time.sleep(f.duration)
            return
        if f.kind == "oom":
            self._oom_waves += max(int(f.duration), 1)
            return
        if f.kind == "malformed":
            # a hostile request arriving mid-trace; the unified rejection
            # path must absorb it without disturbing in-flight work
            self.submit(Request(uid=-(1000 + self._fault_step),
                                prompt=np.zeros((0,), np.int32),
                                max_new_tokens=1))
            return
        # kind == "nan": corrupt a live slot's cache bytes on device —
        # the NaN flows through real attention into real logits, where
        # the in-scan sentinel must catch it
        slot = f.slot if f.slot in self.active else (
            min(self.active) if self.active else None)
        if slot is None or f.pool >= len(self._pools):
            return
        if self._poison_jit is None:
            self._poison_jit = jax.jit(faults_lib.poison_pool,
                                       donate_argnums=(0,))
        meta = self._metas[f.pool]
        # paged pools poison the slot's first page (attention reads it
        # every step); the page id also guards dense engines, where it
        # is simply unused.  The shared id space means the slot's first
        # page is a row of EVERY paged pool, so the same id is right for
        # whichever pool the fault targets.
        pid = self._slot_pages[slot][0] if meta is not None else 0
        pools = list(self._pools)
        pools[f.pool] = self._poison_jit(pools[f.pool], jnp.int32(slot),
                                         jnp.int32(pid))
        self._pools = tuple(pools)

    # ------------------------------------------------------------- step loop
    def _dispatch(self):
        """Launch one on-device macro step (K decode steps — or K whole
        speculative draft→verify→commit blocks — with no sync)."""
        if self.faults is not None:
            self._fault_step += 1
            for f in self.faults.due(self._fault_step):
                self._inject(f)
        tokens, positions, remaining, eos_ids, done, keys = self._state
        stats = None
        dbad = None
        if self.speculative is not None and not self._spec_fallback:
            (block, valid, poison, dbad, tokens, positions, remaining,
             done, pool_t, pool_d, keys, n_prop, n_acc) = self._loop(
                self.params, self.speculative.params, tokens, positions,
                remaining, eos_ids, done, self._pools[0], self._pools[1],
                keys)
            self._pools = (pool_t, pool_d)
            stats = (n_prop, n_acc)
        else:
            # _fb_loop: a speculative engine whose draft misbehaved keeps
            # serving through the plain macro loop on its TARGET pool
            loop = self._fb_loop if self._spec_fallback else self._loop
            if self.sampling is not None:
                (block, valid, poison, tokens, positions, remaining, done,
                 pool, keys) = loop(self.params, tokens, positions,
                                    remaining, eos_ids, done,
                                    self._pools[0], keys)
            else:
                (block, valid, poison, tokens, positions, remaining, done,
                 pool) = loop(self.params, tokens, positions, remaining,
                              eos_ids, done, self._pools[0])
            self._pools = (pool,) + self._pools[1:]
        self._state = (tokens, positions, remaining, eos_ids, done, keys)
        self.n_decode_dispatches += 1
        self.n_decode_steps += self.k
        live = [(slot, seq.req.uid) for slot, seq in self.active.items()]
        self._inflight.append((block, valid, poison, dbad, live, stats))

    def _process(self, item):
        """Block on one macro step's token block (the single host sync per
        dispatch) and advance the host-side sequence records.  The
        NaN/Inf sentinels and the journal's committed-token deltas ride
        this same readback — fault tolerance adds no host sync."""
        block, valid, poison, dbad, live, stats = item
        block, valid, poison, dbad, stats = jax.device_get(
            (block, valid, poison, dbad, stats))
        self.n_host_syncs += 1
        if stats is not None:
            # acceptance telemetry rides the same readback — no extra sync
            self.n_spec_proposed += int(stats[0])
            self.n_spec_accepted += int(stats[1])
        for slot, uid in live:
            seq = self.active.get(slot)
            if seq is None or seq.req.uid != uid:
                # the slot was retired (and possibly re-admitted) while this
                # block was in flight; its rows were device-done, so the
                # valid mask is all False for it anyway
                continue
            vm = valid[:, slot]
            nv = int(vm.sum())
            if nv:
                new = [int(t) for t in block[:, slot][vm]]
                seq.pos += nv
                seq.tokens.extend(new)
                self.n_tokens += nv
                if self.journal is not None:
                    self.journal.record_tokens(uid, new)
                self._finish_if_done(seq, seq.tokens[-1])
            if bool(poison[slot]) and self.active.get(slot) is seq:
                # the row froze itself at the bad step (nothing from it
                # was committed); evict it with an explicit outcome
                self._quarantine(seq)
        if dbad is not None and bool(dbad) and not self._spec_fallback:
            # degradation ladder: draft logits went non-finite — keep
            # serving every request through the plain target-only loop
            self._spec_fallback = True
            self.n_spec_fallbacks += 1
        if self.journal is not None:
            self.journal.flush()

    def step(self, now: Optional[float] = None):
        """One synchronous engine iteration: expire, evict, admit arrived
        requests into free slots, run one macro step, and read it back."""
        if self.upgrade is not None:
            self.upgrade.poll(self)
        self._expire(now)
        self._flush_evictions()
        self._admit_batch(now)
        if not self.active and not self._inflight:
            return
        if self.active:
            self._dispatch()
        while self._inflight:
            self._process(self._inflight.popleft())

    def run(self, requests=None, *, realtime: bool = False,
            pipeline: bool = True):
        """Serve until every submitted request finishes.

        ``realtime=True`` replays ``Request.arrival`` offsets against the
        wall clock (benchmark traces); otherwise arrivals are ignored and
        admission is purely slot-limited (FIFO or spf by ``policy``).

        ``pipeline=True`` double-buffers readback: macro-block N+1 is
        dispatched (device-side dataflow only) before the host blocks on
        block N's tokens, so the device never idles on readback.
        Admissions chain onto the latest dispatched state, which defers a
        queued request by at most one extra block.  ``pipeline=False``
        syncs after every block (the per-token engine of PR 1 when k=1).

        Returns {uid: np.ndarray of generated tokens} for the requests that
        finished during THIS call (``self.finished`` keeps the full
        history across calls).
        """
        already = set(self.finished)
        for r in requests or ():
            self.submit(r)
        t0 = time.monotonic()

        def wall_now():
            return time.monotonic() - t0 if realtime else None

        if not pipeline:
            while self.waiting or self.active or self._inflight:
                now = wall_now()
                if realtime and not self.active and self.waiting:
                    nxt = min(r.arrival for r in self.waiting)
                    if nxt > now:
                        time.sleep(nxt - now)
                        now = wall_now()
                self.step(now=now)
        else:
            while self.waiting or self.active or self._inflight:
                now = wall_now()
                if (realtime and not self.active and not self._inflight
                        and self.waiting):
                    nxt = min(r.arrival for r in self.waiting)
                    if nxt > now:
                        time.sleep(nxt - now)
                        now = wall_now()
                if self.upgrade is not None:
                    self.upgrade.poll(self)
                self._expire(now)
                self._flush_evictions()
                self._admit_batch(now)
                if self.active:
                    self._dispatch()
                # block on the OLDEST in-flight block only once a newer one
                # is already dispatched (or nothing is left to dispatch)
                if len(self._inflight) >= (2 if self.active else 1):
                    self._process(self._inflight.popleft())
        self._flush_evictions()
        return {uid: toks for uid, toks in self.finished.items()
                if uid not in already}

    def drain(self):
        """Return and clear all accumulated results and latency history,
        and roll the telemetry window.

        A long-lived server must call this periodically — ``finished``,
        ``retired``, ``rejected``, and the uid-dedup set otherwise grow
        with every request ever served, and the window counters (token /
        sync / acceptance / prefix tallies) otherwise accumulate forever,
        silently turning every derived rate into a since-boot average.
        The counters snapshot into ``self.lifetime`` and reset to zero;
        ``lifetime_totals()`` keeps the since-boot view.  Drained uids
        become submittable again.
        """
        out = self.finished
        self.finished = {}
        self.retired = []
        self.rejected = {}
        self.outcomes = {}
        self._seen_uids.difference_update(out)
        for uid in out:
            self._t_submit.pop(uid, None)
        for c in _WINDOW_COUNTERS:
            self.lifetime[c] += getattr(self, c)
            setattr(self, c, 0)
        return out
