"""Deterministic fault injection for the continuous-batching engine.

Fault tolerance that has never seen a fault is a hypothesis.  This
module turns the failure modes the engine claims to survive into a
seeded, replayable schedule — a :class:`FaultPlan` — that the engine
consults at two precise points (``_dispatch`` and ``_admit_batch``)
behind a no-op ``None`` default, so the fault-free hot path gains no
work at all.

Fault kinds
-----------
``nan``        Scatter NaN into the target (or draft: ``pool=1``) slot
               pool's device bytes, through the same jitted update path
               as any admission scatter — the corruption then genuinely
               flows through attention into logits, where the in-scan
               sentinels must catch it.  Not a mocked logit.
``oom``        Page-allocator exhaustion: admission waves stall for
               ``duration`` engine steps (requests stay queued), the
               backpressure path a full arena produces.
``slow``       The next dispatch is delayed by ``duration`` seconds on
               the host — a straggler device / contended runtime.
``hang``       ``slow`` with a long default (deadline watchdogs must
               fire while the engine is stuck).
``malformed``  A hostile request (empty prompt) is submitted mid-trace;
               the unified rejection path must absorb it.
``crash``      The engine flushes its journal and raises
               :class:`EngineKilled` BEFORE dispatch ``step`` launches —
               kill -9 semantics: committed tokens are journaled,
               everything in flight is lost, recovery must re-admit.

Determinism: a plan is a plain sorted list of ``(kind, step, ...)``
records; ``FaultPlan.seeded`` draws one from ``numpy``'s PCG64 so the
same (seed, n_steps) always yields the same schedule, and the chaos
bench / CI smoke can assert exact survivor sets.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional

import numpy as np

KINDS = ("nan", "oom", "slow", "hang", "malformed", "crash")


class EngineKilled(RuntimeError):
    """Raised by a ``crash`` fault: simulates the process dying at a
    step boundary.  State already journaled survives; in-flight device
    blocks do not — exactly the contract a real SIGKILL leaves."""


@dataclasses.dataclass(frozen=True)
class Fault:
    """One scheduled fault.

    ``step`` counts engine dispatches (the engine's ``_fault_step``);
    ``slot`` pins a nan fault to a slot (-1 = lowest active slot at
    injection time); ``pool`` picks the poisoned pool (0 = target,
    1 = draft); ``duration`` is seconds for slow/hang, admission waves
    for oom.
    """
    kind: str
    step: int
    slot: int = -1
    pool: int = 0
    duration: float = 0.0

    def __post_init__(self):
        if self.kind not in KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r} "
                             f"(choose from {KINDS})")


class FaultPlan:
    """A deterministic schedule of faults, consumed by engine step."""

    def __init__(self, faults: Optional[List[Fault]] = None):
        self.faults = sorted(faults or [], key=lambda f: f.step)
        self.injected: List[Fault] = []  # consumed, in firing order

    def __len__(self):
        return len(self.faults)

    def due(self, step: int) -> List[Fault]:
        """Pop every fault scheduled at or before ``step`` (at-most-once
        delivery: a consumed fault never fires again, even after the
        engine restarts with the same plan object)."""
        out = []
        while self.faults and self.faults[0].step <= step:
            out.append(self.faults.pop(0))
        self.injected.extend(out)
        return out

    # ------------------------------------------------------------ builders
    @classmethod
    def seeded(cls, seed: int, n_steps: int, *, kinds=KINDS,
               n_faults: int = 4, slow_s: float = 0.05,
               hang_s: float = 0.25, oom_waves: int = 2) -> "FaultPlan":
        """A reproducible random plan: ``n_faults`` draws over
        ``kinds`` at distinct steps in ``[1, n_steps)``.  Same (seed,
        n_steps, kinds, n_faults) → same schedule, always."""
        rng = np.random.default_rng(seed)
        n_faults = min(n_faults, max(n_steps - 1, 1))
        steps = sorted(rng.choice(np.arange(1, max(n_steps, 2)),
                                  size=n_faults, replace=False).tolist())
        faults = []
        for s in steps:
            kind = kinds[int(rng.integers(len(kinds)))]
            dur = {"slow": slow_s, "hang": hang_s,
                   "oom": float(oom_waves)}.get(kind, 0.0)
            faults.append(Fault(kind=kind, step=int(s), duration=dur))
        return cls(faults)

    @classmethod
    def parse(cls, spec: str) -> "FaultPlan":
        """Parse a CLI plan: comma-separated ``kind@step[:arg]`` items,
        e.g. ``nan@3,oom@5:2,slow@7:0.1,crash@9``.  ``arg`` is the
        duration (seconds for slow/hang, waves for oom) or the slot for
        nan.  ``seed:S[:N]`` delegates to :meth:`seeded`."""
        spec = spec.strip()
        if not spec:
            return cls([])
        if spec.startswith("seed:"):
            parts = spec.split(":")
            seed = int(parts[1])
            n_steps = int(parts[2]) if len(parts) > 2 else 32
            return cls.seeded(seed, n_steps)
        faults = []
        for item in spec.split(","):
            item = item.strip()
            if not item:
                continue
            head, _, arg = item.partition(":")
            kind, _, step = head.partition("@")
            if not step:
                raise ValueError(
                    f"fault item {item!r} is not 'kind@step[:arg]'")
            kw = {"kind": kind.strip(), "step": int(step)}
            if arg:
                if kw["kind"] == "nan":
                    kw["slot"] = int(arg)
                else:
                    kw["duration"] = float(arg)
            elif kw["kind"] == "slow":
                kw["duration"] = 0.05
            elif kw["kind"] == "hang":
                kw["duration"] = 0.25
            elif kw["kind"] == "oom":
                kw["duration"] = 2.0
            faults.append(Fault(**kw))
        return cls(faults)


# ---------------------------------------------------------------- injection
def poison_pool(pool, slot: int, pid: int):
    """Scatter NaN into one slot's live cache bytes (jit-compatible; the
    engine wraps this in a donated ``jax.jit``).

    Paged groups poison page ``pid`` (the slot's first block-table page —
    every decode step's attention reads it, so the NaN must surface in
    the row's logits within one step).  Dense float leaves poison the
    slot's whole row.  Integer leaves (block tables, recurrent counters)
    are untouched — the fault model is corrupted VALUES, not corrupted
    indices.
    """
    import jax.numpy as jnp

    def walk(p):
        if isinstance(p, dict) and "bt" in p:
            out = dict(p)
            for key in ("k", "v"):
                out[key] = p[key].at[:, pid].set(jnp.nan, mode="drop")
            return out
        if isinstance(p, dict):
            return {k: walk(v) for k, v in p.items()}
        if jnp.issubdtype(p.dtype, jnp.floating):
            return p.at[:, slot].set(jnp.nan, mode="drop")
        return p

    return walk(pool)
