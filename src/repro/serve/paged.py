"""Paged slot pool: block tables over a shared page arena.

The dense slot pool reserves a full ``(capacity, max_len)`` cache row per
slot.  This module re-lays every cache group a family DECLARES pageable
(``models.paged_groups`` — part of the slot-state protocol) as shared
page arenas plus per-slot block tables:

    seq   dense {"k": (L, B, S, KV, hd), "v": ...}
          paged {"k": (L, n_pages, page, KV, hd), "v": ...,
                 "bt": (L, B, nblk) int32}          nblk = S // page
    slot  dense {"conv": (L, B, K-1, d), ...dense carries}
          paged {"conv": (L, n_pages, K-1, d), ...dense carries,
                 "bt": (L, B, 1) int32}             the whole tail is
                                                    one page

with ``page`` the ``pad_cache_len`` quantum for ``S`` (8 below 256, 64
above).  The block table rides inside the group dict, tiled identically
per layer, so it flows through ``lax.scan`` over the layer axis with
zero plumbing changes; model code detects a paged group purely by
``"bt" in cache``.  Leaves of a declared group that are NOT named
(xlstm's mLSTM C/n/m carries) stay dense-per-slot inside the same dict.

Page-id conventions
-------------------
* Page ids live in ``[0, n_pages)``; the value ``n_pages`` is the OOB
  SENTINEL.  Scatters through a sentinel entry are dropped (jnp
  out-of-bounds scatter semantics) and gathers clamp it to the last page
  — the garbage read is finite and always hidden behind a ``kv_len`` /
  ring-validity / band mask, which pins masked logits to ``NEG_INF`` so
  the softmax contribution underflows to exactly 0.0.
* ONE page-id space spans every group of a pool — and, for a
  speculative pair, both the target and draft pools: page ``p`` is row
  ``p`` of EVERY group's arena in every engine sharing the allocator.  A
  request allocates ``pages_needed`` ids once and each group consumes
  the leading ``nblk_g`` of them, so draft and target memory trade
  freely inside one ``--pages`` budget instead of a static split.
* All layers of a group share one logical page-id space: page ``p`` is
  row ``p`` of every layer's arena, and ``bt`` is the same (B, nblk_g)
  table broadcast over L.

The host-side :class:`PageAllocator` owns the free list, per-namespace
refcounts (one namespace per engine sharing the arena), and the prefix
registry (rolling blake2b chain hashes of full prompt pages).
"Copy-on-write" prefix sharing needs no actual copy for full layouts:
shared pages cover only FULL pages strictly before a prompt's last
token, and every write a slot performs lands in its private tail pages.
Ring layouts can NOT alias (the donor wraps and overwrites its own
registered pages) — they register registry-only absolute-position
copies at admission and a hit RECONSTRUCTS the new slot's ring from the
resident tail pages (see ``serve/engine.py``).
"""
from __future__ import annotations

import dataclasses
import hashlib
from collections import OrderedDict
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class GroupMeta:
    """Static paging geometry of one declared cache group (hashable)."""
    path: tuple      # key path to the group dict from the pool root
    kind: str        # "seq" (paged sequence axis) | "slot" (whole tail)
    leaves: tuple    # arena leaf names inside the group dict
    page: int        # positions per page ("slot": the tail length)
    nblk: int        # block-table entries per slot ("slot": 1)


@dataclasses.dataclass(frozen=True)
class PoolMeta:
    """Static paging geometry of one pool (hashable: jit-cache key).

    ``page``/``nblk`` summarize the pool for the engine: ``page`` is the
    shared sequence-group quantum (0 for pools with no seq group — the
    prefix cache then has nothing to share), ``nblk`` the per-request
    allocation bound (max over groups).  ``groups`` carries the
    per-group layout; an empty tuple is the legacy single-{"k","v"}
    geometry (kept constructible for allocator-only uses in tests).
    """
    page: int
    nblk: int
    n_pages: int     # arena depth; also the OOB sentinel page id
    groups: tuple = ()

    @property
    def sentinel(self) -> int:
        return self.n_pages


def page_quantum(padded_len: int) -> int:
    """The natural page size for a padded cache axis — the same quantum
    ``pad_cache_len`` rounded to, re-derived from its output (both
    branches of the quantum divide their padded lengths exactly)."""
    return 8 if padded_len <= 256 else 64


def pool_meta(cfg, cache_shapes: Any, pages: Optional[int] = None
              ) -> Optional[PoolMeta]:
    """Paging geometry for a pool (concrete or ``jax.eval_shape`` tree).

    Reads the family's ``paged_groups`` declaration.  Returns None when
    the family declares nothing pageable, or its seq groups disagree on
    the padded sequence length (prefix pages must mean the same token
    span in every arena — never violated in the current zoo).
    """
    from repro import models

    decl = models.paged_groups(cfg)
    groups = []
    seq_geom = set()
    B = None
    for key in sorted(decl):
        kind, leaves = decl[key]
        if key not in cache_shapes:
            continue
        g = cache_shapes[key]
        lead = g[leaves[0]]
        B = lead.shape[1]
        if kind == "seq":
            S = lead.shape[2]
            page = page_quantum(S)
            if S % page:
                return None
            seq_geom.add((page, S // page))
            groups.append(GroupMeta(path=(key,), kind="seq",
                                    leaves=tuple(leaves), page=page,
                                    nblk=S // page))
        else:
            groups.append(GroupMeta(path=(key,), kind="slot",
                                    leaves=tuple(leaves),
                                    page=int(lead.shape[2]), nblk=1))
    if not groups or len(seq_geom) > 1:
        return None
    page, _ = seq_geom.pop() if seq_geom else (0, 0)
    nblk = max(g.nblk for g in groups)
    return PoolMeta(page=page, nblk=nblk,
                    n_pages=int(pages) if pages else B * nblk,
                    groups=tuple(groups))


def pool_fallback_reason(cfg) -> Optional[str]:
    """Why a config cannot serve paged — or None when it can.  The named
    counterpart of the old silent ``pool_kind`` flip."""
    from repro import models

    if not models.paged_groups(cfg):
        return (f"{cfg.family} declares no pageable cache groups "
                "(O(1) recurrent state only)")
    return None


def build_paged_pool(fam, cfg, capacity: int, max_len: int,
                     pages: Optional[int] = None,
                     n_pages: Optional[int] = None):
    """Construct a zeroed paged pool for ``fam``/``cfg``.

    Returns ``(pool, meta)``; ``meta is None`` means the family declares
    nothing pageable and ``pool`` is the ordinary dense pool.
    ``n_pages`` overrides the arena depth directly (a speculative pair
    shares one page-id space, so both pools must be built to the SAME
    depth regardless of their own defaults).
    """
    shapes = jax.eval_shape(
        lambda: fam.init_cache(cfg, capacity, max_len))
    meta = pool_meta(cfg, shapes, pages)
    if meta is None:
        return fam.init_cache(cfg, capacity, max_len), None
    if n_pages is not None and n_pages != meta.n_pages:
        meta = dataclasses.replace(meta, n_pages=int(n_pages))

    paged_paths = {g.path[0]: g for g in meta.groups}

    def dense(node):
        if isinstance(node, dict):
            return {k: dense(v) for k, v in node.items()}
        return jnp.zeros(node.shape, node.dtype)

    out = {}
    for key, grp in shapes.items():
        g = paged_paths.get(key)
        if g is None:
            out[key] = dense(grp)
            continue
        og = {}
        L = grp[g.leaves[0]].shape[0]
        for lk, leaf in grp.items():
            if lk in g.leaves:
                # (L, B, S, ...) -> (L, n_pages, page, ...) for seq;
                # (L, B, tail...) -> (L, n_pages, tail...) for slot
                tail = leaf.shape[3:] if g.kind == "seq" else leaf.shape[2:]
                og[lk] = jnp.zeros((L, meta.n_pages, g.page) + tail
                                   if g.kind == "seq" else
                                   (L, meta.n_pages) + leaf.shape[2:],
                                   leaf.dtype)
            else:
                og[lk] = jnp.zeros(leaf.shape, leaf.dtype)
        og["bt"] = jnp.full((L, capacity, g.nblk), meta.sentinel,
                            jnp.int32)
        out[key] = og
    return out, meta


def pages_needed(prompt_len: int, max_new: int, meta: PoolMeta) -> int:
    """Pages a request needs up-front so no mid-flight top-up is ever
    required — the max over the pool's groups, since every group
    consumes the leading ``nblk_g`` ids of one shared allocation.  For a
    seq group the ``nblk`` clamp covers both layouts at once: a full
    cache fits ``prompt + max_new`` inside ``nblk`` pages by the
    engine's admission check, and a ring layout wraps at ``nblk *
    page``; a slot group always needs exactly its single block."""
    if not meta.groups:  # legacy single-seq-group geometry
        return min(-(-(prompt_len + max_new) // meta.page), meta.nblk)
    need = 0
    for g in meta.groups:
        if g.kind == "seq":
            need = max(need,
                       min(-(-(prompt_len + max_new) // g.page), g.nblk))
        else:
            need = max(need, 1)
    return need


# --------------------------------------------------------------- jit helpers
def _paged_map(meta: PoolMeta):
    return {g.path[0]: g for g in meta.groups}


def admit_scatter(pool, rows, slots, bt_rows, meta: PoolMeta):
    """Scatter freshly-prefilled dense cache rows into a paged pool.
    jit-safe; donated in the engine's admit step.

    pool: the live pool pytree (paged groups carry "bt").
    rows: matching DENSE pytree of (L, npad, S, ...) prefill scratch rows
          (no "bt" keys — prefill always runs on dense scratch).
    slots: (npad,) int32 slot ids; padding rows carry the OOB slot id.
    bt_rows: (npad, meta.nblk) int32 page ids per admitted row; each
          group consumes its leading ``nblk_g`` columns; unallocated
          blocks and padding rows carry the page sentinel.
    """
    paged = _paged_map(meta)

    def dense_scatter(p, r):
        return jax.tree.map(
            lambda pl, rl: pl.at[:, slots].set(rl.astype(pl.dtype),
                                               mode="drop"), p, r)

    out = {}
    npad = bt_rows.shape[0]
    for key, grp in pool.items():
        g = paged.get(key)
        if g is None:
            out[key] = dense_scatter(grp, rows[key])
            continue
        bt_g = bt_rows[:, :g.nblk]
        flat = bt_g.reshape(-1)  # (npad * nblk_g,)
        og = {}
        L = grp["bt"].shape[0]
        for lk, leaf in grp.items():
            if lk == "bt":
                og[lk] = leaf.at[:, slots].set(
                    jnp.broadcast_to(bt_g[None], (L, npad, g.nblk)),
                    mode="drop")
            elif lk in g.leaves:
                chunks = rows[key][lk].reshape(
                    (L, npad * g.nblk) + leaf.shape[2:])
                og[lk] = leaf.at[:, flat].set(chunks.astype(leaf.dtype),
                                              mode="drop")
            else:
                og[lk] = leaf.at[:, slots].set(
                    rows[key][lk].astype(leaf.dtype), mode="drop")
        out[key] = og
    return out


def register_copy(pool, reg_pids, reg_blk, rows, meta: PoolMeta):
    """Copy prefill-scratch pages into REGISTRY-ONLY pages — the ring
    prefix-cache path: ring block tables wrap, so future hits reconstruct
    from these absolute-position copies instead of aliasing live ring
    pages (which the donor keeps overwriting).

    rows: the (L, npad, S, ...) prefill scratch handed to
    ``admit_scatter`` (ring layout for windowed configs — the caller
    passes the RING block index of each wanted absolute page in
    ``reg_blk``); reg_pids/reg_blk: (npad, nreg) int32 — destination
    page id and source block index per copy; sentinel page ids drop.
    Only seq groups participate (slot tails cannot be shared).
    """
    paged = _paged_map(meta)
    flat_pid = reg_pids.reshape(-1)
    out = {}
    for key, grp in pool.items():
        g = paged.get(key)
        if g is None or g.kind != "seq":
            out[key] = grp
            continue
        og = dict(grp)
        npad, nreg = reg_pids.shape
        for lk in g.leaves:
            leaf = grp[lk]
            L = leaf.shape[0]
            r = rows[key][lk]  # (L, npad, S, ...)
            rp = r.reshape((L, npad, r.shape[2] // g.page, g.page)
                           + r.shape[3:])
            blk = jnp.minimum(reg_blk, rp.shape[2] - 1)
            src = jnp.take_along_axis(
                rp, blk.reshape((1, npad, nreg)
                                + (1,) * (rp.ndim - 3)), axis=2)
            src = src.reshape((L, npad * nreg, g.page) + r.shape[3:])
            og[lk] = leaf.at[:, flat_pid].set(src.astype(leaf.dtype),
                                              mode="drop")
        out[key] = og
    return out


def ring_restore_copy(pool, src_pids, dst_pids, meta: PoolMeta):
    """Arena-to-arena page copy for ring prefix-hit reconstruction.

    src_pids/dst_pids: (npad, nblk) int32 — for each admitted row, copy
    registry page ``src_pids[i, j]`` into the row's private ring page
    ``dst_pids[i, j]``; sentinel destinations drop, sentinel sources
    clamp (their destinations are sentinel too).  Applies to every seq
    group (all share the page-id space and geometry).
    """
    paged = _paged_map(meta)
    flat_src = src_pids.reshape(-1)
    flat_dst = dst_pids.reshape(-1)
    out = {}
    for key, grp in pool.items():
        g = paged.get(key)
        if g is None or g.kind != "seq":
            out[key] = grp
            continue
        og = dict(grp)
        for lk in g.leaves:
            leaf = grp[lk]
            n_pages = leaf.shape[1]
            src = leaf[:, jnp.minimum(flat_src, n_pages - 1)]
            og[lk] = leaf.at[:, flat_dst].set(src, mode="drop")
        out[key] = og
    return out


def evict_clear(pool, slots, zero_pids, meta: PoolMeta):
    """Clear evicted slots.  Dense leaves zero their rows; paged groups
    zero the handed-back pages listed in ``zero_pids`` (padded with the
    page sentinel — prefix-registered pages are retained, so they are
    simply absent from the list) and reset the rows' block tables to the
    sentinel."""
    paged = _paged_map(meta)

    def dense_clear(p):
        return jax.tree.map(
            lambda pl: pl.at[:, slots].set(0, mode="drop"), p)

    out = {}
    for key, grp in pool.items():
        g = paged.get(key)
        if g is None:
            out[key] = dense_clear(grp)
            continue
        og = {}
        L, _, nblk = grp["bt"].shape
        for lk, leaf in grp.items():
            if lk == "bt":
                og[lk] = leaf.at[:, slots].set(
                    jnp.full((L, slots.shape[0], nblk), meta.sentinel,
                             jnp.int32), mode="drop")
            elif lk in g.leaves:
                og[lk] = leaf.at[:, zero_pids].set(0, mode="drop")
            else:
                og[lk] = leaf.at[:, slots].set(0, mode="drop")
        out[key] = og
    return out


def set_block_tables(pool, slots, bt_rows, meta: PoolMeta):
    """Point admitted rows' block tables at pages WITHOUT touching arena
    bytes — the prefix-hit admission path (leading entries alias resident
    pages; tail pages fill via the decode-scan tail prefill)."""
    paged = _paged_map(meta)
    out = {}
    npad = bt_rows.shape[0]
    for key, grp in pool.items():
        g = paged.get(key)
        if g is None:
            out[key] = grp
            continue
        L = grp["bt"].shape[0]
        bt_g = bt_rows[:, :g.nblk]
        out[key] = {**grp, "bt": grp["bt"].at[:, slots].set(
            jnp.broadcast_to(bt_g[None], (L, npad, g.nblk)),
            mode="drop")}
    return out


# ------------------------------------------------------------ prefix hashing
def prefix_digests(tokens, page: int) -> list:
    """Rolling chain digests of each FULL page of a prompt.

    ``digest[j]`` commits to tokens ``[0, (j+1) * page)`` — chaining means
    a page is only ever shared under an identical full prefix, never by
    content coincidence at different offsets.
    """
    toks = np.asarray(tokens, np.int64)
    out = []
    h = b""
    for j in range(len(toks) // page):
        h = hashlib.blake2b(
            h + toks[j * page:(j + 1) * page].tobytes(),
            digest_size=16).digest()
        out.append(h)
    return out


# ------------------------------------------------------------ host allocator
class PageAllocator:
    """Host-side page bookkeeping for one page-id space: free list,
    per-namespace refcounts, and the prefix registry with LRU retention
    of zero-ref registered pages (their bytes ARE the cached value —
    they are reclaimed lazily, oldest first, only when the free list
    runs dry).

    ``namespaces`` > 1 merges several engines' arenas into ONE id space
    (the speculative draft/target pair): page ``p`` is a row in every
    engine's arenas, each engine holds references in its own namespace,
    and the page returns to the free list only when EVERY namespace has
    released it — so pages freed by one engine's retirements are
    immediately allocatable by the other, with no static budget split.
    The prefix registry lives in namespace 0 (the target engine).
    """

    def __init__(self, meta: PoolMeta, namespaces: int = 1):
        self.meta = meta
        self.namespaces = namespaces
        self.free: list[int] = list(range(meta.n_pages))[::-1]
        self.refcount = np.zeros((meta.n_pages, namespaces), np.int32)
        self.registry: dict[bytes, int] = {}       # digest -> page id
        self.page_key: dict[int, bytes] = {}       # page id -> digest
        self.lru: OrderedDict[int, None] = OrderedDict()
        self.highwater = 0

    # -- capacity -----------------------------------------------------------
    def pages_in_use(self) -> int:
        return self.meta.n_pages - len(self.free) - len(self.lru)

    def available(self) -> int:
        return len(self.free) + len(self.lru)

    # -- alloc / release ----------------------------------------------------
    def alloc(self, n: int, ns=(0,)) -> Optional[list]:
        """Take ``n`` pages (refcount 1 in each namespace of ``ns``),
        reclaiming retained prefix pages oldest-first if the free list
        runs dry.  Returns None — allocating NOTHING — when fewer than
        ``n`` are available: admission backpressure is all-or-nothing
        per request."""
        if n > self.available():
            return None
        out = []
        for _ in range(n):
            if self.free:
                pid = self.free.pop()
            else:
                pid, _ = self.lru.popitem(last=False)
                self._unregister(pid)
            for i in ns:
                self.refcount[pid, i] = 1
            out.append(pid)
        self.highwater = max(self.highwater, self.pages_in_use())
        return out

    def incref(self, pids, ns: int = 0) -> None:
        for pid in pids:
            if self.refcount[pid].sum() == 0:
                # a retained registry page comes back to life
                self.lru.pop(pid, None)
            self.refcount[pid, ns] += 1
        self.highwater = max(self.highwater, self.pages_in_use())

    def release(self, pids, ns: int = 0) -> list:
        """Drop one reference per page in namespace ``ns``; returns the
        page ids whose bytes must be ZEROED (every namespace's refcount
        hit zero and the page is not prefix-registered — registered
        pages are retained in the LRU with their bytes intact)."""
        zero = []
        for pid in pids:
            self.refcount[pid, ns] -= 1
            if self.refcount[pid].sum() > 0:
                continue
            if pid in self.page_key:
                self.lru[pid] = None
                self.lru.move_to_end(pid)
            else:
                self.free.append(pid)
                zero.append(pid)
        return zero

    # -- prefix registry ----------------------------------------------------
    def _unregister(self, pid: int) -> None:
        d = self.page_key.pop(pid, None)
        if d is not None:
            self.registry.pop(d, None)

    def register(self, digests, pids) -> None:
        """Record ``pids[j]`` as holding the page whose chain digest is
        ``digests[j]``.  First writer wins — re-registering a digest that
        already resolves elsewhere is a no-op (the resident page keeps
        serving hits)."""
        for d, pid in zip(digests, pids):
            if d in self.registry or pid in self.page_key:
                continue
            self.registry[d] = pid
            self.page_key[pid] = d

    def flush_registry(self) -> list:
        """Drop the entire prefix registry — the arena-fault degradation
        path: once a poisoned slot may have flowed NaNs through shared
        pages, no resident prefix can be trusted for reuse.

        Zero-ref retained pages return to the free list; their ids are
        returned so the engine can zero their bytes in the next eviction
        scatter.  Pages still referenced by live slots are merely
        unregistered: their current holders keep decoding, and when the
        last reference drops, ``release`` now zeroes and frees them like
        any private page.
        """
        zero = list(self.lru.keys())
        for pid in zero:
            self.free.append(pid)
        self.lru.clear()
        self.registry.clear()
        self.page_key.clear()
        return zero

    def lookup(self, digests) -> Optional[list]:
        """Resolve a chain of share digests to resident pages.  Partial
        chains are misses: every looked-up position's bytes must be
        resident (full-KV shares look up the whole prefix; ring shares
        look up only the tail pages that can feed the ring — the chained
        digest of the last page already commits to the entire prefix)."""
        out = []
        for d in digests:
            pid = self.registry.get(d)
            if pid is None:
                return None
            out.append(pid)
        return out
