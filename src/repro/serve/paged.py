"""Paged KV slot pool: block tables over a shared page arena.

The dense slot pool reserves a full ``(capacity, max_len)`` cache row per
slot.  This module re-lays every sequence-axis cache group as a shared
page arena plus per-slot block tables:

    dense   {"k": (L, B, S, KV, hd), "v": ...}
    paged   {"k": (L, n_pages, page, KV, hd), "v": ...,
             "bt": (L, B, nblk) int32}

with ``page`` the ``pad_cache_len`` quantum for ``S`` (8 below 256, 64
above) and ``nblk = S // page``.  The block table rides inside the group
dict, tiled identically per layer, so it flows through ``lax.scan`` over
the layer axis with zero plumbing changes; model code detects a paged
group purely by ``"bt" in cache``.

Page-id conventions
-------------------
* Page ids live in ``[0, n_pages)``; the value ``n_pages`` is the OOB
  SENTINEL.  Scatters through a sentinel entry are dropped (jnp
  out-of-bounds scatter semantics) and gathers clamp it to the last page
  — the garbage read is finite and always hidden behind a ``kv_len`` /
  ring-validity / band mask, which pins masked logits to ``NEG_INF`` so
  the softmax contribution underflows to exactly 0.0.
* All layers of a group share one logical page-id space: page ``p`` is
  row ``p`` of EVERY layer's arena, and ``bt`` is the same (B, nblk)
  table broadcast over L.
* Pools whose sequence groups disagree on the padded cache length (none
  in the current zoo) and pools with no ``{"k", "v"}`` sequence group at
  all (xlstm's O(1) recurrent state, MLA's latent layout) are not
  pageable — the engine keeps their dense pool.

The host-side :class:`PageAllocator` owns the free list, per-page
refcounts, and the prefix registry (rolling blake2b chain hashes of full
prompt pages).  "Copy-on-write" prefix sharing needs no actual copy:
shared pages cover only FULL pages strictly before a prompt's last
token, and every write a slot performs lands at positions at or past
that last token — i.e. always in the slot's private tail pages.
"""
from __future__ import annotations

import dataclasses
import hashlib
from collections import OrderedDict
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class PoolMeta:
    """Static paging geometry of one pool (hashable: jit-cache key)."""
    page: int        # tokens per page (the pad_cache_len quantum)
    nblk: int        # block-table entries per slot (= padded S // page)
    n_pages: int     # arena depth; also the OOB sentinel page id

    @property
    def sentinel(self) -> int:
        return self.n_pages


def page_quantum(padded_len: int) -> int:
    """The natural page size for a padded cache axis — the same quantum
    ``pad_cache_len`` rounded to, re-derived from its output (both
    branches of the quantum divide their padded lengths exactly)."""
    return 8 if padded_len <= 256 else 64


def _seq_group(node: Any) -> bool:
    """A pageable cache group: exactly {"k", "v"} leaves of matching
    (L, B, S, ...) shape.  MLA's {"ckv", "kr"} and recurrent leaves fail
    this test and stay dense."""
    if not (isinstance(node, dict) and set(node.keys()) == {"k", "v"}):
        return False
    k, v = node["k"], node["v"]
    return (hasattr(k, "ndim") and k.ndim >= 4 and v.ndim == k.ndim
            and k.shape[:3] == v.shape[:3])


def _walk_groups(cache: Any):
    """Yield every pageable {"k","v"} group dict inside a pool pytree."""
    if _seq_group(cache):
        yield cache
        return
    if isinstance(cache, dict):
        for sub in cache.values():
            yield from _walk_groups(sub)


def pool_meta(cache_shapes: Any, pages: Optional[int] = None
              ) -> Optional[PoolMeta]:
    """Paging geometry for a pool (concrete or ``jax.eval_shape`` tree).

    Returns None when the pool has no pageable group or its groups
    disagree on the padded sequence length.
    """
    lens, batch = set(), set()
    for g in _walk_groups(cache_shapes):
        lens.add(g["k"].shape[2])
        batch.add(g["k"].shape[1])
    if len(lens) != 1 or len(batch) != 1:
        return None
    (S,), (B,) = lens, batch
    page = page_quantum(S)
    if S % page:
        return None
    nblk = S // page
    return PoolMeta(page=page, nblk=nblk,
                    n_pages=int(pages) if pages else B * nblk)


def build_paged_pool(fam, cfg, capacity: int, max_len: int,
                     pages: Optional[int] = None):
    """Construct a zeroed paged pool for ``fam``/``cfg``.

    Returns ``(pool, meta)``; ``meta is None`` means the family is not
    pageable and ``pool`` is the ordinary dense pool.
    """
    shapes = jax.eval_shape(
        lambda: fam.init_cache(cfg, capacity, max_len))
    meta = pool_meta(shapes, pages)
    if meta is None:
        return fam.init_cache(cfg, capacity, max_len), None

    def one(node):
        if _seq_group(node):
            out = {}
            for key in ("k", "v"):
                sd = node[key]
                L = sd.shape[0]
                out[key] = jnp.zeros(
                    (L, meta.n_pages, meta.page) + sd.shape[3:], sd.dtype)
            out["bt"] = jnp.full((L, capacity, meta.nblk), meta.sentinel,
                                 jnp.int32)
            return out
        if isinstance(node, dict):
            return {k: one(v) for k, v in node.items()}
        # dense leaf (recurrent state etc.) — allocate as-is
        return jnp.zeros(node.shape, node.dtype)

    return one(shapes), meta


def pages_needed(prompt_len: int, max_new: int, meta: PoolMeta) -> int:
    """Pages a request needs up-front so no mid-flight top-up is ever
    required.  The ``nblk`` clamp covers both layouts at once: a full
    cache fits ``prompt + max_new`` inside ``nblk`` pages by the engine's
    admission check, and a ring layout wraps at ``nblk * page``, so it
    never touches more than the full table either."""
    return min(-(-(prompt_len + max_new) // meta.page), meta.nblk)


# --------------------------------------------------------------- jit helpers
def admit_scatter(pool, rows, slots, bt_rows):
    """Scatter freshly-prefilled dense cache rows into a (possibly paged)
    pool.  jit-safe; donated in the engine's admit step.

    pool: the live pool pytree (paged groups carry "bt").
    rows: matching DENSE pytree of (L, npad, S, ...) prefill scratch rows
          (no "bt" keys — prefill always runs on dense scratch).
    slots: (npad,) int32 slot ids; padding rows carry the OOB slot id.
    bt_rows: (npad, nblk) int32 page ids per admitted row; unallocated
          blocks and padding rows carry the page sentinel.
    """
    def walk(p, r):
        if isinstance(p, dict) and "bt" in p:
            L, _, page = p["k"].shape[:3]
            npad, nblk = bt_rows.shape
            flat = bt_rows.reshape(-1)  # (npad * nblk,)
            out = {}
            for key in ("k", "v"):
                chunks = r[key].reshape(
                    (L, npad * nblk, page) + r[key].shape[3:])
                out[key] = p[key].at[:, flat].set(
                    chunks.astype(p[key].dtype), mode="drop")
            out["bt"] = p["bt"].at[:, slots].set(
                jnp.broadcast_to(bt_rows[None], (L, npad, nblk)),
                mode="drop")
            return out
        if isinstance(p, dict):
            return {k: walk(p[k], r[k]) for k in p}
        return p.at[:, slots].set(r.astype(p.dtype), mode="drop")

    return walk(pool, rows)


def evict_clear(pool, slots, zero_pids):
    """Clear evicted slots.  Dense leaves zero their rows; paged groups
    zero the handed-back pages listed in ``zero_pids`` (padded with the
    page sentinel — prefix-registered pages are retained, so they are
    simply absent from the list) and reset the rows' block tables to the
    sentinel."""
    def walk(p):
        if isinstance(p, dict) and "bt" in p:
            out = {}
            for key in ("k", "v"):
                out[key] = p[key].at[:, zero_pids].set(0, mode="drop")
            L, _, nblk = p["bt"].shape
            sent = p["k"].shape[1]
            out["bt"] = p["bt"].at[:, slots].set(
                jnp.full((L, slots.shape[0], nblk), sent, jnp.int32),
                mode="drop")
            return out
        if isinstance(p, dict):
            return {k: walk(v) for k, v in p.items()}
        return p.at[:, slots].set(0, mode="drop")

    return walk(pool)


def set_block_tables(pool, slots, bt_rows):
    """Point admitted rows' block tables at pages WITHOUT touching arena
    bytes — the prefix-hit admission path (leading entries alias resident
    pages; tail pages fill via the decode-scan tail prefill)."""
    def walk(p):
        if isinstance(p, dict) and "bt" in p:
            L = p["bt"].shape[0]
            npad, nblk = bt_rows.shape
            return {**p, "bt": p["bt"].at[:, slots].set(
                jnp.broadcast_to(bt_rows[None], (L, npad, nblk)),
                mode="drop")}
        if isinstance(p, dict):
            return {k: walk(v) for k, v in p.items()}
        return p

    return walk(pool)


# ------------------------------------------------------------ prefix hashing
def prefix_digests(tokens, page: int) -> list:
    """Rolling chain digests of each FULL page of a prompt.

    ``digest[j]`` commits to tokens ``[0, (j+1) * page)`` — chaining means
    a page is only ever shared under an identical full prefix, never by
    content coincidence at different offsets.
    """
    toks = np.asarray(tokens, np.int64)
    out = []
    h = b""
    for j in range(len(toks) // page):
        h = hashlib.blake2b(
            h + toks[j * page:(j + 1) * page].tobytes(),
            digest_size=16).digest()
        out.append(h)
    return out


# ------------------------------------------------------------ host allocator
class PageAllocator:
    """Host-side page bookkeeping for one arena: free list, refcounts,
    and the prefix registry with LRU retention of zero-ref registered
    pages (their bytes ARE the cached value — they are reclaimed lazily,
    oldest first, only when the free list runs dry)."""

    def __init__(self, meta: PoolMeta):
        self.meta = meta
        self.free: list[int] = list(range(meta.n_pages))[::-1]
        self.refcount = np.zeros(meta.n_pages, np.int32)
        self.registry: dict[bytes, int] = {}       # digest -> page id
        self.page_key: dict[int, bytes] = {}       # page id -> digest
        self.lru: OrderedDict[int, None] = OrderedDict()
        self.highwater = 0

    # -- capacity -----------------------------------------------------------
    def pages_in_use(self) -> int:
        return self.meta.n_pages - len(self.free) - len(self.lru)

    def available(self) -> int:
        return len(self.free) + len(self.lru)

    # -- alloc / release ----------------------------------------------------
    def alloc(self, n: int) -> Optional[list[int]]:
        """Take ``n`` pages (refcount 1 each), reclaiming retained
        prefix pages oldest-first if the free list runs dry.  Returns
        None — allocating NOTHING — when fewer than ``n`` are available:
        admission backpressure is all-or-nothing per request."""
        if n > self.available():
            return None
        out = []
        for _ in range(n):
            if self.free:
                pid = self.free.pop()
            else:
                pid, _ = self.lru.popitem(last=False)
                self._unregister(pid)
            self.refcount[pid] = 1
            out.append(pid)
        self.highwater = max(self.highwater, self.pages_in_use())
        return out

    def incref(self, pids) -> None:
        for pid in pids:
            if self.refcount[pid] == 0:
                # a retained registry page comes back to life
                self.lru.pop(pid, None)
            self.refcount[pid] += 1
        self.highwater = max(self.highwater, self.pages_in_use())

    def release(self, pids) -> list[int]:
        """Drop one reference per page; returns the page ids whose bytes
        must be ZEROED (refcount hit zero and the page is not prefix-
        registered — registered pages are retained in the LRU with their
        bytes intact)."""
        zero = []
        for pid in pids:
            self.refcount[pid] -= 1
            if self.refcount[pid] > 0:
                continue
            if pid in self.page_key:
                self.lru[pid] = None
                self.lru.move_to_end(pid)
            else:
                self.free.append(pid)
                zero.append(pid)
        return zero

    # -- prefix registry ----------------------------------------------------
    def _unregister(self, pid: int) -> None:
        d = self.page_key.pop(pid, None)
        if d is not None:
            self.registry.pop(d, None)

    def register(self, digests, pids) -> None:
        """Record ``pids[j]`` as holding the page whose chain digest is
        ``digests[j]``.  First writer wins — re-registering a digest that
        already resolves elsewhere is a no-op (the resident page keeps
        serving hits)."""
        for d, pid in zip(digests, pids):
            if d in self.registry or pid in self.page_key:
                continue
            self.registry[d] = pid
            self.page_key[pid] = d

    def flush_registry(self) -> list[int]:
        """Drop the entire prefix registry — the arena-fault degradation
        path: once a poisoned slot may have flowed NaNs through shared
        pages, no resident prefix can be trusted for reuse.

        Zero-ref retained pages return to the free list; their ids are
        returned so the engine can zero their bytes in the next eviction
        scatter.  Pages still referenced by live slots are merely
        unregistered: their current holders keep decoding, and when the
        last reference drops, ``release`` now zeroes and frees them like
        any private page.
        """
        zero = list(self.lru.keys())
        for pid in zero:
            self.free.append(pid)
        self.lru.clear()
        self.registry.clear()
        self.page_key.clear()
        return zero

    def lookup(self, digests) -> Optional[list[int]]:
        """Resolve a FULL chain of share digests to resident pages.
        Partial chains are misses: the tail-prefill contract needs every
        shared position's KV bytes resident."""
        out = []
        for d in digests:
            pid = self.registry.get(d)
            if pid is None:
                return None
            out.append(pid)
        return out
