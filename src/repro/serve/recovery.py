"""Crash-safe request journal + engine snapshot/restore.

The engine's slot-state protocol already makes every sequence a pure
function of (params, prompt, committed tokens, per-request PRNG chain) —
greedy decode is deterministic, and a sampled request's chain position
always equals its generated-token count.  So fault tolerance does not
need device-state checkpoints at all: journal WHAT was committed, and a
restarted engine re-derives the rest by prefilling ``prompt ‖ committed``
through the ordinary admission path.

Journal format
--------------
Append-only JSONL, one record per line:

    {"t": "submit", "uid", "prompt": [...], "max_new_tokens",
     "eos_id", "n_committed", "deadline"}
    {"t": "tok", "uid", "toks": [...]}     # committed-token delta
    {"t": "fin", "uid", "outcome"}         # finished/expired/quarantined/…
    {"t": "rej", "uid", "why"}             # submit() refused it

Buffered records are flushed ONLY at block-readback granularity — the
points where the engine already pays a host sync — so journaling adds
zero syncs to the hot loop.  The reader tolerates a torn tail (a crash
mid-write leaves at most one unparseable last line) and applies
last-submit-wins per uid: a resumed request re-submits with its
committed run folded into ``prompt`` and counted by ``n_committed``, so
one journal file survives any number of crash/restart cycles.

Token-exactness caveat: per-TOKEN chains make greedy and sampled macro
decode resume token-exactly.  Speculative SAMPLED decode advances one
chain split per speculative block (not per token), so its resume is
distribution-preserving but not replay-exact; greedy speculative decode
never consumes chain splits and stays token-exact.

Snapshot/restore
----------------
``snapshot_engine`` persists the weights (target + draft) through
``checkpoint/manager.py``'s atomic CRC-checked format, with the full
engine geometry in the manifest's ``extra``; ``restore_engine`` rebuilds
an equivalent engine from the snapshot alone.  Weights change rarely
(hot-swap growth events), the journal changes every block — separating
the two keeps the per-block fault-tolerance cost at one buffered write.
"""
from __future__ import annotations

import dataclasses
import json
import os
from typing import Dict, List, Optional, Tuple

import jax
import numpy as np

from repro.checkpoint.manager import (
    CheckpointShapeError,
    latest_step,
    load_checkpoint,
    save_checkpoint,
)
from repro.serve.engine import ContinuousBatchingEngine, Request
from repro.serve.sampling import SamplingParams
from repro.serve.speculative import SpeculativeConfig


class RequestJournal:
    """Append-only journal of request lifecycle events.

    Writes are buffered in memory; ``flush()`` is called by the engine
    only where it already blocks on a device readback, so the journal
    never adds a host sync.  ``fsync=True`` additionally fsyncs every
    flush (true crash safety at ~ms cost per block; the default relies
    on OS page-cache survival, which covers process kills).
    """

    def __init__(self, path: str, *, fsync: bool = False):
        self.path = path
        self.fsync = fsync
        d = os.path.dirname(os.path.abspath(path))
        os.makedirs(d, exist_ok=True)
        self._f = open(path, "a", encoding="utf-8")
        self._buf: List[str] = []

    # ------------------------------------------------------------- records
    def record_submit(self, req: Request) -> None:
        self._buf.append(json.dumps({
            "t": "submit", "uid": int(req.uid),
            "prompt": [int(x) for x in req.prompt],
            "max_new_tokens": int(req.max_new_tokens),
            "eos_id": None if req.eos_id is None else int(req.eos_id),
            "n_committed": int(getattr(req, "n_committed", 0)),
            "deadline": getattr(req, "deadline", None),
        }))

    def record_tokens(self, uid: int, toks) -> None:
        if len(toks):
            self._buf.append(json.dumps(
                {"t": "tok", "uid": int(uid),
                 "toks": [int(t) for t in toks]}))

    def record_finish(self, uid: int, outcome: str) -> None:
        self._buf.append(json.dumps(
            {"t": "fin", "uid": int(uid), "outcome": outcome}))

    def record_reject(self, uid: int, why: str) -> None:
        self._buf.append(json.dumps(
            {"t": "rej", "uid": int(uid), "why": why}))

    # ------------------------------------------------------------- plumbing
    def flush(self) -> None:
        if not self._buf:
            return
        self._f.write("\n".join(self._buf) + "\n")
        self._buf.clear()
        self._f.flush()
        if self.fsync:
            os.fsync(self._f.fileno())

    def close(self) -> None:
        self.flush()
        self._f.close()


@dataclasses.dataclass
class JournalState:
    """Reconstructed view of a journal file."""
    submits: Dict[int, dict]          # uid -> latest submit record
    committed: Dict[int, List[int]]   # uid -> all committed tokens so far
    finished: Dict[int, str]          # uid -> outcome (terminal records)
    order: List[int]                  # uids in (first-)submission order


def read_journal(path: str) -> JournalState:
    """Replay a journal.  Torn tails (a crash mid-append) stop the replay
    at the last complete record instead of failing; a ``submit`` record
    RESETS the uid's committed run to the record's own ``n_committed``
    suffix (last-submit-wins — the resumed submit already folds every
    earlier run's tokens into its prompt)."""
    st = JournalState({}, {}, {}, [])
    if not os.path.exists(path):
        return st
    with open(path, "r", encoding="utf-8") as f:
        for line in f:
            line = line.strip()
            if not line:
                continue
            try:
                rec = json.loads(line)
            except json.JSONDecodeError:
                break  # torn tail: everything after it never committed
            uid = rec.get("uid")
            t = rec.get("t")
            if t == "submit":
                if uid not in st.submits:
                    st.order.append(uid)
                st.submits[uid] = rec
                nc = int(rec.get("n_committed", 0))
                st.committed[uid] = list(
                    rec["prompt"][len(rec["prompt"]) - nc:]) if nc else []
                st.finished.pop(uid, None)
            elif t == "tok":
                st.committed.setdefault(uid, []).extend(rec["toks"])
            elif t == "fin":
                st.finished[uid] = rec["outcome"]
            elif t == "rej":
                st.finished[uid] = "rejected"
    return st


def recovery_requests(st: JournalState
                      ) -> Tuple[List[Request], Dict[int, np.ndarray]]:
    """Turn a journal replay into (requests to re-admit, outputs already
    complete).

    A mid-flight uid becomes a resume Request: prompt = original prompt
    ‖ committed tokens, ``n_committed`` marking the committed suffix —
    the engine's ordinary prefill then reproduces the next token
    exactly.  A uid whose committed run already satisfies its budget or
    fired eos needs no slot at all and is returned as finished output
    (its fin record died with the crash, the tokens did not).
    """
    resume: List[Request] = []
    done: Dict[int, np.ndarray] = {}
    for uid in st.order:
        if uid in st.finished:
            if st.finished[uid] == "finished" and st.committed.get(uid):
                done[uid] = np.asarray(st.committed[uid], np.int32)
            continue
        rec = st.submits[uid]
        toks = st.committed.get(uid, [])
        budget = int(rec["max_new_tokens"])
        eos = rec.get("eos_id")
        fired = next((i for i, t in enumerate(toks) if t == eos),
                     None) if eos is not None else None
        if fired is not None:
            done[uid] = np.asarray(toks[:fired + 1], np.int32)
            continue
        if len(toks) >= budget:
            done[uid] = np.asarray(toks[:budget], np.int32)
            continue
        nc0 = int(rec.get("n_committed", 0))
        orig = rec["prompt"][:len(rec["prompt"]) - nc0] if nc0 \
            else rec["prompt"]
        resume.append(Request(
            uid=uid,
            prompt=np.asarray(list(orig) + toks, np.int32),
            max_new_tokens=budget,
            eos_id=eos,
            deadline=rec.get("deadline"),
            n_committed=len(toks)))
    return resume, done


# ------------------------------------------------------------ snapshot/restore
def snapshot_engine(engine: ContinuousBatchingEngine, ckpt_dir: str,
                    step: int = 0) -> str:
    """Persist everything needed to rebuild an equivalent engine: the
    weights (target, plus draft in speculative mode) and the engine
    geometry.  Uses the atomic CRC-checked checkpoint format, so a crash
    mid-snapshot can never corrupt the previous snapshot."""
    tree = {"params": engine.params}
    if engine.speculative is not None:
        tree["draft"] = engine.speculative.params
    sp = engine.sampling
    extra = {
        "kind": "serve_engine",
        "arch": engine.cfg.name,
        "decode_kernel": engine.decode_kernel,
        "capacity": engine.capacity,
        "max_len": engine.max_len,
        "prefill_bucket": engine.prefill_bucket,
        "k": engine.k,
        "policy": engine.policy,
        "pool": "paged" if engine._metas[0] is not None else "dense",
        "pages": engine.pages_arg,
        "mesh_shape": engine.mesh_shape,
        "sampling": None if sp is None else dataclasses.asdict(sp),
        "draft_arch": (None if engine.speculative is None
                       else engine.speculative.cfg.name),
        "spec_d": (None if engine.speculative is None
                   else engine.speculative.d),
        "deadline": engine.deadline,
    }
    return save_checkpoint(ckpt_dir, step, tree, extra)


def restore_engine(ckpt_dir: str, step: Optional[int] = None,
                   arch: Optional[str] = None,
                   draft_arch: Optional[str] = None,
                   **overrides) -> ContinuousBatchingEngine:
    """Rebuild an engine from :func:`snapshot_engine` output.  Keyword
    overrides (``journal=…``, ``faults=…``, ``deadline=…``) pass through
    to the constructor — a restart typically reattaches the journal the
    dead engine was writing.

    ``arch`` / ``draft_arch`` override the snapshot's recorded
    architectures (restoring into an engine whose geometry changed — a
    hot-swap happened after the snapshot).  A mismatch between the
    requested geometry and the weights on disk fails with a named
    :class:`repro.checkpoint.manager.CheckpointShapeError` identifying
    the offending group and leaf — never an XLA shape crash mid-serve."""
    from repro.configs.base import get_config
    from repro.models import get_family

    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no engine snapshot in {ckpt_dir}")
    d = os.path.join(ckpt_dir, f"step_{step:010d}")
    with open(os.path.join(d, "manifest.json")) as f:
        extra = json.load(f)["extra"]
    if extra.get("kind") != "serve_engine":
        raise ValueError(f"{d} is not an engine snapshot")
    arch_name = arch or extra["arch"]
    cfg = get_config(arch_name).replace(
        decode_kernel=extra["decode_kernel"])
    template = {"params": jax.eval_shape(
        lambda: get_family(cfg).init(jax.random.PRNGKey(0), cfg))}
    cfg_d = None
    d_arch = draft_arch if draft_arch is not None else \
        extra.get("draft_arch")
    if d_arch:
        cfg_d = get_config(d_arch).replace(
            decode_kernel=extra["decode_kernel"])
        template["draft"] = jax.eval_shape(
            lambda: get_family(cfg_d).init(jax.random.PRNGKey(0), cfg_d))
    try:
        tree, _, _ = load_checkpoint(ckpt_dir, template, step)
    except CheckpointShapeError as e:
        group = (e.leaf or "?").split(".", 1)[0]
        want = arch_name if group == "params" else d_arch
        have = extra["arch"] if group == "params" \
            else extra.get("draft_arch")
        raise CheckpointShapeError(
            f"engine snapshot step {step} in {ckpt_dir} holds "
            f"{have!r} weights in group {group!r} but the restore "
            f"requests {want!r} — a pre-growth snapshot cannot restore "
            f"into a post-growth engine (snapshot again after the swap, "
            f"or pass the matching arch=): {e}", leaf=e.leaf) from e
    sampling = None
    if extra.get("sampling"):
        sampling = SamplingParams(**extra["sampling"])
    speculative = None
    if cfg_d is not None:
        speculative = SpeculativeConfig(cfg_d, tree["draft"],
                                        d=int(extra["spec_d"]))
    kw = dict(capacity=extra["capacity"], max_len=extra["max_len"],
              prefill_bucket=extra["prefill_bucket"], k=extra["k"],
              policy=extra["policy"], pool=extra["pool"],
              pages=extra.get("pages"), sampling=sampling,
              speculative=speculative, deadline=extra.get("deadline"))
    # Elastic restart is a placement-only problem: the snapshot carries no
    # device state, so the saved mesh shape is a *preference*, not a
    # requirement.  Reuse it only when it still fits the visible device
    # count; otherwise restore single-device (pass ``mesh=…`` explicitly
    # to re-shard onto a different layout).
    saved_mesh = extra.get("mesh_shape")
    if saved_mesh and saved_mesh != "1x1":
        from repro.distributed.serve_sharding import parse_mesh_arg
        shape = parse_mesh_arg(saved_mesh)
        if shape[0] * shape[1] == len(jax.devices()):
            kw["mesh"] = shape
    kw.update(overrides)
    return ContinuousBatchingEngine(cfg, tree["params"], **kw)
