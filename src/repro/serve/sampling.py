"""Sampling for the serving macro loop and speculative rejection sampling.

Everything here is shape-polymorphic over the slot axis and runs inside
the engine's jitted loops:

  * ``SamplingParams`` — temperature / top-k / top-p / seed.  The frozen
    dataclass is hashable, so it keys the engine's jit caches directly;
    ``temperature == 0`` is greedy (argmax) and uses no randomness.
  * per-slot PRNG chains — every request gets an independent key
    (``request_key(seed, uid)``) scattered into the slot pool at
    admission; ``next_keys`` advances all chains in lockstep but the
    engine only keeps the advanced key for LIVE rows, so a request's
    chain depends solely on its own generated-token count.  That makes
    sampled decode reproducible per request: the same (seed, uid, prompt)
    yields the same tokens no matter how requests interleave, and a
    sequential single-request replay using the same helpers is
    token-exact against the engine (``tests/test_sampling.py``).
  * ``filtered_probs`` — temperature -> top-k -> top-p, renormalized.
  * speculative rejection sampling (``residual_probs``) — the leftover
    distribution ``max(p - q, 0)`` a rejected draft token is resampled
    from; with draft == target it degenerates so acceptance is certain.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

NEG_INF = -1e30


@dataclasses.dataclass(frozen=True)
class SamplingParams:
    """Decode-time sampling policy.  ``temperature == 0`` means greedy."""
    temperature: float = 0.0
    top_k: int = 0  # 0: no top-k cut
    top_p: float = 1.0  # 1.0: no nucleus cut
    seed: int = 0

    def __post_init__(self):
        if self.temperature < 0:
            raise ValueError(f"temperature must be >= 0 "
                             f"(got {self.temperature})")
        if self.top_k < 0:
            raise ValueError(f"top_k must be >= 0 (got {self.top_k})")
        if not 0.0 < self.top_p <= 1.0:
            raise ValueError(f"top_p must be in (0, 1] (got {self.top_p})")

    @property
    def greedy(self) -> bool:
        return self.temperature <= 0.0


def is_greedy(sp) -> bool:
    return sp is None or sp.greedy


def request_key(seed: int, uid: int):
    """Root of a request's sampling chain — a pure function of (engine
    seed, request uid), independent of admission timing or slot index."""
    return jax.random.fold_in(jax.random.PRNGKey(seed), uid)


def next_keys(keys):
    """Advance a (B, 2) batch of per-slot chains one step.

    Returns (carry_keys, sample_keys): the carry continues each chain,
    the sample key is consumed by this step's draw.  The caller keeps the
    carry only for rows that really sampled (live rows), so a chain's
    position always equals the row's generated-token count.
    """
    split = jax.vmap(lambda k: jax.random.split(k))(keys)
    return split[:, 0], split[:, 1]


def filtered_probs(logits, sp: SamplingParams):
    """(B, V) sampling distribution: temperature -> top-k -> top-p.

    Filtering masks to ``NEG_INF`` and renormalizes, so downstream
    consumers (categorical draw, speculative accept ratios, residual
    distributions) all see the same support.
    """
    lg = logits.astype(jnp.float32) / jnp.float32(max(sp.temperature, 1e-6))
    V = lg.shape[-1]
    if sp.top_k and sp.top_k < V:
        kth = jax.lax.top_k(lg, sp.top_k)[0][..., -1:]
        lg = jnp.where(lg >= kth, lg, NEG_INF)
    if sp.top_p < 1.0:
        probs = jax.nn.softmax(lg, axis=-1)
        order = jnp.argsort(-lg, axis=-1)
        sorted_probs = jnp.take_along_axis(probs, order, axis=-1)
        exclusive = jnp.cumsum(sorted_probs, axis=-1) - sorted_probs
        keep_sorted = exclusive < sp.top_p  # always keeps the top token
        rows = jnp.arange(lg.shape[0])[:, None]
        keep = jnp.zeros(lg.shape, bool).at[rows, order].set(keep_sorted)
        lg = jnp.where(keep, lg, NEG_INF)
    return jax.nn.softmax(lg, axis=-1)


def sample_probs(probs, sample_keys):
    """Categorical draw per row. probs: (B, V); sample_keys: (B, 2)."""
    logp = jnp.log(jnp.maximum(probs, 1e-38))
    logp = jnp.where(probs > 0, logp, NEG_INF)
    return jax.vmap(jax.random.categorical)(sample_keys, logp) \
        .astype(jnp.int32)


def sample_logits(logits, sample_keys, sp: SamplingParams):
    """One sampled token per row under ``sp`` (greedy falls back to
    argmax, consuming no randomness)."""
    if is_greedy(sp):
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    return sample_probs(filtered_probs(logits, sp), sample_keys)


def residual_probs(p, q):
    """Leftover distribution for speculative rejection sampling.

    A draft token ``x ~ q`` is accepted with probability
    ``min(1, p(x) / q(x))``; on rejection the replacement is drawn from
    ``normalize(max(p - q, 0))`` — the classic construction whose mixture
    is exactly ``p``.  Degenerate rows (``p <= q`` everywhere, possible
    only up to float error when p == q) fall back to ``p``.
    """
    r = jnp.maximum(p - q, 0.0)
    s = jnp.sum(r, axis=-1, keepdims=True)
    return jnp.where(s > 0, r / jnp.maximum(s, 1e-38), p)
