"""Speculative decoding for the continuous-batching engine.

The paper grows every target weight as a (multi-)linear function of the
pretrained source weights — which makes the small source model an
unusually well-matched *draft* for speculative decoding of its grown
target.  This module exploits that pair at serve time:

  * the DRAFT (source config, or the target's seed checkpoint before
    growth) proposes ``d`` tokens per slot by running its own slot-decode
    recurrence on a scratch continuation of the draft pool;
  * the TARGET verifies all ``d`` (+ the carried token) in ONE batched
    chunk forward — the family's ``verify_step_slots`` hook — yielding
    its next-token choice after every chunk prefix;
  * the longest accepted prefix is committed per slot through
    ``commit_slots``: KV layouts scatter only the accepted positions
    (rollback = "never wrote it"), recurrent layouts gather the stacked
    per-step state at the accepted boundary (``freeze_rows``-style
    snapshot/restore).  Paged pools commit the same way through block
    tables — ring layouts via the paged ``spec_ring_restore`` twin
    (``models/common.py``), so griffin + speculative serves paged, and a
    draft/target pair draws from ONE shared page arena (per-engine
    refcount namespaces; see ``serve/paged.py``);
  * per-slot eos / budget stopping is folded into the acceptance mask, so
    a slot that finishes mid-chunk freezes exactly there — the same
    contract as the macro decode loop.

``make_speculative_loop(cfg_t, cfg_d, d, k)`` wraps ``k`` whole
draft→verify→commit blocks under one ``lax.scan``, so a dispatch emits up
to ``k * (d + 1)`` tokens per slot with a single host sync — PR 2's
macro-step structure, now emitting several tokens per target step.

Greedy speculative decode is token-exact versus non-speculative
``generate()``: every emitted token IS the target's argmax after its
committed prefix — acceptance only decides how many of them one block
emits.  With sampling, draft proposals go through classic rejection
sampling (accept ``x ~ q`` with prob ``min(1, p(x)/q(x))``, resample
rejections from ``normalize(max(p - q, 0))``), which preserves the
target's sampling distribution; with draft == target it accepts
everything.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.models import get_family, spec_decode_supported
from repro.serve import sampling as sampling_lib


@dataclasses.dataclass
class SpeculativeConfig:
    """Draft-side configuration for a speculative engine.

    ``cfg``/``params`` are the draft model (typically the pretrained
    source the target was grown from); ``d`` is the speculation depth:
    draft proposals per block, so a block commits between 1 and ``d + 1``
    tokens per live slot.
    """
    cfg: Any
    params: Any
    d: int = 4


def spec_pair_supported(cfg_target, cfg_draft, d: int = 4,
                        max_len: Optional[int] = None):
    """Capability probe for a speculative (target, draft) PAIR.

    Returns (ok, detail).  ``detail`` reports per-mode servability for
    BOTH models — a pair is speculatively servable only when each side
    passes its own slot-decode probe, implements the chunk-verify hooks,
    and the two share a vocabulary; ring-buffer layouts additionally need
    the ``d + 1``-token verify chunk to fit their ring.
    """
    if d < 1:
        return False, f"speculation depth d must be >= 1 (got {d})"
    ok_t, det_t = spec_decode_supported(cfg_target)
    ok_d, det_d = spec_decode_supported(cfg_draft)
    per_mode = (f"target {cfg_target.name!r}: "
                f"{'ok — ' if ok_t else 'NOT SERVABLE — '}{det_t}; "
                f"draft {cfg_draft.name!r}: "
                f"{'ok — ' if ok_d else 'NOT SERVABLE — '}{det_d}")
    if not (ok_t and ok_d):
        return False, per_mode
    if cfg_target.vocab_size != cfg_draft.vocab_size:
        return False, (f"draft/target vocabularies differ "
                       f"({cfg_draft.vocab_size} vs "
                       f"{cfg_target.vocab_size}) — draft proposals would "
                       "not index the target distribution")
    for role, cfg in (("target", cfg_target), ("draft", cfg_draft)):
        ring = min(cfg.window, max_len) if (cfg.window and max_len) \
            else cfg.window
        if ring and d + 1 > ring:
            return False, (f"{role} {cfg.name!r}: verify chunk d+1={d + 1} "
                           f"overruns its ring-buffer window ({ring}) — "
                           "a chunk position would wrap onto a committed "
                           "slot")
    return True, per_mode


def make_draft_prefill(cfg_d):
    """Admission prefill for the DRAFT pool: same bucket-padded prompt
    batch as the target's admission, logits discarded — only the per-row
    prompt state matters (the first generated token is the target's)."""
    fam = get_family(cfg_d)

    def prefill_fn(params_d, tokens, plens, cache):
        _, cache = fam.prefill_full(
            params_d, {"tokens": tokens, "plens": plens}, cfg_d, cache)
        return cache

    return prefill_fn


def make_speculative_loop(cfg_t, cfg_d, d: int, k: int, sampling=None):
    """K speculative blocks under one ``lax.scan`` — the engine's
    macro-step for speculative mode.

    fn(params_t, params_d, tokens (B,), positions (B,), remaining (B,),
       eos_ids (B,), done (B,), pool_t, pool_d, keys (B,2)) ->
        (block (K*(d+1), B) int32, valid (K*(d+1), B) bool,
         poison (B,) bool, draft_bad () bool,
         tokens, positions, remaining, done, pool_t, pool_d, keys,
         n_proposed (), n_accepted ())

    Block semantics mirror ``make_slot_decode_loop``: ``valid[i, b]``
    marks really-committed tokens, rows emit eos as valid then go quiet,
    finished rows are exact no-ops.  ``n_proposed`` / ``n_accepted``
    count draft tokens offered/accepted across the whole dispatch — the
    acceptance-rate telemetry rides the block readback, costing no extra
    host sync.

    NaN/Inf sentinels ride the same readback.  ``poison[b]`` flags a row
    whose TARGET verify logits came back non-finite (the block commits
    nothing for the row — ``n_feed`` forces 0, so both pools stay at the
    row's pre-block state — and the row freezes via the done-mask; the
    engine quarantine-evicts it).  With sampling, a row whose DRAFT
    logits were non-finite is poisoned too: its rejection-sampling draw
    would no longer follow the target distribution.  Under greedy decode
    a broken draft cannot corrupt output (every emitted token is the
    target's own argmax — bad proposals are merely rejected), so greedy
    rows survive a draft fault; either way the scalar ``draft_bad``
    reports any non-finite draft logits across the dispatch, and the
    engine uses it to drop to plain macro decode (degradation ladder).
    """
    fam_t, fam_d = get_family(cfg_t), get_family(cfg_d)
    greedy = sampling_lib.is_greedy(sampling)
    S = d + 1

    def one_block(tokens, positions, remaining, eos_ids, done, pool_t,
                  pool_d, keys, params_t, params_d):
        B = tokens.shape[0]
        live0 = ~done
        # effective proposals: drafts the budget could even use — a row
        # owing R more tokens can accept at most min(d, R - 1) drafts
        # (the block's last output is always the target's own token), so
        # budget clipping must not read as draft rejection in the
        # acceptance telemetry
        n_prop_rows = jnp.where(live0,
                                jnp.minimum(d, jnp.maximum(remaining - 1,
                                                           0)), 0)

        if greedy:
            def draft_body(carry, j):
                tok, cache, dbad = carry
                logits, cache = fam_d.decode_step_slots(
                    params_d, tok, positions + j, cache, cfg_d, done=done)
                dbad = dbad | (~done & ~jnp.all(
                    jnp.isfinite(logits.astype(jnp.float32)), -1))
                nxt = jnp.where(done, tok,
                                jnp.argmax(logits, -1).astype(jnp.int32))
                return (nxt, cache, dbad), nxt

            # the scratch draft continuation: proposals advance a copy of
            # the draft pool; the real pool only moves at commit time
            (_, _, dbad), drafts = jax.lax.scan(
                draft_body, (tokens, pool_d, jnp.zeros((B,), bool)),
                jnp.arange(d))
            chunk = jnp.concatenate([tokens[None], drafts], 0).T  # (B, S)
            logits_t, pend_t = fam_t.verify_step_slots(
                params_t, chunk, positions, pool_t, cfg_t, done=done)
            tbad = ~jnp.all(jnp.isfinite(logits_t.astype(jnp.float32)),
                            axis=(1, 2))
            # greedy: a broken draft only wastes proposals, it cannot
            # change the emitted tokens — poison on target faults alone
            bad = live0 & tbad
            out_tokens = jnp.argmax(logits_t, -1).astype(jnp.int32)
            # greedy acceptance: proposal j survives iff it IS the
            # target's argmax after the (already accepted) prefix — so
            # every emitted token is the target's own token and
            # acceptance only sets how many are emitted per block
            match = chunk[:, 1:] == out_tokens[:, :-1]
        else:
            keys_new, kblock = sampling_lib.next_keys(keys)
            keys = jnp.where(live0[:, None], keys_new, keys)

            def subkey(c):
                return jax.vmap(lambda kk: jax.random.fold_in(kk, c))(kblock)

            def draft_body(carry, j):
                tok, cache, dbad = carry
                logits, cache = fam_d.decode_step_slots(
                    params_d, tok, positions + j, cache, cfg_d, done=done)
                dbad = dbad | (~done & ~jnp.all(
                    jnp.isfinite(logits.astype(jnp.float32)), -1))
                qj = sampling_lib.filtered_probs(logits, sampling)
                kj = jax.vmap(jax.random.fold_in)(kblock, jnp.full((B,), j))
                nxt = jnp.where(done, tok,
                                sampling_lib.sample_probs(qj, kj))
                return (nxt, cache, dbad), (nxt, qj)

            (_, _, dbad), (drafts, qs) = jax.lax.scan(
                draft_body, (tokens, pool_d, jnp.zeros((B,), bool)),
                jnp.arange(d))
            chunk = jnp.concatenate([tokens[None], drafts], 0).T
            logits_t, pend_t = fam_t.verify_step_slots(
                params_t, chunk, positions, pool_t, cfg_t, done=done)
            tbad = ~jnp.all(jnp.isfinite(logits_t.astype(jnp.float32)),
                            axis=(1, 2))
            # sampled: a non-finite draft distribution breaks rejection
            # sampling's target-distribution guarantee — poison the row
            bad = live0 & (tbad | dbad)
            V = logits_t.shape[-1]
            p = sampling_lib.filtered_probs(
                logits_t.reshape(B * S, V), sampling).reshape(B, S, V)
            qs = jnp.swapaxes(qs, 0, 1)  # (B, d, V)
            x = chunk[:, 1:]  # (B, d) draft proposals
            p_x = jnp.take_along_axis(p[:, :-1], x[..., None], -1)[..., 0]
            q_x = jnp.take_along_axis(qs, x[..., None], -1)[..., 0]
            u = jax.vmap(lambda kk: jax.random.uniform(kk, (d,)))(subkey(d))
            match = u < jnp.minimum(1.0, p_x / jnp.maximum(q_x, 1e-38))
            # replacements: residual distribution at each rejection
            # point; the all-accepted bonus draws from the target's own
            # next distribution
            repl_dists = jnp.concatenate(
                [sampling_lib.residual_probs(p[:, :-1], qs), p[:, -1:]], 1)
            logp = jnp.where(repl_dists > 0,
                             jnp.log(jnp.maximum(repl_dists, 1e-38)),
                             sampling_lib.NEG_INF)
            repl = jax.vmap(
                lambda kk, lp: jax.random.categorical(kk, lp, axis=-1))(
                    subkey(d + 1), logp).astype(jnp.int32)  # (B, S)
            acc_tok = jnp.concatenate(
                [x, jnp.zeros((B, 1), jnp.int32)], 1)
            acc_mask = jnp.concatenate(
                [match, jnp.zeros((B, 1), bool)], 1)
            out_tokens = jnp.where(acc_mask, acc_tok, repl)

        # ---- acceptance chain + per-slot stopping (shared) -----------
        # output j (1-based) is committed iff the row is live, proposals
        # 1..j-1 were all accepted, the budget still owes >= j tokens,
        # and no earlier output in this block was the row's eos
        acc_ok = jnp.concatenate(
            [jnp.ones((B, 1), bool), jnp.cumsum(~match, 1) == 0], 1)
        steps = jnp.arange(1, S + 1, dtype=remaining.dtype)
        budget_ok = steps[None] <= remaining[:, None]
        is_eos = out_tokens == eos_ids[:, None]
        no_eos_before = (jnp.cumsum(is_eos, 1) - is_eos) == 0
        # a poisoned row commits NOTHING this block (n_out = 0, so its
        # state and both pools stay at the pre-block snapshot) and
        # freezes via the done-mask — the engine quarantine-evicts it
        alive = live0 & ~bad
        valid = alive[:, None] & acc_ok & budget_ok & no_eos_before
        n_out = valid.sum(1).astype(jnp.int32)
        last_idx = jnp.maximum(n_out - 1, 0)
        last_tok = jnp.take_along_axis(out_tokens, last_idx[:, None],
                                       1)[:, 0]
        tokens = jnp.where(alive, last_tok, tokens)
        remaining = jnp.where(alive, remaining - n_out, remaining)
        fired_eos = jnp.take_along_axis(is_eos, last_idx[:, None], 1)[:, 0]
        done_next = done | bad | (alive & (fired_eos | (remaining <= 0)))
        # ---- commit the accepted prefix into BOTH pools --------------
        # feeds are chunk indices < n_out: the carried token plus the
        # accepted proposals; the last output is never fed (it is the
        # next block's carried token, or the row just finished)
        n_feed = jnp.where(done | bad, 0, n_out)
        pool_t = fam_t.commit_slots(params_t, chunk, positions, n_feed,
                                    pool_t, pend_t, cfg_t, done=done)
        # draft catch-up: the draft consumes the same committed chunk
        # through its own verify/commit hooks (its scratch proposals were
        # discarded), so both pools agree on every committed position —
        # including the bonus-position feed the propose scan never ran
        _, pend_d = fam_d.verify_step_slots(params_d, chunk, positions,
                                            pool_d, cfg_d, done=done)
        pool_d = fam_d.commit_slots(params_d, chunk, positions, n_feed,
                                    pool_d, pend_d, cfg_d, done=done)
        positions = positions + n_out
        n_prop = jnp.sum(n_prop_rows)
        n_acc = jnp.sum(jnp.maximum(n_out - 1, 0))
        return (tokens, positions, remaining, done_next, pool_t, pool_d,
                keys), (out_tokens.T, valid.T, bad, dbad.any(), n_prop,
                        n_acc)

    def loop_fn(params_t, params_d, tokens, positions, remaining, eos_ids,
                done, pool_t, pool_d, keys):
        def body(carry, _):
            (tokens, positions, remaining, done, pool_t, pool_d,
             keys) = carry
            return one_block(tokens, positions, remaining, eos_ids, done,
                             pool_t, pool_d, keys, params_t, params_d)

        carry, (blocks, valids, bads, dbads, props, accs) = jax.lax.scan(
            body, (tokens, positions, remaining, done, pool_t, pool_d,
                   keys), None, length=k)
        tokens, positions, remaining, done, pool_t, pool_d, keys = carry
        B = tokens.shape[0]
        block = blocks.reshape(k * S, B)
        valid = valids.reshape(k * S, B)
        return (block, valid, bads.any(0), dbads.any(), tokens, positions,
                remaining, done, pool_t, pool_d, keys, props.sum(),
                accs.sum())

    return loop_fn
