"""Live-growth serving: hot-swap Mango-grown weights into a running
engine with zero dropped requests.

The paper's core property — multi-linear growth is (approximately)
function-preserving: the grown target computes the source's function at
swap time — turns a model upgrade into a *serving event* instead of a
redeploy.  :class:`UpgradeManager` drives it end to end:

    serving ──start()──▶ growing ──▶ ready ──poll()──▶ relayout ──▶ swapped
                            │                                         │
                            └────────────▶ failed (engine keeps serving
                                                   the source model)

* **growing** — ``core/grow.py: grow_from_source`` runs Mango (or any
  registered growth method) on the engine's CURRENT weights, optionally
  on a background thread while the engine keeps serving the source.
* **ready** — the grown fn set is pre-warmed: a scratch engine with the
  target geometry compiles every jitted function the swap will flip to
  (``_jitted_engine_fns`` is process-wide and keyed on frozen configs,
  so the live engine hits the warm cache).  The swap pause is then one
  quiesce, not a compile.
* **relayout → swapped** — at the next block-readback boundary whose
  lifetime dispatch count has reached ``upgrade_at``, the engine
  quiesces, converts every mid-flight sequence into a journal-style
  resume request (original prompt ‖ committed run), rebuilds pools /
  shardings / fns for the grown geometry, and re-admits the resumes
  through the ordinary admission path — token-exact continuations, zero
  drops (``engine._apply_upgrade``).
* **draft-after-swap** — the old source is, by construction, a
  distribution-matched draft for its own grown target; if the
  ``spec_pair_supported`` probe passes, the swap flips the engine into
  speculative mode with the source as draft, so the upgrade ends with
  spec serving enabled for free.

Everything that can fail is validated eagerly in ``__init__`` with a
named :class:`UpgradeError` — family mismatch, unservable target,
position range, vocabulary change, mesh divisibility — so a doomed
upgrade dies before a single growth FLOP, and never mid-swap.
"""
from __future__ import annotations

import threading
import time
from typing import List, Optional

import jax
import numpy as np

from repro.models import get_family, serve_supported
from repro.serve.engine import ContinuousBatchingEngine, Request
from repro.serve.speculative import SpeculativeConfig, spec_pair_supported

UPGRADE_STATES = ("serving", "growing", "ready", "relayout", "swapped",
                  "failed")


class UpgradeError(RuntimeError):
    """A live upgrade that cannot work, detected before it starts."""


def probe_token_agreement(cfg_src, params_src, cfg_tgt, params_tgt,
                          prompts, *, gen: int = 8) -> float:
    """Fraction of greedy tokens on which source and target agree over a
    probe batch — the measurable form of the paper's function-preservation
    claim (1.0 ⇔ the grown target continues every greedy sequence
    exactly where the source would)."""
    from repro.launch.serve import generate
    prompts = np.asarray(prompts, np.int32)
    a = np.asarray(generate(cfg_src, params_src, prompts,
                            max_new_tokens=gen))
    b = np.asarray(generate(cfg_tgt, params_tgt, prompts,
                            max_new_tokens=gen))
    return float((a == b).mean())


class UpgradeManager:
    """Grow ``engine.cfg`` into ``cfg_tgt`` and hot-swap it in.

    Parameters
    ----------
    engine : the live :class:`ContinuousBatchingEngine` (attaches as
        ``engine.upgrade``; the engine polls at block boundaries).
    cfg_tgt : target model config (same family; Mango maps within one).
    method / rank / grow_steps / data_iter : forwarded to
        ``core/grow.py: grow_from_source`` (``grow_steps > 0`` trains the
        operator on ``data_iter`` first — Eq. 7).
    grow_noise : operator-init noise scale.  Defaults to ``0.0`` — the
        untrained structured init then coincides with the Net2Net
        expansion, the most function-preserving init available (depth
        growth keeps it approximate; measure with
        :func:`probe_token_agreement`).  Pass ``None`` for the trainer's
        default (0.01) when growth is followed by operator training.
    grown_params : skip growth entirely and swap these in (precomputed
        growth, or a checkpoint-restored target).
    speculate_after : ``"auto"`` (default) enables draft-after-swap when
        the pair probe passes and records the reason when it does not;
        ``True`` makes a failed probe an :class:`UpgradeError`;
        ``False`` disables it.
    spec_d : speculation depth for the post-swap pair.
    upgrade_at : minimum LIFETIME decode dispatches before the swap may
        land — "mid-trace upgrade" in the scenario harness.
    prewarm : compile the grown fn set before the flip (recommended; off
        only for tests that want the cold-swap path).
    probe_fp : measure :func:`probe_token_agreement` on synthetic prompts
        after growth (recorded as ``fp_token_agreement``).
    """

    def __init__(self, engine: ContinuousBatchingEngine, cfg_tgt, *,
                 method: str = "mango", rank: int = 1,
                 grow_steps: int = 0, data_iter=None, grow_noise=0.0,
                 grown_params=None, speculate_after="auto",
                 spec_d: int = 4, upgrade_at: int = 0,
                 prewarm: bool = True, probe_fp: bool = False,
                 seed: int = 0):
        if engine.upgrade is not None and engine.upgrade.state not in (
                "swapped", "failed"):
            raise UpgradeError(
                "engine already has an upgrade in flight "
                f"(state {engine.upgrade.state!r})")
        cfg_src = engine.cfg
        # the target inherits the engine's decode-kernel switch so the
        # pre-warmed fn-set key matches what _configure will build
        cfg_tgt = cfg_tgt.replace(decode_kernel=engine.decode_kernel)
        if cfg_src.family != cfg_tgt.family:
            raise UpgradeError(
                f"growth operators map within one family: engine serves "
                f"{cfg_src.name!r} ({cfg_src.family}) but the target is "
                f"{cfg_tgt.name!r} ({cfg_tgt.family})")
        if cfg_src.vocab_size != cfg_tgt.vocab_size:
            raise UpgradeError(
                f"live upgrade needs an unchanged vocabulary (committed "
                f"tokens must stay valid): {cfg_src.vocab_size} -> "
                f"{cfg_tgt.vocab_size}")
        ok, why = serve_supported(cfg_tgt)
        if not ok:
            raise UpgradeError(
                f"target {cfg_tgt.name!r} is not servable: {why}")
        limit = cfg_tgt.max_seq_len
        if cfg_tgt.learned_pos:
            limit = min(limit, cfg_tgt.learned_pos)
        if engine.max_len > limit:
            raise UpgradeError(
                f"engine max_len {engine.max_len} exceeds target "
                f"{cfg_tgt.name!r} position range {limit}")
        if engine.mesh_plan is not None:
            from repro.distributed import serve_sharding
            try:
                serve_sharding.validate_serve_mesh(
                    engine.mesh_plan.shape, cfg_tgt, engine.capacity)
            except ValueError as e:
                raise UpgradeError(
                    f"target {cfg_tgt.name!r} does not fit the engine's "
                    f"{engine.mesh_shape} mesh: {e}") from e
        self._spec_enabled = False
        self.spec_reason: Optional[str] = None
        if speculate_after not in ("auto", True, False):
            raise UpgradeError(
                f"speculate_after must be 'auto', True or False "
                f"(got {speculate_after!r})")
        if speculate_after in ("auto", True):
            ok, why = spec_pair_supported(cfg_tgt, cfg_src, spec_d,
                                          engine.max_len)
            if ok:
                self._spec_enabled = True
            elif speculate_after is True:
                raise UpgradeError(
                    f"draft-after-swap pair {cfg_src.name!r} -> "
                    f"{cfg_tgt.name!r} is unsupported: {why}")
            else:
                self.spec_reason = why

        self.engine = engine
        self.cfg_src = cfg_src
        self.cfg_tgt = cfg_tgt
        # the draft is the source AS SERVED NOW: weights captured before
        # growth, so the post-swap draft is bit-identical to what every
        # mid-flight sequence was decoded with
        self.params_src = engine.params
        self.method = method
        self.rank = rank
        self.grow_steps = grow_steps
        self.data_iter = data_iter
        self.grow_noise = grow_noise
        self.grown_params = grown_params
        self.spec_d = spec_d
        self.upgrade_at = upgrade_at
        self.prewarm = prewarm
        self.probe_fp = probe_fp
        self.seed = seed

        self.state = "serving"
        self.history: List[tuple] = [("serving", time.monotonic())]
        self.error: Optional[BaseException] = None
        self.fp_token_agreement: Optional[float] = None
        self.grow_seconds: Optional[float] = None
        self.pause_ms: Optional[float] = None
        self.resumed: Optional[int] = None
        # page-residency delta of the swap (paged engines; zeros when
        # dense): pages_resident_at_swap were live at quiesce and are all
        # invalidated (cache bytes are activations of the pre-growth
        # function), pages_carried is therefore structurally 0, and
        # pages_reprefilled is the page bill the resume wave pays to
        # rebuild state under the grown model — the measurable cost of
        # the zero-drop guarantee.
        self.pages_resident_at_swap: Optional[int] = None
        self.pages_carried: Optional[int] = None
        self.pages_reprefilled: Optional[int] = None
        self.tokens_at_swap: Optional[int] = None
        self.t_swap: Optional[float] = None
        self._ready = threading.Event()
        self._thread: Optional[threading.Thread] = None
        engine.upgrade = self

    # ---------------------------------------------------------------- states
    def _set_state(self, state: str) -> None:
        assert state in UPGRADE_STATES, state
        self.state = state
        self.history.append((state, time.monotonic()))

    def spec_config(self) -> Optional[SpeculativeConfig]:
        """The post-swap draft pair (None when draft-after-swap is off)."""
        if not self._spec_enabled:
            return None
        return SpeculativeConfig(self.cfg_src, self.params_src,
                                 d=self.spec_d)

    def disable_spec(self, why: str) -> None:
        """Called by the swap when enabling the draft would violate the
        zero-drop guarantee (e.g. the draft's page need pushing a
        resume's shared-arena reservation past an explicit --pages)."""
        self._spec_enabled = False
        self.spec_reason = why

    # ----------------------------------------------------------------- growth
    def start(self, background: bool = True) -> "UpgradeManager":
        """Kick off growth.  ``background=True`` grows on a thread while
        the engine keeps serving the source (the production path);
        ``background=False`` blocks until ready (deterministic tests and
        pre-grown swaps).  A growth failure moves to ``failed`` and the
        engine simply keeps serving — a bad upgrade must never take down
        live traffic."""
        if self.state != "serving":
            raise UpgradeError(f"start() in state {self.state!r}")
        self._set_state("growing")
        if background:
            self._thread = threading.Thread(target=self._grow, daemon=True)
            self._thread.start()
        else:
            self._grow()
            if self.error is not None:
                raise self.error
        return self

    def _grow(self) -> None:
        t0 = time.monotonic()
        try:
            if self.grown_params is None:
                from repro.core.grow import grow_from_source
                data_iter = self.data_iter
                if self.grow_steps and data_iter is None:
                    from repro.data.synthetic import lm_data_iter
                    data_iter = lm_data_iter(self.cfg_tgt.vocab_size, 4, 32,
                                             seed=self.seed + 1)
                self.grown_params = grow_from_source(
                    self.cfg_src, self.cfg_tgt, method=self.method,
                    rank=self.rank, steps=self.grow_steps,
                    data_iter=data_iter, params_src=self.params_src,
                    rng=jax.random.PRNGKey(self.seed),
                    noise=self.grow_noise, log_fn=lambda *a, **k: None)
            self.grow_seconds = time.monotonic() - t0
            if self.probe_fp:
                rng = np.random.default_rng(self.seed)
                prompts = rng.integers(
                    0, self.cfg_tgt.vocab_size, size=(4, 8), dtype=np.int32)
                self.fp_token_agreement = probe_token_agreement(
                    self.cfg_src, self.params_src, self.cfg_tgt,
                    self.grown_params, prompts)
            if self.prewarm:
                self._prewarm()
            self._set_state("ready")
            self._ready.set()
        except BaseException as e:  # noqa: BLE001 — surfaced via .error
            self.error = e
            self._set_state("failed")
            self._ready.set()

    def _prewarm(self) -> None:
        """Compile the grown fn set BEFORE the flip.  A scratch engine
        with the exact post-swap geometry drives every jitted function
        through every (bucket × pow2-group) admission shape and the
        macro loop; ``_jitted_engine_fns`` is lru-cached on frozen
        configs + pool metas + mesh plan, so the live engine's post-swap
        calls hit this warm cache and the swap pause contains no
        compile."""
        eng = self.engine
        scratch = ContinuousBatchingEngine(
            self.cfg_tgt, self.grown_params, capacity=eng.capacity,
            max_len=eng.max_len, prefill_bucket=eng.prefill_bucket,
            k=eng.k, policy=eng.policy, pool=eng._pool_arg,
            pages=eng.pages_arg, sampling=eng.sampling,
            speculative=self.spec_config(), mesh=eng._mesh_arg)
        buckets = sorted({scratch._bucketed(n)
                          for n in range(1, eng.max_len - 1)})
        # group counts whose pow2 padding covers every admission-wave
        # size the swap's resume wave can produce (a wave of `capacity`
        # resumes pads to _pow2(capacity))
        counts = sorted({min(1 << i, eng.capacity)
                         for i in range(eng.capacity.bit_length() + 1)})
        uid = -1_000_000  # scratch uids can never collide with traffic
        for n in counts:
            for b in buckets:
                plen = max(1, min(b, eng.max_len - 2))
                reqs = [Request(uid=uid - i,
                                prompt=np.zeros((plen,), np.int32),
                                max_new_tokens=2) for i in range(n)]
                uid -= n
                scratch.run(reqs)

    # ------------------------------------------------------------------ swap
    def poll(self, engine: Optional[ContinuousBatchingEngine] = None
             ) -> bool:
        """Called by the engine at every block boundary.  Returns True
        when it performed the swap."""
        engine = engine or self.engine
        if self.state != "ready":
            return False
        if engine.lifetime_totals()["n_decode_dispatches"] < self.upgrade_at:
            return False
        self._set_state("relayout")
        engine._apply_upgrade(self)
        return True

    def _swapped(self, engine: ContinuousBatchingEngine, pause_ms: float,
                 resumes, *, pages_resident: int = 0,
                 pages_reprefilled: int = 0) -> None:
        """Engine callback at the end of ``_apply_upgrade``."""
        self.pause_ms = pause_ms
        self.resumed = len(resumes)
        self.resumed_requests = list(resumes)
        self.pages_resident_at_swap = int(pages_resident)
        self.pages_carried = 0
        self.pages_reprefilled = int(pages_reprefilled)
        self.tokens_at_swap = engine.lifetime_totals()["n_tokens"]
        self.t_swap = time.monotonic()
        self._set_state("swapped")

    def wait(self) -> "UpgradeManager":
        """Join a background growth; re-raise its failure here."""
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self.error is not None:
            raise self.error
        return self
