from repro.train.loss import lm_loss, cls_loss
from repro.train.steps import (
    make_train_step,
    make_eval_step,
    make_prefill_step,
    make_decode_step,
    make_grow_step,
)
