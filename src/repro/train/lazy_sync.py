"""Beyond-paper optimization: lazy-sync FSDP training step.

Problem (measured in the dry-run baselines): with pjit-automatic FSDP +
gradient accumulation, every microbatch pays (a) a full parameter
all-gather over the data axis and (b) a full gradient cross-data reduction
— 2 × n_microbatches collective rounds per step.  On yi-9b train_4k the
collective term (≈230 GB/dev wire) is 2.3× the compute term: the cell is
collective-bound purely from re-synchronizing state the algorithm does not
need synchronized until the optimizer runs.

Fix: a *partial-auto* ``jax.shard_map`` over the data(+pod) axes only:

    1. all-gather each FSDP-sharded param over data ONCE;
    2. run all microbatches with purely LOCAL gradients (no cross-data
       collectives inside the loop; model-axis TP collectives still inserted
       automatically — the model axis stays in GSPMD "auto" mode);
    3. one psum_scatter per param back to the FSDP layout, then the
       optimizer update runs on the shard.

Collective rounds per step: 2 × n_micro → 2 (gather + reduce-scatter),
an ~n_micro× cut of the dominant roofline term.  This is the manual ZeRO-3
schedule (what DeepSpeed/FSDP implement in CUDA-land), expressed in 60
lines of shard_map.
"""
from __future__ import annotations

import contextlib
import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.distributed.sharding import suspend_rules
from repro.models import get_family
from repro.optim import OptimizerConfig, make_optimizer
from repro.train.loss import loss_for
from repro.utils.compat import HAS_ABSTRACT_MESH, shard_map_compat


def _data_axes(mesh):
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def _manual_spec(spec: P, manual: set) -> P:
    """Strip non-manual mesh axes from a PartitionSpec (they stay auto)."""
    out = []
    for e in spec:
        if e is None:
            out.append(None)
        elif isinstance(e, tuple):
            kept = tuple(a for a in e if a in manual)
            out.append(kept if len(kept) > 1 else (kept[0] if kept else None))
        else:
            out.append(e if e in manual else None)
    return P(*out)


def _gather_axis(spec: P, manual: set):
    """(dim, mesh-axes) of the leaf dim sharded over manual (data) axes,
    or None if the leaf is replicated across them."""
    for i, e in enumerate(spec):
        es = e if isinstance(e, tuple) else (e,)
        hit = tuple(a for a in es if a in manual)
        if hit:
            return (i, hit)
    return None


def make_lazy_sync_train_step(cfg, opt_cfg: OptimizerConfig, mesh,
                              param_shardings, *, n_microbatches=8,
                              schedule=None):
    """Returns step_fn(params, opt_state, batch, step) with manual FSDP.

    ``param_shardings`` — the pytree of NamedShardings the params live in
    (FSDP layout).  Optimizer state must share the same layout.
    """
    # Old jax cannot partition ``lax.scan`` while-loops inside partial-auto
    # shard_map regions (manual-subgroup check in the SPMD partitioner);
    # fully unrolling the layer/microbatch scans sidesteps the While HLO at
    # the cost of O(L) program size — acceptable for the device counts old
    # jax is realistically run at.
    if not HAS_ABSTRACT_MESH:
        cfg = cfg.replace(unroll_scans=True)
    fam = get_family(cfg)
    loss_fn = loss_for(cfg)
    _, update_fn = make_optimizer(opt_cfg, schedule)
    daxes = _data_axes(mesh)
    manual = set(daxes)
    n_data = 1
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    for a in daxes:
        n_data *= sizes[a]

    p_specs = jax.tree.map(lambda s: s.spec, param_shardings,
                           is_leaf=lambda x: isinstance(x, NamedSharding))
    p_manual = jax.tree.map(lambda s: _manual_spec(s, manual), p_specs,
                            is_leaf=lambda x: isinstance(x, P))
    gather_ax = jax.tree.map(lambda s: _gather_axis(s, manual), p_specs,
                             is_leaf=lambda x: isinstance(x, P))

    # Old jax has no abstract-mesh introspection, so ``annotate`` cannot see
    # it is inside a partial-manual region — and a constraint built on the
    # concrete mesh there trips the SPMD partitioner's manual-subgroup
    # check.  Suspend annotations for the body and let GSPMD infer layouts
    # from the sharded operands.  New jax handles this inside ``annotate``.
    if HAS_ABSTRACT_MESH:
        def body_rules():
            return contextlib.nullcontext()
    else:
        def body_rules():
            return suspend_rules()

    def body_inner(params_local, opt_local, batch_local, step, axis_idx):
        # (1) one all-gather (only over the axes each leaf is sharded on —
        # leaves replicated over pod/data gather nothing).  Old jax's SPMD
        # partitioner crashes on all_gather/psum_scatter of operands that
        # are *also* auto-sharded over the model axis, so there the gather
        # is emulated as pad-to-full + psum and the scatter as psum + slice
        # (same semantics, full-size wire payload — still one collective
        # round per step instead of per microbatch).
        def gather(p, ax):
            if ax is None:
                return p
            dim, axes = ax
            for a in reversed(axes):
                if HAS_ABSTRACT_MESH:
                    p = jax.lax.all_gather(p, a, axis=dim, tiled=True)
                else:
                    shard = p.shape[dim]
                    full = jnp.zeros(
                        p.shape[:dim] + (shard * sizes[a],)
                        + p.shape[dim + 1:], p.dtype)
                    full = jax.lax.dynamic_update_slice_in_dim(
                        full, p, axis_idx[a][0] * shard, axis=dim)
                    p = jax.lax.psum(full, a)
            return p

        params_full = jax.tree.map(gather, params_local, gather_ax)

        # (2) local microbatch gradients — data axis is manual here, so no
        # cross-data collectives appear; model-axis TP stays auto.
        def fwd_loss(p, mb):
            logits, aux = fam.forward(p, mb, cfg)
            loss, metrics = loss_fn(logits, aux, mb, cfg)
            return loss, metrics

        grad_fn = jax.value_and_grad(fwd_loss, has_aux=True)

        def split(x):
            return x.reshape(n_microbatches, x.shape[0] // n_microbatches,
                             *x.shape[1:])

        micro = jax.tree.map(split, batch_local)

        def acc(carry, mb):
            g_acc, m_acc = carry
            (_, metrics), grads = grad_fn(params_full, mb)
            return (jax.tree.map(jnp.add, g_acc, grads),
                    jax.tree.map(jnp.add, m_acc, metrics)), None

        g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                          params_full)
        m0 = jax.eval_shape(lambda: grad_fn(
            params_full, jax.tree.map(lambda x: x[0], micro))[0][1])
        m0 = jax.tree.map(lambda s: jnp.zeros(s.shape, jnp.float32), m0)
        # cfg.unroll_scans is forced True on old jax above, which also
        # fully unrolls this scan (While HLOs don't partition there)
        (grads_full, metrics), _ = jax.lax.scan(
            acc, (g0, m0), micro,
            unroll=getattr(cfg, "unroll_scans", False))

        # (3) one reduce-scatter back to the FSDP shard layout; axes the
        # leaf is replicated over (e.g. pod) contribute a plain psum
        def reduce(g, ax):
            done = ()
            if ax is not None:
                dim, axes = ax
                for a in axes:
                    if HAS_ABSTRACT_MESH:
                        g = jax.lax.psum_scatter(
                            g, a, scatter_dimension=dim, tiled=True)
                    else:
                        g = jax.lax.psum(g, a)
                        shard = g.shape[dim] // sizes[a]
                        g = jax.lax.dynamic_slice_in_dim(
                            g, axis_idx[a][0] * shard, shard, axis=dim)
                done = axes
            for a in daxes:
                if a not in done:
                    g = jax.lax.psum(g, a)
            return g / n_data

        grads_local = jax.tree.map(reduce, grads_full, gather_ax)

        def mean_metric(m):
            for a in daxes:
                m = jax.lax.psum(m, a)
            return m / (n_data * n_microbatches)

        metrics = jax.tree.map(mean_metric, metrics)

        params_local, opt_local, opt_metrics = update_fn(
            params_local, opt_local, grads_local, step)
        metrics.update(opt_metrics)
        return params_local, opt_local, metrics

    def body(params_local, opt_local, batch_local, step, axis_idx=None):
        with body_rules():
            return body_inner(params_local, opt_local, batch_local, step,
                              axis_idx)

    batch_spec = P(daxes if len(daxes) > 1 else daxes[0])
    opt_manual = {"m": p_manual, "v": p_manual}
    if opt_cfg.master_weights:
        opt_manual["master"] = p_manual

    base_specs = (p_manual, opt_manual, batch_spec, P())
    if HAS_ABSTRACT_MESH:
        inner = shard_map_compat(
            body, mesh, manual_axes=manual,
            in_specs=base_specs, out_specs=(p_manual, opt_manual, P()))
        return lambda params, opt_state, batch, step: inner(
            params, opt_state, batch, step)

    # Old jax only: per-axis device indices for the emulated collectives,
    # passed as axis-sharded inputs so each shard reads its own coordinate
    # from its (1,) slice.  (``jax.lax.axis_index`` lowers to PartitionId,
    # which old jax's partitioner rejects inside partial-auto regions.)
    idx_spec = {a: P(a) for a in daxes}
    inner = shard_map_compat(
        body, mesh, manual_axes=manual,
        in_specs=base_specs + (idx_spec,),
        out_specs=(p_manual, opt_manual, P()))

    def step_fn(params, opt_state, batch, step):
        axis_idx = {a: jnp.arange(sizes[a], dtype=jnp.int32) for a in daxes}
        return inner(params, opt_state, batch, step, axis_idx)

    return step_fn
