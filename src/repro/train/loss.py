"""Losses: next-token CE (with z-loss), classification, MTP, MoE aux."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def _ce(logits, targets, z_loss=0.0):
    """logits (..., V) any dtype; targets (...) int32. f32 reduction."""
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, targets[..., None], -1)[..., 0]
    loss = lse - ll
    if z_loss:
        loss = loss + z_loss * jnp.square(lse)
    return loss


def lm_loss(logits, aux, batch, cfg, z_loss=1e-4):
    """Causal LM loss (+ MoE aux + MTP).  Encoder configs (non-causal LM
    heads, e.g. HuBERT units / BERT MLM) predict the *current* position of
    a masked stream instead of shifting."""
    tokens = batch["tokens"]
    if cfg.causal:
        loss = _ce(logits[:, :-1], tokens[:, 1:], z_loss).mean()
    else:
        mask = batch.get("mask")
        per = _ce(logits, tokens, z_loss)
        loss = (per * mask).sum() / jnp.maximum(mask.sum(), 1) \
            if mask is not None else per.mean()
    metrics = {"ce": loss}
    if aux.get("moe_aux") is not None and cfg.moe:
        moe_aux = aux["moe_aux"] * cfg.aux_loss_weight
        loss = loss + moe_aux
        metrics["moe_aux"] = moe_aux
    if "mtp_logits" in aux:
        # depth-1 MTP predicts token t+2 from position t
        mtp = _ce(aux["mtp_logits"][:, :-1], tokens[:, 2:], z_loss).mean()
        loss = loss + cfg.mtp_weight * mtp
        metrics["mtp"] = mtp
    metrics["loss"] = loss
    return loss, metrics


def cls_loss(logits, aux, batch, cfg, z_loss=0.0):
    loss = _ce(logits, batch["labels"], z_loss).mean()
    acc = (logits.argmax(-1) == batch["labels"]).mean()
    return loss, {"loss": loss, "acc": acc}


def loss_for(cfg):
    return cls_loss if cfg.head == "cls" else lm_loss
