"""Step builders: train / eval / prefill / decode / operator-grow.

These are the exact functions the launcher jits with mesh shardings and the
dry-run lowers at full scale, so everything here must be shape-polymorphic
over batch/seq and mesh-agnostic (sharding comes only from annotations +
in/out shardings).
"""
from __future__ import annotations

import functools
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.models import get_family
from repro.optim import OptimizerConfig, make_optimizer
from repro.train.loss import loss_for


def make_train_step(cfg, opt_cfg: OptimizerConfig, schedule=None,
                    n_microbatches: int = 1, grad_transform=None):
    """-> step_fn(params, opt_state, batch, step) -> (params, state, metrics).

    ``n_microbatches`` > 1 splits the global batch and accumulates grads
    under a scan (sequential accumulation — the standard memory/throughput
    trade at large global batch).
    ``grad_transform`` — optional hook applied to the averaged grads before
    the optimizer (gradient compression plugs in here).
    """
    fam = get_family(cfg)
    loss_fn = loss_for(cfg)
    _, update_fn = make_optimizer(opt_cfg, schedule)

    def fwd_loss(params, batch):
        logits, aux = fam.forward(params, batch, cfg)
        return loss_fn(logits, aux, batch, cfg)

    grad_fn = jax.value_and_grad(fwd_loss, has_aux=True)

    def step_fn(params, opt_state, batch, step):
        if n_microbatches == 1:
            (_, metrics), grads = grad_fn(params, batch)
        else:
            B_glob = batch["tokens"].shape[0]

            def split(x):
                ax = next(i for i, s in enumerate(x.shape) if s == B_glob)
                n = n_microbatches
                lead = x.shape[:ax]
                return jnp.moveaxis(
                    x.reshape(*lead, n, x.shape[ax] // n, *x.shape[ax + 1:]),
                    len(lead), 0)
            micro = jax.tree.map(split, batch)

            def acc_body(carry, mb):
                g_acc, m_acc = carry
                (_, metrics), grads = grad_fn(params, mb)
                g_acc = jax.tree.map(jnp.add, g_acc, grads)
                m_acc = jax.tree.map(jnp.add, m_acc, metrics)
                return (g_acc, m_acc), None

            g0 = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            m0 = jax.eval_shape(
                lambda: grad_fn(params, jax.tree.map(lambda x: x[0],
                                                     micro))[0][1])
            m0 = jax.tree.map(lambda s: jnp.zeros(s.shape, jnp.float32), m0)
            (grads, metrics), _ = jax.lax.scan(
                acc_body, (g0, m0), micro,
                unroll=getattr(cfg, "unroll_scans", False))
            grads = jax.tree.map(lambda g: g / n_microbatches, grads)
            metrics = jax.tree.map(lambda m: m / n_microbatches, metrics)
        if grad_transform is not None:
            grads = grad_transform(grads)
        params, opt_state, opt_metrics = update_fn(
            params, opt_state, grads, step)
        metrics.update(opt_metrics)
        return params, opt_state, metrics

    return step_fn


def make_eval_step(cfg):
    fam = get_family(cfg)
    loss_fn = loss_for(cfg)

    def eval_fn(params, batch):
        logits, aux = fam.forward(params, batch, cfg)
        _, metrics = loss_fn(logits, aux, batch, cfg)
        return metrics

    return eval_fn


def make_prefill_step(cfg):
    fam = get_family(cfg)

    def prefill_fn(params, batch, cache):
        return fam.prefill(params, batch, cfg, cache)

    return prefill_fn


def make_decode_step(cfg):
    """One greedy serving step: feed current tokens, emit next + cache.
    (Non-greedy decode lives in the engine's sampled loops —
    ``serve/sampling.py`` — not here.)"""
    fam = get_family(cfg)

    def decode_fn(params, tokens, pos, cache):
        logits, cache = fam.decode_step(params, tokens, pos, cache, cfg)
        nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return nxt, cache

    return decode_fn


def make_prefill_full_step(cfg):
    """Prefill that returns logits at every position (continuous batching:
    prompts are padded to bucket lengths, the engine reads each request's
    true last-token logits)."""
    fam = get_family(cfg)
    if not hasattr(fam, "prefill_full"):
        raise NotImplementedError(
            f"family {cfg.family!r} has no full-logits prefill")

    def prefill_fn(params, batch, cache):
        return fam.prefill_full(params, batch, cfg, cache)

    return prefill_fn


def make_slot_decode_step(cfg):
    """Continuous-batching decode: every batch row is an independent cache
    slot at its own sequence length.

    fn(params, tokens (B,), positions (B,), cache) -> (next (B,), cache).
    """
    fam = get_family(cfg)
    if not hasattr(fam, "decode_step_slots"):
        raise NotImplementedError(
            f"family {cfg.family!r} has no slot-indexed decode path")

    def decode_fn(params, tokens, positions, cache):
        logits, cache = fam.decode_step_slots(params, tokens, positions,
                                              cache, cfg)
        nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return nxt, cache

    return decode_fn


def make_prefill_admit_step(cfg, sampling=None):
    """Batched admission prefill for the continuous-batching engine.

    fn(params, tokens (N, Sbucket), plens (N,), cache) ->
        (first (N,) int32, cache)

    All requests of one prefill bucket run as ONE multi-row forward; the
    first generated token of each row (argmax at its true last prompt
    position) is computed on device, so admission costs one dispatch per
    bucket group instead of one prefill + one host argmax per request.

    ``plens`` rides along in the batch: full KV caches ignore it (the
    pad tail hides behind the per-row ``kv_len`` mask), but ring-buffer
    window caches and recurrent state (griffin, xlstm) must take each
    row's state at its TRUE prompt boundary.

    With a non-greedy ``sampling`` (``serve.sampling.SamplingParams``)
    the signature gains per-row chain roots —
    fn(params, tokens, plens, cache, uids (N,), skips (N,)) ->
    (first, cache, keys)
    — each row's PRNG chain is seeded from (sampling.seed, uid) ON
    DEVICE, its first key samples the first token, and the advanced
    chains come back for the admission scatter (keys never round-trip
    through the host).  ``skips`` is the journal-resume hook: row ``i``'s
    chain is advanced ``skips[i]`` splits before its first draw, exactly
    as if it had already sampled that many tokens — a restarted engine
    re-admitting a mid-flight sequence (prompt ‖ committed tokens) then
    draws the SAME next token the uninterrupted run would have (chains
    advance only on real samples, so chain position == committed-token
    count).  Fresh admissions pass zeros.
    """
    from repro.serve import sampling as sampling_lib

    fam = get_family(cfg)
    if not hasattr(fam, "prefill_full"):
        raise NotImplementedError(
            f"family {cfg.family!r} has no full-logits prefill")

    def last_logits(params, tokens, plens, cache):
        logits, cache = fam.prefill_full(
            params, {"tokens": tokens, "plens": plens}, cfg, cache)
        rows = jnp.arange(tokens.shape[0])
        return logits[rows, plens - 1], cache

    if sampling_lib.is_greedy(sampling):
        def prefill_fn(params, tokens, plens, cache):
            logits, cache = last_logits(params, tokens, plens, cache)
            return jnp.argmax(logits, axis=-1).astype(jnp.int32), cache

        return prefill_fn

    def prefill_sampled(params, tokens, plens, cache, uids, skips):
        logits, cache = last_logits(params, tokens, plens, cache)
        roots = jax.vmap(
            lambda u: sampling_lib.request_key(sampling.seed, u))(uids)

        # a committed token consumed one split of its chain: replay those
        # splits (bounded by the bucket length — a resume's committed run
        # is part of its padded prompt, so skips < tokens.shape[1])
        def advance(i, ks):
            ks_new, _ = sampling_lib.next_keys(ks)
            return jnp.where((i < skips)[:, None], ks_new, ks)

        roots = jax.lax.fori_loop(0, tokens.shape[1], advance, roots)
        keys, subs = sampling_lib.next_keys(roots)
        first = sampling_lib.sample_logits(logits, subs, sampling)
        return first, cache, keys

    return prefill_sampled


def make_slot_decode_loop(cfg, k: int, sampling=None):
    """On-device macro-step: K slot-decode steps under one ``lax.scan``.

    fn(params, tokens (B,), positions (B,), remaining (B,), eos_ids (B,),
       done (B,), cache) ->
        (block (K, B) int32, valid (K, B) bool, poison (B,) bool,
         tokens, positions, remaining, done, cache)

    The host syncs once per K generated tokens instead of once per token:
    eos / max-new-token stopping is applied per slot *inside* the scan.  A
    row that finishes (or starts the block idle) stops advancing — its
    position and token freeze, and the family's ``decode_step_slots``
    turns the row into an exact no-op: full KV caches re-store identical
    bytes and attend with ``kv_len == 0`` (the idle-row short-circuit in
    the attention stack); recurrent families (griffin, xlstm) freeze the
    row's state outright via the ``done`` mask, since a recurrence update
    cannot be re-stored.  ``valid[i, b]`` marks whether ``block[i, b]`` is
    a really generated token; rows emit their eos token as valid and then
    go quiet.

    ``poison`` is the NaN/Inf sentinel: a live row whose logits come back
    non-finite at any step of the block is frozen ON that step exactly
    like an eos row (its garbage token is never committed — ``valid``
    goes quiet, the done-mask turns the row into a no-op for the rest of
    the scan, and a recurrent family's state stops before the poisoned
    update can propagate) and its ``poison`` flag rides the block
    readback, so detection costs zero extra host syncs.  The engine
    quarantine-evicts flagged slots.

    ``eos_ids`` uses -1 for "no eos" (token ids are non-negative).
    ``remaining`` counts decode tokens still owed per row; it hits 0
    exactly when the row's last owed token is emitted.

    With a non-greedy ``sampling`` (``serve.sampling.SamplingParams``)
    the signature gains per-slot PRNG chains —
    fn(..., cache, keys (B,2)) -> (..., cache, keys) — and each step
    draws from the temperature/top-k/top-p-filtered distribution.  A
    chain only advances when its row really samples, so a request's
    tokens are a pure function of (seed, uid, prompt), independent of
    slot placement and interleaving.
    """
    from repro.serve import sampling as sampling_lib

    fam = get_family(cfg)
    if not hasattr(fam, "decode_step_slots"):
        raise NotImplementedError(
            f"family {cfg.family!r} has no slot-indexed decode path")
    greedy = sampling_lib.is_greedy(sampling)

    def step(carry, params, eos_ids):
        if greedy:
            tokens, positions, remaining, done, poison, cache = carry
        else:
            tokens, positions, remaining, done, poison, cache, keys = carry
        live = ~done
        logits, cache = fam.decode_step_slots(
            params, tokens, positions, cache, cfg, done=done)
        # NaN/Inf sentinel: a poisoned live row freezes HERE — its token
        # is never committed and (crucially, for recurrent state) no
        # further update runs on the row.  The elementwise reduce fuses
        # into the dispatch; nothing extra crosses to the host.
        bad = live & ~jnp.all(jnp.isfinite(
            logits.astype(jnp.float32)), axis=-1)
        live = live & ~bad
        poison = poison | bad
        if greedy:
            nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        else:
            keys_new, subs = sampling_lib.next_keys(keys)
            keys = jnp.where(live[:, None], keys_new, keys)
            nxt = sampling_lib.sample_logits(logits, subs, sampling)
        tokens = jnp.where(live, nxt, tokens)
        remaining = jnp.where(live, remaining - 1, remaining)
        done = done | bad | (live & ((tokens == eos_ids)
                                     | (remaining <= 0)))
        positions = jnp.where(live, positions + 1, positions)
        carry = (tokens, positions, remaining, done, poison, cache) \
            if greedy \
            else (tokens, positions, remaining, done, poison, cache, keys)
        return carry, (tokens, live)

    if greedy:
        def loop_fn(params, tokens, positions, remaining, eos_ids, done,
                    cache):
            poison0 = jnp.zeros(tokens.shape, bool)
            carry, (block, valid) = jax.lax.scan(
                lambda c, _: step(c, params, eos_ids),
                (tokens, positions, remaining, done, poison0, cache),
                None, length=k)
            tokens, positions, remaining, done, poison, cache = carry
            return (block, valid, poison, tokens, positions, remaining,
                    done, cache)

        return loop_fn

    def loop_sampled(params, tokens, positions, remaining, eos_ids, done,
                     cache, keys):
        poison0 = jnp.zeros(tokens.shape, bool)
        carry, (block, valid) = jax.lax.scan(
            lambda c, _: step(c, params, eos_ids),
            (tokens, positions, remaining, done, poison0, cache, keys),
            None, length=k)
        tokens, positions, remaining, done, poison, cache, keys = carry
        return (block, valid, poison, tokens, positions, remaining, done,
                cache, keys)

    return loop_sampled


def make_grow_step(gop, cfg_tgt, opt_cfg: OptimizerConfig,
                   n_microbatches: int = 1):
    """Operator-training step (paper Eq. 7): one Adam update on the TR cores.

    fn(op_params, opt_state, small_params, batch, step) ->
        (op_params, opt_state, metrics)

    The big model materializes *inside* the step (sharded by annotation) —
    it never exists outside the jit.  With ``n_microbatches`` > 1 the
    growth contraction is recomputed per microbatch (it is ~1 ms at yi-9b
    scale — see contract_flops) in exchange for an n_micro x smaller
    activation stash of the target model's fwd/bwd.
    """
    from repro.core import grow as growlib

    fam = get_family(cfg_tgt)
    loss_fn = loss_for(cfg_tgt)
    _, update_fn = make_optimizer(opt_cfg)

    def objective(op_params, small_params, batch):
        big = growlib.grow_params(gop, op_params, small_params)
        logits, aux = fam.forward(big, batch, cfg_tgt)
        loss, metrics = loss_fn(logits, aux, batch, cfg_tgt)
        return loss, metrics

    grad_fn = jax.value_and_grad(objective, has_aux=True)

    def step_fn(op_params, opt_state, small_params, batch, step):
        if n_microbatches == 1:
            (_, metrics), grads = grad_fn(op_params, small_params, batch)
        else:
            def split(x):
                return x.reshape(n_microbatches,
                                 x.shape[0] // n_microbatches, *x.shape[1:])
            micro = jax.tree.map(split, batch)

            def acc(carry, mb):
                g_acc, m_acc = carry
                (_, metrics), grads = grad_fn(op_params, small_params, mb)
                return (jax.tree.map(jnp.add, g_acc, grads),
                        jax.tree.map(jnp.add, m_acc, metrics)), None

            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                              op_params)
            m0 = jax.eval_shape(lambda: grad_fn(
                op_params, small_params,
                jax.tree.map(lambda x: x[0], micro))[0][1])
            m0 = jax.tree.map(lambda s: jnp.zeros(s.shape, jnp.float32),
                              m0)
            (grads, metrics), _ = jax.lax.scan(acc, (g0, m0), micro)
            grads = jax.tree.map(lambda g: g / n_microbatches, grads)
            metrics = jax.tree.map(lambda m: m / n_microbatches, metrics)
        op_params, opt_state, opt_metrics = update_fn(
            op_params, opt_state, grads, step)
        metrics.update(opt_metrics)
        return op_params, opt_state, metrics

    return step_fn
