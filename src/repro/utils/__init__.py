from repro.utils.pytree import (
    tree_size_bytes,
    tree_param_count,
    tree_flatten_with_paths,
    path_str,
)
from repro.utils.dtypes import DTypePolicy, canonical_dtype
