"""jax version-compat shims.

The repo targets the modern jax API (explicit mesh axis types, top-level
``jax.shard_map``, abstract-mesh introspection) but must also run on older
releases (0.4.x) where those surfaces either do not exist or live under
``jax.experimental``.  Every call site goes through these helpers so the
version split lives in exactly one file.
"""
from __future__ import annotations

import jax

# ``hasattr`` is safe here: jax's deprecation module raises AttributeError
# for names that have never existed on this version.
HAS_ABSTRACT_MESH = hasattr(jax.sharding, "get_abstract_mesh")


def get_abstract_mesh():
    """Current abstract mesh, or None on jax versions without the concept."""
    if not HAS_ABSTRACT_MESH:
        return None
    return jax.sharding.get_abstract_mesh()


def make_mesh_compat(shape, axes):
    """``jax.make_mesh`` across jax versions.

    Newer jax wants explicit ``axis_types`` (``jax.sharding.AxisType.Auto``)
    to keep meshes in auto-sharding mode; older releases predate ``AxisType``
    and their ``make_mesh`` takes no such kwarg — plain construction is
    already Auto there.
    """
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is not None:
        try:
            return jax.make_mesh(
                shape, axes, axis_types=(axis_type.Auto,) * len(axes))
        except TypeError:  # AxisType exists but make_mesh predates the kwarg
            pass
    return jax.make_mesh(shape, axes)


def shard_map_compat(f, mesh, *, in_specs, out_specs, manual_axes=None):
    """``shard_map`` with optional partial-manual axes, on any jax.

    ``manual_axes=None`` maps every mesh axis (classic shard_map); otherwise
    only the named axes are manual and the rest stay under the automatic
    partitioner.  New jax expresses this as ``axis_names=<manual>``, old jax
    as the complement ``auto=<rest>``.
    """
    sm = getattr(jax, "shard_map", None)
    if sm is not None:
        kw = {}
        if manual_axes is not None:
            kw["axis_names"] = set(manual_axes)
        try:
            return sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      check_vma=False, **kw)
        except TypeError:
            # intermediate versions export top-level shard_map but keep the
            # old check_rep=/auto= signature
            pass
    from jax.experimental.shard_map import shard_map as sm_old
    kw = {}
    if manual_axes is not None:
        auto = frozenset(mesh.axis_names) - frozenset(manual_axes)
        if auto:
            kw["auto"] = auto
    return sm_old(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                  check_rep=False, **kw)
