"""Mixed-precision policy.

Large-scale TPU training convention:
  * ``param_dtype``   — how weights are stored (bf16 at scale, f32 for tests)
  * ``compute_dtype`` — matmul/activation dtype (bf16 on the MXU)
  * reductions (softmax denominators, loss, norms) always in f32.
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp


def canonical_dtype(name):
    if isinstance(name, str):
        return jnp.dtype(
            {"bf16": jnp.bfloat16, "f32": jnp.float32, "f16": jnp.float16}.get(
                name, name
            )
        )
    return jnp.dtype(name)


@dataclasses.dataclass(frozen=True)
class DTypePolicy:
    param_dtype: str = "float32"
    compute_dtype: str = "float32"

    @property
    def param(self):
        return canonical_dtype(self.param_dtype)

    @property
    def compute(self):
        return canonical_dtype(self.compute_dtype)

    def cast_compute(self, x):
        return x.astype(self.compute)


TRAIN_BF16 = DTypePolicy(param_dtype="bfloat16", compute_dtype="bfloat16")
TEST_F32 = DTypePolicy()
