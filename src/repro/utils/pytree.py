"""Small pytree helpers used across the framework."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def tree_param_count(tree) -> int:
    """Total number of elements across all leaves."""
    return sum(int(np.prod(x.shape)) for x in jax.tree.leaves(tree))


def tree_size_bytes(tree) -> int:
    """Total bytes across all leaves (honours per-leaf dtype)."""
    return sum(
        int(np.prod(x.shape)) * jnp.dtype(x.dtype).itemsize
        for x in jax.tree.leaves(tree)
    )


def path_str(path) -> str:
    """Render a jax key-path as 'a.b.0.c'."""
    parts = []
    for p in path:
        if isinstance(p, jax.tree_util.DictKey):
            parts.append(str(p.key))
        elif isinstance(p, jax.tree_util.SequenceKey):
            parts.append(str(p.idx))
        elif isinstance(p, jax.tree_util.GetAttrKey):
            parts.append(str(p.name))
        else:
            parts.append(str(p))
    return ".".join(parts)


def tree_flatten_with_paths(tree):
    """[(path_string, leaf)] for every leaf in the tree."""
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    return [(path_str(p), leaf) for p, leaf in flat]
