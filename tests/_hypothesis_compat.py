"""``hypothesis`` shim: property tests degrade to fixed parametrized cases.

The container image does not ship ``hypothesis``; importing it at module
scope used to kill collection of three whole test modules.  Import
``given``/``settings``/``st`` from here instead:

  * when hypothesis IS installed, the real objects are re-exported and the
    property tests run at full strength;
  * when it is absent, ``@given`` expands each strategy into a small
    deterministic case set (both bounds + seeded draws) via
    ``pytest.mark.parametrize``, and ``@settings`` is a no-op.

The fallback draws are seeded from the test name, so the sweep is stable
across runs and machines.
"""
from __future__ import annotations

import random
import zlib

import pytest

try:
    from hypothesis import given, settings, strategies as st  # noqa: F401
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

    N_FALLBACK_EXAMPLES = 6  # 2 bound cases + 4 seeded draws per test

    class _Strategy:
        def low(self):
            raise NotImplementedError

        def high(self):
            raise NotImplementedError

        def draw(self, rng):
            raise NotImplementedError

    class _Integers(_Strategy):
        def __init__(self, lo, hi):
            self.lo, self.hi = lo, hi

        def low(self):
            return self.lo

        def high(self):
            return self.hi

        def draw(self, rng):
            return rng.randint(self.lo, self.hi)

    class _Floats(_Strategy):
        def __init__(self, lo, hi):
            self.lo, self.hi = lo, hi

        def low(self):
            return self.lo

        def high(self):
            return self.hi

        def draw(self, rng):
            return self.lo + (self.hi - self.lo) * rng.random()

    class _SampledFrom(_Strategy):
        def __init__(self, elems):
            self.elems = list(elems)

        def low(self):
            return self.elems[0]

        def high(self):
            return self.elems[-1]

        def draw(self, rng):
            return rng.choice(self.elems)

    class _Booleans(_SampledFrom):
        def __init__(self):
            super().__init__([False, True])

    class st:  # noqa: N801  (mimics ``hypothesis.strategies`` module)
        @staticmethod
        def integers(min_value, max_value):
            return _Integers(min_value, max_value)

        @staticmethod
        def floats(min_value, max_value):
            return _Floats(min_value, max_value)

        @staticmethod
        def sampled_from(elems):
            return _SampledFrom(elems)

        @staticmethod
        def booleans():
            return _Booleans()

    def settings(*_args, **_kw):
        return lambda fn: fn

    def given(**strategies):
        names = sorted(strategies)

        def deco(fn):
            rng = random.Random(zlib.crc32(fn.__name__.encode()))
            cases = [
                tuple(strategies[n].low() for n in names),
                tuple(strategies[n].high() for n in names),
            ]
            for _ in range(N_FALLBACK_EXAMPLES - len(cases)):
                cases.append(tuple(strategies[n].draw(rng) for n in names))
            return pytest.mark.parametrize(",".join(names), cases)(fn)

        return deco
