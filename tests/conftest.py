"""Shared fixtures + marker registration for the tier-1 suite.

Keeping fixture configs tiny (2 layers, d_model 64) is what holds the
default ``pytest -x -q`` run under the ~2-minute budget; anything that
genuinely needs scale belongs behind ``@pytest.mark.slow``.
"""
import jax
import pytest

from repro.configs.base import get_config
from repro.models import get_family


@pytest.fixture(scope="session")
def qwen_smoke_cfg():
    """Tiny dense decoder (qkv-bias, tied embeddings) — the default serve
    test subject."""
    return get_config("qwen1.5-0.5b-smoke")


@pytest.fixture(scope="session")
def qwen_smoke_params(qwen_smoke_cfg):
    fam = get_family(qwen_smoke_cfg)
    return fam.init(jax.random.PRNGKey(0), qwen_smoke_cfg)


@pytest.fixture(scope="session")
def gpt_micro_cfg():
    """The paper's micro GPT (learned positions) — growth-source model."""
    return get_config("gpt-micro")


@pytest.fixture(scope="session")
def gpt_micro_big_cfg():
    """Growth target for gpt-micro (2x layers, 2x width)."""
    return get_config("gpt-micro-big")
