"""Per-assigned-architecture smoke tests (reduced same-family configs).

Each runs one forward + one train step on CPU and asserts output shapes and
finiteness — the full configs are exercised only via the 512-device dry-run.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.archs import ARCH_IDS
from repro.configs.base import get_config
from repro.data.synthetic import frames_batch, lm_batch
from repro.models import get_family
from repro.optim import OptimizerConfig, make_optimizer
from repro.train.steps import make_train_step

B, S = 2, 32

# recurrent/scan-heavy families compile slowly on CPU; their train steps run
# in the slow tier (forward smoke stays in the default run for all 10)
_HEAVY_TRAIN = {"recurrentgemma-2b", "xlstm-1.3b", "deepseek-v3-671b",
                "hubert-xlarge", "yi-9b"}
_TRAIN_PARAMS = [
    pytest.param(a, marks=pytest.mark.slow) if a in _HEAVY_TRAIN
    else pytest.param(a) for a in ARCH_IDS
]


def _batch_for(cfg):
    if cfg.continuous_inputs:
        b = frames_batch(cfg.continuous_inputs, cfg.vocab_size, B, S)
        b["mask"] = np.ones((B, S), np.float32)
        return {k: jnp.asarray(v) for k, v in b.items()}
    b = {"tokens": jnp.asarray(lm_batch(cfg.vocab_size, B, S))}
    if cfg.rope == "mrope":
        pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
        b["positions"] = jnp.broadcast_to(pos[None], (3, B, S))
    return b


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_forward(arch):
    cfg = get_config(f"{arch}-smoke")
    fam = get_family(cfg)
    params = fam.init(jax.random.PRNGKey(0), cfg)
    batch = _batch_for(cfg)
    logits, aux = jax.jit(lambda p, b: fam.forward(p, b, cfg))(params, batch)
    assert logits.shape == (B, S, cfg.vocab_size), (arch, logits.shape)
    assert np.isfinite(np.asarray(logits, np.float32)).all(), arch


@pytest.mark.parametrize("arch", _TRAIN_PARAMS)
def test_smoke_train_step(arch):
    cfg = get_config(f"{arch}-smoke")
    fam = get_family(cfg)
    params = fam.init(jax.random.PRNGKey(0), cfg)
    opt_cfg = OptimizerConfig(lr=1e-3)
    init_fn, _ = make_optimizer(opt_cfg)
    opt_state = init_fn(params)
    step = jax.jit(make_train_step(cfg, opt_cfg))
    batch = _batch_for(cfg)
    params2, opt_state, metrics = step(params, opt_state, batch,
                                       jnp.int32(1))
    assert np.isfinite(float(metrics["loss"])), arch
    # params actually moved
    moved = jax.tree.map(
        lambda a, b: float(jnp.max(jnp.abs(a.astype(jnp.float32)
                                           - b.astype(jnp.float32)))),
        params, params2)
    assert max(jax.tree.leaves(moved)) > 0, arch
