"""Chunked attention vs oracle (property-swept) + MoE dispatch semantics."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.models.attention import attention, reference_attention
from repro.models.moe import dispatch_combine, moe_mlp, router
from repro.configs.base import ModelConfig


# ---------------------------------------------------------------- attention
@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("window", [None, 8])
@pytest.mark.parametrize("kv", [1, 2, 4])
def test_chunked_attention_matches_reference(causal, window, kv):
    if window is not None and not causal:
        pytest.skip("look-back windows are causal by construction")
    keys = jax.random.split(jax.random.PRNGKey(0), 3)
    B, S, H, hd = 2, 64, 4, 16
    q = jax.random.normal(keys[0], (B, S, H, hd))
    k = jax.random.normal(keys[1], (B, S, kv, hd))
    v = jax.random.normal(keys[2], (B, S, kv, hd))
    out = attention(q, k, v, causal=causal, window=window, chunk_q=16)
    ref = reference_attention(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


def test_ragged_tail_padding():
    keys = jax.random.split(jax.random.PRNGKey(1), 3)
    B, S, H, hd = 1, 63, 2, 16  # 63 % 16 != 0 -> pad path
    q = jax.random.normal(keys[0], (B, S, H, hd))
    k = jax.random.normal(keys[1], (B, S, 2, hd))
    v = jax.random.normal(keys[2], (B, S, 2, hd))
    out = attention(q, k, v, causal=True, chunk_q=16)
    ref = reference_attention(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


@settings(max_examples=15, deadline=None)
@given(s=st.sampled_from([32, 48, 64]), chunk=st.sampled_from([8, 16]),
       q_offset=st.integers(0, 16))
def test_attention_chunk_invariance(s, chunk, q_offset):
    """Output must not depend on the chunk size (pure scheduling knob)."""
    keys = jax.random.split(jax.random.PRNGKey(2), 3)
    q = jax.random.normal(keys[0], (1, s, 2, 8))
    k = jax.random.normal(keys[1], (1, s + q_offset, 2, 8))
    v = jax.random.normal(keys[2], (1, s + q_offset, 2, 8))
    a = attention(q, k, v, causal=True, q_offset=q_offset, chunk_q=chunk)
    b = attention(q, k, v, causal=True, q_offset=q_offset, chunk_q=s)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-5,
                               atol=2e-5)


# --------------------------------------------------------------------- MoE
def test_router_topk_and_aux():
    x = jax.random.normal(jax.random.PRNGKey(0), (2, 16, 8))
    w = jax.random.normal(jax.random.PRNGKey(1), (8, 4))
    for score in ("softmax", "sigmoid"):
        wts, idx, aux = router(x, w, top_k=2, score=score)
        assert wts.shape == (2, 16, 2) and idx.shape == (2, 16, 2)
        np.testing.assert_allclose(np.asarray(wts.sum(-1)), 1.0, atol=1e-5)
        # top-k indices are distinct per token
        assert (np.asarray(idx[..., 0]) != np.asarray(idx[..., 1])).all()
        assert float(aux) > 0


def test_dispatch_respects_capacity():
    B, S, K, E, C = 1, 16, 1, 2, 3
    # route every token to expert 0 -> only C survive
    idx = jnp.zeros((B, S, K), jnp.int32)
    wts = jnp.ones((B, S, K))
    disp, comb = dispatch_combine(wts, idx, E, C)
    assert disp.shape == (B, S, E, C)
    assert float(disp.sum()) == C  # capacity-truncated
    # earlier tokens win
    assert float(disp[0, :C, 0].sum()) == C


def test_dispatch_combine_identity_when_unconstrained():
    """With ample capacity, combine(dispatch(x)) == sum_k w_k * x."""
    B, S, K, E = 2, 8, 2, 4
    key = jax.random.PRNGKey(3)
    logits = jax.random.normal(key, (B, S, E))
    probs = jax.nn.softmax(logits)
    wts, idx = jax.lax.top_k(probs, K)
    wts = wts / wts.sum(-1, keepdims=True)
    disp, comb = dispatch_combine(wts, idx, E, capacity=S)
    x = jax.random.normal(jax.random.PRNGKey(4), (B, S, 3))
    xe = jnp.einsum("bsec,bsd->becd", disp, x)
    y = jnp.einsum("bsec,becd->bsd", comb, xe)  # identity experts
    np.testing.assert_allclose(np.asarray(y), np.asarray(x), rtol=1e-5,
                               atol=1e-5)


def test_moe_mlp_group_reshape_consistency():
    """Group size must not change results when capacity is ample."""
    from repro.models import moe as moe_lib

    cfg = ModelConfig(name="m", n_layers=1, d_model=16, n_heads=2,
                      n_kv_heads=2, d_ff=32, vocab_size=11, moe=True,
                      n_experts=4, top_k=2, expert_d_ff=32,
                      capacity_factor=8.0)
    p = moe_lib.init_moe(iter(jax.random.split(jax.random.PRNGKey(0), 10)),
                         cfg, layers=None)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, 16))
    y1, _ = moe_lib.moe_mlp(x, p, cfg)
    old = moe_lib.MOE_GROUP_SIZE
    try:
        moe_lib.MOE_GROUP_SIZE = 4
        y2, _ = moe_lib.moe_mlp(x, p, cfg)
    finally:
        moe_lib.MOE_GROUP_SIZE = old
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=1e-5,
                               atol=1e-5)
