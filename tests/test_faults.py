"""Runtime-guard invariants under injected faults.

Every fault the engine claims to survive is injected here through the
deterministic :class:`FaultPlan` harness and the blast radius is pinned:
a NaN quarantines exactly the poisoned slot (survivors stay token-exact),
a draft-pool NaN demotes speculation to plain decode without changing
one token, a paged-arena fault degrades admissions to full reservation,
deadlines evict hung requests with their partial output delivered, and
queue-age shedding keeps an overloaded engine live.
"""
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data.synthetic import lm_batch
from repro.launch.serve import generate
from repro.serve import (
    ContinuousBatchingEngine,
    Fault,
    FaultPlan,
    Request,
    SpeculativeConfig,
)

MAX_LEN = 32


def _mixed_requests(cfg, specs, *, uid0=0, seed0=50):
    reqs = []
    for i, (plen, gen) in enumerate(specs):
        prompt = lm_batch(cfg.vocab_size, 1, plen, seed=seed0 + i)[0]
        reqs.append(Request(uid=uid0 + i, prompt=prompt,
                            max_new_tokens=gen))
    return reqs


def _sequential_baseline(cfg, params, reqs):
    out = {}
    for r in reqs:
        toks = generate(cfg, params, jnp.asarray(r.prompt)[None],
                        max_new_tokens=r.max_new_tokens, max_len=MAX_LEN)
        out[r.uid] = np.asarray(toks[0])
    return out


# ------------------------------------------------------------------- plans
def test_fault_plan_parse_seeded_and_delivery():
    plan = FaultPlan.parse("nan@3:1,oom@5:2,slow@7:0.1,crash@9")
    assert [f.kind for f in plan.faults] == ["nan", "oom", "slow", "crash"]
    assert plan.faults[0].slot == 1          # nan arg is a slot
    assert plan.faults[1].duration == 2.0    # oom arg is waves
    assert plan.faults[2].duration == 0.1
    # defaults when the arg is omitted
    assert FaultPlan.parse("slow@1").faults[0].duration == 0.05
    assert FaultPlan.parse("hang@1").faults[0].duration == 0.25
    with pytest.raises(ValueError, match="kind"):
        FaultPlan.parse("meteor@3")
    with pytest.raises(ValueError, match="not 'kind@step"):
        FaultPlan.parse("nan3")
    # seeded plans are a pure function of (seed, n_steps)
    a = FaultPlan.seeded(11, 24)
    b = FaultPlan.seeded(11, 24)
    assert a.faults == b.faults and len(a) == 4
    assert a.faults != FaultPlan.seeded(12, 24).faults
    assert all(1 <= f.step < 24 for f in a.faults)
    # at-most-once delivery: due() pops, a second call returns nothing
    plan = FaultPlan([Fault("nan", 2), Fault("slow", 5, duration=0.01)])
    assert [f.kind for f in plan.due(3)] == ["nan"]
    assert plan.due(3) == [] and len(plan.injected) == 1
    assert [f.kind for f in plan.due(99)] == ["slow"]


# --------------------------------------------------------------- quarantine
def test_nan_quarantines_only_poisoned_slot(qwen_smoke_cfg,
                                            qwen_smoke_params):
    """NaN scattered into slot 0's live cache bytes: the in-scan sentinel
    catches it at the next block readback, that request alone retires as
    ``quarantined`` with its pre-fault prefix delivered, and every other
    request's tokens are bit-identical to the fault-free run."""
    cfg, params = qwen_smoke_cfg, qwen_smoke_params
    reqs = _mixed_requests(cfg, [(4, 9), (6, 7), (5, 8), (7, 6)],
                           seed0=30)
    want = _sequential_baseline(cfg, params, reqs)
    engine = ContinuousBatchingEngine(
        cfg, params, capacity=2, max_len=MAX_LEN, prefill_bucket=4, k=4,
        faults=FaultPlan([Fault("nan", 2, slot=0)]))
    got = engine.run(reqs)
    assert engine.n_quarantined == 1 and engine.n_faults_injected == 1
    bad = [u for u, o in engine.outcomes.items() if o == "quarantined"]
    assert len(bad) == 1
    for uid in want:
        if uid in bad:
            # the poisoned row froze AT the bad step: its delivered
            # prefix is still a prefix of the true sequence
            n = len(got[uid])
            assert n < len(want[uid])
            np.testing.assert_array_equal(got[uid], want[uid][:n])
        else:
            np.testing.assert_array_equal(got[uid], want[uid],
                                          err_msg=f"uid {uid}")


def test_oom_slow_malformed_are_absorbed(qwen_smoke_cfg,
                                         qwen_smoke_params):
    """Allocator exhaustion stalls admission (requests wait, none lost),
    a slow dispatch just costs wall clock, and a hostile mid-trace
    request lands in rejection telemetry — every real request finishes
    token-exact."""
    cfg, params = qwen_smoke_cfg, qwen_smoke_params
    reqs = _mixed_requests(cfg, [(4, 7), (6, 5), (5, 6), (7, 4), (3, 5),
                                 (8, 6)], seed0=40)
    want = _sequential_baseline(cfg, params, reqs)
    engine = ContinuousBatchingEngine(
        cfg, params, capacity=2, max_len=MAX_LEN, prefill_bucket=4, k=4,
        faults=FaultPlan.parse("oom@1:1,slow@2:0.01,malformed@3"))
    got = engine.run(reqs)
    assert engine.n_faults_injected == 3
    for uid in want:
        np.testing.assert_array_equal(got[uid], want[uid],
                                      err_msg=f"uid {uid}")
    # the injected hostile request was rejected, not served and not fatal
    assert any(uid < 0 for uid in engine.rejected)
    assert all("empty prompt" in why for uid, why in
               engine.rejected.items() if uid < 0)


# ----------------------------------------------------------------- deadlines
def test_deadline_evicts_hung_requests(qwen_smoke_cfg, qwen_smoke_params):
    """A hang longer than the deadline: the watchdog expires every
    over-deadline request at the next step boundary, delivering the
    partial output instead of blocking forever."""
    cfg, params = qwen_smoke_cfg, qwen_smoke_params
    reqs = _mixed_requests(cfg, [(4, 20), (6, 20), (5, 20)], seed0=60)
    engine = ContinuousBatchingEngine(
        cfg, params, capacity=2, max_len=MAX_LEN, prefill_bucket=4, k=2,
        deadline=0.12, faults=FaultPlan([Fault("hang", 2, duration=0.4)]))
    t0 = time.monotonic()
    got = engine.run(reqs)
    assert engine.n_expired == 3
    assert all(o == "expired" for o in engine.outcomes.values())
    assert set(got) == {0, 1, 2}  # partial outputs still delivered
    assert time.monotonic() - t0 < 5.0  # bounded, not 20-token serving


def test_per_request_deadline_overrides_engine_default(qwen_smoke_cfg,
                                                       qwen_smoke_params):
    cfg, params = qwen_smoke_cfg, qwen_smoke_params
    reqs = _mixed_requests(cfg, [(4, 12), (6, 4)], seed0=70)
    reqs[0].deadline = 0.05  # tighter than the engine's default
    engine = ContinuousBatchingEngine(
        cfg, params, capacity=2, max_len=MAX_LEN, prefill_bucket=4, k=2,
        deadline=60.0, faults=FaultPlan([Fault("slow", 2, duration=0.1)]))
    engine.run(reqs)
    assert engine.outcomes[0] == "expired"
    assert engine.outcomes[1] == "finished"


def test_shed_by_queue_age(qwen_smoke_cfg, qwen_smoke_params):
    """Load shedding: with the engine stuck behind a slow dispatch,
    waiting requests older than ``shed_age`` are shed (telemetered,
    uid freed) instead of accumulating into an unbounded backlog."""
    cfg, params = qwen_smoke_cfg, qwen_smoke_params
    reqs = _mixed_requests(cfg, [(4, 6)] * 6, seed0=80)
    engine = ContinuousBatchingEngine(
        cfg, params, capacity=1, max_len=MAX_LEN, prefill_bucket=4, k=2,
        shed_age=0.05, faults=FaultPlan([Fault("slow", 1, duration=0.2)]))
    engine.run(reqs)
    assert engine.n_shed > 0
    shed = [u for u, o in engine.outcomes.items() if o == "shed"]
    assert shed and all(u in engine.rejected for u in shed)
    # shed uids are freed for resubmission (client may retry)
    assert all(u not in engine._seen_uids for u in shed)


# ----------------------------------------------------------- degradation
def test_draft_nan_falls_back_to_plain_decode(qwen_smoke_cfg,
                                              qwen_smoke_params):
    """A draft-pool NaN must not cost one token of output: the engine
    demotes to the plain target-only macro loop (greedy tokens are the
    target's argmax either way) and stays demoted."""
    cfg, params = qwen_smoke_cfg, qwen_smoke_params

    def perturbed(p, k):
        return p + 3e-3 * jax.random.normal(k, p.shape, p.dtype)

    keys = jax.random.split(jax.random.PRNGKey(1),
                            len(jax.tree.leaves(params)))
    flat, treedef = jax.tree.flatten(params)
    params_d = jax.tree.unflatten(
        treedef, [perturbed(p, k) for p, k in zip(flat, keys)])
    reqs = _mixed_requests(cfg, [(4, 8), (6, 6), (5, 7), (7, 5)],
                           seed0=90)
    want = _sequential_baseline(cfg, params, reqs)
    engine = ContinuousBatchingEngine(
        cfg, params, capacity=2, max_len=MAX_LEN, prefill_bucket=4, k=2,
        speculative=SpeculativeConfig(cfg, params_d, d=2),
        faults=FaultPlan([Fault("nan", 2, slot=0, pool=1)]))
    got = engine.run(reqs)
    assert engine.n_spec_fallbacks == 1 and engine._spec_fallback
    assert engine.n_quarantined == 0  # the TARGET rows were never bad
    for uid in want:
        np.testing.assert_array_equal(got[uid], want[uid],
                                      err_msg=f"uid {uid}")


def test_paged_arena_degrades_after_quarantine(qwen_smoke_cfg,
                                               qwen_smoke_params):
    """A NaN in a paged arena may sit in prefix pages other requests
    would share, so quarantine also flushes the prefix registry and
    degrades admissions to dense-style full reservation — correctness
    over memory efficiency until a restart."""
    cfg, params = qwen_smoke_cfg, qwen_smoke_params
    reqs = _mixed_requests(cfg, [(4, 8), (6, 6), (5, 7), (7, 5), (4, 6),
                                 (6, 5)], seed0=100)
    want = _sequential_baseline(cfg, params, reqs)
    engine = ContinuousBatchingEngine(
        cfg, params, capacity=2, max_len=MAX_LEN, prefill_bucket=4, k=4,
        pool="paged", faults=FaultPlan([Fault("nan", 2, slot=0)]))
    got = engine.run(reqs)
    assert engine.n_quarantined == 1 and engine._arena_degraded
    assert engine.n_degraded_admissions > 0
    bad = [u for u, o in engine.outcomes.items() if o == "quarantined"]
    for uid in want:
        if uid not in bad:
            np.testing.assert_array_equal(got[uid], want[uid],
                                          err_msg=f"uid {uid}")


@pytest.mark.slow
def test_seeded_chaos_survivors_token_exact(qwen_smoke_cfg,
                                            qwen_smoke_params):
    """Chaos sweep: seeded random fault schedules (no crash — that mode
    is the recovery suite's) against a mixed trace.  Whatever the plan
    does, every request that finishes normally is token-exact and every
    request is accounted for in outcomes."""
    cfg, params = qwen_smoke_cfg, qwen_smoke_params
    kinds = ("nan", "oom", "slow", "malformed")
    reqs = _mixed_requests(cfg, [(4, 8), (6, 6), (5, 9), (7, 5), (3, 7),
                                 (8, 6), (5, 5), (6, 8)], seed0=110)
    want = _sequential_baseline(cfg, params, reqs)
    for seed in range(4):
        plan = FaultPlan.seeded(seed, 12, kinds=kinds, n_faults=3,
                                slow_s=0.01)
        engine = ContinuousBatchingEngine(
            cfg, params, capacity=3, max_len=MAX_LEN, prefill_bucket=4,
            k=4, faults=plan)
        got = engine.run([Request(uid=r.uid, prompt=r.prompt,
                                  max_new_tokens=r.max_new_tokens)
                          for r in reqs])
        assert engine.n_faults_injected == 3, seed
        for r in reqs:
            o = engine.outcomes.get(r.uid)
            assert o in ("finished", "quarantined"), (seed, r.uid, o)
            if o == "finished":
                np.testing.assert_array_equal(
                    got[r.uid], want[r.uid],
                    err_msg=f"seed {seed} uid {r.uid}")
