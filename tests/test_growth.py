"""Growth-operator correctness: packing inverses, contraction oracle,
structured-init preservation, method complexity ordering (paper Table 1),
and hypothesis property tests on the TR-MPO algebra."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.configs.base import ModelConfig
from repro.core import baselines, grow as growlib, mango, packing
from repro.models import get_family

CFG_S = ModelConfig(name="s", n_layers=2, d_model=32, n_heads=4,
                    n_kv_heads=2, d_ff=64, vocab_size=97)
CFG_T = ModelConfig(name="t", n_layers=4, d_model=64, n_heads=8,
                    n_kv_heads=4, d_ff=128, vocab_size=97)


def _params(cfg, seed=0):
    return get_family(cfg).init(jax.random.PRNGKey(seed), cfg)


def test_pack_unpack_roundtrip():
    """unpack(pack(params)) == params when D2==D1, L2==L1, identity op."""
    params = _params(CFG_S)
    shapes = jax.eval_shape(lambda: _params(CFG_S))
    plan = packing.build_plan(CFG_S, shapes)
    g = plan.groups[0]
    M = packing.pack_group(g, params["dense_blocks"], CFG_S.d_model)
    assert M.shape[0] == len(g.slots)
    out = packing.unpack_group(g, M, shapes["dense_blocks"], CFG_S.d_model)
    for path, val in out.items():
        ref = packing._get(params["dense_blocks"], path)
        np.testing.assert_allclose(np.asarray(val, np.float32),
                                   np.asarray(ref, np.float32), atol=1e-6)


def test_contract_matches_full_mapping():
    op = mango.build_operator(CFG_S, CFG_T, rank=2)
    dims = op.dims("dense_blocks")
    cores = mango.init_cores(jax.random.PRNGKey(0), dims, 2, noise=0.05)
    M1 = jax.random.normal(jax.random.PRNGKey(1),
                           (dims["B1"], dims["I1"], dims["O1"], dims["L1"]))
    np.testing.assert_allclose(
        np.asarray(mango.contract(M1, cores)),
        np.asarray(mango.contract_reference(M1, cores)),
        rtol=3e-5, atol=3e-5)


def test_structured_init_is_net2net_like():
    """With noise=0, Mango's structured cores reproduce the bert2BERT-style
    expansion exactly (S_B=I, S_I=split, S_O=dup, S_L=layer-copy)."""
    op = mango.build_operator(CFG_S, CFG_T, rank=1)
    p_mango = mango.init_operator_params(jax.random.PRNGKey(0), op, noise=0.0)
    p_b2b = baselines.init_bert2bert_params(op, aki=False)
    small = _params(CFG_S)
    big_m = mango.grow(op, p_mango, small)
    big_b = mango.grow(op, p_b2b, small)
    for (pa, a), (pb, b) in zip(
            jax.tree_util.tree_flatten_with_path(big_m)[0],
            jax.tree_util.tree_flatten_with_path(big_b)[0]):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), atol=1e-5,
                                   err_msg=str(pa))


def test_net2net_width_function_preservation():
    """Width-only growth of the MLP path preserves function closely."""
    cfg_t = CFG_S.replace(name="w", d_model=64, n_heads=4, n_kv_heads=2,
                          d_ff=128)
    gop, op_params = growlib.build("net2net", CFG_S, cfg_t)
    small = _params(CFG_S)
    big = growlib.grow_params(gop, op_params, small)
    fam = get_family(CFG_S)
    toks = jax.random.randint(jax.random.PRNGKey(2), (2, 16), 0, 97)
    lo_s, _ = fam.forward(small, {"tokens": toks}, CFG_S)
    lo_b, _ = fam.forward(big, {"tokens": toks}, cfg_t)
    # logits need not match exactly (attention scale, rms over duped dims),
    # but rank correlation of predictions should be near-perfect
    ps = np.asarray(jax.nn.softmax(lo_s[:, -1]), np.float32)
    pb = np.asarray(jax.nn.softmax(lo_b[:, -1]), np.float32)
    corr = np.corrcoef(ps.ravel(), pb.ravel())[0, 1]
    assert corr > 0.9, corr


def test_operator_param_counts_table1():
    """TR-MPO core count R^2*(B1B2 + O1O2 + L1L2 + I1I2) + width matrix;
    at rank 1 this reduces to the paper's Table-1 form
    2*D1*D2 + (B1B2 + L1L2).  LiGO < Mango(rank 3); frozen methods have
    zero trainable params."""
    for rank in (1, 3):
        gop, p = growlib.build("mango", CFG_S, CFG_T, rank=rank)
        n = growlib.operator_param_count(gop, p)
        dims = gop.op.dims("dense_blocks")
        expected = rank * rank * (
            dims["B1"] * dims["B2"] + dims["L1"] * dims["L2"]
            + dims["I1"] * dims["I2"] + dims["O1"] * dims["O2"]) \
            + CFG_S.d_model * CFG_T.d_model  # + shared width matrix
        assert n == expected, (rank, n, expected)
    gop_l, p_l = growlib.build("ligo", CFG_S, CFG_T)
    n_ligo = growlib.operator_param_count(gop_l, p_l)
    gop_m1, p_m1 = growlib.build("mango", CFG_S, CFG_T, rank=1)
    assert n_ligo < growlib.operator_param_count(
        *(growlib.build("mango", CFG_S, CFG_T, rank=3)))
    for frozen in ("bert2bert", "net2net", "stackbert"):
        cfg_t = CFG_S.replace(name="d", n_layers=4) \
            if frozen == "stackbert" else CFG_T
        gop_f, p_f = growlib.build(frozen, CFG_S, cfg_t)
        assert growlib.operator_param_count(gop_f, p_f) == 0


def test_grow_is_differentiable():
    gop, op_params = growlib.build("mango", CFG_S, CFG_T, rank=1)
    small = _params(CFG_S)
    toks = jax.random.randint(jax.random.PRNGKey(3), (2, 16), 0, 97)
    fam = get_family(CFG_T)

    def loss(p):
        big = growlib.grow_params(gop, p, small)
        logits, _ = fam.forward(big, {"tokens": toks}, CFG_T)
        return jnp.mean(jnp.square(logits.astype(jnp.float32)))

    g = jax.grad(loss)(op_params)
    gnorm = sum(float(jnp.sum(jnp.abs(x)))
                for x in jax.tree.leaves(g["groups"]))
    assert np.isfinite(gnorm) and gnorm > 0


# --------------------------------------------------------- property tests
@settings(max_examples=20, deadline=None)
@given(
    b1=st.integers(2, 5), l1=st.integers(1, 3), i1=st.integers(2, 6),
    o1=st.integers(2, 6), rank=st.integers(1, 3), scale=st.floats(0.5, 2.0),
)
def test_contract_linearity_property(b1, l1, i1, o1, rank, scale):
    """The growth map is linear in M1: Φ(aM) = aΦ(M); Φ(M+N) = Φ(M)+Φ(N)."""
    dims = {"B1": b1, "B2": b1 + 1, "I1": i1, "I2": i1 + 2,
            "O1": o1, "O2": o1 + 1, "L1": l1, "L2": l1 + 1}
    cores = mango.init_cores(jax.random.PRNGKey(0), dims, rank, noise=0.1)
    key = jax.random.PRNGKey(b1 * 100 + o1)
    M = jax.random.normal(key, (b1, i1, o1, l1))
    N = jax.random.normal(jax.random.PRNGKey(7), (b1, i1, o1, l1))
    a = jnp.float32(scale)
    np.testing.assert_allclose(
        np.asarray(mango.contract(a * M, cores)),
        np.asarray(a * mango.contract(M, cores)), rtol=2e-4, atol=1e-4)
    np.testing.assert_allclose(
        np.asarray(mango.contract(M + N, cores)),
        np.asarray(mango.contract(M, cores)
                   + mango.contract(N, cores)), rtol=2e-4, atol=1e-4)


@settings(max_examples=10, deadline=None)
@given(d1=st.sampled_from([16, 32]), mult=st.integers(1, 3))
def test_width_expand_preserves_rowspace(d1, mult):
    """Split/dup width maps compose to identity: dup @ split^T == I."""
    d2 = d1 * mult
    split = mango.width_expand_matrix(d1, d2, normalized=True)
    dup = mango.width_expand_matrix(d1, d2, normalized=False)
    np.testing.assert_allclose(np.asarray(dup @ split.T), np.eye(d1),
                               atol=1e-6)
