"""Kernel-backed slot decode: token-exactness of the Pallas serving path.

``cfg.decode_kernel`` swaps the slot-decode / chunk-verify attention from
the pure-jnp model path to the Pallas kernel family (interpret mode on
this CPU container).  The contract: greedy engine tokens are EXACTLY the
jnp path's tokens — which are themselves exactly the sequential
``generate()`` tokens — for every slot cache layout:

  * full KV          (transformer dense/GQA),
  * ring-buffer window (sliding-window transformer, wraps included),
  * recurrent + ring (griffin's local-attention blocks),
  * speculative chunk-verify (draft proposals, target verify, commit).

Configs are kept micro: every decode step in interpret mode emulates the
kernel per layer, so these tests budget their traces tightly.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig
from repro.data.synthetic import lm_batch
from repro.launch.serve import generate
from repro.models import get_family
from repro.serve import ContinuousBatchingEngine, Request, SpeculativeConfig

MAX_LEN = 32
KMODE = "interpret"


def tiny_cfg(**kw):
    base = dict(name="kern-serve", n_layers=2, d_model=48, n_heads=4,
                n_kv_heads=2, d_ff=96, vocab_size=97, attn_chunk=8)
    base.update(kw)
    return ModelConfig(**base)


def griffin_cfg():
    # window (6) below MAX_LEN so the local-attention rings really wrap
    return ModelConfig(name="kern-griffin", family="griffin", n_layers=3,
                       d_model=48, n_heads=4, n_kv_heads=1, d_ff=96,
                       vocab_size=97, lru_width=48, window=6, act="geglu",
                       attn_chunk=8, scale_embeddings=True,
                       block_pattern=("rec", "rec", "attn"))


def _params(cfg):
    return get_family(cfg).init(jax.random.PRNGKey(0), cfg)


def _requests(cfg, specs, *, seed0=50, eos=None):
    reqs = [Request(uid=i,
                    prompt=lm_batch(cfg.vocab_size, 1, p, seed=seed0 + i)[0],
                    max_new_tokens=g)
            for i, (p, g) in enumerate(specs)]
    if eos is not None:
        reqs[0].eos_id = eos
    return reqs


def _run(cfg, params, specs, *, k, capacity=2, speculative=None, eos=None):
    engine = ContinuousBatchingEngine(cfg, params, capacity=capacity,
                                      max_len=MAX_LEN, prefill_bucket=4,
                                      k=k, speculative=speculative)
    return engine.run(_requests(cfg, specs, eos=eos))


def _assert_same(a, b, tag):
    assert set(a) == set(b)
    for uid in a:
        np.testing.assert_array_equal(a[uid], b[uid],
                                      err_msg=f"{tag} uid {uid}")


@pytest.mark.parametrize("k", [1, 8])
def test_full_kv_kernel_token_exact(k):
    """Kernel-backed full-KV slot decode == jnp slot decode == sequential
    generate(), through admission bucketing, slot reuse, and macro
    stepping at K in {1, 8}."""
    cfg = tiny_cfg()
    params = _params(cfg)
    specs = [(3, 6), (9, 2), (5, 8)]
    jnp_out = _run(cfg, params, specs, k=k)
    ker_out = _run(cfg.replace(decode_kernel=KMODE), params, specs, k=k)
    _assert_same(ker_out, jnp_out, f"full k={k}")
    seq = {r.uid: np.asarray(generate(
        cfg, params, jnp.asarray(r.prompt)[None],
        max_new_tokens=r.max_new_tokens, max_len=MAX_LEN)[0])
        for r in _requests(cfg, specs)}
    _assert_same(ker_out, seq, f"full-vs-seq k={k}")


def test_full_kv_kernel_done_rows_freeze_mid_block():
    """An eos inside a macro block: the kernel path's done rows take the
    kv_len == 0 short-circuit as exact no-ops and the neighbour's tokens
    stay exact (mirrors test_eos_mid_block on the jnp path)."""
    cfg = tiny_cfg(name="kern-eos", learned_pos=64, rope="none",
                   tie_embeddings=True)
    params = _params(cfg)
    specs = [(6, 12), (8, 12)]
    base = _run(cfg, params, specs, k=4)
    # first request's 3rd token as its eos: fires strictly inside a block
    eos = int(base[0][2])
    jnp_out = _run(cfg, params, specs, k=4, eos=eos)
    ker_out = _run(cfg.replace(decode_kernel=KMODE), params, specs, k=4,
                   eos=eos)
    _assert_same(ker_out, jnp_out, "eos-mid-block")
    assert len(ker_out[0]) < len(base[0])  # really stopped early


@pytest.mark.parametrize("k", [1, 8])
def test_ring_window_kernel_token_exact(k):
    """Kernel-backed ring-window slot decode (band mask reconstructed
    from the ring invariant in-kernel) == jnp path, across ring wraps."""
    cfg = tiny_cfg(name="kern-win", window=8)
    params = _params(cfg)
    specs = [(3, 12), (10, 8), (6, 14)]  # well past the window: wraps
    jnp_out = _run(cfg, params, specs, k=k, capacity=3)
    ker_out = _run(cfg.replace(decode_kernel=KMODE), params, specs, k=k,
                   capacity=3)
    _assert_same(ker_out, jnp_out, f"ring k={k}")


def test_griffin_ring_kernel_token_exact():
    """Griffin's local-attention blocks route their ring slot decode
    through the same kernel switch (recurrent state stays jnp)."""
    cfg = griffin_cfg()
    params = _params(cfg)
    specs = [(3, 8), (9, 4), (5, 10)]
    jnp_out = _run(cfg, params, specs, k=4)
    ker_out = _run(cfg.replace(decode_kernel=KMODE), params, specs, k=4)
    _assert_same(ker_out, jnp_out, "griffin")


@pytest.mark.parametrize("d", [1, 3])
def test_chunk_verify_kernel_token_exact(d):
    """Speculative serving with the kernel backend: the draft's slot
    decode, the target's chunk verify, and both commits produce exactly
    the jnp engine's tokens (the engine aligns the draft cfg's switch to
    the target's automatically)."""
    cfg = tiny_cfg()
    params = _params(cfg)
    specs = [(3, 8), (6, 6)]
    spec = SpeculativeConfig(cfg, params, d=d)
    jnp_out = _run(cfg, params, specs, k=2, speculative=spec)
    ker_out = _run(cfg.replace(decode_kernel=KMODE), params, specs, k=2,
                   speculative=SpeculativeConfig(cfg, params, d=d))
    _assert_same(ker_out, jnp_out, f"spec d={d}")


def test_chunk_verify_kernel_ring_window():
    """Speculative chunk-verify over a WRAPPING ring-buffer window cache:
    the kernel's ring reconstruction at per-row committed lengths matches
    the jnp path token for token."""
    cfg = tiny_cfg(name="kern-win-spec", window=8)
    params = _params(cfg)
    specs = [(3, 12), (6, 10)]  # beyond the window: verify spans wraps
    spec = SpeculativeConfig(cfg, params, d=2)
    jnp_out = _run(cfg, params, specs, k=2, speculative=spec)
    ker_out = _run(cfg.replace(decode_kernel=KMODE), params, specs, k=2,
                   speculative=SpeculativeConfig(cfg, params, d=2))
    _assert_same(ker_out, jnp_out, "spec-ring")


def test_odd_and_prime_max_len_kernel_serves():
    """Regression for the ``_pick_bk`` failure class: an odd max_len
    (pool pads to a block multiple) serves through the kernel path, and
    padded prime lengths > 256 always have a block."""
    cfg = tiny_cfg(name="kern-odd").replace(decode_kernel=KMODE)
    params = _params(cfg)
    engine = ContinuousBatchingEngine(cfg, params, capacity=2, max_len=29,
                                      prefill_bucket=4, k=4)
    kleaf = engine.pool["dense"]["k"]
    assert kleaf.shape[2] == 32  # 29 padded to the sublane quantum
    reqs = _requests(cfg, [(3, 5), (7, 4)])
    got = engine.run(reqs)
    want = {r.uid: np.asarray(generate(
        cfg.replace(decode_kernel="jnp"), params,
        jnp.asarray(r.prompt)[None], max_new_tokens=r.max_new_tokens,
        max_len=29)[0]) for r in reqs}
    _assert_same(got, want, "odd-max-len")


def test_reference_mode_matches_jnp_engine():
    """mode="reference" (the kernels/ref.py oracles) is a third
    independent implementation of the slot path — its engine tokens must
    match the jnp engine's too."""
    cfg = tiny_cfg(name="kern-refmode", window=8)
    params = _params(cfg)
    specs = [(3, 10), (6, 8)]
    jnp_out = _run(cfg, params, specs, k=4)
    ref_out = _run(cfg.replace(decode_kernel="reference"), params, specs,
                   k=4)
    _assert_same(ref_out, jnp_out, "reference")
