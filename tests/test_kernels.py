"""Per-kernel allclose sweeps: Pallas (interpret mode) vs jnp oracles.

Shapes/dtypes swept per kernel per the deliverable; block sizes kept small
so the CPU interpreter stays fast.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 \
        else dict(rtol=2e-4, atol=2e-4)


def _p(*vals, slow=False):
    """One representative case per kernel runs in the default tier; the
    full interpret-mode sweep stays available under ``-m slow``."""
    return pytest.param(*vals, marks=pytest.mark.slow) if slow \
        else pytest.param(*vals)


@pytest.mark.parametrize("n,d1,d2", [_p(2, 128, 128, slow=True),
                                     _p(3, 256, 128),
                                     _p(1, 128, 384, slow=True)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_tr_sandwich(n, d1, d2, dtype):
    kx, ki, ko = jax.random.split(jax.random.PRNGKey(0), 3)
    x = jax.random.normal(kx, (n, d1, d1), dtype)
    a_i = (0.05 * jax.random.normal(ki, (d1, d2))).astype(dtype)
    a_o = (0.05 * jax.random.normal(ko, (d1, d2))).astype(dtype)
    y = ops.tr_sandwich(x, a_i, a_o, mode="interpret", ti=128, to=128,
                        tk=128)
    yr = ref.tr_sandwich_ref(x, a_i, a_o)
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(yr, np.float32), **_tol(dtype))


@pytest.mark.parametrize("b,h,kv,s,hd", [_p(1, 4, 4, 256, 64, slow=True),
                                         _p(2, 4, 2, 256, 64),
                                         _p(1, 8, 1, 128, 128, slow=True)])
@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention(b, h, kv, s, hd, causal, dtype):
    keys = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(keys[0], (b, h, s, hd), dtype)
    k = jax.random.normal(keys[1], (b, kv, s, hd), dtype)
    v = jax.random.normal(keys[2], (b, kv, s, hd), dtype)
    o = ops.flash_attention(q, k, v, causal=causal, mode="interpret",
                            bq=128, bk=128)
    orf = ref.flash_attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(o, np.float32),
                               np.asarray(orf, np.float32), **_tol(dtype))


@pytest.mark.parametrize("b,h,kv,s,hd,kvlen",
                         [_p(2, 8, 2, 512, 64, 300, slow=True),
                          _p(1, 4, 4, 256, 128, 256),
                          _p(2, 16, 1, 512, 64, 1, slow=True)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_decode_attention(b, h, kv, s, hd, kvlen, dtype):
    keys = jax.random.split(jax.random.PRNGKey(2), 3)
    q = jax.random.normal(keys[0], (b, h, hd), dtype)
    k = jax.random.normal(keys[1], (b, kv, s, hd), dtype)
    v = jax.random.normal(keys[2], (b, kv, s, hd), dtype)
    o = ops.decode_attention(q, k, v, kvlen, mode="interpret", bk=256)
    orf = ref.decode_attention_ref(q, k, v, kvlen)
    np.testing.assert_allclose(np.asarray(o, np.float32),
                               np.asarray(orf, np.float32), **_tol(dtype))


def test_decode_attention_per_row_lengths():
    """Vector kv_len (continuous batching): every row masks with its own
    length and matches the scalar-length kernel run row by row."""
    keys = jax.random.split(jax.random.PRNGKey(5), 3)
    b, h, kv, s, hd = 3, 4, 2, 256, 64
    q = jax.random.normal(keys[0], (b, h, hd))
    k = jax.random.normal(keys[1], (b, kv, s, hd))
    v = jax.random.normal(keys[2], (b, kv, s, hd))
    lens = jnp.asarray([1, 100, 256], jnp.int32)
    o = ops.decode_attention(q, k, v, lens, mode="interpret", bk=64)
    orf = ref.decode_attention_ref(q, k, v, lens)
    np.testing.assert_allclose(np.asarray(o), np.asarray(orf), rtol=2e-4,
                               atol=2e-4)
    for i in range(b):
        oi = ops.decode_attention(q[i:i + 1], k[i:i + 1], v[i:i + 1],
                                  int(lens[i]), mode="interpret", bk=64)
        np.testing.assert_allclose(np.asarray(o[i]), np.asarray(oi[0]),
                                   rtol=2e-5, atol=2e-5, err_msg=f"row {i}")
    # idle slots (kv_len == 0) return zeros in kernel and oracle alike
    zlens = jnp.asarray([0, 1, 256], jnp.int32)
    oz = ops.decode_attention(q, k, v, zlens, mode="interpret", bk=64)
    assert (np.asarray(oz[0]) == 0).all()
    np.testing.assert_allclose(
        np.asarray(oz), np.asarray(ref.decode_attention_ref(q, k, v, zlens)),
        rtol=2e-4, atol=2e-4)
    # the macro-step done vector takes the same short-circuit: done rows
    # are forced to kv_len 0 regardless of their nominal length
    done = jnp.asarray([True, False, True])
    od = ops.decode_attention(q, k, v, lens, done=done, mode="interpret",
                              bk=64)
    assert (np.asarray(od[0]) == 0).all() and (np.asarray(od[2]) == 0).all()
    np.testing.assert_allclose(np.asarray(od[1]), np.asarray(o[1]),
                               rtol=2e-5, atol=2e-5)


def test_decode_attention_auto_bk_short_cache():
    """bk=None picks the largest divisor of the cache length <= 256, so a
    short serve pool (e.g. the serving benchmark's max_len=48) runs the
    Pallas path instead of tripping the old ``S % 256 == 0`` assert."""
    from repro.kernels.decode_attention import _pick_bk
    assert _pick_bk(48) == 48
    assert _pick_bk(512) == 256
    assert _pick_bk(384) == 192
    assert _pick_bk(1) == 1
    with pytest.raises(ValueError, match="no block divisor"):
        _pick_bk(257)  # prime > 256: refuse a pathological 1-wide grid
    keys = jax.random.split(jax.random.PRNGKey(9), 3)
    b, h, kv, s, hd = 2, 4, 2, 48, 64
    q = jax.random.normal(keys[0], (b, h, hd))
    k = jax.random.normal(keys[1], (b, kv, s, hd))
    v = jax.random.normal(keys[2], (b, kv, s, hd))
    lens = jnp.asarray([5, 48], jnp.int32)
    o = ops.decode_attention(q, k, v, lens, mode="interpret")  # bk auto
    orf = ref.decode_attention_ref(q, k, v, lens)
    np.testing.assert_allclose(np.asarray(o), np.asarray(orf), rtol=2e-4,
                               atol=2e-4)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_slot_decode_attention_pool_layout(dtype):
    """The pool-layout kernel (k/v as (B, S, KV, hd) — the serve engine's
    slot pool, no transpose on the hot path) matches both its own oracle
    and the head-major kernel on transposed operands."""
    keys = jax.random.split(jax.random.PRNGKey(11), 3)
    b, h, kv, s, hd = 3, 4, 2, 40, 32
    q = jax.random.normal(keys[0], (b, h, hd), dtype)
    k = jax.random.normal(keys[1], (b, s, kv, hd), dtype)
    v = jax.random.normal(keys[2], (b, s, kv, hd), dtype)
    lens = jnp.asarray([0, 7, 40], jnp.int32)
    o = ops.slot_decode_attention(q, k, v, lens, mode="interpret")
    orf = ref.slot_decode_attention_ref(q, k, v, lens)
    np.testing.assert_allclose(np.asarray(o, np.float32),
                               np.asarray(orf, np.float32), **_tol(dtype))
    assert (np.asarray(o[0], np.float32) == 0).all()  # idle row
    ot = ops.decode_attention(q, k.transpose(0, 2, 1, 3),
                              v.transpose(0, 2, 1, 3), lens,
                              mode="interpret")
    np.testing.assert_allclose(np.asarray(o, np.float32),
                               np.asarray(ot, np.float32), rtol=2e-5,
                               atol=2e-5)
    # done folds to kv_len = 0
    od = ops.slot_decode_attention(q, k, v, lens,
                                   done=jnp.asarray([False, True, False]),
                                   mode="interpret")
    assert (np.asarray(od[1], np.float32) == 0).all()


@pytest.mark.parametrize("positions", [[3, 9, 0], [15, 40, 101]])
def test_ring_decode_attention(positions):
    """Ring kernel vs oracle vs the model's jnp ``ring_slot_attend``:
    pre-wrap, exactly-at-ring, and far-beyond-wrap positions; done rows
    exact-zero."""
    from repro.models.attention import ring_slot_attend

    keys = jax.random.split(jax.random.PRNGKey(12), 3)
    b, h, kv, ring, hd, window = 3, 4, 2, 16, 32, 10
    q = jax.random.normal(keys[0], (b, h, hd))
    k = jax.random.normal(keys[1], (b, ring, kv, hd))
    v = jax.random.normal(keys[2], (b, ring, kv, hd))
    pos = jnp.asarray(positions, jnp.int32)
    o = ops.ring_decode_attention(q, k, v, pos, window=window,
                                  mode="interpret")
    orf = ref.ring_decode_attention_ref(q, k, v, pos, window=window)
    np.testing.assert_allclose(np.asarray(o), np.asarray(orf), rtol=2e-4,
                               atol=2e-4)
    om = ring_slot_attend(q[:, None], k, v, pos, window=window)[:, 0]
    np.testing.assert_allclose(np.asarray(o), np.asarray(om), rtol=2e-4,
                               atol=2e-4)
    done = jnp.asarray([True, False, True])
    od = ops.ring_decode_attention(q, k, v, pos, window=window, done=done,
                                   mode="interpret")
    assert (np.asarray(od[0]) == 0).all() and (np.asarray(od[2]) == 0).all()
    np.testing.assert_allclose(np.asarray(od[1]), np.asarray(o[1]),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("ring,window", [(False, None), (True, 10),
                                         (False, 10)])
def test_chunk_verify_attention(ring, window):
    """Chunk-verify kernel vs oracle vs the model's jnp
    ``chunk_verify_attend`` for full-prefix and ring-buffer caches; done
    rows exact-zero and the cache operands are read-only by contract."""
    from repro.models.attention import chunk_verify_attend

    keys = jax.random.split(jax.random.PRNGKey(13), 6)
    b, h, kv, sc, hd, s = 3, 4, 2, 24, 32, 3
    q = jax.random.normal(keys[0], (b, s, h, hd))
    ck = jax.random.normal(keys[1], (b, sc, kv, hd))
    cv = jax.random.normal(keys[2], (b, sc, kv, hd))
    k = jax.random.normal(keys[3], (b, s, kv, hd))
    v = jax.random.normal(keys[4], (b, s, kv, hd))
    off = jnp.asarray([1, 7, 20], jnp.int32)
    o = ops.chunk_verify_attention(q, ck, cv, k, v, off, ring=ring,
                                   window=window, mode="interpret")
    orf = ref.chunk_verify_attention_ref(q, ck, cv, k, v, off, ring=ring,
                                         window=window)
    np.testing.assert_allclose(np.asarray(o), np.asarray(orf), rtol=2e-4,
                               atol=2e-4)
    om = chunk_verify_attend(q, ck, cv, k, v, off, ring=ring, window=window)
    np.testing.assert_allclose(np.asarray(o), np.asarray(om), rtol=2e-4,
                               atol=2e-4)
    done = jnp.asarray([False, True, False])
    od = ops.chunk_verify_attention(q, ck, cv, k, v, off, ring=ring,
                                    window=window, done=done,
                                    mode="interpret")
    assert (np.asarray(od[1]) == 0).all()
    np.testing.assert_allclose(np.asarray(od[0]), np.asarray(o[0]),
                               rtol=2e-5, atol=2e-5)


def test_pad_cache_len_always_blockable():
    """The TPU-layout pool contract: a padded cache length always has a
    kernel block — including the prime/odd > 256 failure class that used
    to raise in ``_pick_bk``."""
    from repro.kernels.decode_attention import _pick_bk
    from repro.models.common import pad_cache_len
    for n in [1, 5, 8, 29, 47, 48, 127, 256, 257, 263, 514, 1021, 4111]:
        p = pad_cache_len(n)
        assert p >= n
        bk = _pick_bk(p)  # must not raise
        assert p % bk == 0
        if p > 256:
            assert bk >= 32
    # unpadded prime > 256 still refuses loudly (callers must pad)
    with pytest.raises(ValueError, match="no block divisor"):
        _pick_bk(257)


@pytest.mark.parametrize("b,s,w", [_p(2, 256, 256),
                                   _p(1, 128, 512, slow=True)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("with_h0", [True, False])
def test_rglru_scan(b, s, w, dtype, with_h0):
    keys = jax.random.split(jax.random.PRNGKey(3), 3)
    a = jax.nn.sigmoid(jax.random.normal(keys[0], (b, s, w))).astype(dtype)
    bb = (0.1 * jax.random.normal(keys[1], (b, s, w))).astype(dtype)
    h0 = jax.random.normal(keys[2], (b, w), jnp.float32) if with_h0 else None
    h = ops.rglru_scan(a, bb, h0, mode="interpret", bs=128, bw=256)
    hr = ref.rglru_scan_ref(a, bb, h0)
    np.testing.assert_allclose(np.asarray(h, np.float32),
                               np.asarray(hr, np.float32),
                               rtol=5e-2 if dtype == jnp.bfloat16 else 1e-4,
                               atol=5e-2 if dtype == jnp.bfloat16 else 1e-4)


def test_flash_matches_model_attention():
    """Kernel agrees with the model's chunked-jnp attention path."""
    from repro.models.attention import attention

    keys = jax.random.split(jax.random.PRNGKey(4), 3)
    B, H, KV, S, hd = 2, 4, 2, 256, 64
    q = jax.random.normal(keys[0], (B, S, H, hd))
    k = jax.random.normal(keys[1], (B, S, KV, hd))
    v = jax.random.normal(keys[2], (B, S, KV, hd))
    o_model = attention(q, k, v, causal=True, chunk_q=64)
    o_kernel = ops.flash_attention(
        q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
        v.transpose(0, 2, 1, 3), causal=True, mode="interpret",
        bq=128, bk=128).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(o_model), np.asarray(o_kernel),
                               rtol=2e-4, atol=2e-4)
