"""Lazy-sync (manual ZeRO-3) step must match the pjit-automatic step."""
import os
import subprocess
import sys
import textwrap

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


@pytest.mark.slow
def test_lazy_sync_matches_baseline():
    code = """
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs.base import get_config
        from repro.models import get_family
        from repro.optim import OptimizerConfig, make_optimizer
        from repro.train.steps import make_train_step
        from repro.train.lazy_sync import make_lazy_sync_train_step
        from repro.distributed.sharding import (params_shardings,
            sharding_rules_for_mesh, use_rules)
        from repro.data.synthetic import lm_batch

        cfg = get_config("qwen3-0.6b-smoke")
        fam = get_family(cfg)
        params = fam.init(jax.random.PRNGKey(0), cfg)
        opt_cfg = OptimizerConfig(lr=1e-3, clip_norm=None,
                                  master_weights=False)
        init_fn, _ = make_optimizer(opt_cfg)
        batch = {"tokens": jnp.asarray(lm_batch(cfg.vocab_size, 16, 32))}

        from repro.launch.mesh import make_mesh_compat
        mesh = make_mesh_compat((4, 2), ("data", "model"))
        rules = sharding_rules_for_mesh(mesh, fsdp=True)
        p_sh = params_shardings(fam.param_specs(cfg), mesh, rules,
                                shapes=params)
        params_s = jax.device_put(params, p_sh)

        base = make_train_step(cfg, opt_cfg, n_microbatches=4)
        with mesh, use_rules(mesh, rules):
            p1, o1, m1 = jax.jit(base)(params_s, init_fn(params_s), batch,
                                       jnp.int32(1))

        lazy = make_lazy_sync_train_step(cfg, opt_cfg, mesh, p_sh,
                                         n_microbatches=4)
        with mesh, use_rules(mesh, rules):
            p2, o2, m2 = jax.jit(lazy)(params_s, init_fn(params_s), batch,
                                       jnp.int32(1))
        a, b = float(m1["loss"]), float(m2["loss"])
        assert abs(a - b) < 2e-3, (a, b)
        d = max(float(jnp.max(jnp.abs(x.astype(jnp.float32)
                                      - y.astype(jnp.float32))))
                for x, y in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)))
        assert d < 2e-3, d
        print("LAZY-MATCH", a, b, d)
    """
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = SRC
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env,
                         timeout=560)
    assert out.returncode == 0, out.stderr[-4000:]
    assert "LAZY-MATCH" in out.stdout
