"""Live-growth hot swap: grow Mango weights behind a serving engine and
flip them in with zero dropped requests.

The contract under test (ISSUE 9 acceptance):

  * every request that is mid-flight at the swap continues
    TOKEN-EXACTLY — its committed prefix is exactly what a source-only
    run produces, and its post-swap suffix is exactly what the grown
    target produces on (original prompt ‖ committed prefix);
  * nothing is dropped or rejected by the swap, for dense AND paged
    pools, and for a non-transformer (recurrent-state) family;
  * submits that arrive during the quiesce window are held, then
    admitted — never refused;
  * a doomed upgrade fails with a named ``UpgradeError`` before any
    growth FLOP, and a growth failure leaves the engine serving the
    source model;
  * a pre-swap ``snapshot_engine`` cannot silently restore into a
    post-swap geometry — ``restore_engine`` names the offending group.
"""
import jax
import numpy as np
import pytest

from repro.checkpoint.manager import CheckpointShapeError
from repro.configs.base import get_config
from repro.core.grow import grow_from_source
from repro.data.synthetic import lm_batch
from repro.launch.serve import generate
from repro.models import get_family
from repro.serve import (
    ContinuousBatchingEngine,
    Request,
    UpgradeError,
    UpgradeManager,
    restore_engine,
    snapshot_engine,
)

MAX_LEN = 32


def _requests(cfg, specs, *, uid0=0, seed0=70):
    reqs = []
    for i, (plen, gen) in enumerate(specs):
        prompt = lm_batch(cfg.vocab_size, 1, plen, seed=seed0 + i)[0]
        reqs.append(Request(uid=uid0 + i, prompt=prompt,
                            max_new_tokens=gen))
    return reqs


@pytest.fixture(scope="module")
def gpt_pair(gpt_micro_cfg, gpt_micro_big_cfg):
    """(cfg_src, params_src, cfg_tgt, grown_params) — growth precomputed
    once so every swap test pays zero grow time."""
    params_src = get_family(gpt_micro_cfg).init(
        jax.random.PRNGKey(0), gpt_micro_cfg)
    grown = grow_from_source(gpt_micro_cfg, gpt_micro_big_cfg,
                             params_src=params_src, noise=0.0,
                             log_fn=lambda *a, **k: None)
    return gpt_micro_cfg, params_src, gpt_micro_big_cfg, grown


@pytest.fixture(scope="module")
def griffin_pair():
    cfg_src = get_config("griffin-micro")
    cfg_tgt = get_config("griffin-micro-big")
    params_src = get_family(cfg_src).init(jax.random.PRNGKey(0), cfg_src)
    grown = grow_from_source(cfg_src, cfg_tgt, params_src=params_src,
                             noise=0.0, log_fn=lambda *a, **k: None)
    return cfg_src, params_src, cfg_tgt, grown


def _swap_run(pair, reqs, *, upgrade_at=2, pool="dense", k=2, capacity=3,
              speculate_after="auto", prewarm=False, **eng_kw):
    """Serve ``reqs`` through a mid-trace hot swap (growth pre-done so the
    swap point is deterministic).  Returns (engine, manager, outputs)."""
    cfg_src, params_src, cfg_tgt, grown = pair
    eng = ContinuousBatchingEngine(cfg_src, params_src, capacity=capacity,
                                   max_len=MAX_LEN, k=k, pool=pool,
                                   **eng_kw)
    mgr = UpgradeManager(eng, cfg_tgt, grown_params=grown,
                         upgrade_at=upgrade_at, prewarm=prewarm,
                         speculate_after=speculate_after)
    mgr.start(background=False)
    assert mgr.state == "ready"
    got = eng.run(reqs)
    return eng, mgr, got


def _assert_token_exact(pair, mgr, got, reqs):
    """Every mid-flight request split exactly at the swap: committed
    prefix == source-only run, post-swap suffix == grown-target run on
    (prompt ‖ committed)."""
    cfg_src, params_src, cfg_tgt, grown = pair
    by_uid = {r.uid: r for r in mgr.resumed_requests}
    assert set(by_uid) == {r.uid for r in reqs}, \
        "every request should have been mid-flight at the swap"
    for r in reqs:
        res = by_uid[r.uid]
        nc = res.n_committed
        assert 0 < nc < r.max_new_tokens
        orig = np.asarray(res.prompt[:len(res.prompt) - nc])
        committed = np.asarray(res.prompt[len(res.prompt) - nc:])
        np.testing.assert_array_equal(orig, np.asarray(r.prompt))
        out = np.asarray(got[r.uid])
        assert out.shape == (r.max_new_tokens,)
        want_pre = np.asarray(generate(
            cfg_src, params_src, orig[None], max_new_tokens=nc,
            max_len=MAX_LEN))[0]
        np.testing.assert_array_equal(
            out[:nc], want_pre, err_msg=f"uid {r.uid}: pre-swap prefix "
            f"diverged from the source-only run")
        np.testing.assert_array_equal(out[:nc], committed)
        want_post = np.asarray(generate(
            cfg_tgt, grown, np.asarray(res.prompt)[None],
            max_new_tokens=r.max_new_tokens - nc, max_len=MAX_LEN))[0]
        np.testing.assert_array_equal(
            out[nc:], want_post, err_msg=f"uid {r.uid}: post-swap suffix "
            f"diverged from the grown-target run")


@pytest.mark.parametrize("pool", ["dense", "paged"])
def test_hot_swap_token_exact(pool, gpt_pair):
    reqs = _requests(gpt_pair[0], [(5, 12), (8, 12), (11, 12)])
    eng, mgr, got = _swap_run(gpt_pair, reqs, pool=pool)
    assert mgr.state == "swapped"
    assert eng.cfg.name == gpt_pair[2].name
    assert eng.n_upgrades == 1
    assert mgr.pause_ms is not None and mgr.pause_ms >= 0
    assert eng.rejected == {}
    assert all(eng.outcomes[r.uid] == "finished" for r in reqs)
    _assert_token_exact(gpt_pair, mgr, got, reqs)


def test_hot_swap_token_exact_griffin(griffin_pair):
    """Non-transformer acceptance case: griffin's recurrent + local-attn
    ring state is rebuilt through the resume path, not migrated."""
    reqs = _requests(griffin_pair[0], [(5, 10), (9, 10), (7, 10)],
                     seed0=80)
    eng, mgr, got = _swap_run(griffin_pair, reqs)
    assert mgr.state == "swapped"
    assert eng.rejected == {}
    assert all(eng.outcomes[r.uid] == "finished" for r in reqs)
    _assert_token_exact(griffin_pair, mgr, got, reqs)


def test_draft_after_swap_speculation(gpt_pair):
    """Post-swap the old source serves as the speculative draft — spec
    genuinely runs AND outputs stay token-exact (spec decoding is
    lossless)."""
    reqs = _requests(gpt_pair[0], [(6, 14), (9, 14)], seed0=75)
    eng, mgr, got = _swap_run(gpt_pair, reqs, capacity=2,
                              speculate_after=True)
    assert mgr.state == "swapped"
    assert eng.speculative is not None
    assert eng.speculative.cfg.name == gpt_pair[0].name
    assert eng.lifetime_totals()["n_spec_proposed"] > 0
    _assert_token_exact(gpt_pair, mgr, got, reqs)


def test_submit_during_swap_is_held_not_dropped(gpt_pair):
    """A submit that lands inside the quiesce window parks in the hold
    queue and is admitted right after the flip — zero refusals."""
    cfg_src, params_src, cfg_tgt, grown = gpt_pair
    eng = ContinuousBatchingEngine(cfg_src, params_src, capacity=3,
                                   max_len=MAX_LEN, k=2)
    mgr = UpgradeManager(eng, cfg_tgt, grown_params=grown, upgrade_at=2,
                         prewarm=False, speculate_after=False)
    mgr.start(background=False)
    late = _requests(cfg_src, [(6, 8)], uid0=100, seed0=95)[0]
    orig_configure = eng._configure

    def inject_then_configure(cfg, params, speculative):
        assert eng.upgrade_state == "relayout"
        eng.submit(late)  # mid-swap arrival
        assert late.uid not in eng.rejected
        return orig_configure(cfg, params, speculative)

    eng._configure = inject_then_configure
    reqs = _requests(cfg_src, [(5, 10), (8, 10)], seed0=85)
    got = eng.run(reqs)
    eng._configure = orig_configure
    assert mgr.state == "swapped"
    assert eng.n_held_for_upgrade + eng.lifetime["n_held_for_upgrade"] == 1
    assert eng.rejected == {}
    assert eng.outcomes[late.uid] == "finished"
    # the held request ran entirely on the grown target
    want = np.asarray(generate(cfg_tgt, grown,
                               np.asarray(late.prompt)[None],
                               max_new_tokens=late.max_new_tokens,
                               max_len=MAX_LEN))[0]
    np.testing.assert_array_equal(np.asarray(got[late.uid]), want)
    _assert_token_exact(gpt_pair, mgr, got, reqs)


def test_prewarm_covers_swap_shapes(gpt_pair):
    """With prewarm on, the post-swap fn set is already compiled: the
    swap itself must not add cache entries (the pause contains no
    compile)."""
    from repro.serve.engine import _jitted_engine_fns
    reqs = _requests(gpt_pair[0], [(5, 8), (7, 8)], seed0=88)
    cfg_src, params_src, cfg_tgt, grown = gpt_pair
    eng = ContinuousBatchingEngine(cfg_src, params_src, capacity=2,
                                   max_len=16, k=2)
    mgr = UpgradeManager(eng, cfg_tgt, grown_params=grown, upgrade_at=2,
                         prewarm=True, speculate_after=False)
    mgr.start(background=False)
    misses_before = _jitted_engine_fns.cache_info().misses
    got = eng.run(reqs)
    assert mgr.state == "swapped"
    assert _jitted_engine_fns.cache_info().misses == misses_before
    assert all(eng.outcomes[r.uid] == "finished" for r in reqs)
    _assert_token_exact(gpt_pair, mgr, got, reqs)


def test_upgrade_errors_are_named_and_eager(gpt_pair, gpt_micro_cfg):
    cfg_src, params_src, cfg_tgt, grown = gpt_pair
    eng = ContinuousBatchingEngine(cfg_src, params_src, capacity=2,
                                   max_len=MAX_LEN)
    with pytest.raises(UpgradeError, match="family"):
        UpgradeManager(eng, get_config("griffin-micro"))
    with pytest.raises(UpgradeError, match="vocabulary"):
        UpgradeManager(eng, cfg_tgt.replace(vocab_size=996))
    with pytest.raises(UpgradeError, match="position range"):
        UpgradeManager(eng, cfg_tgt.replace(learned_pos=8,
                                            max_seq_len=MAX_LEN))
    mgr = UpgradeManager(eng, cfg_tgt, grown_params=grown, prewarm=False)
    with pytest.raises(UpgradeError, match="in flight"):
        UpgradeManager(eng, cfg_tgt, grown_params=grown, prewarm=False)
    eng2 = ContinuousBatchingEngine(cfg_src, params_src, capacity=2,
                                    max_len=MAX_LEN)
    with pytest.raises(UpgradeError, match="speculate_after"):
        UpgradeManager(eng2, cfg_tgt, speculate_after="yes")
    assert mgr.state == "serving"  # eager checks never start growth


def test_failed_growth_keeps_engine_serving(gpt_micro_cfg,
                                            gpt_micro_big_cfg):
    """A growth that blows up moves the manager to 'failed' and the
    engine simply keeps serving the source — live traffic survives."""
    params = get_family(gpt_micro_cfg).init(jax.random.PRNGKey(0),
                                            gpt_micro_cfg)
    eng = ContinuousBatchingEngine(gpt_micro_cfg, params, capacity=2,
                                   max_len=MAX_LEN)
    mgr = UpgradeManager(eng, gpt_micro_big_cfg, prewarm=False,
                         speculate_after=False,
                         method="no-such-method")  # dies inside _grow()
    mgr.start(background=True)
    with pytest.raises(AssertionError):
        mgr.wait()
    assert mgr.state == "failed"
    assert mgr.error is not None
    reqs = _requests(gpt_micro_cfg, [(5, 6), (7, 6)], seed0=92)
    got = eng.run(reqs)  # poll() is a no-op in 'failed'
    assert eng.cfg.name == gpt_micro_cfg.name
    assert all(eng.outcomes[r.uid] == "finished" for r in reqs)
    for r in reqs:
        want = np.asarray(generate(gpt_micro_cfg, params,
                                   np.asarray(r.prompt)[None],
                                   max_new_tokens=r.max_new_tokens,
                                   max_len=MAX_LEN))[0]
        np.testing.assert_array_equal(np.asarray(got[r.uid]), want)


def test_restore_geometry_mismatch_names_group(gpt_pair, tmp_path):
    """A snapshot taken BEFORE the swap must not silently restore into
    the post-swap architecture: restore_engine(arch=target) fails with a
    named error identifying the offending parameter group."""
    cfg_src, params_src, cfg_tgt, _ = gpt_pair
    eng = ContinuousBatchingEngine(cfg_src, params_src, capacity=2,
                                   max_len=MAX_LEN)
    snapshot_engine(eng, str(tmp_path), step=0)
    with pytest.raises(CheckpointShapeError) as ei:
        restore_engine(str(tmp_path), arch=cfg_tgt.name)
    msg = str(ei.value)
    assert cfg_tgt.name in msg and cfg_src.name in msg
    assert "pre-growth snapshot" in msg
    # round trip with the matching arch still works
    eng2 = restore_engine(str(tmp_path))
    assert eng2.cfg.name == cfg_src.name
