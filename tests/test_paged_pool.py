"""Paged slot-pool invariants: block-table KV + copy-on-write prefix cache.

The paged pool re-lays every cache group a family DECLARES pageable
(``models.paged_groups``) over ONE shared page arena plus per-slot block
tables (``pool="paged"``).  Its contract mirrors the dense pool's:
*token-exactness* — for any trace, greedy tokens equal both the dense
engine's and the sequential ``generate()`` loop's, across transformer
full-KV, MLA latent, ring-window, griffin, xlstm slot-tail, and
speculative chunk-verify serving (griffin pairs included), in the jnp
path and the Pallas interpreter path alike.  On top of that sit the
pool's own invariants: all-or-nothing page allocation with backpressure
(never a partial admission), refcounted page release on eviction across
the draft/target namespaces of a shared arena, prefix-cache hits — full
KV, ring tail-restore, and sampled replay — that skip re-prefill without
changing a single token, and the allocator conservation law (free +
held + LRU-retained == n_pages, live block tables only ever referencing
held pages).
"""
import collections
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig
from repro.data.synthetic import lm_batch
from repro.launch.serve import generate
from repro.serve import ContinuousBatchingEngine, Request, SpeculativeConfig
from repro.serve.paged import PageAllocator, PoolMeta, prefix_digests

MAX_LEN = 32


@pytest.fixture(scope="module", autouse=True)
def _drop_compile_caches():
    """Release this module's jitted executables when it finishes.

    The engine parity tests here compile ~15 distinct engine variants
    (paged/dense x family x kernel).  Those executables stay pinned by
    ``_jitted_engine_fns``'s unbounded lru_cache and jax's global jit
    caches for the rest of the pytest process, and the cumulative XLA
    state has been observed to push later unrelated compiles into a
    segfault on small containers.  Dropping the caches at module teardown
    keeps the suite's peak compiled-state bounded.
    """
    yield
    from repro.serve.engine import _jitted_engine_fns
    _jitted_engine_fns.cache_clear()
    jax.clear_caches()


def _requests(cfg, specs, *, uid0=0, seed0=50):
    return [Request(uid=uid0 + i,
                    prompt=lm_batch(cfg.vocab_size, 1, p, seed=seed0 + i)[0],
                    max_new_tokens=g)
            for i, (p, g) in enumerate(specs)]


def _clone(reqs, *, uid0=0):
    return [Request(uid=uid0 + r.uid, prompt=r.prompt,
                    max_new_tokens=r.max_new_tokens, arrival=r.arrival)
            for r in reqs]


def _sequential(cfg, params, reqs):
    return {r.uid: np.asarray(generate(
        cfg, params, jnp.asarray(r.prompt)[None],
        max_new_tokens=r.max_new_tokens, max_len=MAX_LEN)[0])
        for r in reqs}


def _run_both(cfg, params, reqs, *, capacity=3, k=4, pages=None, **kw):
    """Run the same trace through a dense and a paged engine; return
    (dense tokens, paged tokens, paged engine)."""
    dense = ContinuousBatchingEngine(cfg, params, capacity=capacity,
                                     max_len=MAX_LEN, prefill_bucket=4,
                                     k=k, pool="dense", **kw)
    paged = ContinuousBatchingEngine(cfg, params, capacity=capacity,
                                     max_len=MAX_LEN, prefill_bucket=4,
                                     k=k, pool="paged", pages=pages, **kw)
    got_d = dense.run(_clone(reqs))
    got_p = paged.run(_clone(reqs))
    return got_d, got_p, paged


def _assert_equal(got_d, got_p, want=None):
    assert set(got_d) == set(got_p)
    for uid in got_d:
        np.testing.assert_array_equal(got_p[uid], got_d[uid],
                                      err_msg=f"uid {uid} paged vs dense")
        if want is not None:
            np.testing.assert_array_equal(got_p[uid], want[uid],
                                          err_msg=f"uid {uid} vs generate")


def _window_cfg():
    return ModelConfig(name="win-paged", n_layers=2, d_model=48, n_heads=4,
                       n_kv_heads=2, d_ff=96, vocab_size=97, window=8,
                       attn_chunk=8)


def _griffin_cfg():
    return ModelConfig(name="griffin-paged", family="griffin", n_layers=3,
                       d_model=48, n_heads=4, n_kv_heads=1, d_ff=96,
                       vocab_size=97, lru_width=48, window=6, act="geglu",
                       attn_chunk=8, scale_embeddings=True,
                       block_pattern=("rec", "rec", "attn"))


def _griffin_rec_cfg():
    """All-recurrent griffin: servable, but with NO pageable cache group
    (the one remaining honest dense-fallback case in the zoo)."""
    return ModelConfig(name="griffin-rec-only", family="griffin",
                       n_layers=2, d_model=48, n_heads=4, n_kv_heads=1,
                       d_ff=96, vocab_size=97, lru_width=48, window=6,
                       act="geglu", attn_chunk=8, scale_embeddings=True,
                       block_pattern=("rec", "rec"))


def _xlstm_cfg():
    return ModelConfig(name="xlstm-paged", family="xlstm", n_layers=2,
                       d_model=48, n_heads=4, n_kv_heads=4, d_ff=0,
                       vocab_size=97, proj_factor=2.0, attn_chunk=8,
                       block_pattern=("m", "s"))


def _window9_cfg():
    """window=9 over a 16-deep ring (page 8, nblk 2): the smallest
    geometry where the ring retains one full UNCLOBBERED page —
    ``(nblk-1)*page + 1 >= window`` — so ring prefix sharing can fire
    (window=8/ring=8/nblk=1 can never hit: the prompt's partial tail
    page always overwrites the only ring page)."""
    return ModelConfig(name="win9-paged", n_layers=2, d_model=48,
                       n_heads=4, n_kv_heads=2, d_ff=96, vocab_size=97,
                       window=9, attn_chunk=8)


def _arena_invariants(engine):
    """The allocator conservation law, checked against device state:
    free ∪ held ∪ LRU-retained partitions the page-id space, pages
    pending a zeroing scatter are already free, and every non-sentinel
    block-table entry of a LIVE slot references a held page."""
    alloc = engine._alloc
    n = alloc.meta.n_pages
    free, lru = set(alloc.free), set(alloc.lru)
    held = {p for p in range(n) if alloc.refcount[p].sum() > 0}
    assert len(alloc.free) == len(free)  # no duplicate free entries
    assert not (free & held) and not (free & lru) and not (held & lru)
    assert free | held | lru == set(range(n))
    assert set(engine._zero_pending) <= free
    live = set()
    for pool, meta in zip(engine._pools, engine._metas):
        if meta is None:
            continue
        for g in meta.groups:
            bt = np.asarray(pool[g.path[0]]["bt"][0])
            for slot in engine.active:
                live |= {int(x) for x in bt[slot] if int(x) < n}
    assert live <= held, (live, held)


def _params(cfg):
    from repro.models import get_family
    return get_family(cfg).init(jax.random.PRNGKey(0), cfg)


def test_paged_matches_dense_and_sequential(qwen_smoke_cfg,
                                            qwen_smoke_params):
    """Full-KV transformer serving through the paged pool is token-exact
    vs the dense pool AND vs sequential ``generate()`` across admission
    bucketing, slot recycling, and macro stepping."""
    cfg, params = qwen_smoke_cfg, qwen_smoke_params
    specs = [(3, 6), (9, 2), (5, 8), (12, 4), (4, 7), (7, 1), (6, 5)]
    reqs = _requests(cfg, specs)
    got_d, got_p, engine = _run_both(cfg, params, reqs)
    assert engine.pool_kind == "paged"
    _assert_equal(got_d, got_p, _sequential(cfg, params, reqs))
    assert len(reqs) > engine.capacity  # slots really were recycled


def test_paged_ring_window_wrap_parity():
    """Ring-buffer window slots through the paged pool: sequences far
    beyond the window wrap their (single-page) ring exactly as dense."""
    cfg = _window_cfg()
    params = _params(cfg)
    specs = [(3, 12), (10, 8), (6, 14), (12, 4), (5, 9)]
    reqs = _requests(cfg, specs, seed0=80)
    got_d, got_p, engine = _run_both(cfg, params, reqs)
    assert engine.pool_kind == "paged"
    _assert_equal(got_d, got_p, _sequential(cfg, params, reqs))


def test_paged_griffin_mixed_groups():
    """Griffin pools page the local-attention KV group while the
    recurrent-state group stays dense — both ride the same admission,
    decode, and eviction paths, token-exact vs dense and sequential."""
    cfg = _griffin_cfg()
    params = _params(cfg)
    specs = [(3, 6), (9, 2), (5, 8), (12, 4), (4, 7)]
    reqs = _requests(cfg, specs)
    got_d, got_p, engine = _run_both(cfg, params, reqs)
    assert engine.pool_kind == "paged"
    # the recurrent group really is dense alongside the paged attn group
    paged_groups = [g for g in engine.pool.values()
                    if isinstance(g, dict) and "bt" in g]
    assert paged_groups and len(paged_groups) < len(engine.pool)
    _assert_equal(got_d, got_p, _sequential(cfg, params, reqs))


def test_paged_xlstm_slot_groups_parity():
    """xlstm pages its conv-tail SLOT groups (one whole tail per page,
    nblk=1) while the mLSTM/sLSTM carries stay dense-per-slot — the
    family serves paged now instead of silently flipping dense —
    token-exact vs the dense pool and sequential generate()."""
    cfg = _xlstm_cfg()
    params = _params(cfg)
    reqs = _requests(cfg, [(3, 6), (9, 2), (5, 8), (12, 4), (4, 7)])
    got_d, got_p, engine = _run_both(cfg, params, reqs, capacity=2)
    assert engine.pool_kind == "paged"
    assert engine.pool_fallback_reason is None
    # both blocks page their conv tails; carries stay dense in-place
    paged_groups = [g for g in engine.pool.values()
                    if isinstance(g, dict) and "bt" in g]
    assert len(paged_groups) == 2
    assert all(len(g) > 2 for g in paged_groups)  # dense carries ride along
    _assert_equal(got_d, got_p, _sequential(cfg, params, reqs))


def test_paged_mla_latent_parity():
    """MLA pages its latent caches (ckv/kr) — absorbed decode consumes
    the paged latents through a layout gather, token-exact vs dense and
    sequential."""
    from repro.configs.base import get_config
    cfg = get_config("deepseek-v3-671b-smoke")
    params = _params(cfg)
    reqs = _requests(cfg, [(3, 6), (9, 2), (5, 8), (11, 4)])
    got_d, got_p, engine = _run_both(cfg, params, reqs, capacity=2)
    assert engine.pool_kind == "paged"
    _assert_equal(got_d, got_p, _sequential(cfg, params, reqs))


def test_unpageable_config_serves_dense_with_named_reason():
    """A config with no pageable cache group (all-recurrent griffin:
    O(1) state only) degrades to the dense pool WITH a named
    ``pool_fallback_reason`` — the silent ``pool_kind`` flip is retired —
    and still serves token-exactly."""
    cfg = _griffin_rec_cfg()
    params = _params(cfg)
    reqs = _requests(cfg, [(3, 6), (9, 2), (5, 8)])
    engine = ContinuousBatchingEngine(cfg, params, capacity=2,
                                      max_len=MAX_LEN, prefill_bucket=4,
                                      k=4, pool="paged")
    assert engine.pool_kind == "dense"
    assert "no pageable cache groups" in engine.pool_fallback_reason
    got = engine.run(reqs)
    want = _sequential(cfg, params, reqs)
    for uid in want:
        np.testing.assert_array_equal(got[uid], want[uid])


def test_paged_speculative_chunk_verify(gpt_micro_cfg, gpt_micro_big_cfg):
    """Speculative serving allocates BOTH pools (draft + target) from
    page arenas; chunk-verify over block tables accepts/rejects exactly
    as the dense pools do."""
    from repro.models import get_family
    cfg_t, cfg_d = gpt_micro_big_cfg, gpt_micro_cfg
    params_t = get_family(cfg_t).init(jax.random.PRNGKey(0), cfg_t)
    params_d = get_family(cfg_d).init(jax.random.PRNGKey(1), cfg_d)
    reqs = _requests(cfg_t, [(4, 6), (9, 3), (6, 5)], seed0=70)
    got_d, got_p, engine = _run_both(
        cfg_t, params_t, reqs, capacity=2, k=2,
        speculative=SpeculativeConfig(cfg_d, params_d, d=2))
    assert engine.pool_kind == "paged"
    _assert_equal(got_d, got_p, _sequential(cfg_t, params_t, reqs))


@pytest.mark.parametrize("d", [2, 4])
def test_paged_griffin_speculative_parity(d):
    """Griffin + speculative no longer forces dense: the paged
    ``spec_ring_restore`` twin commits/rolls back verify blocks directly
    in the paged local-attention rings.  Token-exact vs the dense spec
    engine and sequential generate() at both depths, with generations
    long enough to wrap the window ring several times."""
    cfg = _griffin_cfg()
    params = _params(cfg)
    from repro.models import get_family
    cfg_d = _griffin_cfg().replace(name="griffin-draft")
    # a DISAGREEING draft (different init): rejections exercise the paged
    # ring rollback, not just the all-accept fast path
    params_d = get_family(cfg_d).init(jax.random.PRNGKey(3), cfg_d)
    # window 6 -> an 8-deep ring: gens of 12-14 wrap it repeatedly
    specs = [(3, 14), (10, 8), (6, 12), (12, 4)]
    reqs = _requests(cfg, specs, seed0=85)
    got_d, got_p, engine = _run_both(
        cfg, params, reqs, capacity=2, k=2,
        speculative=SpeculativeConfig(cfg_d, params_d, d=d))
    assert engine.pool_kind == "paged"
    assert engine.pool_fallback_reason is None
    _assert_equal(got_d, got_p, _sequential(cfg, params, reqs))


@pytest.mark.parametrize("window", [None, 8])
def test_paged_kernel_interpret_parity(gpt_micro_cfg, window):
    """The paged Pallas kernels (block-table indirection in the index
    map, scalar-prefetched bt) are token-exact vs the jnp paged path in
    interpreter mode, full-KV and ring alike."""
    cfg = gpt_micro_cfg if window is None else \
        gpt_micro_cfg.replace(name="gpt-micro-win", window=window)
    params = _params(gpt_micro_cfg)
    reqs = _requests(cfg, [(4, 6), (9, 4)], seed0=90)
    jnp_engine = ContinuousBatchingEngine(cfg, params, capacity=2,
                                          max_len=MAX_LEN, prefill_bucket=4,
                                          k=2, pool="paged")
    kcfg = cfg.replace(decode_kernel="interpret")
    k_engine = ContinuousBatchingEngine(kcfg, params, capacity=2,
                                        max_len=MAX_LEN, prefill_bucket=4,
                                        k=2, pool="paged")
    got_j = jnp_engine.run(_clone(reqs))
    got_k = k_engine.run(_clone(reqs))
    assert k_engine.pool_kind == "paged"
    _assert_equal(got_j, got_k)


def test_page_exhaustion_backpressure(qwen_smoke_cfg, qwen_smoke_params):
    """With fewer pages than the trace wants at once, admission applies
    backpressure (requests wait for released pages) instead of partially
    admitting — every request still finishes with exact tokens, and the
    arena high-water never exceeds the budget."""
    cfg, params = qwen_smoke_cfg, qwen_smoke_params
    specs = [(9, 8), (10, 7), (11, 6), (9, 5), (12, 4), (10, 8)]
    reqs = _requests(cfg, specs, seed0=120)
    # each request needs 3 pages (8-token quantum); 4 pages admit only
    # one at a time even though 4 slots are free
    got_d, got_p, engine = _run_both(cfg, params, reqs, capacity=4,
                                     pages=4)
    assert engine.pages_highwater <= 4
    assert set(got_p) == {r.uid for r in reqs}  # nobody starved
    _assert_equal(got_d, got_p)


def test_prefix_hit_skips_prefill_token_exact(qwen_smoke_cfg,
                                              qwen_smoke_params):
    """Requests sharing a prompt prefix: after the first admission wave
    registers its prefill pages, later requests hit the prefix cache —
    fewer prefill dispatches, shared pages referenced copy-on-write —
    with tokens exactly equal to the dense engine's and generate()'s."""
    cfg, params = qwen_smoke_cfg, qwen_smoke_params
    prefix = lm_batch(cfg.vocab_size, 1, 18, seed=200)[0]
    reqs = []
    for i in range(4):
        tail = lm_batch(cfg.vocab_size, 1, 2 + i, seed=210 + i)[0]
        reqs.append(Request(uid=i, prompt=np.concatenate([prefix, tail]),
                            max_new_tokens=5))
    # capacity 1 forces one admission wave per request, so waves 2-4 can
    # hit the pages wave 1 registered
    got_d, got_p, engine = _run_both(cfg, params, reqs, capacity=1)
    dense = ContinuousBatchingEngine(cfg, params, capacity=1,
                                     max_len=MAX_LEN, prefill_bucket=4,
                                     k=4, pool="dense")
    dense.run(_clone(reqs, uid0=100))
    assert engine.n_prefix_hits == 3 and engine.n_prefix_misses == 1
    assert engine.n_prefills < dense.n_prefills  # re-prefill skipped
    assert engine.prefix_hit_rate == pytest.approx(0.75)
    # hits allocate only tail pages: strictly fewer than a miss would
    assert engine.n_pages_allocated < 4 * 3
    _assert_equal(got_d, got_p, _sequential(cfg, params, reqs))


def test_prefix_hit_under_pressure_pins_resident_pages(
        qwen_smoke_cfg, qwen_smoke_params, monkeypatch):
    """Regression: a prefix-hit admission must pin (incref) the resident
    pages BEFORE allocating its tail.  With a dry free list, alloc()
    reclaims zero-ref LRU-retained pages — previously including the very
    pages the lookup just returned, so one physical page served as both
    shared prefix and private tail of the same slot (pids like
    ``[3, 4, 5, 3]``) and tail writes aliased the prefix KV.  The fixed
    path stalls the hit (telemetry: ``n_prefix_stalls``, not a registry
    miss) until pages free up; no admission record may ever book the
    same page twice."""
    cfg, params = qwen_smoke_cfg, qwen_smoke_params
    prefix = lm_batch(cfg.vocab_size, 1, 17, seed=600)[0]
    long_runner = Request(uid=0,
                          prompt=lm_batch(cfg.vocab_size, 1, 9,
                                          seed=601)[0],
                          max_new_tokens=15)  # 3 pages, held for many steps
    registrar = Request(uid=1, prompt=prefix, max_new_tokens=1)  # 3 pages
    hitter = Request(uid=2,
                     prompt=np.concatenate(
                         [prefix[:16], lm_batch(cfg.vocab_size, 1, 1,
                                                seed=602)[0]]),
                     max_new_tokens=14)  # hit: 2 resident + 2 tail pages
    reqs = [long_runner, registrar, hitter]
    # arena of 6: wave 1 (long_runner + registrar) takes all 6 pages; by
    # the hitter's admission only ONE page is free while the 2 resident
    # pages sit zero-ref in the LRU — exactly the reclaim-aliasing setup
    engine = ContinuousBatchingEngine(cfg, params, capacity=2,
                                      max_len=MAX_LEN, prefill_bucket=4,
                                      k=4, pool="paged", pages=6)
    orig = ContinuousBatchingEngine._alloc_request
    double_booked = []

    def checked(self, req):
        info = orig(self, req)
        if info is not None:
            pids = list(info["pids"]) + list(info.get("resident") or [])
            if pids and len(set(pids)) != len(pids):
                double_booked.append((req.uid, pids))
        return info

    monkeypatch.setattr(ContinuousBatchingEngine, "_alloc_request",
                        checked)
    got = engine.run(_clone(reqs))
    assert not double_booked  # the direct aliasing signature
    assert engine.n_prefix_hits == 1
    assert engine.n_prefix_stalls >= 1  # the hit waited, pages pinned
    assert engine.n_prefix_misses == 2  # stalls are NOT misses
    assert engine.pages_highwater <= 6
    want = _sequential(cfg, params, reqs)
    for uid in want:
        np.testing.assert_array_equal(got[uid], want[uid],
                                      err_msg=f"uid {uid}")


def test_unservable_page_budget_rejected_not_livelocked(qwen_smoke_cfg,
                                                        qwen_smoke_params):
    """Regression: a request whose page need exceeds the whole arena used
    to bounce off admission forever (run() livelocked re-queueing it).
    submit() must reject it up front — recorded, uid reusable — while
    requests the arena CAN hold keep serving."""
    cfg, params = qwen_smoke_cfg, qwen_smoke_params
    engine = ContinuousBatchingEngine(cfg, params, capacity=2,
                                      max_len=MAX_LEN, prefill_bucket=4,
                                      k=4, pool="paged", pages=2)
    reqs = [Request(uid=0,
                    prompt=lm_batch(cfg.vocab_size, 1, 9, seed=700)[0],
                    max_new_tokens=8),   # 3 pages > 2-page arena
            Request(uid=1,
                    prompt=lm_batch(cfg.vocab_size, 1, 4, seed=701)[0],
                    max_new_tokens=3)]   # 1 page: servable
    got = engine.run(reqs)
    assert "pages" in engine.rejected[0] and 0 not in got
    np.testing.assert_array_equal(
        got[1], _sequential(cfg, params, reqs[1:])[1])


def test_cow_divergence_and_refcount_release(qwen_smoke_cfg,
                                             qwen_smoke_params):
    """Copy-on-write: two live requests share resident prefix pages but
    write their decode tokens to private tail pages — divergent suffixes
    never cross-contaminate — and eviction drops refcounts so the arena
    returns to zero pages in use."""
    cfg, params = qwen_smoke_cfg, qwen_smoke_params
    prefix = lm_batch(cfg.vocab_size, 1, 17, seed=300)[0]
    reqs = [Request(uid=i,
                    prompt=np.concatenate(
                        [prefix, lm_batch(cfg.vocab_size, 1, 3 + i,
                                          seed=310 + i)[0]]),
                    max_new_tokens=6) for i in range(3)]
    engine = ContinuousBatchingEngine(cfg, params, capacity=1,
                                      max_len=MAX_LEN, prefill_bucket=4,
                                      k=4, pool="paged")
    got = engine.run(_clone(reqs))
    assert engine.n_prefix_hits >= 1
    want = _sequential(cfg, params, reqs)
    for uid in want:
        np.testing.assert_array_equal(got[uid], want[uid],
                                      err_msg=f"uid {uid}")
    # all requests retired: flush releases every slot's pages; only
    # zero-ref registered pages may linger (LRU-retained for reuse)
    engine._flush_evictions()
    alloc = engine._alloc
    assert engine.pages_in_use == 0
    assert not engine._slot_pages
    # and the retained pages are reclaimable: a fresh burst fits
    got2 = engine.run(_clone(reqs, uid0=100))
    for uid in want:
        np.testing.assert_array_equal(got2[100 + uid], want[uid])
    assert alloc.highwater <= alloc.meta.n_pages


def test_page_allocator_refcounts_and_lru_reclaim():
    """PageAllocator unit contract: all-or-nothing alloc, refcounted
    release, digest registry lookups, and LRU reclaim of zero-ref
    registered pages when the free list runs dry."""
    alloc = PageAllocator(PoolMeta(page=8, nblk=2, n_pages=4))
    a = alloc.alloc(3)
    assert len(a) == 3 and alloc.pages_in_use() == 3
    assert alloc.alloc(2) is None  # only 1 free: all-or-nothing refusal
    assert alloc.pages_in_use() == 3  # the refused alloc grabbed nothing
    # register two of them under a digest chain, then fully release
    digs = prefix_digests(np.arange(16, dtype=np.int32), 8)
    alloc.register(digs, a[:2])
    assert alloc.lookup(digs) == a[:2]
    zero = alloc.release(a)
    # registered pages are retained (no zeroing) for future hits;
    # the unregistered page is returned for zeroing
    assert zero == [a[2]] and alloc.pages_in_use() == 0
    assert alloc.lookup(digs) == a[:2]
    # a hit increfs resident pages without touching the free list
    alloc.incref(a[:2])
    assert alloc.pages_in_use() == 2
    alloc.release(a[:2])
    # demand exceeding the free list reclaims the LRU retained pages
    b = alloc.alloc(4)
    assert b is not None and sorted(b) == sorted(range(4))
    assert alloc.lookup(digs) is None  # reclaim evicted the registry entry
    assert alloc.highwater == 4


def test_select_admissions_linear_not_quadratic(qwen_smoke_cfg,
                                                qwen_smoke_params):
    """Regression guard for the admission-scan bugfix: selecting from a
    deep waiting queue must scale ~linearly (one scan + one rebuild per
    wave), not quadratically (per-take deque deletes)."""
    cfg, params = qwen_smoke_cfg, qwen_smoke_params
    engine = ContinuousBatchingEngine(cfg, params, capacity=4,
                                      max_len=MAX_LEN, prefill_bucket=4,
                                      policy="spf")
    prompt = np.ones(4, np.int32)

    def timed(n):
        reqs = [Request(uid=i, prompt=prompt, max_new_tokens=1,
                        arrival=float(i % 7)) for i in range(n)]
        best = float("inf")
        for _ in range(3):
            engine.waiting = collections.deque(reqs)
            t0 = time.perf_counter()
            take = engine._select_admissions(now=1e9)
            best = min(best, time.perf_counter() - t0)
            assert len(take) == engine.capacity
        return best

    t_small, t_big = timed(500), timed(4000)
    # 8x the queue: linear ≈ 8x, the old quadratic path ≈ 64x.  The
    # bound sits far above linear noise and far below quadratic.
    assert t_big < 24 * max(t_small, 1e-5), (t_small, t_big)


def test_drain_resets_window_keeps_lifetime(qwen_smoke_cfg,
                                            qwen_smoke_params):
    """Regression: drain() used to clear results but leave the telemetry
    counters accumulating forever, so per-window rates (bench traces,
    acceptance checks) were polluted by history.  drain() must zero the
    window counters, fold them into lifetime totals, and clear the
    rejection log."""
    cfg, params = qwen_smoke_cfg, qwen_smoke_params
    engine = ContinuousBatchingEngine(cfg, params, capacity=2,
                                      max_len=MAX_LEN, prefill_bucket=4,
                                      k=4, pool="paged")
    engine.submit(Request(uid=999, prompt=np.zeros(MAX_LEN, np.int32),
                          max_new_tokens=4))  # rejected, not raised
    engine.run(_requests(cfg, [(4, 5), (6, 3)], seed0=400))
    w1 = {c: getattr(engine, c) for c in ("n_tokens", "n_prefills",
                                          "n_decode_dispatches")}
    assert w1["n_tokens"] == 8 and engine.rejected
    engine.drain()
    for c in w1:
        assert getattr(engine, c) == 0, c  # window reset
        assert engine.lifetime[c] == w1[c], c  # history kept
    assert not engine.rejected
    # a second window accumulates independently; totals = both windows
    engine.run(_requests(cfg, [(5, 2)], uid0=10, seed0=410))
    assert engine.n_tokens == 2
    assert engine.lifetime_totals()["n_tokens"] == w1["n_tokens"] + 2


def test_paged_pool_specs_match_engine(qwen_smoke_cfg, qwen_smoke_params):
    """launch/specs.py's abstract paged-pool specs must track the real
    engine pool (shape + dtype), or dry-run lowering drifts silently;
    unpageable configs must report None, matching the dense fallback."""
    from repro.launch import specs as specs_lib
    cfg, params = qwen_smoke_cfg, qwen_smoke_params
    engine = ContinuousBatchingEngine(cfg, params, capacity=2,
                                      max_len=MAX_LEN, prefill_bucket=4,
                                      pool="paged")
    spec = specs_lib.paged_slot_pool_specs(cfg, 2, MAX_LEN)
    assert jax.tree.map(lambda s: (s.shape, str(s.dtype)), spec) \
        == jax.tree.map(lambda a: (a.shape, str(a.dtype)), engine.pool)
    # slot-group families (xlstm conv tails) page too, and the abstract
    # specs track their engine pools the same way
    xcfg = _xlstm_cfg().replace(name="xlstm-spec")
    xengine = ContinuousBatchingEngine(xcfg, _params(xcfg), capacity=2,
                                       max_len=MAX_LEN, prefill_bucket=4,
                                       pool="paged")
    xspec = specs_lib.paged_slot_pool_specs(xcfg, 2, MAX_LEN)
    assert jax.tree.map(lambda s: (s.shape, str(s.dtype)), xspec) \
        == jax.tree.map(lambda a: (a.shape, str(a.dtype)), xengine.pool)
    # a config with nothing pageable reports None, matching the engine's
    # named dense fallback
    assert specs_lib.paged_slot_pool_specs(
        _griffin_rec_cfg(), 2, MAX_LEN) is None


def test_ring_prefix_hit_tail_restore_token_exact():
    """Windowed prefix sharing: admission registers absolute-position
    copies of the ring's registrable tail pages, and later identical
    prefixes HIT — the new slot's ring is reconstructed from the resident
    pages plus a short tail replay, skipping the full prefill.  Fewer
    prefill batches, hit rate > 0, tokens exactly equal to the dense
    engine's and generate()'s."""
    cfg = _window9_cfg()
    params = _params(cfg)
    prompt = lm_batch(cfg.vocab_size, 1, 13, seed=800)[0]
    reqs = [Request(uid=i, prompt=prompt.copy(), max_new_tokens=6)
            for i in range(6)]
    got_d, got_p, engine = _run_both(cfg, params, reqs, capacity=2,
                                     pages=8)
    assert engine.pool_kind == "paged" and engine._windowed
    assert engine.n_prefix_hits > 0
    assert engine.prefix_hit_rate > 0
    dense = ContinuousBatchingEngine(cfg, params, capacity=2,
                                     max_len=MAX_LEN, prefill_bucket=4,
                                     k=4, pool="dense")
    dense.run(_clone(reqs, uid0=100))
    assert engine.n_prefills < dense.n_prefills  # prefill batches drop
    _arena_invariants(engine)
    _assert_equal(got_d, got_p, _sequential(cfg, params, reqs))


def test_ring_too_tight_for_sharing_stays_exact():
    """window=8 over an 8-deep single-page ring can NEVER serve a prefix
    hit (the prompt's partial tail page always clobbers the one ring
    page) — the slack gate must keep sharing off rather than serve
    garbage, and the trace stays token-exact."""
    cfg = _window_cfg()
    params = _params(cfg)
    prompt = lm_batch(cfg.vocab_size, 1, 13, seed=810)[0]
    reqs = [Request(uid=i, prompt=prompt.copy(), max_new_tokens=6)
            for i in range(4)]
    got_d, got_p, engine = _run_both(cfg, params, reqs, capacity=2,
                                     pages=8)
    assert not engine._prefix_ok  # (nblk-1)*page + 1 < window
    assert engine.n_prefix_hits == 0
    _assert_equal(got_d, got_p, _sequential(cfg, params, reqs))


def test_sampled_prefix_hit_chain_exact_replay(qwen_smoke_cfg,
                                               qwen_smoke_params):
    """Prefix hits no longer require greedy decode: a sampled admission
    replays the skipped prefill's PRNG chain (one advance per sampled
    prompt-tail draw, exactly as ``prefill_sampled`` would have), so a
    hit emits the very token sequence a miss would have — asserted
    against a dense SAMPLED engine and hit rate > 0."""
    from repro.serve.sampling import SamplingParams
    cfg, params = qwen_smoke_cfg, qwen_smoke_params
    sp = SamplingParams(temperature=0.9, top_k=12, seed=11)
    prompt = lm_batch(cfg.vocab_size, 1, 19, seed=820)[0]
    reqs = [Request(uid=i, prompt=prompt.copy(), max_new_tokens=6)
            for i in range(6)]
    got_d, got_p, engine = _run_both(cfg, params, reqs, capacity=2,
                                     pages=16, sampling=sp)
    assert engine.n_prefix_hits > 0 and engine.prefix_hit_rate > 0
    dense = ContinuousBatchingEngine(cfg, params, capacity=2,
                                     max_len=MAX_LEN, prefill_bucket=4,
                                     k=4, pool="dense", sampling=sp)
    dense.run(_clone(reqs, uid0=100))
    assert engine.n_prefills < dense.n_prefills
    # per-uid chains: identical prompts still sample DISTINCT sequences
    outs = {tuple(np.asarray(got_p[u]).tolist()) for u in got_p}
    assert len(outs) > 1
    _assert_equal(got_d, got_p)


def test_shared_arena_draft_target_trade_pages(gpt_micro_cfg):
    """Speculative serving allocates from ONE physical arena: a request
    books its worst-case page count once, holding a reference in BOTH
    engine namespaces, and pages freed when draft+target retire a slot
    are immediately reusable by the next admission — a tight ``--pages``
    budget that a static split would deadlock serves the whole trace
    without backpressure."""
    from repro.models import get_family
    cfg = gpt_micro_cfg
    params = get_family(cfg).init(jax.random.PRNGKey(0), cfg)
    params_d = get_family(cfg).init(jax.random.PRNGKey(7), cfg)
    specs = [(9, 8), (10, 7), (11, 6), (9, 5), (12, 4), (10, 8)]
    reqs = _requests(cfg, specs, seed0=830)
    # 3 pages per request shared across both pools; 6 pages run 2 slots
    got_d, got_p, engine = _run_both(
        cfg, params, reqs, capacity=2, k=2, pages=6,
        speculative=SpeculativeConfig(cfg.replace(name="gpt-micro-draft"),
                                      params_d, d=2))
    assert engine.pool_kind == "paged"
    assert engine._alloc.namespaces == 2
    assert engine.pages_highwater <= 6
    assert set(got_p) == {r.uid for r in reqs}  # nobody starved
    # page ids were RECYCLED across waves (one id space, not a split)
    assert engine.n_pages_allocated > 6
    # both pools' block tables resolved the SAME page ids while live
    # (checked post-hoc via the allocator: every page that was ever
    # allocated carried a reference in both namespaces)
    _arena_invariants(engine)
    engine._flush_evictions()
    assert engine.pages_in_use == 0
    _assert_equal(got_d, got_p, _sequential(cfg, params, reqs))


def test_shared_arena_namespace_release_contract():
    """Allocator-level twin of the trade test: a page allocated into
    both namespaces survives the draft's release (still held by the
    target), frees + zeroes only on the LAST namespace's release, and is
    immediately reallocatable."""
    alloc = PageAllocator(PoolMeta(page=8, nblk=2, n_pages=4),
                          namespaces=2)
    a = alloc.alloc(3, ns=(0, 1))
    assert len(a) == 3 and alloc.pages_in_use() == 3
    assert alloc.release(a, ns=1) == []  # draft retires: target holds on
    assert alloc.pages_in_use() == 3
    zero = alloc.release(a, ns=0)        # target retires: free + zero
    assert sorted(zero) == sorted(a) and alloc.pages_in_use() == 0
    b = alloc.alloc(4, ns=(0,))          # every page immediately reusable
    assert b is not None and alloc.alloc(1) is None
    assert alloc.highwater == 4


def test_page_allocator_conservation_property():
    """Property-style sweep: under a random interleaving of alloc /
    release / register / incref / flush ops, the allocator never
    violates conservation (free ∪ held ∪ LRU-retained partitions the id
    space, disjointly) and the registry stays a bijection onto resident
    pages."""
    rng = np.random.default_rng(0)
    meta = PoolMeta(page=8, nblk=4, n_pages=16)
    alloc = PageAllocator(meta, namespaces=2)
    digs = prefix_digests(np.arange(64 * 8, dtype=np.int32), 8)
    held = []

    def check():
        n = meta.n_pages
        free, lru = set(alloc.free), set(alloc.lru)
        in_use = {p for p in range(n) if alloc.refcount[p].sum() > 0}
        assert len(alloc.free) == len(free)
        assert not (free & in_use) and not (free & lru)
        assert not (lru & in_use)
        assert free | in_use | lru == set(range(n))
        assert (alloc.refcount >= 0).all()
        for pid, d in alloc.page_key.items():
            assert alloc.registry.get(d) == pid
        assert len(alloc.registry) == len(alloc.page_key)
        assert alloc.pages_in_use() == len(in_use)

    for step in range(400):
        op = int(rng.integers(5))
        if op == 0:
            ns = ((0,), (0, 1))[int(rng.integers(2))]
            got = alloc.alloc(int(rng.integers(1, 5)), ns=ns)
            if got is not None:
                held.append((got, ns))
                if rng.integers(2):
                    j = int(rng.integers(len(digs) - len(got)))
                    alloc.register(digs[j:j + len(got)], got)
        elif op == 1 and held:
            pids, ns = held.pop(int(rng.integers(len(held))))
            for i in ns:
                alloc.release(pids, ns=i)
        elif op == 2 and alloc.lru:
            pid = next(iter(alloc.lru))
            alloc.incref([pid])
            held.append(([pid], (0,)))
        elif op == 3 and not rng.integers(8):
            alloc.flush_registry()
        check()
    for pids, ns in held:  # drain: everything comes back
        for i in ns:
            alloc.release(pids, ns=i)
    alloc.flush_registry()
    assert alloc.pages_in_use() == 0
    assert len(alloc.free) == meta.n_pages


def test_oversize_rejection_is_resubmittable(qwen_smoke_cfg,
                                             qwen_smoke_params):
    """A rejected request is not burned: its uid stays reusable, the
    reason is recorded, and the trace around it keeps serving."""
    cfg, params = qwen_smoke_cfg, qwen_smoke_params
    engine = ContinuousBatchingEngine(cfg, params, capacity=1,
                                      max_len=MAX_LEN, prefill_bucket=4,
                                      pool="paged")
    engine.submit(Request(uid=0, prompt=np.zeros(30, np.int32),
                          max_new_tokens=8))  # 30 + 8 > 32
    assert "exceeds max_len" in engine.rejected[0]
    # resubmit the same uid with a servable budget: accepted this time
    got = engine.run(_requests(cfg, [(4, 3)], seed0=500))
    assert set(got) == {0} and len(got[0]) == 3
