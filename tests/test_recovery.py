"""Crash-safe journal + token-exact restart invariants.

The fault-tolerance contract: kill the engine at ANY dispatch boundary,
rebuild it from the journal alone, and every surviving request's final
token sequence is bit-identical to the uninterrupted run — greedy,
sampled (the per-request PRNG chain is advanced past the committed run),
and greedy-speculative, including a paged-pool shared-prefix trace.
The journal reader itself must shrug off a torn tail (a crash mid-append)
and any number of crash/restart cycles in one file (last-submit-wins).
"""
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data.synthetic import lm_batch
from repro.launch.serve import generate
from repro.models import get_family
from repro.serve import (
    ContinuousBatchingEngine,
    EngineKilled,
    Fault,
    FaultPlan,
    Request,
    RequestJournal,
    SamplingParams,
    SpeculativeConfig,
    read_journal,
    recovery_requests,
    restore_engine,
    snapshot_engine,
)

MAX_LEN = 32


def _mixed_requests(cfg, specs, *, uid0=0, seed0=50):
    reqs = []
    for i, (plen, gen) in enumerate(specs):
        prompt = lm_batch(cfg.vocab_size, 1, plen, seed=seed0 + i)[0]
        reqs.append(Request(uid=uid0 + i, prompt=prompt,
                            max_new_tokens=gen))
    return reqs


def _fresh(reqs):
    return [Request(uid=r.uid, prompt=r.prompt,
                    max_new_tokens=r.max_new_tokens, eos_id=r.eos_id)
            for r in reqs]


def _crash_and_resume(cfg, params, reqs, crash_step, path, **kw):
    """Run ``reqs`` on an engine wired to die at dispatch ``crash_step``,
    then rebuild from the journal alone and finish the trace.  Returns
    (merged outputs, the resume Requests, the resumed engine)."""
    j = RequestJournal(str(path))
    eng = ContinuousBatchingEngine(
        cfg, params, journal=j,
        faults=FaultPlan([Fault("crash", crash_step)]), **kw)
    with pytest.raises(EngineKilled):
        eng.run(_fresh(reqs))
    j.close()
    resumed, done = recovery_requests(read_journal(str(path)))
    j2 = RequestJournal(str(path))
    eng2 = ContinuousBatchingEngine(cfg, params, journal=j2, **kw)
    out = eng2.run(resumed)
    j2.close()
    return {**done, **out}, resumed, eng2


def _uninterrupted(cfg, params, reqs, **kw):
    return ContinuousBatchingEngine(cfg, params, **kw).run(_fresh(reqs))


@pytest.mark.parametrize("crash_step", [1, 3])
def test_greedy_crash_resume_token_exact(crash_step, gpt_micro_cfg,
                                         tmp_path):
    """Kill-at-step-N + journal resume == the uninterrupted run, token
    for token.  gpt-micro's learned positions make its greedy trace
    position-dependent, so an off-by-one in the resume prefill (wrong
    position for the first regenerated token) cannot pass silently."""
    cfg = gpt_micro_cfg
    params = get_family(cfg).init(jax.random.PRNGKey(0), cfg)
    reqs = _mixed_requests(cfg, [(4, 8), (7, 5), (5, 9), (9, 3), (3, 6)])
    kw = dict(capacity=2, max_len=MAX_LEN, prefill_bucket=4, k=4)
    want = _uninterrupted(cfg, params, reqs, **kw)
    got, resumed, _ = _crash_and_resume(
        cfg, params, reqs, crash_step, tmp_path / "j.jsonl", **kw)
    assert set(got) == set(want)
    for uid in want:
        np.testing.assert_array_equal(got[uid], want[uid],
                                      err_msg=f"uid {uid}")
    # the crash really interrupted mid-flight sequences: at least one
    # resume carried committed tokens back into its prompt
    assert any(r.n_committed > 0 for r in resumed)
    # and the resumed run matches the sequential loop too (belt/braces)
    for r in reqs:
        seq = generate(cfg, params, jnp.asarray(r.prompt)[None],
                       max_new_tokens=r.max_new_tokens, max_len=MAX_LEN)
        np.testing.assert_array_equal(got[r.uid], np.asarray(seq[0]))


def test_sampled_crash_resume_token_exact(qwen_smoke_cfg,
                                          qwen_smoke_params, tmp_path):
    """Sampled resume: a request's chain position always equals its
    generated-token count, so the resume prefill advances the chain by
    ``n_committed`` splits and the first regenerated draw lands on
    exactly the key the dead engine would have used next."""
    cfg, params = qwen_smoke_cfg, qwen_smoke_params
    sp = SamplingParams(temperature=0.9, top_k=12, top_p=0.9, seed=7)
    reqs = _mixed_requests(cfg, [(4, 9), (6, 6), (8, 8), (5, 7)],
                           seed0=80)
    kw = dict(capacity=2, max_len=MAX_LEN, prefill_bucket=4, k=4,
              sampling=sp)
    want = _uninterrupted(cfg, params, reqs, **kw)
    got, resumed, _ = _crash_and_resume(
        cfg, params, reqs, 2, tmp_path / "j.jsonl", **kw)
    assert any(r.n_committed > 0 for r in resumed)
    for uid in want:
        np.testing.assert_array_equal(got[uid], want[uid],
                                      err_msg=f"uid {uid}")


def _perturbed(params, scale=3e-3, seed=1):
    keys = jax.random.split(jax.random.PRNGKey(seed),
                            len(jax.tree.leaves(params)))
    flat, treedef = jax.tree.flatten(params)
    flat = [p + scale * jax.random.normal(k, p.shape, p.dtype)
            for p, k in zip(flat, keys)]
    return jax.tree.unflatten(treedef, flat)


def test_speculative_greedy_crash_resume(qwen_smoke_cfg,
                                         qwen_smoke_params, tmp_path):
    """Greedy speculative decode consumes no PRNG splits, so its resume
    is token-exact like plain greedy — every committed token is the
    target's argmax regardless of what the draft proposed before or
    after the crash."""
    cfg, params = qwen_smoke_cfg, qwen_smoke_params
    spec = SpeculativeConfig(cfg, _perturbed(params), d=2)
    reqs = _mixed_requests(cfg, [(4, 8), (7, 5), (5, 7)], seed0=90)
    kw = dict(capacity=2, max_len=MAX_LEN, prefill_bucket=4, k=2,
              speculative=spec)
    want = _uninterrupted(cfg, params, reqs, **kw)
    got, resumed, _ = _crash_and_resume(
        cfg, params, reqs, 2, tmp_path / "j.jsonl", **kw)
    assert any(r.n_committed > 0 for r in resumed)
    for uid in want:
        np.testing.assert_array_equal(got[uid], want[uid],
                                      err_msg=f"uid {uid}")
    # and speculative output == plain target decode (the base invariant)
    plain = _uninterrupted(cfg, params, reqs, capacity=2, max_len=MAX_LEN,
                           prefill_bucket=4, k=4)
    for uid in plain:
        np.testing.assert_array_equal(got[uid], plain[uid])


def test_paged_prefix_hit_crash_resume(qwen_smoke_cfg, qwen_smoke_params,
                                       tmp_path):
    """A paged-pool shared-prefix trace through a crash: the restarted
    engine rebuilds its prefix registry from scratch (device state died
    with the process), re-prefills ``prompt ‖ committed`` for the
    survivors, and later admissions in the SAME restart hit the rebuilt
    resident pages — outputs stay token-identical to the dense
    uninterrupted run throughout."""
    cfg, params = qwen_smoke_cfg, qwen_smoke_params
    prefix = lm_batch(cfg.vocab_size, 1, 8, seed=701)[0]
    reqs = []
    for uid in range(6):
        tail = lm_batch(cfg.vocab_size, 1, 2 + uid % 3, seed=900 + uid)[0]
        reqs.append(Request(uid=uid,
                            prompt=np.concatenate([prefix, tail]),
                            max_new_tokens=5 + uid % 3))
    kw = dict(capacity=2, max_len=MAX_LEN, prefill_bucket=4, k=4)
    want = _uninterrupted(cfg, params, reqs, **kw)  # dense reference
    got, resumed, eng2 = _crash_and_resume(
        cfg, params, reqs, 2, tmp_path / "j.jsonl", pool="paged", **kw)
    assert set(got) == set(want)
    for uid in want:
        np.testing.assert_array_equal(got[uid], want[uid],
                                      err_msg=f"uid {uid}")
    assert any(r.n_committed > 0 for r in resumed)
    # the restarted engine really served some admissions from resident
    # prefix pages (capacity 2 < len(resumed) forces multiple waves)
    assert eng2.n_prefix_hits > 0


def test_journal_torn_tail_and_multi_crash(tmp_path):
    """The reader stops at a torn tail instead of failing, and one file
    survives two crash cycles: a resumed submit RESETS the uid's
    committed run to its own ``n_committed`` suffix, so earlier cycles'
    tok records are never double-counted."""
    path = tmp_path / "j.jsonl"
    j = RequestJournal(str(path))
    j.record_submit(Request(uid=1, prompt=np.array([5, 6, 7], np.int32),
                            max_new_tokens=6))
    j.record_tokens(1, [10, 11])
    j.record_submit(Request(uid=2, prompt=np.array([8, 9], np.int32),
                            max_new_tokens=4))
    j.record_tokens(2, [20, 21, 22, 23])
    j.close()
    # crash cycle 2: uid 1 resumes with its run folded into the prompt
    j = RequestJournal(str(path))
    j.record_submit(Request(uid=1,
                            prompt=np.array([5, 6, 7, 10, 11], np.int32),
                            max_new_tokens=6, n_committed=2))
    j.record_tokens(1, [12])
    j.close()
    # torn tail: a crash mid-append leaves half a record
    with open(path, "a") as f:
        f.write('{"t": "tok", "uid": 1, "toks": [99')
    st = read_journal(str(path))
    assert st.committed[1] == [10, 11, 12]  # reset + delta, no 99
    resume, done = recovery_requests(st)
    # uid 2's committed run already fills its budget: finished, no slot
    np.testing.assert_array_equal(done[2], [20, 21, 22, 23])
    (r1,) = resume
    assert r1.uid == 1 and r1.n_committed == 3
    np.testing.assert_array_equal(r1.prompt, [5, 6, 7, 10, 11, 12])


def test_recovery_classifies_eos_and_finished(tmp_path):
    """A committed run that already fired eos needs no slot — it returns
    as finished output truncated at the eos; an explicitly finished uid
    comes back verbatim; a rejected uid stays dead."""
    path = tmp_path / "j.jsonl"
    j = RequestJournal(str(path))
    j.record_submit(Request(uid=1, prompt=np.array([3], np.int32),
                            max_new_tokens=8, eos_id=42))
    j.record_tokens(1, [7, 42, 9])  # eos fired mid-run, fin record lost
    j.record_submit(Request(uid=2, prompt=np.array([4], np.int32),
                            max_new_tokens=2))
    j.record_tokens(2, [5, 6])
    j.record_finish(2, "finished")
    j.record_reject(3, "request 3: empty prompt")
    j.close()
    resume, done = recovery_requests(read_journal(str(path)))
    assert resume == []
    np.testing.assert_array_equal(done[1], [7, 42])
    np.testing.assert_array_equal(done[2], [5, 6])
    assert 3 not in done


def test_snapshot_restore_roundtrip(qwen_smoke_cfg, qwen_smoke_params,
                                    tmp_path):
    """``restore_engine`` rebuilds an equivalent engine from the
    snapshot alone: same geometry, same sampling policy, same tokens."""
    cfg, params = qwen_smoke_cfg, qwen_smoke_params
    sp = SamplingParams(temperature=0.8, top_k=8, seed=3)
    eng = ContinuousBatchingEngine(cfg, params, capacity=3,
                                   max_len=MAX_LEN, prefill_bucket=4,
                                   k=4, sampling=sp, deadline=30.0)
    snapshot_engine(eng, str(tmp_path / "snap"), step=5)
    eng2 = restore_engine(str(tmp_path / "snap"))
    assert eng2.capacity == 3 and eng2.k == 4
    assert eng2.deadline == 30.0 and eng2.sampling == sp
    reqs = _mixed_requests(cfg, [(4, 6), (7, 4)], seed0=60)
    a = eng.run(_fresh(reqs))
    b = eng2.run(_fresh(reqs))
    for uid in a:
        np.testing.assert_array_equal(a[uid], b[uid])
    # constructor overrides pass through (a restart reattaches a journal)
    j = RequestJournal(str(tmp_path / "j.jsonl"))
    eng3 = restore_engine(str(tmp_path / "snap"), journal=j, deadline=None)
    assert eng3.journal is j and eng3.deadline is None
    with pytest.raises(FileNotFoundError):
        restore_engine(str(tmp_path / "empty"))
    # a non-engine checkpoint is refused, not misparsed
    from repro.checkpoint.manager import save_checkpoint
    save_checkpoint(str(tmp_path / "train"), 1, {"w": np.zeros(2)},
                    extra={"kind": "train"})
    with pytest.raises(ValueError, match="not an engine snapshot"):
        restore_engine(str(tmp_path / "train"))


@pytest.mark.slow
def test_greedy_crash_resume_every_step(gpt_micro_cfg, tmp_path):
    """Exhaustive kill-point sweep: the resume is token-exact no matter
    WHICH dispatch boundary the crash lands on."""
    cfg = gpt_micro_cfg
    params = get_family(cfg).init(jax.random.PRNGKey(0), cfg)
    reqs = _mixed_requests(cfg, [(4, 8), (7, 5), (5, 9), (9, 3)])
    kw = dict(capacity=2, max_len=MAX_LEN, prefill_bucket=4, k=4)
    want = _uninterrupted(cfg, params, reqs, **kw)
    for crash_step in range(1, 8):
        got, _, _ = _crash_and_resume(
            cfg, params, reqs, crash_step,
            tmp_path / f"j{crash_step}.jsonl", **kw)
        for uid in want:
            np.testing.assert_array_equal(
                got[uid], want[uid],
                err_msg=f"crash@{crash_step} uid {uid}")
