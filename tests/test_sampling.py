"""Non-greedy decode in the macro loop + speculative rejection sampling.

The sampling contract: a request's sampled tokens are a pure function of
(engine seed, uid, prompt) — per-slot PRNG chains advance only when their
row really samples, so slot placement, macro-step length, admission
interleaving, and pool capacity never change a request's output.  A
sequential single-request replay using the same ``serve.sampling``
helpers is therefore token-exact against the engine.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data.synthetic import lm_batch
from repro.models import get_family
from repro.serve import (
    ContinuousBatchingEngine,
    Request,
    SamplingParams,
    SpeculativeConfig,
)
from repro.serve import sampling as sampling_lib

MAX_LEN = 32


# ------------------------------------------------------------ filter units
def test_filtered_probs_top_k():
    logits = jnp.asarray(np.random.default_rng(0).normal(size=(3, 17)),
                         jnp.float32)
    probs = sampling_lib.filtered_probs(
        logits, SamplingParams(temperature=1.0, top_k=5))
    assert probs.shape == (3, 17)
    np.testing.assert_allclose(np.asarray(probs).sum(-1), 1.0, rtol=1e-5)
    assert int((np.asarray(probs) > 0).sum(-1).max()) <= 5
    # the argmax always survives filtering
    assert (np.take_along_axis(np.asarray(probs),
                               np.argmax(np.asarray(logits), -1)[:, None],
                               1) > 0).all()


def test_filtered_probs_top_p():
    rng = np.random.default_rng(1)
    logits = jnp.asarray(rng.normal(size=(4, 33)) * 3, jnp.float32)
    full = jax.nn.softmax(logits, -1)
    probs = sampling_lib.filtered_probs(
        logits, SamplingParams(temperature=1.0, top_p=0.5))
    kept = np.asarray(probs) > 0
    # the kept set is the smallest head of the sorted distribution whose
    # exclusive cumulative mass is < p: its full-distribution mass must
    # reach p, and dropping its least likely member must fall below p
    for b in range(4):
        mass = float(np.asarray(full)[b][kept[b]].sum())
        assert mass >= 0.5
        if kept[b].sum() > 1:
            smallest = np.asarray(full)[b][kept[b]].min()
            assert mass - smallest < 0.5
    # temperature 0 is greedy and consumes no keys
    sp0 = SamplingParams()
    assert sp0.greedy and sampling_lib.is_greedy(sp0)


def test_sampling_params_validation():
    with pytest.raises(ValueError, match="temperature"):
        SamplingParams(temperature=-1.0)
    with pytest.raises(ValueError, match="top_p"):
        SamplingParams(top_p=0.0)
    with pytest.raises(ValueError, match="top_k"):
        SamplingParams(top_k=-2)


# --------------------------------------------------- engine vs sequential
def _sampled_reference(cfg, params, req, sp, max_len=MAX_LEN):
    """Single-request replay of the engine's sampling discipline: the
    chain root is (seed, uid); the first key samples the prefill token,
    each later key one decode token."""
    fam = get_family(cfg)
    cache = fam.init_cache(cfg, 1, max_len)
    prompt = jnp.asarray(req.prompt)[None]
    logits, cache = fam.prefill(params, {"tokens": prompt}, cfg, cache)
    keys = sampling_lib.request_key(sp.seed, req.uid)[None]
    keys, subs = sampling_lib.next_keys(keys)
    tok = sampling_lib.sample_logits(logits, subs, sp)
    out = [int(tok[0])]
    pos = len(req.prompt)
    while (len(out) < req.max_new_tokens
           and (req.eos_id is None or out[-1] != req.eos_id)):
        logits, cache = fam.decode_step(params, tok, jnp.int32(pos), cache,
                                        cfg)
        keys, subs = sampling_lib.next_keys(keys)
        tok = sampling_lib.sample_logits(logits, subs, sp)
        out.append(int(tok[0]))
        pos += 1
    return np.asarray(out, np.int32)


def _mixed_requests(cfg, specs, *, uid0=0, seed0=50):
    return [Request(uid=uid0 + i,
                    prompt=lm_batch(cfg.vocab_size, 1, plen,
                                    seed=seed0 + i)[0],
                    max_new_tokens=gen)
            for i, (plen, gen) in enumerate(specs)]


def test_sampled_engine_matches_sequential_reference(qwen_smoke_cfg,
                                                     qwen_smoke_params):
    """Engine-sampled tokens == the sequential replay, token-exact, for a
    mixed oversubscribed trace through recycled slots."""
    cfg, params = qwen_smoke_cfg, qwen_smoke_params
    sp = SamplingParams(temperature=0.8, top_k=12, top_p=0.9, seed=5)
    # three requests through two slots: still oversubscribed (recycling)
    # but ~2/5 less per-request replay time than the old 5-request trace
    specs = [(3, 7), (9, 3), (5, 8)]
    reqs = _mixed_requests(cfg, specs)
    engine = ContinuousBatchingEngine(cfg, params, capacity=2,
                                      max_len=MAX_LEN, prefill_bucket=4,
                                      k=4, sampling=sp)
    got = engine.run(reqs)
    for r in reqs:
        want = _sampled_reference(cfg, params, r, sp)
        np.testing.assert_array_equal(got[r.uid], want,
                                      err_msg=f"uid {r.uid}")
    # non-degenerate: the sampled trace differs from the greedy one
    greedy = ContinuousBatchingEngine(cfg, params, capacity=2,
                                      max_len=MAX_LEN, prefill_bucket=4,
                                      k=4)
    got_g = greedy.run([Request(uid=100 + r.uid, prompt=r.prompt,
                                max_new_tokens=r.max_new_tokens)
                        for r in reqs])
    assert any(not np.array_equal(got[r.uid], got_g[100 + r.uid])
               for r in reqs)


def test_sampled_interleaving_independence(qwen_smoke_cfg,
                                           qwen_smoke_params):
    """Same requests, different submission order and macro length: every
    request's sampled tokens are identical — chains are keyed by uid, not
    by slot or step parity."""
    cfg, params = qwen_smoke_cfg, qwen_smoke_params
    sp = SamplingParams(temperature=1.2, top_k=0, top_p=0.95, seed=9)
    specs = [(4, 6), (8, 5), (6, 7)]
    reqs = _mixed_requests(cfg, specs, seed0=30)
    e1 = ContinuousBatchingEngine(cfg, params, capacity=2, max_len=MAX_LEN,
                                  prefill_bucket=4, k=4, sampling=sp)
    got1 = e1.run(reqs)
    e2 = ContinuousBatchingEngine(cfg, params, capacity=2, max_len=MAX_LEN,
                                  prefill_bucket=4, k=4, sampling=sp)
    got2 = e2.run([Request(uid=r.uid, prompt=r.prompt,
                           max_new_tokens=r.max_new_tokens)
                   for r in reversed(reqs)])
    for uid in got1:
        np.testing.assert_array_equal(got1[uid], got2[uid],
                                      err_msg=f"uid {uid}")


# --------------------------------------------- speculative rejection sampling
def test_residual_probs_construction():
    p = jnp.asarray([[0.5, 0.3, 0.2], [0.25, 0.25, 0.5]])
    q = jnp.asarray([[0.2, 0.5, 0.3], [0.25, 0.25, 0.5]])
    r = np.asarray(sampling_lib.residual_probs(p, q))
    np.testing.assert_allclose(r[0], [1.0, 0.0, 0.0], atol=1e-6)
    # p == q degenerates: falls back to p (acceptance is certain anyway)
    np.testing.assert_allclose(r[1], np.asarray(p)[1], atol=1e-6)


def test_spec_rejection_sampling_self_draft(qwen_smoke_cfg,
                                            qwen_smoke_params):
    """draft == target under sampling: ``min(1, p/q) == 1`` so every
    proposal is accepted, and two identical runs are bit-identical
    (deterministic chains)."""
    cfg, params = qwen_smoke_cfg, qwen_smoke_params
    sp = SamplingParams(temperature=0.9, top_k=16, seed=11)
    reqs = _mixed_requests(cfg, [(3, 8), (7, 6), (5, 9)], seed0=10)

    def fresh():
        return [Request(uid=r.uid, prompt=r.prompt,
                        max_new_tokens=r.max_new_tokens) for r in reqs]

    def run():
        e = ContinuousBatchingEngine(
            cfg, params, capacity=2, max_len=MAX_LEN, prefill_bucket=4,
            k=2, sampling=sp,
            speculative=SpeculativeConfig(cfg, params, d=3))
        return e, e.run(fresh())

    e1, got1 = run()
    assert e1.n_spec_proposed > 0
    assert e1.acceptance_rate == 1.0
    e2, got2 = run()
    for uid in got1:
        np.testing.assert_array_equal(got1[uid], got2[uid],
                                      err_msg=f"uid {uid}")
    # tokens really vary (sampling, not greedy)
    greedy = ContinuousBatchingEngine(
        cfg, params, capacity=2, max_len=MAX_LEN, prefill_bucket=4, k=2,
        speculative=SpeculativeConfig(cfg, params, d=3))
    got_g = greedy.run([Request(uid=200 + r.uid, prompt=r.prompt,
                                max_new_tokens=r.max_new_tokens)
                        for r in reqs])
    assert any(not np.array_equal(got1[r.uid], got_g[200 + r.uid])
               for r in reqs)


@pytest.mark.slow
def test_spec_rejection_sampling_perturbed_draft(qwen_smoke_cfg,
                                                 qwen_smoke_params):
    """A nearby-but-different draft: rejection sampling must stay inside
    the filtered support of the TARGET distribution and accept only part
    of the proposals.  (slow tier: the self-draft test covers the
    rejection-sampling mechanics in the default run — this adds the
    partial-acceptance support check at ~30 s of replay compiles.)"""
    cfg, params = qwen_smoke_cfg, qwen_smoke_params
    keys = jax.random.split(jax.random.PRNGKey(3),
                            len(jax.tree.leaves(params)))
    flat, treedef = jax.tree.flatten(params)
    draft = jax.tree.unflatten(
        treedef, [p + 2e-2 * jax.random.normal(k, p.shape, p.dtype)
                  for p, k in zip(flat, keys)])
    sp = SamplingParams(temperature=0.9, top_k=4, seed=13)
    reqs = _mixed_requests(cfg, [(4, 10), (8, 8)], seed0=90)
    e = ContinuousBatchingEngine(
        cfg, params, capacity=2, max_len=MAX_LEN, prefill_bucket=4, k=2,
        sampling=sp, speculative=SpeculativeConfig(cfg, draft, d=3))
    got = e.run(reqs)
    assert 0.0 < e.acceptance_rate <= 1.0
    # every emitted token lies in the target's top-k filtered support of
    # its own prefix distribution (verified by replaying the prefix)
    fam = get_family(cfg)
    for r in reqs:
        toks = got[r.uid]
        cache = fam.init_cache(cfg, 1, MAX_LEN)
        logits, cache = fam.prefill(
            params, {"tokens": jnp.asarray(r.prompt)[None]}, cfg, cache)
        pos = len(r.prompt)
        for t in np.asarray(toks):
            probs = sampling_lib.filtered_probs(logits, sp)
            assert float(probs[0, int(t)]) > 0.0
            logits, cache = fam.decode_step(
                params, jnp.asarray([int(t)], jnp.int32), jnp.int32(pos),
                cache, cfg)
            pos += 1
