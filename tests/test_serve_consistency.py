"""Prefill+decode must reproduce teacher-forced forward logits.

This is the core serving invariant: running the prompt through ``prefill``
and then stepping ``decode_step`` token by token must give the same logits
as one full ``forward`` pass (up to accumulation-order noise).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig
from repro.models import get_family

# 8 unjitted decode steps after the prefill: each eager step costs real
# dispatch time, and 8 steps already cross every cache-write boundary the
# 16-step sweep did (tier-1 time audit)
B, S = 2, 16
PROMPT = 8


def _run(cfg, atol=2e-4):
    fam = get_family(cfg)
    rng = jax.random.PRNGKey(0)
    params = fam.init(rng, cfg)
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0,
                                cfg.vocab_size)

    full_logits, _ = fam.forward(params, {"tokens": tokens}, cfg)

    cache = fam.init_cache(cfg, B, S)
    logits_p, cache = fam.prefill(params, {"tokens": tokens[:, :PROMPT]},
                                  cfg, cache)
    np.testing.assert_allclose(
        np.asarray(logits_p, np.float32),
        np.asarray(full_logits[:, PROMPT - 1], np.float32),
        atol=atol, rtol=1e-3)

    for t in range(PROMPT, S):
        logits_t, cache = fam.decode_step(params, tokens[:, t - 1] * 0 +
                                          tokens[:, t], jnp.int32(t), cache,
                                          cfg)
        # feed ground-truth token t, compare against forward position t
        np.testing.assert_allclose(
            np.asarray(logits_t, np.float32),
            np.asarray(full_logits[:, t], np.float32),
            atol=atol, rtol=1e-3, err_msg=f"step {t}")


def test_dense_gqa():
    cfg = ModelConfig(name="d", n_layers=3, d_model=64, n_heads=4,
                      n_kv_heads=2, d_ff=128, vocab_size=97, qkv_bias=True,
                      qk_norm=True, attn_chunk=8)
    _run(cfg)


@pytest.mark.slow
def test_moe():
    cfg = ModelConfig(name="m", n_layers=4, d_model=64, n_heads=4,
                      n_kv_heads=4, d_ff=128, vocab_size=97, moe=True,
                      n_experts=4, top_k=2, expert_d_ff=64,
                      moe_layer_start=2, n_shared_experts=1,
                      capacity_factor=4.0, attn_chunk=8)
    # generous capacity so prefill/decode routing drops match
    _run(cfg, atol=5e-4)


def test_mla():
    cfg = ModelConfig(name="a", n_layers=3, d_model=64, n_heads=4,
                      n_kv_heads=4, d_ff=128, vocab_size=97, mla=True,
                      q_lora_rank=32, kv_lora_rank=16, qk_nope_dim=16,
                      qk_rope_dim=8, v_head_dim=16, attn_chunk=8)
    _run(cfg)


@pytest.mark.slow
def test_local_window():
    cfg = ModelConfig(name="w", n_layers=2, d_model=64, n_heads=4,
                      n_kv_heads=1, d_ff=128, vocab_size=97, window=6,
                      attn_chunk=8)
    _run(cfg)


@pytest.mark.slow
def test_griffin():
    cfg = ModelConfig(name="g", family="griffin", n_layers=5, d_model=64,
                      n_heads=4, n_kv_heads=1, d_ff=128, vocab_size=97,
                      lru_width=64, window=6, act="geglu", attn_chunk=8,
                      scale_embeddings=True)
    _run(cfg, atol=5e-4)


@pytest.mark.slow
def test_xlstm():
    cfg = ModelConfig(name="x", family="xlstm", n_layers=4, d_model=64,
                      n_heads=4, n_kv_heads=4, d_ff=0, vocab_size=97,
                      proj_factor=2.0, slstm_every=4, attn_chunk=8)
    _run(cfg, atol=1e-3)
