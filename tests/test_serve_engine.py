"""Continuous-batching engine invariants.

The engine's contract is *token-exactness*: for any interleaving of
admissions, retirements, and slot reuse, every request's greedy tokens
equal what the sequential ``generate()`` loop produces for that request
alone.  Per-row decode arithmetic is identical to the scalar-offset path
and masked cache positions contribute exact softmax zeros, so this holds
bit-for-bit, not just approximately.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data.synthetic import lm_batch
from repro.launch.serve import build_params, generate
from repro.serve import ContinuousBatchingEngine, Request

MAX_LEN = 32


def _mixed_requests(cfg, specs, *, uid0=0, seed0=50):
    """specs: list of (prompt_len, max_new_tokens)."""
    reqs = []
    for i, (plen, gen) in enumerate(specs):
        prompt = lm_batch(cfg.vocab_size, 1, plen, seed=seed0 + i)[0]
        reqs.append(Request(uid=uid0 + i, prompt=prompt,
                            max_new_tokens=gen))
    return reqs


def _sequential_baseline(cfg, params, reqs):
    """Each request alone through the naive prefill+decode loop, with the
    same cache length the engine uses (padding never changes the math —
    masked positions are exact softmax zeros — but equal shapes make the
    comparison airtight)."""
    out = {}
    for r in reqs:
        toks = generate(cfg, params, jnp.asarray(r.prompt)[None],
                        max_new_tokens=r.max_new_tokens, max_len=MAX_LEN)
        out[r.uid] = np.asarray(toks[0])
    return out


def test_continuous_matches_sequential_mixed_trace(qwen_smoke_cfg,
                                                   qwen_smoke_params):
    """(a) a mixed-length trace through a small slot pool reproduces the
    sequential tokens exactly — including requests that queue behind a full
    pool and land in recycled slots."""
    cfg, params = qwen_smoke_cfg, qwen_smoke_params
    specs = [(3, 6), (9, 2), (5, 8), (12, 4), (4, 7), (7, 1), (6, 5)]
    reqs = _mixed_requests(cfg, specs)
    engine = ContinuousBatchingEngine(cfg, params, capacity=3,
                                      max_len=MAX_LEN, prefill_bucket=4)
    got = engine.run(reqs)
    want = _sequential_baseline(cfg, params, reqs)
    assert set(got) == set(want)
    for uid in want:
        np.testing.assert_array_equal(got[uid], want[uid], err_msg=f"uid {uid}")
    # the pool was actually oversubscribed (slots reused), not one wave
    assert len(reqs) > engine.capacity


def test_slot_eviction_no_stale_kv(qwen_smoke_cfg, qwen_smoke_params):
    """(b) a slot's next tenant sees exactly what it would in a fresh
    engine — eviction + admission-overwrite never leak the previous
    sequence's KV."""
    cfg, params = qwen_smoke_cfg, qwen_smoke_params
    wave1 = _mixed_requests(cfg, [(8, 6), (11, 6)], uid0=0, seed0=10)
    wave2 = _mixed_requests(cfg, [(5, 8), (9, 3)], uid0=100, seed0=90)

    used = ContinuousBatchingEngine(cfg, params, capacity=2,
                                    max_len=MAX_LEN, prefill_bucket=4)
    used.run(wave1)  # dirty every slot
    got = used.run(wave2)  # same slots, recycled

    fresh = ContinuousBatchingEngine(cfg, params, capacity=2,
                                     max_len=MAX_LEN, prefill_bucket=4)
    want = fresh.run(_mixed_requests(cfg, [(5, 8), (9, 3)], uid0=100,
                                     seed0=90))
    for uid in want:
        np.testing.assert_array_equal(got[uid], want[uid], err_msg=f"uid {uid}")
    # and both equal the sequential tokens
    seq = _sequential_baseline(cfg, params, wave2)
    for uid in seq:
        np.testing.assert_array_equal(got[uid], seq[uid], err_msg=f"uid {uid}")


def test_continuous_matches_sequential_mla():
    """The MLA latent-cache slot path (per-row scatter + absorbed-weight
    decode with per-row lengths) is token-exact too."""
    from repro.configs.base import ModelConfig
    from repro.models import get_family
    cfg = ModelConfig(name="mla-serve", n_layers=2, d_model=64, n_heads=4,
                      n_kv_heads=4, d_ff=128, vocab_size=97, mla=True,
                      q_lora_rank=32, kv_lora_rank=16, qk_nope_dim=16,
                      qk_rope_dim=8, v_head_dim=16, attn_chunk=8)
    params = get_family(cfg).init(jax.random.PRNGKey(0), cfg)
    reqs = _mixed_requests(cfg, [(4, 5), (9, 3), (6, 6)], seed0=40)
    engine = ContinuousBatchingEngine(cfg, params, capacity=2,
                                      max_len=MAX_LEN, prefill_bucket=4)
    got = engine.run(reqs)
    want = _sequential_baseline(cfg, params, reqs)
    for uid in want:
        np.testing.assert_array_equal(got[uid], want[uid], err_msg=f"uid {uid}")


def test_serves_mango_grown_params(gpt_micro_big_cfg):
    """(c) the engine serves Mango-grown params with the same consistency
    invariant as ``test_serve_consistency``: continuous tokens == the
    sequential prefill/decode tokens, on weights produced by the paper's
    operator."""
    cfg = gpt_micro_big_cfg
    params = build_params(cfg, grow_from="gpt-micro", grow_method="mango")
    specs = [(4, 6), (10, 3), (6, 5)]
    reqs = _mixed_requests(cfg, specs, seed0=70)
    engine = ContinuousBatchingEngine(cfg, params, capacity=2,
                                      max_len=MAX_LEN, prefill_bucket=4)
    got = engine.run(reqs)
    want = _sequential_baseline(cfg, params, reqs)
    for uid in want:
        np.testing.assert_array_equal(got[uid], want[uid], err_msg=f"uid {uid}")


def test_rejects_oversized_and_wrong_family(qwen_smoke_cfg,
                                            qwen_smoke_params):
    cfg, params = qwen_smoke_cfg, qwen_smoke_params
    engine = ContinuousBatchingEngine(cfg, params, capacity=1,
                                      max_len=MAX_LEN)
    with pytest.raises(ValueError, match="exceeds max_len"):
        engine.submit(Request(uid=0,
                              prompt=np.zeros(MAX_LEN, np.int32),
                              max_new_tokens=4))
    engine.run([Request(uid=7, prompt=np.zeros(4, np.int32),
                        max_new_tokens=2)])
    with pytest.raises(ValueError, match="already submitted"):
        engine.submit(Request(uid=7, prompt=np.zeros(4, np.int32),
                              max_new_tokens=2))
    # drain clears history and frees the uid for reuse
    out = engine.drain()
    assert set(out) == {7} and not engine.finished and not engine.retired
    engine.run([Request(uid=7, prompt=np.zeros(4, np.int32),
                        max_new_tokens=2)])
    from repro.configs.base import get_config
    griffin = get_config("recurrentgemma-2b-smoke")
    with pytest.raises(NotImplementedError):
        ContinuousBatchingEngine(griffin, {}, capacity=1, max_len=MAX_LEN)


def test_admission_by_arrival_not_submission_order(qwen_smoke_cfg,
                                                   qwen_smoke_params):
    """A later-submitted but earlier-arriving request must not queue behind
    an unarrived head-of-line request when slots are free."""
    cfg, params = qwen_smoke_cfg, qwen_smoke_params
    late, early = _mixed_requests(cfg, [(4, 3), (5, 6)], seed0=20)
    late.arrival, early.arrival = 5.0, 0.1
    engine = ContinuousBatchingEngine(cfg, params, capacity=2,
                                      max_len=MAX_LEN, prefill_bucket=4)
    engine.submit(late)
    engine.submit(early)
    engine.step(now=0.2)  # only `early` has arrived
    assert [s.req.uid for s in engine.active.values()] == [early.uid]
    engine.step(now=6.0)
    assert {s.req.uid for s in engine.active.values()} == {late.uid,
                                                           early.uid}


def test_eos_early_exit_frees_slot(qwen_smoke_cfg, qwen_smoke_params):
    """EOS retirement must free the slot early and still produce a prefix
    of the no-EOS sequential tokens."""
    cfg, params = qwen_smoke_cfg, qwen_smoke_params
    reqs = _mixed_requests(cfg, [(6, 10), (8, 10)], seed0=30)
    base = _sequential_baseline(cfg, params, reqs)
    # pick the first request's 3rd token as its EOS so it retires early
    eos = int(base[0][2])
    reqs[0].eos_id = eos
    engine = ContinuousBatchingEngine(cfg, params, capacity=1,
                                      max_len=MAX_LEN, prefill_bucket=4)
    got = engine.run(reqs)
    stop = int(np.argmax(base[0] == eos)) + 1
    np.testing.assert_array_equal(got[0], base[0][:stop])
    np.testing.assert_array_equal(got[1], base[1])
