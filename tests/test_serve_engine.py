"""Continuous-batching engine invariants.

The engine's contract is *token-exactness*: for any interleaving of
admissions, retirements, and slot reuse, every request's greedy tokens
equal what the sequential ``generate()`` loop produces for that request
alone.  Per-row decode arithmetic is identical to the scalar-offset path
and masked cache positions contribute exact softmax zeros, so this holds
bit-for-bit, not just approximately.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data.synthetic import lm_batch
from repro.launch.serve import build_params, generate
from repro.serve import ContinuousBatchingEngine, Request

MAX_LEN = 32


def _mixed_requests(cfg, specs, *, uid0=0, seed0=50):
    """specs: list of (prompt_len, max_new_tokens)."""
    reqs = []
    for i, (plen, gen) in enumerate(specs):
        prompt = lm_batch(cfg.vocab_size, 1, plen, seed=seed0 + i)[0]
        reqs.append(Request(uid=uid0 + i, prompt=prompt,
                            max_new_tokens=gen))
    return reqs


def _sequential_baseline(cfg, params, reqs):
    """Each request alone through the naive prefill+decode loop, with the
    same cache length the engine uses (padding never changes the math —
    masked positions are exact softmax zeros — but equal shapes make the
    comparison airtight)."""
    out = {}
    for r in reqs:
        toks = generate(cfg, params, jnp.asarray(r.prompt)[None],
                        max_new_tokens=r.max_new_tokens, max_len=MAX_LEN)
        out[r.uid] = np.asarray(toks[0])
    return out


@pytest.mark.parametrize("k", [1, 4, 16])
def test_continuous_matches_sequential_mixed_trace(k, qwen_smoke_cfg,
                                                   qwen_smoke_params):
    """(a) a mixed-length trace through a small slot pool reproduces the
    sequential tokens exactly for every macro-step length — including
    requests that queue behind a full pool and land in recycled slots,
    and rows that finish mid-block and coast as on-device no-ops."""
    cfg, params = qwen_smoke_cfg, qwen_smoke_params
    specs = [(3, 6), (9, 2), (5, 8), (12, 4), (4, 7), (7, 1), (6, 5)]
    reqs = _mixed_requests(cfg, specs)
    engine = ContinuousBatchingEngine(cfg, params, capacity=3,
                                      max_len=MAX_LEN, prefill_bucket=4,
                                      k=k)
    got = engine.run(reqs)
    want = _sequential_baseline(cfg, params, reqs)
    assert set(got) == set(want)
    for uid in want:
        np.testing.assert_array_equal(got[uid], want[uid], err_msg=f"uid {uid}")
    # the pool was actually oversubscribed (slots reused), not one wave
    assert len(reqs) > engine.capacity


def test_slot_eviction_no_stale_kv(qwen_smoke_cfg, qwen_smoke_params):
    """(b) a slot's next tenant sees exactly what it would in a fresh
    engine — eviction + admission-overwrite never leak the previous
    sequence's KV."""
    cfg, params = qwen_smoke_cfg, qwen_smoke_params
    wave1 = _mixed_requests(cfg, [(8, 6), (11, 6)], uid0=0, seed0=10)
    wave2 = _mixed_requests(cfg, [(5, 8), (9, 3)], uid0=100, seed0=90)

    used = ContinuousBatchingEngine(cfg, params, capacity=2,
                                    max_len=MAX_LEN, prefill_bucket=4)
    used.run(wave1)  # dirty every slot
    got = used.run(wave2)  # same slots, recycled

    fresh = ContinuousBatchingEngine(cfg, params, capacity=2,
                                     max_len=MAX_LEN, prefill_bucket=4)
    want = fresh.run(_mixed_requests(cfg, [(5, 8), (9, 3)], uid0=100,
                                     seed0=90))
    for uid in want:
        np.testing.assert_array_equal(got[uid], want[uid], err_msg=f"uid {uid}")
    # and both equal the sequential tokens
    seq = _sequential_baseline(cfg, params, wave2)
    for uid in seq:
        np.testing.assert_array_equal(got[uid], seq[uid], err_msg=f"uid {uid}")


def test_continuous_matches_sequential_mla():
    """The MLA latent-cache slot path (per-row scatter + absorbed-weight
    decode with per-row lengths) is token-exact too."""
    from repro.configs.base import ModelConfig
    from repro.models import get_family
    cfg = ModelConfig(name="mla-serve", n_layers=2, d_model=64, n_heads=4,
                      n_kv_heads=4, d_ff=128, vocab_size=97, mla=True,
                      q_lora_rank=32, kv_lora_rank=16, qk_nope_dim=16,
                      qk_rope_dim=8, v_head_dim=16, attn_chunk=8)
    params = get_family(cfg).init(jax.random.PRNGKey(0), cfg)
    reqs = _mixed_requests(cfg, [(4, 5), (9, 3), (6, 6)], seed0=40)
    engine = ContinuousBatchingEngine(cfg, params, capacity=2,
                                      max_len=MAX_LEN, prefill_bucket=4)
    got = engine.run(reqs)
    want = _sequential_baseline(cfg, params, reqs)
    for uid in want:
        np.testing.assert_array_equal(got[uid], want[uid], err_msg=f"uid {uid}")


def test_serves_mango_grown_params(gpt_micro_big_cfg):
    """(c) the engine serves Mango-grown params with the same consistency
    invariant as ``test_serve_consistency``: continuous tokens == the
    sequential prefill/decode tokens, on weights produced by the paper's
    operator."""
    cfg = gpt_micro_big_cfg
    params = build_params(cfg, grow_from="gpt-micro", grow_method="mango")
    specs = [(4, 6), (10, 3), (6, 5)]
    reqs = _mixed_requests(cfg, specs, seed0=70)
    engine = ContinuousBatchingEngine(cfg, params, capacity=2,
                                      max_len=MAX_LEN, prefill_bucket=4)
    got = engine.run(reqs)
    want = _sequential_baseline(cfg, params, reqs)
    for uid in want:
        np.testing.assert_array_equal(got[uid], want[uid], err_msg=f"uid {uid}")


def test_rejects_oversized_and_wrong_family(qwen_smoke_cfg,
                                            qwen_smoke_params):
    cfg, params = qwen_smoke_cfg, qwen_smoke_params
    engine = ContinuousBatchingEngine(cfg, params, capacity=1,
                                      max_len=MAX_LEN)
    # EVERY malformed-request class is RECORDED, not raised — raising
    # mid-trace used to kill the whole replay; the engine keeps serving
    # around it and telemeters the reason
    bads = [
        (Request(uid=0, prompt=np.zeros(MAX_LEN, np.int32),
                 max_new_tokens=4), "exceeds max_len"),
        (Request(uid=1, prompt=np.zeros((0,), np.int32),
                 max_new_tokens=4), "empty prompt"),
        (Request(uid=2, prompt=np.zeros(4, np.int32),
                 max_new_tokens=0), "max_new_tokens"),
        (Request(uid=3, prompt=np.zeros(4, np.int32), max_new_tokens=2,
                 eos_id=cfg.vocab_size), "eos_id"),
        (Request(uid=4, prompt=np.full(4, cfg.vocab_size, np.int32),
                 max_new_tokens=2), "outside the vocabulary"),
        (Request(uid=5, prompt=np.zeros(4, np.int32), max_new_tokens=2,
                 deadline=-1.0), "deadline"),
        (Request(uid=6, prompt=np.zeros(4, np.int32), max_new_tokens=8,
                 n_committed=9), "n_committed"),
    ]
    for req, why in bads:
        engine.submit(req)
        assert why in engine.rejected[req.uid], req.uid
        assert engine.outcomes[req.uid] == "rejected"
        # the uid is NOT burned: a corrected resubmission stays possible
        assert not engine.waiting and req.uid not in engine._seen_uids
    engine.run([Request(uid=7, prompt=np.zeros(4, np.int32),
                        max_new_tokens=2)])
    assert set(engine.finished) == {7}  # rejection didn't stop serving
    with pytest.raises(ValueError, match="already submitted"):
        engine.submit(Request(uid=7, prompt=np.zeros(4, np.int32),
                              max_new_tokens=2))
    # drain clears history and frees the uid for reuse
    out = engine.drain()
    assert set(out) == {7} and not engine.finished and not engine.retired
    engine.run([Request(uid=7, prompt=np.zeros(4, np.int32),
                        max_new_tokens=2)])
    from repro.configs.base import get_config
    # non-causal/continuous-input configs fail the capability probe
    # (griffin/xlstm are served now — see test_serve_families.py)
    hubert = get_config("hubert-xlarge-smoke")
    with pytest.raises(NotImplementedError, match="causal"):
        ContinuousBatchingEngine(hubert, {}, capacity=1, max_len=MAX_LEN)


def test_admission_by_arrival_not_submission_order(qwen_smoke_cfg,
                                                   qwen_smoke_params):
    """A later-submitted but earlier-arriving request must not queue behind
    an unarrived head-of-line request when slots are free."""
    cfg, params = qwen_smoke_cfg, qwen_smoke_params
    late, early = _mixed_requests(cfg, [(4, 3), (5, 6)], seed0=20)
    late.arrival, early.arrival = 5.0, 0.1
    # k=1 so the first step decodes exactly one token and `early` is still
    # in flight when we inspect the active set
    engine = ContinuousBatchingEngine(cfg, params, capacity=2,
                                      max_len=MAX_LEN, prefill_bucket=4,
                                      k=1)
    engine.submit(late)
    engine.submit(early)
    engine.step(now=0.2)  # only `early` has arrived
    assert [s.req.uid for s in engine.active.values()] == [early.uid]
    engine.step(now=6.0)
    assert {s.req.uid for s in engine.active.values()} == {late.uid,
                                                           early.uid}


def test_eos_early_exit_frees_slot(qwen_smoke_cfg, qwen_smoke_params):
    """EOS retirement must free the slot early and still produce a prefix
    of the no-EOS sequential tokens."""
    cfg, params = qwen_smoke_cfg, qwen_smoke_params
    reqs = _mixed_requests(cfg, [(6, 10), (8, 10)], seed0=30)
    base = _sequential_baseline(cfg, params, reqs)
    # pick the first request's 3rd token as its EOS so it retires early
    eos = int(base[0][2])
    reqs[0].eos_id = eos
    engine = ContinuousBatchingEngine(cfg, params, capacity=1,
                                      max_len=MAX_LEN, prefill_bucket=4)
    got = engine.run(reqs)
    stop = int(np.argmax(base[0] == eos)) + 1
    np.testing.assert_array_equal(got[0], base[0][:stop])
    np.testing.assert_array_equal(got[1], base[1])


def test_generate_eos_early_stop(gpt_micro_cfg):
    """Regression: the naive ``generate()`` loop used to ignore eos and
    always decode ``max_new_tokens``.  With ``eos_id`` it must stop as
    soon as every row fired (shorter output), freeze finished rows to
    eos, and leave the no-eos call byte-identical to before."""
    from repro.models import get_family
    cfg = gpt_micro_cfg
    params = get_family(cfg).init(jax.random.PRNGKey(0), cfg)
    prompt = jnp.asarray(lm_batch(cfg.vocab_size, 1, 6, seed=3))
    base = np.asarray(generate(cfg, params, prompt, max_new_tokens=12))
    assert base.shape == (1, 12)  # eos_id=None: full budget, unchanged
    eos = int(base[0][2])
    stop = int(np.argmax(base[0] == eos)) + 1
    got = np.asarray(generate(cfg, params, prompt, max_new_tokens=12,
                              eos_id=eos))
    assert got.shape[1] == stop < 12  # early exit, not a full budget
    np.testing.assert_array_equal(got[0], base[0][:stop])
    # mixed batch: the finished row freezes to eos while the other runs
    prompts = jnp.asarray(lm_batch(cfg.vocab_size, 2, 6, seed=3))
    base2 = np.asarray(generate(cfg, params, prompts, max_new_tokens=12))
    eos = int(base2[0][2])
    got2 = np.asarray(generate(cfg, params, prompts, max_new_tokens=12,
                               eos_id=eos))
    i0 = int(np.argmax(base2[0] == eos))
    np.testing.assert_array_equal(got2[0][:i0 + 1], base2[0][:i0 + 1])
    assert (got2[0][i0:] == eos).all()


@pytest.mark.parametrize("k", [4, 16])
def test_eos_mid_block(k, gpt_micro_cfg):
    """An eos firing strictly inside a macro block must truncate exactly
    there: the in-scan stopping rule freezes the row mid-block, the valid
    mask goes quiet after the eos token, and the slot's remaining no-op
    steps never corrupt its neighbour's tokens.

    Uses gpt-micro: its learned positions make random-init greedy traces
    position-dependent, so distinct tokens exist inside the first block
    (the qwen smoke arch greedy-decodes to a single repeated token).
    """
    from repro.models import get_family
    cfg = gpt_micro_cfg
    params = get_family(cfg).init(jax.random.PRNGKey(0), cfg)
    reqs = _mixed_requests(cfg, [(6, 12), (8, 12)], seed0=30)
    base = _sequential_baseline(cfg, params, reqs)
    # choose an eos whose FIRST occurrence is strictly inside the first
    # macro block (index in [1, k-1)): the row then dies mid-scan
    eos, stop = None, None
    for i in range(1, min(k - 1, len(base[0]))):
        cand = int(base[0][i])
        if int(np.argmax(base[0] == cand)) == i:
            eos, stop = cand, i + 1
            break
    assert eos is not None, "trace has no mid-block eos candidate"
    reqs[0].eos_id = eos
    engine = ContinuousBatchingEngine(cfg, params, capacity=2,
                                      max_len=MAX_LEN, prefill_bucket=4,
                                      k=k)
    got = engine.run(reqs)
    np.testing.assert_array_equal(got[0], base[0][:stop])
    np.testing.assert_array_equal(got[1], base[1])
    assert 1 < stop < k + 1  # really fired inside one block's scan


@pytest.mark.parametrize("k", [1, 4])
def test_macro_step_random_interleavings(k, qwen_smoke_cfg,
                                         qwen_smoke_params):
    """Token-exactness under randomized arrival interleavings driven
    through ``step(now=...)`` on a logical clock: admissions land at
    arbitrary points relative to macro-block boundaries and slot reuse."""
    cfg, params = qwen_smoke_cfg, qwen_smoke_params
    rng = np.random.default_rng(7)
    specs = [(int(rng.integers(2, 12)), int(rng.integers(1, 9)))
             for _ in range(9)]
    reqs = _mixed_requests(cfg, specs, seed0=110)
    for i, r in enumerate(reqs):
        r.arrival = float(rng.uniform(0, 6.0))
    engine = ContinuousBatchingEngine(cfg, params, capacity=3,
                                      max_len=MAX_LEN, prefill_bucket=4,
                                      k=k)
    for r in reqs:
        engine.submit(r)
    t = 0.0
    while engine.waiting or engine.active or engine._inflight:
        t += float(rng.uniform(0.1, 1.5))  # logical time, no wall clock
        engine.step(now=t)
    want = _sequential_baseline(cfg, params, reqs)
    for uid in want:
        np.testing.assert_array_equal(engine.finished[uid], want[uid],
                                      err_msg=f"uid {uid}")


def test_admission_finish_does_not_leak_slot_mid_wave(qwen_smoke_cfg,
                                                      qwen_smoke_params):
    """Regression: a request that finishes AT its prefill token (max_new=1)
    retires its slot while later bucket groups of the same admission wave
    are still being admitted.  The freed slot must not be handed to one of
    them before its pending zero-eviction is applied — that would wipe the
    new tenant's cache and mark its row done, losing the request."""
    cfg, params = qwen_smoke_cfg, qwen_smoke_params
    a = _mixed_requests(cfg, [(3, 1)], uid0=0, seed0=140)[0]   # bucket 4
    b = _mixed_requests(cfg, [(6, 5)], uid0=1, seed0=141)[0]   # bucket 8
    engine = ContinuousBatchingEngine(cfg, params, capacity=2,
                                      max_len=MAX_LEN, prefill_bucket=4,
                                      k=4)
    engine.submit(a)
    engine.submit(b)
    for _ in range(20):  # bounded drive: the bug loses b forever
        if not (engine.waiting or engine.active or engine._inflight):
            break
        engine.step()
    want = _sequential_baseline(cfg, params, [a, b])
    assert set(engine.finished) == {0, 1}
    for uid in want:
        np.testing.assert_array_equal(engine.finished[uid], want[uid],
                                      err_msg=f"uid {uid}")


def test_spf_policy_admits_short_prefills_first(qwen_smoke_cfg,
                                                qwen_smoke_params):
    """Length-bucketed shortest-prefill-first: when slots are scarce, the
    shorter arrived prompt wins the slot even if submitted later — and
    the reordering never changes any request's tokens."""
    cfg, params = qwen_smoke_cfg, qwen_smoke_params
    long_r, short_r = _mixed_requests(cfg, [(12, 4), (3, 4)], seed0=160)
    for policy, first_uid in (("fifo", long_r.uid), ("spf", short_r.uid)):
        engine = ContinuousBatchingEngine(cfg, params, capacity=1,
                                          max_len=MAX_LEN,
                                          prefill_bucket=4, k=1,
                                          policy=policy)
        engine.submit(_mixed_requests(cfg, [(12, 4)], seed0=160)[0])
        engine.submit(_mixed_requests(cfg, [(3, 4)], uid0=1, seed0=161)[0])
        engine.step()
        assert [s.req.uid for s in engine.active.values()] == [first_uid], \
            policy
        # drive to completion: both finish with the sequential tokens
        for _ in range(40):
            if not (engine.waiting or engine.active or engine._inflight):
                break
            engine.step()
        want = _sequential_baseline(
            cfg, params, _mixed_requests(cfg, [(12, 4)], seed0=160)
            + _mixed_requests(cfg, [(3, 4)], uid0=1, seed0=161))
        for uid in want:
            np.testing.assert_array_equal(engine.finished[uid], want[uid],
                                          err_msg=f"{policy} uid {uid}")
    with pytest.raises(ValueError, match="policy"):
        ContinuousBatchingEngine(cfg, params, capacity=1, max_len=MAX_LEN,
                                 policy="lifo")


def test_dispatch_and_sync_amortization(qwen_smoke_cfg, qwen_smoke_params):
    """Regression: the macro-step engine must not regress to per-token
    host interaction.  For K=4 and one same-bucket admission wave:
      * ONE prefill dispatch for the whole admission batch;
      * <= 1/K decode dispatches per generated decode token (+ pipeline
        drain slack);
      * host syncs per generated token <= 1/K overall.
    """
    cfg, params = qwen_smoke_cfg, qwen_smoke_params
    k = 4
    gen = 13  # 12 decode tokens each -> 3 full blocks of 4
    reqs = _mixed_requests(cfg, [(3, gen), (4, gen)], seed0=120)
    engine = ContinuousBatchingEngine(cfg, params, capacity=2,
                                      max_len=MAX_LEN, prefill_bucket=4,
                                      k=k)
    got = engine.run(reqs)
    n_tok = sum(len(v) for v in got.values())
    assert n_tok == 2 * gen
    # both requests share the 4-bucket: one batched prefill dispatch
    assert engine.n_prefills == 1
    n_decode_tok = n_tok - len(reqs)
    # ceil(decode tokens per row / k) blocks + <= 2 no-op drain blocks
    assert engine.n_decode_dispatches <= -(-(gen - 1) // k) + 2
    assert engine.n_decode_dispatches * k >= n_decode_tok // len(reqs)
    # the acceptance bound: syncs (block readbacks + admission readback)
    # amortize to <= 1/K per token
    assert engine.n_host_syncs / n_tok <= 1.0 / k
    # and the per-token engine really pays ~1 sync per token, so the ratio
    # is a genuine K-fold drop
    per_tok = ContinuousBatchingEngine(cfg, params, capacity=2,
                                       max_len=MAX_LEN, prefill_bucket=4,
                                       k=1)
    got1 = per_tok.run([Request(uid=100 + r.uid, prompt=r.prompt,
                                max_new_tokens=r.max_new_tokens)
                        for r in reqs], pipeline=False)
    n1 = sum(len(v) for v in got1.values())
    assert per_tok.n_host_syncs >= per_tok.n_decode_dispatches \
        == n1 // len(reqs) - 1
    for uid in got:
        np.testing.assert_array_equal(got[uid], got1[100 + uid])
