"""Family-agnostic slot-decode protocol invariants.

PR 1/2 proved token-exactness of continuous batching for the transformer
family's full KV / MLA caches.  These tests extend the same contract to
the rest of the zoo through the slot-state protocol:

  * griffin / xlstm — O(1)-per-slot recurrent state (rglru h + conv
    tails; mLSTM C/n/m + sLSTM carries), scattered/gathered per slot and
    FROZEN exactly by the macro-step ``done`` mask (a recurrence update is
    irreversible, so eos firing mid-block must stop the state, not just
    the token);
  * ring-buffer window caches — a sliding-window config's slot pool is
    O(window) per slot (asserted on the pool shape), positions wrap, and
    decode stays token-exact both inside the window (where it must equal
    the FULL-cache model) and far beyond it.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig, get_config
from repro.data.synthetic import lm_batch
from repro.launch.serve import generate
from repro.models import get_family, serve_supported
from repro.serve import ContinuousBatchingEngine, Request

MAX_LEN = 32


def griffin_cfg():
    # window (6) far below MAX_LEN so attention ring slots genuinely wrap;
    # the pattern carries both recurrent and local-attention state
    return ModelConfig(name="griffin-serve", family="griffin", n_layers=3,
                       d_model=48, n_heads=4, n_kv_heads=1, d_ff=96,
                       vocab_size=97, lru_width=48, window=6, act="geglu",
                       attn_chunk=8, scale_embeddings=True,
                       block_pattern=("rec", "rec", "attn"))


def xlstm_cfg():
    # one mLSTM + one sLSTM block: every recurrent state kind is carried
    return ModelConfig(name="xlstm-serve", family="xlstm", n_layers=2,
                       d_model=48, n_heads=4, n_kv_heads=4, d_ff=0,
                       vocab_size=97, proj_factor=2.0, attn_chunk=8,
                       block_pattern=("m", "s"))


def window_cfg():
    # sliding-window transformer: ring-buffer slot pool
    return ModelConfig(name="win-serve", n_layers=2, d_model=48, n_heads=4,
                       n_kv_heads=2, d_ff=96, vocab_size=97, window=8,
                       attn_chunk=8)


FAMILY_CFGS = {"griffin": griffin_cfg, "xlstm": xlstm_cfg}


def _params(cfg):
    return get_family(cfg).init(jax.random.PRNGKey(0), cfg)


def _requests(cfg, specs, *, uid0=0, seed0=50):
    return [Request(uid=uid0 + i,
                    prompt=lm_batch(cfg.vocab_size, 1, p, seed=seed0 + i)[0],
                    max_new_tokens=g)
            for i, (p, g) in enumerate(specs)]


def _sequential(cfg, params, reqs):
    return {r.uid: np.asarray(generate(
        cfg, params, jnp.asarray(r.prompt)[None],
        max_new_tokens=r.max_new_tokens, max_len=MAX_LEN)[0])
        for r in reqs}


# k=1 is the degenerate per-token case of the same macro-loop code path;
# k=4 exercises everything it does plus in-scan freezing, so the k=1
# sweep rides the slow tier (tier-1 time audit)
@pytest.mark.parametrize(
    "k", [pytest.param(1, marks=pytest.mark.slow), 4])
@pytest.mark.parametrize("family", sorted(FAMILY_CFGS))
def test_recurrent_slot_decode_matches_sequential(family, k):
    """Recurrent-state slot decode is token-exact vs sequential
    ``generate()`` through admission bucketing (tail-padded prompts),
    per-slot macro stepping, retirement, and slot recycling."""
    cfg = FAMILY_CFGS[family]()
    params = _params(cfg)
    specs = [(3, 6), (9, 2), (5, 8), (12, 4), (4, 7)]
    reqs = _requests(cfg, specs)
    engine = ContinuousBatchingEngine(cfg, params, capacity=3,
                                      max_len=MAX_LEN, prefill_bucket=4,
                                      k=k)
    got = engine.run(reqs)
    want = _sequential(cfg, params, reqs)
    assert set(got) == set(want)
    for uid in want:
        np.testing.assert_array_equal(got[uid], want[uid],
                                      err_msg=f"{family} uid {uid}")
    # the pool really was oversubscribed: recurrent slots were recycled
    assert len(reqs) > engine.capacity


@pytest.mark.parametrize("family", sorted(FAMILY_CFGS))
def test_recurrent_eos_mid_block_freezes_state(family):
    """An eos firing strictly inside a macro block must freeze the row's
    RECURRENT state mid-scan: the remaining no-op steps advance neither
    conv tails nor h/C/n/m, and the neighbour row's tokens stay exact."""
    k = 4
    cfg = FAMILY_CFGS[family]()
    params = _params(cfg)
    # seed chosen so both families' greedy traces emit a token at block
    # index 1 or 2 whose first occurrence is there (a usable mid-block eos)
    reqs = _requests(cfg, [(6, 12), (8, 12)], seed0=31)
    base = _sequential(cfg, params, reqs)
    # choose an eos whose FIRST occurrence lands inside the first macro
    # block (index in [1, k-1)): the row then dies mid-scan
    eos, stop = None, None
    for i in range(1, min(k - 1, len(base[0]))):
        cand = int(base[0][i])
        if int(np.argmax(base[0] == cand)) == i:
            eos, stop = cand, i + 1
            break
    assert eos is not None, "trace has no mid-block eos candidate"
    reqs[0].eos_id = eos
    engine = ContinuousBatchingEngine(cfg, params, capacity=2,
                                      max_len=MAX_LEN, prefill_bucket=4,
                                      k=k)
    got = engine.run(reqs)
    np.testing.assert_array_equal(got[0], base[0][:stop])
    np.testing.assert_array_equal(got[1], base[1])
    assert 1 < stop < k + 1  # really fired inside one block's scan


def test_ring_window_pool_shape_and_exactness_inside_window():
    """A sliding-window config serves from a ring-buffer slot pool whose
    KV footprint is O(window) — asserted on the pool shape — and inside
    the window its tokens equal the FULL-cache model's (the window mask
    is invisible until a sequence outgrows it)."""
    cfg_win = window_cfg()
    cfg_full = cfg_win.replace(window=None)
    params = _params(cfg_full)  # same param pytree for both configs
    engine = ContinuousBatchingEngine(cfg_win, params, capacity=2,
                                      max_len=MAX_LEN, prefill_bucket=4,
                                      k=4)
    kleaf = engine.pool["dense"]["k"]
    assert kleaf.shape[2] == cfg_win.window < MAX_LEN  # O(window), not O(max_len)
    # prompt + gen <= window: ring never wraps, full-cache tokens match
    reqs = _requests(cfg_win, [(3, 4), (5, 3), (2, 5), (4, 4)], seed0=60)
    got = engine.run(reqs)
    want = _sequential(cfg_full, params, reqs)
    for uid in want:
        np.testing.assert_array_equal(got[uid], want[uid],
                                      err_msg=f"uid {uid}")


@pytest.mark.parametrize(
    "k", [pytest.param(1, marks=pytest.mark.slow), 4])
def test_ring_window_wrap_matches_sequential(k):
    """Sequences far beyond the window: ring slots wrap (positions
    overwrite ``pos % window``) and slot decode stays token-exact vs the
    sequential ring decode of the SAME windowed config."""
    cfg = window_cfg()
    params = _params(cfg)
    specs = [(3, 12), (10, 8), (6, 14), (12, 4), (5, 9)]
    reqs = _requests(cfg, specs, seed0=80)
    engine = ContinuousBatchingEngine(cfg, params, capacity=3,
                                      max_len=MAX_LEN, prefill_bucket=4,
                                      k=k)
    got = engine.run(reqs)
    want = _sequential(cfg, params, reqs)
    for uid in want:
        np.testing.assert_array_equal(got[uid], want[uid],
                                      err_msg=f"uid {uid}")


@pytest.mark.parametrize("family", sorted(FAMILY_CFGS))
def test_done_rows_freeze_recurrent_state_exactly(family):
    """Protocol contract, tested at the family level: decode_step_slots
    with ``done`` set must leave EVERY cache leaf of those rows
    bit-identical — mLSTM and sLSTM carries, conv tails, rglru h, and
    ring KV alike.  (Regression: the sLSTM block once advanced its
    carries on done rows.)"""
    cfg = FAMILY_CFGS[family]()
    params = _params(cfg)
    fam = get_family(cfg)
    prompts = jnp.asarray(np.stack([lm_batch(cfg.vocab_size, 1, 5,
                                             seed=7 + i)[0]
                                    for i in range(2)]))
    cache = fam.init_cache(cfg, 2, 16)
    _, cache = fam.prefill_full(params, {"tokens": prompts,
                                         "plens": jnp.asarray([5, 5])},
                                cfg, cache)  # non-trivial state
    _, nc = fam.decode_step_slots(params, jnp.asarray([1, 2], jnp.int32),
                                  jnp.asarray([5, 5], jnp.int32), cache,
                                  cfg, done=jnp.asarray([True, True]))
    jax.tree.map(
        lambda a, b: np.testing.assert_array_equal(np.asarray(a),
                                                   np.asarray(b)),
        cache, nc)


def test_slot_decode_specs_match_engine_state():
    """launch/specs.py's abstract slot-decode specs must track the real
    engine state (shape + dtype), or dry-run lowering drifts silently."""
    from repro.launch import specs as specs_lib
    cfg = window_cfg()
    engine = ContinuousBatchingEngine(cfg, _params(cfg), capacity=2,
                                      max_len=MAX_LEN, prefill_bucket=4,
                                      k=4)
    spec = specs_lib.slot_decode_specs(cfg, engine.capacity, engine.max_len)
    names = ("tokens", "positions", "remaining", "eos_ids", "done", "keys")
    # leaf-count drift must fail loudly — zip would silently truncate
    assert len(names) == len(engine._state)
    for name, arr in zip(names, engine._state):
        assert (spec[name].shape, spec[name].dtype) == (arr.shape, arr.dtype)
    assert jax.tree.map(lambda s: (s.shape, str(s.dtype)), spec["pool"]) \
        == jax.tree.map(lambda a: (a.shape, str(a.dtype)), engine.pool)


def test_capability_probe():
    """The probe — not a hard-coded family check — gates the engine, with
    an actionable reason for unservable configs."""
    ok, why = serve_supported(get_config("hubert-xlarge-smoke"))
    assert not ok and "causal" in why
    with pytest.raises(NotImplementedError, match="causal"):
        ContinuousBatchingEngine(get_config("hubert-xlarge-smoke"), {},
                                 capacity=1, max_len=16)
    # griffin local attention without a window is probed out, not crashed
    ok, why = serve_supported(griffin_cfg().replace(window=None))
    assert not ok and "window" in why
    # every family in the zoo has a servable representative
    for arch in ("qwen1.5-0.5b-smoke", "deepseek-v3-671b-smoke",
                 "recurrentgemma-2b-smoke", "xlstm-1.3b-smoke"):
        ok, why = serve_supported(get_config(arch))
        assert ok, f"{arch}: {why}"
