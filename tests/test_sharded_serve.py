"""Sharded serving: mesh selection/validation units, token-exactness of
the (data=replica, model=TP) engine vs single-device, the speculative
paged-arena budget split, and the flash-attention prefill backend.

The in-process jax sees 1 CPU device, so anything needing a real mesh
runs in a subprocess with ``--xla_force_host_platform_device_count``
(the ``tests/test_sharding.py`` pattern).  Single-process tests cover
everything that is pure geometry (parse/choose/validate, the 1x1 inert
path, budget split + refcount, flash parity).
"""
import json
import os
import subprocess
import sys
import textwrap

import jax
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs.base import get_config
from repro.distributed.serve_sharding import (
    choose_serve_mesh_shape,
    parse_mesh_arg,
    serve_sharding_rules,
    validate_serve_mesh,
)
from repro.distributed.sharding import logical_to_spec
from repro.models import get_family
from repro.serve import ContinuousBatchingEngine, Request, SamplingParams

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _run_subprocess(code, devices=4):
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = SRC
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env,
                         timeout=560)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


# --------------------------------------------------------------- geometry
def test_parse_mesh_arg():
    assert parse_mesh_arg("2x2") == (2, 2)
    assert parse_mesh_arg("1X4") == (1, 4)
    assert parse_mesh_arg((4, 1)) == (4, 1)
    for bad in ("2", "2x2x2", "ax2", "0x4", (2,)):
        with pytest.raises(ValueError):
            parse_mesh_arg(bad)


def test_validate_serve_mesh_names_the_offender():
    cfg = get_config("gpt-micro")  # 4 heads
    assert validate_serve_mesh("2x2", cfg, capacity=4) == (2, 2)
    with pytest.raises(ValueError, match="devices"):
        validate_serve_mesh("2x2", cfg, capacity=4, n_devices=8)
    with pytest.raises(ValueError, match="n_heads"):
        validate_serve_mesh("1x3", cfg, capacity=4)
    with pytest.raises(ValueError, match="capacity"):
        validate_serve_mesh("4x1", cfg, capacity=6)


def test_choose_serve_mesh_shape_prefers_tp():
    cfg = get_config("gpt-micro")  # 4 heads
    assert choose_serve_mesh_shape(4, cfg, capacity=4) == (1, 4)
    assert choose_serve_mesh_shape(2, cfg, capacity=4) == (1, 2)
    # model=8 does not divide 4 heads -> fall to 8 = 2 data x 4 model
    assert choose_serve_mesh_shape(8, cfg, capacity=4) == (2, 4)
    # 8 devices, 4 heads, capacity 3: every layout fails one divisor
    with pytest.raises(ValueError, match="no \\(data, model\\) layout"):
        choose_serve_mesh_shape(8, cfg, capacity=3)


def test_serve_rules_keep_cache_seq_local():
    class _FakeMesh:
        axis_names = ("data", "model")

        class devices:
            shape = (2, 2)

    rules = serve_sharding_rules()
    # slot pool: slots band over data, kv heads over model, seq LOCAL
    spec = logical_to_spec(("layers", "batch", "cache_seq", "kv_heads",
                            "head_dim"), (4, 8, 64, 4, 16),
                           _FakeMesh, rules)
    assert spec == P(None, "data", None, "model", None)
    # griffin kv_heads=1: divisibility guard replicates the head axis
    spec = logical_to_spec(("layers", "batch", "cache_seq", "kv_heads",
                            "head_dim"), (3, 8, 16, 1, 16),
                           _FakeMesh, rules)
    assert spec == P(None, "data", None, None, None)


def test_mesh_1x1_is_inert():
    cfg = get_config("gpt-micro")
    params = get_family(cfg).init(jax.random.PRNGKey(0), cfg)
    reqs = lambda: [Request(uid=u, prompt=np.arange(1, 5 + u, dtype=np.int32),
                            max_new_tokens=4) for u in range(3)]
    base = ContinuousBatchingEngine(cfg, params, capacity=2, max_len=32,
                                    k=2)
    inert = ContinuousBatchingEngine(cfg, params, capacity=2, max_len=32,
                                     k=2, mesh="1x1")
    assert inert.mesh_plan is None and inert.mesh_shape == "1x1"
    assert inert.n_devices == 1
    got, want = inert.run(reqs()), base.run(reqs())
    for u in want:
        np.testing.assert_array_equal(got[u], want[u])


# ------------------------------------------------- speculative page budget
def test_spec_paged_budget_split_and_cross_pool_release():
    """An explicit --pages budget is the ENGINE's arena budget: target and
    draft split it by per-slot block count (no double-counting), and a
    finished run releases every page of both pools back to its own
    allocator (the cross-pool refcount contract)."""
    from repro.serve import SpeculativeConfig

    cfg = get_config("gpt-micro-big")
    cfg_d = get_config("gpt-micro")
    params = get_family(cfg).init(jax.random.PRNGKey(0), cfg)
    params_d = get_family(cfg_d).init(jax.random.PRNGKey(1), cfg_d)
    eng = ContinuousBatchingEngine(
        cfg, params, capacity=2, max_len=32, k=2, pool="paged", pages=20,
        speculative=SpeculativeConfig(cfg_d, params_d, d=2))
    assert eng.pages_arg == 20
    assert eng.pages_budget is not None
    assert sum(eng.pages_budget) == 20
    assert all(b >= 1 for b in eng.pages_budget)
    assert tuple(m.n_pages for m in eng._metas) == eng.pages_budget
    reqs = [Request(uid=u, prompt=np.arange(1, 7 + u, dtype=np.int32),
                    max_new_tokens=6) for u in range(4)]
    out = eng.run(reqs)
    assert set(out) == set(range(4))
    for alloc, meta in zip(eng._allocs, eng._metas):
        # retained prefix pages sit in the LRU but stay allocatable
        assert alloc.available() == meta.n_pages


def test_spec_paged_default_pages_unsplit():
    """Without an explicit budget each pool keeps its dense-equivalent
    footprint (capacity * blocks-per-slot) — nothing to split."""
    from repro.serve import SpeculativeConfig

    cfg = get_config("gpt-micro-big")
    cfg_d = get_config("gpt-micro")
    params = get_family(cfg).init(jax.random.PRNGKey(0), cfg)
    params_d = get_family(cfg_d).init(jax.random.PRNGKey(1), cfg_d)
    eng = ContinuousBatchingEngine(
        cfg, params, capacity=2, max_len=32, k=2, pool="paged",
        speculative=SpeculativeConfig(cfg_d, params_d, d=2))
    assert eng.pages_arg is None
    assert all(m.n_pages == 2 * m.nblk for m in eng._metas)


# ------------------------------------------------------ flash prefill path
def test_flash_attention_matches_reference_gqa():
    from repro.kernels import ops, ref

    rng = np.random.default_rng(0)
    q = rng.standard_normal((2, 4, 32, 16), np.float32)
    k = rng.standard_normal((2, 2, 32, 16), np.float32)
    v = rng.standard_normal((2, 2, 32, 16), np.float32)
    out = ops.flash_attention(q, k, v, causal=True, mode="interpret",
                              bq=8, bk=8)
    want = ref.flash_attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               atol=1e-5, rtol=1e-5)


def test_flash_block_sizing():
    from repro.models.transformer import _flash_block

    assert _flash_block(48) == 16
    assert _flash_block(128) == 128
    assert _flash_block(384) == 128
    assert _flash_block(8) == 8
    assert _flash_block(20) is None  # pow2 divisor 4 < 8: jnp fallback


@pytest.mark.parametrize("arch", ["gpt-micro", "qwen1.5-0.5b-smoke"])
def test_flash_prefill_engine_token_exact(arch):
    """The kernel-backed engine prefills admissions through the flash
    kernel (interpret mode on CPU) and must emit the same tokens as the
    pure-jnp oracle path — including GQA + tail-padded prompt rows."""
    cfg = get_config(arch)
    params = get_family(cfg).init(jax.random.PRNGKey(0), cfg)
    reqs = lambda: [Request(uid=u,
                            prompt=np.arange(1, 4 + 3 * u, dtype=np.int32)
                            % cfg.vocab_size,
                            max_new_tokens=4) for u in range(3)]
    want = ContinuousBatchingEngine(cfg, params, capacity=2, max_len=48,
                                    k=2).run(reqs())
    cfg_k = cfg.replace(decode_kernel="interpret")
    got = ContinuousBatchingEngine(cfg_k, params, capacity=2, max_len=48,
                                   k=2).run(reqs())
    for u in want:
        np.testing.assert_array_equal(got[u], want[u])


# ------------------------------------------------- multi-device subprocess
_CHILD_PRELUDE = """
    import json
    import jax
    import numpy as np
    from repro.configs.base import get_config
    from repro.models import get_family
    from repro.serve import (ContinuousBatchingEngine, Request,
                             SamplingParams)

    def reqs(cfg, n=6, gen=8):
        return [Request(uid=u,
                        prompt=(np.arange(1, 4 + 2 * u, dtype=np.int32)
                                % cfg.vocab_size),
                        max_new_tokens=gen) for u in range(n)]

    def serve(cfg, params, mesh, **kw):
        eng = ContinuousBatchingEngine(cfg, params, capacity=4,
                                       max_len=48, mesh=mesh, **kw)
        out = eng.run(reqs(cfg))
        return eng, {u: np.asarray(t).tolist() for u, t in out.items()}
"""


def test_sharded_engine_token_exact_dense_and_paged():
    """2x2 mesh over 4 forced host devices: dense and paged slot pools
    emit the single-device engine's exact tokens, the round-robin free
    list bands admissions across replicas, and the committed pool
    shardings match the contract (slots over data, heads over model,
    block tables replicated)."""
    out = _run_subprocess(_CHILD_PRELUDE + """
    cfg = get_config("gpt-micro")
    params = get_family(cfg).init(jax.random.PRNGKey(0), cfg)
    eng1, single = serve(cfg, params, None, k=4)
    eng2, dense = serve(cfg, params, "2x2", k=4)
    assert dense == single, (dense, single)
    _, paged = serve(cfg, params, (2, 2), k=4, pool="paged")
    assert paged == single, (paged, single)
    # round-robin admission order across the two replica bands
    assert eng2.mesh_plan.free_slot_order(4) == [0, 2, 1, 3]
    # committed placement: slots band over data, heads over model,
    # cache seq local
    ksh = eng2.pool["dense"]["k"].sharding
    assert ksh.spec == jax.sharding.PartitionSpec(
        None, "data", None, "model"), ksh.spec
    from repro.launch.specs import slot_pool_shardings
    psh = slot_pool_shardings(cfg, 4, 48, (2, 2), pool="paged")
    assert psh["dense"]["bt"].spec == jax.sharding.PartitionSpec(), \\
        psh["dense"]["bt"].spec
    assert psh["dense"]["k"].spec == jax.sharding.PartitionSpec(
        None, None, None, "model", None), psh["dense"]["k"].spec
    print("OK")
    """)
    assert "OK" in out


@pytest.mark.slow
@pytest.mark.parametrize("k", [1, 8])
def test_sharded_sweep_ring_and_griffin(k):
    """Token-exactness across cache families and decode modes: a
    ring-window transformer and griffin (recurrent + local-attention
    rings), greedy and sampled, sharded 2x2 vs single-device."""
    out = _run_subprocess(_CHILD_PRELUDE + f"""
    ring = get_config("qwen1.5-0.5b-smoke").replace(
        name="ring-smoke", window=8)
    grif = get_config("griffin-micro")
    for cfg in (ring, grif):
        params = get_family(cfg).init(jax.random.PRNGKey(0), cfg)
        for sampling in (None, SamplingParams(temperature=0.8, top_k=20,
                                              seed=7)):
            _, single = serve(cfg, params, None, k={k}, sampling=sampling)
            _, shard = serve(cfg, params, "2x2", k={k}, sampling=sampling)
            assert shard == single, (cfg.name, sampling, shard, single)
    print("OK")
    """)
    assert "OK" in out


@pytest.mark.slow
def test_sharded_paged_prefix_hit_trace():
    """The copy-on-write prefix cache behaves identically under the mesh:
    a shared-prefix wave hits the page registry on both engines, and the
    tokens (prefix-hit fast path included) stay exact."""
    out = _run_subprocess(_CHILD_PRELUDE + """
    cfg = get_config("gpt-micro")
    params = get_family(cfg).init(jax.random.PRNGKey(0), cfg)
    shared = np.arange(1, 17, dtype=np.int32)  # 2 full pages of prefix
    def prefix_reqs():
        return [Request(uid=u,
                        prompt=np.concatenate([shared,
                                               np.int32([u + 1])]),
                        max_new_tokens=6) for u in range(6)]
    def run(mesh):
        eng = ContinuousBatchingEngine(cfg, params, capacity=4,
                                       max_len=48, k=4, pool="paged",
                                       mesh=mesh)
        out = eng.run(prefix_reqs())
        return (eng.prefix_hit_rate,
                {u: np.asarray(t).tolist() for u, t in out.items()})
    hit1, single = run(None)
    hit2, shard = run("2x2")
    assert shard == single, (shard, single)
    assert hit1 > 0 and hit2 == hit1, (hit1, hit2)
    print("OK")
    """)
    assert "OK" in out


@pytest.mark.slow
def test_journal_resume_onto_different_mesh(tmp_path):
    """Elastic restart as a placement-only problem: kill a 2x2-sharded
    engine mid-run (injected crash), resume its journal on a 2-device
    mesh, and the union of committed + resumed tokens equals the
    uninterrupted single-device run."""
    journal = str(tmp_path / "mesh_kill.jsonl")
    _run_subprocess(_CHILD_PRELUDE + f"""
    from repro.serve import EngineKilled, FaultPlan, RequestJournal
    cfg = get_config("gpt-micro")
    params = get_family(cfg).init(jax.random.PRNGKey(0), cfg)
    eng = ContinuousBatchingEngine(
        cfg, params, capacity=4, max_len=48, k=2, mesh="2x2",
        journal=RequestJournal({journal!r}),
        faults=FaultPlan.parse("crash@3"))
    try:
        eng.run(reqs(cfg))
        raise SystemExit("crash fault did not fire")
    except EngineKilled:
        eng.journal.close()
    print("KILLED")
    """, devices=4)
    out = _run_subprocess(_CHILD_PRELUDE + f"""
    from repro.serve import (RequestJournal, read_journal,
                             recovery_requests)
    cfg = get_config("gpt-micro")
    params = get_family(cfg).init(jax.random.PRNGKey(0), cfg)
    _, want = serve(cfg, params, None, k=2)
    resumed, done = recovery_requests(read_journal({journal!r}))
    eng = ContinuousBatchingEngine(cfg, params, capacity=4, max_len=48,
                                   k=2, mesh="1x2")
    got = {{u: np.asarray(t).tolist() for u, t in eng.run(resumed).items()}}
    got.update({{u: np.asarray(t).tolist() for u, t in done.items()}})
    assert got == want, (got, want)
    print("OK")
    """, devices=2)
    assert "OK" in out
