"""Sharding-rule unit tests + multi-device subprocess tests.

The in-process jax here sees 1 CPU device (the dry-run's 512-device flag
must never leak into tests), so anything needing a real multi-device mesh
runs in a subprocess with a small forced host device count.
"""
import json
import os
import subprocess
import sys
import textwrap

import jax
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs.base import get_config
from repro.distributed.sharding import (
    LOGICAL_RULES_SINGLE_POD,
    fsdp_rules,
    inference_rules,
    logical_to_spec,
)
from repro.models import get_family

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


class _FakeMesh:
    axis_names = ("data", "model")

    class devices:
        shape = (16, 16)


def test_divisibility_guard():
    rules = LOGICAL_RULES_SINGLE_POD
    # kv_heads=8 cannot shard over model=16 -> replicated
    spec = logical_to_spec(("layers", "batch", "seq", "kv_heads"),
                           (32, 128, 4096, 8), _FakeMesh, rules)
    assert spec == P(None, "data", None, None)
    # heads=32 shards fine
    spec = logical_to_spec(("layers", "embed", "heads"), (32, 4096, 4096),
                           _FakeMesh, rules)
    assert spec == P(None, None, "model")


def test_used_axis_tracking():
    rules = fsdp_rules(LOGICAL_RULES_SINGLE_POD)
    # activations: batch claims data, so embed must NOT double-use it
    spec = logical_to_spec(("batch", "seq", "embed"), (256, 4096, 4096),
                           _FakeMesh, rules)
    assert spec == P("data", None, None)
    # params: no batch axis -> embed gets data (FSDP)
    spec = logical_to_spec(("layers", "embed", "mlp"), (32, 4096, 11008),
                           _FakeMesh, rules)
    assert spec == P(None, "data", "model")


def test_inference_rules_cache_layout():
    rules = inference_rules(LOGICAL_RULES_SINGLE_POD)
    spec = logical_to_spec(("layers", "batch", "cache_seq", "kv_heads",
                            "head_dim"), (32, 128, 32768, 8, 128),
                           _FakeMesh, rules)
    assert spec == P(None, "data", "model", None, None)
    # weights still shard kv over model when divisible
    spec = logical_to_spec(("layers", "embed", "kv_heads"),
                           (32, 4096, 1024), _FakeMesh, rules)
    assert spec == P(None, None, "model")


def _run_subprocess(code, devices=8):
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = SRC
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env,
                         timeout=560)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


@pytest.mark.slow
def test_sharded_train_step_matches_single_device():
    """The pjit train step on an 8-device mesh computes the same loss as
    1 device (data parallel + tensor parallel correctness)."""
    out = _run_subprocess("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs.base import get_config
        from repro.models import get_family
        from repro.optim import OptimizerConfig, make_optimizer
        from repro.train.steps import make_train_step
        from repro.distributed.sharding import (params_shardings,
            sharding_rules_for_mesh, use_rules)
        from repro.data.synthetic import lm_batch

        cfg = get_config("qwen3-0.6b-smoke")
        fam = get_family(cfg)
        params = fam.init(jax.random.PRNGKey(0), cfg)
        opt_cfg = OptimizerConfig(lr=1e-3)
        init_fn, _ = make_optimizer(opt_cfg)
        opt = init_fn(params)
        batch = {"tokens": jnp.asarray(lm_batch(cfg.vocab_size, 8, 32))}
        step = make_train_step(cfg, opt_cfg)

        # single device result
        p1, o1, m1 = jax.jit(step)(params, opt, batch, jnp.int32(1))

        from repro.launch.mesh import make_mesh_compat
        mesh = make_mesh_compat((4, 2), ("data", "model"))
        rules = sharding_rules_for_mesh(mesh)
        p_sh = params_shardings(fam.param_specs(cfg), mesh, rules,
                                shapes=params)
        params_s = jax.device_put(params, p_sh)
        with mesh, use_rules(mesh, rules):
            p2, o2, m2 = jax.jit(step)(params_s, init_fn(params_s), batch,
                                       jnp.int32(1))
        a, b = float(m1["loss"]), float(m2["loss"])
        assert abs(a - b) < 1e-3, (a, b)
        d = max(float(jnp.max(jnp.abs(x.astype(jnp.float32)
                                      - y.astype(jnp.float32))))
                for x, y in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)))
        assert d < 2e-3, d
        print("MATCH", a, b, d)
    """)
    assert "MATCH" in out


def test_elastic_reshard_roundtrip(tmp_path):
    """Save on an 8-device mesh, restore on 4 devices (elastic restart)."""
    code = f"""
        import jax, jax.numpy as jnp, numpy as np, os
        from repro.configs.base import get_config
        from repro.models import get_family
        from repro.checkpoint import save_checkpoint
        from repro.distributed.elastic import reshard_restore, \\
            choose_mesh_shape
        assert choose_mesh_shape(256, 16) == (16, 16)
        assert choose_mesh_shape(8, 16) == (1, 8)
        assert choose_mesh_shape(12, 16) == (3, 4)

        cfg = get_config("qwen3-0.6b-smoke")
        fam = get_family(cfg)
        params = fam.init(jax.random.PRNGKey(0), cfg)
        save_checkpoint(r"{tmp_path}", 5, params)
        tree, mesh, step, extra = reshard_restore(
            r"{tmp_path}", params, fam.param_specs(cfg), prefer_model=2)
        assert step == 5
        assert mesh.devices.size == len(jax.devices())
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(tree)):
            np.testing.assert_allclose(np.asarray(a, np.float32),
                                       np.asarray(b, np.float32))
        print("ELASTIC-OK", mesh.devices.shape)
    """
    assert "ELASTIC-OK" in _run_subprocess(code, devices=4)


def test_gradient_compression():
    """bf16 + int8(+error feedback) cross-pod psum on a pod-axis mesh."""
    out = _run_subprocess("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.distributed.compression import (make_crosspod_psum,
            init_error_feedback)

        from repro.launch.mesh import make_mesh_compat
        mesh = make_mesh_compat((2, 2, 2), ("pod", "data", "model"))
        grads = {"w": jnp.asarray(np.random.default_rng(0)
                                  .standard_normal((8, 16)), jnp.float32)}
        # replicated grads: psum/n == identity -> lossless check of plumbing
        f16 = make_crosspod_psum(mesh, method="bf16")
        with mesh:
            out16 = f16(grads)
        err = np.max(np.abs(np.asarray(out16["w"]) - np.asarray(grads["w"])))
        assert err < 1e-2, err

        f8 = make_crosspod_psum(mesh, method="int8")
        ef = init_error_feedback(grads)
        with mesh:
            out8, ef = f8(grads, ef)
        err8 = np.max(np.abs(np.asarray(out8["w"]) - np.asarray(grads["w"])))
        assert err8 < 0.1, err8
        # error feedback carries the quantization residual
        assert float(jnp.sum(jnp.abs(ef["w"]))) > 0
        print("COMPRESS-OK", err, err8)
    """)
    assert "COMPRESS-OK" in out
