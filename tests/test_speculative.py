"""Speculative-decoding invariants.

The speculative engine's contract extends the serve engine's: GREEDY
speculative decode is token-exact versus the sequential ``generate()``
loop — every committed token is the target's own argmax after its
committed prefix, so the draft (and the acceptance rate) can only change
speed, never output.  That must hold for any speculation depth, any
acceptance level (draft == target, correlated, or unrelated), mid-chunk
eos, budget truncation mid-chunk, and every slot-cache layout (full KV,
ring-buffer windows, recurrent states).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data.synthetic import lm_batch
from repro.launch.serve import build_params, generate
from repro.models import get_family
from repro.serve import (
    ContinuousBatchingEngine,
    Request,
    SpeculativeConfig,
    spec_pair_supported,
)

MAX_LEN = 32


def _mixed_requests(cfg, specs, *, uid0=0, seed0=50):
    reqs = []
    for i, (plen, gen) in enumerate(specs):
        prompt = lm_batch(cfg.vocab_size, 1, plen, seed=seed0 + i)[0]
        reqs.append(Request(uid=uid0 + i, prompt=prompt,
                            max_new_tokens=gen))
    return reqs


def _sequential_baseline(cfg, params, reqs, max_len=MAX_LEN):
    """Each request alone through the TARGET-only prefill+decode loop —
    the speculative engine must reproduce these tokens bit-for-bit."""
    out = {}
    for r in reqs:
        toks = generate(cfg, params, jnp.asarray(r.prompt)[None],
                        max_new_tokens=r.max_new_tokens, max_len=max_len)
        out[r.uid] = np.asarray(toks[0])
    return out


def _perturbed(params, scale=3e-3, seed=1):
    """A draft that ALMOST agrees with the target: same config, weights
    nudged — acceptance lands strictly between 0 and 1, so tests exercise
    partial commits and mid-chunk rollback, not just the two extremes."""
    keys = jax.random.split(jax.random.PRNGKey(seed),
                            len(jax.tree.leaves(params)))
    flat, treedef = jax.tree.flatten(params)
    flat = [p + scale * jax.random.normal(k, p.shape, p.dtype)
            for p, k in zip(flat, keys)]
    return jax.tree.unflatten(treedef, flat)


def _run_spec(cfg_t, params_t, cfg_d, params_d, reqs, *, d, k=2,
              capacity=2, max_len=MAX_LEN):
    engine = ContinuousBatchingEngine(
        cfg_t, params_t, capacity=capacity, max_len=max_len,
        prefill_bucket=4, k=k,
        speculative=SpeculativeConfig(cfg_d, params_d, d=d))
    got = engine.run(reqs)
    return engine, got


@pytest.mark.parametrize("d", [2, 4])
def test_spec_exact_grown_transformer(d, gpt_micro_cfg, gpt_micro_big_cfg):
    """The paper's pair end-to-end: the pretrained SOURCE (gpt-micro)
    drafts for the target GROWN from it (gpt-micro-big via Mango) — the
    first subsystem connecting the growth core to the serving stack.
    Greedy speculative tokens must equal the target-only sequential
    tokens exactly, for any acceptance the pair happens to achieve."""
    cfg_t, cfg_d = gpt_micro_big_cfg, gpt_micro_cfg
    params_t, src_cfg, params_d = build_params(
        cfg_t, grow_from=cfg_d.name, grow_method="mango",
        return_source=True)
    assert src_cfg.name == cfg_d.name
    specs = [(4, 7), (9, 3), (6, 9), (5, 2), (11, 5)]
    reqs = _mixed_requests(cfg_t, specs, seed0=70)
    engine, got = _run_spec(cfg_t, params_t, cfg_d, params_d, reqs, d=d)
    want = _sequential_baseline(cfg_t, params_t, reqs)
    assert set(got) == set(want)
    for uid in want:
        np.testing.assert_array_equal(got[uid], want[uid],
                                      err_msg=f"uid {uid}")
    # the pool was oversubscribed (slot reuse under speculation)
    assert len(reqs) > engine.capacity
    assert engine.n_spec_proposed > 0
    assert 0.0 <= engine.acceptance_rate <= 1.0


def test_spec_self_draft_accepts_everything(qwen_smoke_cfg,
                                            qwen_smoke_params):
    """draft == target: greedy acceptance must be exactly 1.0 (modulo
    budget clipping, which the telemetry excludes) and every block
    commits its full d+1 tokens — the degenerate upper bound that pins
    the acceptance accounting."""
    cfg, params = qwen_smoke_cfg, qwen_smoke_params
    reqs = _mixed_requests(cfg, [(3, 9), (7, 11), (5, 6)], seed0=20)
    engine, got = _run_spec(cfg, params, cfg, params, reqs, d=3)
    want = _sequential_baseline(cfg, params, reqs)
    for uid in want:
        np.testing.assert_array_equal(got[uid], want[uid],
                                      err_msg=f"uid {uid}")
    assert engine.n_spec_proposed > 0
    assert engine.acceptance_rate == 1.0


@pytest.mark.parametrize(
    "d", [pytest.param(2, marks=pytest.mark.slow), 4])
def test_spec_exact_griffin(d):
    """Recurrent target + recurrent draft (griffin-micro): partial
    acceptance must roll rglru state, conv tails, AND the local-attention
    ring back to each row's accepted boundary.  Generations run past the
    window (16), so the rings genuinely wrap under speculation."""
    from repro.configs.base import get_config
    cfg = get_config("griffin-micro")
    params = get_family(cfg).init(jax.random.PRNGKey(0), cfg)
    draft = _perturbed(params, scale=1e-1)
    reqs = _mixed_requests(cfg, [(4, 20), (9, 16), (6, 18)], seed0=40)
    engine, got = _run_spec(cfg, params, cfg, draft, reqs, d=d,
                            capacity=2)
    want = _sequential_baseline(cfg, params, reqs)
    for uid in want:
        np.testing.assert_array_equal(got[uid], want[uid],
                                      err_msg=f"uid {uid}")
    # the perturbed draft is correlated but not identical: speculation
    # must really have been exercised in BOTH regimes
    assert 0.0 < engine.acceptance_rate < 1.0, engine.acceptance_rate


def test_griffin_verify_stacks_only_o1_state():
    """Verify memory contract: the recurrent verify stacks ONLY the O(1)
    recurrent leaves per chunk position — the O(window) local-attention
    rings commit via accept-masked restore, so a chunk of length S must
    not multiply ring memory by S."""
    from repro.configs.base import get_config
    cfg = get_config("griffin-micro")
    fam = get_family(cfg)
    params = fam.init(jax.random.PRNGKey(0), cfg)
    cache = fam.init_cache(cfg, 2, MAX_LEN)
    tokens = jnp.zeros((2, 5), jnp.int32)
    positions = jnp.full((2,), 3, jnp.int32)
    _, pending = jax.eval_shape(
        lambda: fam.verify_step_slots(params, tokens, positions, cache,
                                      cfg))
    # rings: post-chunk bytes only (same shape as the cache, no S axis)
    assert pending["attn_new"]["k"].shape == cache["attn"]["k"].shape
    # recurrent state: stacked with a leading chunk axis
    assert pending["rec"]["h"].shape == (5,) + cache["rec"]["h"].shape


def test_spec_exact_griffin_pair_micro_big():
    """griffin-micro drafting for griffin-micro-big — the recurrent
    small→large pair (independent inits: acceptance may be low, output
    must still be the target's exactly)."""
    from repro.configs.base import get_config
    cfg_t = get_config("griffin-micro-big")
    cfg_d = get_config("griffin-micro")
    params_t = get_family(cfg_t).init(jax.random.PRNGKey(0), cfg_t)
    params_d = get_family(cfg_d).init(jax.random.PRNGKey(1), cfg_d)
    reqs = _mixed_requests(cfg_t, [(5, 8), (8, 6)], seed0=90)
    engine, got = _run_spec(cfg_t, params_t, cfg_d, params_d, reqs, d=2)
    want = _sequential_baseline(cfg_t, params_t, reqs)
    for uid in want:
        np.testing.assert_array_equal(got[uid], want[uid],
                                      err_msg=f"uid {uid}")


def test_spec_exact_xlstm():
    """xLSTM's stacked-state rollback (mLSTM C/n/m, sLSTM carries, conv
    tails) under partial acceptance."""
    from repro.configs.base import get_config
    cfg = get_config("xlstm-1.3b-smoke")
    params = get_family(cfg).init(jax.random.PRNGKey(0), cfg)
    draft = _perturbed(params, scale=1e-2)
    reqs = _mixed_requests(cfg, [(4, 8), (7, 10)], seed0=60)
    engine, got = _run_spec(cfg, params, cfg, draft, reqs, d=2)
    want = _sequential_baseline(cfg, params, reqs)
    for uid in want:
        np.testing.assert_array_equal(got[uid], want[uid],
                                      err_msg=f"uid {uid}")


def test_spec_exact_ring_window_transformer(qwen_smoke_cfg):
    """Sliding-window transformer target: the deferred commit scatter
    writes ring slots (pos % window) for accepted positions only;
    generations run far past window=8 so rejected overshoot would corrupt
    live ring entries if it were ever written."""
    cfg = qwen_smoke_cfg.replace(window=8)
    params = get_family(cfg).init(jax.random.PRNGKey(0), cfg)
    draft = _perturbed(params, scale=1e-2)
    reqs = _mixed_requests(cfg, [(4, 18), (9, 14), (6, 16)], seed0=80)
    engine, got = _run_spec(cfg, params, cfg, draft, reqs, d=3)
    want = _sequential_baseline(cfg, params, reqs)
    for uid in want:
        np.testing.assert_array_equal(got[uid], want[uid],
                                      err_msg=f"uid {uid}")


def test_spec_eos_mid_chunk(gpt_micro_cfg):
    """An eos landing strictly inside a verify chunk must truncate the
    commit exactly there: outputs after the eos are invalid, the eos is
    never fed into either model, and the neighbour slot is unaffected —
    the speculative mirror of the macro loop's mid-block eos rule."""
    cfg = gpt_micro_cfg
    params = get_family(cfg).init(jax.random.PRNGKey(0), cfg)
    reqs = _mixed_requests(cfg, [(6, 12), (8, 12)], seed0=30)
    base = _sequential_baseline(cfg, params, reqs)
    d = 4
    # choose an eos whose FIRST occurrence is strictly inside the first
    # d+1-token chunk, so the row dies mid-verify
    eos, stop = None, None
    for i in range(1, min(d, len(base[0]))):
        cand = int(base[0][i])
        if int(np.argmax(base[0] == cand)) == i:
            eos, stop = cand, i + 1
            break
    assert eos is not None, "trace has no mid-chunk eos candidate"
    reqs[0].eos_id = eos
    engine, got = _run_spec(cfg, params, cfg, params, reqs, d=d)
    np.testing.assert_array_equal(got[0], base[0][:stop])
    np.testing.assert_array_equal(got[1], base[1])
    assert 1 < stop < d + 1  # really fired inside one chunk


def test_spec_pair_probe_rejections(qwen_smoke_cfg, gpt_micro_cfg):
    """The pair probe reports per-mode servability and rejects vocab
    mismatches and non-servable drafts; the engine refuses such pairs
    before allocating anything."""
    from repro.configs.base import get_config
    ok, why = spec_pair_supported(gpt_micro_cfg, qwen_smoke_cfg)
    assert not ok and "vocab" in why
    hubert = get_config("hubert-xlarge-smoke")
    ok, why = spec_pair_supported(qwen_smoke_cfg, hubert)
    assert not ok
    # per-mode detail: the failing side is named, the healthy side reported
    assert "draft 'hubert-xlarge-smoke': NOT SERVABLE" in why
    assert "target 'qwen1.5-0.5b-smoke': ok" in why
    ok, _ = spec_pair_supported(qwen_smoke_cfg, qwen_smoke_cfg, d=0)
    assert not ok
    # a verify chunk must fit the ring: window 8 rejects d >= 8
    windowed = qwen_smoke_cfg.replace(window=8)
    ok, why = spec_pair_supported(windowed, windowed, d=8)
    assert not ok and "ring" in why
    with pytest.raises(NotImplementedError, match="vocab"):
        ContinuousBatchingEngine(
            gpt_micro_cfg, {}, capacity=1, max_len=MAX_LEN,
            speculative=SpeculativeConfig(qwen_smoke_cfg, {}, d=2))


def test_spec_slot_reuse_no_stale_state(qwen_smoke_cfg, qwen_smoke_params):
    """A recycled slot under speculation sees exactly what a fresh engine
    would — eviction + admission overwrite BOTH pools (target and
    draft)."""
    cfg, params = qwen_smoke_cfg, qwen_smoke_params
    draft = _perturbed(params)
    wave1 = _mixed_requests(cfg, [(8, 6), (11, 6)], uid0=0, seed0=10)
    wave2 = _mixed_requests(cfg, [(5, 8), (9, 3)], uid0=100, seed0=90)
    used, _ = _run_spec(cfg, params, cfg, draft, wave1, d=3)
    got = used.run(wave2)
    want = _sequential_baseline(cfg, params, wave2)
    for uid in want:
        np.testing.assert_array_equal(got[uid], want[uid],
                                      err_msg=f"uid {uid}")
