"""Substrate tests: optimizer, schedules, checkpoint, data determinism."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.checkpoint import (CheckpointManager, latest_step,
                              load_checkpoint, save_checkpoint)
from repro.data.synthetic import frames_batch, lm_batch, vision_batch
from repro.optim import (OptimizerConfig, adamw_init, adamw_update,
                         cosine_schedule, linear_warmup_cosine,
                         make_optimizer)


# ------------------------------------------------------------- optimizer
def _quad_problem():
    target = jnp.asarray([1.5, -2.0, 0.5])
    params = {"w": jnp.zeros(3)}

    def loss(p):
        return jnp.sum(jnp.square(p["w"] - target))

    return params, loss, target


def test_adamw_converges():
    params, loss, target = _quad_problem()
    state = adamw_init(params)
    for step in range(1, 300):
        g = jax.grad(loss)(params)
        params, state = adamw_update(params, state, g, jnp.int32(step),
                                     lr=5e-2, weight_decay=0.0)
    np.testing.assert_allclose(np.asarray(params["w"]), np.asarray(target),
                               atol=1e-2)


@pytest.mark.parametrize("moment_dtype", ["float32", "bfloat16"])
@pytest.mark.parametrize("master", [False, True])
def test_full_optimizer_converges(moment_dtype, master):
    params, loss, target = _quad_problem()
    if master:
        params = jax.tree.map(lambda p: p.astype(jnp.bfloat16), params)
    cfg = OptimizerConfig(lr=5e-2, weight_decay=0.0,
                          moment_dtype=moment_dtype, master_weights=master,
                          clip_norm=10.0)
    init_fn, update_fn = make_optimizer(cfg)
    state = init_fn(params)
    for step in range(1, 400):
        g = jax.grad(lambda p: loss(jax.tree.map(
            lambda x: x.astype(jnp.float32), p)))(params)
        params, state, metrics = update_fn(params, state, g,
                                           jnp.int32(step))
    got = np.asarray(params["w"], np.float32)
    np.testing.assert_allclose(got, np.asarray(target), atol=5e-2)
    assert np.isfinite(float(metrics["grad_norm"]))


def test_clipping_bounds_update():
    cfg = OptimizerConfig(lr=1.0, clip_norm=1e-3, weight_decay=0.0)
    init_fn, update_fn = make_optimizer(cfg)
    params = {"w": jnp.zeros(4)}
    state = init_fn(params)
    g = {"w": jnp.full(4, 1e6)}
    p2, _, m = update_fn(params, state, g, jnp.int32(1))
    assert float(m["grad_norm"]) > 1e5
    assert float(jnp.max(jnp.abs(p2["w"]))) < 2.0  # adam step bounded


def test_schedules():
    s = linear_warmup_cosine(1.0, 10, 100)
    assert float(s(jnp.float32(0))) == 0.0
    assert abs(float(s(jnp.float32(10))) - 1.0) < 1e-6
    assert float(s(jnp.float32(100))) < 0.2
    c = cosine_schedule(1.0, 100)
    assert float(c(jnp.float32(0))) == 1.0


# ------------------------------------------------------------ checkpoint
def _tree(seed=0):
    k = jax.random.PRNGKey(seed)
    return {"a": jax.random.normal(k, (4, 8)),
            "b": {"c": jnp.arange(7, dtype=jnp.int32),
                  "d": jax.random.normal(k, (3,)).astype(jnp.bfloat16)}}


def test_checkpoint_roundtrip(tmp_path):
    tree = _tree()
    save_checkpoint(str(tmp_path), 7, tree, extra={"note": "x"})
    out, step, extra = load_checkpoint(str(tmp_path), tree)
    assert step == 7 and extra["note"] == "x"
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(out)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_checkpoint_detects_corruption(tmp_path):
    tree = _tree()
    path = save_checkpoint(str(tmp_path), 1, tree)
    victim = os.path.join(path, "leaf_00000.npy")
    arr = np.load(victim)
    arr.ravel()[0] += 1.0
    np.save(victim, arr)
    with pytest.raises(IOError, match="checksum"):
        load_checkpoint(str(tmp_path), tree)


def test_manager_keep_k_and_resume(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2, every=1)
    tree = _tree()
    for s in range(1, 6):
        mgr.maybe_save(s, tree)
    steps = sorted(int(n.split("_")[1]) for n in os.listdir(tmp_path)
                   if n.startswith("step_"))
    assert steps == [4, 5]
    out = mgr.restore_latest(tree)
    assert out is not None and out[1] == 5


def test_async_save(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2, every=1, async_save=True)
    mgr.maybe_save(3, _tree())
    mgr.wait()
    assert latest_step(str(tmp_path)) == 3


def test_async_save_failure_surfaces(tmp_path):
    """Regression: a failing async save used to die silently on its
    daemon thread — the train loop believed the checkpoint existed.  The
    worker's exception must re-raise on ``wait()`` (or the next
    ``maybe_save``), once, and the manager must stay usable after."""
    blocker = tmp_path / "ckpt"
    blocker.write_text("a file where the checkpoint dir should go")
    mgr = CheckpointManager(str(blocker), keep=2, every=1, async_save=True)
    mgr.maybe_save(1, _tree())
    with pytest.raises(OSError):
        mgr.wait()
    mgr.wait()  # the error was consumed, not raised forever
    # the NEXT maybe_save also surfaces a pending failure (a loop that
    # never calls wait() between saves still finds out)
    mgr.maybe_save(2, _tree())
    with pytest.raises(OSError):
        mgr.maybe_save(3, _tree())
    blocker.unlink()
    mgr.maybe_save(4, _tree())
    mgr.wait()
    assert latest_step(str(blocker)) == 4


def test_atomicity_no_partial_dirs(tmp_path):
    save_checkpoint(str(tmp_path), 2, _tree())
    assert not any(n.startswith("tmp.") for n in os.listdir(tmp_path))


def test_back_to_back_maybe_save_joins_inflight(tmp_path, monkeypatch):
    """Two ``maybe_save`` calls with the first still on the wire: the
    second must JOIN the in-flight save (one at a time — no overlapping
    writers racing on the same step dir), and both checkpoints land."""
    import threading
    import time

    import repro.checkpoint.manager as M

    release = threading.Event()
    started = threading.Event()
    real, calls = M.save_checkpoint, []

    def slow_save(ckpt_dir, step, tree, extra=None):
        calls.append(step)
        started.set()
        assert release.wait(30), "test deadlock: save never released"
        return real(ckpt_dir, step, tree, extra)

    monkeypatch.setattr(M, "save_checkpoint", slow_save)
    mgr = CheckpointManager(str(tmp_path), keep=3, every=1,
                            async_save=True)
    assert mgr.maybe_save(1, _tree())
    assert started.wait(30)
    t = threading.Thread(
        target=lambda: mgr.maybe_save(2, _tree()), daemon=True)
    t.start()
    time.sleep(0.2)
    assert t.is_alive(), "second maybe_save should block on the join"
    assert calls == [1], "saves must never overlap"
    release.set()
    t.join(30)
    assert not t.is_alive()
    mgr.wait()
    assert calls == [1, 2]
    assert latest_step(str(tmp_path)) == 2


# ------------------------------------------------------------------ data
def test_lm_data_deterministic_and_learnable():
    a = lm_batch(997, 4, 64, seed=1, step=5)
    b = lm_batch(997, 4, 64, seed=1, step=5)
    np.testing.assert_array_equal(a, b)
    c = lm_batch(997, 4, 64, seed=1, step=6)
    assert not np.array_equal(a, c)
    # learnable: next token is a deterministic-ish function of current
    nxt = (5 * a[:, :-1] + 17) % 997
    close = np.abs(a[:, 1:] - nxt) <= 4
    assert close.mean() > 0.95


def test_vision_and_frames_shapes():
    v = vision_batch(16, 3, 32, 8, seed=0, step=0)
    assert v["inputs"].shape == (3, 16, 8 * 8 * 3)
    assert v["labels"].shape == (3,)
    f = frames_batch(24, 31, 2, 16, seed=0, step=0)
    assert f["inputs"].shape == (2, 16, 24)
    assert f["tokens"].shape == (2, 16)


@settings(max_examples=10, deadline=None)
@given(shard=st.integers(0, 7), step=st.integers(0, 100))
def test_data_shard_independence(shard, step):
    """Different shards at the same step never collide (fault-tolerant
    recomputation contract)."""
    a = lm_batch(503, 2, 32, seed=0, step=step, shard=shard)
    b = lm_batch(503, 2, 32, seed=0, step=step, shard=shard + 8)
    assert not np.array_equal(a, b)
    np.testing.assert_array_equal(
        a, lm_batch(503, 2, 32, seed=0, step=step, shard=shard))
