"""Trainer integration: loss goes down, growth helps, resume works,
microbatch accumulation is consistent with full-batch."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_config
from repro.data.synthetic import lm_batch
from repro.launch.train import train
from repro.optim import OptimizerConfig, make_optimizer
from repro.train.steps import make_train_step


@pytest.mark.slow
def test_training_reduces_loss():
    _, hist = train("gpt-micro", steps=80, batch=8, seq=64, lr=1e-3,
                    warmup=5, log_every=10, log_fn=lambda *_: None)
    assert hist[-1]["loss"] < hist[0]["loss"] * 0.9, hist


def test_resume_from_checkpoint(tmp_path):
    d = str(tmp_path / "ck")
    train("gpt-micro", steps=20, batch=4, seq=48, ckpt_dir=d, ckpt_every=10,
          log_fn=lambda *_: None)
    _, hist = train("gpt-micro", steps=30, batch=4, seq=48, ckpt_dir=d,
                    resume=True, log_every=5, log_fn=lambda *_: None)
    assert hist[0]["step"] >= 20  # continued, not restarted


@pytest.mark.slow
def test_grown_run_beats_scratch_early(tmp_path):
    src_dir = str(tmp_path / "gpt-micro")
    train("gpt-micro", steps=60, batch=4, seq=48, lr=2e-3, warmup=5,
          ckpt_dir=src_dir, ckpt_every=60, log_fn=lambda *_: None)
    _, hist_g = train("gpt-micro-big", steps=8, batch=4, seq=48,
                      grow_from="gpt-micro", grow_src_ckpt=src_dir,
                      grow_method="mango", grow_steps=15, log_every=4,
                      log_fn=lambda *_: None)
    _, hist_s = train("gpt-micro-big", steps=8, batch=4, seq=48,
                      log_every=4, log_fn=lambda *_: None)
    assert hist_g[0]["loss"] < hist_s[0]["loss"] - 0.5, \
        (hist_g[0], hist_s[0])


def test_microbatch_accumulation_matches_full_batch():
    cfg = get_config("gpt-micro")
    from repro.models import get_family
    fam = get_family(cfg)
    params = fam.init(jax.random.PRNGKey(0), cfg)
    opt_cfg = OptimizerConfig(lr=1e-3, clip_norm=None)
    init_fn, _ = make_optimizer(opt_cfg)
    batch = {"tokens": jnp.asarray(lm_batch(cfg.vocab_size, 8, 32))}

    s1 = jax.jit(make_train_step(cfg, opt_cfg, n_microbatches=1))
    s4 = jax.jit(make_train_step(cfg, opt_cfg, n_microbatches=4))
    p1, _, m1 = s1(params, init_fn(params), batch, jnp.int32(1))
    p4, _, m4 = s4(params, init_fn(params), batch, jnp.int32(1))
    assert abs(float(m1["loss"]) - float(m4["loss"])) < 1e-4
    d = max(float(jnp.max(jnp.abs(a - b)))
            for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p4)))
    assert d < 5e-5, d
